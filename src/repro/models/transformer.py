"""Model assembly: blocks → layer stack (scan or unrolled) → LM API.

``build_model(cfg)`` returns a :class:`Model` of pure functions:

* ``init(rng) → params`` — per-layer params stacked on a leading ``L``
  axis, consumed via ``jax.lax.scan`` (keeps HLO size O(1) in depth).
* ``forward(params, tokens) → (logits, aux)`` — full-sequence.
* ``loss(params, tokens, labels) → scalar`` — mean xent + MoE aux.
* ``init_cache / prefill / decode_step`` — serving path.
* ``param_specs() → pytree[PartitionSpec]`` — logical shardings.

``layer_mode="unroll"`` replaces the scan with a Python loop — needed by
the roofline pass, because XLA's cost analysis counts a while-loop body
once (see launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distribution.sharding import current_ctx, pspec, shard
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rk
from repro.models.common import ModelCfg
from repro.models.layers import (apply_norm, embed, init_embed, init_mlp,
                                 lm_logits, mlp, rmsnorm, sinusoidal_pe,
                                 softmax_xent)


class Model(NamedTuple):
    cfg: ModelCfg
    init: Callable
    forward: Callable          # (params, tokens) -> (logits, aux)
    loss: Callable             # (params, tokens, labels) -> loss
    init_cache: Callable       # (batch, max_len) -> cache
    prefill: Callable          # (params, tokens, cache) -> (logits, cache)
    decode_step: Callable      # (params, tok[B,1], cache, pos[B]) -> (logits, cache)
    param_specs: Callable      # () -> pytree of PartitionSpec
    cache_specs: Callable      # (batch, max_len) -> pytree of PartitionSpec


def _norm_param(cfg, key):
    if cfg.norm == "layernorm_np":
        return {}
    return {key: jnp.zeros((cfg.d_model,), cfg.p_dtype)}


def _maybe(p, key):
    return p.get(key)


# ---------------------------------------------------------------------------
# Dense / MoE transformer block
# ---------------------------------------------------------------------------

def init_dense_block(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    p = {}
    p.update({f"ln1{k}": v for k, v in _norm_param(cfg, "s").items()})
    p.update({f"ln2{k}": v for k, v in _norm_param(cfg, "s").items()})
    p["attn"] = attn.init_mla(k1, cfg) if cfg.mla else \
        attn.init_attention(k1, cfg)
    p["mlp"] = init_moe(k2, cfg) if cfg.moe else init_mlp(k2, cfg)
    return p


def init_moe(key, cfg):
    return moe_mod.init_moe(key, cfg)


def _block_mlp(cfg, p, x, *, decode: bool):
    if cfg.moe is not None:
        return moe_mod.moe(cfg, p["mlp"], x, decode=decode)
    return mlp(cfg, p["mlp"], x), jnp.float32(0.0)


def dense_block(cfg, p, x, pos, *, want_kv: bool):
    """Full-seq block.  Returns (x, kv_for_cache, aux)."""
    h = apply_norm(cfg, x, _maybe(p, "ln1s"))
    if cfg.mla is not None:
        q, k, v, latent = attn._mla_qkv(cfg, p["attn"], h, pos)
        o = attn._mla_sdpa(cfg, q, k, v)
        B, S = x.shape[:2]
        a = jnp.einsum("bse,ed->bsd",
                       o.reshape(B, S, cfg.n_heads * cfg.mla.v_dim),
                       p["attn"]["wo"].astype(x.dtype))
        kv = latent if want_kv else None
    else:
        q, k, v = attn._qkv(cfg, p["attn"], h, pos)
        o = attn.sdpa(cfg, q, k, v)
        B, S = x.shape[:2]
        a = jnp.einsum("bse,ed->bsd", o.reshape(B, S, cfg.q_dim),
                       p["attn"]["wo"].astype(x.dtype))
        kv = (k, v) if want_kv else None
    x = shard(x + a, "batch", "act_seq", "embed")
    h = apply_norm(cfg, x, _maybe(p, "ln2s"))
    y, aux = _block_mlp(cfg, p, h, decode=False)
    return shard(x + y, "batch", "act_seq", "embed"), kv, aux


def dense_block_decode(cfg, p, x, cache_l, pos):
    """One-token block.  cache_l: per-layer cache dict (write-through)."""
    h = apply_norm(cfg, x, _maybe(p, "ln1s"))
    if cfg.mla is not None:
        c_kv, k_rope = attn.mla_append_kv(cfg, p["attn"], h,
                                          cache_l["c_kv"],
                                          cache_l["k_rope"], pos)
        a = attn.mla_decode(cfg, p["attn"], h, c_kv, k_rope, pos)
        cache_l = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        k_c, v_c = attn.append_kv(cfg, p["attn"], h, cache_l["k"],
                                  cache_l["v"], pos)
        a = attn.decode_attention(cfg, p["attn"], h, k_c, v_c, pos)
        cache_l = {"k": k_c, "v": v_c}
    x = x + a
    h = apply_norm(cfg, x, _maybe(p, "ln2s"))
    y, _ = _block_mlp(cfg, p, h, decode=True)
    return x + y, cache_l


# ---------------------------------------------------------------------------
# zamba2 hybrid block (mamba2 backbone + shared attention block)
# ---------------------------------------------------------------------------

def init_hybrid_shared(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.zeros((cfg.d_model,), cfg.p_dtype),
            "ln2": jnp.zeros((cfg.d_model,), cfg.p_dtype),
            "attn": attn.init_attention(k1, cfg),
            "mlp": init_mlp(k2, cfg)}


def shared_attn_block(cfg, sp, x, pos, *, want_kv: bool):
    h = rmsnorm(x, sp["ln1"])
    q, k, v = attn._qkv(cfg, sp["attn"], h, pos)
    o = attn.sdpa(cfg, q, k, v)
    B, S = x.shape[:2]
    a = jnp.einsum("bse,ed->bsd", o.reshape(B, S, cfg.q_dim),
                   sp["attn"]["wo"].astype(x.dtype))
    x = x + a
    x = x + mlp(cfg, sp["mlp"], rmsnorm(x, sp["ln2"]))
    return x, ((k, v) if want_kv else None)


def shared_attn_decode(cfg, sp, x, k_c, v_c, pos):
    h = rmsnorm(x, sp["ln1"])
    k_c, v_c = attn.append_kv(cfg, sp["attn"], h, k_c, v_c, pos)
    a = attn.decode_attention(cfg, sp["attn"], h, k_c, v_c, pos)
    x = x + a
    x = x + mlp(cfg, sp["mlp"], rmsnorm(x, sp["ln2"]))
    return x, k_c, v_c


# ---------------------------------------------------------------------------
# Layer-stack drivers
# ---------------------------------------------------------------------------

def _stacked_init(init_one, key, cfg):
    keys = jax.random.split(key, cfg.n_layers)
    return jax.vmap(lambda k: init_one(k, cfg))(keys)


def _split_layer(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def build_model(cfg: ModelCfg, layer_mode: str = "scan") -> Model:
    if cfg.family == "rwkv6":
        return _build_rwkv(cfg, layer_mode)
    if cfg.family == "hybrid":
        return _build_hybrid(cfg, layer_mode)
    return _build_dense(cfg, layer_mode)


def _positions(tokens):
    return jnp.arange(tokens.shape[1])


def _embed_in(cfg, params, tokens):
    x = embed(cfg, params["embed"], tokens)
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_pe(tokens.shape[1], cfg.d_model
                              ).astype(x.dtype)[None]
    return x


def _sinusoidal_at(pos, d_model, dtype):
    """Position-embedding rows at dynamic positions ``pos`` [B]."""
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos[:, None].astype(jnp.float32) / jnp.power(1e4, dim / d_model)
    pe = jnp.zeros((pos.shape[0], d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


# -- dense / moe -----------------------------------------------------------

def _build_dense(cfg: ModelCfg, layer_mode: str) -> Model:
    L = cfg.n_layers

    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "embed": init_embed(k1, cfg),
            "layers": _stacked_init(init_dense_block, k2, cfg),
            "final_norm": (jnp.zeros((cfg.d_model,), cfg.p_dtype)
                           if cfg.norm == "rmsnorm" else jnp.zeros((0,))),
        }

    def _stack_forward(params, x, pos, want_kv):
        aux0 = jnp.float32(0.0)

        def body_fn(x, p_l):
            y, kv, aux = dense_block(cfg, p_l, x, pos, want_kv=want_kv)
            return y, kv, aux
        body_fn = _remat(cfg, body_fn)

        if layer_mode == "scan":
            def scan_body(carry, p_l):
                x, aux = carry
                y, kv, a = body_fn(x, p_l)
                return (y, aux + a), kv
            (x, aux), kvs = jax.lax.scan(scan_body, (x, aux0),
                                         params["layers"])
        else:
            aux, kvs_list = aux0, []
            for i in range(L):
                x, kv, a = body_fn(x, _split_layer(params["layers"], i))
                aux = aux + a
                kvs_list.append(kv)
            kvs = (jax.tree.map(lambda *xs: jnp.stack(xs), *kvs_list)
                   if want_kv else None)
        return x, kvs, aux

    def forward(params, tokens):
        x = _embed_in(cfg, params, tokens)
        x, _, aux = _stack_forward(params, x, _positions(tokens), False)
        x = apply_norm(cfg, x, params["final_norm"]
                       if cfg.norm == "rmsnorm" else None)
        return lm_logits(cfg, params["embed"], x), aux

    def loss(params, tokens, labels):
        logits, aux = forward(params, tokens)
        return softmax_xent(logits, labels) + aux

    def init_cache(batch, max_len):
        if cfg.mla is not None:
            return attn.init_mla_cache(cfg, batch, max_len)
        return attn.init_kv_cache(cfg, batch, max_len)

    def prefill(params, tokens, cache):
        S = tokens.shape[1]
        x = _embed_in(cfg, params, tokens)
        x, kvs, _ = _stack_forward(params, x, _positions(tokens), True)
        x = apply_norm(cfg, x, params["final_norm"]
                       if cfg.norm == "rmsnorm" else None)
        logits = lm_logits(cfg, params["embed"], x[:, -1:])
        if cfg.mla is not None:
            c_kv, k_rope = kvs
            cache = {
                "c_kv": jax.lax.dynamic_update_slice_in_dim(
                    cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, 2),
                "k_rope": jax.lax.dynamic_update_slice_in_dim(
                    cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                    0, 2),
            }
        else:
            k, v = kvs
            cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), 0, 2),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), 0, 2),
            }
        return logits, cache

    def decode_step(params, tok, cache, pos):
        x = embed(cfg, params["embed"], tok)
        if cfg.pos == "sinusoidal":
            x = x + _sinusoidal_at(pos, cfg.d_model, x.dtype)[:, None]

        def body_fn(x, p_l, cache_l):
            return dense_block_decode(cfg, p_l, x, cache_l, pos)

        if layer_mode == "scan":
            def scan_body(x, inp):
                p_l, cache_l = inp
                return body_fn(x, p_l, cache_l)
            x, cache = jax.lax.scan(scan_body, x, (params["layers"], cache))
        else:
            outs = []
            for i in range(L):
                x, c = body_fn(x, _split_layer(params["layers"], i),
                               _split_layer(cache, i))
                outs.append(c)
            cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        x = apply_norm(cfg, x, params["final_norm"]
                       if cfg.norm == "rmsnorm" else None)
        return lm_logits(cfg, params["embed"], x), cache

    return Model(cfg, init, forward, loss, init_cache, prefill, decode_step,
                 partial(_dense_specs, cfg),
                 partial(_dense_cache_specs, cfg))


# -- rwkv6 ------------------------------------------------------------------

def _build_rwkv(cfg: ModelCfg, layer_mode: str) -> Model:
    L = cfg.n_layers

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"embed": init_embed(k1, cfg),
                "layers": _stacked_init(rk.init_rwkv_block, k2, cfg),
                "final_norm": jnp.zeros((cfg.d_model,), cfg.p_dtype)}

    def _run(params, x, state):
        def body_fn(x, p_l, st_l):
            return rk.rwkv_block(cfg, p_l, x, st_l,
                                 chunk=cfg.rwkv.chunk)
        body_fn = _remat(cfg, body_fn)
        if layer_mode == "scan":
            def scan_body(x, inp):
                p_l, st_l = inp
                return body_fn(x, p_l, st_l)
            x, state = jax.lax.scan(scan_body, x, (params["layers"], state))
        else:
            outs = []
            for i in range(L):
                x, st = body_fn(x, _split_layer(params["layers"], i),
                                _split_layer(state, i))
                outs.append(st)
            state = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return x, state

    def forward(params, tokens):
        x = _embed_in(cfg, params, tokens)
        st = rk.init_rwkv_state(cfg, tokens.shape[0])
        x, _ = _run(params, x, st)
        x = rmsnorm(x, params["final_norm"])
        return lm_logits(cfg, params["embed"], x), jnp.float32(0.0)

    def loss(params, tokens, labels):
        logits, _ = forward(params, tokens)
        return softmax_xent(logits, labels)

    def init_cache(batch, max_len):
        return rk.init_rwkv_state(cfg, batch)     # O(1) in max_len

    def prefill(params, tokens, cache):
        x = _embed_in(cfg, params, tokens)
        x, cache = _run(params, x, cache)
        x = rmsnorm(x[:, -1:], params["final_norm"])
        return lm_logits(cfg, params["embed"], x), cache

    def decode_step(params, tok, cache, pos):
        x = embed(cfg, params["embed"], tok)
        x, cache = _run(params, x, cache)
        x = rmsnorm(x, params["final_norm"])
        return lm_logits(cfg, params["embed"], x), cache

    return Model(cfg, init, forward, loss, init_cache, prefill, decode_step,
                 partial(_rwkv_specs, cfg), partial(_rwkv_cache_specs, cfg))


# -- zamba2 hybrid ----------------------------------------------------------

def _build_hybrid(cfg: ModelCfg, layer_mode: str) -> Model:
    L = cfg.n_layers
    every = cfg.hybrid_attn_every
    n_attn = L // every if every else 0

    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {"embed": init_embed(k1, cfg),
                "layers": _stacked_init(
                    lambda k, c: {"m": m2.init_mamba2(k, c),
                                  "ln": jnp.zeros((c.d_model,), c.p_dtype)},
                    k2, cfg),
                "shared": init_hybrid_shared(k3, cfg),
                "final_norm": jnp.zeros((cfg.d_model,), cfg.p_dtype)}

    def _layer(params, x, p_l, st_l, li, pos, attn_kv, want_kv):
        """One mamba layer (+ shared attn block every ``every`` layers).

        ``li`` may be a Python int (unrolled mode — the branch resolves at
        trace time, keeping the shared-attn FLOPs visible to XLA's cost
        analysis) or a traced index (scan mode — ``lax.cond``).
        """
        h = rmsnorm(x, p_l["ln"])
        y, st_out = m2.mamba2_block(cfg, p_l["m"], h, st_l)
        x = shard(x + y, "batch", "act_seq", "embed")
        if every:
            k_c, v_c = attn_kv

            def with_attn(x):
                xa, kv = shared_attn_block(cfg, params["shared"], x, pos,
                                           want_kv=want_kv)
                if want_kv:
                    ai = li // every
                    k2_ = jax.lax.dynamic_update_index_in_dim(
                        k_c, kv[0].astype(k_c.dtype), ai, 0)
                    v2_ = jax.lax.dynamic_update_index_in_dim(
                        v_c, kv[1].astype(v_c.dtype), ai, 0)
                    return xa, (k2_, v2_)
                return xa, (k_c, v_c)

            if isinstance(li, int):                    # unrolled: static
                if li % every == every - 1:
                    x, attn_kv = with_attn(x)
            else:
                x, attn_kv = jax.lax.cond(li % every == every - 1,
                                          with_attn,
                                          lambda x: (x, (k_c, v_c)), x)
        return x, st_out, attn_kv

    def _run(params, x, state, pos, want_kv, attn_cache):
        k_c, v_c = attn_cache
        if layer_mode == "scan":
            def scan_body(carry, inp):
                x, kcs = carry
                (p_l, st_l), li = inp
                x, st_out, kcs = _layer(params, x, p_l, st_l, li, pos,
                                        kcs, want_kv)
                return (x, kcs), st_out
            (x, (k_c, v_c)), state = jax.lax.scan(
                scan_body, (x, (k_c, v_c)),
                ((params["layers"], state), jnp.arange(L)))
        else:
            outs = []
            for i in range(L):
                x, st, (k_c, v_c) = _layer(
                    params, x, _split_layer(params["layers"], i),
                    _split_layer(state, i), i, pos,
                    (k_c, v_c), want_kv)
                outs.append(st)
            state = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return x, state, (k_c, v_c)

    def forward(params, tokens):
        B, S = tokens.shape
        x = _embed_in(cfg, params, tokens)
        st = m2.init_mamba_state(cfg, B)
        kv_shape = (n_attn, B, S, cfg.n_kv_heads, cfg.head_dim)
        dummy = (jnp.zeros(kv_shape, cfg.act_dtype),
                 jnp.zeros(kv_shape, cfg.act_dtype))
        x, _, _ = _run(params, x, st, _positions(tokens), False, dummy)
        x = rmsnorm(x, params["final_norm"])
        return lm_logits(cfg, params["embed"], x), jnp.float32(0.0)

    def loss(params, tokens, labels):
        logits, _ = forward(params, tokens)
        return softmax_xent(logits, labels)

    def init_cache(batch, max_len):
        c = m2.init_mamba_state(cfg, batch)
        c["attn_k"] = jnp.zeros(
            (n_attn, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
            cfg.act_dtype)
        c["attn_v"] = jnp.zeros_like(c["attn_k"])
        return c

    def prefill(params, tokens, cache):
        B, S = tokens.shape
        x = _embed_in(cfg, params, tokens)
        st = {"conv": cache["conv"], "ssm": cache["ssm"]}
        kv_shape = (n_attn, B, S, cfg.n_kv_heads, cfg.head_dim)
        fresh = (jnp.zeros(kv_shape, cfg.act_dtype),
                 jnp.zeros(kv_shape, cfg.act_dtype))
        x, st, (k_c, v_c) = _run(params, x, st, _positions(tokens), True,
                                 fresh)
        new_cache = {
            "conv": st["conv"], "ssm": st["ssm"],
            "attn_k": jax.lax.dynamic_update_slice_in_dim(
                cache["attn_k"], k_c, 0, 2),
            "attn_v": jax.lax.dynamic_update_slice_in_dim(
                cache["attn_v"], v_c, 0, 2),
        }
        x = rmsnorm(x[:, -1:], params["final_norm"])
        return lm_logits(cfg, params["embed"], x), new_cache

    def decode_step(params, tok, cache, pos):
        B = tok.shape[0]
        x = embed(cfg, params["embed"], tok)
        st = {"conv": cache["conv"], "ssm": cache["ssm"]}

        def _layer_d(carry, inp):
            x, k_c, v_c = carry
            (p_l, st_l), li = inp
            h = rmsnorm(x, p_l["ln"])
            y, st_out = m2.mamba2_block(cfg, p_l["m"], h, st_l)
            x = x + y

            def with_attn(args):
                x, k_c, v_c = args
                ai = li // every
                xa, k_l, v_l = shared_attn_decode(
                    cfg, params["shared"], x, k_c[ai], v_c[ai], pos)
                k_c = jax.lax.dynamic_update_index_in_dim(k_c, k_l, ai, 0)
                v_c = jax.lax.dynamic_update_index_in_dim(v_c, v_l, ai, 0)
                return xa, k_c, v_c

            if every:
                if isinstance(li, int):                # unrolled: static
                    if li % every == every - 1:
                        x, k_c, v_c = with_attn((x, k_c, v_c))
                else:
                    x, k_c, v_c = jax.lax.cond(
                        li % every == every - 1, with_attn,
                        lambda a: a, (x, k_c, v_c))
            return (x, k_c, v_c), st_out

        if layer_mode == "scan":
            (x, k_c, v_c), st = jax.lax.scan(
                _layer_d, (x, cache["attn_k"], cache["attn_v"]),
                ((params["layers"], st), jnp.arange(L)))
        else:
            k_c, v_c = cache["attn_k"], cache["attn_v"]
            outs = []
            for i in range(L):
                (x, k_c, v_c), st_out = _layer_d(
                    (x, k_c, v_c),
                    ((_split_layer(params["layers"], i),
                      _split_layer(st, i)), i))
                outs.append(st_out)
            st = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        cache = {"conv": st["conv"], "ssm": st["ssm"],
                 "attn_k": k_c, "attn_v": v_c}
        x = rmsnorm(x, params["final_norm"])
        return lm_logits(cfg, params["embed"], x), cache

    return Model(cfg, init, forward, loss, init_cache, prefill, decode_step,
                 partial(_hybrid_specs, cfg),
                 partial(_hybrid_cache_specs, cfg))


# ---------------------------------------------------------------------------
# Parameter / cache PartitionSpecs (logical → physical via active rules)
# ---------------------------------------------------------------------------

def _sp(*logical):
    return pspec(*logical)


def _dense_specs(cfg) -> dict:
    attn_specs = (
        {"wq_a": _sp("fsdp", None), "q_a_norm": _sp(None),
         "wq_b": _sp(None, "ff"), "wkv_a": _sp("fsdp", None),
         "kv_a_norm": _sp(None), "wk_b": _sp(None, "ff"),
         "wv_b": _sp(None, "ff"), "wo": _sp("ff", "fsdp")}
        if cfg.mla is not None else
        {k: v for k, v in {
            "wq": _sp("fsdp", "ff"), "wk": _sp("fsdp", "ff"),
            "wv": _sp("fsdp", "ff"), "wo": _sp("ff", "fsdp"),
            "q_norm": _sp(None), "k_norm": _sp(None)}.items()
         if not (k in ("q_norm", "k_norm") and not cfg.qk_norm)})
    if cfg.moe is not None:
        mlp_specs = {"router": _sp(None, None),
                     "w_gate": _sp("expert", "fsdp", "expert_ff"),
                     "w_in": _sp("expert", "fsdp", "expert_ff"),
                     "w_out": _sp("expert", "expert_ff", "fsdp")}
        if cfg.moe.n_shared > 0:
            mlp_specs["shared"] = {"w_gate": _sp("fsdp", "ff"),
                                   "w_in": _sp("fsdp", "ff"),
                                   "w_out": _sp("ff", "fsdp")}
    elif cfg.mlp in ("swiglu", "geglu"):
        mlp_specs = {"w_gate": _sp("fsdp", "ff"), "w_in": _sp("fsdp", "ff"),
                     "w_out": _sp("ff", "fsdp")}
    else:
        mlp_specs = {"w_in": _sp("fsdp", "ff"), "w_out": _sp("ff", "fsdp")}
    layer = {"attn": attn_specs, "mlp": mlp_specs}
    if cfg.norm == "rmsnorm":
        layer["ln1s"] = _sp(None)
        layer["ln2s"] = _sp(None)
    layer = jax.tree.map(lambda s: P(None, *s), layer,
                         is_leaf=lambda s: isinstance(s, P))
    emb = {"tok": _sp("vocab", None)}
    if not cfg.tie_embeddings:
        emb["lm_head"] = _sp(None, "vocab")
    return {"embed": emb, "layers": layer,
            "final_norm": _sp(None) if cfg.norm == "rmsnorm" else _sp(None)}


def _dense_cache_specs(cfg, batch=None, max_len=None):
    """Decode-cache shardings, divisibility-aware.

    When the arch's kv heads divide the TP degree, shard them; otherwise
    shard the cache *sequence* dim over the model axis instead (decode
    attention then executes as a flash-decode: per-shard partial softmax
    merged by GSPMD's reduction).  MLA's latent cache has no head dim —
    it always seq-shards.  ``seq_kv`` (data axis) is added for the
    long-context shapes.
    """
    from repro.distribution.sharding import axis_size, phys
    if cfg.mla is not None:
        seq = phys("seq_kv", "seq_kv_tp")
        return {"c_kv": P(None, *pspec("batch"), seq, None),
                "k_rope": P(None, *pspec("batch"), seq, None)}
    kv_ok = (cfg.shard_heads
             and cfg.n_kv_heads % max(axis_size("kv_heads"), 1) == 0
             and axis_size("kv_heads") > 1)
    if kv_ok:
        seq = phys("seq_kv")
        kv = phys("kv_heads")
    else:
        seq = phys("seq_kv", "seq_kv_tp")
        kv = None
    b = phys("batch")
    return {"k": P(None, b, seq, kv, None),
            "v": P(None, b, seq, kv, None)}


def _rwkv_specs(cfg) -> dict:
    tm = {"mu_x": _sp(None), "mu": _sp(None, None),
          "mix_w1": _sp(None, None), "mix_w2": _sp(None, None, None),
          "wr": _sp("fsdp", "ff"), "wk": _sp("fsdp", "ff"),
          "wv": _sp("fsdp", "ff"), "wg": _sp("fsdp", "ff"),
          "wo": _sp("ff", "fsdp"),
          "decay_base": _sp(None), "decay_w1": _sp(None, None),
          "decay_w2": _sp(None, None), "bonus": _sp(None),
          "ln_scale": _sp(None), "ln_bias": _sp(None)}
    cm = {"mu_k": _sp(None), "mu_r": _sp(None),
          "wk": _sp("fsdp", "ff"), "wv": _sp("ff", "fsdp"),
          "wr": _sp("fsdp", "ff")}
    layer = jax.tree.map(lambda s: P(None, *s),
                         {"tm": tm, "cm": cm, "ln1": _sp(None),
                          "ln2": _sp(None)},
                         is_leaf=lambda s: isinstance(s, P))
    emb = {"tok": _sp("vocab", None)}
    if not cfg.tie_embeddings:
        emb["lm_head"] = _sp(None, "vocab")
    return {"embed": emb, "layers": layer, "final_norm": _sp(None)}


def _rwkv_cache_specs(cfg, batch=None, max_len=None):
    from repro.distribution.sharding import axis_size, phys
    H = cfg.d_model // cfg.rwkv.head_size
    h_ok = H % max(axis_size("heads"), 1) == 0
    b = phys("batch")
    return {"tm_shift": P(None, b, None),
            "cm_shift": P(None, b, None),
            "wkv": P(None, b, "model" if h_ok and axis_size("heads") > 1
                     else None, None, None)}


def _hybrid_specs(cfg) -> dict:
    m = {"in_proj": _sp("fsdp", "ff"), "conv_w": _sp(None, None),
         "conv_b": _sp(None), "a_log": _sp(None), "d_skip": _sp(None),
         "dt_bias": _sp(None), "norm_scale": _sp(None),
         "out_proj": _sp("ff", "fsdp")}
    layer = jax.tree.map(lambda s: P(None, *s),
                         {"m": m, "ln": _sp(None)},
                         is_leaf=lambda s: isinstance(s, P))
    shared = {"ln1": _sp(None), "ln2": _sp(None),
              "attn": {"wq": _sp("fsdp", "ff"), "wk": _sp("fsdp", "ff"),
                       "wv": _sp("fsdp", "ff"), "wo": _sp("ff", "fsdp")},
              "mlp": {"w_gate": _sp("fsdp", "ff"),
                      "w_in": _sp("fsdp", "ff"),
                      "w_out": _sp("ff", "fsdp")}}
    emb = {"tok": _sp("vocab", None)}
    if not cfg.tie_embeddings:
        emb["lm_head"] = _sp(None, "vocab")
    return {"embed": emb, "layers": layer, "shared": shared,
            "final_norm": _sp(None)}


def _hybrid_cache_specs(cfg, batch=None, max_len=None):
    from repro.distribution.sharding import axis_size, phys
    b = phys("batch")
    ssm_h = (cfg.ssm.expand * cfg.d_model) // cfg.ssm.head_dim
    h_ok = ssm_h % max(axis_size("heads"), 1) == 0
    kv_ok = (cfg.n_kv_heads % max(axis_size("kv_heads"), 1) == 0
             and axis_size("kv_heads") > 1)
    seq = phys("seq_kv") if kv_ok else phys("seq_kv", "seq_kv_tp")
    return {"conv": P(None, b, None, None),
            "ssm": P(None, b, "model" if h_ok and axis_size("heads") > 1
                     else None, None, None),
            "attn_k": P(None, b, seq, phys("kv_heads") if kv_ok else None,
                        None),
            "attn_v": P(None, b, seq, phys("kv_heads") if kv_ok else None,
                        None)}
