"""Attention: GQA/MQA (+qk-norm), MLA, prefill and KV-cache decode.

Three interchangeable implementations of causal self-attention, selected
by ``cfg.attn_impl``:

* ``xla_chunked`` — q-block scan with an inner dynamic ``fori_loop`` over
  KV blocks up to the causal frontier (flash-attention-style online
  softmax, O(block) memory, no upper-triangle compute).  Used for the
  full-config dry-run compiles (memory analysis) and real runs.
* ``naive`` — full [S,S] score matrix.  Small models / tests / and the
  *roofline* compiles, where loop bodies must be visible to XLA's cost
  analysis (while-loop bodies are counted once; see launch/dryrun.py).
* ``pallas`` — the TPU flash-attention kernel in ``repro.kernels``
  (validated under ``interpret=True`` on CPU).

Weights are stored flat (``wq: [D, H*Dh]``) so parameter shardings always
divide evenly; head-shaped activations get (possibly uneven) logical
sharding constraints, which GSPMD pads transparently.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distribution.sharding import shard, shard_map_compat
from repro.models.layers import apply_rope, dense_init, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> dict:
    D, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.p_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, Hq * Dh), dt),
        "wk": dense_init(ks[1], (D, Hkv * Dh), dt),
        "wv": dense_init(ks[2], (D, Hkv * Dh), dt),
        "wo": dense_init(ks[3], (Hq * Dh, D), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((Dh,), dt)
        p["k_norm"] = jnp.zeros((Dh,), dt)
    return p


def _qkv(cfg, p, x, pos):
    """Project and position-encode.  x: [B,S,D] → q[B,S,H,Dh], k/v[B,S,KV,Dh]."""
    B, S, _ = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt))
    q = q.reshape(B, S, Hq, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.pos == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


# ---------------------------------------------------------------------------
# Core causal attention (three impls)
# ---------------------------------------------------------------------------

def _sdpa_naive(q, k, v, q_off: int = 0, causal: bool = True):
    """q: [B,Sq,H,Dh]; k,v: [B,Skv,KV,Dh].  Full score matrix."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Sq, KV, G, Dh)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k).astype(jnp.float32) * scale
    if causal:
        qpos = q_off + jnp.arange(Sq)
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqt,btkd->bqkgd", a, v)
    return o.reshape(B, Sq, H, Dh)


def _sdpa_chunked(q, k, v, chunk: int):
    """Flash-style causal attention: scan q blocks × scan kv blocks.

    Upper-triangle block pairs are skipped by a ``lax.cond`` (a real
    branch at runtime — no wasted compute), which keeps the loop bounds
    static so reverse-mode autodiff works (training path).  Memory is
    O(block), never O(S²).
    """
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)
    qc = min(chunk, S)
    n_q = S // qc
    assert S % qc == 0, (S, qc)

    kg = k  # [B,S,KV,Dh]
    vg = v

    def q_block(carry, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)
        qg = q_blk.reshape(B, qc, KV, G, Dh)
        acc0 = jnp.zeros((B, qc, KV, G, Dh), jnp.float32)
        m0 = jnp.full((B, qc, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, KV, G), jnp.float32)

        def kv_block(mla, ki):
            m, l, acc = mla

            def compute(args):
                m, l, acc = args
                k_blk = jax.lax.dynamic_slice_in_dim(kg, ki * qc, qc,
                                                     axis=1)
                v_blk = jax.lax.dynamic_slice_in_dim(vg, ki * qc, qc,
                                                     axis=1)
                s = jnp.einsum("bqkgd,btkd->bqkgt", qg, k_blk)
                s = s.astype(jnp.float32) * scale
                qpos = qi * qc + jnp.arange(qc)
                kpos = ki * qc + jnp.arange(qc)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l = l * corr + p.sum(axis=-1)
                acc = acc * corr[..., None] + jnp.einsum(
                    "bqkgt,btkd->bqkgd", p.astype(q.dtype), v_blk
                ).astype(jnp.float32)
                return m_new, l, acc

            return jax.lax.cond(ki <= qi, compute, lambda a: a,
                                (m, l, acc)), None

        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, acc0),
                                      jnp.arange(n_q))
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return carry, o.reshape(B, qc, H, Dh)

    _, o = jax.lax.scan(q_block, 0, jnp.arange(n_q))
    # o: [n_q, B, qc, H, Dh] → [B, S, H, Dh]
    return o.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dh)


def _sdpa_unrolled(q, k, v, chunk: int):
    """Python-loop flash attention: every (q,kv) block pair is a distinct
    HLO op, so XLA's cost analysis counts the true causal FLOPs
    (while-loop bodies are counted once — this impl exists for the
    roofline pass).  Upper-triangle block pairs are skipped at trace time.
    """
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)
    qc = min(chunk, S)
    n = S // qc
    outs = []
    for qi in range(n):
        qg = q[:, qi * qc:(qi + 1) * qc].reshape(B, qc, KV, G, Dh)
        acc = jnp.zeros((B, qc, KV, G, Dh), jnp.float32)
        m = jnp.full((B, qc, KV, G), NEG_INF, jnp.float32)
        l = jnp.zeros((B, qc, KV, G), jnp.float32)
        for ki in range(qi + 1):
            k_blk = k[:, ki * qc:(ki + 1) * qc]
            v_blk = v[:, ki * qc:(ki + 1) * qc]
            s = jnp.einsum("bqkgd,btkd->bqkgt", qg, k_blk)
            s = s.astype(jnp.float32) * scale
            if ki == qi:                     # diagonal block: mask
                t_idx = jnp.arange(qc)
                mask = t_idx[:, None] >= t_idx[None, :]
                s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgt,btkd->bqkgd", p.astype(q.dtype), v_blk
            ).astype(jnp.float32)
            m = m_new
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        outs.append(o.reshape(B, qc, H, Dh))
    return jnp.concatenate(outs, axis=1)


def _gqa_tp_pad(cfg, q, k, v):
    """Pad query heads / replicate KV heads so attention shards evenly.

    When ``H % TP != 0`` (e.g. qwen3's 40 heads on a 16-way model axis),
    GSPMD falls back to "involuntary full rematerialization" — it
    replicates head-sharded tensors at every transition, which the
    roofline measured as TB-scale collective+copy traffic.  Instead we
    make the head dim divisible: each of the KV heads is replicated
    ``rep = TP/KV`` times and its query group padded to ``rep·⌈G/rep⌉``
    — group-to-KV mapping is preserved, padded heads are sliced off
    after SDPA.  Cost: ≤(H'/H)× attention FLOPs, vs the replication
    pathology it removes.

    Returns (q', k', v', unpad) where unpad maps [B,S,H',Dh]→[B,S,H,Dh].
    """
    from repro.distribution.sharding import axis_size, current_ctx
    tp = axis_size("heads")
    H, KV = cfg.n_heads, cfg.n_kv_heads
    if (not cfg.gqa_pad or current_ctx() is None or tp <= 1
            or not cfg.shard_heads or H % tp == 0 or tp % KV != 0):
        return q, k, v, None
    rep = tp // KV
    G = H // KV
    Gp = -(-G // rep)                      # ceil
    B, S, _, Dh = q.shape
    qg = q.reshape(B, S, KV, G, Dh)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, rep * Gp - G), (0, 0)))
    qp = qg.reshape(B, S, KV * rep, Gp, Dh).reshape(B, S, KV * rep * Gp,
                                                    Dh)
    kp = jnp.repeat(k, rep, axis=2)
    vp = jnp.repeat(v, rep, axis=2)

    def unpad(o):
        o = o.reshape(B, S, KV, rep * Gp, Dh)[:, :, :, :G]
        return o.reshape(B, S, H, Dh)

    return qp, kp, vp, unpad


def sdpa(cfg, q, k, v):
    """Dispatch causal self-attention by ``cfg.attn_impl``."""
    q, k, v, unpad = _gqa_tp_pad(cfg, q, k, v)
    if unpad is not None:
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "seq", "heads", None)
        v = shard(v, "batch", "seq", "heads", None)
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=True)
    elif cfg.attn_impl == "xla_unrolled" and q.shape[1] > cfg.attn_chunk:
        o = _sdpa_unrolled(q, k, v, max(cfg.attn_chunk, q.shape[1] // 8))
    elif cfg.attn_impl == "xla_chunked" and q.shape[1] > cfg.attn_chunk:
        o = _sdpa_chunked(q, k, v, cfg.attn_chunk)
    else:
        o = _sdpa_naive(q, k, v)
    return unpad(o) if unpad is not None else o


def attention(cfg, p, x, pos):
    """Full-sequence causal self-attention.  x: [B,S,D]."""
    q, k, v = _qkv(cfg, p, x, pos)
    o = sdpa(cfg, q, k, v)
    o = shard(o, "batch", "seq", "heads", None)
    B, S = x.shape[:2]
    out = jnp.einsum("bse,ed->bsd",
                     o.reshape(B, S, cfg.q_dim), p["wo"].astype(x.dtype))
    return shard(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_len: int, n_layers: int | None = None
                  ) -> dict:
    L = n_layers if n_layers is not None else cfg.n_layers
    shp = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shp, cfg.act_dtype),
            "v": jnp.zeros(shp, cfg.act_dtype)}


def kv_cache_spec():
    """Logical dim names of a KV cache entry [L,B,S,KV,Dh]."""
    return (None, "batch", "seq_kv", "kv_heads", None)


def cache_seq_axes(cfg):
    """Physical mesh axes the decode-cache sequence dim is sharded over
    (mirrors the cache-spec logic in transformer.py)."""
    from repro.distribution.sharding import axis_size, current_ctx, phys
    ctx = current_ctx()
    if ctx is None:
        return None
    kv_ok = (cfg.shard_heads
             and cfg.n_kv_heads % max(axis_size("kv_heads"), 1) == 0
             and axis_size("kv_heads") > 1)
    if cfg.mla is not None or not kv_ok:
        return phys("seq_kv", "seq_kv_tp")
    return phys("seq_kv")


def _flash_decode_sharded(qg, k_cache, v_cache, pos, scale, axes):
    """Partial-softmax flash-decode over a seq-sharded cache (shard_map).

    qg: [B,KV,G,Dh] (replicated over ``axes``); k/v_cache:
    [B,S,KV,Dh] with S sharded over ``axes``; pos: [B].  Each shard
    computes f32 scores over only its local cache slice — the combine is
    a 3-scalar-ish collective (pmax of m, psum of l and o) instead of a
    gathered [B,H,S] f32 score array.
    """
    from repro.distribution.sharding import current_ctx
    ctx = current_ctx()
    mesh = ctx.mesh
    n_sh = 1
    for a in axes:
        n_sh *= mesh.shape[a]
    S = k_cache.shape[1]
    S_l = S // n_sh
    dp = ctx.rules.get("batch")

    def local(qg, kc, vc, pos):
        # global offset of this shard's cache slice
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        t = idx * S_l + jnp.arange(S_l)
        s = jnp.einsum("bkgd,btkd->bkgt", qg, kc).astype(jnp.float32)
        s = s * scale
        mask = t[None, :] <= pos[:, None]
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m = jax.lax.pmax(s.max(axis=-1), axes)
        p = jnp.exp(s - m[..., None])
        l = jax.lax.psum(p.sum(axis=-1), axes)
        o = jnp.einsum("bkgt,btkd->bkgd", p.astype(qg.dtype), vc)
        o = jax.lax.psum(o.astype(jnp.float32), axes)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(qg.dtype)

    return shard_map_compat(
        local, mesh,
        in_specs=(P(dp, None, None, None), P(dp, axes, None, None),
                  P(dp, axes, None, None), P(dp)),
        out_specs=P(dp, None, None, None),
        check_vma=False)(qg, k_cache, v_cache, pos)


def decode_attention(cfg, p, x, k_cache, v_cache, pos):
    """One-token decode.  x: [B,1,D]; k/v_cache: [B,S_max,KV,Dh] (already
    containing this step's k,v at index ``pos``).  ``pos``: [B] int32.

    When the cache's sequence dim is sharded (MQA/GQA archs whose kv
    heads don't divide the TP degree, and long-context shapes), the
    attention runs as a shard_map flash-decode: per-shard partial
    softmax + a tiny (m, l, o) combine, never materializing a gathered
    [B,H,S] f32 score array.
    """
    B, _, D = x.shape
    Hq, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = Hq // KV
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)).reshape(B, Hq, Dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
    if cfg.pos == "rope":
        q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    qg = q.reshape(B, KV, G, Dh)
    scale = 1.0 / math.sqrt(Dh)
    axes = cache_seq_axes(cfg) if cfg.flash_decode else None
    if axes:
        o = _flash_decode_sharded(qg, k_cache, v_cache, pos, scale, axes)
        o = o.reshape(B, Hq * Dh)
        out = jnp.einsum("be,ed->bd", o, p["wo"].astype(dt))
        return out[:, None, :]
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache).astype(jnp.float32)
    s = s * scale
    t = jnp.arange(k_cache.shape[1])
    mask = t[None, :] <= pos[:, None]                       # [B,S]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(dt)
    o = jnp.einsum("bkgt,btkd->bkgd", a, v_cache).reshape(B, Hq * Dh)
    out = jnp.einsum("be,ed->bd", o, p["wo"].astype(dt))
    return out[:, None, :]                                  # [B,1,D]


def append_kv(cfg, p, x, k_cache, v_cache, pos):
    """Project this token's k,v and write them into the cache at ``pos``."""
    B = x.shape[0]
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt)).reshape(B, 1, KV, Dh)
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt)).reshape(B, 1, KV, Dh)
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"])
    if cfg.pos == "rope":
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, pos].set(k[:, 0])
    v_cache = v_cache.at[bidx, pos].set(v[:, 0])
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg) -> dict:
    m, D, H = cfg.mla, cfg.d_model, cfg.n_heads
    dt = cfg.p_dtype
    qk = m.qk_nope + m.qk_rope
    ks = jax.random.split(key, 7)
    return {
        "wq_a": dense_init(ks[0], (D, m.q_lora), dt),         # q down
        "q_a_norm": jnp.zeros((m.q_lora,), dt),
        "wq_b": dense_init(ks[1], (m.q_lora, H * qk), dt),    # q up
        "wkv_a": dense_init(ks[2], (D, m.kv_lora + m.qk_rope), dt),
        "kv_a_norm": jnp.zeros((m.kv_lora,), dt),
        "wk_b": dense_init(ks[3], (m.kv_lora, H * m.qk_nope), dt),
        "wv_b": dense_init(ks[4], (m.kv_lora, H * m.v_dim), dt),
        "wo": dense_init(ks[5], (H * m.v_dim, D), dt),
    }


def _mla_qkv(cfg, p, x, pos):
    """Decompressed-path MLA projections (prefill/training)."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    dt = x.dtype
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dt)),
                 p["q_a_norm"])
    q = jnp.einsum("bsr,re->bse", cq, p["wq_b"].astype(dt))
    q = q.reshape(B, S, H, m.qk_nope + m.qk_rope)
    q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dt))
    c_kv = rmsnorm(kv[..., :m.kv_lora], p["kv_a_norm"])      # [B,S,kv_lora]
    k_rope = kv[..., m.kv_lora:][:, :, None, :]              # [B,S,1,rope]
    k_nope = jnp.einsum("bsr,re->bse", c_kv, p["wk_b"].astype(dt))
    k_nope = k_nope.reshape(B, S, H, m.qk_nope)
    v = jnp.einsum("bsr,re->bse", c_kv, p["wv_b"].astype(dt))
    v = v.reshape(B, S, H, m.v_dim)

    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    k_rope = apply_rope(k_rope, pos, cfg.rope_theta)
    k_rope1 = k_rope[:, :, 0, :]                             # cached (roped)
    k_rope = jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "heads", None)
    v = shard(v, "batch", "seq", "heads", None)
    return q, k, v, (c_kv, k_rope1)


def mla_attention(cfg, p, x, pos):
    """Full-sequence MLA (prefill/training): decompress then dense SDPA."""
    m = cfg.mla
    q, k, v, _ = _mla_qkv(cfg, p, x, pos)
    # kv heads == q heads after decompression → GQA group of 1.
    o = _mla_sdpa(cfg, q, k, v)
    B, S = x.shape[:2]
    out = jnp.einsum("bse,ed->bsd",
                     o.reshape(B, S, cfg.n_heads * m.v_dim),
                     p["wo"].astype(x.dtype))
    return shard(out, "batch", "seq", "embed")


def _mla_sdpa(cfg, q, k, v):
    """SDPA where q/k dims differ from v dim (MLA: 192 vs 128)."""
    B, S, H, qk = q.shape
    scale = 1.0 / math.sqrt(qk)
    if cfg.attn_impl == "xla_unrolled" and S > cfg.attn_chunk:
        return _sdpa_unrolled_vd(q, k, v,
                                 max(cfg.attn_chunk, S // 8), scale)
    if cfg.attn_impl == "xla_chunked" and S > cfg.attn_chunk:
        return _sdpa_chunked_vd(q, k, v, cfg.attn_chunk, scale)
    s = jnp.einsum("bqhd,bthd->bhqt", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(S)
    mask = qpos[:, None] >= qpos[None, :]
    s = jnp.where(mask[None, None], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqt,bthd->bqhd", a, v)


def _sdpa_chunked_vd(q, k, v, chunk, scale):
    """Chunked causal SDPA with distinct qk / v head dims (MLA).

    Same structure as :func:`_sdpa_chunked`: static-bound scans with a
    ``lax.cond`` causal skip, so it is reverse-mode differentiable.
    """
    B, S, H, _ = q.shape
    Dv = v.shape[-1]
    qc = min(chunk, S)
    n_q = S // qc

    def q_block(carry, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)
        acc0 = jnp.zeros((B, qc, H, Dv), jnp.float32)
        m0 = jnp.full((B, qc, H), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, H), jnp.float32)

        def kv_block(mla_, ki):
            m, l, acc = mla_

            def compute(args):
                m, l, acc = args
                k_blk = jax.lax.dynamic_slice_in_dim(k, ki * qc, qc,
                                                     axis=1)
                v_blk = jax.lax.dynamic_slice_in_dim(v, ki * qc, qc,
                                                     axis=1)
                s = jnp.einsum("bqhd,bthd->bqht", q_blk, k_blk)
                s = s.astype(jnp.float32) * scale
                qpos = qi * qc + jnp.arange(qc)
                kpos = ki * qc + jnp.arange(qc)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, :, None, :], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                pp = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l = l * corr + pp.sum(axis=-1)
                acc = acc * corr[..., None] + jnp.einsum(
                    "bqht,bthd->bqhd", pp.astype(q.dtype), v_blk
                ).astype(jnp.float32)
                return m_new, l, acc

            return jax.lax.cond(ki <= qi, compute, lambda a: a,
                                (m, l, acc)), None

        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, acc0),
                                      jnp.arange(n_q))
        return carry, (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    _, o = jax.lax.scan(q_block, 0, jnp.arange(n_q))
    return o.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dv)


def _sdpa_unrolled_vd(q, k, v, chunk, scale):
    """Unrolled (trace-time loop) MLA SDPA — roofline-visible FLOPs."""
    B, S, H, _ = q.shape
    Dv = v.shape[-1]
    qc = min(chunk, S)
    n = S // qc
    outs = []
    for qi in range(n):
        q_blk = q[:, qi * qc:(qi + 1) * qc]
        acc = jnp.zeros((B, qc, H, Dv), jnp.float32)
        m = jnp.full((B, qc, H), NEG_INF, jnp.float32)
        l = jnp.zeros((B, qc, H), jnp.float32)
        for ki in range(qi + 1):
            k_blk = k[:, ki * qc:(ki + 1) * qc]
            v_blk = v[:, ki * qc:(ki + 1) * qc]
            s = jnp.einsum("bqhd,bthd->bqht", q_blk, k_blk)
            s = s.astype(jnp.float32) * scale
            if ki == qi:
                t_idx = jnp.arange(qc)
                mask = t_idx[:, None] >= t_idx[None, :]
                s = jnp.where(mask[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqht,bthd->bqhd", p.astype(q.dtype), v_blk
            ).astype(jnp.float32)
            m = m_new
        outs.append((acc / jnp.maximum(l, 1e-30)[..., None]
                     ).astype(q.dtype))
    return jnp.concatenate(outs, axis=1)


def init_mla_cache(cfg, batch: int, max_len: int,
                   n_layers: int | None = None) -> dict:
    """MLA caches the *compressed* latent + shared rope key — the paper-
    faithful memory win (kv_lora + qk_rope per token instead of
    2·H·head_dim)."""
    m = cfg.mla
    L = n_layers if n_layers is not None else cfg.n_layers
    return {
        "c_kv": jnp.zeros((L, batch, max_len, m.kv_lora), cfg.act_dtype),
        "k_rope": jnp.zeros((L, batch, max_len, m.qk_rope), cfg.act_dtype),
    }


def mla_decode(cfg, p, x, c_kv_cache, k_rope_cache, pos):
    """One-token MLA decode with weight absorption.

    Scores are computed directly in the latent space:
      q_lat = q_nope @ W_kb  (absorb)          [B,H,kv_lora]
      s     = q_lat · c_kv + q_rope · k_rope   [B,H,S]
      o_lat = softmax(s) · c_kv                [B,H,kv_lora]
      o     = o_lat @ W_vb                     [B,H,v_dim]
    so the per-token cache stays (kv_lora + qk_rope) wide.
    """
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    dt = x.dtype
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dt)),
                 p["q_a_norm"])
    q = jnp.einsum("bsr,re->bse", cq, p["wq_b"].astype(dt))
    q = q.reshape(B, H, m.qk_nope + m.qk_rope)
    q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
    q_rope = apply_rope(q_rope[:, None], pos[:, None],
                        cfg.rope_theta)[:, 0]
    wk_b = p["wk_b"].astype(dt).reshape(m.kv_lora, H, m.qk_nope)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, wk_b)        # absorb W_kb

    scale = 1.0 / math.sqrt(m.qk_nope + m.qk_rope)
    axes = cache_seq_axes(cfg) if cfg.flash_decode else None
    if axes:
        o_lat = _mla_flash_decode_sharded(q_lat, q_rope, c_kv_cache,
                                          k_rope_cache, pos, scale, axes)
    else:
        s = jnp.einsum("bhr,btr->bht", q_lat, c_kv_cache)
        s = s + jnp.einsum("bhn,btn->bht", q_rope, k_rope_cache)
        s = s.astype(jnp.float32) * scale
        t = jnp.arange(c_kv_cache.shape[1])
        mask = t[None, :] <= pos[:, None]
        s = jnp.where(mask[:, None, :], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1).astype(dt)
        o_lat = jnp.einsum("bht,btr->bhr", a, c_kv_cache)
    wv_b = p["wv_b"].astype(dt).reshape(m.kv_lora, H, m.v_dim)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, wv_b).reshape(B, H * m.v_dim)
    out = jnp.einsum("be,ed->bd", o, p["wo"].astype(dt))
    return out[:, None, :]


def _mla_flash_decode_sharded(q_lat, q_rope, c_kv_cache, k_rope_cache,
                              pos, scale, axes):
    """MLA flash-decode over a seq-sharded latent cache (shard_map)."""
    from repro.distribution.sharding import current_ctx
    ctx = current_ctx()
    mesh = ctx.mesh
    n_sh = 1
    for a in axes:
        n_sh *= mesh.shape[a]
    S_l = c_kv_cache.shape[1] // n_sh
    dp = ctx.rules.get("batch")

    def local(ql, qr, ckv, krope, pos):
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        t = idx * S_l + jnp.arange(S_l)
        s = jnp.einsum("bhr,btr->bht", ql, ckv)
        s = s + jnp.einsum("bhn,btn->bht", qr, krope)
        s = s.astype(jnp.float32) * scale
        mask = t[None, :] <= pos[:, None]
        s = jnp.where(mask[:, None, :], s, NEG_INF)
        m = jax.lax.pmax(s.max(axis=-1), axes)
        p = jnp.exp(s - m[..., None])
        l = jax.lax.psum(p.sum(axis=-1), axes)
        o = jnp.einsum("bht,btr->bhr", p.astype(ql.dtype), ckv)
        o = jax.lax.psum(o.astype(jnp.float32), axes)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(ql.dtype)

    return shard_map_compat(
        local, mesh,
        in_specs=(P(dp, None, None), P(dp, None, None),
                  P(dp, axes, None), P(dp, axes, None), P(dp)),
        out_specs=P(dp, None, None),
        check_vma=False)(q_lat, q_rope, c_kv_cache, k_rope_cache, pos)


def mla_append_kv(cfg, p, x, c_kv_cache, k_rope_cache, pos):
    m = cfg.mla
    B = x.shape[0]
    dt = x.dtype
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dt))
    c_kv = rmsnorm(kv[..., :m.kv_lora], p["kv_a_norm"])[:, 0]
    k_rope = apply_rope(kv[..., m.kv_lora:][:, :, None, :],
                        pos[:, None], cfg.rope_theta)[:, 0, 0]
    bidx = jnp.arange(B)
    c_kv_cache = c_kv_cache.at[bidx, pos].set(c_kv)
    k_rope_cache = k_rope_cache.at[bidx, pos].set(k_rope)
    return c_kv_cache, k_rope_cache
