"""Model configuration schema covering the ten assigned architectures.

One frozen dataclass per concern; ``ModelCfg`` composes them.  Every arch
in ``repro.configs`` instantiates a full-size ``ModelCfg`` (exact numbers
from the assignment table) plus a reduced ``smoke()`` variant used by the
CPU tests (full configs are exercised only through the dry-run, which
never allocates).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int            # routed experts
    top_k: int
    d_ff_expert: int          # per-expert hidden dim
    n_shared: int = 0         # always-on shared experts (deepseek-v2: 2)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3   # router z-loss (stability)
    aux_coef: float = 1e-2        # load-balance aux loss


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora: int = 1536        # low-rank q down-projection
    kv_lora: int = 512        # compressed kv latent (the cached tensor)
    qk_nope: int = 128        # non-rotary per-head q/k dim
    qk_rope: int = 64         # rotary per-head dim (shared k_rope)
    v_dim: int = 128          # per-head value dim


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    """Mamba2 (SSD) block configuration (zamba2)."""
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64        # SSD head size (d_inner / n_heads)
    conv_width: int = 4
    chunk: int = 128          # SSD chunked-scan block length


@dataclasses.dataclass(frozen=True)
class RWKVCfg:
    head_size: int = 64       # per-head k/v channel count
    decay_lora: int = 64      # low-rank data-dependent decay (w) dim
    mix_lora: int = 32        # low-rank token-shift mixing dim
    ff_mult: float = 3.5      # channel-mix hidden = ff_mult * d_model
    chunk: int = 32           # WKV chunked-scan block length


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: Literal["dense", "moe", "rwkv6", "hybrid"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # -- variations ---------------------------------------------------------
    norm: Literal["rmsnorm", "layernorm_np"] = "rmsnorm"
    mlp: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    qk_norm: bool = False
    pos: Literal["rope", "sinusoidal", "none"] = "rope"
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    logit_softcap: float = 0.0        # gemma-style tanh soft-capping (0=off)
    moe: MoECfg | None = None
    mla: MLACfg | None = None
    ssm: SSMCfg | None = None
    rwkv: RWKVCfg | None = None
    hybrid_attn_every: int = 0        # zamba2: shared attn block period
    # -- numerics / impl ----------------------------------------------------
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    attn_impl: Literal["xla_chunked", "xla_unrolled", "naive",
                       "pallas"] = "xla_chunked"
    attn_chunk: int = 512             # KV block for chunked attention
    remat: Literal["none", "full", "dots"] = "full"
    # -- sharding hints (consumed by distribution.rules_for) ----------------
    fsdp: bool = False                # ZeRO-3 param sharding over data axis
    shard_heads: bool = True          # False when heads % TP != 0 everywhere
    # perf toggles (True = optimized path; False reproduces the baseline
    # lowering for the §Perf before/after attribution)
    flash_decode: bool = True         # shard_map partial-softmax decode
    gqa_pad: bool = True              # head pad/KV-rep when H % TP != 0
    # -- modality stub ------------------------------------------------------
    frontend: Literal["text", "audio_tokens", "vq_image_tokens"] = "text"

    # -- derived ------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    def n_params(self) -> int:
        """Exact parameter count (used for 6·N·D roofline bookkeeping)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv6":
            r = self.rwkv
            H = self.d_model // r.head_size
            tm = (D * D * 4                      # r,k,v,g (square for rwkv6)
                  + D * D                        # output
                  + 2 * (D * r.decay_lora)       # w lora
                  + 5 * (D * r.mix_lora) * 2     # ddlerp loras (x5 targets)
                  + 6 * D + H * r.head_size)     # mix biases, decay, bonus
            cm = D * int(r.ff_mult * D) * 2 + 2 * D
            per_layer = tm + cm + 2 * D
            return emb + L * per_layer + D
        per_attn = (D * self.q_dim + 2 * D * self.kv_dim
                    + self.q_dim * D)
        if self.mla is not None:
            m = self.mla
            qk = m.qk_nope + m.qk_rope
            per_attn = (D * m.q_lora + m.q_lora * self.n_heads * qk
                        + D * (m.kv_lora + m.qk_rope)
                        + m.kv_lora * self.n_heads * (m.qk_nope + m.v_dim)
                        + self.n_heads * m.v_dim * D)
        n_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        per_mlp = n_mats * D * F
        if self.moe is not None:
            e = self.moe
            per_mlp = (D * e.n_experts                       # router
                       + n_mats * D * e.d_ff_expert
                       * (e.n_experts + e.n_shared))
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * D
            nh = d_in // s.head_dim
            per_ssm = (D * (2 * d_in + 2 * s.d_state + nh)   # in_proj
                       + s.conv_width * (d_in + 2 * s.d_state)
                       + d_in * D + nh + nh + d_in)          # out, A, D, norm
            per_mlp = n_mats * D * F
            attn_layers = (self.n_layers // self.hybrid_attn_every
                           if self.hybrid_attn_every else 0)
            # shared attn+mlp block counted once (zamba2's trick)
            shared = per_attn + per_mlp + 2 * D
            return emb + L * (per_ssm + 2 * D) + shared + D \
                + attn_layers * 0
        per_norm = 2 * D if self.norm == "rmsnorm" else 0
        return emb + L * (per_attn + per_mlp + per_norm) + \
            (D if self.norm == "rmsnorm" else 0)

    def active_params(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        n_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        full_moe = n_mats * self.d_model * e.d_ff_expert * \
            (e.n_experts + e.n_shared) * self.n_layers
        act_moe = n_mats * self.d_model * e.d_ff_expert * \
            (e.top_k + e.n_shared) * self.n_layers
        return self.n_params() - full_moe + act_moe
