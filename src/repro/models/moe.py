"""Mixture-of-Experts: router, dense oracle, and expert-parallel dispatch.

Three compute paths:

* ``moe_dense`` — dropless oracle: every (token, expert) pair is computed
  and masked by the combine weights.  Exact; used by smoke tests, as the
  reference for the EP path, and for *decode* steps (token count per
  device ≪ expert count, so dense-local + psum is both exact and cheap —
  expert weights stay sharded, XLA reduces partial sums over the model
  axis).
* ``moe_ep`` — production path for train/prefill: per-device top-k
  routing, capacity-bounded sort-based dispatch into an ``[E, C, D]``
  buffer, ``all_to_all`` over the model (expert) axis, batched expert
  FFN, reverse ``all_to_all``, weighted combine.  Tokens over capacity
  are dropped (standard GShard/Switch semantics; capacity_factor controls
  the drop rate).
* shared experts (DeepSeek-V2) are a plain dense MLP added to the output.

Router losses: Switch-style load-balance aux (``E·Σ f_e·P_e``) and z-loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distribution.sharding import (current_ctx, shard,
                                         shard_map_compat)
from repro.models.layers import dense_init


def init_moe(key, cfg) -> dict:
    e, D = cfg.moe, cfg.d_model
    F = e.d_ff_expert
    dt = cfg.p_dtype
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (D, e.n_experts), dt),
        "w_gate": dense_init(ks[1], (e.n_experts, D, F), dt, in_axis=-2),
        "w_in": dense_init(ks[2], (e.n_experts, D, F), dt, in_axis=-2),
        "w_out": dense_init(ks[3], (e.n_experts, F, D), dt, in_axis=-2),
    }
    if e.n_shared > 0:
        Fs = e.n_shared * F
        p["shared"] = {
            "w_gate": dense_init(ks[4], (D, Fs), dt),
            "w_in": dense_init(ks[5], (D, Fs), dt),
            "w_out": dense_init(ks[6], (Fs, D), dt),
        }
    return p


def _act(cfg, g, h):
    a = jax.nn.silu(g) if cfg.mlp != "geglu" else jax.nn.gelu(g, True)
    return a * h


def _router(cfg, p, xf):
    """xf: [T, D] → gates [T,k], idx [T,k] i32, aux losses (f32 scalars)."""
    e = cfg.moe
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, e.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux: fraction routed vs mean prob (Switch eq. 4-6)
    one_hot = jax.nn.one_hot(idx, e.n_experts, dtype=jnp.float32)
    f = one_hot.sum(axis=(0, 1)) / (xf.shape[0] * e.top_k)
    pmean = probs.mean(axis=0)
    aux = e.n_experts * jnp.sum(f * pmean) * e.aux_coef
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * e.router_z_coef
    return gates.astype(xf.dtype), idx, aux + z


def _shared_mlp(cfg, p, x):
    dt = x.dtype
    sp = p["shared"]
    g = jnp.einsum("bsd,df->bsf", x, sp["w_gate"].astype(dt))
    h = jnp.einsum("bsd,df->bsf", x, sp["w_in"].astype(dt))
    g = shard(g, "batch", "seq", "ff")
    h = shard(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", _act(cfg, g, h), sp["w_out"].astype(dt))


# ---------------------------------------------------------------------------
# Dense (oracle / decode) path — pure SPMD, no shard_map
# ---------------------------------------------------------------------------

def moe_dense(cfg, p, x):
    """x: [B,S,D].  Every expert computed for every token, masked combine.

    With expert weights sharded over the model axis, GSPMD computes the
    per-shard partial sums and inserts one all-reduce — this is exactly
    dense-local expert parallelism.  Cost/token = E_local experts, which
    is the right trade for decode (T per device small); the EP path below
    is the train/prefill fast path.
    """
    B, S, D = x.shape
    e = cfg.moe
    xf = x.reshape(B * S, D)
    gates, idx, aux = _router(cfg, p, xf)
    # combine weights [T, E]
    comb = jnp.zeros((B * S, e.n_experts), x.dtype)
    comb = comb.at[jnp.arange(B * S)[:, None], idx].add(gates)
    dt = x.dtype
    g = jnp.einsum("td,edf->etf", xf, p["w_gate"].astype(dt))
    h = jnp.einsum("td,edf->etf", xf, p["w_in"].astype(dt))
    hh = _act(cfg, g, h) * comb.T[:, :, None]
    y = jnp.einsum("etf,efd->td", hh, p["w_out"].astype(dt))
    y = y.reshape(B, S, D)
    if e.n_shared > 0:
        y = y + _shared_mlp(cfg, p, x)
    return shard(y, "batch", "seq", "embed"), aux


# ---------------------------------------------------------------------------
# Expert-parallel (sorted dispatch + all_to_all) path
# ---------------------------------------------------------------------------

def _capacity(t_local: int, cfg) -> int:
    e = cfg.moe
    c = int(math.ceil(t_local * e.top_k / e.n_experts * e.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_ep(cfg, p, x):
    """Expert-parallel MoE for many-token steps (train / prefill).

    Requires an active sharding context; falls back to the dense oracle
    otherwise (tests, single-device runs).
    """
    ctx = current_ctx()
    if ctx is None:
        return moe_dense(cfg, p, x)
    B, S, D = x.shape
    e = cfg.moe
    tp = ctx.tp_axis
    M = ctx.mesh.shape[tp]
    dp = ctx.rules.get("batch")
    fsdp = ctx.rules.get("fsdp")
    if S % M != 0 or e.n_experts % M != 0:
        return moe_dense(cfg, p, x)
    E_l = e.n_experts // M

    def local(xl, wr, wg, wi, wo):
        # xl: [B_l, S_l, D]; wr: [D,E]; wg/wi: [E_l, D', F]; wo: [E_l, F, D']
        if fsdp is not None:  # FSDP: gather the layer's weights before use
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wi = jax.lax.all_gather(wi, fsdp, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, fsdp, axis=2, tiled=True)
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xf = xl.reshape(T, D)
        gates, idx, aux = _router(cfg, {"router": wr}, xf)
        dp_axes = (dp if isinstance(dp, tuple) else
                   ((dp,) if dp is not None else ()))
        aux = jax.lax.pmean(aux, (*dp_axes, tp))
        C = _capacity(T, cfg)
        A = T * e.top_k
        e_flat = idx.reshape(A)
        t_flat = jnp.repeat(jnp.arange(T), e.top_k)
        g_flat = gates.reshape(A)
        order = jnp.argsort(e_flat)                      # stable
        e_s, t_s, g_s = e_flat[order], t_flat[order], g_flat[order]
        starts = jnp.searchsorted(e_s, jnp.arange(e.n_experts))
        pos = jnp.arange(A) - starts[e_s]
        keep = pos < C
        pos_c = jnp.where(keep, pos, 0)
        buf = jnp.zeros((e.n_experts, C, D), xl.dtype)
        src = jnp.where(keep[:, None], xf[t_s], 0)
        buf = buf.at[e_s, pos_c].add(src)
        # dispatch: every device sends C slots of each expert to its owner
        recv = jax.lax.all_to_all(buf, tp, split_axis=0, concat_axis=1,
                                  tiled=True)            # [E_l, M*C, D]
        dt = xl.dtype
        g1 = jnp.einsum("ecd,edf->ecf", recv, wg.astype(dt))
        h1 = jnp.einsum("ecd,edf->ecf", recv, wi.astype(dt))
        y = jnp.einsum("ecf,efd->ecd", _act(cfg, g1, h1), wo.astype(dt))
        back = jax.lax.all_to_all(y, tp, split_axis=1, concat_axis=0,
                                  tiled=True)            # [E, C, D]
        contrib = back[e_s, pos_c] * keep[:, None]
        out = jnp.zeros((T, D), xl.dtype)
        out = out.at[t_s].add(g_s[:, None] * contrib)
        return out.reshape(Bl, Sl, D), aux

    wspec_df = P(tp, fsdp, None)   # [E, D, F] experts over model (+fsdp on D)
    wspec_fd = P(tp, None, fsdp)
    y, aux = shard_map_compat(
        local, ctx.mesh,
        in_specs=(P(dp, tp, None), P(None, None),
                  wspec_df, wspec_df, wspec_fd),
        out_specs=(P(dp, tp, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_in"], p["w_out"])
    if e.n_shared > 0:
        y = y + _shared_mlp(cfg, p, x)
    return shard(y, "batch", "seq", "embed"), aux


def moe(cfg, p, x, *, decode: bool = False):
    """Dispatch: dense-local for decode / tiny token counts, EP otherwise."""
    if decode or x.shape[0] * x.shape[1] < 4 * cfg.moe.n_experts:
        return moe_dense(cfg, p, x)
    return moe_ep(cfg, p, x)
