"""RWKV-6 "Finch" block: data-dependent decay time-mix + channel-mix.

The WKV recurrence per head (K = V = head_size):

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u) · k_tᵀ v_t)

with per-channel, *data-dependent* decay ``w_t = exp(-exp(ŵ_t))`` (the
RWKV-6 novelty over RWKV-5's static decay).  Training/prefill uses a
chunked formulation: within a chunk the pairwise decay factors
``exp(Lx_t − Li_s)`` are computed in log space (always ≤ 1 for s < t, so
no overflow), and the carried state is advanced once per chunk — the same
structure as the Pallas kernel in ``repro.kernels.rwkv6_wkv``.

State per layer (decode): token-shift carries (time-mix and channel-mix)
plus the [H, K, V] WKV state — O(1) in sequence length, which is exactly
why ``long_500k`` is runnable for this arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distribution.sharding import shard
from repro.models.layers import dense_init, rmsnorm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_rwkv_block(key, cfg) -> dict:
    r, D = cfg.rwkv, cfg.d_model
    K = r.head_size
    H = D // K
    F = int(r.ff_mult * D)
    dt = cfg.p_dtype
    ks = jax.random.split(key, 10)
    tm = {
        "mu_x": jnp.full((D,), 0.5, dt),
        "mu": jnp.full((5, D), 0.5, dt),                  # r,k,v,w,g lerp
        "mix_w1": dense_init(ks[0], (D, 5 * r.mix_lora), dt),
        "mix_w2": (jax.random.normal(ks[1], (5, r.mix_lora, D)) * 0.01
                   ).astype(dt),
        "wr": dense_init(ks[2], (D, D), dt),
        "wk": dense_init(ks[3], (D, D), dt),
        "wv": dense_init(ks[4], (D, D), dt),
        "wg": dense_init(ks[5], (D, D), dt),
        "wo": dense_init(ks[6], (D, D), dt),
        "decay_base": jnp.full((D,), -4.0, dt),           # ŵ bias
        "decay_w1": dense_init(ks[7], (D, r.decay_lora), dt),
        "decay_w2": (jax.random.normal(ks[8], (r.decay_lora, D)) * 0.01
                     ).astype(dt),
        "bonus": jnp.zeros((D,), dt),                     # u, per channel
        "ln_scale": jnp.ones((D,), dt),                   # per-head groupnorm
        "ln_bias": jnp.zeros((D,), dt),
    }
    k9, k10, k11 = jax.random.split(ks[9], 3)
    cm = {
        "mu_k": jnp.full((D,), 0.5, dt),
        "mu_r": jnp.full((D,), 0.5, dt),
        "wk": dense_init(k9, (D, F), dt),
        "wv": dense_init(k10, (F, D), dt),
        "wr": dense_init(k11, (D, D), dt),
    }
    return {"tm": tm, "cm": cm,
            "ln1": jnp.zeros((D,), dt), "ln2": jnp.zeros((D,), dt)}


def init_rwkv_state(cfg, batch: int, n_layers: int | None = None) -> dict:
    D = cfg.d_model
    K = cfg.rwkv.head_size
    H = D // K
    L = n_layers if n_layers is not None else cfg.n_layers
    return {
        "tm_shift": jnp.zeros((L, batch, D), cfg.act_dtype),
        "cm_shift": jnp.zeros((L, batch, D), cfg.act_dtype),
        "wkv": jnp.zeros((L, batch, H, K, K), jnp.float32),
    }


# ---------------------------------------------------------------------------
# WKV — chunked (train/prefill) and stepwise (decode)
# ---------------------------------------------------------------------------

def wkv_chunked(r, k, v, lw, u, s0, chunk: int = 32):
    """Chunked WKV scan.

    r,k,v: [B,T,H,K]; lw: [B,T,H,K] log-decay (≤0); u: [H,K];
    s0: [B,H,K,K] f32 carry-in.  Returns (y [B,T,H,K], s_out).
    """
    B, T, H, K = r.shape
    c = min(chunk, T)
    T0 = T
    if T % c:          # pad tail: lw=0 ⇒ decay 1, k=v=0 ⇒ no contribution
        pad = c - T % c
        r, k, v, lw = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                       for a in (r, k, v, lw))
        T = T + pad
    n = T // c

    def rs(x):
        return x.reshape(B, n, c, H, K).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = rs(r), rs(k), rs(v), rs(lw)   # [n,B,H,c,K]

    def chunk_step(s, inp):
        rr, kk, vv, ll = inp                         # [B,H,c,K]
        ll = ll.astype(jnp.float32)
        li = jnp.cumsum(ll, axis=2)                  # inclusive  Li[s]
        lx = li - ll                                 # exclusive  Lx[t]
        # pairwise decay D[t,s] = exp(Lx[t] - Li[s]), s < t  (≤ 1 — safe)
        dec = jnp.exp(lx[:, :, :, None, :] - li[:, :, None, :, :])
        rrf = rr.astype(jnp.float32)
        kkf = kk.astype(jnp.float32)
        a = (rrf[:, :, :, None, :] * kkf[:, :, None, :, :] * dec).sum(-1)
        t_idx = jnp.arange(c)
        mask = t_idx[:, None] > t_idx[None, :]
        a = jnp.where(mask[None, None], a, 0.0)      # strict lower
        diag = (rrf * u[None, :, None, :].astype(jnp.float32) * kkf).sum(-1)
        a = a + jnp.eye(c)[None, None] * diag[:, :, :, None]
        y = jnp.einsum("bhts,bhsk->bhtk", a, vv.astype(jnp.float32))
        y = y + jnp.einsum("bhtk,bhkv->bhtv", rrf * jnp.exp(lx), s)
        # advance state:  S' = diag(e^Lc) S + Σ_s (k_s e^{Lc−Li_s})ᵀ v_s
        lc = li[:, :, -1:, :]                        # [B,H,1,K]
        kd = kkf * jnp.exp(lc - li)
        s_new = s * jnp.exp(lc.squeeze(2))[..., None] + jnp.einsum(
            "bhsk,bhsv->bhkv", kd, vv.astype(jnp.float32))
        return s_new, y

    s_out, ys = jax.lax.scan(chunk_step, s0, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, T, H, K).astype(r.dtype)
    return y[:, :T0], s_out


def wkv_step(r, k, v, lw, u, s):
    """Single-token WKV.  r,k,v,lw: [B,H,K]; s: [B,H,K,V] f32."""
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    kv = kf[..., :, None] * vf[..., None, :]               # [B,H,K,V]
    y = jnp.einsum("bhk,bhkv->bhv",
                   rf, s + u[None].astype(jnp.float32)[..., None] * kv)
    s_new = s * jnp.exp(lw.astype(jnp.float32))[..., None] + kv
    return y.astype(r.dtype), s_new


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------

def _ddlerp(tm, x, x_prev):
    """Data-dependent token-shift interpolation (RWKV-6)."""
    B, T, D = x.shape
    xx = x_prev - x
    base = x + xx * tm["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("btd,de->bte", base,
                               tm["mix_w1"].astype(x.dtype)))
    lora = lora.reshape(B, T, 5, -1)
    delta = jnp.einsum("btfe,fed->fbtd", lora, tm["mix_w2"].astype(x.dtype))
    mixed = x[None] + xx[None] * (tm["mu"].astype(x.dtype)[:, None, None]
                                  + delta)
    return mixed  # [5, B, T, D] → r,k,v,w,g


def time_mix(cfg, tm, x, shift_in, wkv_in, chunk: int = 32):
    """x: [B,T,D].  Returns (out, shift_out, wkv_out)."""
    B, T, D = x.shape
    K = cfg.rwkv.head_size
    H = D // K
    x_prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(tm, x, x_prev)
    dt = x.dtype
    r = jnp.einsum("btd,de->bte", xr, tm["wr"].astype(dt))
    k = jnp.einsum("btd,de->bte", xk, tm["wk"].astype(dt))
    v = jnp.einsum("btd,de->bte", xv, tm["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, tm["wg"].astype(dt)))
    w_hat = tm["decay_base"].astype(jnp.float32) + jnp.einsum(
        "btd,de,ef->btf", xw.astype(jnp.float32),
        tm["decay_w1"].astype(jnp.float32),
        tm["decay_w2"].astype(jnp.float32))
    lw = -jnp.exp(w_hat)                                   # log w ≤ 0

    hs = (B, T, H, K)
    r_, k_, v_ = (a.reshape(hs) for a in (r, k, v))
    lw_ = lw.reshape(hs)
    u = tm["bonus"].astype(jnp.float32).reshape(H, K)
    r_ = shard(r_, "batch", "seq", "heads", None)
    k_ = shard(k_, "batch", "seq", "heads", None)
    v_ = shard(v_, "batch", "seq", "heads", None)
    lw_ = shard(lw_, "batch", "seq", "heads", None)
    if T == 1:
        y, s_out = wkv_step(r_[:, 0], k_[:, 0], v_[:, 0], lw_[:, 0], u,
                            wkv_in)
        y = y[:, None]
    else:
        y, s_out = wkv_chunked(r_, k_, v_, lw_, u, wkv_in, chunk)
    # per-head group norm, then gate and output projection
    y = y.reshape(B, T, H, K)
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, T, D) * tm["ln_scale"].astype(dt) + \
        tm["ln_bias"].astype(dt)
    out = jnp.einsum("btd,de->bte", y.astype(dt) * g, tm["wo"].astype(dt))
    return shard(out, "batch", "seq", "embed"), x[:, -1], s_out


def channel_mix(cfg, cm, x, shift_in):
    B, T, D = x.shape
    dt = x.dtype
    x_prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * cm["mu_k"].astype(dt)
    xr = x + xx * cm["mu_r"].astype(dt)
    k = jnp.einsum("btd,df->btf", xk, cm["wk"].astype(dt))
    k = shard(k, "batch", "seq", "ff")
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("btf,fd->btd", k, cm["wv"].astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, cm["wr"].astype(dt)))
    return shard(r * kv, "batch", "seq", "embed"), x[:, -1]


def rwkv_block(cfg, p, x, state: dict, chunk: int = 32):
    """One RWKV-6 layer.  state: {tm_shift, cm_shift, wkv} (per layer)."""
    h = rmsnorm(x, p["ln1"])
    att, tm_shift, wkv = time_mix(cfg, p["tm"], h, state["tm_shift"],
                                  state["wkv"], chunk)
    x = shard(x + att, "batch", "act_seq", "embed")
    h = rmsnorm(x, p["ln2"])
    ff, cm_shift = channel_mix(cfg, p["cm"], h, state["cm_shift"])
    x = shard(x + ff, "batch", "act_seq", "embed")
    return x, {"tm_shift": tm_shift, "cm_shift": cm_shift, "wkv": wkv}
