"""Mamba-2 (SSD) block — the state-space backbone of zamba2.

Selective state space with scalar-per-head decay (the SSD restriction):

    h_t = exp(Δ_t·A_h) · h_{t-1} + Δ_t · B_t ⊗ x_t      h: [H, P, N]
    y_t = C_t · h_t + D_h · x_t

Training/prefill uses the chunked "1-semiseparable" matrix form: within a
chunk the pairwise decay ``exp(la_t − la_s)`` (s ≤ t, exponent ≤ 0 — log
space, no overflow) forms an [c, c] attention-like score matrix per head,
and the carried state advances once per chunk.  Decode is the O(1)
recurrence with a rolling conv window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distribution.sharding import shard
from repro.models.layers import dense_init


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return s, d_in, n_heads


def init_mamba2(key, cfg) -> dict:
    s, d_in, H = _dims(cfg)
    D, N = cfg.d_model, s.d_state
    dt = cfg.p_dtype
    ks = jax.random.split(key, 3)
    conv_ch = d_in + 2 * N
    return {
        "in_proj": dense_init(ks[0], (D, 2 * d_in + 2 * N + H), dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch))
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.zeros((H,), dt),                  # A = -exp(a_log)
        "d_skip": jnp.ones((H,), dt),
        "dt_bias": jnp.zeros((H,), dt),
        "norm_scale": jnp.ones((d_in,), dt),
        "out_proj": dense_init(ks[2], (d_in, D), dt),
    }


def init_mamba_state(cfg, batch: int, n_layers: int | None = None) -> dict:
    s, d_in, H = _dims(cfg)
    L = n_layers if n_layers is not None else cfg.n_layers
    return {
        "conv": jnp.zeros((L, batch, s.conv_width - 1, d_in + 2 * s.d_state),
                          cfg.act_dtype),
        "ssm": jnp.zeros((L, batch, H, s.head_dim, s.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# SSD scan — chunked (train/prefill) and stepwise (decode)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt_h, bmat, cmat, a, h0, chunk: int = 128):
    """Chunked SSD scan.

    x: [B,T,H,P]; dt_h: [B,T,H] (post-softplus Δ); bmat/cmat: [B,T,N];
    a: [H] (negative); h0: [B,H,P,N] f32.  Returns (y [B,T,H,P], h_out).
    """
    B, T, H, P = x.shape
    N = bmat.shape[-1]
    c = min(chunk, T)
    T0 = T
    if T % c:                      # pad tail: Δ=0 ⇒ no state contribution
        pad = c - T % c
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_h = jnp.pad(dt_h, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        T = T + pad
    n = T // c

    def rs(z, trailing):
        return z.reshape((B, n, c) + trailing).swapaxes(0, 1)

    xc = rs(x, (H, P))
    dtc = rs(dt_h, (H,))
    bc = rs(bmat, (N,))
    cc = rs(cmat, (N,))

    def chunk_step(h, inp):
        xx, dd, bb, ccm = inp                      # [B,c,H,P],[B,c,H],[B,c,N]
        dd = dd.astype(jnp.float32)
        la = jnp.cumsum(dd * a[None, None, :], axis=1)       # [B,c,H] ≤ 0
        # intra-chunk scores  M[t,s] = (C_t·B_s)·exp(la_t−la_s)·Δ_s, s ≤ t
        cb = jnp.einsum("btn,bsn->bts", ccm.astype(jnp.float32),
                        bb.astype(jnp.float32))
        dec = jnp.exp(la[:, :, None, :] - la[:, None, :, :])  # [B,t,s,H]
        t_idx = jnp.arange(c)
        mask = (t_idx[:, None] >= t_idx[None, :])[None, :, :, None]
        m = jnp.where(mask, cb[..., None] * dec * dd[:, None], 0.0)
        y = jnp.einsum("btsh,bshp->bthp", m, xx.astype(jnp.float32))
        # carry-in contribution:  C_t · (h0 ⊙ e^{la_t})
        y = y + jnp.einsum("btn,bhpn,bth->bthp", ccm.astype(jnp.float32),
                           h, jnp.exp(la))
        # state update:  h' = h·e^{la_end} + Σ_s e^{la_end−la_s}·Δ_s·B_s⊗x_s
        la_end = la[:, -1:, :]                                # [B,1,H]
        w = jnp.exp(la_end - la) * dd                         # [B,c,H]
        h_new = h * jnp.exp(la_end[:, 0])[:, :, None, None] + jnp.einsum(
            "bsh,bsn,bshp->bhpn", w, bb.astype(jnp.float32),
            xx.astype(jnp.float32))
        return h_new, y.astype(x.dtype)

    h_out, ys = jax.lax.scan(chunk_step, h0, (xc, dtc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(B, T, H, P)
    return y[:, :T0], h_out


def ssd_step(x, dt_h, bvec, cvec, a, h):
    """One-token SSD.  x: [B,H,P]; dt_h: [B,H]; b,c: [B,N]; h: [B,H,P,N]."""
    dd = dt_h.astype(jnp.float32)
    decay = jnp.exp(dd * a[None, :])[:, :, None, None]
    upd = (dd[:, :, None, None] * x.astype(jnp.float32)[..., None]
           * bvec.astype(jnp.float32)[:, None, None, :])
    h_new = h * decay + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, cvec.astype(jnp.float32))
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------

def _causal_conv(seq, w, b, conv_in):
    """seq: [B,T,C]; w: [W,C]; conv_in: [B,W-1,C] carry.  Depthwise."""
    W = w.shape[0]
    full = jnp.concatenate([conv_in, seq], axis=1)          # [B,T+W-1,C]
    out = sum(full[:, i:i + seq.shape[1]] * w[i][None, None]
              for i in range(W))
    out = out + b[None, None]
    carry = full[:, -(W - 1):] if W > 1 else conv_in
    return jax.nn.silu(out), carry


def mamba2_block(cfg, p, x, state: dict):
    """x: [B,T,D]; state: {conv [B,W-1,C], ssm [B,H,P,N]}."""
    s, d_in, H = _dims(cfg)
    N, P = s.d_state, s.head_dim
    B, T, D = x.shape
    dt = x.dtype
    proj = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(dt))
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + d_in + 2 * N]
    dt_raw = proj[..., -H:]
    xbc, conv_out = _causal_conv(xbc, p["conv_w"].astype(dt),
                                 p["conv_b"].astype(dt), state["conv"])
    xin = xbc[..., :d_in].reshape(B, T, H, P)
    bmat = xbc[..., d_in:d_in + N]
    cmat = xbc[..., d_in + N:]
    dt_h = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xin = shard(xin, "batch", "seq", "heads", None)
    if T == 1:
        y, ssm = ssd_step(xin[:, 0], dt_h[:, 0], bmat[:, 0], cmat[:, 0],
                          a, state["ssm"])
        y = y[:, None]
    else:
        y, ssm = ssd_chunked(xin, dt_h, bmat, cmat, a, state["ssm"],
                             s.chunk)
    y = y + xin * p["d_skip"].astype(dt)[None, None, :, None]
    y = y.reshape(B, T, d_in)
    # gated RMSNorm (Mamba-2): norm(y · silu(z))
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         ).astype(dt) * p["norm_scale"].astype(dt)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(dt))
    return (shard(out, "batch", "seq", "embed"),
            {"conv": conv_out, "ssm": ssm})
