"""Shared neural building blocks (pure functions over param dicts).

Conventions:
* params are nested dicts of ``jnp`` arrays; per-layer params are stacked
  along a leading ``L`` axis and consumed through ``jax.lax.scan``.
* compute runs in ``cfg.act_dtype`` (bf16 by default); params are stored
  in ``cfg.param_dtype`` (f32) and cast at use — standard mixed precision.
* every activation is annotated with logical axis names via
  :func:`repro.distribution.sharding.shard` (no-ops without a mesh ctx).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distribution.sharding import shard


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, *, in_axis: int = -2) -> jax.Array:
    """LeCun-normal in the contraction dim (matches common LM inits)."""
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6
            ) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if scale is not None:
        x = x * (1.0 + scale.astype(jnp.float32))
    return x.astype(dt)


def layernorm_np(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Non-parametric LayerNorm (OLMo): no scale, no bias."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def apply_norm(cfg, x: jax.Array, scale: jax.Array | None) -> jax.Array:
    if cfg.norm == "layernorm_np":
        return layernorm_np(x)
    return rmsnorm(x, scale)


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; pos: broadcastable to [..., S] (int32)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    ang = pos[..., None].astype(jnp.float32) * freqs    # [..., S, Dh/2]
    cos = jnp.cos(ang)[..., None, :]                    # [..., S, 1, Dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pe(seq: int, d_model: int, offset: int = 0) -> jax.Array:
    """Classic transformer sinusoidal position embedding (musicgen)."""
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = cfg.p_dtype
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], (D, F), dt),
                "w_in": dense_init(ks[1], (D, F), dt),
                "w_out": dense_init(ks[2], (F, D), dt)}
    return {"w_in": dense_init(ks[0], (D, F), dt),
            "w_out": dense_init(ks[1], (F, D), dt)}


def mlp(cfg, p: dict, x: jax.Array) -> jax.Array:
    """x: [B, S, D] → [B, S, D]."""
    dt = x.dtype
    if cfg.mlp in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(dt))
        g = shard(g, "batch", "seq", "ff")
        h = shard(h, "batch", "seq", "ff")
        act = jax.nn.silu(g) if cfg.mlp == "swiglu" else \
            jax.nn.gelu(g, approximate=True)
        h = act * h
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(dt))
        h = shard(h, "batch", "seq", "ff")
        h = jax.nn.gelu(h, approximate=True)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(dt))
    return shard(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embed(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": embed_init(k1, (cfg.vocab, cfg.d_model), cfg.p_dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, (cfg.d_model, cfg.vocab), cfg.p_dtype)
    return p


def embed(cfg, p: dict, tokens: jax.Array) -> jax.Array:
    x = p["tok"].astype(cfg.act_dtype)[tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.act_dtype)
    return shard(x, "batch", "seq", "embed")


def lm_logits(cfg, p: dict, x: jax.Array) -> jax.Array:
    w = (p["tok"].T if cfg.tie_embeddings else p["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    if cfg.logit_softcap > 0.0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return shard(logits, "batch", "seq", "vocab")


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy; fp32 reduction; labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
