"""Jit'd wrapper: seq-major [B,S,H,Dh] API over the head-major kernel.

On CPU (this container) the kernel executes under ``interpret=True``; on
TPU it lowers through Mosaic.  Model code calls :func:`flash_attention`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_hm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128):
    """q: [B,S,H,Dh]; k,v: [B,S,KV,Dh] → [B,S,H,Dh]."""
    assert causal, "only causal attention is provided"
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    o = flash_attention_hm(qh, kh, vh, bq=bq, bk=bk,
                           interpret=_interpret())
    return o.transpose(0, 2, 1, 3)
