"""Pallas TPU flash attention (causal, GQA-aware).

Canonical online-softmax tiling: grid ``(B, H, n_q, n_kv)`` with the KV
index innermost; running ``(m, l, acc)`` live in VMEM scratch and persist
across the KV dim; upper-triangle blocks are skipped with ``pl.when``.
GQA is handled in the BlockSpec index maps (query head ``h`` reads KV head
``h // group``) — KV is never materialized per-query-head.

Layout: q [B, H, S, Dh]; k,v [B, KV, S, Dh] (head-major for clean tiling).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, bq: int, bk: int, n_kv: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (innermost)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal frontier: kv block j intersects q block i iff j*bk <= i*bq+bq-1
    @pl.when(j * bk <= i * bq + bq - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, Dh]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, Dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_hm(q, k, v, *, bq: int = 128, bk: int = 128,
                       interpret: bool = False):
    """Head-major flash attention.  q: [B,H,S,Dh]; k,v: [B,KV,S,Dh]."""
    B, H, S, Dh = q.shape
    KV = k.shape[1]
    assert H % KV == 0
    g = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    n_q, n_kv = pl.cdiv(S, bq), pl.cdiv(S, bk)
    scale = 1.0 / math.sqrt(Dh)
    kernel = functools.partial(_kernel, scale=scale, bq=bq, bk=bk,
                               n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # m
            pltpu.VMEM((bq, 1), jnp.float32),     # l
            pltpu.VMEM((bq, Dh), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
