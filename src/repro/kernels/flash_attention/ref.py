"""Pure-jnp oracle for flash attention (causal, GQA)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v):
    """q: [B,S,H,Dh]; k,v: [B,S,KV,Dh] → [B,S,H,Dh] (fp32 math)."""
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, g, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qf, kf) / math.sqrt(Dh)
    pos = jnp.arange(S, dtype=jnp.int32)
    mask = pos[:, None] >= pos[None, :]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", a, vf)
    return o.reshape(B, S, H, Dh).astype(q.dtype)
