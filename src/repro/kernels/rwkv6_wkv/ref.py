"""Pure-jnp oracle for the WKV6 recurrence: literal per-step scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, lw, u, s0=None):
    """r,k,v,lw: [B,T,H,K]; u: [H,K].  Sequential fp32 recurrence.

    Returns (y [B,T,H,K], state [B,H,K,K]).
    """
    B, T, H, K = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, K, K), jnp.float32)

    def step(s, inp):
        rt, kt, vt, lt = (x.astype(jnp.float32) for x in inp)  # [B,H,K]
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv",
                       rt, s + u.astype(jnp.float32)[None, :, :, None] * kv)
        s = s * jnp.exp(lt)[..., None] + kv
        return s, y

    xs = tuple(x.swapaxes(0, 1) for x in (r, k, v, lw))
    s, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).astype(r.dtype), s
