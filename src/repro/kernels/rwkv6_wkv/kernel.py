"""Pallas TPU kernel for the RWKV-6 WKV recurrence (chunked, log-space).

Grid ``(B, H, n_chunks)`` with the chunk dim innermost; the [K, K] state
matrix lives in fp32 VMEM scratch and persists across chunks, so the HBM
traffic is exactly one pass over r/k/v/decay plus one state write — the
recurrence never round-trips the state.  Within a chunk the pairwise
decay matrix ``exp(Lx_t − Li_s)`` (s < t → exponent ≤ 0, numerically
safe) forms the attention-like intra-chunk term; the carry state update
is a rank-c matmul.

Layout: r,k,v,lw [B, H, T, K] (head-major); u [H, K]; state out [B,H,K,K].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_out_ref, s_ref,
            *, chunk: int, n_chunks: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)           # [c, K]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)              # [K]

    li = jnp.cumsum(lw, axis=0)                   # inclusive
    lx = li - lw                                  # exclusive
    # A[t,s] = Σ_k r[t,k]·k[s,k]·exp(lx[t]−li[s])   (s < t)
    dec = jnp.exp(lx[:, None, :] - li[None, :, :])           # [c,c,K]
    a = jnp.sum(r[:, None, :] * k[None, :, :] * dec, axis=-1)
    c = chunk
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)
    a = jnp.where(ti > si, a, 0.0) + jnp.where(
        ti == si, diag[:, None], 0.0)
    y = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + jax.lax.dot_general(r * jnp.exp(lx), s_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state: S' = diag(e^{Lc}) S + Σ_s (k_s e^{Lc−Li_s})ᵀ v_s
    lc = li[-1:, :]                                # [1,K]
    kd = k * jnp.exp(lc - li)
    s_ref[...] = s_ref[...] * jnp.exp(lc).T + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(t == n_chunks - 1)
    def _finish():
        s_out_ref[0, 0] = s_ref[...]


def wkv6_hm(r, k, v, lw, u, *, chunk: int = 32, interpret: bool = False):
    """Head-major WKV6.  r,k,v,lw: [B,H,T,K]; u: [H,K].

    Returns (y [B,H,T,K], state [B,H,K,K] fp32).
    """
    B, H, T, K = r.shape
    c = min(chunk, T)
    assert T % c == 0
    n = T // c
    kernel = functools.partial(_kernel, chunk=c, n_chunks=n)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, c, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, c, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, c, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, c, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, K), lambda b, h, t: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, K, K), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, K), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, K), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
    return y, s_out
