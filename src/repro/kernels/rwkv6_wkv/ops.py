"""Jit'd wrapper: seq-major [B,T,H,K] API over the head-major WKV kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import wkv6_hm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, lw, u, *, chunk: int = 32):
    """r,k,v,lw: [B,T,H,K]; u: [H,K] → (y [B,T,H,K], state [B,H,K,K])."""
    rh, kh, vh, lh = (x.transpose(0, 2, 1, 3) for x in (r, k, v, lw))
    y, s = wkv6_hm(rh, kh, vh, lh, u, chunk=chunk, interpret=_interpret())
    return y.transpose(0, 2, 1, 3), s
