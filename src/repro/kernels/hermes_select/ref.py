"""Oracle for the batched Hermes dispatch: numpy loop over arrivals."""
from __future__ import annotations

import numpy as np

from repro.core.policies import hermes_score_np


def hermes_select_ref(active, warm_cols, *, cores: int, slots: int):
    """active: [W] int; warm_cols: [N, W].  Sequential reference."""
    active = np.asarray(active, np.int64).copy()
    warm_cols = np.asarray(warm_cols)
    N = warm_cols.shape[0]
    out = np.full(N, -1, np.int32)
    for i in range(N):
        if not (active < slots).any():
            continue
        score, _ = hermes_score_np(active, warm_cols[i], cores, slots)
        w = int(np.argmax(score))
        out[i] = w
        active[w] += 1
    return out, active.astype(np.int32)
