"""Pallas TPU kernel for the Hermes controller hot loop (paper §4.2, §6.6).

The OpenWhisk controller sustains ~3.8 k scheduling decisions per second;
each decision is a streaming reduction over the per-worker load vector
(score → argmax → load update).  A scalar implementation re-reads the
cluster state from HBM per invocation.  On TPU the natural formulation is
*batched sequential dispatch*: the whole ``[W]`` active-count vector stays
resident in VMEM while a batch of arrivals is dispatched in order — one
HBM read of cluster state per *batch* rather than per invocation, with
each decision a vectorized O(W) score + argmax on the VPU.

Semantics (must match ``repro.core.policies.hermes_score_np`` exactly —
the sequential dependency is preserved, this is not an approximation):

* low-load mode (∃ worker with a free core): among workers with a free
  core prefer class 3 = non-empty & warm, 2 = non-empty, 1 = warm,
  0 = empty; within a class prefer the most loaded (packing).
* high-load mode: least-loaded among workers with a free slot; warmth
  breaks ties.  All-full → sentinel ``-1`` (rejection).

Completions between arrivals are applied by the caller batch-by-batch
(the serving controller syncs worker state at batch boundaries, exactly
like the paper's synchronous Controller↔Worker protocol).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BIG = 1 << 30


def _kernel(active_ref, warm_ref, out_ref, active_out_ref, act_ref,
            *, n: int, cores: int, slots: int):
    act_ref[...] = active_ref[...]                    # [1, W] int32

    def body(i, _):
        active = act_ref[0]                           # [W]
        warm = warm_ref[i] > 0                        # [W] bool
        has_slot = active < slots
        has_core = active < cores
        nonempty = active > 0
        warm_i = warm.astype(jnp.int32)
        cls = jnp.where(nonempty, 2 + warm_i, warm_i)
        lo = jnp.where(has_core, cls * (slots + 1) + active, -_BIG)
        hi = jnp.where(has_slot, -(active * 2 - warm_i), -_BIG)
        score = jnp.where(has_core.any(), lo, hi)
        w = jnp.argmax(score).astype(jnp.int32)
        ok = has_slot.any()
        out_ref[i] = jnp.where(ok, w, -1)
        act_ref[0] = jnp.where(
            ok & (jax.lax.iota(jnp.int32, active.shape[0]) == w),
            active + 1, active)
        return _

    # strong-typed bounds/carry: Python-int literals would thread a
    # weak int64 carry through the loop (repro.analysis JXP001)
    jax.lax.fori_loop(jnp.int32(0), jnp.int32(n), body, jnp.int32(0))
    active_out_ref[...] = act_ref[...]


def hermes_select_batch(active, warm_cols, *, cores: int, slots: int,
                        interpret: bool = False):
    """Dispatch a batch of arrivals with Hermes hybrid balancing.

    active: [W] int32 current per-worker active counts;
    warm_cols: [N, W] int32 — warm-executor count of each arrival's
    function on each worker (gathered by the caller from ``warm[W, F]``).

    Returns (choices [N] int32 — worker ids or -1, active_out [W]).
    """
    W = active.shape[0]
    N = warm_cols.shape[0]
    kernel = functools.partial(_kernel, n=N, cores=cores, slots=slots)
    out, active_out = pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec((1, W), lambda: (0, 0)),
                  pl.BlockSpec((N, W), lambda: (0, 0))],
        out_specs=[pl.BlockSpec((N,), lambda: (0,)),
                   pl.BlockSpec((1, W), lambda: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((1, W), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, W), jnp.int32)],
        interpret=interpret,
    )(active[None], warm_cols)
    return out, active_out[0]
