"""Jit'd wrapper for batched Hermes dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import hermes_select_batch


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("cores", "slots"))
def hermes_select(active, warm, funcs, *, cores: int, slots: int):
    """active: [W] i32; warm: [W, F] i32; funcs: [N] i32 arrival fn ids."""
    warm_cols = warm.T[funcs].astype(jnp.int32)       # [N, W]
    return hermes_select_batch(active.astype(jnp.int32), warm_cols,
                               cores=cores, slots=slots,
                               interpret=_interpret())
