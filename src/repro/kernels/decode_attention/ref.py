"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, pos):
    """q: [B,H,Dh]; k,v: [B,S,KV,Dh]; pos: [B] → [B,H,Dh] (fp32 math)."""
    B, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, g, Dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32))
    s = s / math.sqrt(Dh)
    t = jnp.arange(S, dtype=pos.dtype)
    mask = t[None, :] <= pos[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", a, v.astype(jnp.float32))
    return o.reshape(B, H, Dh).astype(q.dtype)
