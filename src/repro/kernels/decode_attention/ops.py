"""Jit'd wrapper: seq-major cache API over the head-major decode kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import decode_attention_hm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("bk",))
def decode_attention(q, k, v, pos, *, bk: int = 512):
    """q: [B,H,Dh]; k,v: [B,S,KV,Dh]; pos: [B] int32 → [B,H,Dh]."""
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    return decode_attention_hm(q, kh, vh, pos, bk=bk,
                               interpret=_interpret())
