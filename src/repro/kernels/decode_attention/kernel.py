"""Pallas TPU decode attention (single query token vs a long KV cache).

Memory-bound streaming reduction: grid ``(B, H, n_kv)`` with KV blocks
innermost; the query row and running ``(m, l, acc)`` stay in VMEM while
the cache streams HBM→VMEM once.  Positions past ``pos`` are masked (the
cache is a ring of capacity ≥ pos+1).

Layout: q [B, H, Dh]; k,v [B, KV, S, Dh]; pos [B] int32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, bk: int, n_kv: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    # skip blocks entirely past the valid prefix
    @pl.when(j * bk <= pos)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [1, Dh] row
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, Dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        t = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(t <= pos, s * scale, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_hm(q, k, v, pos, *, bk: int = 512,
                        interpret: bool = False):
    """q: [B,H,Dh]; k,v: [B,KV,S,Dh]; pos: [B] int32 → [B,H,Dh]."""
    B, H, Dh = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    bk = min(bk, S)
    n_kv = pl.cdiv(S, bk)
    scale = 1.0 / math.sqrt(Dh)
    kernel = functools.partial(_kernel, scale=scale, bk=bk, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
            pl.BlockSpec((1, 1, 1, Dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Dh), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(pos, q[:, :, None, :], k, v)
    return out[:, :, 0, :]
