"""Jit'd wrapper over the SSD kernel (interpret on CPU, Mosaic on TPU)."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import ssd_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt_h, bmat, cmat, a, *, chunk: int = 128):
    """x: [B,T,H,P]; dt_h: [B,T,H]; b,c: [B,T,N]; a: [H]."""
    return ssd_pallas(x, dt_h, bmat, cmat, a, chunk=chunk,
                      interpret=_interpret())
