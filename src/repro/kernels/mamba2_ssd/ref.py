"""Pure-jnp oracle for the SSD scan: literal per-step recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt_h, bmat, cmat, a, h0=None):
    """x: [B,T,H,P]; dt_h: [B,T,H]; bmat,cmat: [B,T,N]; a: [H].

    Returns (y [B,T,H,P], state [B,H,P,N]).
    """
    B, T, H, P = x.shape
    N = bmat.shape[-1]
    a = jnp.asarray(a, jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dt_t, bt, ct = inp
        xt = xt.astype(jnp.float32)
        dt_t = dt_t.astype(jnp.float32)
        decay = jnp.exp(dt_t * a[None, :])[:, :, None, None]
        upd = dt_t[:, :, None, None] * xt[..., None] * \
            bt.astype(jnp.float32)[:, None, None, :]
        h = h * decay + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct.astype(jnp.float32))
        return h, y

    xs = (x.swapaxes(0, 1), dt_h.swapaxes(0, 1),
          bmat.swapaxes(0, 1), cmat.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), h
