"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid ``(B, n_chunks)``; all heads of one sequence-chunk are processed per
step so the [H, P, N] state (fp32) persists in VMEM scratch across the
chunk dim.  Intra-chunk work is the 1-semiseparable matrix form: a
scalar-per-head pairwise decay builds [c, c] score matrices (log-space,
exponent ≤ 0), inter-chunk work is a rank-c state update.

Layout: x [B,T,H,P]; dt [B,T,H]; bmat,cmat [B,T,N]; a [H];
outs: y [B,T,H,P], state [B,H,P,N] fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, s_out_ref, s_ref,
            *, chunk: int, n_chunks: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)             # [c, H, P]
    dd = dt_ref[0].astype(jnp.float32)           # [c, H]
    bm = b_ref[0].astype(jnp.float32)            # [c, N]
    cm = c_ref[0].astype(jnp.float32)            # [c, N]
    a = a_ref[...].astype(jnp.float32)           # [H]

    la = jnp.cumsum(dd * a[None, :], axis=0)     # [c, H], ≤ 0
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [c,c]
    dec = jnp.exp(la[:, None, :] - la[None, :, :])               # [t,s,H]
    c = chunk
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    m = jnp.where((ti >= si)[:, :, None],
                  cb[:, :, None] * dec * dd[None, :, :], 0.0)    # [t,s,H]
    y = jnp.einsum("tsh,shp->thp", m, x)
    # carry-in: y += C_t · (S ⊙ e^{la_t})  per head
    y = y + jnp.einsum("tn,hpn,th->thp", cm, s_ref[...], jnp.exp(la))
    y_ref[0] = y.astype(y_ref.dtype)
    # state: S' = S·e^{la_end} + Σ_s e^{la_end−la_s}·Δ_s·B_s⊗x_s
    la_end = la[-1:, :]                                          # [1,H]
    w = jnp.exp(la_end - la) * dd                                # [c,H]
    upd = jnp.einsum("sh,sn,shp->hpn", w, bm, x)
    s_ref[...] = s_ref[...] * jnp.exp(la_end[0])[:, None, None] + upd

    @pl.when(t == n_chunks - 1)
    def _finish():
        s_out_ref[0] = s_ref[...]


def ssd_pallas(x, dt_h, bmat, cmat, a, *, chunk: int = 128,
               interpret: bool = False):
    """x: [B,T,H,P]; dt_h: [B,T,H]; bmat,cmat: [B,T,N]; a: [H].

    Returns (y [B,T,H,P], state [B,H,P,N] fp32).
    """
    B, T, H, P = x.shape
    N = bmat.shape[-1]
    c = min(chunk, T)
    assert T % c == 0
    n = T // c
    kernel = functools.partial(_kernel, chunk=c, n_chunks=n)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(B, n),
        in_specs=[
            pl.BlockSpec((1, c, H, P), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, c, H), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, c, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, c, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((H,), lambda b, t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, H, P), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, t: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt_h, bmat, cmat, a)
    return y, s_out
