"""Telemetry plane 4 — windowed time-series flight recorder (jax side).

The functional twins of :mod:`repro.telemetry.timeline`'s numpy
updaters: each takes the timeline pytree (dict of jax arrays) plus
traced event operands and returns the updated pytree.  They are called
inside the simulator's ``lax.scan`` / ``lax.while_loop`` bodies behind
a python gate (``if tl_on:``), so with the timeline off the engine
traces the bit-identical pre-timeline program — the same golden
contract as ``TelemetryState`` / ``life`` / ``fleet``.

Parity contract with the numpy side:

* the window index is ``clip(floor(now / window_s), 0, K-1)`` — one f64
  division, floor and clip over identical operands on both sides, so
  window assignment is bitwise np ≡ jax;
* sketch coarsening is *integer* division of the fine bin index
  (``bin // (N_BINS // B)``) — the fine bin comes from the shared
  ``searchsorted`` over :func:`repro.telemetry.sketch.hist_edges`, so
  coarse counts are bitwise equal;
* masked updates scatter into a dropped out-of-range row
  (``mode="drop"``), mirroring the oracle's plain ``if``;
* the bounded event log writes at ``where(count < E, count, E)`` with
  ``mode="drop"`` — the count keeps incrementing past the bound so
  truncation is observable, exactly like the numpy side.

jax-only by design (imported from ``repro.core.simulator``, never from
the numpy oracle), like :mod:`repro.telemetry.engine`.
"""
from __future__ import annotations

import jax.numpy as jnp

from .engine import bin_index
from .sketch import N_BINS
from .timeline import TimelineCfg, coarse_group


def init_state(n_workers: int, cfg: TimelineCfg) -> dict:
    """Zeroed timeline pytree — the jax twin of ``timeline.init_tl_np``.

    ``window_s`` starts 0 and is overwritten with the runtime width
    (horizon / K, or the configured constant) before the scan runs.
    """
    K, B = int(cfg.n_windows), int(cfg.coarse_bins)
    E = int(cfg.max_events)
    return {
        "window_s": jnp.float64(0.0),
        "mode": jnp.int32(1),
        "arrivals": jnp.zeros(K, dtype=jnp.int64),
        "n_cold": jnp.zeros(K, dtype=jnp.int64),
        "n_warm": jnp.zeros(K, dtype=jnp.int64),
        "n_evict": jnp.zeros(K, dtype=jnp.int64),
        "n_reject": jnp.zeros(K, dtype=jnp.int64),
        "slow_hist": jnp.zeros((K, B), dtype=jnp.int64),
        "lat_hist": jnp.zeros((K, B), dtype=jnp.int64),
        "busy_time": jnp.zeros((K, n_workers), dtype=jnp.float64),
        "qlen_time": jnp.zeros(K, dtype=jnp.float64),
        "prov_core": jnp.zeros(K, dtype=jnp.float64),
        "n_on": jnp.zeros(K, dtype=jnp.int32),
        "ev_t": jnp.zeros(E, dtype=jnp.float64),
        "ev_kind": jnp.zeros(E, dtype=jnp.int32),
        "ev_val": jnp.zeros(E, dtype=jnp.int32),
        "ev_p99": jnp.full(E, jnp.nan, dtype=jnp.float64),
        "ev_count": jnp.zeros((), dtype=jnp.int64),
    }


def window_index(now, window_s, n_windows: int):
    """Twin of ``timeline.window_index_np`` (identical f64 ops)."""
    safe = jnp.where(window_s > 0.0, window_s, 1.0)
    k = jnp.clip(jnp.floor(now / safe).astype(jnp.int64),
                 0, n_windows - 1)
    return jnp.where(window_s > 0.0, k, jnp.int64(0))


def _k(tl: dict, t):
    return window_index(t, tl["window_s"], tl["arrivals"].shape[0])


def on_arrival(tl: dict, t, n_on) -> dict:
    """Count an arrival; last-write-wins the active-worker level."""
    k = _k(tl, t)
    return {
        **tl,
        "arrivals": tl["arrivals"].at[k].add(jnp.int64(1)),
        "n_on": tl["n_on"].at[k].set(
            jnp.asarray(n_on, dtype=jnp.int32)),
    }


def on_place(tl: dict, t, is_cold, evicted) -> dict:
    """Record one placement (callers only place *accepted* arrivals)."""
    k = _k(tl, t)
    cold = is_cold.astype(jnp.int64)
    return {
        **tl,
        "n_cold": tl["n_cold"].at[k].add(cold),
        "n_warm": tl["n_warm"].at[k].add(jnp.int64(1) - cold),
        "n_evict": tl["n_evict"].at[k].add(evicted.astype(jnp.int64)),
    }


def on_advance(tl: dict, t, tau, active, qlen) -> dict:
    """Busy/queue-length integrals, credited to the interval start —
    the same left-Riemann convention as ``server_time``."""
    k = _k(tl, t)
    return {
        **tl,
        "busy_time": tl["busy_time"].at[k].add(
            tau * active.astype(jnp.float64)),
        "qlen_time": tl["qlen_time"].at[k].add(
            tau * qlen.astype(jnp.float64)),
    }


def on_complete(tl: dict, t, response, service, completed,
                edges) -> dict:
    """Coarse sketch scatter at the (masked) completion time."""
    group = N_BINS // int(tl["slow_hist"].shape[1])
    K = tl["arrivals"].shape[0]
    k = _k(tl, t)
    kk = jnp.where(completed, k, jnp.int64(K))   # out of range -> drop
    slow = response / jnp.maximum(service, 1e-12)
    sb = bin_index(slow, edges) // group
    lb = bin_index(response, edges) // group
    return {
        **tl,
        "slow_hist": tl["slow_hist"].at[kk, sb].add(jnp.int64(1),
                                                    mode="drop"),
        "lat_hist": tl["lat_hist"].at[kk, lb].add(jnp.int64(1),
                                                  mode="drop"),
    }


def on_evict(tl: dict, t, count) -> dict:
    k = _k(tl, t)
    return {**tl, "n_evict": tl["n_evict"].at[k].add(
        count.astype(jnp.int64))}


def on_reject(tl: dict, t, rejected) -> dict:
    k = _k(tl, t)
    return {**tl, "n_reject": tl["n_reject"].at[k].add(
        rejected.astype(jnp.int64))}


def on_prov(tl: dict, t, core_s) -> dict:
    k = _k(tl, t)
    return {**tl, "prov_core": tl["prov_core"].at[k].add(core_s)}


def on_event(tl: dict, record, t, kind: int, val, p99) -> dict:
    """Masked append to the bounded decision log.

    ``record`` gates the write; the index parks out of range
    (``mode="drop"``) when not recording or when the log is full.  The
    count increments on every recorded event regardless, so truncation
    stays visible host-side.
    """
    E = tl["ev_t"].shape[0]
    c = tl["ev_count"]
    idx = jnp.where(record & (c < E), c, jnp.int64(E))
    return {
        **tl,
        "ev_t": tl["ev_t"].at[idx].set(t, mode="drop"),
        "ev_kind": tl["ev_kind"].at[idx].set(jnp.int32(kind),
                                             mode="drop"),
        "ev_val": tl["ev_val"].at[idx].set(
            jnp.asarray(val, dtype=jnp.int32), mode="drop"),
        "ev_p99": tl["ev_p99"].at[idx].set(p99, mode="drop"),
        "ev_count": c + record.astype(jnp.int64),
    }


def sensor_p99(window, edges):
    """Twin of ``timeline.sensor_p99_np`` — the exact op sequence of
    ``repro.fleet.policies._target_p99_jax``'s percentile read."""
    window = window.astype(jnp.int64)
    total = window.sum()
    tot_f = total.astype(jnp.float64)
    k = jnp.clip(jnp.ceil(0.99 * tot_f).astype(jnp.int64),
                 jnp.int64(1), jnp.maximum(total, 1))
    b = jnp.searchsorted(jnp.cumsum(window), k, side="left")
    return jnp.sqrt(edges[b] * edges[b + 1])
