"""repro.telemetry — three-plane observability subsystem.

Plane 1 — in-engine streaming metrics (:mod:`~repro.telemetry.state` /
:mod:`~repro.telemetry.sketch`; jax twins in
:mod:`~repro.telemetry.engine`): an opt-in ``TelemetryState`` pytree
carried through the scan — log-spaced slowdown/latency histogram
sketches, cold/warm/evict/reject counters, per-worker busy-time and
queue-depth integrals, balancer decision histograms.

Plane 2 — host-side span tracing (:mod:`~repro.telemetry.spans`):
zero-dep nested spans exported as Perfetto-loadable Chrome trace JSON.

Plane 3 — run provenance (:mod:`~repro.telemetry.manifest`):
``RunManifest`` blocks attached to benchmark reports.

Plane 4 — windowed time-series flight recorder
(:mod:`~repro.telemetry.timeline`; jax twins in
:mod:`~repro.telemetry.timeline_engine`): an opt-in fixed-``K``-window
``TimelineCfg`` plane carried next to the telemetry state — per-window
arrival/cold/evict/reject counts, coarse slowdown/latency sketches,
busy/queue/provisioned integrals, the active-worker trajectory and a
bounded autoscaler/mode-flip decision log, exported as CSV /
OpenMetrics / Perfetto counter tracks.

This package is importable without jax — :mod:`repro.telemetry.engine`
and :mod:`repro.telemetry.timeline_engine` (the jax twins) are
deliberately *not* imported here; the simulator imports them directly.
"""
from .manifest import RunManifest, collect as collect_manifest, \
    wall_split_from_aggregate
from .sketch import (HIST_HI, HIST_LO, N_BINS, bin_index_np, hist_edges,
                     sketch_count, sketch_percentile)
from .spans import (Tracer, configure_tracing, get_tracer, set_tracer,
                    span)
from .state import (TelemetryCfg, TelemetryResult, WarmupMismatchError,
                    init_np, on_advance_np, on_complete_np, on_evict_np,
                    on_place_np, on_reject_np, warmup_cutoff)
from .timeline import (TimelineCfg, TimelineResult, auto_window_s,
                       coarse_edges, coarse_group, validate_timeline,
                       window_index_np)

__all__ = [
    "N_BINS", "HIST_LO", "HIST_HI", "hist_edges", "bin_index_np",
    "sketch_percentile", "sketch_count",
    "TelemetryCfg", "TelemetryResult", "WarmupMismatchError", "init_np",
    "warmup_cutoff",
    "on_place_np", "on_advance_np", "on_complete_np", "on_evict_np",
    "on_reject_np",
    "TimelineCfg", "TimelineResult", "auto_window_s", "coarse_edges",
    "coarse_group", "validate_timeline", "window_index_np",
    "Tracer", "configure_tracing", "get_tracer", "set_tracer", "span",
    "RunManifest", "collect_manifest", "wall_split_from_aggregate",
]
