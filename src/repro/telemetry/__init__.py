"""repro.telemetry — three-plane observability subsystem.

Plane 1 — in-engine streaming metrics (:mod:`~repro.telemetry.state` /
:mod:`~repro.telemetry.sketch`; jax twins in
:mod:`~repro.telemetry.engine`): an opt-in ``TelemetryState`` pytree
carried through the scan — log-spaced slowdown/latency histogram
sketches, cold/warm/evict/reject counters, per-worker busy-time and
queue-depth integrals, balancer decision histograms.

Plane 2 — host-side span tracing (:mod:`~repro.telemetry.spans`):
zero-dep nested spans exported as Perfetto-loadable Chrome trace JSON.

Plane 3 — run provenance (:mod:`~repro.telemetry.manifest`):
``RunManifest`` blocks attached to benchmark reports.

This package is importable without jax — :mod:`repro.telemetry.engine`
(the jax twins) is deliberately *not* imported here; the simulator
imports it directly.
"""
from .manifest import RunManifest, collect as collect_manifest, \
    wall_split_from_aggregate
from .sketch import (HIST_HI, HIST_LO, N_BINS, bin_index_np, hist_edges,
                     sketch_count, sketch_percentile)
from .spans import (Tracer, configure_tracing, get_tracer, set_tracer,
                    span)
from .state import (TelemetryCfg, TelemetryResult, init_np,
                    on_advance_np, on_complete_np, on_evict_np,
                    on_place_np, on_reject_np, warmup_cutoff)

__all__ = [
    "N_BINS", "HIST_LO", "HIST_HI", "hist_edges", "bin_index_np",
    "sketch_percentile", "sketch_count",
    "TelemetryCfg", "TelemetryResult", "init_np", "warmup_cutoff",
    "on_place_np", "on_advance_np", "on_complete_np", "on_evict_np",
    "on_reject_np",
    "Tracer", "configure_tracing", "get_tracer", "set_tracer", "span",
    "RunManifest", "collect_manifest", "wall_split_from_aggregate",
]
