"""Telemetry plane 1 — streaming in-engine metrics (jax side).

The functional twins of :mod:`repro.telemetry.state`'s numpy updaters:
each takes the telemetry pytree (dict of jax arrays) plus traced event
operands and returns the updated pytree.  They are called *inside* the
simulator's ``lax.while_loop`` / ``lax.scan`` bodies, behind a python
gate (``if tel_on:``) identical in spirit to ``SimState.life`` — with
telemetry off the engine traces the bit-identical pre-telemetry
program.

Parity contract with the numpy side:

* bin assignment uses ``jnp.searchsorted(edges, x, side="right") - 1``
  over the *same float64 edge array* (``sketch.hist_edges()``) — a
  binary search over identical bits yields identical integer bins, so
  histogram counts are bitwise np ≡ jax;
* counters are int64 adds of exact small integers — bitwise equal;
* time integrals are float64 ``tau * occupancy`` sums accumulated in
  the same event order — equal to ~1e-9 relative (same tolerance class
  as the engines' ``server_time`` agreement).

This module is jax-only by design: it is imported from
``repro.core.simulator`` (a hot-path module), never from the numpy
oracle, so ``repro.telemetry`` stays importable without jax.
"""
from __future__ import annotations

import jax.numpy as jnp

from .sketch import N_BINS, hist_edges


def init_state(n_workers: int) -> dict:
    """Zeroed telemetry pytree — the jax twin of ``state.init_np``."""
    return {
        "slow_hist": jnp.zeros(N_BINS, dtype=jnp.int64),
        "lat_hist": jnp.zeros(N_BINS, dtype=jnp.int64),
        "n_cold": jnp.zeros((), dtype=jnp.int64),
        "n_warm": jnp.zeros((), dtype=jnp.int64),
        "n_evict": jnp.zeros((), dtype=jnp.int64),
        "n_reject": jnp.zeros((), dtype=jnp.int64),
        "busy_time": jnp.zeros(n_workers, dtype=jnp.float64),
        "depth_time": jnp.zeros(n_workers, dtype=jnp.float64),
        "qlen_time": jnp.zeros((), dtype=jnp.float64),
        "decisions": jnp.zeros(n_workers, dtype=jnp.int64),
    }


def edges_for_trace() -> jnp.ndarray:
    """The shared bin edges as a jax constant (closed over at build)."""
    return jnp.asarray(hist_edges(), dtype=jnp.float64)


def bin_index(x, edges) -> jnp.ndarray:
    """Clamped right-searchsorted bin — twin of ``sketch.bin_index_np``."""
    return jnp.clip(jnp.searchsorted(edges, x, side="right") - 1,
                    0, N_BINS - 1)


def on_place(tel: dict, worker, is_cold, evicted) -> dict:
    """Record one placement (callers only place *accepted* arrivals)."""
    cold = is_cold.astype(jnp.int64)
    return {
        **tel,
        "n_cold": tel["n_cold"] + cold,
        "n_warm": tel["n_warm"] + (jnp.int64(1) - cold),
        "n_evict": tel["n_evict"] + evicted.astype(jnp.int64),
        "decisions": tel["decisions"].at[worker].add(jnp.int64(1)),
    }


def on_advance(tel: dict, tau, active, depth, qlen) -> dict:
    """Accumulate pre-advance occupancy integrals over interval ``tau``."""
    return {
        **tel,
        "busy_time": tel["busy_time"]
        + tau * active.astype(jnp.float64),
        "depth_time": tel["depth_time"]
        + tau * depth.astype(jnp.float64),
        "qlen_time": tel["qlen_time"]
        + tau * qlen.astype(jnp.float64),
    }


def on_complete(tel: dict, response, service, arr_idx, completed,
                cutoff, edges) -> dict:
    """Scatter one (masked) completion into both histograms.

    ``completed`` is the per-worker completion mask for this advance;
    completions of warmup tasks (``arr_idx < cutoff``) are masked out so
    the sketch population equals ``summarize``'s post-warmup set.
    Masked lanes scatter into a dropped out-of-range bin.
    """
    rec = completed & (arr_idx >= cutoff)
    slow = response / jnp.maximum(service, 1e-12)
    slow_bin = jnp.where(rec, bin_index(slow, edges), N_BINS)
    lat_bin = jnp.where(rec, bin_index(response, edges), N_BINS)
    return {
        **tel,
        "slow_hist": tel["slow_hist"].at[slow_bin].add(jnp.int64(1),
                                                       mode="drop"),
        "lat_hist": tel["lat_hist"].at[lat_bin].add(jnp.int64(1),
                                                    mode="drop"),
    }


def on_evict(tel: dict, count) -> dict:
    """Add ``count`` lifecycle (idle-budget / keep-alive) evictions."""
    return {**tel, "n_evict": tel["n_evict"] + count.astype(jnp.int64)}


def on_reject(tel: dict, rejected) -> dict:
    return {**tel,
            "n_reject": tel["n_reject"] + rejected.astype(jnp.int64)}
