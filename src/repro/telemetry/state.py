"""Telemetry plane 1 — streaming in-engine metrics (numpy side).

``TelemetryState`` is a plain dict of numpy arrays with fixed shapes —
the same layout the jax engine carries as a pytree inside the vmapped
``lax.scan`` (see :mod:`repro.telemetry.engine`).  Keeping the state a
dict (not a dataclass) means one ``jax.tree_util``-compatible container
serves both backends, and np ≡ jax parity is a per-key array compare.

Update points mirror the simulator's event structure exactly, so the
oracle (`sim_ref`), the serving platform and the scan engine all observe
the same counters at the same event boundaries:

=================  =======================================================
``on_place``       cold/warm counters, capacity-eviction counter, the
                   balancer decision histogram (one bump per placement)
``on_advance``     per-worker busy-time / queue-depth time integrals and
                   the global queue-length time integral (pre-advance
                   occupancy x tau, i.e. a left-Riemann integral exact
                   for piecewise-constant occupancy)
``on_complete``    slowdown/latency histogram scatter (only for tasks
                   past the warmup cutoff, matching ``summarize``'s
                   warmup-drop population)
``on_evict``       keep-alive / idle-budget evictions (lifecycle plane)
``on_reject``      admission rejections
=================  =======================================================

All counters are int64 and all time integrals float64 — the integer
planes are asserted *bitwise* equal between numpy and jax in the parity
tests; the float integrals to 1e-9 relative.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping, NamedTuple

import numpy as np

from .sketch import (N_BINS, bin_index_np, hist_edges, sketch_count,
                     sketch_percentile)


class TelemetryCfg(NamedTuple):
    """Opt-in telemetry configuration (hashable: part of the engine key).

    ``warmup_frac`` must match the ``warmup_frac`` later passed to
    ``summarize`` for the sketch population to equal the exact-percentile
    population; the default mirrors ``metrics.summarize``'s default.
    A mismatch raises :class:`WarmupMismatchError` at summarize time
    instead of silently skewing the comparison.
    """
    warmup_frac: float = 0.1


class WarmupMismatchError(ValueError):
    """The engine's ``TelemetryCfg.warmup_frac`` differs from the
    ``warmup_frac`` handed to ``summarize``/``summarize_batch``.

    The sketch population is fixed at engine time (``warmup_cutoff``);
    summarizing the same run with a different cutoff would compare two
    different task populations — a silent skew this error makes loud.
    """

    def __init__(self, engine_frac: float, summarize_frac: float):
        self.engine_frac = float(engine_frac)
        self.summarize_frac = float(summarize_frac)
        super().__init__(
            f"telemetry sketches were accumulated with warmup_frac="
            f"{engine_frac!r} but summarize was called with "
            f"warmup_frac={summarize_frac!r}; the two populations "
            f"differ — pass the same warmup_frac to both (or rerun the "
            f"engine with TelemetryCfg(warmup_frac={summarize_frac!r}))")


def init_np(n_workers: int) -> dict:
    """Fresh zeroed telemetry state for an ``n_workers``-wide cluster."""
    return {
        "slow_hist": np.zeros(N_BINS, dtype=np.int64),
        "lat_hist": np.zeros(N_BINS, dtype=np.int64),
        "n_cold": np.int64(0),
        "n_warm": np.int64(0),
        "n_evict": np.int64(0),
        "n_reject": np.int64(0),
        "busy_time": np.zeros(n_workers, dtype=np.float64),
        "depth_time": np.zeros(n_workers, dtype=np.float64),
        "qlen_time": np.float64(0.0),
        "decisions": np.zeros(n_workers, dtype=np.int64),
    }


# --------------------------------------------------------------------------
# Oracle-side update functions (mutate the dict in place; the jax engine
# in telemetry/engine.py performs the same arithmetic functionally).
# --------------------------------------------------------------------------

def on_place_np(tel: dict, worker: int, is_cold: bool,
                evicted: bool) -> None:
    if is_cold:
        tel["n_cold"] += 1
    else:
        tel["n_warm"] += 1
    if evicted:
        tel["n_evict"] += 1
    tel["decisions"][worker] += 1


def on_advance_np(tel: dict, tau: float, active_per_worker: np.ndarray,
                  depth_per_worker: np.ndarray, qlen: int) -> None:
    """Accumulate time integrals over a ``tau``-long constant interval.

    ``active_per_worker`` — workers with >= 1 running task (0/1);
    ``depth_per_worker`` — number of running tasks per worker; ``qlen``
    — central queue length.  All sampled *before* the advance, matching
    the engine's pre-advance occupancy convention for server/core time.
    """
    tel["busy_time"] += tau * np.asarray(active_per_worker,
                                         dtype=np.float64)
    tel["depth_time"] += tau * np.asarray(depth_per_worker,
                                          dtype=np.float64)
    tel["qlen_time"] += tau * float(qlen)


def on_complete_np(tel: dict, response_s: float, service_s: float,
                   arr_idx: int, cutoff: int) -> None:
    if arr_idx < cutoff:
        return
    slow = response_s / max(service_s, 1e-12)
    tel["slow_hist"][bin_index_np(slow)] += 1
    tel["lat_hist"][bin_index_np(response_s)] += 1


def on_evict_np(tel: dict, count: int = 1) -> None:
    tel["n_evict"] += count


def on_reject_np(tel: dict) -> None:
    tel["n_reject"] += 1


# --------------------------------------------------------------------------
# Result wrapper
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TelemetryResult:
    """Materialized telemetry from one run (or a batch, pooled on read).

    Array fields keep whatever leading batch axes the engine produced
    (``[R, ...]`` from ``simulate_many``); the percentile/summary readers
    pool across them, mirroring ``summarize_batch``'s pooled statistics.
    """
    slow_hist: np.ndarray
    lat_hist: np.ndarray
    n_cold: np.ndarray
    n_warm: np.ndarray
    n_evict: np.ndarray
    n_reject: np.ndarray
    busy_time: np.ndarray
    depth_time: np.ndarray
    qlen_time: np.ndarray
    decisions: np.ndarray
    cfg: TelemetryCfg = TelemetryCfg()

    @staticmethod
    def from_state(tel: Mapping[str, Any],
                   cfg: TelemetryCfg = TelemetryCfg()) -> "TelemetryResult":
        return TelemetryResult(
            slow_hist=np.asarray(tel["slow_hist"], dtype=np.int64),
            lat_hist=np.asarray(tel["lat_hist"], dtype=np.int64),
            n_cold=np.asarray(tel["n_cold"], dtype=np.int64),
            n_warm=np.asarray(tel["n_warm"], dtype=np.int64),
            n_evict=np.asarray(tel["n_evict"], dtype=np.int64),
            n_reject=np.asarray(tel["n_reject"], dtype=np.int64),
            busy_time=np.asarray(tel["busy_time"], dtype=np.float64),
            depth_time=np.asarray(tel["depth_time"], dtype=np.float64),
            qlen_time=np.asarray(tel["qlen_time"], dtype=np.float64),
            decisions=np.asarray(tel["decisions"], dtype=np.int64),
            cfg=cfg,
        )

    # -- streaming percentile reads (pooled over any batch axes) --------
    def slow_percentile(self, q: float) -> float:
        return sketch_percentile(self.slow_hist, q)

    def lat_percentile(self, q: float) -> float:
        return sketch_percentile(self.lat_hist, q)

    def summary(self) -> dict:
        """Compact JSON-friendly digest (used by reports / manifests)."""
        n_obs = sketch_count(self.slow_hist)
        n_cold = int(self.n_cold.sum())
        n_warm = int(self.n_warm.sum())
        placed = n_cold + n_warm
        return {
            "n_observed": n_obs,
            "slow_p50": _r(self.slow_percentile(50.0)),
            "slow_p99": _r(self.slow_percentile(99.0)),
            "lat_p50_s": _r(self.lat_percentile(50.0)),
            "lat_p99_s": _r(self.lat_percentile(99.0)),
            "n_cold": n_cold,
            "n_warm": n_warm,
            "cold_frac": _r(n_cold / placed) if placed else 0.0,
            "n_evict": int(self.n_evict.sum()),
            "n_reject": int(self.n_reject.sum()),
            "busy_time_s": _r(float(self.busy_time.sum())),
            "qlen_time_s": _r(float(np.asarray(self.qlen_time).sum())),
            "decision_max_frac": _r(
                float(self.decisions.sum(axis=tuple(
                    range(self.decisions.ndim - 1))).max()) / placed
            ) if placed else 0.0,
        }

    # -- batch accessors (mirror BatchSimOutput.rep / slicing) ----------
    def rep(self, r: int) -> "TelemetryResult":
        return self[r]

    def __getitem__(self, idx) -> "TelemetryResult":
        kw = {f.name: getattr(self, f.name)[idx]
              for f in dataclasses.fields(self) if f.name != "cfg"}
        return TelemetryResult(cfg=self.cfg, **kw)


def _r(x: float, nd: int = 6) -> float:
    return float("nan") if isinstance(x, float) and math.isnan(x) \
        else round(float(x), nd)


def warmup_cutoff(n_arrivals: int, cfg: TelemetryCfg) -> int:
    """Static warmup cutoff index — the histogram population starts here.

    Matches ``summarize``'s ``lo = int(n * warmup_frac)`` drop exactly.
    """
    return int(n_arrivals * cfg.warmup_frac)


__all__ = [
    "TelemetryCfg", "TelemetryResult", "WarmupMismatchError", "init_np",
    "warmup_cutoff",
    "on_place_np", "on_advance_np", "on_complete_np", "on_evict_np",
    "on_reject_np", "hist_edges", "N_BINS",
]
