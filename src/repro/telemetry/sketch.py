"""Fixed-bin log-spaced histogram sketch (streaming percentiles).

The streaming-engine precondition from the ROADMAP: percentile metrics
accumulated *online*, per completion, inside the scan carry — instead of
materializing a per-task slowdown array and calling ``np.percentile`` at
the end.  A log-spaced fixed-bin histogram is the jit-friendliest sketch
there is: the update is one ``searchsorted`` + one scatter-add (O(log B)
/ O(1), fixed shapes, trivially vmappable), and the np and jax updates
are *bitwise identical* because both sides binary-search the same
float64 edge array.

Accuracy contract (documented tolerance): with ``N_BINS`` bins spanning
``[HIST_LO, HIST_HI]`` the bin-width ratio is
``r = (HIST_HI/HIST_LO)**(1/N_BINS)`` and a percentile read off the
sketch (geometric midpoint of the selected bin) is within a factor
``sqrt(r)`` of the true order statistic — ``r ≈ 1.0151`` for the
default 1536 bins over 10 decades, i.e. ≤ **0.76 %** relative error
inside the range, plus rank-interpolation slack vs ``np.percentile``'s
linear interpolation between adjacent order statistics.  The
REPRO-CHECK gate budgets 2 % total.  Values outside the range clamp to
the first/last bin (percentiles there are range-limited, not wrong by
more than the clamp).
"""
from __future__ import annotations

import math

import numpy as np

#: Number of histogram bins (shared by slowdown and latency sketches).
N_BINS = 1536
#: Histogram range (seconds for latency; dimensionless for slowdown).
#: 10 decades cover sub-millisecond services through multi-day backlogs.
HIST_LO = 1e-4
HIST_HI = 1e6

_EDGES: np.ndarray | None = None


def hist_edges() -> np.ndarray:
    """The shared ``[N_BINS + 1]`` float64 log-spaced bin-edge array.

    Computed once in numpy and reused verbatim by both backends (the jax
    engine closes over ``jnp.asarray(hist_edges())``), so bin assignment
    is the same binary search over the same bits on both sides.
    """
    global _EDGES
    if _EDGES is None:
        edges = np.logspace(math.log10(HIST_LO), math.log10(HIST_HI),
                            N_BINS + 1).astype(np.float64)
        edges.setflags(write=False)
        _EDGES = edges
    return _EDGES


def bin_index_np(x, edges: np.ndarray | None = None):
    """Bin of value(s) ``x``: clamped ``searchsorted(edges, x, 'right')-1``.

    The jax engine mirrors this exactly (``jnp.searchsorted`` with
    ``side='right'`` over the same edges).
    """
    if edges is None:
        edges = hist_edges()
    return np.clip(np.searchsorted(edges, x, side="right") - 1,
                   0, N_BINS - 1)


def sketch_percentile(counts: np.ndarray, q: float,
                      edges: np.ndarray | None = None) -> float:
    """Percentile ``q`` (0..100) estimated from histogram ``counts``.

    ``counts`` may carry leading batch axes (e.g. ``[R, B]`` from the
    vmapped engine); they are summed first, so a batched sketch reads as
    the *pooled* population — matching how
    :func:`repro.core.metrics.summarize_batch` pools percentiles.
    Returns the geometric midpoint of the bin holding the
    ``ceil(q/100 * total)``-th order statistic; NaN on an empty sketch.
    """
    if edges is None:
        edges = hist_edges()
    counts = np.asarray(counts, dtype=np.int64)
    if counts.ndim > 1:
        counts = counts.sum(axis=tuple(range(counts.ndim - 1)))
    total = int(counts.sum())
    if total == 0:
        return float("nan")
    k = min(max(int(math.ceil(q / 100.0 * total)), 1), total)
    b = int(np.searchsorted(np.cumsum(counts), k, side="left"))
    return float(math.sqrt(edges[b] * edges[b + 1]))


def sketch_count(counts: np.ndarray) -> int:
    """Total observations recorded in a (possibly batched) sketch."""
    return int(np.asarray(counts, dtype=np.int64).sum())
