"""Telemetry plane 3 — run provenance.

A :class:`RunManifest` pins down *what produced a report*: git revision,
library versions, platform/devices, the seeds and CLI args in play, the
engine compile-cache counters and the compile-vs-run wall split derived
from the tracer's span aggregate.  ``benchmarks.run`` attaches one to
every ``BENCH_report.json`` so a figure can always be traced back to the
exact code + environment that drew it.

Everything here degrades gracefully: no git checkout, no jax install,
no tracer — the corresponding fields just read ``None``/empty.
"""
from __future__ import annotations

import dataclasses
import datetime
import platform
import subprocess
import sys
from typing import Any, Mapping


@dataclasses.dataclass
class RunManifest:
    git_sha: str | None
    git_dirty: bool | None
    python: str
    platform: str
    jax_version: str | None
    numpy_version: str | None
    devices: list[str]
    started_at: str
    duration_s: float | None = None
    seeds: dict = dataclasses.field(default_factory=dict)
    args: dict = dataclasses.field(default_factory=dict)
    engine_cache: dict = dataclasses.field(default_factory=dict)
    wall_split: dict = dataclasses.field(default_factory=dict)
    #: windowed flight-recorder digest (``TimelineResult.summary()``);
    #: empty when the run had no timeline plane
    timeline: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _git(*argv: str) -> str | None:
    try:
        out = subprocess.run(["git", *argv], capture_output=True,
                             text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else None
    except Exception:
        return None


def collect(seeds: Mapping[str, Any] | None = None,
            args: Mapping[str, Any] | None = None) -> RunManifest:
    """Snapshot provenance at run start; fill timing/cache fields later."""
    jax_version = None
    devices: list[str] = []
    try:
        import jax
        jax_version = jax.__version__
        devices = [str(d) for d in jax.devices()]
    except Exception:
        pass
    numpy_version = None
    try:
        import numpy
        numpy_version = numpy.__version__
    except Exception:
        pass
    dirty = _git("status", "--porcelain")
    return RunManifest(
        git_sha=_git("rev-parse", "HEAD"),
        git_dirty=None if dirty is None else bool(dirty),
        python=sys.version.split()[0],
        platform=platform.platform(),
        jax_version=jax_version,
        numpy_version=numpy_version,
        devices=devices,
        started_at=datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        seeds=dict(seeds or {}),
        args=dict(args or {}),
    )


def wall_split_from_aggregate(agg: Mapping[str, Mapping[str, Any]]) -> dict:
    """Compile-vs-run wall split from a tracer span aggregate.

    ``engine.build`` spans cover trace+lowering on cache misses;
    ``engine.first_run`` covers the XLA-compile-inclusive first
    dispatch; ``engine.run`` covers steady-state cached dispatches.
    """
    def _get(name: str) -> tuple[int, float]:
        a = agg.get(name, {})
        return int(a.get("count", 0)), float(a.get("total_s", 0.0))

    n_build, t_build = _get("engine.build")
    n_first, t_first = _get("engine.first_run")
    n_run, t_run = _get("engine.run")
    return {
        "build_s": round(t_build, 6), "builds": n_build,
        "first_run_s": round(t_first, 6), "first_runs": n_first,
        "run_s": round(t_run, 6), "runs": n_run,
        "compile_heavy_s": round(t_build + t_first, 6),
        "steady_state_s": round(t_run, 6),
    }


# ------------------------------------------------------------------
# Peak-memory probes (the streaming engine's horizon gate):
# benchmarks/fig14_stream.py resets the kernel's high-water mark,
# runs a full-day horizon, and records the peak as a budget row in
# BENCH_report.json.
# ------------------------------------------------------------------

def reset_peak_rss() -> bool:
    """Reset this process's peak-RSS high-water mark (Linux only).

    Writes ``"5"`` to ``/proc/self/clear_refs`` so the next
    :func:`peak_rss_mb` read reflects only allocations made after this
    call.  Returns False (and changes nothing) where the proc file is
    unavailable — callers then get the process-lifetime peak, which is
    still a valid *upper bound* for the budget gate.
    """
    try:
        with open("/proc/self/clear_refs", "w") as f:
            f.write("5")
        return True
    except OSError:
        return False


def peak_rss_mb() -> float:
    """Peak resident set size in MiB (``VmHWM``; ``ru_maxrss`` fallback)."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return float(line.split()[1]) / 1024.0
    except OSError:
        pass
    import resource
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
