"""Host-side span tracing (observability plane 2).

A zero-dependency tracer: nested :meth:`Tracer.span` context managers
record wall-clock intervals (``time.perf_counter`` based) and export
them as Chrome trace-event JSON — load the file at https://ui.perfetto.dev
(or ``chrome://tracing``) to see engine build/compile/dispatch phases,
benchmark figures and serving-platform task lifecycles on one timeline.

Design constraints:

* **Opt-in and near-free when off.**  The process-wide tracer starts
  disabled; a disabled ``span()`` returns a shared no-op context
  manager (no allocation, no clock read), so instrumented hot paths —
  the engine-cache lookup, every ``simulate`` call — cost nothing in
  ordinary runs.
* **Host-side only.**  Spans never enter jitted code (a span inside a
  ``lax.scan`` would be a host callback — exactly what the ``JXP004``
  audit rule forbids).  Device-side visibility comes from the optional
  :mod:`jax.profiler` bridge: with ``jax_bridge=True`` every span also
  opens a ``jax.profiler.TraceAnnotation``, so spans show up inside
  XLA profiles too.
* **Two clock domains.**  ``span()`` measures real wall time;
  :meth:`Tracer.event_at` records *virtual-time* events (the serving
  platform's simulated task lifecycles) under a separate pid so the
  two timelines never interleave confusingly in Perfetto.

Typical use::

    from repro.telemetry import configure_tracing, get_tracer, span

    configure_tracing(True)
    with span("fig2", loads=7):
        ...
    get_tracer().export("experiments/trace_bench.json")
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Any, Iterator

#: pid used for real wall-clock spans in the exported trace.
WALL_PID = 1
#: pid used for virtual-time events (simulated task lifecycles).
VIRTUAL_PID = 2


class Tracer:
    """Collects spans/events; exports Chrome trace-event JSON."""

    def __init__(self, enabled: bool = True, jax_bridge: bool = False):
        self.enabled = enabled
        self.jax_bridge = jax_bridge
        self._events: list[dict] = []
        self._t0 = time.perf_counter()
        self._epoch0 = time.time()
        self._depth = 0

    # ------------------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, **args: Any) -> Iterator[None]:
        """Record a nested wall-clock span around the ``with`` body."""
        if not self.enabled:
            yield
            return
        bridge = None
        if self.jax_bridge:
            try:
                import jax.profiler
                bridge = jax.profiler.TraceAnnotation(name)
                bridge.__enter__()
            except Exception:
                bridge = None
        ts = self._now_us()
        self._depth += 1
        try:
            yield
        finally:
            self._depth -= 1
            self._events.append({
                "name": name, "ph": "X", "ts": ts,
                "dur": self._now_us() - ts,
                "pid": WALL_PID, "tid": 0,
                "args": {k: _jsonable(v) for k, v in args.items()},
            })
            if bridge is not None:
                bridge.__exit__(None, None, None)

    def instant(self, name: str, **args: Any) -> None:
        """A zero-duration marker on the wall-clock timeline."""
        if not self.enabled:
            return
        self._events.append({
            "name": name, "ph": "i", "ts": self._now_us(), "s": "g",
            "pid": WALL_PID, "tid": 0,
            "args": {k: _jsonable(v) for k, v in args.items()},
        })

    def event_at(self, name: str, ts_s: float, dur_s: float, *,
                 tid: int = 0, **args: Any) -> None:
        """A retrospective *virtual-time* complete event.

        Used for simulated timelines (e.g. one event per serving-platform
        task: ``ts_s`` = arrival, ``dur_s`` = response time, ``tid`` =
        worker).  Virtual seconds map 1:1 onto trace microseconds×1e6
        under :data:`VIRTUAL_PID`, separate from the wall-clock track.
        """
        if not self.enabled:
            return
        self._events.append({
            "name": name, "ph": "X", "ts": ts_s * 1e6,
            "dur": max(dur_s, 0.0) * 1e6,
            "pid": VIRTUAL_PID, "tid": int(tid),
            "args": {k: _jsonable(v) for k, v in args.items()},
        })

    def counter_at(self, name: str, ts_s: float, value: float, *,
                   tid: int = 0) -> None:
        """A *virtual-time* counter sample (Perfetto counter track).

        Used by the timeline exporter to merge per-window metrics into
        the span trace: one ``ph: "C"`` sample per window start renders
        as a stepped counter track under :data:`VIRTUAL_PID`, aligned
        with the serving platform's task events."""
        if not self.enabled:
            return
        self._events.append({
            "name": name, "ph": "C", "ts": ts_s * 1e6,
            "pid": VIRTUAL_PID, "tid": int(tid),
            "args": {"value": float(value)},
        })

    # ------------------------------------------------------------------
    @property
    def events(self) -> list[dict]:
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()

    def aggregate(self) -> dict:
        """``{span name: {"count": n, "total_s": s}}`` over wall spans."""
        agg: dict[str, dict] = {}
        for ev in self._events:
            if ev.get("ph") != "X" or ev.get("pid") != WALL_PID:
                continue
            a = agg.setdefault(ev["name"], {"count": 0, "total_s": 0.0})
            a["count"] += 1
            a["total_s"] += ev.get("dur", 0.0) / 1e6
        for a in agg.values():
            a["total_s"] = round(a["total_s"], 6)
        return agg

    def export(self, path: str) -> str:
        """Write Chrome trace-event JSON (Perfetto-loadable)."""
        doc = {
            "traceEvents": self._events,
            "displayTimeUnit": "ms",
            "otherData": {
                "epoch0": self._epoch0,
                "process_names": {str(WALL_PID): "wall-clock",
                                  str(VIRTUAL_PID): "virtual-time"},
            },
        }
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


def _jsonable(v: Any):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


# --------------------------------------------------------------------------
# Process-wide default tracer (disabled until configured).
# --------------------------------------------------------------------------

_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    global _TRACER
    old = _TRACER
    _TRACER = tracer
    return old


def configure_tracing(enabled: bool = True, *,
                      jax_bridge: bool = False) -> Tracer:
    """Swap in a fresh process-wide tracer; returns it."""
    tracer = Tracer(enabled=enabled, jax_bridge=jax_bridge)
    set_tracer(tracer)
    return tracer


def span(name: str, **args: Any):
    """Convenience: a span on the process-wide tracer."""
    return _TRACER.span(name, **args)
