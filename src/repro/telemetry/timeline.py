"""Telemetry plane 4 — windowed time-series flight recorder (numpy side).

Run-aggregate telemetry (:mod:`repro.telemetry.state`) collapses a whole
run into one sketch; this plane keeps a *time-resolved* view: the
virtual-time horizon is cut into a fixed number ``K`` of equal windows
and every counter/sketch/integral is accumulated per window.  The state
is a plain dict of fixed-shape numpy arrays — the same layout the jax
engine carries as a pytree inside the vmapped ``lax.scan``
(:mod:`repro.telemetry.timeline_engine`) — so np ≡ jax parity is a
per-key array compare, exactly like ``TelemetryState``.

Because every shape depends only on ``(K, coarse_bins, W, max_events)``
— never on the horizon ``N`` — the plane rides the streaming engine's
carry across chunk boundaries unchanged: windows are *virtual-time*
buckets, so the chunk size never shows in the result (gated bitwise by
``benchmarks/fig15_timeline.py``).

Window layout (``K`` windows × ``B`` coarse bins × ``W`` workers):

=================  ========  ==========================================
``window_s``       f64       runtime window width (horizon / K if auto)
``arrivals``       [K] i64   arrivals whose time falls in the window
``n_cold/warm``    [K] i64   placements by warm-pool outcome
``n_evict``        [K] i64   capacity + keep-alive evictions
``n_reject``       [K] i64   admission rejections
``slow_hist``      [K,B] i64 per-window slowdown sketch (coarsened)
``lat_hist``       [K,B] i64 per-window latency sketch (coarsened)
``busy_time``      [K,W] f64 per-worker busy-time integral
``qlen_time``      [K] f64   central queue-length time integral
``prov_core``      [K] f64   provisioned core-seconds integral
``n_on``           [K] i32   active-worker count (last write wins)
``mode``           i32       hybrid-balancer mode carry (1 = low load)
``ev_*``           [E]       bounded decision-event log (see below)
=================  ========  ==========================================

Attribution conventions (identical in all three engines, so parity is
bitwise by construction):

* advance-time integrals (busy/qlen/provisioned) credit the window of
  the *interval start* — the same left-Riemann convention as
  ``server_time``;
* completions credit the window of the completion time;
* arrivals, placements and rejections credit the window of the arrival
  time; events at or past the horizon clamp into the last window (the
  end-of-run drain).

Unlike the run-aggregate sketches, the per-window sketches record *all*
completions (no warmup cutoff): the flight recorder exists to show the
ramp-up, not to hide it.

The decision-event log records every autoscaler grow/shrink (kind 0,
with the sensor p99 the controller read) and every hybrid-balancer
pack↔spread mode flip (kind 1).  It is bounded at ``max_events``
entries; ``ev_count`` keeps counting past the bound so truncation is
visible (``n_events_dropped`` in :meth:`TimelineResult.summary`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping, NamedTuple

import numpy as np

from .sketch import N_BINS, bin_index_np, hist_edges, sketch_percentile
from .state import _r

#: Decision-event kinds recorded in the bounded log.
EV_AUTOSCALE = 0   # autoscaler changed n_on; ev_val = new n_on
EV_MODE_FLIP = 1   # hybrid balancer flipped pack<->spread; ev_val = mode


class TimelineCfg(NamedTuple):
    """Opt-in timeline configuration (hashable: part of the engine key).

    ``n_windows`` fixes the number ``K`` of virtual-time windows;
    ``window_s`` the window width in virtual seconds (``0.0`` = auto:
    the horizon — last arrival time — divided by ``K``, computed at run
    time so one compiled engine serves any horizon); ``coarse_bins``
    the per-window sketch resolution (must divide the ``N_BINS``-bin
    edge grid so coarse bins are exact groups of fine bins — integer
    bin coarsening keeps np ≡ jax bitwise); ``max_events`` bounds the
    decision-event log.
    """
    n_windows: int = 64
    window_s: float = 0.0
    coarse_bins: int = 96
    max_events: int = 256


def validate_timeline(cfg: TimelineCfg) -> TimelineCfg:
    """Named errors instead of silent bad shapes downstream."""
    if int(cfg.n_windows) < 1:
        raise ValueError(
            f"TimelineCfg.n_windows must be >= 1, got {cfg.n_windows}")
    if int(cfg.max_events) < 1:
        raise ValueError(
            f"TimelineCfg.max_events must be >= 1, got {cfg.max_events}")
    b = int(cfg.coarse_bins)
    if b < 1 or N_BINS % b != 0:
        raise ValueError(
            f"TimelineCfg.coarse_bins must be a positive divisor of the "
            f"{N_BINS}-bin sketch grid (so coarse bins are exact groups "
            f"of fine bins), got {cfg.coarse_bins}")
    return cfg


def coarse_group(cfg: TimelineCfg) -> int:
    """Fine bins per coarse bin (``N_BINS // coarse_bins``)."""
    return N_BINS // int(cfg.coarse_bins)


def coarse_edges(cfg: TimelineCfg) -> np.ndarray:
    """The ``[coarse_bins + 1]`` edge subgrid of :func:`hist_edges`."""
    return hist_edges()[::coarse_group(cfg)]


def auto_window_s(horizon: float, cfg: TimelineCfg) -> float:
    """The runtime window width: configured, or horizon / K.

    Both the numpy oracle and the jax engine compute this as one f64
    division of the same operands, so the width — and therefore every
    window index — is bitwise identical across engines.
    """
    if float(cfg.window_s) > 0.0:
        return float(cfg.window_s)
    return float(horizon) / float(int(cfg.n_windows))


def window_index_np(now: float, window_s: float, n_windows: int) -> int:
    """Window of virtual time ``now``: ``clip(floor(now / w), 0, K-1)``.

    A non-positive width (degenerate horizon) maps everything into
    window 0; times at/past the horizon clamp into the last window (the
    drain tail).  The jax twin performs the identical f64 division,
    floor and clip.
    """
    if not window_s > 0.0:
        return 0
    k = math.floor(float(now) / float(window_s))
    return int(min(max(k, 0), int(n_windows) - 1))


def init_tl_np(n_workers: int, cfg: TimelineCfg,
               window_s: float) -> dict:
    """Fresh zeroed timeline state (mirrors ``timeline_engine.init_state``
    plus the runtime window width)."""
    K, B, E = int(cfg.n_windows), int(cfg.coarse_bins), int(cfg.max_events)
    return {
        "window_s": np.float64(window_s),
        # hybrid-balancer mode carry; an empty cluster is low-load, so
        # starting at 1 records no spurious flip on the first arrival
        "mode": np.int32(1),
        "arrivals": np.zeros(K, dtype=np.int64),
        "n_cold": np.zeros(K, dtype=np.int64),
        "n_warm": np.zeros(K, dtype=np.int64),
        "n_evict": np.zeros(K, dtype=np.int64),
        "n_reject": np.zeros(K, dtype=np.int64),
        "slow_hist": np.zeros((K, B), dtype=np.int64),
        "lat_hist": np.zeros((K, B), dtype=np.int64),
        "busy_time": np.zeros((K, n_workers), dtype=np.float64),
        "qlen_time": np.zeros(K, dtype=np.float64),
        "prov_core": np.zeros(K, dtype=np.float64),
        "n_on": np.zeros(K, dtype=np.int32),
        "ev_t": np.zeros(E, dtype=np.float64),
        "ev_kind": np.zeros(E, dtype=np.int32),
        "ev_val": np.zeros(E, dtype=np.int32),
        "ev_p99": np.full(E, np.nan, dtype=np.float64),
        "ev_count": np.int64(0),
    }


def _widx(tl: dict, t: float) -> int:
    return window_index_np(t, float(tl["window_s"]),
                           tl["arrivals"].shape[0])


# --------------------------------------------------------------------------
# Oracle-side update functions (mutate the dict in place; the jax engine
# in timeline_engine.py performs the same arithmetic functionally).
# --------------------------------------------------------------------------

def tl_on_arrival_np(tl: dict, t: float, n_on: int) -> None:
    """Count an arrival and write the current active-worker count."""
    k = _widx(tl, t)
    tl["arrivals"][k] += 1
    tl["n_on"][k] = np.int32(n_on)


def tl_on_place_np(tl: dict, t: float, is_cold: bool,
                   evicted: bool) -> None:
    k = _widx(tl, t)
    if is_cold:
        tl["n_cold"][k] += 1
    else:
        tl["n_warm"][k] += 1
    if evicted:
        tl["n_evict"][k] += 1


def tl_on_advance_np(tl: dict, t: float, tau: float,
                     active_per_worker: np.ndarray, qlen: int) -> None:
    """Busy/queue-length integrals, credited to the interval start."""
    k = _widx(tl, t)
    tl["busy_time"][k] += tau * np.asarray(active_per_worker,
                                           dtype=np.float64)
    tl["qlen_time"][k] += tau * float(qlen)


def tl_on_complete_np(tl: dict, t: float, response_s: float,
                      service_s: float) -> None:
    """Coarse sketch scatter at the completion time (all completions —
    the flight recorder keeps the warmup ramp visible)."""
    k = _widx(tl, t)
    group = N_BINS // tl["slow_hist"].shape[1]
    slow = response_s / max(service_s, 1e-12)
    tl["slow_hist"][k, bin_index_np(slow) // group] += 1
    tl["lat_hist"][k, bin_index_np(response_s) // group] += 1


def tl_on_evict_np(tl: dict, t: float, count: int = 1) -> None:
    k = _widx(tl, t)
    tl["n_evict"][k] += count


def tl_on_reject_np(tl: dict, t: float) -> None:
    k = _widx(tl, t)
    tl["n_reject"][k] += 1


def tl_on_prov_np(tl: dict, t: float, core_s: float) -> None:
    """Provisioned core-seconds over an interval starting at ``t``."""
    k = _widx(tl, t)
    tl["prov_core"][k] += core_s


def tl_event_np(tl: dict, t: float, kind: int, val: int,
                p99: float) -> None:
    """Append to the bounded decision log; count past the bound."""
    c = int(tl["ev_count"])
    if c < tl["ev_t"].shape[0]:
        tl["ev_t"][c] = t
        tl["ev_kind"][c] = np.int32(kind)
        tl["ev_val"][c] = np.int32(val)
        tl["ev_p99"][c] = p99
    tl["ev_count"] = tl["ev_count"] + 1


def sensor_p99_np(window: np.ndarray) -> float:
    """The p99 the ``TARGET_P99`` controller read from ``window``.

    Mirrors ``repro.fleet.policies._target_p99_np`` op for op (same
    ceil-rank, same ``searchsorted(cumsum, k, 'left')``, same geometric
    midpoint) so the logged sensor value is bitwise the one the
    decision used.  Only called on non-empty windows (the engines gate
    decisions on ``window.sum() >= 1``).
    """
    edges = hist_edges()
    window = np.asarray(window, dtype=np.int64)
    total = int(window.sum())
    k = min(max(int(math.ceil(0.99 * total)), 1), total)
    b = int(np.searchsorted(np.cumsum(window), k, side="left"))
    return math.sqrt(float(edges[b]) * float(edges[b + 1]))


# --------------------------------------------------------------------------
# Result wrapper + exporters
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TimelineResult:
    """Materialized timeline from one run (or a batch; see notes).

    Array fields keep whatever leading batch axes the engine produced
    (``[R, ...]`` from ``simulate_many``).  Scalar readers and exporters
    pool across them: counters, sketches and time integrals sum over
    replications; ``n_on`` and ``window_s`` average (they are levels,
    not totals).  Use :meth:`rep` for one replication's exact planes
    (the decision log is only meaningful per replication).
    """
    window_s: np.ndarray
    mode: np.ndarray
    arrivals: np.ndarray
    n_cold: np.ndarray
    n_warm: np.ndarray
    n_evict: np.ndarray
    n_reject: np.ndarray
    slow_hist: np.ndarray
    lat_hist: np.ndarray
    busy_time: np.ndarray
    qlen_time: np.ndarray
    prov_core: np.ndarray
    n_on: np.ndarray
    ev_t: np.ndarray
    ev_kind: np.ndarray
    ev_val: np.ndarray
    ev_p99: np.ndarray
    ev_count: np.ndarray
    cfg: TimelineCfg = TimelineCfg()

    @staticmethod
    def from_state(tl: Mapping[str, Any],
                   cfg: TimelineCfg = TimelineCfg()) -> "TimelineResult":
        kw = {}
        for f in dataclasses.fields(TimelineResult):
            if f.name == "cfg":
                continue
            kw[f.name] = np.asarray(tl[f.name])
        return TimelineResult(cfg=cfg, **kw)

    # -- shape helpers --------------------------------------------------
    @property
    def n_windows(self) -> int:
        return int(self.arrivals.shape[-1])

    @property
    def batched(self) -> bool:
        return self.arrivals.ndim > 1

    def rep(self, r: int) -> "TimelineResult":
        return self[r]

    def __getitem__(self, idx) -> "TimelineResult":
        kw = {f.name: getattr(self, f.name)[idx]
              for f in dataclasses.fields(self) if f.name != "cfg"}
        return TimelineResult(cfg=self.cfg, **kw)

    def _pool_sum(self, a: np.ndarray, keep: int) -> np.ndarray:
        """Sum any leading batch axes, keeping the last ``keep`` dims."""
        a = np.asarray(a)
        if a.ndim > keep:
            a = a.sum(axis=tuple(range(a.ndim - keep)))
        return a

    def _pool_mean(self, a: np.ndarray, keep: int) -> np.ndarray:
        a = np.asarray(a, dtype=np.float64)
        if a.ndim > keep:
            a = a.mean(axis=tuple(range(a.ndim - keep)))
        return a

    def window_starts(self) -> np.ndarray:
        """``[K]`` window start times (pooled width for batches)."""
        w = float(self._pool_mean(self.window_s, 0))
        return np.arange(self.n_windows, dtype=np.float64) * w

    # -- per-window percentile reads (coarse sketch) --------------------
    def slow_percentile(self, window: int, q: float) -> float:
        return sketch_percentile(
            self._pool_sum(self.slow_hist, 2)[window], q,
            edges=coarse_edges(self.cfg))

    def lat_percentile(self, window: int, q: float) -> float:
        return sketch_percentile(
            self._pool_sum(self.lat_hist, 2)[window], q,
            edges=coarse_edges(self.cfg))

    # -- decision log ---------------------------------------------------
    def events(self) -> list[dict]:
        """The recorded decision events, oldest first (single rep only)."""
        if self.batched:
            raise ValueError(
                "the decision-event log is per-replication; select one "
                "with .rep(r) before reading events()")
        n = min(int(self.ev_count), int(self.ev_t.shape[0]))
        out = []
        for i in range(n):
            kind = int(self.ev_kind[i])
            ev = {"t": float(self.ev_t[i]),
                  "kind": "autoscale" if kind == EV_AUTOSCALE
                  else "mode_flip",
                  "value": int(self.ev_val[i])}
            if kind == EV_AUTOSCALE:
                ev["sensor_p99"] = float(self.ev_p99[i])
            out.append(ev)
        return out

    def replay_n_on(self, n_start: int) -> np.ndarray:
        """Reconstruct the per-window ``n_on`` plane from the decision
        log alone: start at ``n_start``, apply autoscale events in
        order, sample at each window's *last arrival* — i.e. the value
        the engine's last-write-wins plane holds.  Exact as long as the
        log was not truncated (``ev_count <= max_events``)."""
        if self.batched:
            raise ValueError("replay_n_on needs a single replication; "
                             "select one with .rep(r)")
        if int(self.ev_count) > int(self.ev_t.shape[0]):
            raise ValueError(
                f"decision log truncated ({int(self.ev_count)} events > "
                f"max_events={int(self.ev_t.shape[0])}); the n_on "
                f"trajectory cannot be replayed exactly")
        out = np.zeros(self.n_windows, dtype=np.int32)
        level = np.int32(n_start)
        ei, n_ev = 0, min(int(self.ev_count), int(self.ev_t.shape[0]))
        w = float(self.window_s)
        for k in range(self.n_windows):
            if self.arrivals[k] == 0:
                continue
            # events apply at arrival boundaries before the n_on write,
            # so every autoscale event in or before this window that
            # precedes its last arrival has taken effect; within one
            # window the plane keeps only the final level
            while ei < n_ev:
                if int(self.ev_kind[ei]) != EV_AUTOSCALE:
                    ei += 1
                    continue
                if window_index_np(float(self.ev_t[ei]), w,
                                   self.n_windows) > k:
                    break
                level = np.int32(int(self.ev_val[ei]))
                ei += 1
            out[k] = level
        return out

    # -- digests / exporters --------------------------------------------
    def summary(self) -> dict:
        """Compact JSON-friendly digest for reports / RunManifest."""
        arr = self._pool_sum(self.arrivals, 1)
        cold = self._pool_sum(self.n_cold, 1)
        warm = self._pool_sum(self.n_warm, 1)
        n_ev_seen = int(np.asarray(self.ev_count).sum())
        cap = int(self.ev_t.shape[-1])
        reps = int(np.prod(np.asarray(self.ev_count).shape)) \
            if np.asarray(self.ev_count).ndim else 1
        placed = int(cold.sum() + warm.sum())
        return {
            "n_windows": self.n_windows,
            "window_s": _r(float(self._pool_mean(self.window_s, 0))),
            "coarse_bins": int(self.cfg.coarse_bins),
            "arrivals_total": int(arr.sum()),
            "arrivals_peak": int(arr.max()) if arr.size else 0,
            "cold_frac": _r(float(cold.sum()) / placed) if placed
            else 0.0,
            "n_reject": int(self._pool_sum(self.n_reject, 1).sum()),
            "n_events": n_ev_seen,
            "n_events_dropped": max(0, n_ev_seen - cap * reps),
            "n_on_min": int(np.asarray(self.n_on).min())
            if np.asarray(self.n_on).size else 0,
            "n_on_max": int(np.asarray(self.n_on).max())
            if np.asarray(self.n_on).size else 0,
            "prov_core_s": _r(float(
                self._pool_sum(self.prov_core, 1).sum())),
        }

    def to_rows(self) -> list[dict]:
        """One CSV-friendly dict per window (pooled over batch axes)."""
        K = self.n_windows
        w = float(self._pool_mean(self.window_s, 0))
        n_workers = int(self.busy_time.shape[-1])
        arr = self._pool_sum(self.arrivals, 1)
        cold = self._pool_sum(self.n_cold, 1)
        warm = self._pool_sum(self.n_warm, 1)
        evict = self._pool_sum(self.n_evict, 1)
        rej = self._pool_sum(self.n_reject, 1)
        busy = self._pool_sum(self.busy_time, 2)
        qlen = self._pool_sum(self.qlen_time, 1)
        prov = self._pool_sum(self.prov_core, 1)
        n_on = self._pool_mean(self.n_on, 1)
        reps = 1
        if self.batched:
            reps = int(np.prod(self.arrivals.shape[:-1]))
        denom = max(w * reps, 1e-12)
        rows = []
        for k in range(K):
            rows.append({
                "window": k,
                "t_start_s": _r(k * w),
                "arrivals": int(arr[k]),
                "n_cold": int(cold[k]),
                "n_warm": int(warm[k]),
                "n_evict": int(evict[k]),
                "n_reject": int(rej[k]),
                "slow_p50": _r(self.slow_percentile(k, 50.0)),
                "slow_p99": _r(self.slow_percentile(k, 99.0)),
                "lat_p50_s": _r(self.lat_percentile(k, 50.0)),
                "lat_p99_s": _r(self.lat_percentile(k, 99.0)),
                "busy_frac": _r(float(busy[k].sum())
                                / (denom * n_workers)),
                "qlen_avg": _r(float(qlen[k]) / denom),
                "n_on": _r(float(n_on[k]), 3),
                "prov_core_s": _r(float(prov[k])),
            })
        return rows

    def to_openmetrics(self, prefix: str = "repro_timeline") -> str:
        """OpenMetrics / Prometheus text exposition of the timeline.

        Each per-window value becomes one sample with a ``window`` label
        (plus its virtual start time ``t_start_s``); the decision log is
        exported as an info-style gauge per event.  The text ends with
        ``# EOF`` per the OpenMetrics spec.
        """
        rows = self.to_rows()
        counters = ("arrivals", "n_cold", "n_warm", "n_evict", "n_reject")
        gauges = ("slow_p50", "slow_p99", "lat_p50_s", "lat_p99_s",
                  "busy_frac", "qlen_avg", "n_on", "prov_core_s")
        lines = []
        for name in counters:
            lines.append(f"# TYPE {prefix}_{name} counter")
            for r in rows:
                lines.append(
                    f'{prefix}_{name}_total{{window="{r["window"]}",'
                    f't_start_s="{r["t_start_s"]}"}} {r[name]}')
        for name in gauges:
            lines.append(f"# TYPE {prefix}_{name} gauge")
            for r in rows:
                v = r[name]
                v = "NaN" if isinstance(v, float) and math.isnan(v) else v
                lines.append(
                    f'{prefix}_{name}{{window="{r["window"]}",'
                    f't_start_s="{r["t_start_s"]}"}} {v}')
        if not self.batched:
            lines.append(f"# TYPE {prefix}_decision gauge")
            for i, ev in enumerate(self.events()):
                p99 = ev.get("sensor_p99", float("nan"))
                p99 = "NaN" if math.isnan(p99) else _r(p99)
                lines.append(
                    f'{prefix}_decision{{seq="{i}",kind="{ev["kind"]}",'
                    f't_s="{_r(ev["t"])}",sensor_p99="{p99}"}} '
                    f'{ev["value"]}')
        lines.append("# EOF")
        return "\n".join(lines) + "\n"

    def write_csv(self, path: str) -> str:
        """Write the per-window table as CSV; returns the path."""
        import csv
        import os
        rows = self.to_rows()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
        return path

    def write_openmetrics(self, path: str) -> str:
        import os
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_openmetrics())
        return path

    def emit_counters(self, tracer, prefix: str = "timeline") -> None:
        """Merge the timeline into a span trace as Perfetto counter
        tracks (one ``ph: "C"`` sample per window on the virtual-time
        process, alongside the serving platform's task events)."""
        rows = self.to_rows()
        tracks = ("arrivals", "n_cold", "n_reject", "slow_p99",
                  "busy_frac", "qlen_avg", "n_on", "prov_core_s")
        for r in rows:
            for name in tracks:
                v = r[name]
                if isinstance(v, float) and math.isnan(v):
                    continue
                tracer.counter_at(f"{prefix}.{name}",
                                  float(r["t_start_s"]), float(v))


__all__ = [
    "TimelineCfg", "TimelineResult", "EV_AUTOSCALE", "EV_MODE_FLIP",
    "validate_timeline", "coarse_group", "coarse_edges", "auto_window_s",
    "window_index_np", "init_tl_np", "sensor_p99_np",
    "tl_on_arrival_np", "tl_on_place_np", "tl_on_advance_np",
    "tl_on_complete_np", "tl_on_evict_np", "tl_on_reject_np",
    "tl_on_prov_np", "tl_event_np",
]
