"""Per-function cold-start latency models (provider presets).

The seed simulator charged one scalar ``cold_start_penalty`` for every
cold start; real platforms pay a per-function price (runtime, package
size, weight residency — Wang et al. ATC'18 measured 10x spreads across
functions on the same provider).  A preset maps a function count ``F``
to a deterministic per-function latency vector; the engines bake the
vector in at build time, so both simulators and the serving platform
charge identical costs.

Determinism: each preset's spread is drawn from a generator seeded by a
CRC32 of the preset name — stable across processes and platforms (no
``hash()`` salting), so ``np`` and ``jax`` engines, CI and local runs
all see the same costs.

The special name ``"scalar"`` keeps the legacy single-penalty model
(``ClusterCfg.cold_start_penalty`` / ``ServeCfg.cold_start_s``);
:func:`cold_costs_for` returns ``None`` for it so callers can keep the
legacy code path.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable

import numpy as np

#: Name of the pass-through preset (legacy scalar penalty).
SCALAR = "scalar"


@dataclasses.dataclass(frozen=True)
class ColdStartPreset:
    """A registered cold-start latency model.

    ``make(F) -> np.ndarray [F]`` returns per-function cold-start
    latencies in seconds, deterministic in ``F``.
    """

    name: str
    doc: str = ""
    make: Callable[[int], np.ndarray] = None


COLD_PRESETS: dict[str, ColdStartPreset] = {}


def register_cold_preset(name: str, make, *, doc: str = "",
                         overwrite: bool = False) -> ColdStartPreset:
    name = name.strip().lower()
    if not name or "/" in name:
        raise ValueError(f"invalid cold-start preset name {name!r}")
    if not overwrite and name in COLD_PRESETS:
        raise ValueError(f"cold-start preset {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    p = ColdStartPreset(name=name, doc=doc, make=make)
    COLD_PRESETS[name] = p
    return p


def cold_preset_names() -> tuple[str, ...]:
    return (SCALAR,) + tuple(COLD_PRESETS)


def get_cold_preset(name) -> ColdStartPreset:
    key = str(name).strip().lower()
    try:
        return COLD_PRESETS[key]
    except KeyError:
        raise ValueError(
            f"unknown cold-start preset {key!r}; registered cold-start "
            f"presets: "
            f"{', '.join(sorted(cold_preset_names()))}") from None


def parse_cold_preset(name: str) -> str:
    """Validate a CLI preset token; returns the canonical name.

    Accepts ``"scalar"`` (the legacy single-penalty model) plus every
    registered preset; unknown tokens raise the registry's named
    ``ValueError`` listing the alternatives.
    """
    key = str(name).strip().lower()
    if key == SCALAR:
        return SCALAR
    return get_cold_preset(key).name


def cold_costs_for(name: str, n_functions: int):
    """Per-function cold-start cost vector, or ``None`` for ``scalar``."""
    key = str(name).strip().lower()
    if key == SCALAR:
        return None
    return np.asarray(get_cold_preset(key).make(int(n_functions)),
                      dtype=np.float64)


def _spread(name: str, base_s: float, sigma: float):
    """Log-normal per-function spread around ``base_s`` (median)."""
    def make(F: int) -> np.ndarray:
        rng = np.random.default_rng(zlib.crc32(name.encode()))
        return base_s * np.exp(sigma * rng.standard_normal(F))
    return make


register_cold_preset(
    "paper-sim", lambda F: np.zeros(F),
    doc="the paper's simulator: container start-up not modeled (0 s)")
register_cold_preset(
    "openwhisk", lambda F: np.full(F, 0.5),
    doc="constant 0.5 s spin-up, the paper's OpenWhisk testbed figure")
register_cold_preset(
    "aws-lambda", _spread("aws-lambda", 0.25, 0.6),
    doc="median 0.25 s with per-function log-normal spread (sigma 0.6)")
register_cold_preset(
    "azure-functions", _spread("azure-functions", 0.5, 0.8),
    doc="median 0.5 s with a heavier per-function spread (sigma 0.8)")
