"""Lifecycle configuration record — the ``ClusterCfg.lifecycle`` field.

Kept dependency-free (no :mod:`repro.core` imports) because
:mod:`repro.core.cluster` embeds this record in :class:`ClusterCfg`; the
rest of the lifecycle package (registry, policies, runtime) layers on
top.  The record is a ``NamedTuple`` of hashable primitives so clusters
carrying a lifecycle stay valid engine-cache keys
(``repro.core.simulator`` memoizes compiled programs on
``tuple(cluster)``).
"""
from __future__ import annotations

from typing import NamedTuple


class LifecycleCfg(NamedTuple):
    """Container-lifecycle knobs for both simulators and the platform.

    ``keepalive`` names a policy in the lifecycle registry
    (:func:`repro.lifecycle.register_keepalive`); ``NONE`` tears every
    executor down at completion, ``FIXED_TTL`` keeps idle executors for
    ``ttl_s`` seconds, ``HYBRID_HIST`` learns per-function pre-warm +
    keep-alive windows from an idle-time histogram (Shahrad et al.,
    ATC'20).  ``ttl_s`` is the ``FIXED_TTL`` window and the
    ``HYBRID_HIST`` fallback/cap unit.  ``max_idle`` caps the number of
    *reserved* idle executors per worker (the warm-pool budget; ``0`` =
    bounded only by slot pressure).  ``coldstart`` names a per-function
    cold-start latency preset (:mod:`repro.lifecycle.coldstart`);
    ``"scalar"`` keeps the legacy single-penalty model
    (``ClusterCfg.cold_start_penalty`` in the simulators,
    ``ServeCfg.cold_start_s`` on the platform).

    ``ClusterCfg(lifecycle=None)`` — the default — preserves the
    pre-lifecycle semantics bit-for-bit: an ever-growing warm set with
    no idle-timeout and the scalar penalty.
    """

    keepalive: str = "FIXED_TTL"
    ttl_s: float = 60.0
    max_idle: int = 0
    coldstart: str = "scalar"
