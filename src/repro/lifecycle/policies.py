"""Built-in keep-alive policies — numpy / jax pairs.

Every backend of a policy implements the identical deterministic
contract (:mod:`repro.lifecycle.registry`): ``windows(state) ->
(pre[F], keep[F])`` plus, for adaptive policies, ``observe(state, func,
gap) -> state``.  Both backends perform the same float/int operations
in the same order, so np ≡ jax holds bitwise (the parity tests in
``tests/test_lifecycle.py`` thread state across both).

* ``NONE`` — no keep-alive: every executor is torn down at completion
  (``pre = keep = 0``), the cold-start upper bound.
* ``FIXED_TTL`` — one fixed idle-timeout for every function
  (``keep = cfg.ttl_s``), the OpenWhisk/AWS-style default.
* ``HYBRID_HIST`` — the hybrid-histogram policy of Shahrad et al.
  (ATC'20): per-function idle-time histograms choose a pre-warm window
  (just below the head of the idle-time distribution — the container is
  released at completion and re-provisioned at ``pre``) and a
  keep-alive window covering the distribution up to the tail quantile.
  Functions with fewer than ``HIST_MIN_OBS`` observed gaps fall back to
  the fixed TTL.
"""
from __future__ import annotations

import numpy as np

from .registry import register_keepalive

# HYBRID_HIST shape: HIST_BINS linear bins spanning HIST_RANGE_TTLS
# keep-alive units (cfg.ttl_s), so gaps up to 4x the fixed TTL are
# distinguishable; longer gaps clamp into the last bin.
HIST_BINS = 32
HIST_RANGE_TTLS = 4.0
HIST_MIN_OBS = 3
# head/tail quantiles of the idle-time distribution and the safety
# margin applied to them (ATC'20 §4.2 uses 5%/99% with a margin).
HIST_HEAD_Q = 0.05
HIST_TAIL_Q = 0.99
HIST_MARGIN = 0.15


# --------------------------------------------------------------------------
# NONE / FIXED_TTL — stateless: constant windows, no observation hook
# --------------------------------------------------------------------------

def _const_np(pre_s: float, keep_s: float):
    def make(cfg, n_functions):
        pre = np.full(n_functions, pre_s, dtype=np.float64)
        keep = np.full(n_functions, keep_s, dtype=np.float64)

        def windows(state):
            return pre, keep
        return windows, None
    return make


def _const_jax(pre_s: float, keep_s: float):
    def make(cfg, n_functions):
        import jax.numpy as jnp
        pre = jnp.full((n_functions,), pre_s, dtype=jnp.float64)
        keep = jnp.full((n_functions,), keep_s, dtype=jnp.float64)

        def windows(state):
            return pre, keep
        return windows, None
    return make


def _none_np(cfg, n_functions):
    return _const_np(0.0, 0.0)(cfg, n_functions)


def _none_jax(cfg, n_functions):
    return _const_jax(0.0, 0.0)(cfg, n_functions)


def _fixed_ttl_np(cfg, n_functions):
    return _const_np(0.0, float(cfg.ttl_s))(cfg, n_functions)


def _fixed_ttl_jax(cfg, n_functions):
    return _const_jax(0.0, float(cfg.ttl_s))(cfg, n_functions)


# --------------------------------------------------------------------------
# HYBRID_HIST — per-function idle-time histogram → (pre, keep) windows
# --------------------------------------------------------------------------

def _hybrid_init(cfg, n_workers, n_functions):
    """Fresh per-function histogram state (counts as f64 for jax)."""
    return {"hist": np.zeros((n_functions, HIST_BINS), dtype=np.float64),
            "n_obs": np.zeros(n_functions, dtype=np.float64)}


def _hybrid_params(cfg):
    bin_s = float(cfg.ttl_s) * HIST_RANGE_TTLS / HIST_BINS
    return bin_s, float(cfg.ttl_s)


def _hybrid_np(cfg, n_functions):
    bin_s, ttl = _hybrid_params(cfg)

    def windows(state):
        hist, n_obs = state["hist"], state["n_obs"]
        cdf = np.cumsum(hist, axis=1)
        # head: first bin covering HEAD_Q of the mass -> pre-warm just
        # below its lower edge; tail: first bin covering TAIL_Q -> keep
        # through its upper edge, padded by the margin.
        head = np.argmax(cdf >= HIST_HEAD_Q * n_obs[:, None], axis=1)
        tail = np.argmax(cdf >= HIST_TAIL_Q * n_obs[:, None], axis=1)
        pre = head * bin_s * (1.0 - HIST_MARGIN)
        end = (tail + 1.0) * bin_s * (1.0 + HIST_MARGIN)
        learned = n_obs >= HIST_MIN_OBS
        pre = np.where(learned, pre, 0.0)
        keep = np.where(learned, end - pre, ttl)
        return pre, keep

    def observe(state, func, gap):
        b = min(int(gap / bin_s), HIST_BINS - 1)
        b = max(b, 0)
        hist = state["hist"].copy()
        hist[func, b] += 1.0
        n_obs = state["n_obs"].copy()
        n_obs[func] += 1.0
        return dict(state, hist=hist, n_obs=n_obs)

    return windows, observe


def _hybrid_jax(cfg, n_functions):
    import jax.numpy as jnp
    bin_s, ttl = _hybrid_params(cfg)

    def windows(state):
        hist, n_obs = state["hist"], state["n_obs"]
        cdf = jnp.cumsum(hist, axis=1)
        head = jnp.argmax(cdf >= HIST_HEAD_Q * n_obs[:, None], axis=1)
        tail = jnp.argmax(cdf >= HIST_TAIL_Q * n_obs[:, None], axis=1)
        # .astype first: int64 * python-float stays weak-typed and
        # would thread weak f64 carries through the engine scan.  Same
        # association as the np oracle above — bitwise identical.
        pre = head.astype(jnp.float64) * bin_s * (1.0 - HIST_MARGIN)
        end = (tail.astype(jnp.float64) + 1.0) * bin_s \
            * (1.0 + HIST_MARGIN)
        learned = n_obs >= HIST_MIN_OBS
        pre = jnp.where(learned, pre, 0.0)
        keep = jnp.where(learned, end - pre, ttl)
        return pre, keep

    def observe(state, func, gap):
        b = jnp.minimum(jnp.asarray(gap / bin_s).astype(jnp.int32),
                        HIST_BINS - 1)
        b = jnp.maximum(b, 0)
        hist = state["hist"].at[func, b].add(1.0)
        n_obs = state["n_obs"].at[func].add(1.0)
        return dict(state, hist=hist, n_obs=n_obs)

    return windows, observe


register_keepalive(
    "NONE", doc="no keep-alive: executors torn down at completion "
                "(cold-start upper bound)",
    make_np=_none_np, make_jax=_none_jax)
register_keepalive(
    "FIXED_TTL", doc="fixed idle-timeout of cfg.ttl_s seconds for every "
                     "function (OpenWhisk-style)",
    make_np=_fixed_ttl_np, make_jax=_fixed_ttl_jax)
register_keepalive(
    "HYBRID_HIST", doc="per-function idle-time histogram choosing "
                       "pre-warm + keep-alive windows (Shahrad et al. "
                       "ATC'20)",
    make_np=_hybrid_np, make_jax=_hybrid_jax, init_state=_hybrid_init)
