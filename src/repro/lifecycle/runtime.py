"""np-side lifecycle state machine — shared by the oracle and platform.

One implementation of the keep-alive / eviction / cold-start semantics
drives both event-driven loops (:mod:`repro.core.sim_ref` and
:mod:`repro.serving.engine`); the vectorized scan engine
(:mod:`repro.core.simulator`) re-expresses the identical operations in
traced form.  Keeping the np logic in one place makes the parity
contract auditable: every method here names the engine code point it
mirrors.

State (per :class:`LifecycleRuntime`):

* ``idle_since [W, F]`` — time of each pool's most recent completion
  (its executors' idle clock; *not* refreshed by warm placements — an
  idle executor's clock starts when it went idle, matching ATC'20).
  ``-1`` marks a pool with no completion history yet.
* ``pre/keep [F]`` — the active windows, recomputed after each
  observation for adaptive policies.

Pool visibility at time ``now`` (age ``a = now - idle_since``): a pool
is **materialized** iff ``pre <= a <= pre + keep``.  Only materialized
pools serve warm hits, occupy memory (slot pressure + the ``max_idle``
budget) and are LRU eviction candidates; during the pre-warm phase
``[0, pre)`` the container is unloaded — the ATC'20 memory saving — and
past the window it is released.  Expiry is *lazy*: the mask is applied
wherever counts are read, a stale pool's count is zeroed when its next
completion refreshes it, and the ``max_idle`` budget is enforced at
completion events (pools materializing out of their pre-warm phase
between completions are reclaimed at the worker's next completion).

Adaptive policies observe the *placed worker's* pool age at each
placement — the exact idle duration their windows must cover (a
cluster-wide gap would systematically underestimate per-worker pool
idle times by roughly the worker count).

Eviction tie-breaking contract (shared with the scan engine): the
victim is the materialized pool with the *oldest* ``idle_since``; ties
break toward the lowest function id (``argmin`` takes the first minimum
in both numpy and jax).
"""
from __future__ import annotations

import numpy as np

from .registry import ResolvedLifecycle


class LifecycleRuntime:
    """Mutable lifecycle state for one event-driven simulation run."""

    def __init__(self, res: ResolvedLifecycle, n_workers: int,
                 n_functions: int):
        self.res = res
        self.W, self.F = int(n_workers), int(n_functions)
        self.idle_since = np.full((self.W, self.F), -1.0, dtype=np.float64)
        self.ka = res.init_policy_state(self.W, self.F)
        self.pre, self.keep = res.windows(self.ka)
        self.max_idle = res.max_idle

    # ---------------------------------------------------------------- costs

    def cold_cost(self, f: int, scalar_default: float) -> float:
        """Cold-start latency of function ``f`` (preset or legacy scalar)."""
        if self.res.cold_costs is None:
            return float(scalar_default)
        return float(self.res.cold_costs[f])

    # ------------------------------------------------------------- queries

    def materialized_col(self, warm_col: np.ndarray, f: int,
                         now: float) -> np.ndarray:
        """Warm counts of function ``f`` visible to placement, per worker.

        Mirrors the scan engine's selection-time warm-column mask.
        """
        age = now - self.idle_since[:, f]
        ok = (age >= self.pre[f]) & (age <= self.pre[f] + self.keep[f])
        return np.where(ok, warm_col, 0)

    def materialized_at(self, w: int, f: int, count: int,
                        now: float) -> int:
        """O(1) warm-hit check for one ``(worker, function)`` pool —
        the placement hot path (the column/matrix forms below serve
        selection and the batched kernel dispatch)."""
        age = now - self.idle_since[w, f]
        if self.pre[f] <= age <= self.pre[f] + self.keep[f]:
            return int(count)
        return 0

    def materialized_all(self, warm: np.ndarray, now: float) -> np.ndarray:
        """The whole ``[W, F]`` masked warm matrix in one expression.

        The batched-controller (kernel dispatch) form of
        :meth:`materialized_col` — no per-function Python loop on the
        per-decision hot path.
        """
        ages = now - self.idle_since
        ok = (ages >= self.pre) & (ages <= self.pre + self.keep)
        return np.where(ok, warm, 0)

    def eff_row(self, warm_row: np.ndarray, w: int,
                now: float) -> np.ndarray:
        """Materialized (memory-occupying) counts of worker ``w``, per fn."""
        age = now - self.idle_since[w]
        ok = (age >= self.pre) & (age <= self.pre + self.keep)
        return np.where(ok, warm_row, 0)

    def evict_victim(self, warm_row: np.ndarray, w: int, now: float) -> int:
        """LRU eviction victim on worker ``w`` (oldest materialized pool).

        Mirrors the scan engine's ``place``/completion eviction victim;
        callers only invoke this when at least one materialized pool
        exists.
        """
        eff = self.eff_row(warm_row, w, now)
        return int(np.argmin(np.where(eff > 0, self.idle_since[w],
                                      np.inf)))

    # ------------------------------------------------------------- updates

    def on_complete(self, warm: np.ndarray, w: int, f: int,
                    now: float) -> bool:
        """A task of function ``f`` completed on worker ``w`` at ``now``.

        Zeroes a stale pool before the increment (no resurrection of
        expired executors), refreshes the idle clock, and enforces the
        ``max_idle`` warm-pool budget by LRU eviction.  Mirrors the
        scan engine's per-completion lifecycle block.  Returns whether
        the budget evicted an executor (telemetry counts it — the scan
        engine's ``over`` flag).
        """
        age = now - self.idle_since[w, f]
        if age > self.pre[f] + self.keep[f]:
            warm[w, f] = 0
        warm[w, f] += 1
        self.idle_since[w, f] = now
        if self.max_idle > 0:
            eff = self.eff_row(warm[w], w, now)
            if eff.sum() > self.max_idle:
                v = int(np.argmin(np.where(eff > 0, self.idle_since[w],
                                           np.inf)))
                warm[w, v] -= 1
                return True
        return False

    def observe_place(self, w: int, f: int, now: float) -> None:
        """Feed the keep-alive policy the placed pool's idle age.

        Called once per placement, *after* the warm/cold decision (the
        placement was scheduled under the windows in force when its
        executors went idle); recomputes the windows for subsequent
        decisions.  Virgin pools (no completion on ``w`` yet) are not
        observations — there was no idle period to cover.  Mirrors the
        scan engine's in-``place`` observation block.
        """
        if self.res.observe is None:
            return
        if self.idle_since[w, f] >= 0.0:
            self.ka = self.res.observe(self.ka, f,
                                       now - self.idle_since[w, f])
            self.pre, self.keep = self.res.windows(self.ka)
