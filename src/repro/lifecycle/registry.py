"""Open keep-alive policy registry, mirroring :mod:`repro.policy`.

The keep-alive axis is a first-class scheduling dimension (Przybylski
et al. 2021; SFS, Fu et al. 2022): *when to release an idle executor*
shapes cold-start rates as much as *where to place an invocation*.
This module makes that axis an open registry so keep-alive strategies
are sweepable like balancers.

**The keep-alive contract.**  Warm executors live in per-``(worker,
function)`` pools; the engines track one idle-since timestamp per pool
(the time of the pool's most recent completion).  A policy maps its
(optional) carried state to per-function *windows*::

    windows(state) -> (pre[F], keep[F])     # f64 seconds

A pool of function ``f`` whose idle age is ``a = now - idle_since`` is
**materialized** iff ``pre[f] <= a <= pre[f] + keep[f]``.  Only
materialized pools serve warm hits, occupy memory (slot pressure and
the ``max_idle`` budget) and are LRU eviction candidates; during the
pre-warm phase ``[0, pre)`` the container is unloaded, to be
re-provisioned just before the predicted next invocation (the ATC'20
pre-warming model — the memory saving is the point of the ``pre``
output), and past the window it is released.

Expiry is *lazy*: both engines apply the window mask wherever pool
counts are read, and a stale pool's count is zeroed when its next
completion refreshes it — no expiry events are simulated, so the
vectorized scan engine and the numpy oracle stay in lockstep by
construction.  The ``max_idle`` budget is likewise enforced at
completion events.

Adaptive policies additionally declare ``init_state`` — a factory
``(cfg, n_workers, n_functions) -> dict[str, np.ndarray]`` — and an
observation hook fed once per *placement* with the placed worker's
pool idle age (the exact idle duration the windows must cover)::

    observe(state, func, gap) -> state      # pure, both backends

``make_np`` / ``make_jax`` are factories ``(cfg, n_functions) ->
(windows, observe)`` (``observe`` is ``None`` for stateless policies);
both backends must perform identical float/int operations in identical
order so np ≡ jax parity holds bitwise, exactly as the balancer
carried-state contract demands (:mod:`repro.policy.registry`).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional

from .config import LifecycleCfg

_BACKENDS = ("np", "jax")


@dataclasses.dataclass(frozen=True)
class KeepAlivePolicy:
    """A registered keep-alive strategy (see the module contract)."""

    name: str
    doc: str = ""
    make_np: Optional[Callable[[LifecycleCfg, int], tuple]] = None
    make_jax: Optional[Callable[[LifecycleCfg, int], tuple]] = None
    init_state: Optional[Callable[[LifecycleCfg, int, int], Any]] = None

    @property
    def stateful(self) -> bool:
        return self.init_state is not None

    def backends(self) -> tuple[str, ...]:
        return tuple(b for b, fn in zip(
            _BACKENDS, (self.make_np, self.make_jax)) if fn is not None)


KEEPALIVES: dict[str, KeepAlivePolicy] = {}

_builtin_lock = threading.Lock()
_builtins_loaded = False


def _load_builtins() -> None:
    """Idempotently register the built-in policies (import side effect).

    The flag is set *before* the import: the built-in registrations
    re-enter :func:`register_keepalive` (which loads built-ins first so
    name collisions surface at the caller), and must not recurse into
    the non-reentrant lock.  A failed import resets the flag.
    """
    global _builtins_loaded
    if _builtins_loaded:
        return
    with _builtin_lock:
        if _builtins_loaded:
            return
        _builtins_loaded = True
        try:
            from . import policies  # noqa: F401  (registers on import)
        except BaseException:
            _builtins_loaded = False
            raise


def register_keepalive(name: str, *, make_np=None, make_jax=None,
                       init_state=None, doc: str = "",
                       overwrite: bool = False) -> KeepAlivePolicy:
    """Register a keep-alive policy under ``name`` (upper-cased).

    At least one of ``make_np`` / ``make_jax`` must be given; a policy
    with both runs through every engine in the repo.  ``init_state``
    opts into the carried-state contract (the ``make_*`` factories then
    return ``(windows, observe)`` with a non-``None`` observe hook).
    Returns the :class:`KeepAlivePolicy` record.
    """
    name = name.strip().upper()
    if "/" in name or "*" in name or not name:
        raise ValueError(f"invalid keep-alive name {name!r}")
    if make_np is None and make_jax is None:
        raise ValueError(f"keep-alive {name!r} needs an np or jax backend")
    # load built-ins first so a collision with a built-in name is
    # reported HERE — checked against an empty registry it would
    # succeed silently and then wedge the deferred built-in import
    _load_builtins()
    if not overwrite and name in KEEPALIVES:
        raise ValueError(f"keep-alive {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    ka = KeepAlivePolicy(name=name, doc=doc, make_np=make_np,
                         make_jax=make_jax, init_state=init_state)
    KEEPALIVES[name] = ka
    _engine_cache_clear()
    return ka


def unregister_keepalive(name: str) -> None:
    _load_builtins()
    KEEPALIVES.pop(str(name).strip().upper(), None)
    _engine_cache_clear()


def _engine_cache_clear() -> None:
    # compiled simulator engines capture resolved lifecycle closures;
    # (re-)registration must drop them, like the policy registry does.
    import sys
    sim = sys.modules.get("repro.core.simulator")
    clear = getattr(sim, "clear_engine_cache", None)
    if clear is not None:
        clear()


def keepalive_names() -> tuple[str, ...]:
    _load_builtins()
    return tuple(KEEPALIVES)


def get_keepalive(name) -> KeepAlivePolicy:
    _load_builtins()
    key = str(name).strip().upper()
    try:
        return KEEPALIVES[key]
    except KeyError:
        raise ValueError(
            f"unknown keep-alive policy {key!r}; registered keep-alive "
            f"policies: "
            f"{', '.join(sorted(KEEPALIVES))}") from None


def parse_keepalive(name: str) -> str:
    """Validate a CLI keep-alive token against the registry.

    Returns the canonical (upper-cased) name; raises the registry's
    named ``ValueError`` (listing what IS registered) on unknown input —
    the same error style as :func:`repro.core.taxonomy.parse_policy`.
    """
    return get_keepalive(name).name


# --------------------------------------------------------------------------
# resolve — lifecycle cfg → backend callables (the engines' entry point)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ResolvedLifecycle:
    """A lifecycle config resolved against one backend and shape.

    ``windows``/``observe`` follow the module contract for the chosen
    backend (``observe`` is ``None`` for stateless policies, and then
    ``windows`` ignores its argument).  ``cold_costs`` is the
    per-function cold-start latency vector of the configured preset, or
    ``None`` for the legacy scalar-penalty model.  ``max_idle`` is the
    per-worker warm-pool budget (0 = unbounded).
    """

    cfg: LifecycleCfg
    policy: KeepAlivePolicy
    backend: str
    windows: Callable
    observe: Optional[Callable]
    cold_costs: Optional[Any]          # np.ndarray [F] or None
    max_idle: int

    @property
    def stateful(self) -> bool:
        return self.policy.stateful

    def init_policy_state(self, n_workers: int, n_functions: int):
        if self.policy.init_state is None:
            return None
        return self.policy.init_state(self.cfg, n_workers, n_functions)


def resolve_lifecycle(cluster, *, backend: str = "np",
                      n_functions: int) -> Optional[ResolvedLifecycle]:
    """Resolve ``cluster.lifecycle`` into backend callables.

    Returns ``None`` when the cluster carries no lifecycle config (the
    legacy infinite-keep-alive model) so engines can gate the whole
    subsystem on one check.  ``backend`` is ``"np"`` or ``"jax"``
    (``"pallas"`` select backends share the jax lifecycle path).
    """
    cfg = getattr(cluster, "lifecycle", None)
    if cfg is None:
        return None
    _load_builtins()
    if backend == "pallas":
        backend = "jax"
    if backend not in _BACKENDS:
        raise ValueError(f"unknown lifecycle backend {backend!r}; "
                         f"choose from {_BACKENDS}")
    ka = get_keepalive(cfg.keepalive)
    make = ka.make_np if backend == "np" else ka.make_jax
    if make is None:
        raise ValueError(f"keep-alive {ka.name!r} has no {backend} "
                         f"backend (has: {ka.backends()})")
    windows, observe = make(cfg, int(n_functions))
    from .coldstart import cold_costs_for
    costs = cold_costs_for(cfg.coldstart, int(n_functions))
    return ResolvedLifecycle(cfg=cfg, policy=ka, backend=backend,
                             windows=windows, observe=observe,
                             cold_costs=costs,
                             max_idle=int(cfg.max_idle))
