"""``repro.lifecycle`` — container lifecycle, keep-alive & cold starts.

The third pillar of the paper (locality to reduce cold starts, §5.3 /
Fig 7) promoted to a first-class, sweepable axis: per-``(worker,
function)`` warm pools with idle clocks, an open :class:`KeepAlivePolicy`
registry mirroring :mod:`repro.policy` (``NONE`` / ``FIXED_TTL`` /
``HYBRID_HIST`` shipped), LRU eviction under slot/memory pressure, and
per-function cold-start latency presets replacing the single scalar
penalty.  Engines gate the whole subsystem on ``ClusterCfg.lifecycle``
— the ``None`` default reproduces the pre-lifecycle semantics
bit-for-bit.

Registering a custom keep-alive policy (sweepable everywhere a
``LifecycleCfg`` is accepted)::

    import numpy as np
    from repro.lifecycle import register_keepalive

    def make_np(cfg, n_functions):
        keep = np.where(np.arange(n_functions) % 2 == 0,
                        2.0 * cfg.ttl_s, 0.5 * cfg.ttl_s)
        pre = np.zeros(n_functions)
        def windows(state):
            return pre, keep
        return windows, None          # stateless: no observe hook

    def make_jax(cfg, n_functions):
        import jax.numpy as jnp
        keep = jnp.where(jnp.arange(n_functions) % 2 == 0,
                         2.0 * cfg.ttl_s, 0.5 * cfg.ttl_s)
        pre = jnp.zeros(n_functions)
        def windows(state):
            return pre, keep
        return windows, None

    register_keepalive("TIERED", make_np=make_np, make_jax=make_jax,
                       doc="even fns get 2x TTL, odd fns 0.5x")
    # ClusterCfg(lifecycle=LifecycleCfg(keepalive="TIERED")) now runs
    # through both simulators, the platform, and every CLI flag.
"""
import math

from .config import LifecycleCfg
from .coldstart import (SCALAR, ColdStartPreset, cold_costs_for,
                        cold_preset_names, get_cold_preset,
                        parse_cold_preset, register_cold_preset)
from .registry import (KeepAlivePolicy, ResolvedLifecycle, get_keepalive,
                       keepalive_names, parse_keepalive,
                       register_keepalive, resolve_lifecycle,
                       unregister_keepalive)
from .runtime import LifecycleRuntime


def lifecycle_from_flags(keepalive=None, ttl_s: float = 60.0,
                         max_idle: int = 0, coldstart: str = SCALAR):
    """CLI glue: an ``Optional[LifecycleCfg]`` from flag values.

    Every name is validated against its registry (named ``ValueError``
    listing what IS registered).  Without an explicit ``keepalive``, a
    cold-start preset or warm-pool budget alone enables the lifecycle
    with an *infinite* ``FIXED_TTL`` window — executors never expire,
    so the user gets the requested costs/budget without a surprise
    idle-timeout (the only behavioral delta vs the legacy model is that
    slot-pressure eviction becomes LRU rather than most-idle-count).
    All flags at their defaults return ``None`` (the legacy model,
    bit-for-bit).
    """
    preset = parse_cold_preset(coldstart)
    if keepalive is not None:
        return LifecycleCfg(keepalive=parse_keepalive(keepalive),
                            ttl_s=float(ttl_s), max_idle=int(max_idle),
                            coldstart=preset)
    if preset != SCALAR or int(max_idle) > 0:
        return LifecycleCfg(keepalive="FIXED_TTL", ttl_s=math.inf,
                            max_idle=int(max_idle), coldstart=preset)
    return None


__all__ = [
    "SCALAR", "ColdStartPreset", "KeepAlivePolicy", "LifecycleCfg",
    "LifecycleRuntime", "ResolvedLifecycle", "cold_costs_for",
    "cold_preset_names", "get_cold_preset", "get_keepalive",
    "keepalive_names", "lifecycle_from_flags", "parse_cold_preset",
    "parse_keepalive", "register_cold_preset", "register_keepalive",
    "resolve_lifecycle", "unregister_keepalive",
]
