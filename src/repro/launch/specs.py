"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape_cfg)`` returns (args, in_specs) for the step
that the shape lowers: ``train_step`` for train shapes, ``prefill`` for
prefill shapes, ``decode_step`` for decode shapes.  For the ``[audio]``
/ ``[vlm]`` archs the modality frontend is a stub — these specs ARE the
precomputed frame/patch token ids, per the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distribution.sharding import pspec
from repro.models.transformer import Model


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_shapes(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def cache_shapes(model: Model, batch: int, max_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def input_specs(model: Model, shape_cfg):
    """Returns (args, arg_pspecs) for the step function of this shape."""
    cfg = model.cfg
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    tok_spec = pspec("batch", "seq")
    if shape_cfg.kind == "train":
        args = {"tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32)}
        specs = {"tokens": tok_spec, "labels": tok_spec}
        return args, specs
    if shape_cfg.kind == "prefill":
        cache = cache_shapes(model, B, S)
        args = {"tokens": _sds((B, S), jnp.int32), "cache": cache}
        specs = {"tokens": tok_spec,
                 "cache": model.cache_specs(B, S)}
        return args, specs
    # decode: one new token against a seq_len-deep cache/state
    cache = cache_shapes(model, B, S)
    args = {"tok": _sds((B, 1), jnp.int32), "cache": cache,
            "pos": _sds((B,), jnp.int32)}
    specs = {"tok": tok_spec, "cache": model.cache_specs(B, S),
             "pos": pspec("batch")}
    return args, specs
