"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for each
assigned architecture and input shape, the full-size model step is
``jit(...).lower(...).compile()``-ed against the 16×16 single-pod mesh
and the 2×16×16 multi-pod mesh (512 placeholder host devices).  Sharding
mismatches, compile-time OOMs and unsupported collectives surface here
as hard failures.

Outputs per cell: per-device memory analysis (proves it fits a 16 GB
v5e chip), per-device cost analysis (FLOPs/bytes), and the collective-op
census parsed from the compiled HLO — consumed by ``repro.roofline``.

Usage::

    python -m repro.launch.dryrun --all [--out experiments/dryrun.json]
    python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
"""
# The device-count override MUST precede any other import that could
# initialize jax (jax locks the device count on first backend init).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                              # noqa: E402
from repro.configs.shapes import SHAPES, applicable    # noqa: E402
from repro.distribution.sharding import (              # noqa: E402
    param_sharding_tree, sharding_ctx)
from repro.launch.mesh import make_ctx, make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs, param_shapes      # noqa: E402
from repro.models.transformer import build_model       # noqa: E402
from repro.training.optimizer import OptCfg, OptState  # noqa: E402
from repro.training.train import (TrainState, build_train_step,  # noqa: E402
                                  init_train_state)

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                "u32": 4, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
                "s16": 2, "u16": 2}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = _DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def collective_census(hlo: str, pod_stride: int | None = None) -> dict:
    """Sum result bytes of every collective op kind in (post-SPMD) HLO.

    HLO line form: ``%name = TYPE[dims]{layout} all-reduce(...)``; for
    variadic collectives the type is a tuple — all element shapes are
    summed.  When ``pod_stride`` is given, collectives whose replica
    group spans device ids across a pod boundary are tallied separately
    (``cross_pod`` — these ride the slow inter-pod links).

    Note: while-loop (scan) bodies appear once in the text; the roofline
    pass uses unrolled lowerings where this census is exact.
    """
    out: dict[str, float] = {}
    cross_pod = 0.0
    for line in hlo.splitlines():
        for op in _COLL_OPS:
            tok = f" {op}("
            if tok not in line and f" {op}-start(" not in line:
                continue
            head = line.split(tok)[0] if tok in line else \
                line.split(f" {op}-start(")[0]
            if "=" not in head:
                continue
            type_str = head.split("=", 1)[1]
            nbytes = _shape_bytes(type_str)
            out[op] = out.get(op, 0) + nbytes
            if pod_stride:
                g = _GROUPS_RE.search(line)
                if g:
                    ids = [int(x) for x in g.group(1).split(",")]
                    if len({i // pod_stride for i in ids}) > 1:
                        cross_pod += nbytes
            break
    out["total"] = sum(out.values())
    if pod_stride:
        out["cross_pod"] = cross_pod
    return out


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str               # ok | skip | fail
    reason: str = ""
    seconds: float = 0.0
    flops: float = 0.0                # per-device, from cost_analysis
    bytes_accessed: float = 0.0       # per-device
    peak_memory_bytes: float = 0.0    # per-device
    arg_bytes: float = 0.0
    temp_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)

    def row(self):
        return dataclasses.asdict(self)


def _step_builder(model, shape_cfg):
    """Returns (fn, args_dict, in_specs_dict) for this shape's step."""
    args, specs = input_specs(model, shape_cfg)
    if shape_cfg.kind == "train":
        step = build_train_step(model, OptCfg())
        state_shapes = jax.eval_shape(
            lambda: init_train_state(model, jax.random.key(0)))
        ps = model.param_specs()
        state_specs = TrainState(params=ps,
                                 opt=OptState(m=ps, v=ps, step=P()),
                                 err=None)
        fn = lambda state, tokens, labels: step(state, tokens, labels)  # noqa
        all_args = (state_shapes, args["tokens"], args["labels"])
        all_specs = (state_specs, specs["tokens"], specs["labels"])
        return fn, all_args, all_specs
    p_shapes = param_shapes(model)
    p_specs = model.param_specs()
    if shape_cfg.kind == "prefill":
        fn = model.prefill
        return fn, (p_shapes, args["tokens"], args["cache"]), \
            (p_specs, specs["tokens"], specs["cache"])
    fn = model.decode_step
    return fn, (p_shapes, args["tok"], args["cache"], args["pos"]), \
        (p_specs, specs["tok"], specs["cache"], specs["pos"])


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             layer_mode: str = "scan", n_layers: int | None = None,
             attn_impl: str | None = None, want_hlo: bool = False,
             rule_overrides: dict | None = None,
             cfg_overrides: dict | None = None,
             collect_hlo_census: bool = True) -> CellResult:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = configs.get(arch)
    shape_cfg = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape_cfg)
    res = CellResult(arch=arch, shape=shape_name, mesh=mesh_name,
                     status="skip", reason=reason)
    if not ok:
        return res
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    if attn_impl is not None:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    if cfg_overrides:
        # nested dataclass fields addressed as "moe.capacity_factor"
        plain = {k: v for k, v in cfg_overrides.items() if "." not in k}
        cfg = dataclasses.replace(cfg, **plain)
        for k, v in cfg_overrides.items():
            if "." in k:
                head, leaf = k.split(".", 1)
                sub = dataclasses.replace(getattr(cfg, head), **{leaf: v})
                cfg = dataclasses.replace(cfg, **{head: sub})
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh, cfg, shape_cfg, **(rule_overrides or {}))
    try:
        with sharding_ctx(ctx):
            model = build_model(cfg, layer_mode=layer_mode)
            fn, arg_shapes, arg_specs = _step_builder(model, shape_cfg)
            shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), arg_specs,
                is_leaf=lambda s: isinstance(s, P))
            donate = (0,) if shape_cfg.kind == "train" else \
                ((2,) if shape_cfg.kind == "prefill" else (2,))
            lowered = jax.jit(fn, in_shardings=shardings,
                              donate_argnums=donate).lower(*arg_shapes)
            compiled = lowered.compile()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # jax<=0.4.x: list per device
                ca = ca[0] if ca else {}
            ma = compiled.memory_analysis()
            res.status = "ok"
            res.flops = float(ca.get("flops", 0.0))
            res.bytes_accessed = float(ca.get("bytes accessed", 0.0))
            if ma is not None:
                res.peak_memory_bytes = float(
                    getattr(ma, "peak_memory_in_bytes", 0) or
                    (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes))
                res.arg_bytes = float(ma.argument_size_in_bytes)
                res.temp_bytes = float(ma.temp_size_in_bytes)
            if collect_hlo_census:
                res.collectives = collective_census(
                    compiled.as_text(),
                    pod_stride=256 if multi_pod else None)
            if want_hlo:
                res.collectives["_hlo"] = compiled.as_text()
    except Exception as e:                                 # noqa: BLE001
        res.status = "fail"
        res.reason = f"{type(e).__name__}: {e}"[:500]
    res.seconds = time.time() - t0
    return res


def iter_cells():
    for arch in configs.ARCH_NAMES:
        for shape in SHAPES:
            yield arch, shape


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    rows = []
    for arch, shape in cells:
        for mp in meshes:
            r = run_cell(arch, shape, multi_pod=mp)
            coll = r.collectives.get("total", 0) / 1e6
            print(f"{arch:18s} {shape:12s} {r.mesh:8s} {r.status:5s} "
                  f"t={r.seconds:6.1f}s flops/dev={r.flops:.3e} "
                  f"peak_mem/dev={r.peak_memory_bytes/2**30:6.2f}GiB "
                  f"coll={coll:9.1f}MB {r.reason[:60]}",
                  flush=True)
            rows.append(r.row())
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    n_fail = sum(1 for r in rows if r["status"] == "fail")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
