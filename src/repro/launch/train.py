"""Production training launcher.

Builds the mesh + sharding context for an assigned architecture, places
the train state under its PartitionSpecs, and drives the fault-tolerant
training loop (checkpoint every N steps, restart on failure, optional
cross-pod int8 gradient compression).

On real hardware::

    python -m repro.launch.train --arch qwen3-14b --steps 1000 \
        --mesh single --ckpt-dir gs://.../ckpts

On this CPU container use ``--smoke`` (reduced config, no mesh) — the
full-size lowering is validated by ``repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-pods", action="store_true",
                    help="int8 error-feedback cross-pod gradient sync")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.data.pipeline import make_data_iter
    from repro.distribution.sharding import sharding_ctx
    from repro.launch.mesh import make_ctx, make_production_mesh
    from repro.models.transformer import build_model
    from repro.training.checkpoint import CheckpointManager
    from repro.training.optimizer import OptCfg
    from repro.training.train import (build_train_step,
                                      build_train_step_compressed,
                                      init_train_state, run_with_restarts,
                                      state_specs)

    cfg = configs.get_smoke(args.arch) if args.smoke else \
        configs.get(args.arch)
    ocfg = OptCfg(lr=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
                  total_steps=args.steps)

    def run():
        model = build_model(cfg)
        state = init_train_state(model, jax.random.key(0),
                                 compressed=args.compress_pods)
        ctx = None
        if args.mesh != "none":
            mesh = make_production_mesh(multi_pod=args.mesh == "multi")
            ctx = make_ctx(mesh, cfg)
            specs = state_specs(model, compressed=args.compress_pods)
            sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                              is_leaf=lambda s: isinstance(s, P))
            state = jax.tree.map(jax.device_put, state, sh)
        builder = (build_train_step_compressed if args.compress_pods
                   else build_train_step)
        step_fn = jax.jit(builder(model, ocfg,
                                  microbatches=args.microbatches))
        data = make_data_iter("lcg", args.batch, args.seq, cfg.vocab)
        mgr = CheckpointManager(args.ckpt_dir)
        t0 = time.time()
        state, rep = run_with_restarts(step_fn, state, data,
                                       n_steps=args.steps, ckpt_mgr=mgr,
                                       ckpt_every=args.ckpt_every)
        dt = time.time() - t0
        print(f"{rep.steps_done} steps in {dt:.0f}s; loss "
              f"{rep.losses[0]:.3f} → {rep.final_loss:.3f}; "
              f"restarts={rep.restarts}")

    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        with sharding_ctx(make_ctx(mesh, cfg)):
            run()
    else:
        run()


if __name__ == "__main__":
    main()
