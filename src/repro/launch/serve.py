"""Serving launcher: Hermes-scheduled cluster over a request trace.

Two modes:

* ``--backend platform`` (default) — the event-driven serving platform
  (cold-start model, straggler mitigation), any ``T/LB/S`` policy,
  Azure-shaped or custom workload.  This is the §6 evaluation vehicle.
* ``--backend models`` — real reduced-config JAX models behind the
  Hermes frontend with measured compile-time cold starts.

Workloads are any ``repro.core.WORKLOADS`` entry — the synthetic §6.1
generators *and* the ``azure-*`` trace-replay scenarios — or a real
Azure-schema trace slice given as the two dataset CSVs.

Container lifecycle: ``--keepalive <name>`` threads a keep-alive policy
from the :mod:`repro.lifecycle` registry (``NONE`` / ``FIXED_TTL`` /
``HYBRID_HIST`` or anything registered) through the platform, and
``--cold-start-preset <name>`` swaps the scalar spin-up cost for a
per-function provider preset; both flags are validated against the
lifecycle registry with named errors, like ``--policy`` is against the
policy registry.

Fleet & autoscaling: ``--fleet-preset`` / ``--speed`` make the worker
pool heterogeneous (per-worker speeds from a :mod:`repro.fleet` preset
or given explicitly), and ``--autoscale`` turns on an active-worker
control loop (``TARGET_P99`` with ``--target-p99`` / ``--min-workers``
/ ``--cooldown`` / ``--hysteresis``).  All names are validated against
the fleet registries with named errors; autoscalers that read the
telemetry sketch enable telemetry automatically.  With every fleet
flag at its default the launcher keeps the exact homogeneous fixed-W
model.

Examples::

    python -m repro.launch.serve --policy E/H/PS --load 0.6 -n 5000
    python -m repro.launch.serve --workload azure-diurnal --load 0.7
    python -m repro.launch.serve --keepalive HYBRID_HIST --ttl 30 \
        --cold-start-preset aws-lambda
    python -m repro.launch.serve --fleet-preset two-gen --policy E/SWARM/PS
    python -m repro.launch.serve --workload azure-diurnal \
        --autoscale TARGET_P99 --target-p99 3 --min-workers 2 --cooldown 2
    python -m repro.launch.serve \
        --trace-invocations inv.csv --trace-durations dur.csv
    python -m repro.launch.serve --backend models --requests 12
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=["platform", "models"],
                    default="platform")
    ap.add_argument("--policy", default="E/H/PS",
                    help="T/LB/S triple over the repro.policy registry "
                         "(e.g. E/H/PS, E/JSQ2/PS, L/*/*)")
    ap.add_argument("--workload", default="ms-trace",
                    help="any repro.core.WORKLOADS name, incl. azure-* "
                         "trace-replay scenarios")
    ap.add_argument("--trace-invocations", metavar="CSV",
                    help="Azure-schema invocations-per-minute file; "
                         "replayed instead of --workload")
    ap.add_argument("--trace-durations", metavar="CSV",
                    help="Azure-schema duration-percentiles file "
                         "(required with --trace-invocations)")
    ap.add_argument("--load", type=float, default=0.6)
    ap.add_argument("-n", type=int, default=4000)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--cores", type=int, default=12)
    ap.add_argument("--cold-start", type=float, default=0.5)
    ap.add_argument("--keepalive", metavar="NAME",
                    help="container keep-alive policy from the "
                         "repro.lifecycle registry (NONE, FIXED_TTL, "
                         "HYBRID_HIST, ...); omit for the legacy "
                         "keep-forever warm pool")
    ap.add_argument("--ttl", type=float, default=60.0,
                    help="keep-alive window seconds (FIXED_TTL window / "
                         "HYBRID_HIST fallback+range unit)")
    ap.add_argument("--max-idle", type=int, default=0,
                    help="per-worker warm-pool budget (idle executors; "
                         "0 = bounded only by slot pressure)")
    ap.add_argument("--cold-start-preset", metavar="NAME",
                    default="scalar",
                    help="per-function cold-start latency preset from "
                         "the lifecycle registry ('scalar' keeps "
                         "--cold-start)")
    ap.add_argument("--fleet-preset", metavar="NAME",
                    help="per-worker speed preset from the repro.fleet "
                         "registry (uniform, two-gen, long-tail, ...); "
                         "omit (with no other fleet flag) for the "
                         "homogeneous pool")
    ap.add_argument("--speed", nargs="+", type=float, metavar="S",
                    help="explicit per-worker speed vector (overrides "
                         "--fleet-preset; length must equal --workers)")
    ap.add_argument("--autoscale", metavar="NAME",
                    help="active-worker autoscale policy from the "
                         "repro.fleet registry (STATIC, TARGET_P99, ...)")
    ap.add_argument("--target-p99", type=float, default=5.0,
                    help="autoscaler p99 slowdown ceiling")
    ap.add_argument("--min-workers", type=int, default=1,
                    help="autoscaler floor on active workers")
    ap.add_argument("--cooldown", type=float, default=60.0,
                    help="seconds between autoscale decisions")
    ap.add_argument("--hysteresis", type=float, default=0.1,
                    help="autoscaler dead-band half-width (fraction of "
                         "the setpoint)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true",
                    help="dispatch through the balancer's batched Pallas "
                         "controller kernel (policies whose balancer "
                         "ships one, e.g. E/H/*)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--telemetry", action="store_true",
                    help="collect streaming platform telemetry "
                         "(repro.telemetry) and print its summary; "
                         "with --trace-out also records per-task "
                         "virtual-time lifecycle events")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="export a Perfetto-loadable Chrome trace JSON "
                         "of the run (implies --telemetry)")
    ap.add_argument("--timeline-out", metavar="PATH", default=None,
                    help="record the windowed flight-recorder timeline "
                         "(repro.telemetry.timeline) and export it: "
                         "per-window CSV at PATH plus an OpenMetrics "
                         "text sibling at PATH.om; with --trace-out "
                         "the windows also land in the trace JSON as "
                         "Perfetto counter tracks")
    args = ap.parse_args()

    if args.backend == "models":
        from repro import configs
        from repro.lifecycle import parse_keepalive
        from repro.serving.backends import (HermesFrontend, Invocation,
                                            ModelRegistry)
        import numpy as np
        reg = ModelRegistry()
        reg.register("olmo-tiny", configs.get_smoke("olmo-1b"))
        reg.register("rwkv-tiny", configs.get_smoke("rwkv6-3b"))
        # on real models, keep-alive maps to executor idle expiry with
        # the --ttl window (cold starts here are measured XLA compiles,
        # so --cold-start-preset does not apply); the name is still
        # validated against the lifecycle registry
        keepalive_s = None
        if args.keepalive is not None:
            parse_keepalive(args.keepalive)   # named ValueError
            keepalive_s = args.ttl
        fe = HermesFrontend(reg, n_workers=2, cores=2, max_len=64,
                            keepalive_s=keepalive_s)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            fn = ("olmo-tiny", "rwkv-tiny")[i % 2]
            out = fe.dispatch(Invocation(
                func=fn, prompt=rng.integers(0, 100, 8), n_new=4))
            print(f"req {i:2d} {fn:10s} worker={out.worker} "
                  f"{'COLD' if out.cold else 'warm'} "
                  f"{out.response_s*1e3:8.1f}ms")
        return

    from repro.core import (ClusterCfg, WORKLOADS, parse_policy, summarize)
    from repro.fleet import STATIC, fleet_from_flags, get_autoscaler
    from repro.lifecycle import lifecycle_from_flags
    from repro.serving.engine import ServeCfg, ServingCluster
    # named ValueError on unknown names; a preset/budget without an
    # explicit --keepalive gets an infinite window (no surprise expiry)
    lifecycle = lifecycle_from_flags(args.keepalive, args.ttl,
                                     args.max_idle, args.cold_start_preset)
    # same contract for the fleet axes: all defaults -> fleet=None
    fleet = fleet_from_flags(args.fleet_preset, args.speed, args.autoscale,
                             args.target_p99, args.min_workers,
                             args.cooldown, args.hysteresis)
    cl = ClusterCfg(n_workers=args.workers, cores=args.cores,
                    lifecycle=lifecycle, fleet=fleet).validate()
    if args.trace_invocations or args.trace_durations:
        if not (args.trace_invocations and args.trace_durations):
            ap.error("--trace-invocations and --trace-durations "
                     "must be given together")
        from repro.trace.cache import load_trace_cached
        from repro.trace.replay import replay_trace
        trace = load_trace_cached(args.trace_invocations,
                                  args.trace_durations,
                                  allow_missing_durations=True)
        wl = replay_trace(trace, cl, load=args.load, n_arrivals=args.n,
                          seed=args.seed, name="trace-file")
        wname = args.trace_invocations
    else:
        wl = WORKLOADS[args.workload](cl, args.load, args.n,
                                      seed=args.seed)
        wname = args.workload
    # a sketch-reading autoscaler needs the telemetry carry regardless
    # of whether the user asked for a printed summary
    auto_needs_tel = (fleet is not None and
                      get_autoscaler(fleet.autoscale).needs_telemetry)
    telemetry_on = bool(args.telemetry or args.trace_out or auto_needs_tel)
    tel_cfg = None
    tracer = None
    if telemetry_on:
        from repro.telemetry import TelemetryCfg, configure_tracing
        tel_cfg = TelemetryCfg()
        if args.telemetry or args.trace_out:   # span tracing stays opt-in
            tracer = configure_tracing(True)
    tl_cfg = None
    if args.timeline_out:
        from repro.telemetry import TimelineCfg
        tl_cfg = TimelineCfg()
    cfg = ServeCfg(cluster=cl, cold_start_s=args.cold_start)
    sc = ServingCluster(cfg, parse_policy(args.policy),
                        use_kernel=args.use_kernel, telemetry=tel_cfg,
                        timeline=tl_cfg)
    if tracer is not None:
        with tracer.span("serve.run", policy=args.policy,
                         workload=wname, load=args.load, n=args.n):
            out = sc.run(wl)
    else:
        out = sc.run(wl)
    s = summarize(out.response, wl.service, out.cold, out.rejected,
                  out.server_time, out.core_time, out.end_time)
    ka = lifecycle.keepalive if lifecycle else "legacy-inf"
    preset = lifecycle.coldstart if lifecycle else "scalar"
    fdesc = "homogeneous" if fleet is None else \
        f"{'explicit' if fleet.speed else fleet.preset}/{fleet.autoscale}"
    print(f"policy={args.policy} workload={wname} "
          f"load={args.load} keepalive={ka} coldstart={preset} "
          f"fleet={fdesc}")
    print(f"  slow p50/p99 = {s.slow_p50:.2f} / {s.slow_p99:.1f}")
    print(f"  lat  p50/p99 = {s.lat_p50:.2f}s / {s.lat_p99:.2f}s")
    print(f"  cold starts  = {100*s.cold_frac:.1f}%   "
          f"servers = {s.mean_servers:.2f}   rejected = {s.n_rejected}")
    if fleet is not None and fleet.autoscale != STATIC:
        print(f"  autoscale    : target p99 ≤ {fleet.target_p99:g}, "
              f"provisioned = {out.prov_core_s:.0f} core-s "
              f"(static fleet would be "
              f"{out.end_time * cl.n_workers * cl.cores:.0f})")
    if out.telemetry is not None:
        t = out.telemetry.summary()
        print(f"  telemetry    : sketch slow p50/p99 = "
              f"{t['slow_p50']:.2f} / {t['slow_p99']:.1f}  "
              f"cold={t['n_cold']} warm={t['n_warm']} "
              f"evict={t['n_evict']} reject={t['n_reject']}  "
              f"busy={t['busy_time_s']:.1f}s")
    if out.timeline is not None:
        ts = out.timeline.summary()
        csv_p = out.timeline.write_csv(args.timeline_out)
        om_p = out.timeline.write_openmetrics(args.timeline_out + ".om")
        if tracer is not None:
            out.timeline.emit_counters(tracer)
        print(f"  timeline     : {ts['n_windows']} windows of "
              f"{ts['window_s']:.2f}s, peak arrivals="
              f"{ts['arrivals_peak']}, {ts['n_events']} decision "
              f"events -> {csv_p} + {om_p}")
    if args.trace_out:
        tracer.export(args.trace_out)
        print(f"  trace        : {args.trace_out} "
              f"(load at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
