"""Production mesh construction + sharding-context assembly.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no JAX device state.  The single-pod mesh
is 16×16 = 256 chips (one v5e pod); multi-pod adds a leading ``pod`` axis
(2 × 256 = 512 chips) used as an outer data-parallel / replica axis.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType
except ImportError:  # older jax (e.g. 0.4.x): no AxisType / axis_types kwarg
    AxisType = None

from repro.distribution.sharding import ShardCtx, make_rules


def _mesh(shape, axes):
    """``jax.make_mesh`` across JAX versions.

    Newer JAX takes ``axis_types``; we always want ``Auto`` (the implicit
    default of older versions), so on a JAX without ``AxisType`` plain
    construction is semantically identical.
    """
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires host-device override)."""
    return _mesh(shape, axes)


def make_rep_mesh(n_devices: int | None = None):
    """1-D device mesh over the simulator's replication axis.

    The streaming engine (:func:`repro.core.streaming.simulate_stream`)
    shards stacked replications / policy-sweep cells across devices by
    placing the batched carry and per-chunk inputs with a
    ``NamedSharding`` over this mesh's single ``"rep"`` axis (see
    :mod:`repro.distribution.sim_shard`).  Defaults to all local
    devices; pass ``n_devices`` to use a prefix of them.
    """
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    if n < 1:
        raise ValueError(f"n_devices must be >= 1, got {n}")
    return _mesh((n,), ("rep",))


def make_ctx(mesh, cfg, shape_cfg=None, **rule_overrides) -> ShardCtx:
    """Build the sharding context for (arch cfg × input shape × mesh)."""
    multi_pod = "pod" in mesh.axis_names
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    seq_kv_data = bool(shape_cfg is not None
                       and shape_cfg.kind == "decode"
                       and shape_cfg.seq_len >= 262_144)
    rules = make_rules(multi_pod=multi_pod, fsdp=cfg.fsdp,
                       shard_heads=cfg.shard_heads,
                       seq_kv_data=seq_kv_data)
    if shape_cfg is not None and shape_cfg.global_batch % dp != 0:
        rules["batch"] = None            # e.g. long_500k's global_batch=1
    # sequence-parallel residual stream for many-token steps: the values
    # remat saves shrink by the TP degree (decode steps have S=1 — off).
    if (shape_cfg is not None and shape_cfg.kind in ("train", "prefill")
            and shape_cfg.seq_len % mesh.shape["model"] == 0):
        rules["act_seq"] = "model"
    # Serving weight layout: on the decode latency path a ZeRO-3/FSDP
    # layout forces a per-layer weight all-gather that moves far more
    # bytes than the few decode tokens need.  Serving replicas keep
    # params TP-sharded only (they fit without optimizer state); MoE
    # expert weights additionally shard their ff dim over 'data'
    # (reads stay local, the combine psum is [T,D]-sized).
    if shape_cfg is not None and shape_cfg.kind == "decode":
        rules["fsdp"] = None
        if cfg.moe is not None:
            rules["expert_ff"] = "data"
    rules.update(rule_overrides)
    return ShardCtx(mesh=mesh, rules=rules, dp_axes=dp_axes,
                    tp_axis="model",
                    pod_axis="pod" if multi_pod else None)
