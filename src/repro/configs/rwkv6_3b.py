"""rwkv6-3b — Finch: attention-free, data-dependent decay [arXiv:2404.05892]."""
import dataclasses

from repro.models.common import ModelCfg, RWKVCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="rwkv6-3b", family="rwkv6",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=8960, vocab=65536, pos="none",
        rwkv=RWKVCfg(head_size=64, decay_lora=64, mix_lora=32, ff_mult=3.5),
    )


def smoke() -> ModelCfg:
    return dataclasses.replace(
        full(), n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        d_ff=448, vocab=512,
        rwkv=RWKVCfg(head_size=64, decay_lora=8, mix_lora=4, ff_mult=3.5),
        remat="none")
