"""qwen3-14b — dense GQA with per-head qk RMSNorm [hf:Qwen/Qwen3-8B]."""
import dataclasses

from repro.models.common import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=17408, vocab=151936, qk_norm=True, rope_theta=1e6,
        fsdp=True,
        # 8 kv heads < 16-way TP → kv replicated, q heads sharded (uneven)
        shard_heads=True,
    )


def smoke() -> ModelCfg:
    return dataclasses.replace(
        full(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=512, fsdp=False, remat="none")
