"""granite-20b — code model, MQA (kv=1) [arXiv:2405.04324].

d_ff = 4·d_model with a 2-matrix GELU MLP (gpt-bigcode style — this is
what lands the advertised 20B total); attention follows the assignment
(48 heads, single KV head, rope).
"""
import dataclasses

from repro.models.common import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="granite-20b", family="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
        d_ff=24576, vocab=49152, mlp="gelu", fsdp=True,
    )


def smoke() -> ModelCfg:
    return dataclasses.replace(
        full(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
        head_dim=32, d_ff=256, vocab=512, fsdp=False, remat="none")
