"""chameleon-34b — early-fusion VQ image tokens [arXiv:2405.09818].

Modality frontend is a STUB: input_specs() provides precomputed VQ image
token ids inside the unified 65536 vocabulary; the backbone is a llama-
style decoder with qk-norm (chameleon's divergence fix).
"""
import dataclasses

from repro.models.common import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="chameleon-34b", family="dense",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22016, vocab=65536, qk_norm=True, fsdp=True,
        frontend="vq_image_tokens",
    )


def smoke() -> ModelCfg:
    return dataclasses.replace(
        full(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=512, fsdp=False, remat="none")
