"""olmo-1b — dense, non-parametric LayerNorm [arXiv:2402.00838]."""
import dataclasses

from repro.models.common import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=8192, vocab=50304, norm="layernorm_np", tie_embeddings=True,
    )


def smoke() -> ModelCfg:
    return dataclasses.replace(
        full(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=512, remat="none")
