"""deepseek-v2-236b — MLA (kv_lora=512), 2 shared + 160 routed top-6 MoE
[arXiv:2405.04434].

Deviation noted in DESIGN.md: the real model's layer 0 uses a dense FFN;
we keep a uniform MoE stack so the depth dimension scans.
"""
import dataclasses

from repro.models.common import MLACfg, ModelCfg, MoECfg


def full() -> ModelCfg:
    return ModelCfg(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        head_dim=192,                      # qk_nope + qk_rope (informational)
        d_ff=1536, vocab=102400, rope_theta=1e4,
        moe=MoECfg(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
        mla=MLACfg(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
                   v_dim=128),
        fsdp=True,
        # pure-bf16 params + fp32 moments: the 16 GB/chip budget at this
        # scale (see EXPERIMENTS.md memory analysis)
        param_dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return dataclasses.replace(
        full(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=48, d_ff=64, vocab=512,
        moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=64, n_shared=1),
        mla=MLACfg(q_lora=64, kv_lora=32, qk_nope=32, qk_rope=16,
                   v_dim=32),
        fsdp=False, remat="none")
