"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

Modality frontend is a STUB: input_specs() provides precomputed EnCodec
frame token ids (vocab 2048); the backbone below is the transformer.
"""
import dataclasses

from repro.models.common import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="musicgen-large", family="dense",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab=2048, mlp="gelu", pos="sinusoidal",
        frontend="audio_tokens",
    )


def smoke() -> ModelCfg:
    return dataclasses.replace(
        full(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=128, remat="none")
