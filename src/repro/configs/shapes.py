"""Assigned input shapes (LM-family: seq_len × global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
KV cache / recurrent state of ``seq_len``), not ``train_step``.
``long_500k`` requires sub-quadratic attention state and is run only for
the SSM/hybrid archs (rwkv6-3b, zamba2-2.7b) — skipped for pure
full-attention archs, per the assignment (see DESIGN.md §Shape-skips).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeCfg("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeCfg("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeCfg("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeCfg("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in
          (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# families with O(1)-in-seq decode state → long_500k is runnable
_SUBQUADRATIC = ("rwkv6", "hybrid")


def applicable(cfg, shape: ShapeCfg) -> tuple[bool, str]:
    """(runnable?, reason).  All 10 archs are decoder LMs → decode OK."""
    if shape.name == "long_500k" and cfg.family not in _SUBQUADRATIC:
        return False, ("full-attention arch: a 500k dense KV cache per "
                       "token is outside this shape's regime (assignment: "
                       "run for SSM/hybrid/linear-attn only)")
    return True, ""


def smoke_shape(shape: ShapeCfg) -> ShapeCfg:
    """Reduced version of a shape for CPU smoke tests."""
    return ShapeCfg(shape.name + "-smoke",
                    seq_len=min(shape.seq_len, 64),
                    global_batch=min(shape.global_batch, 2),
                    kind=shape.kind)
