"""dbrx-132b — 16 experts top-4 fine-grained MoE [hf:databricks/dbrx-base]."""
import dataclasses

from repro.models.common import ModelCfg, MoECfg


def full() -> ModelCfg:
    return ModelCfg(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=10752, vocab=100352, rope_theta=5e5,
        moe=MoECfg(n_experts=16, top_k=4, d_ff_expert=10752),
        fsdp=True,
        # pure-bf16 params + fp32 moments: the 16 GB/chip budget at this
        # scale (see EXPERIMENTS.md memory analysis)
        param_dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return dataclasses.replace(
        full(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=128, vocab=512,
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=128),
        fsdp=False, remat="none")
