"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
import dataclasses

from repro.models.common import ModelCfg, SSMCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=10240, vocab=32000,
        ssm=SSMCfg(d_state=64, expand=2, head_dim=64, conv_width=4,
                   chunk=128),
        hybrid_attn_every=6,
    )


def smoke() -> ModelCfg:
    return dataclasses.replace(
        full(), n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=512,
        ssm=SSMCfg(d_state=16, expand=2, head_dim=32, conv_width=4,
                   chunk=16),
        hybrid_attn_every=2, remat="none")
