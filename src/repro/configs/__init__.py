"""Registry of the ten assigned architectures (+ shapes).

``get(name)`` returns the exact full-size config from the assignment
table; ``get_smoke(name)`` a reduced same-family variant for CPU tests.
"""
from __future__ import annotations

from importlib import import_module

from .shapes import (SHAPES, ShapeCfg, applicable, smoke_shape,  # noqa
                     TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

_MODULES = {
    "rwkv6-3b": "rwkv6_3b",
    "qwen3-14b": "qwen3_14b",
    "olmo-1b": "olmo_1b",
    "granite-20b": "granite_20b",
    "gemma-2b": "gemma_2b",
    "zamba2-2.7b": "zamba2_2p7b",
    "musicgen-large": "musicgen_large",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "dbrx-132b": "dbrx_132b",
    "chameleon-34b": "chameleon_34b",
}

ARCH_NAMES = tuple(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str):
    return _mod(name).full()


def get_smoke(name: str):
    return _mod(name).smoke()
