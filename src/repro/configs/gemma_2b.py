"""gemma-2b — GeGLU, head_dim=256, MQA [arXiv:2403.08295]."""
import dataclasses

from repro.models.common import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="gemma-2b", family="dense",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab=256000, mlp="geglu", tie_embeddings=True,
        # 8 heads < 16-way TP: attention replicated over the model axis;
        # the param mass is in vocab (524M) + GeGLU ff — both TP-sharded.
        shard_heads=False,
    )


def smoke() -> ModelCfg:
    return dataclasses.replace(
        full(), n_layers=2, d_model=128, n_heads=2, n_kv_heads=1,
        head_dim=64, d_ff=512, vocab=512, remat="none")
