"""repro.fleet — heterogeneous workers & latency-target autoscaling.

Three planes (see ROADMAP "heterogeneous clusters" item):

* **Heterogeneity** — :class:`FleetCfg` per-worker ``speed[W]`` /
  ``mem[W]`` vectors (explicit or from the named presets ``uniform`` /
  ``two-gen`` / ``long-tail``), embedded as ``ClusterCfg.fleet``;
  ``None`` keeps today's homogeneous model bit-for-bit.
* **SWARM balancing** — lives in :mod:`repro.policy.balancers` (the
  ``SWARM`` registered balancer learns per-worker throughput online
  and dispatches speed-aware without reading ``FleetCfg`` at all).
* **Autoscaling** — the open :func:`register_autoscaler` registry
  (``STATIC`` / ``TARGET_P99``) driving an active-worker mask through
  every engine against a p99-slowdown target.
"""
from __future__ import annotations

from typing import Optional, Sequence

from .config import (FLEET_PRESETS, FleetCfg, STATIC, fleet_preset_names,
                     mem_for, parse_fleet_preset, register_fleet_preset,
                     speeds_for)
from .registry import (AUTOSCALERS, AutoscalePolicy, ResolvedFleet,
                       autoscaler_names, get_autoscaler, parse_autoscale,
                       register_autoscaler, resolve_fleet,
                       unregister_autoscaler)

__all__ = [
    "FLEET_PRESETS", "FleetCfg", "STATIC", "fleet_preset_names",
    "mem_for", "parse_fleet_preset", "register_fleet_preset",
    "speeds_for", "AUTOSCALERS", "AutoscalePolicy", "ResolvedFleet",
    "autoscaler_names", "get_autoscaler", "parse_autoscale",
    "register_autoscaler", "resolve_fleet", "unregister_autoscaler",
    "fleet_from_flags",
]


def fleet_from_flags(preset: Optional[str] = None,
                     speed: Optional[Sequence[float]] = None,
                     autoscale: Optional[str] = None,
                     target_p99: float = 5.0,
                     min_workers: int = 1,
                     cooldown_s: float = 60.0,
                     hysteresis: float = 0.1) -> Optional[FleetCfg]:
    """Build a :class:`FleetCfg` from CLI flags, or ``None``.

    Mirrors :func:`repro.lifecycle.lifecycle_from_flags`: with every
    fleet flag at its default the launcher keeps the exact homogeneous
    fixed-W model (``fleet=None``), and preset / autoscale names are
    validated against their registries up front so typos raise the
    named ``ValueError`` instead of surfacing mid-run.  An autoscale
    flag without an explicit preset runs on the ``uniform`` fleet
    (autoscaling a homogeneous fleet is the common SLO scenario).
    """
    if preset is None and not speed and autoscale is None:
        return None
    kw = {}
    if preset is not None:
        kw["preset"] = parse_fleet_preset(preset)
    if speed:
        kw["speed"] = tuple(float(s) for s in speed)
    if autoscale is not None:
        kw["autoscale"] = parse_autoscale(autoscale)
        kw["target_p99"] = float(target_p99)
        kw["min_workers"] = int(min_workers)
        kw["cooldown_s"] = float(cooldown_s)
        kw["hysteresis"] = float(hysteresis)
    return FleetCfg(**kw)
