"""Fleet configuration: per-worker speed/memory vectors and presets.

The paper's cluster is homogeneous — every worker has identical cores
and unit speed.  Real providers run mixed hardware generations; the
fleet layer gives :class:`~repro.core.cluster.ClusterCfg` a per-worker
``speed[W]`` vector (service times on worker ``w`` scale by
``1 / speed[w]``) and a reserved ``mem[W]`` vector, either explicit or
derived from a named preset.

``FleetCfg`` is a plain ``NamedTuple`` of hashable scalars/tuples so a
``ClusterCfg`` carrying one remains a valid engine-cache key
(``tuple(cluster)`` hashes; the jaxpr audit probes every field).  The
``ClusterCfg.fleet`` default of ``None`` keeps today's homogeneous
model bit-for-bit — the same python-gated contract as ``lifecycle``
and ``telemetry``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np

#: Name of the pass-through autoscale policy (fixed worker set).
STATIC = "STATIC"


class FleetCfg(NamedTuple):
    """Heterogeneous-fleet model for a :class:`ClusterCfg`.

    ``speed`` / ``mem`` are per-worker vectors (tuples, so the config
    stays hashable); empty tuples mean "derive from ``preset``".
    ``speed[w] = 0.5`` makes worker ``w`` run every invocation twice as
    long; cold-start penalties scale the same way (spin-up is compute
    too).  ``mem`` is validated and carried but semantically reserved:
    per-worker slot capacity is the memory-aware-lifecycle ROADMAP item
    and would change the scalar ``(cores, slots)`` balancer contract.

    The autoscale fields configure the closed-loop controller
    (:mod:`repro.fleet.policies`): ``autoscale="TARGET_P99"`` grows /
    shrinks the active worker set against ``target_p99`` slowdown with
    ``hysteresis`` dead-band and ``cooldown_s`` between decisions,
    never below ``min_workers``.  ``"STATIC"`` (default) keeps all
    ``W`` workers active.
    """

    preset: str = "uniform"
    speed: tuple = ()
    mem: tuple = ()
    autoscale: str = STATIC
    target_p99: float = 5.0
    min_workers: int = 1
    cooldown_s: float = 60.0
    hysteresis: float = 0.1


def _uniform(W: int) -> np.ndarray:
    return np.ones(W, dtype=np.float64)


def _two_gen(W: int) -> np.ndarray:
    """Half current-gen (speed 1.0), half previous-gen (speed 0.5)."""
    new = (W + 1) // 2
    s = np.full(W, 0.5, dtype=np.float64)
    s[:new] = 1.0
    return s


def _long_tail(W: int) -> np.ndarray:
    """Smooth generational decay: fastest 1.0 down to slowest 0.25."""
    k = np.arange(W, dtype=np.float64)
    return 1.0 / (1.0 + 3.0 * k / max(W - 1, 1))


FLEET_PRESETS: dict[str, Callable[[int], np.ndarray]] = {}


def register_fleet_preset(name: str, make, *, overwrite: bool = False):
    """Register a named ``W -> speed[W]`` fleet preset."""
    name = str(name).strip().lower()
    if not name or "/" in name:
        raise ValueError(f"invalid fleet preset name {name!r}")
    if not overwrite and name in FLEET_PRESETS:
        raise ValueError(f"fleet preset {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    FLEET_PRESETS[name] = make
    return make


register_fleet_preset("uniform", _uniform)
register_fleet_preset("two-gen", _two_gen)
register_fleet_preset("long-tail", _long_tail)


def fleet_preset_names() -> tuple[str, ...]:
    return tuple(FLEET_PRESETS)


def parse_fleet_preset(name: str) -> str:
    """Validate a CLI preset token; returns the canonical name."""
    key = str(name).strip().lower()
    if key not in FLEET_PRESETS:
        raise ValueError(
            f"unknown fleet preset {key!r}; registered fleet presets: "
            f"{', '.join(sorted(FLEET_PRESETS))}")
    return key


def speeds_for(fleet: FleetCfg, n_workers: int) -> np.ndarray:
    """Resolve the per-worker speed vector (``[W] float64``).

    An explicit ``fleet.speed`` tuple wins; otherwise the named preset
    generates it.  Length/positivity are enforced by
    :meth:`ClusterCfg.validate`; this re-checks length so direct
    callers fail with the same named error.
    """
    if fleet.speed:
        s = np.asarray(fleet.speed, dtype=np.float64)
        if s.shape != (n_workers,):
            raise ValueError(
                f"FleetCfg.speed has {s.size} entries for "
                f"n_workers={n_workers}, got {tuple(fleet.speed)}")
        return s
    return np.asarray(FLEET_PRESETS[parse_fleet_preset(fleet.preset)](
        int(n_workers)), dtype=np.float64)


def mem_for(fleet: FleetCfg, n_workers: int) -> np.ndarray:
    """Resolve the per-worker memory vector (``[W] float64``, unit 1.0
    default).  Reserved: validated and carried, not yet consumed by the
    engines (memory-aware lifecycle is a separate ROADMAP item)."""
    if fleet.mem:
        m = np.asarray(fleet.mem, dtype=np.float64)
        if m.shape != (n_workers,):
            raise ValueError(
                f"FleetCfg.mem has {m.size} entries for "
                f"n_workers={n_workers}, got {tuple(fleet.mem)}")
        return m
    return np.ones(n_workers, dtype=np.float64)
