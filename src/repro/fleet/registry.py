"""Open autoscale-policy registry, mirroring :mod:`repro.lifecycle`.

Capacity is the third scheduling axis: the balancer decides *where*,
the worker scheduler decides *in what order*, and the autoscaler
decides *how much fleet exists at all* — the closed control loop real
providers run against latency SLOs (the ROADMAP's "millions of users
on a finite fleet").  This module makes that axis an open registry so
autoscalers are sweepable like balancers and keep-alive policies.

**The autoscale contract.**  The engines maintain an *active-worker
count* ``n_on`` (workers ``0..n_on-1`` accept placements; the rest are
masked slot-full, so the balancer contract is untouched) plus a
histogram *window* — the slowdown-sketch counts observed since the
last decision (the PR-7 telemetry carry is the sensor).  A policy is a
pair of backend factories::

    make_np(cfg, n_workers)  -> decide
    make_jax(cfg, n_workers) -> decide
    decide(n_on, window) -> n_on'        # window: [N_BINS] int64

``decide`` is pure: it reads the windowed sketch, compares against the
config's target, and returns the new active count already clipped to
``[cfg.min_workers, n_workers]``.  The engines call it only when the
cooldown has elapsed *and* the window is non-empty, then snapshot the
sketch and re-arm the cooldown — identical gating in the scan engine
and the numpy oracle, so ``decide`` itself must be np ≡ jax on integer
decisions (mirror :func:`repro.telemetry.sketch.sketch_percentile`'s
exact op sequence when reading percentiles, as ``TARGET_P99`` does).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional

import numpy as np

from .config import FleetCfg, STATIC, mem_for, speeds_for

_BACKENDS = ("np", "jax")


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """A registered autoscale strategy (see the module contract)."""

    name: str
    doc: str = ""
    make_np: Optional[Callable[[FleetCfg, int], Callable]] = None
    make_jax: Optional[Callable[[FleetCfg, int], Callable]] = None
    #: ``True`` when ``decide`` reads the telemetry slowdown sketch —
    #: the engines then require a ``TelemetryCfg`` (named error if
    #: absent).  ``STATIC`` has no sensor and runs anywhere.
    needs_telemetry: bool = True

    def backends(self) -> tuple[str, ...]:
        return tuple(b for b, fn in zip(
            _BACKENDS, (self.make_np, self.make_jax)) if fn is not None)


AUTOSCALERS: dict[str, AutoscalePolicy] = {}

_builtin_lock = threading.Lock()
_builtins_loaded = False


def _load_builtins() -> None:
    """Idempotently register the built-in policies (import side effect).

    Same re-entrancy shape as the keep-alive registry: the flag is set
    *before* the import (built-ins re-enter :func:`register_autoscaler`)
    and reset if the import fails.
    """
    global _builtins_loaded
    if _builtins_loaded:
        return
    with _builtin_lock:
        if _builtins_loaded:
            return
        _builtins_loaded = True
        try:
            from . import policies  # noqa: F401  (registers on import)
        except BaseException:
            _builtins_loaded = False
            raise


def register_autoscaler(name: str, *, make_np=None, make_jax=None,
                        needs_telemetry: bool = True, doc: str = "",
                        overwrite: bool = False) -> AutoscalePolicy:
    """Register an autoscale policy under ``name`` (upper-cased).

    At least one of ``make_np`` / ``make_jax`` must be given; a policy
    with both runs through every engine in the repo.  Returns the
    :class:`AutoscalePolicy` record.
    """
    name = name.strip().upper()
    if "/" in name or "*" in name or not name:
        raise ValueError(f"invalid autoscale policy name {name!r}")
    if make_np is None and make_jax is None:
        raise ValueError(f"autoscaler {name!r} needs an np or jax backend")
    # built-ins first so a collision with a built-in surfaces here
    _load_builtins()
    if not overwrite and name in AUTOSCALERS:
        raise ValueError(f"autoscaler {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    pol = AutoscalePolicy(name=name, doc=doc, make_np=make_np,
                          make_jax=make_jax,
                          needs_telemetry=needs_telemetry)
    AUTOSCALERS[name] = pol
    _engine_cache_clear()
    return pol


def unregister_autoscaler(name: str) -> None:
    _load_builtins()
    AUTOSCALERS.pop(str(name).strip().upper(), None)
    _engine_cache_clear()


def _engine_cache_clear() -> None:
    # compiled simulator engines capture resolved decide closures;
    # (re-)registration must drop them, like the policy registry does.
    import sys
    sim = sys.modules.get("repro.core.simulator")
    clear = getattr(sim, "clear_engine_cache", None)
    if clear is not None:
        clear()


def autoscaler_names() -> tuple[str, ...]:
    _load_builtins()
    return tuple(AUTOSCALERS)


def get_autoscaler(name) -> AutoscalePolicy:
    _load_builtins()
    key = str(name).strip().upper()
    try:
        return AUTOSCALERS[key]
    except KeyError:
        raise ValueError(
            f"unknown autoscale policy {key!r}; registered autoscale "
            f"policies: "
            f"{', '.join(sorted(AUTOSCALERS))}") from None


def parse_autoscale(name: str) -> str:
    """Validate a CLI autoscale token; returns the canonical name."""
    return get_autoscaler(name).name


# --------------------------------------------------------------------------
# resolve — fleet cfg → speed vector + decide callable (engines' entry)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ResolvedFleet:
    """A fleet config resolved against one backend and worker count.

    ``speeds`` / ``mem`` are the concrete ``[W] float64`` vectors.
    ``decide`` follows the module contract for the chosen backend;
    ``auto_on`` is ``False`` for ``STATIC`` (no decisions, no carry —
    the engines then apply speed scaling only).
    """

    cfg: FleetCfg
    policy: AutoscalePolicy
    backend: str
    speeds: Any                        # np.ndarray [W] f64
    mem: Any                           # np.ndarray [W] f64
    decide: Optional[Callable]

    @property
    def auto_on(self) -> bool:
        return self.cfg.autoscale.strip().upper() != STATIC

    @property
    def uniform(self) -> bool:
        """True when every worker runs at exactly speed 1.0."""
        return bool(np.all(self.speeds == 1.0))


def resolve_fleet(cluster, *, backend: str = "np"
                  ) -> Optional[ResolvedFleet]:
    """Resolve ``cluster.fleet`` into the speed vector and decide hook.

    Returns ``None`` when the cluster carries no fleet config (the
    homogeneous fixed-W model) so engines can gate the whole subsystem
    on one check.  ``backend`` is ``"np"`` or ``"jax"`` (``"pallas"``
    select backends share the jax fleet path).
    """
    cfg = getattr(cluster, "fleet", None)
    if cfg is None:
        return None
    _load_builtins()
    if backend == "pallas":
        backend = "jax"
    if backend not in _BACKENDS:
        raise ValueError(f"unknown fleet backend {backend!r}; "
                         f"choose from {_BACKENDS}")
    pol = get_autoscaler(cfg.autoscale)
    make = pol.make_np if backend == "np" else pol.make_jax
    if make is None:
        raise ValueError(f"autoscaler {pol.name!r} has no {backend} "
                         f"backend (has: {pol.backends()})")
    W = int(cluster.n_workers)
    rf = ResolvedFleet(cfg=cfg, policy=pol, backend=backend,
                       speeds=speeds_for(cfg, W), mem=mem_for(cfg, W),
                       decide=make(cfg, W))
    return rf
