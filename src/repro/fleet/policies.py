"""Built-in autoscale policies: ``STATIC`` and ``TARGET_P99``.

``STATIC`` keeps the whole fleet active — the pass-through policy that
makes a ``FleetCfg`` with default autoscale behave as "heterogeneity
only".

``TARGET_P99`` is the closed loop from the ROADMAP heterogeneity item:
grow the active worker set when the observed p99 slowdown (read from
the telemetry sketch window) overshoots, shrink it when the fleet is
over-provisioned, with a hysteresis dead-band so the controller
doesn't chatter and a cooldown (enforced by the engines) between
decisions.  Two control choices make the configured ``target_p99`` a
*ceiling* the pooled run-level p99 actually stays under:

* the internal setpoint is ``target_p99 / 2`` — the sensor is a
  completion-time signal read over the window since the last decision,
  so it reports excursions only after they have already hurt the tail;
  regulating to half the target leaves headroom for that lag;
* growth is multiplicative (``n_on += max(1, n_on // 2)``) while
  shrink is additive (``-1``) — a diurnal ramp out of a scaled-down
  trough needs capacity *now*, while over-provisioning only costs
  core-hours linearly (the MIAD asymmetry).

The percentile read mirrors
:func:`repro.telemetry.sketch.sketch_percentile` op-for-op — same
``ceil``-rank, same ``searchsorted(cumsum, k, 'left')``, same
geometric-midpoint value — so the np and jax controllers take
identical integer decisions on identical windows (the parity lane
checks this bitwise).
"""
# repro-lint: hot-path
# repro-lint: parity-lane
from __future__ import annotations

import math

import numpy as np

from .config import FleetCfg, STATIC
from .registry import register_autoscaler


def _static_np(cfg: FleetCfg, n_workers: int):
    def decide(n_on, window):
        return int(n_on)
    return decide


def _static_jax(cfg: FleetCfg, n_workers: int):
    import jax.numpy as jnp

    def decide(n_on, window):
        return jnp.asarray(n_on, dtype=jnp.int32)
    return decide


def _p99_bounds(cfg: FleetCfg) -> tuple[float, float]:
    """Hysteresis band edges, computed once in python so both backends
    compare against bit-identical thresholds.

    The band is centered on the internal setpoint ``target_p99 / 2``
    (ceiling semantics — see the module docstring), not on the target
    itself.
    """
    t = float(cfg.target_p99) * 0.5
    h = float(cfg.hysteresis)
    return t * (1.0 + h), t * (1.0 - h)


def _target_p99_np(cfg: FleetCfg, n_workers: int):
    from repro.telemetry.sketch import hist_edges
    edges = hist_edges()
    hi, lo = _p99_bounds(cfg)
    min_w = int(cfg.min_workers)

    def decide(n_on, window):
        window = np.asarray(window, dtype=np.int64)
        total = int(window.sum())
        if total < 1:                  # engines gate on this too
            return int(n_on)
        # exact sketch_percentile op sequence (q = 99)
        k = min(max(int(math.ceil(0.99 * total)), 1), total)
        b = int(np.searchsorted(np.cumsum(window), k, side="left"))
        p99 = math.sqrt(float(edges[b]) * float(edges[b + 1]))
        # MIAD: multiplicative grow (ramp recovery), additive shrink
        if p99 > hi:
            n_new = int(n_on) + max(1, int(n_on) // 2)
        elif p99 < lo:
            n_new = int(n_on) - 1
        else:
            n_new = int(n_on)
        return int(min(max(n_new, min_w), n_workers))
    return decide


def _target_p99_jax(cfg: FleetCfg, n_workers: int):
    import jax.numpy as jnp

    from repro.telemetry.sketch import hist_edges
    edges = jnp.asarray(hist_edges())
    hi, lo = _p99_bounds(cfg)
    min_w = int(cfg.min_workers)

    def decide(n_on, window):
        window = window.astype(jnp.int64)
        total = window.sum()
        tot_f = total.astype(jnp.float64)
        k = jnp.clip(jnp.ceil(0.99 * tot_f).astype(jnp.int64),
                     jnp.int64(1), jnp.maximum(total, 1))
        b = jnp.searchsorted(jnp.cumsum(window), k, side="left")
        p99 = jnp.sqrt(edges[b] * edges[b + 1])
        n_i = n_on.astype(jnp.int32)
        # MIAD: multiplicative grow (ramp recovery), additive shrink
        delta = jnp.where(p99 > hi, jnp.maximum(1, n_i // 2),
                          jnp.where(p99 < lo, -1, 0))
        scaled = jnp.clip(n_i + delta, min_w, n_workers)
        # empty window -> no decision (engines gate on this too)
        return jnp.where(total > 0, scaled, n_on).astype(jnp.int32)
    return decide


register_autoscaler(
    STATIC, make_np=_static_np, make_jax=_static_jax,
    needs_telemetry=False,
    doc="fixed fleet: all W workers stay active (no control loop)")
register_autoscaler(
    "TARGET_P99", make_np=_target_p99_np, make_jax=_target_p99_jax,
    doc="keep p99 slowdown under a target ceiling: telemetry-sketch "
        "sensor, half-target setpoint, MIAD grow/shrink, hysteresis "
        "band, engine cooldown")
