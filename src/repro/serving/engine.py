"""Serving platform: Controller + Workers with Hermes as the dispatcher.

This is the OpenWhisk analogue of the paper (§5) adapted to a model-
serving cluster: "functions" are registered model entry points, a warm
executor is a worker-resident compiled step + weights, a cold start pays
the compile/residency cost, and each Worker timeshares its cores across
active invocations (processor sharing — the serving runtime's CFS
analogue).  On top of the paper's design it adds **straggler
mitigation**: invocations stuck on a degraded worker past a deadline are
re-dispatched (early binding's correction mechanism at scale).

The engine is an event-driven virtual-time loop (the platform layer the
paper implements in Scala); the *policy* math is shared with the
simulator through the registry (:func:`repro.policy.resolve` with the
``np`` backend — any registered balancer/scheduler serves unchanged),
and the controller can execute its dispatch decisions through the
batched Pallas kernel when the balancer ships one (``H`` →
``repro.kernels.hermes_select``) — one cluster-state read per arrival
batch, the TPU-native form of the §6.6 hot loop.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.cluster import ClusterCfg
from repro.core.taxonomy import LoadBalance, PolicySpec, HERMES
from repro.core.workload import Workload
from repro.fleet import resolve_fleet
from repro.lifecycle import LifecycleRuntime, resolve_lifecycle
from repro.policy import resolve
from repro.telemetry.spans import get_tracer
from repro.telemetry.state import (TelemetryCfg, TelemetryResult, init_np,
                                   on_advance_np, on_complete_np,
                                   on_evict_np, on_place_np, on_reject_np,
                                   warmup_cutoff)
from repro.telemetry.timeline import (EV_AUTOSCALE, EV_MODE_FLIP,
                                      TimelineCfg, TimelineResult,
                                      auto_window_s, init_tl_np,
                                      sensor_p99_np, tl_event_np,
                                      tl_on_advance_np, tl_on_arrival_np,
                                      tl_on_complete_np, tl_on_evict_np,
                                      tl_on_place_np, tl_on_prov_np,
                                      tl_on_reject_np, validate_timeline)

EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ServeCfg:
    """Platform config.  ``cluster.lifecycle`` (if set) threads the
    container-lifecycle subsystem (:mod:`repro.lifecycle`) through the
    platform: keep-alive windows gate warm hits, the ``max_idle``
    budget LRU-evicts idle executors, and a cold-start preset replaces
    the scalar ``cold_start_s`` (which stays the fallback for the
    ``"scalar"`` preset)."""

    cluster: ClusterCfg = ClusterCfg(n_workers=8, cores=12)
    cold_start_s: float = 0.5          # executor spin-up (compile+weights)
    ctrl_latency_s: float = 0.0005     # controller decision latency (§6.6)
    # straggler mitigation: re-dispatch when a task on a degraded worker
    # has completed < frac of its work after deadline_s of residence.
    redispatch_deadline_s: float | None = None
    redispatch_frac: float = 0.1
    # failure detector: degraded workers (speed < health_threshold) are
    # masked out of dispatch while healthy capacity exists — OpenWhisk's
    # unhealthy-invoker handling.  Without this, Hermes's packing mode
    # keeps refilling the straggler (it looks attractively non-empty).
    health_aware: bool = False
    health_threshold: float = 0.5
    detect_after_s: float = 0.0     # failure-detector latency
    # worker speed factors (1.0 = healthy); index → factor.  When empty
    # and ``cluster.fleet`` is set, the fleet's per-worker speed vector
    # (repro.fleet presets / explicit speeds) is used instead — explicit
    # ServeCfg.speeds always wins (the straggler experiments override a
    # single worker without redefining the fleet).
    speeds: tuple = ()

    def speed(self, w: int) -> float:
        return self.speeds[w] if w < len(self.speeds) else 1.0


@dataclasses.dataclass
class _Task:
    arr_idx: int
    func: int
    arrival: float
    placed_at: float
    work: float               # total work (incl. cold start)
    remaining: float
    seq: int
    rate: float = 0.0
    migrations: int = 0


@dataclasses.dataclass(frozen=True)
class ServeResult:
    response: np.ndarray      # [N] seconds (NaN = rejected)
    cold: np.ndarray          # [N] bool
    rejected: np.ndarray      # [N] bool
    worker: np.ndarray        # [N] final worker
    redispatched: np.ndarray  # [N] bool
    server_time: float
    core_time: float
    end_time: float
    n_cold: int
    n_redispatch: int
    #: streaming metrics (None unless the cluster was built with a
    #: TelemetryCfg) — same layout as the simulators' telemetry
    telemetry: TelemetryResult | None = None
    #: provisioned core-seconds: the autoscaler's ``n_on × cores`` time
    #: integral, or ``end_time × total_cores`` for a fixed fleet
    prov_core_s: float = 0.0
    #: windowed flight recorder (None unless the cluster was built with
    #: a TimelineCfg) — same layout as the simulators' timeline plane
    timeline: TimelineResult | None = None


class ServingCluster:
    """Event-driven serving cluster under a scheduling policy."""

    def __init__(self, cfg: ServeCfg, policy: PolicySpec = HERMES,
                 use_kernel: bool = False,
                 telemetry: TelemetryCfg | None = None,
                 timeline: TimelineCfg | None = None):
        self.cfg = cfg
        self.policy = policy
        self.use_kernel = use_kernel
        self.telemetry = telemetry
        self.timeline = validate_timeline(timeline) \
            if timeline is not None else None
        # numpy-backend resolution drives the virtual-time loop; the
        # balancer's batched kernel (if registered) serves the
        # ``use_kernel`` controller path
        self._res = resolve(policy, backend="np", cluster=cfg.cluster)
        if use_kernel:
            if self._res.batch_select is None:
                raise ValueError(
                    f"policy {self._res.spec.name} has no batched kernel "
                    f"dispatch (balancer lacks a make_batch backend)")
            self._kernel = self._res.batch_select

    # ------------------------------------------------------------------
    def run(self, wl: Workload) -> ServeResult:
        cfg = self.cfg
        cl = cfg.cluster
        W, C, S = cl.n_workers, cl.cores, cl.slots
        F = wl.n_functions
        N = wl.n
        res = self._res
        late = res.late

        tasks: list[list[_Task]] = [[] for _ in range(W)]
        warm = np.zeros((W, F), dtype=np.int64)
        queue: list[int] = []
        # carried-state balancers (HIKU ready-ring, DD estimates, ...):
        # the same np-backend state pytree + hooks as the simulators
        lb_state = res.init_state(W, F) \
            if (res.stateful and not late) else None
        # container lifecycle: the same np state machine the oracle
        # threads (None = legacy infinite keep-alive)
        lres = resolve_lifecycle(cl, backend="np", n_functions=F)
        life = LifecycleRuntime(lres, W, F) if lres is not None else None
        # streaming telemetry + virtual-time task lifecycle events
        tel = init_np(W) if self.telemetry is not None else None
        tel_cutoff = warmup_cutoff(N, self.telemetry) \
            if self.telemetry is not None else 0
        # windowed flight recorder — the simulators' plane-4 layout with
        # the platform's own event semantics (responses include the
        # controller latency, migrations count evictions only)
        tl = None
        if self.timeline is not None:
            tl = init_tl_np(W, self.timeline,
                            auto_window_s(float(wl.arrival[-1]),
                                          self.timeline))
        flip_on = tl is not None and not late \
            and self.policy.balance == LoadBalance.HYBRID
        tracer = get_tracer()
        # heterogeneous fleet (repro.fleet): when ServeCfg.speeds is
        # empty, the fleet's speed vector drives the same per-worker
        # rate scaling the straggler model uses; a non-STATIC autoscale
        # policy adds the simulators' arrival-boundary control loop
        fres = resolve_fleet(cl, backend="np")
        fleet_on = fres is not None
        if fleet_on and not cfg.speeds:
            fl_speeds = np.asarray(fres.speeds)

            def speed(w: int) -> float:
                return float(fl_speeds[w])
        else:
            speed = cfg.speed
        auto_on = fleet_on and fres.auto_on
        if auto_on:
            if late:
                raise ValueError(
                    f"autoscaler {fres.policy.name!r} requires early "
                    f"binding — late binding has no per-worker placement "
                    f"to mask")
            if fres.policy.needs_telemetry and tel is None:
                raise ValueError(
                    f"autoscaler {fres.policy.name!r} reads the telemetry "
                    f"slowdown sketch as its sensor; pass telemetry="
                    f"TelemetryCfg() to the platform")
            from repro.telemetry.sketch import N_BINS
            auto_decide = fres.decide
            auto_cool = float(fres.cfg.cooldown_s)
            n_on = W
            cool_until = 0.0
            prov_time = 0.0
            snap = np.zeros(N_BINS, dtype=np.int64)
        response = np.full(N, np.nan)
        cold = np.zeros(N, dtype=bool)
        rejected = np.zeros(N, dtype=bool)
        redisp = np.zeros(N, dtype=bool)
        worker_of = np.full(N, -1, dtype=np.int32)
        server_time = core_time = 0.0
        now = 0.0

        def set_rates(w: int) -> None:
            ts = tasks[w]
            if not ts:
                return
            spd = speed(w)
            if late:
                for t in ts:
                    t.rate = spd
                return
            # registry rate assignment, scaled by the worker's speed
            # factor (straggler model)
            rs = res.rates([t.remaining for t in ts],
                           [t.seq for t in ts])
            for t, r in zip(ts, rs):
                t.rate = r * spd

        def place(w: int, arr_idx: int, work: float | None = None,
                  migration: bool = False) -> None:
            f = int(wl.func[arr_idx])
            avail = int(warm[w, f]) if life is None \
                else life.materialized_at(w, f, warm[w, f], now)
            evicted = False
            if avail > 0 and work is None:
                warm[w, f] -= 1
                is_cold = False
            else:
                is_cold = True
                idle = int(warm[w].sum()) if life is None \
                    else int(life.eff_row(warm[w], w, now).sum())
                if len(tasks[w]) + idle >= S:
                    victim = int(np.argmax(warm[w])) if life is None \
                        else life.evict_victim(warm[w], w, now)
                    warm[w, victim] -= 1
                    evicted = True
            if tel is not None:
                if not migration:
                    on_place_np(tel, w, is_cold, evicted)
                elif evicted:
                    # a migration's slot-pressure eviction is real even
                    # though the placement itself is not a decision
                    on_evict_np(tel)
            if tl is not None:
                if not migration:
                    tl_on_place_np(tl, now, is_cold, evicted)
                elif evicted:
                    tl_on_evict_np(tl, now)
            cold_s = cfg.cold_start_s if life is None \
                else life.cold_cost(f, cfg.cold_start_s)
            if life is not None:
                # adaptive keep-alive observes the placed pool's idle
                # age after the warm/cold decision (oracle order)
                life.observe_place(w, f, now)
            if not migration:
                cold[arr_idx] = is_cold
            worker_of[arr_idx] = w
            if work is None:
                work = float(wl.service[arr_idx]) + \
                    (cold_s if is_cold else 0.0)
            elif is_cold:
                work += cold_s
            tasks[w].append(_Task(
                arr_idx=arr_idx, func=f, arrival=float(wl.arrival[arr_idx]),
                placed_at=now, work=work, remaining=work, seq=arr_idx))

        def pop_queue() -> None:
            while queue:
                loads = [len(tasks[w]) for w in range(W)]
                w = int(np.argmin(loads))
                if loads[w] >= C:
                    break
                place(w, queue.pop(0))

        def maybe_redispatch() -> None:
            # Migrations place without consulting the balancer, so a
            # carried-state balancer's accounting is approximate under
            # re-dispatch: HIKU validates popped workers against
            # ``active`` (ring pops of a migrated-onto worker fall back
            # to least-loaded), and DD's expected-work ledger keeps the
            # charge on the source worker (bounded drift — the
            # completion discharge is clamped at zero on the target).
            if cfg.redispatch_deadline_s is None:
                return
            active = np.array([len(tasks[w]) for w in range(W)])
            for w in range(W):
                if speed(w) >= 1.0:
                    continue
                for t in list(tasks[w]):
                    resident = now - t.placed_at
                    done_frac = 1.0 - t.remaining / max(t.work, EPS)
                    if resident >= cfg.redispatch_deadline_s and \
                            done_frac < cfg.redispatch_frac:
                        key = np.array([active[x] / speed(x)
                                        if x != w else np.inf
                                        for x in range(W)])
                        tgt = int(np.argmin(key))
                        if active[tgt] >= S:
                            continue
                        tasks[w].remove(t)
                        active[w] -= 1
                        redisp[t.arr_idx] = True
                        place(tgt, t.arr_idx, work=t.remaining,
                              migration=True)
                        active[tgt] += 1

        def advance(dt: float) -> None:
            nonlocal now, server_time, core_time, lb_state
            dt_left = dt
            while True:
                if late:
                    pop_queue()
                if not any(tasks[w] for w in range(W)):
                    break
                for w in range(W):
                    set_rates(w)
                tau = dt_left
                for w in range(W):
                    for t in tasks[w]:
                        if t.rate > 0:
                            tau = min(tau, t.remaining / t.rate)
                if tau <= 0 and dt_left <= 0:
                    break
                tau = max(tau, 0.0)
                server_time += tau * sum(1 for w in range(W) if tasks[w])
                core_time += tau * sum(min(len(tasks[w]), C)
                                       for w in range(W))
                if tel is not None:
                    on_advance_np(
                        tel, tau,
                        np.array([bool(tasks[w]) for w in range(W)]),
                        np.array([len(tasks[w]) for w in range(W)]),
                        len(queue))
                if tl is not None:
                    tl_on_advance_np(
                        tl, now, tau,
                        np.array([bool(tasks[w]) for w in range(W)]),
                        len(queue))
                now += tau
                dt_left -= tau
                for w in range(W):
                    survivors = []
                    n_alive = len(tasks[w])
                    for t in tasks[w]:
                        t.remaining -= t.rate * tau
                        if t.remaining <= EPS:
                            response[t.arr_idx] = now - t.arrival + \
                                self.cfg.ctrl_latency_s
                            if tel is not None:
                                on_complete_np(
                                    tel, response[t.arr_idx],
                                    float(wl.service[t.arr_idx]),
                                    t.arr_idx, tel_cutoff)
                            if tl is not None:
                                tl_on_complete_np(
                                    tl, now, response[t.arr_idx],
                                    float(wl.service[t.arr_idx]))
                            if tracer.enabled:
                                # one virtual-time event per task:
                                # arrival → completion on its worker's
                                # track (Perfetto pid "virtual-time")
                                tracer.event_at(
                                    f"f{t.func}", t.arrival,
                                    response[t.arr_idx], tid=w,
                                    task=t.arr_idx,
                                    cold=bool(cold[t.arr_idx]),
                                    migrations=t.migrations)
                            if life is None:
                                warm[w, t.func] += 1
                            else:
                                budget_evicted = life.on_complete(
                                    warm, w, t.func, now)
                                if budget_evicted:
                                    if tel is not None:
                                        on_evict_np(tel)
                                    if tl is not None:
                                        tl_on_evict_np(tl, now)
                            n_alive -= 1
                            if lb_state is not None:
                                # observed (speed-scaled) duration under
                                # a heterogeneous fleet (oracle contract)
                                svc_obs = wl.service[t.arr_idx] / speed(w) \
                                    if fleet_on else wl.service[t.arr_idx]
                                lb_state = res.on_complete(
                                    lb_state, w, t.func, float(svc_obs),
                                    n_alive)
                        else:
                            survivors.append(t)
                    tasks[w] = survivors
                maybe_redispatch()
                if dt_left <= 0:
                    break

        # the failure detector reads the *straggler* speeds (explicit
        # ServeCfg.speeds) only — a heterogeneous fleet's slow
        # generation is a capability, not a degradation, and must stay
        # schedulable (the simulators have no health mask either)
        unhealthy = np.array([cfg.speed(w) < cfg.health_threshold
                              for w in range(W)]) if cfg.health_aware \
            else np.zeros(W, dtype=bool)

        # pre-gather warm columns when using the kernel path
        for i in range(N):
            t_i = float(wl.arrival[i])
            if auto_on:
                # provisioned-time integral over [now, t_i] at the
                # current n_on (decisions land at arrival boundaries)
                prov_time += (t_i - now) * float(n_on)
            if tl is not None:
                n_prov = float(n_on) if auto_on else float(W)
                tl_on_prov_np(tl, now, (t_i - now) * n_prov * float(C))
            advance(t_i - now)
            now = t_i
            active = np.array([len(tasks[w]) for w in range(W)])
            if cfg.health_aware and unhealthy.any() and \
                    now >= cfg.detect_after_s:
                healthy_free = (~unhealthy) & (active < S)
                if healthy_free.any():      # mask stragglers out
                    active = np.where(unhealthy, S, active)
            if auto_on:
                # autoscale decision against the slowdown-sketch window
                # (same gating as the simulators), then mask
                # deprovisioned workers slot-full — the health-mask
                # idiom, composed after it
                window = tel["slow_hist"] - snap
                if t_i >= cool_until and int(window.sum()) >= 1:
                    n_new = int(auto_decide(n_on, window))
                    if tl is not None and n_new != n_on:
                        tl_event_np(tl, t_i, EV_AUTOSCALE, n_new,
                                    sensor_p99_np(window))
                    n_on = n_new
                    cool_until = t_i + auto_cool
                    snap = tel["slow_hist"].copy()
                active = np.where(np.arange(W) < n_on, active, S)
            if tl is not None:
                tl_on_arrival_np(tl, t_i, n_on if auto_on else W)
                if flip_on:
                    new_mode = int(bool((active < C).any()))
                    if new_mode != int(tl["mode"]):
                        tl_event_np(tl, t_i, EV_MODE_FLIP, new_mode,
                                    float("nan"))
                    tl["mode"] = np.int32(new_mode)
            if late:
                if active.min() < C:
                    place(int(np.argmin(active)), i)
                else:
                    queue.append(i)
                continue
            f = int(wl.func[i])
            wcol = warm[:, f] if life is None \
                else life.materialized_col(warm[:, f], f, now)
            if self.use_kernel:
                import jax.numpy as jnp
                kwarm = warm if life is None \
                    else life.materialized_all(warm, now)
                ws, _ = self._kernel(
                    jnp.asarray(active, jnp.int32),
                    jnp.asarray(kwarm, jnp.int32),
                    jnp.asarray([f], jnp.int32))
                w = int(ws[0])
            elif lb_state is not None:
                w, lb_state = res.select(lb_state, active, wcol, f,
                                         wl.func_home, float(wl.u_lb[i]), i)
            else:
                w = res.select(active, wcol, f, wl.func_home,
                               float(wl.u_lb[i]), i)
            if w < 0:
                rejected[i] = True
                if tel is not None:
                    on_reject_np(tel)
                if tl is not None:
                    tl_on_reject_np(tl, t_i)
            else:
                place(w, i)

        t_last = now
        advance(math.inf)
        if auto_on:
            # drain tail: provisioned until the last completion
            prov_time += (now - t_last) * float(n_on)
            prov_core_s = prov_time * C
        else:
            prov_core_s = now * W * C
        if tl is not None:
            n_prov = float(n_on) if auto_on else float(W)
            tl_on_prov_np(tl, t_last, (now - t_last) * n_prov * float(C))
        return ServeResult(
            response=response, cold=cold, rejected=rejected,
            worker=worker_of, redispatched=redisp,
            server_time=server_time, core_time=core_time, end_time=now,
            n_cold=int(cold[~rejected].sum()),
            n_redispatch=int(redisp.sum()),
            telemetry=None if tel is None else TelemetryResult.from_state(
                tel, cfg=self.telemetry),
            prov_core_s=prov_core_s,
            timeline=None if tl is None else TimelineResult.from_state(
                tl, cfg=self.timeline))
