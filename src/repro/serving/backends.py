"""Real-model serving backend: registered functions are JAX models.

Demonstrates the full life-cycle of §2.1 with actual compute: a function
invocation is (model, prompt, n_new_tokens); a *warm executor* is a
worker-resident compiled ``(prefill, decode_step)`` pair + params; a
*cold start* is the real XLA compile + weight-init cost, measured — not
modeled.  The controller schedules invocations onto in-process workers
with the Hermes policy; continuous batching timeshares each worker's
compute across its active invocations at decode-step granularity
(processor sharing at step quantum).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import build_model
from repro.policy import np_select


@dataclasses.dataclass
class Invocation:
    func: str
    prompt: np.ndarray           # [S] int32
    n_new: int
    arrival: float = 0.0
    # filled by the platform:
    response_s: float | None = None
    cold: bool = False
    worker: int = -1
    tokens: np.ndarray | None = None


class ModelRegistry:
    """Function store (the CouchDB analogue): name → model config."""

    def __init__(self):
        self._fns: dict[str, Callable] = {}

    def register(self, name: str, cfg, seed: int = 0):
        self._fns[name] = (cfg, seed)

    def names(self):
        return list(self._fns)

    def build(self, name: str):
        cfg, seed = self._fns[name]
        model = build_model(cfg)
        params = model.init(jax.random.key(seed))
        return model, params


class Executor:
    """A warm executor: compiled steps + resident params for one function."""

    def __init__(self, registry: ModelRegistry, name: str, max_len: int):
        t0 = time.perf_counter()
        model, params = registry.build(name)
        self.model = model
        self.params = params
        self.max_len = max_len
        self.prefill = jax.jit(model.prefill)
        self.decode = jax.jit(model.decode_step)
        # trigger compilation now (the cold start cost, measured)
        B = 1
        cache = model.init_cache(B, max_len)
        toks = jnp.zeros((B, 8), jnp.int32)
        _, cache = self.prefill(self.params, toks, cache)
        _ = self.decode(self.params, toks[:, :1], cache,
                        jnp.full((B,), 8, jnp.int32))
        jax.block_until_ready(_[0])
        self.cold_start_s = time.perf_counter() - t0

    def run(self, inv: Invocation) -> np.ndarray:
        model = self.model
        prompt = jnp.asarray(inv.prompt, jnp.int32)[None]
        S = prompt.shape[1]
        cache = model.init_cache(1, self.max_len)
        logits, cache = self.prefill(self.params, prompt, cache)
        out = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for i in range(inv.n_new):
            out.append(int(tok[0, 0]))
            logits, cache = self.decode(self.params, tok, cache,
                                        jnp.full((1,), S + i, jnp.int32))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return np.asarray(out, np.int32)


class InProcessWorker:
    """One worker: warm-executor cache + invocation execution.

    The cache is bounded two ways, like the simulated lifecycle
    subsystem (:mod:`repro.lifecycle`): ``max_warm`` is the warm-pool
    budget (LRU eviction under pressure) and ``keepalive_s`` an
    optional idle-timeout — executors idle longer than the window are
    released lazily before each execution (``None`` keeps the legacy
    keep-forever behavior).
    """

    def __init__(self, registry: ModelRegistry, max_len: int = 128,
                 max_warm: int = 4, keepalive_s: float | None = None):
        self.registry = registry
        self.max_len = max_len
        self.max_warm = max_warm
        self.keepalive_s = keepalive_s
        self.warm: dict[str, Executor] = {}
        self.active = 0
        self.lru: list[str] = []
        self.idle_since: dict[str, float] = {}

    def has_warm(self, func: str) -> bool:
        return func in self.warm

    def expire_idle(self, now: float | None = None) -> int:
        """Release executors idle past the keep-alive window."""
        if self.keepalive_s is None:
            return 0
        now = time.perf_counter() if now is None else now
        dead = [f for f in self.warm
                if now - self.idle_since.get(f, now) > self.keepalive_s]
        for f in dead:
            del self.warm[f]
            self.idle_since.pop(f, None)
            if f in self.lru:
                self.lru.remove(f)
        return len(dead)

    def execute(self, inv: Invocation) -> Invocation:
        t0 = time.perf_counter()
        self.expire_idle(t0)
        if inv.func not in self.warm:
            if len(self.warm) >= self.max_warm:          # evict LRU
                victim = self.lru.pop(0)
                del self.warm[victim]
                self.idle_since.pop(victim, None)
            self.warm[inv.func] = Executor(self.registry, inv.func,
                                           self.max_len)
            inv.cold = True
        if inv.func in self.lru:
            self.lru.remove(inv.func)
        self.lru.append(inv.func)
        inv.tokens = self.warm[inv.func].run(inv)
        self.idle_since[inv.func] = time.perf_counter()
        inv.response_s = time.perf_counter() - t0
        return inv


class HermesFrontend:
    """Controller for in-process workers using a registry balancer.

    Carried-state balancers (``HIKU``/``DD``) are fully supported: the
    dispatcher threads their state through every selection and feeds the
    ``on_complete`` hook the *measured* wall time of each invocation —
    the live-serving analogue of the simulator's oracle durations.
    """

    def __init__(self, registry: ModelRegistry, n_workers: int = 2,
                 cores: int = 2, max_len: int = 128,
                 balancer: str = "H", keepalive_s: float | None = None):
        self.workers = [InProcessWorker(registry, max_len,
                                        keepalive_s=keepalive_s)
                        for _ in range(n_workers)]
        self.cores = cores
        self.slots = 8 * cores
        self.fn_ids = {n: i for i, n in enumerate(registry.names())}
        from repro.policy import get_balancer
        bal = get_balancer(balancer)
        if bal.stateful:
            self._select, self._on_complete = bal.make_np(self.cores,
                                                          self.slots)
            self._lb_state = bal.init_state(n_workers, len(self.fn_ids))
        else:
            self._select = np_select(balancer, self.cores, self.slots)
            self._on_complete = None
            self._lb_state = None
        self._n_dispatched = 0

    def dispatch(self, inv: Invocation) -> Invocation:
        W = len(self.workers)
        F = len(self.fn_ids)
        active = np.array([w.active for w in self.workers])
        warm = np.zeros((W, F), dtype=np.int64)
        for wi, w in enumerate(self.workers):
            for name in w.warm:
                warm[wi, self.fn_ids[name]] = 1
        fid = self.fn_ids[inv.func]
        homes = np.zeros(F, np.int32)
        if self._lb_state is not None:
            w, self._lb_state = self._select(
                self._lb_state, active, warm[:, fid], fid, homes, 0.0,
                self._n_dispatched)
        else:
            w = self._select(active, warm[:, fid], fid, homes, 0.0,
                             self._n_dispatched)
        self._n_dispatched += 1
        if w < 0:
            raise RuntimeError("cluster full")
        inv.worker = int(w)
        worker = self.workers[w]
        worker.active += 1
        t0 = time.perf_counter()
        try:
            return worker.execute(inv)
        finally:
            worker.active -= 1
            if self._lb_state is not None:
                self._lb_state = self._on_complete(
                    self._lb_state, int(w), fid,
                    time.perf_counter() - t0, worker.active)
