"""Built-in intra-worker schedulers — rate assignment in numpy and jax.

Shared semantics (paper §3.1/§3.4; both simulators implement these
through this registry):

* ``PS``   — processor sharing: every active task gets ``min(1, C/n)``
  cores (the CFS analogue).
* ``FCFS`` — the ``C`` earliest-arrived tasks run at rate 1, the rest 0
  (arrival sequence number is the key).
* ``SRPT`` — the ``C`` tasks with least remaining work run at rate 1
  (oracle execution times; ties broken by arrival sequence in numpy and
  by slot order in jax — a measure-zero event for continuous service
  distributions).

The numpy backend operates on one worker's parallel task lists; the jax
backend on the engine's ``[W, S]`` slot matrix (``task_idx < 0`` marks
empty slots).
"""
from __future__ import annotations

from .registry import register_sched


# --------------------------------------------------------------------------
# numpy backends: (cores) -> rates(remaining, seqs) -> list[float]
# --------------------------------------------------------------------------

def _ps_np(cores: int):
    def rates(remaining, seqs):
        n = len(remaining)
        r = min(1.0, cores / n) if n else 0.0
        return [r] * n
    return rates


def _fcfs_np(cores: int):
    def rates(remaining, seqs):
        n = len(seqs)
        order = sorted(range(n), key=lambda i: seqs[i])
        out = [0.0] * n
        for k, i in enumerate(order):
            out[i] = 1.0 if k < cores else 0.0
        return out
    return rates


def _srpt_np(cores: int):
    def rates(remaining, seqs):
        n = len(seqs)
        order = sorted(range(n), key=lambda i: (remaining[i], seqs[i]))
        out = [0.0] * n
        for k, i in enumerate(order):
            out[i] = 1.0 if k < cores else 0.0
        return out
    return rates


# --------------------------------------------------------------------------
# jax backends: (cores) -> rates(task_idx [W,S] i32, remaining [W,S] f64)
# --------------------------------------------------------------------------

def _rank_rows(jnp, key):
    """Per-row rank of each element (0 = smallest). Stable."""
    order = jnp.argsort(key, axis=1)
    ranks = jnp.zeros_like(order)
    rows = jnp.arange(key.shape[0], dtype=order.dtype)[:, None]
    return ranks.at[rows, order].set(
        jnp.broadcast_to(jnp.arange(key.shape[1], dtype=order.dtype),
                         key.shape))


def _ps_jax(cores: int):
    import jax.numpy as jnp

    def rates(task_idx, remaining):
        active = task_idx >= 0
        n = active.sum(axis=1, keepdims=True)
        r = jnp.minimum(1.0, cores / jnp.maximum(n, 1))
        return jnp.where(active, r, 0.0)
    return rates


def _fcfs_jax(cores: int):
    import jax.numpy as jnp

    def rates(task_idx, remaining):
        active = task_idx >= 0
        key = jnp.where(active, task_idx, jnp.int32(1 << 30))
        rank = _rank_rows(jnp, key)
        return jnp.where(active & (rank < cores), 1.0, 0.0)
    return rates


def _srpt_jax(cores: int):
    import jax.numpy as jnp

    def rates(task_idx, remaining):
        active = task_idx >= 0
        key = jnp.where(active, remaining, jnp.inf)
        rank = _rank_rows(jnp, key)
        return jnp.where(active & (rank < cores), 1.0, 0.0)
    return rates


register_sched("PS", doc="processor sharing: min(1, C/n) cores per task",
               make_np=_ps_np, make_jax=_ps_jax)
register_sched("FCFS", doc="first C tasks in arrival order run at rate 1",
               make_np=_fcfs_np, make_jax=_fcfs_jax)
register_sched("SRPT", doc="C tasks with least remaining work run at "
                           "rate 1 (oracle)",
               make_np=_srpt_np, make_jax=_srpt_jax)
