"""Built-in load balancers — numpy / jax (/ Pallas) triplets.

Every backend of a balancer implements the identical deterministic
contract (see :mod:`repro.policy.registry`) so the numpy oracle, the
jitted scan engine, and the Pallas controller kernel can be compared
task-by-task (``tests/test_policies.py`` asserts this for every
registered balancer).

Paper balancers (§3.1, §4.2): ``LOC`` (OpenWhisk sticky hashing), ``R``
(uniform over free workers), ``LL`` (join-shortest-queue), ``H`` (Hermes
hybrid — packing at low load, least-loaded at high load, warm-executor
tie-breaks; its ``pallas`` backend is the batched controller kernel in
:mod:`repro.kernels.hermes_select`).

Registry extensions beyond the paper (the policy zoo):

* ``JSQ2`` — power-of-two-choices: sample two workers from the single
  pre-drawn uniform ``u``, join the shorter queue; falls back to the
  global least-loaded worker when both candidates are slot-full (so it
  only rejects when the whole cluster is full, like every balancer
  here).
* ``RR`` — round-robin: start at worker ``idx mod W`` (``idx`` is the
  arrival sequence number) and linear-probe to the first worker with a
  free slot — LOC's ring walk with a rotating home.
* ``HIKU`` — pull-based assignment (Hiku, Akbari & Hauswirth 2025):
  workers *advertise* themselves into a FIFO ready-ring when their last
  active task completes; an arrival pops the oldest advertised (idle)
  worker and falls back to least-loaded when the ring is empty.  The
  ring (worker ids + membership flags + head/tail counters) is carried
  state threaded through the engines; duplicates are impossible (the
  membership flag gates pushes), so a popped worker is idle by
  invariant and a pop never rejects.  All workers start advertised.
* ``DD`` — data-driven dispatch (per-function execution-time estimates
  à la Przybylski et al. 2021): carried state holds a per-function EMA
  of observed execution times (``α = 0.25``, prior 1 s) plus each
  worker's expected outstanding work; an arrival joins the worker with
  the least expected work (shortest-expected-load), charging the
  function's current estimate, and completions both discharge the
  worker and refine the function's estimate.
* ``SWARM`` — smoothed-priority throughput learning (per the
  Helix/SWARM exemplar): least-loaded *weighted by a learned
  per-worker slowness factor*.  Completions report the *observed*
  (wall-clock) execution time on the completing worker; the state
  tracks a per-function duration scale ``est[f]`` and a per-worker
  slowness ``inv[w]`` (prior 1.0 ≈ learned ``1/speed``), both as
  multiplicative sign-EMAs — exponentially-weighted *median* trackers,
  robust to the heavy-tailed Azure duration mix where a mean EMA is
  noise-dominated.  The slowness sample is ``observed / est[f]``
  (function-scale-normalized, so every worker's samples are
  comparable), stepped fast for a worker's first completions and an
  order of magnitude slower once burned in.  Selection is
  congestion-gated: below core saturation an arrival simply joins the
  fastest (min ``inv``) worker with a free slot; at saturation it
  minimizes ``(active + 1) × inv[w]`` — queue depth scaled by
  slowness, i.e. expected wait.  On a heterogeneous fleet
  (``ClusterCfg.fleet``) this learns the speed vector online without
  ever reading it; on a homogeneous cluster ``inv`` stays flat and
  SWARM degrades to pack-then-least-loaded.

The Hermes lexicographic score (shared by np / jax / Pallas):

* low-load mode (some worker has a free core) — among workers with a
  free core, prefer class ``3`` = non-empty with a warm executor for the
  function, ``2`` = non-empty, ``1`` = empty with warm executor, ``0`` =
  empty; within a class prefer the *most* loaded (packing / fill-up).
* high-load mode (no free core anywhere) — least-loaded among workers
  with a free slot, warm executor breaks ties.
"""
from __future__ import annotations

import numpy as np

from .registry import register_balancer

_INT_INF = np.int64(1 << 40)


def hermes_score_np(active: np.ndarray, warm_f: np.ndarray, cores: int,
                    slots: int) -> tuple[np.ndarray, bool]:
    """Return (score vector to maximize, low_load_mode)."""
    has_core = active < cores
    low_load = bool(has_core.any())
    warm = warm_f > 0
    if low_load:
        nonempty = active > 0
        cls = np.where(nonempty, 2 + warm.astype(np.int64),
                       warm.astype(np.int64))
        score = cls * (slots + 1) + active
        score = np.where(has_core, score, -_INT_INF)
    else:
        has_slot = active < slots
        key = active.astype(np.int64) * 2 - warm.astype(np.int64)
        score = np.where(has_slot, -key, -_INT_INF)  # maximize = least loaded
    return score, low_load


def _two_choices(u: float, n_workers: int) -> tuple[int, int]:
    """Two candidate indices derived from one uniform draw.

    Splits ``u`` into integer part (first candidate) and the fractional
    remainder rescaled (second candidate) — float64 on every backend, so
    numpy and jax truncate identically.
    """
    x = u * n_workers
    a = min(int(x), n_workers - 1)
    frac = x - np.floor(x)
    b = min(int(frac * n_workers), n_workers - 1)
    return a, b


# --------------------------------------------------------------------------
# numpy backends
# --------------------------------------------------------------------------

def _loc_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1
        W = active.shape[0]
        home = int(func_home[func])
        ring = (home + np.arange(W)) % W
        return int(ring[int(np.argmax(has_slot[ring]))])
    return select


def _random_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1
        free_idx = np.nonzero(has_slot)[0]
        return int(free_idx[min(int(u * len(free_idx)), len(free_idx) - 1)])
    return select


def _ll_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1
        key = np.where(has_slot, active, _INT_INF)
        return int(np.argmin(key))
    return select


def _hybrid_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        if not (active < slots).any():
            return -1
        score, _ = hermes_score_np(active, warm_col, cores, slots)
        return int(np.argmax(score))
    return select


def _jsq2_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1
        W = active.shape[0]
        a, b = _two_choices(float(u), W)
        key = np.where(has_slot, active, _INT_INF)
        w = b if key[b] < key[a] else a
        if not has_slot[w]:            # both sampled workers full
            w = int(np.argmin(key))
        return int(w)
    return select


def _rr_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1
        W = active.shape[0]
        ring = (int(idx) % W + np.arange(W)) % W
        return int(ring[int(np.argmax(has_slot[ring]))])
    return select


# --------------------------------------------------------------------------
# jax backends — jax imported lazily so numpy-only users avoid jax init
# --------------------------------------------------------------------------

def _guarded(jnp):
    def guard(w, has_slot):
        return jnp.where(has_slot.any(), w, -1).astype(jnp.int32)
    return guard


def _loc_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)

    def select(active, warm_col, func, func_home, u, idx):
        W = active.shape[0]
        has_slot = active < slots
        home = func_home[func]
        ring = (home + jnp.arange(W, dtype=jnp.int32)) % W
        return guard(ring[jnp.argmax(has_slot[ring])], has_slot)
    return select


def _random_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)

    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        k = has_slot.sum()
        target = jnp.minimum((u * k).astype(jnp.int32), k - 1)
        # index of the (target+1)-th free worker
        csum = jnp.cumsum(has_slot.astype(jnp.int32)) - 1
        hit = has_slot & (csum == target)
        return guard(jnp.argmax(hit), has_slot)
    return select


def _ll_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)
    BIG = jnp.int32(1 << 30)

    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        key = jnp.where(has_slot, active, BIG)
        return guard(jnp.argmin(key), has_slot)
    return select


def _hybrid_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)
    BIG = jnp.int32(1 << 30)

    def select(active, warm_col, func, func_home, u, idx):
        active = active.astype(jnp.int32)
        has_slot = active < slots
        has_core = active < cores
        warm = (warm_col > 0).astype(jnp.int32)
        nonempty = (active > 0).astype(jnp.int32)
        cls = jnp.where(nonempty > 0, 2 + warm, warm)
        lo_score = jnp.where(has_core, cls * (slots + 1) + active, -BIG)
        hi_key = active * 2 - warm
        hi_score = jnp.where(has_slot, -hi_key, -BIG)
        score = jnp.where(has_core.any(), lo_score, hi_score)
        return guard(jnp.argmax(score), has_slot)
    return select


def _jsq2_jax(cores: int, slots: int):
    import jax
    import jax.numpy as jnp
    # the two-choices derivation truncates u*W, so it matches the f64
    # numpy oracle only under x64.  The engines enable x64 process-wide
    # on import (repro.core.simulator); enforce the same here so a
    # standalone jax_select("JSQ2", ...) keeps the cross-backend
    # contract (model code in this repo pins explicit dtypes — safe).
    jax.config.update("jax_enable_x64", True)
    guard = _guarded(jnp)
    BIG = jnp.int32(1 << 30)

    def select(active, warm_col, func, func_home, u, idx):
        W = active.shape[0]
        has_slot = active < slots
        x = jnp.asarray(u, jnp.float64) * W
        a = jnp.minimum(x.astype(jnp.int32), W - 1)
        frac = x - jnp.floor(x)
        b = jnp.minimum((frac * W).astype(jnp.int32), W - 1)
        key = jnp.where(has_slot, active.astype(jnp.int32), BIG)
        w = jnp.where(key[b] < key[a], b, a)
        w = jnp.where(has_slot[w], w, jnp.argmin(key).astype(jnp.int32))
        return guard(w, has_slot)
    return select


def _rr_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)

    def select(active, warm_col, func, func_home, u, idx):
        W = active.shape[0]
        has_slot = active < slots
        home = jnp.asarray(idx, jnp.int32) % W
        ring = (home + jnp.arange(W, dtype=jnp.int32)) % W
        return guard(ring[jnp.argmax(has_slot[ring])], has_slot)
    return select


# --------------------------------------------------------------------------
# Carried-state balancers: HIKU (pull-based ready-ring) and DD
# (data-driven per-function EMA).  Their make_* factories return
# (select, on_complete) pairs — see the carried-state contract in
# repro.policy.registry.  Both backends of each balancer perform the
# identical float/int operations in the identical order, so np ≡ jax
# holds bitwise (the parity tests thread state across both).
# --------------------------------------------------------------------------

# EMA smoothing factor for DD's per-function estimates.  A power of two,
# and the update is written in incremental form est + α·(obs − est):
# α·d is then *exact* (pure exponent shift), so XLA fusing the
# multiply-add into an FMA rounds identically to numpy's separate
# mul-then-add and the np ≡ jax bitwise parity contract holds.
DD_ALPHA = 0.25
DD_PRIOR_S = 1.0      # estimate before a function's first completion


def _hiku_init(n_workers: int, n_functions: int):
    """All workers start advertised (everyone is idle at t=0)."""
    return {"ring": np.arange(n_workers, dtype=np.int32),
            "in_ring": np.ones(n_workers, dtype=np.int32),
            "head": np.int32(0),
            "tail": np.int32(n_workers)}


def _hiku_np(cores: int, slots: int):
    def select(state, active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1, state
        if int(state["tail"]) > int(state["head"]):
            ring = state["ring"]
            cand = int(ring[int(state["head"]) % ring.shape[0]])
            in_ring = state["in_ring"].copy()
            in_ring[cand] = 0
            new = dict(state, head=np.int32(int(state["head"]) + 1),
                       in_ring=in_ring)
            # inside the engines a ring member is idle by invariant, but
            # external placements (serving-platform re-dispatch) can
            # busy an advertised worker — validate before committing,
            # falling back to least-loaded (identical check in the jax
            # backend keeps bitwise parity)
            if has_slot[cand]:
                return cand, new
            key = np.where(has_slot, active, _INT_INF)
            return int(np.argmin(key)), new
        key = np.where(has_slot, active, _INT_INF)
        return int(np.argmin(key)), state

    def on_complete(state, w, func, service, n_active_after):
        if n_active_after != 0 or int(state["in_ring"][w]) != 0:
            return state
        ring = state["ring"].copy()
        ring[int(state["tail"]) % ring.shape[0]] = w
        in_ring = state["in_ring"].copy()
        in_ring[w] = 1
        return dict(state, ring=ring, in_ring=in_ring,
                    tail=np.int32(int(state["tail"]) + 1))

    return select, on_complete


def _hiku_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)
    BIG = jnp.int32(1 << 30)

    def select(state, active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        ring, in_ring = state["ring"], state["in_ring"]
        W = ring.shape[0]
        pop = (state["tail"] > state["head"]) & has_slot.any()
        cand = ring[state["head"] % W]
        key = jnp.where(has_slot, active.astype(jnp.int32), BIG)
        ll_w = jnp.argmin(key).astype(jnp.int32)
        # a popped worker is idle by engine invariant; under external
        # perturbation (serving re-dispatch) validate its slot and fall
        # back to least-loaded — mirrors the np backend bit-for-bit
        w = jnp.where(pop & has_slot[cand], cand, ll_w)
        in_ring = in_ring.at[cand].set(
            jnp.where(pop, 0, in_ring[cand]).astype(in_ring.dtype))
        new = dict(state, head=state["head"] + pop.astype(state["head"].dtype),
                   in_ring=in_ring)
        return guard(w, has_slot), new

    def on_complete(state, w, func, service, n_active_after):
        ring, in_ring = state["ring"], state["in_ring"]
        W = ring.shape[0]
        push = (n_active_after == 0) & (in_ring[w] == 0)
        pos = state["tail"] % W
        ring = ring.at[pos].set(
            jnp.where(push, w, ring[pos]).astype(ring.dtype))
        in_ring = in_ring.at[w].set(
            jnp.where(push, 1, in_ring[w]).astype(in_ring.dtype))
        return dict(state, ring=ring, in_ring=in_ring,
                    tail=state["tail"] + push.astype(state["tail"].dtype))

    return select, on_complete


def _dd_init(n_workers: int, n_functions: int):
    return {"est": np.full(n_functions, DD_PRIOR_S, dtype=np.float64),
            "ew": np.zeros(n_workers, dtype=np.float64)}


def _dd_np(cores: int, slots: int):
    def select(state, active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1, state
        key = np.where(has_slot, state["ew"], np.inf)
        w = int(np.argmin(key))
        ew = state["ew"].copy()
        ew[w] = ew[w] + state["est"][func]
        return w, dict(state, ew=ew)

    def on_complete(state, w, func, service, n_active_after):
        est = state["est"].copy()
        ew = state["ew"].copy()
        ew[w] = np.maximum(ew[w] - est[func], 0.0)
        est[func] = est[func] + DD_ALPHA * (service - est[func])
        return dict(state, est=est, ew=ew)

    return select, on_complete


def _dd_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)

    def select(state, active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        key = jnp.where(has_slot, state["ew"], jnp.inf)
        w = jnp.argmin(key).astype(jnp.int32)
        placed = has_slot.any()
        ew = state["ew"].at[w].add(jnp.where(placed, state["est"][func], 0.0))
        return guard(w, has_slot), dict(state, ew=ew)

    def on_complete(state, w, func, service, n_active_after):
        est_f = state["est"][func]
        ew = state["ew"].at[w].set(
            jnp.maximum(state["ew"][w] - est_f, 0.0))
        est = state["est"].at[func].set(
            est_f + DD_ALPHA * (service - est_f))
        return dict(state, est=est, ew=ew)

    return select, on_complete


# SWARM smoothing factors.  Every update is a *multiplicative sign-EMA*
# (a geometric median tracker): the tracked value is multiplied by a
# compile-time constant chosen by a comparison — a single IEEE multiply
# per update, with no add to fuse into, so XLA FMA fusion cannot change
# rounding and np ≡ jax stays bitwise.  The only other float combining
# op is an IEEE division (never fused).  Median tracking (not mean EMA)
# is what makes the learner robust to the heavy-tailed Azure duration
# mix: a mean EMA of lognormal samples is dominated by outliers and the
# learned slowness barely separates a 2× speed gap (measured on
# azure-diurnal), while the median tracker recovers it cleanly.
SWARM_ALPHA = 0.25          # est step: est ×= (1±α) toward the median
SWARM_GAMMA = 0.125         # inv step while a worker is burning in
SWARM_GAMMA_COLD = 0.0078125   # 1/128 — inv step after burn-in
SWARM_WARM_N = 128          # completions per worker before the step drop
SWARM_PRIOR_S = 1.0


def _swarm_init(n_workers: int, n_functions: int):
    return {"est": np.full(n_functions, SWARM_PRIOR_S, dtype=np.float64),
            "inv": np.ones(n_workers, dtype=np.float64),
            "cnt": np.zeros(n_workers, dtype=np.int64)}


# Precomputed multiplicative steps (python floats; identical constants
# embedded in both backends' traces).
_SW_EST_UP = 1.0 + SWARM_ALPHA
_SW_EST_DN = 1.0 / (1.0 + SWARM_ALPHA)
_SW_HOT_UP = 1.0 + SWARM_GAMMA
_SW_HOT_DN = 1.0 / (1.0 + SWARM_GAMMA)
_SW_COLD_UP = 1.0 + SWARM_GAMMA_COLD
_SW_COLD_DN = 1.0 / (1.0 + SWARM_GAMMA_COLD)


def _swarm_np(cores: int, slots: int):
    def select(state, active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1, state
        inv = state["inv"]
        # congestion-gated key: below core saturation join the fastest
        # free worker; at saturation minimize queue-depth × slowness
        # (= expected wait).  argmin ties resolve to the first index on
        # both backends.
        key = np.where(has_slot,
                       np.where(active + 1 <= cores, inv,
                                (active + 1.0) * inv),
                       np.inf)
        return int(np.argmin(key)), state

    def on_complete(state, w, func, service, n_active_after):
        # ``service`` is the observed wall-clock execution time on
        # worker ``w`` (the engines report effective durations when a
        # fleet is configured; see repro.policy.registry)
        est = state["est"].copy()
        inv = state["inv"].copy()
        cnt = state["cnt"].copy()
        sample = service / est[func]          # function-normalized slowness
        est[func] = est[func] * (_SW_EST_UP if service > est[func]
                                 else _SW_EST_DN)
        hot = cnt[w] < SWARM_WARM_N
        inv[w] = inv[w] * ((_SW_HOT_UP if hot else _SW_COLD_UP)
                           if sample > inv[w]
                           else (_SW_HOT_DN if hot else _SW_COLD_DN))
        cnt[w] = cnt[w] + 1
        return dict(state, est=est, inv=inv, cnt=cnt)

    return select, on_complete


def _swarm_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)

    def select(state, active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        inv = state["inv"]
        key = jnp.where(has_slot,
                        jnp.where(active + 1 <= cores, inv,
                                  (active + 1.0) * inv),
                        jnp.inf)
        w = jnp.argmin(key).astype(jnp.int32)
        return guard(w, has_slot), state

    def on_complete(state, w, func, service, n_active_after):
        est_f = state["est"][func]
        sample = service / est_f
        est = state["est"].at[func].set(
            est_f * jnp.where(service > est_f, _SW_EST_UP, _SW_EST_DN))
        hot = state["cnt"][w] < SWARM_WARM_N
        inv_w = state["inv"][w]
        step = jnp.where(sample > inv_w,
                         jnp.where(hot, _SW_HOT_UP, _SW_COLD_UP),
                         jnp.where(hot, _SW_HOT_DN, _SW_COLD_DN))
        inv = state["inv"].at[w].set(inv_w * step)
        cnt = state["cnt"].at[w].add(1)
        return dict(state, est=est, inv=inv, cnt=cnt)

    return select, on_complete


# --------------------------------------------------------------------------
# Pallas backend (H) — the batched controller kernel as a per-arrival
# select inside the scan engine, and as the batched dispatch for the
# serving controller
# --------------------------------------------------------------------------

def _hybrid_pallas(cores: int, slots: int):
    import jax
    import jax.numpy as jnp
    from repro.kernels.hermes_select.kernel import hermes_select_batch
    interpret = jax.default_backend() != "tpu"

    def select(active, warm_col, func, func_home, u, idx):
        # N=1 batch: the sequential contract is preserved exactly — the
        # engine applies completions between arrivals, so each decision
        # sees fresh cluster state.  Under ``vmap`` (simulate_many) the
        # replication axis becomes the kernel's batch dimension: one
        # kernel dispatch serves every stacked replication per arrival.
        out, _ = hermes_select_batch(
            active.astype(jnp.int32), warm_col.astype(jnp.int32)[None, :],
            cores=cores, slots=slots, interpret=interpret)
        return out[0]
    return select


def _hybrid_batch(cores: int, slots: int):
    from repro.kernels.hermes_select.ops import hermes_select

    def batch(active, warm, funcs):
        return hermes_select(active, warm, funcs, cores=cores, slots=slots)
    return batch


register_balancer(
    "LOC", doc="locality/sticky hashing (OpenWhisk default)",
    make_np=_loc_np, make_jax=_loc_jax)
register_balancer(
    "R", doc="uniform over workers with a free slot",
    make_np=_random_np, make_jax=_random_jax)
register_balancer(
    "LL", doc="least-loaded / join-shortest-queue",
    make_np=_ll_np, make_jax=_ll_jax)
register_balancer(
    "H", doc="Hermes hybrid: pack at low load, LL at high load",
    make_np=_hybrid_np, make_jax=_hybrid_jax,
    make_pallas=_hybrid_pallas, make_batch=_hybrid_batch)
register_balancer(
    "JSQ2", doc="power-of-two-choices: join the shorter of two sampled "
                "queues",
    make_np=_jsq2_np, make_jax=_jsq2_jax)
register_balancer(
    "RR", doc="round-robin ring probe from worker (idx mod W)",
    make_np=_rr_np, make_jax=_rr_jax)
register_balancer(
    "HIKU", doc="pull-based: idle workers advertise into a ready-ring; "
                "arrivals pop it, LL fallback when empty",
    make_np=_hiku_np, make_jax=_hiku_jax, init_state=_hiku_init)
register_balancer(
    "DD", doc="data-driven: shortest expected load via per-function "
              "execution-time EMAs",
    make_np=_dd_np, make_jax=_dd_jax, init_state=_dd_init)
register_balancer(
    "SWARM", doc="slowness-weighted least-loaded: learns per-worker "
                 "1/speed online via median-tracking priorities",
    make_np=_swarm_np, make_jax=_swarm_jax, init_state=_swarm_init)
