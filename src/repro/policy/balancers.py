"""Built-in load balancers — numpy / jax (/ Pallas) triplets.

Every backend of a balancer implements the identical deterministic
contract (see :mod:`repro.policy.registry`) so the numpy oracle, the
jitted scan engine, and the Pallas controller kernel can be compared
task-by-task (``tests/test_policies.py`` asserts this for every
registered balancer).

Paper balancers (§3.1, §4.2): ``LOC`` (OpenWhisk sticky hashing), ``R``
(uniform over free workers), ``LL`` (join-shortest-queue), ``H`` (Hermes
hybrid — packing at low load, least-loaded at high load, warm-executor
tie-breaks; its ``pallas`` backend is the batched controller kernel in
:mod:`repro.kernels.hermes_select`).

Registry extensions beyond the paper (the policy zoo):

* ``JSQ2`` — power-of-two-choices: sample two workers from the single
  pre-drawn uniform ``u``, join the shorter queue; falls back to the
  global least-loaded worker when both candidates are slot-full (so it
  only rejects when the whole cluster is full, like every balancer
  here).
* ``RR`` — round-robin: start at worker ``idx mod W`` (``idx`` is the
  arrival sequence number) and linear-probe to the first worker with a
  free slot — LOC's ring walk with a rotating home.

The Hermes lexicographic score (shared by np / jax / Pallas):

* low-load mode (some worker has a free core) — among workers with a
  free core, prefer class ``3`` = non-empty with a warm executor for the
  function, ``2`` = non-empty, ``1`` = empty with warm executor, ``0`` =
  empty; within a class prefer the *most* loaded (packing / fill-up).
* high-load mode (no free core anywhere) — least-loaded among workers
  with a free slot, warm executor breaks ties.
"""
from __future__ import annotations

import numpy as np

from .registry import register_balancer

_INT_INF = np.int64(1 << 40)


def hermes_score_np(active: np.ndarray, warm_f: np.ndarray, cores: int,
                    slots: int) -> tuple[np.ndarray, bool]:
    """Return (score vector to maximize, low_load_mode)."""
    has_core = active < cores
    low_load = bool(has_core.any())
    warm = warm_f > 0
    if low_load:
        nonempty = active > 0
        cls = np.where(nonempty, 2 + warm.astype(np.int64),
                       warm.astype(np.int64))
        score = cls * (slots + 1) + active
        score = np.where(has_core, score, -_INT_INF)
    else:
        has_slot = active < slots
        key = active.astype(np.int64) * 2 - warm.astype(np.int64)
        score = np.where(has_slot, -key, -_INT_INF)  # maximize = least loaded
    return score, low_load


def _two_choices(u: float, n_workers: int) -> tuple[int, int]:
    """Two candidate indices derived from one uniform draw.

    Splits ``u`` into integer part (first candidate) and the fractional
    remainder rescaled (second candidate) — float64 on every backend, so
    numpy and jax truncate identically.
    """
    x = u * n_workers
    a = min(int(x), n_workers - 1)
    frac = x - np.floor(x)
    b = min(int(frac * n_workers), n_workers - 1)
    return a, b


# --------------------------------------------------------------------------
# numpy backends
# --------------------------------------------------------------------------

def _loc_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1
        W = active.shape[0]
        home = int(func_home[func])
        ring = (home + np.arange(W)) % W
        return int(ring[int(np.argmax(has_slot[ring]))])
    return select


def _random_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1
        free_idx = np.nonzero(has_slot)[0]
        return int(free_idx[min(int(u * len(free_idx)), len(free_idx) - 1)])
    return select


def _ll_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1
        key = np.where(has_slot, active, _INT_INF)
        return int(np.argmin(key))
    return select


def _hybrid_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        if not (active < slots).any():
            return -1
        score, _ = hermes_score_np(active, warm_col, cores, slots)
        return int(np.argmax(score))
    return select


def _jsq2_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1
        W = active.shape[0]
        a, b = _two_choices(float(u), W)
        key = np.where(has_slot, active, _INT_INF)
        w = b if key[b] < key[a] else a
        if not has_slot[w]:            # both sampled workers full
            w = int(np.argmin(key))
        return int(w)
    return select


def _rr_np(cores: int, slots: int):
    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        if not has_slot.any():
            return -1
        W = active.shape[0]
        ring = (int(idx) % W + np.arange(W)) % W
        return int(ring[int(np.argmax(has_slot[ring]))])
    return select


# --------------------------------------------------------------------------
# jax backends — jax imported lazily so numpy-only users avoid jax init
# --------------------------------------------------------------------------

def _guarded(jnp):
    def guard(w, has_slot):
        return jnp.where(has_slot.any(), w, -1).astype(jnp.int32)
    return guard


def _loc_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)

    def select(active, warm_col, func, func_home, u, idx):
        W = active.shape[0]
        has_slot = active < slots
        home = func_home[func]
        ring = (home + jnp.arange(W, dtype=jnp.int32)) % W
        return guard(ring[jnp.argmax(has_slot[ring])], has_slot)
    return select


def _random_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)

    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        k = has_slot.sum()
        target = jnp.minimum((u * k).astype(jnp.int32), k - 1)
        # index of the (target+1)-th free worker
        csum = jnp.cumsum(has_slot.astype(jnp.int32)) - 1
        hit = has_slot & (csum == target)
        return guard(jnp.argmax(hit), has_slot)
    return select


def _ll_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)
    BIG = jnp.int32(1 << 30)

    def select(active, warm_col, func, func_home, u, idx):
        has_slot = active < slots
        key = jnp.where(has_slot, active, BIG)
        return guard(jnp.argmin(key), has_slot)
    return select


def _hybrid_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)
    BIG = jnp.int32(1 << 30)

    def select(active, warm_col, func, func_home, u, idx):
        active = active.astype(jnp.int32)
        has_slot = active < slots
        has_core = active < cores
        warm = (warm_col > 0).astype(jnp.int32)
        nonempty = (active > 0).astype(jnp.int32)
        cls = jnp.where(nonempty > 0, 2 + warm, warm)
        lo_score = jnp.where(has_core, cls * (slots + 1) + active, -BIG)
        hi_key = active * 2 - warm
        hi_score = jnp.where(has_slot, -hi_key, -BIG)
        score = jnp.where(has_core.any(), lo_score, hi_score)
        return guard(jnp.argmax(score), has_slot)
    return select


def _jsq2_jax(cores: int, slots: int):
    import jax
    import jax.numpy as jnp
    # the two-choices derivation truncates u*W, so it matches the f64
    # numpy oracle only under x64.  The engines enable x64 process-wide
    # on import (repro.core.simulator); enforce the same here so a
    # standalone jax_select("JSQ2", ...) keeps the cross-backend
    # contract (model code in this repo pins explicit dtypes — safe).
    jax.config.update("jax_enable_x64", True)
    guard = _guarded(jnp)
    BIG = jnp.int32(1 << 30)

    def select(active, warm_col, func, func_home, u, idx):
        W = active.shape[0]
        has_slot = active < slots
        x = jnp.asarray(u, jnp.float64) * W
        a = jnp.minimum(x.astype(jnp.int32), W - 1)
        frac = x - jnp.floor(x)
        b = jnp.minimum((frac * W).astype(jnp.int32), W - 1)
        key = jnp.where(has_slot, active.astype(jnp.int32), BIG)
        w = jnp.where(key[b] < key[a], b, a)
        w = jnp.where(has_slot[w], w, jnp.argmin(key).astype(jnp.int32))
        return guard(w, has_slot)
    return select


def _rr_jax(cores: int, slots: int):
    import jax.numpy as jnp
    guard = _guarded(jnp)

    def select(active, warm_col, func, func_home, u, idx):
        W = active.shape[0]
        has_slot = active < slots
        home = jnp.asarray(idx, jnp.int32) % W
        ring = (home + jnp.arange(W, dtype=jnp.int32)) % W
        return guard(ring[jnp.argmax(has_slot[ring])], has_slot)
    return select


# --------------------------------------------------------------------------
# Pallas backend (H) — the batched controller kernel as a per-arrival
# select inside the scan engine, and as the batched dispatch for the
# serving controller
# --------------------------------------------------------------------------

def _hybrid_pallas(cores: int, slots: int):
    import jax
    import jax.numpy as jnp
    from repro.kernels.hermes_select.kernel import hermes_select_batch
    interpret = jax.default_backend() != "tpu"

    def select(active, warm_col, func, func_home, u, idx):
        # N=1 batch: the sequential contract is preserved exactly — the
        # engine applies completions between arrivals, so each decision
        # sees fresh cluster state.  Under ``vmap`` (simulate_many) the
        # replication axis becomes the kernel's batch dimension: one
        # kernel dispatch serves every stacked replication per arrival.
        out, _ = hermes_select_batch(
            active.astype(jnp.int32), warm_col.astype(jnp.int32)[None, :],
            cores=cores, slots=slots, interpret=interpret)
        return out[0]
    return select


def _hybrid_batch(cores: int, slots: int):
    from repro.kernels.hermes_select.ops import hermes_select

    def batch(active, warm, funcs):
        return hermes_select(active, warm, funcs, cores=cores, slots=slots)
    return batch


register_balancer(
    "LOC", doc="locality/sticky hashing (OpenWhisk default)",
    make_np=_loc_np, make_jax=_loc_jax)
register_balancer(
    "R", doc="uniform over workers with a free slot",
    make_np=_random_np, make_jax=_random_jax)
register_balancer(
    "LL", doc="least-loaded / join-shortest-queue",
    make_np=_ll_np, make_jax=_ll_jax)
register_balancer(
    "H", doc="Hermes hybrid: pack at low load, LL at high load",
    make_np=_hybrid_np, make_jax=_hybrid_jax,
    make_pallas=_hybrid_pallas, make_batch=_hybrid_batch)
register_balancer(
    "JSQ2", doc="power-of-two-choices: join the shorter of two sampled "
                "queues",
    make_np=_jsq2_np, make_jax=_jsq2_jax)
register_balancer(
    "RR", doc="round-robin ring probe from worker (idx mod W)",
    make_np=_rr_np, make_jax=_rr_jax)
