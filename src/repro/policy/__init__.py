"""``repro.policy`` — composable policy registry, multi-backend dispatch.

The policy space of the paper (§3.1) as an *open registry*: balancers
(worker selection), worker schedulers (rate assignment) and bindings are
registered by name, each carrying per-backend implementations (``np`` /
``jax`` / optionally ``pallas``), and :func:`resolve` turns a policy +
backend + cluster into ready callables.  The engines
(:mod:`repro.core.simulator`, :mod:`repro.core.sim_ref`,
:mod:`repro.serving.engine`) consume resolved callables and never branch
on policy names — registering a balancer here makes it sweepable
everywhere (``parse_policy``, ``sweep_policies``, ``policy_explorer``,
``launch.serve``).

Registering a custom balancer::

    import numpy as np
    from repro.policy import register_balancer

    def make_np(cores, slots):
        def select(active, warm_col, func, func_home, u, idx):
            free = np.nonzero(active < slots)[0]
            return int(free[0]) if len(free) else -1
        return select

    def make_jax(cores, slots):
        import jax.numpy as jnp
        def select(active, warm_col, func, func_home, u, idx):
            has_slot = active < slots
            w = jnp.argmax(has_slot).astype(jnp.int32)
            return jnp.where(has_slot.any(), w, -1).astype(jnp.int32)
        return select

    register_balancer("FF", make_np=make_np, make_jax=make_jax,
                      doc="first free worker")
    # "E/FF/PS" now works in every sweep, CLI and engine.
"""
from .registry import (Balancer, BindingDef, ResolvedPolicy, SchedDef,
                       balancer_names, binding_names, canonical_name,
                       default_backend, get_balancer, get_binding,
                       get_sched, jax_select, np_select,
                       register_balancer, register_binding, register_sched,
                       resolve, sched_names, unregister_balancer)
from .balancers import hermes_score_np

__all__ = [
    "Balancer", "BindingDef", "ResolvedPolicy", "SchedDef",
    "balancer_names", "binding_names", "canonical_name",
    "default_backend", "get_balancer", "get_binding", "get_sched",
    "hermes_score_np", "jax_select", "np_select", "register_balancer",
    "register_binding", "register_sched", "resolve", "sched_names",
    "unregister_balancer",
]
