"""Composable policy registry with multi-backend selection dispatch.

The paper's contribution is a *policy space* (§3.1): binding × load
balancing × worker scheduling.  This module makes that space an open
registry instead of a closed enum triple.  Each axis is a small protocol
carrying per-backend implementations:

* :class:`Balancer` — worker selection.  Backends: ``np`` (the numpy
  oracle used by :mod:`repro.core.sim_ref` and the serving platform),
  ``jax`` (jit/vmap-able, used inside the scan engine), and optionally
  ``pallas`` (the batched TPU controller kernel, e.g.
  :mod:`repro.kernels.hermes_select` for ``H``).
* :class:`SchedDef` — intra-worker rate assignment (PS / FCFS / SRPT).
  Backends: ``np`` (per-worker task lists) and ``jax`` (the ``[W, S]``
  slot matrix).
* :class:`BindingDef` — binding time.  Structural (the engines own the
  controller queue), so the registry only carries the ``late`` flag.

All selection backends implement ONE deterministic contract::

    select(active, warm_col, func, func_home, u, idx) -> worker | -1

where ``active`` is the per-worker active-invocation count ``[W]``,
``warm_col`` is ``warm[:, func]`` (idle warm executors of the arrival's
function per worker), ``func`` the function id, ``func_home`` the
locality hash table ``[F]``, ``u`` the pre-drawn per-arrival uniform,
and ``idx`` the arrival sequence number (round-robin state lives in the
workload, not the balancer — every backend stays pure).  ``-1`` means
every worker's slots are exhausted (the caller counts a rejection).

**Carried state.**  A balancer may declare ``init_state`` — a factory
``(n_workers, n_functions) -> dict[str, np.ndarray]`` — and then its
``make_np`` / ``make_jax`` / ``make_pallas`` factories return a
``(select, on_complete)`` *pair* implementing the stateful contract::

    select(state, active, warm_col, func, func_home, u, idx)
        -> (worker | -1, state)
    on_complete(state, worker, func, service, n_active_after) -> state

Both are pure (functional state updates, identical float/int semantics
on every backend); the engines thread the state through the vmapped
scan carry (:mod:`repro.core.simulator`), the numpy oracle's event loop
(:mod:`repro.core.sim_ref`) and the serving platform
(:mod:`repro.serving.engine`), calling ``on_complete`` once per task
completion (``service`` is the task's *observed* execution time
excluding any cold-start penalty — the oracle duration on a
homogeneous cluster, the speed-scaled effective duration under a
heterogeneous :mod:`repro.fleet` config, so throughput learners like
``SWARM`` see real wall-clock signal; ``n_active_after`` the worker's
remaining active-task count).  A rejected arrival (``-1``) must return its input
state unchanged.  Examples: ``HIKU`` (pull-based ready-ring) and ``DD``
(per-function execution-time EMAs) in :mod:`repro.policy.balancers`.

:func:`resolve` is the single entry point: it turns a
:class:`~repro.core.taxonomy.PolicySpec` (or ``"E/LL/PS"`` text) plus a
backend name plus a :class:`~repro.core.cluster.ClusterCfg` into ready
callables; the engines consume those and never branch on policy names.
:func:`register_balancer` / :func:`register_sched` are the extension
hooks — a new balancer becomes sweepable by every engine, benchmark and
CLI flag without touching any of them.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from functools import lru_cache
from typing import Any, Callable, Optional

_BACKENDS = ("np", "jax", "pallas")


def canonical_name(x) -> str:
    """Registry key of an axis value: enum member → value, else str."""
    if isinstance(x, enum.Enum):
        return str(x.value)
    return str(x)


@dataclasses.dataclass(frozen=True)
class Balancer:
    """A registered load balancer (worker selection strategy).

    ``make_np`` / ``make_jax`` / ``make_pallas`` are factories
    ``(cores, slots) -> select`` baking the cluster shape into a closure
    (the jax/pallas ones must return jit-traceable functions).
    ``make_batch`` optionally builds the batched controller dispatch
    ``(active [W], warm [W, F], funcs [N]) -> (choices [N], active_out)``
    — the one-HBM-read-per-arrival-batch form used by the serving
    platform and ``tab_overhead``.

    ``init_state`` marks the balancer *stateful* (carried-state
    contract, see the module docstring): a factory
    ``(n_workers, n_functions) -> dict[str, np.ndarray]`` returning a
    fresh state pytree, with the ``make_*`` factories then returning
    ``(select, on_complete)`` pairs instead of bare closures.
    """

    name: str
    doc: str = ""
    make_np: Optional[Callable[[int, int], Callable]] = None
    make_jax: Optional[Callable[[int, int], Callable]] = None
    make_pallas: Optional[Callable[[int, int], Callable]] = None
    make_batch: Optional[Callable[[int, int], Callable]] = None
    init_state: Optional[Callable[[int, int], Any]] = None

    @property
    def stateful(self) -> bool:
        return self.init_state is not None

    def backends(self) -> tuple[str, ...]:
        return tuple(b for b, fn in zip(
            _BACKENDS, (self.make_np, self.make_jax, self.make_pallas))
            if fn is not None)


@dataclasses.dataclass(frozen=True)
class SchedDef:
    """A registered intra-worker scheduler (rate assignment).

    ``make_np(cores) -> rates(remaining, seqs) -> list[float]`` assigns a
    core rate to each task of ONE worker (lists are parallel; ``seqs``
    are arrival sequence numbers, the FCFS key).  ``make_jax(cores) ->
    rates(task_idx, remaining) -> [W, S]`` does the same over the whole
    slot matrix (``task_idx < 0`` marks empty slots).
    """

    name: str
    doc: str = ""
    make_np: Optional[Callable[[int], Callable]] = None
    make_jax: Optional[Callable[[int], Callable]] = None


@dataclasses.dataclass(frozen=True)
class BindingDef:
    name: str
    late: bool
    doc: str = ""


BALANCERS: dict[str, Balancer] = {}
SCHEDS: dict[str, SchedDef] = {}
BINDINGS: dict[str, BindingDef] = {}

_builtin_lock = threading.Lock()
_builtins_loaded = False


def _load_builtins() -> None:
    """Idempotently register the built-in axes (import side effect)."""
    global _builtins_loaded
    if _builtins_loaded:
        return
    with _builtin_lock:
        if _builtins_loaded:
            return
        if "E" not in BINDINGS:
            register_binding("E", late=False,
                             doc="early: dispatch on arrival, queue at "
                                 "workers")
            register_binding("L", late=True,
                             doc="late: queue at the controller until a "
                                 "core frees")
        from . import balancers, scheds  # noqa: F401  (register on import)
        _builtins_loaded = True


# --------------------------------------------------------------------------
# Registration hooks
# --------------------------------------------------------------------------

def register_balancer(name: str, *, make_np=None, make_jax=None,
                      make_pallas=None, make_batch=None, init_state=None,
                      doc: str = "", overwrite: bool = False) -> Balancer:
    """Register a load balancer under ``name`` (upper-cased).

    At least one of ``make_np`` / ``make_jax`` must be given; a balancer
    with both is sweepable by every engine in the repo.  ``init_state``
    opts into the carried-state contract (see the module docstring) —
    the ``make_*`` factories must then return ``(select, on_complete)``
    pairs.  Returns the :class:`Balancer` record.
    """
    name = name.strip().upper()
    if "/" in name or "*" in name or not name:
        raise ValueError(f"invalid balancer name {name!r}")
    if make_np is None and make_jax is None:
        raise ValueError(f"balancer {name!r} needs an np or jax backend")
    if not overwrite and name in BALANCERS:
        raise ValueError(f"balancer {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    bal = Balancer(name=name, doc=doc, make_np=make_np, make_jax=make_jax,
                   make_pallas=make_pallas, make_batch=make_batch,
                   init_state=init_state)
    BALANCERS[name] = bal
    _factory_cache_clear()
    return bal


def unregister_balancer(name: str) -> None:
    BALANCERS.pop(canonical_name(name).upper(), None)
    _factory_cache_clear()


def register_sched(name: str, *, make_np=None, make_jax=None, doc: str = "",
                   overwrite: bool = False) -> SchedDef:
    name = name.strip().upper()
    if "/" in name or "*" in name or not name:
        raise ValueError(f"invalid sched name {name!r}")
    if not overwrite and name in SCHEDS:
        raise ValueError(f"sched {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    sd = SchedDef(name=name, doc=doc, make_np=make_np, make_jax=make_jax)
    SCHEDS[name] = sd
    _factory_cache_clear()
    return sd


def register_binding(name: str, *, late: bool, doc: str = "",
                     overwrite: bool = False) -> BindingDef:
    name = name.strip().upper()
    if not overwrite and name in BINDINGS:
        raise ValueError(f"binding {name!r} already registered")
    bd = BindingDef(name=name, late=late, doc=doc)
    BINDINGS[name] = bd
    return bd


def balancer_names() -> tuple[str, ...]:
    _load_builtins()
    return tuple(BALANCERS)


def sched_names() -> tuple[str, ...]:
    _load_builtins()
    return tuple(SCHEDS)


def binding_names() -> tuple[str, ...]:
    _load_builtins()
    return tuple(BINDINGS)


def get_balancer(name) -> Balancer:
    _load_builtins()
    key = canonical_name(name).upper()
    try:
        return BALANCERS[key]
    except KeyError:
        raise ValueError(
            f"unknown load balancer {key!r}; registered balancers: "
            f"{', '.join(sorted(BALANCERS))}") from None


def get_sched(name) -> SchedDef:
    _load_builtins()
    key = canonical_name(name).upper()
    try:
        return SCHEDS[key]
    except KeyError:
        raise ValueError(
            f"unknown worker scheduler {key!r}; registered schedulers: "
            f"{', '.join(sorted(SCHEDS))}") from None


def get_binding(name) -> BindingDef:
    _load_builtins()
    key = canonical_name(name).upper()
    try:
        return BINDINGS[key]
    except KeyError:
        raise ValueError(
            f"unknown binding {key!r}; registered bindings: "
            f"{', '.join(sorted(BINDINGS))}") from None


# --------------------------------------------------------------------------
# Cached factory instantiation (one closure per (axis, cores, slots))
# --------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _np_select(name: str, cores: int, slots: int):
    bal = get_balancer(name)
    if bal.make_np is None:
        raise ValueError(f"balancer {name!r} has no np backend "
                         f"(has: {bal.backends()})")
    return bal.make_np(cores, slots)


@lru_cache(maxsize=None)
def _jax_select(name: str, cores: int, slots: int):
    bal = get_balancer(name)
    if bal.make_jax is None:
        raise ValueError(f"balancer {name!r} has no jax backend "
                         f"(has: {bal.backends()})")
    return bal.make_jax(cores, slots)


@lru_cache(maxsize=None)
def _pallas_select(name: str, cores: int, slots: int):
    bal = get_balancer(name)
    if bal.make_pallas is not None:
        return bal.make_pallas(cores, slots)
    # graceful degradation: balancers without a kernel run their jax
    # implementation under the pallas backend so whole-space sweeps with
    # backend="pallas" stay valid
    return _jax_select(name, cores, slots)


@lru_cache(maxsize=None)
def _np_rates(name: str, cores: int):
    sd = get_sched(name)
    if sd.make_np is None:
        raise ValueError(f"sched {name!r} has no np backend")
    return sd.make_np(cores)


@lru_cache(maxsize=None)
def _jax_rates(name: str, cores: int):
    sd = get_sched(name)
    if sd.make_jax is None:
        raise ValueError(f"sched {name!r} has no jax backend")
    return sd.make_jax(cores)


def _factory_cache_clear() -> None:
    for c in (_np_select, _jax_select, _pallas_select, _np_rates,
              _jax_rates):
        c.cache_clear()
    # compiled simulator engines capture resolved closures, so a
    # (re-)registration must also drop them — the engine cache keys on
    # policy *names*, which an overwrite silently rebinds.  getattr
    # guards the builtin registrations that fire while the simulator
    # module itself is still mid-import (no engines exist yet then).
    import sys
    sim = sys.modules.get("repro.core.simulator")
    clear = getattr(sim, "clear_engine_cache", None)
    if clear is not None:
        clear()


def np_select(balancer, cores: int, slots: int):
    """The numpy-backend select closure for ``balancer`` (cached).

    For a stateful balancer this is the raw factory product — a
    ``(select, on_complete)`` pair; prefer :func:`resolve`, which
    unpacks it.
    """
    return _np_select(canonical_name(balancer).upper(), int(cores),
                      int(slots))


def jax_select(balancer, cores: int, slots: int):
    """The jax-backend select closure for ``balancer`` (cached).

    Stateful balancers yield a ``(select, on_complete)`` pair — see
    :func:`np_select`.
    """
    return _jax_select(canonical_name(balancer).upper(), int(cores),
                       int(slots))


# --------------------------------------------------------------------------
# resolve — the single policy → callables entry point
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ResolvedPolicy:
    """A policy resolved against one backend and one cluster shape.

    ``select``/``rates`` are ``None`` for late binding (the controller
    queue is structural — engines place on ``argmin(active)`` and run
    dispatched tasks at rate 1, exactly the paper's model).
    ``batch_select`` is the batched controller dispatch when the
    balancer ships one (today: the ``H`` Pallas kernel), else ``None``.

    For a stateful balancer (:attr:`stateful` true), ``select`` follows
    the carried-state contract ``(state, ...) -> (worker, state)``,
    ``init_state`` builds a fresh state pytree ``(W, F) -> dict`` and
    ``on_complete`` is the per-completion update hook; all three are
    ``None``/stateless otherwise.
    """

    spec: Any                      # PolicySpec
    backend: str                   # "np" | "jax" | "pallas"
    late: bool
    select: Optional[Callable]
    rates: Optional[Callable]
    batch_select: Optional[Callable]
    balancer: Optional[Balancer]
    sched: Optional[SchedDef]
    init_state: Optional[Callable] = None
    on_complete: Optional[Callable] = None

    @property
    def stateful(self) -> bool:
        return self.init_state is not None


def default_backend(policy) -> str:
    """The backend ``resolve(..., backend="auto")`` picks for ``policy``.

    Early-binding policies whose balancer ships a Pallas kernel dispatch
    through it (closing the ROADMAP kernel-batch-path item for ``H``) —
    in the batched engine the replication axis amortizes the kernel
    dispatch; the single-workload engine uses the same backend so the
    two stay bit-identical by construction.  Everything else uses the
    pure-jax path.
    """
    _load_builtins()
    spec = _as_spec(policy)
    if get_binding(spec.binding).late:
        return "jax"
    bal = get_balancer(spec.balance)
    return "pallas" if bal.make_pallas is not None else "jax"


def _as_spec(policy):
    if isinstance(policy, str):
        from ..core.taxonomy import parse_policy
        return parse_policy(policy)
    return policy


def resolve(policy, backend: str = "np", cluster=None) -> ResolvedPolicy:
    """Resolve ``policy`` into backend callables for ``cluster``.

    ``policy`` is a :class:`~repro.core.taxonomy.PolicySpec` or
    ``"T/LB/S"`` text; ``backend`` is ``"np"``, ``"jax"``, ``"pallas"``
    or ``"auto"`` (see :func:`default_backend`); ``cluster`` supplies
    ``cores``/``slots``.  Raises a named ``ValueError`` for unknown axis
    names, listing what IS registered.
    """
    _load_builtins()
    spec = _as_spec(policy)
    if cluster is None:
        raise ValueError("resolve() needs a cluster (cores/slots source)")
    if hasattr(cluster, "validate"):
        cluster.validate()   # named errors at the API boundary
    C, S = int(cluster.cores), int(cluster.slots)
    binding = get_binding(spec.binding)
    if backend == "auto":
        backend = default_backend(spec)
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choose from "
                         f"{_BACKENDS} or 'auto'")
    if binding.late:
        return ResolvedPolicy(spec=spec, backend=backend, late=True,
                              select=None, rates=None, batch_select=None,
                              balancer=None, sched=None)
    bal = get_balancer(spec.balance)
    sched = get_sched(spec.sched)
    bname = bal.name
    if backend == "np":
        select = _np_select(bname, C, S)
        rates = _np_rates(sched.name, C)
    elif backend == "jax":
        select = _jax_select(bname, C, S)
        rates = _jax_rates(sched.name, C)
    else:  # pallas
        select = _pallas_select(bname, C, S)
        rates = _jax_rates(sched.name, C)
    on_complete = None
    if bal.stateful:
        # stateful factories return (select, on_complete) pairs
        select, on_complete = select
    batch = bal.make_batch(C, S) if bal.make_batch is not None else None
    return ResolvedPolicy(spec=spec, backend=backend, late=binding.late,
                          select=select, rates=rates, batch_select=batch,
                          balancer=bal, sched=sched,
                          init_state=bal.init_state,
                          on_complete=on_complete)
