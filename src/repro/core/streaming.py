"""Horizon-scale streaming front end over the chunked scan engine.

:func:`simulate_stream` runs the same discrete-event program as
:func:`repro.core.simulate_many`, but over fixed-size *chunks* of
arrivals instead of one monolithic ``lax.scan``:

* one compiled per-chunk scan (see ``_build_engine(..., stream=True)``)
  whose full carry — :class:`~repro.core.simulator.SimState` with the
  lifecycle pools, balancer state, telemetry sketches and fleet /
  autoscaler planes — is handed across segment boundaries with
  ``jax.jit(..., donate_argnums=(0,))`` buffer donation;
* no ``(N,)``-sized array anywhere on the long path: per-arrival
  outputs stream out through the scan ``ys`` (and are discarded unless
  ``collect_outputs=True``), metrics accumulate online in the
  :mod:`repro.telemetry` histogram sketches plus the exact counters in
  ``SimState.stream``;
* device memory and compile cost are both horizon-independent — the
  engine-cache key carries the chunk size, not ``N``, so growing the
  horizon reuses one compiled program per (policy, cluster, chunk).

Because every chunk step executes the *same ops* the monolithic scan
executes at that arrival (one shared ``early_arrival`` body), the final
carry and all pooled metrics are **bit-equal** to the monolithic engine
— gated per segment by ``benchmarks/fig14_stream.py`` against both the
monolithic scan and the numpy oracle's chunked replay
(:func:`repro.core.sim_ref.simulate_ref_chunks`).

The replication axis can additionally be sharded across devices: pass a
1-D mesh (see :func:`repro.launch.mesh.make_rep_mesh`) and the carry +
per-chunk inputs are placed with a ``NamedSharding`` over the leading
axis (:mod:`repro.distribution.sim_shard`), so policy sweeps scale with
device count.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.telemetry.spans import get_tracer
from repro.telemetry.state import (TelemetryCfg, TelemetryResult,
                                   warmup_cutoff)
from repro.telemetry.timeline import TimelineCfg, TimelineResult

from .cluster import ClusterCfg
from .simulator import SimState, _get_stream_engine, _prov_core_s
from .taxonomy import PolicySpec
from .workload import Workload, WorkloadBatch, stack_workloads

#: SimState planes that exist in only one of the two engines — excluded
#: from the bit-equality contract (everything else must match bitwise).
_MODE_ONLY_PLANES = frozenset({
    "q", "resp", "cold", "rejected", "worker_of",   # monolithic (N,)
    "task_fn", "task_svc", "stream",                # stream mirrors
})


@dataclasses.dataclass(frozen=True)
class StreamOutput:
    """Results of a chunked streaming run (leading axis ``R``).

    Unlike :class:`~repro.core.simulator.BatchSimOutput` there are no
    per-task arrays by default — percentiles come from the telemetry
    sketches, means from the exact online counters.  Pass
    ``collect_outputs=True`` (small-N parity checks only) to also get
    the per-arrival ``cold``/``rejected``/``worker`` planes.
    """

    #: pooled streaming metrics (histogram sketches, counters,
    #: occupancy integrals) — the percentile source at full horizon
    telemetry: TelemetryResult
    n_done: np.ndarray       # [R] i64 — completions over the horizon
    n_observed: np.ndarray   # [R] i64 — post-warmup completions
    resp_mean: np.ndarray    # [R] f64 — exact mean post-warmup response
    slow_mean: np.ndarray    # [R] f64 — exact mean post-warmup slowdown
    server_time: np.ndarray  # [R] f64
    core_time: np.ndarray    # [R] f64
    end_time: np.ndarray     # [R] f64
    prov_core_s: np.ndarray  # [R] f64
    n_arrivals: int
    chunk_size: int
    n_chunks: int
    #: per-arrival planes ([R, N]; None unless ``collect_outputs``)
    cold: np.ndarray | None = None
    rejected: np.ndarray | None = None
    worker: np.ndarray | None = None
    #: the post-drain device carry (None unless ``keep_final_state``;
    #: used by the bit-equality REPRO-CHECK gates)
    final_state: SimState | None = None
    #: windowed flight recorder ([R, ...] planes; None unless
    #: ``timeline=`` was passed) — fixed-shape virtual-time windows, so
    #: it rides the carry across chunk boundaries and is bit-equal to
    #: the monolithic engine's for any chunk size
    timeline: TimelineResult | None = None

    @property
    def n_reps(self) -> int:
        return int(self.n_done.shape[0])


def simulate_stream(policy: PolicySpec, cluster: ClusterCfg,
                    workloads, *, chunk_size: int,
                    backend: str = "auto",
                    telemetry: TelemetryCfg | None = None,
                    timeline: TimelineCfg | None = None,
                    collect_outputs: bool = False,
                    mesh=None,
                    keep_final_state: bool = False,
                    chunk_callback: Callable[[int, SimState], None]
                    | None = None) -> StreamOutput:
    """Run stacked replications through the chunked streaming engine.

    ``workloads`` is a single :class:`Workload`, a sequence of them, or
    a :class:`WorkloadBatch`.  ``chunk_size`` fixes the compiled scan
    length; results are bit-equal to :func:`simulate_many` for *any*
    chunk size (including sizes that do not divide ``N`` — the last
    chunk is padded with masked steps).  ``telemetry`` defaults to an
    enabled :class:`TelemetryCfg`: the streaming path reports
    percentiles from sketches, so it cannot run blind.

    ``mesh`` (a 1-D device mesh, see
    :func:`repro.launch.mesh.make_rep_mesh`) shards the replication
    axis across devices; the rep count must divide the mesh size.

    ``chunk_callback(chunk_idx, carry)`` observes the carry after each
    segment (the per-segment parity hook).  The *next* chunk dispatch
    donates that carry's buffers — callbacks must ``np.asarray`` any
    leaf they keep.
    """
    if isinstance(workloads, Workload):
        workloads = [workloads]
    wb = workloads if isinstance(workloads, WorkloadBatch) \
        else stack_workloads(workloads)
    if telemetry is None:
        telemetry = TelemetryCfg()
    k = int(chunk_size)
    N, F, R = wb.n, wb.n_functions, wb.n_reps
    (init, step_fn, drain_fn), fresh = _get_stream_engine(
        policy, cluster, k, F, backend, telemetry, timeline)
    cutoff = warmup_cutoff(N, telemetry)
    # the runtime window width is horizon-dependent (auto = horizon/K),
    # so it is computed host-side per replication and written into the
    # carry — one f64 division with the same operands as the monolithic
    # engine's in-trace arrivals[N-1]/K, hence bitwise identical
    window_s = None
    if timeline is not None:
        if float(timeline.window_s) > 0.0:
            window_s = np.full(R, float(timeline.window_s),
                               dtype=np.float64)
        else:
            window_s = np.asarray(wb.arrival[:, -1], dtype=np.float64) \
                / np.float64(int(timeline.n_windows))
    n_chunks = -(-N // k)
    pad = n_chunks * k - N

    def pad_tail(a, mode):
        a = np.asarray(a)
        if pad == 0:
            return a
        tail = np.repeat(a[:, -1:], pad, axis=1) if mode == "edge" \
            else np.zeros((R, pad), dtype=a.dtype)
        return np.concatenate([a, tail], axis=1)

    # padded tail steps are skipped via the valid mask; arrival times
    # pad with the last arrival so even the (dead) skip branch sees a
    # non-decreasing clock
    arr = pad_tail(wb.arrival, "edge")
    fns = pad_tail(wb.func, "zero")
    svcs = pad_tail(wb.service, "zero")
    us = pad_tail(wb.u_lb, "zero")
    gids = np.arange(n_chunks * k, dtype=np.int64)
    valid = gids < N
    homes = jnp.asarray(wb.func_home)

    shard = None
    if mesh is not None:
        from repro.distribution.sim_shard import shard_reps
        shard = lambda tree: shard_reps(tree, mesh)
        homes = shard(homes)

    st = init(R, cutoff, window_s)
    if shard is not None:
        st = shard(st)
    outs: list[tuple] = []
    tr = get_tracer()
    with tr.span("engine.first_run" if fresh else "engine.run",
                 policy=str(policy), backend=backend, n=N, reps=R,
                 chunk=k, chunks=n_chunks):
        for c in range(n_chunks):
            sl = slice(c * k, (c + 1) * k)
            ins = (jnp.asarray(arr[:, sl]), jnp.asarray(fns[:, sl]),
                   jnp.asarray(svcs[:, sl]), jnp.asarray(us[:, sl]))
            if shard is not None:
                ins = shard(ins)
            st, ys = step_fn(st, jnp.asarray(gids[sl]),
                             jnp.asarray(valid[sl]),
                             ins[0], ins[1], ins[2], ins[3], homes)
            if collect_outputs:
                outs.append(tuple(np.asarray(y) for y in ys))
            if chunk_callback is not None:
                chunk_callback(c, st)
        st = drain_fn(st)
        st = jax.block_until_ready(st)

    sc = jax.tree_util.tree_map(np.asarray, st.stream)
    denom = np.maximum(sc["n_obs"], 1).astype(np.float64)
    cold = rej = wkr = None
    if collect_outputs:
        rej = np.concatenate([o[0] for o in outs], axis=1)[:, :N]
        cold = np.concatenate([o[1] for o in outs], axis=1)[:, :N]
        wkr = np.concatenate([o[2] for o in outs], axis=1)[:, :N]
    return StreamOutput(
        telemetry=TelemetryResult.from_state(
            jax.tree_util.tree_map(np.asarray, st.tel), cfg=telemetry),
        n_done=sc["n_done"], n_observed=sc["n_obs"],
        resp_mean=sc["resp_sum"] / denom,
        slow_mean=sc["slow_sum"] / denom,
        server_time=np.asarray(st.server_time),
        core_time=np.asarray(st.core_time),
        end_time=np.asarray(st.now),
        prov_core_s=np.asarray(_prov_core_s(st, cluster),
                               dtype=np.float64),
        n_arrivals=N, chunk_size=k, n_chunks=n_chunks,
        cold=cold, rejected=rej, worker=wkr,
        final_state=st if keep_final_state else None,
        timeline=None if timeline is None else TimelineResult.from_state(
            jax.tree_util.tree_map(np.asarray, st.tl), cfg=timeline))


def final_states_equal(a: SimState, b: SimState
                       ) -> tuple[bool, list[str]]:
    """Bitwise comparison of the carry planes both engines share.

    The monolithic-only ``(N,)`` planes and the stream-only slot
    mirrors/counters are skipped; everything else — slot matrices,
    warm pools, clocks, time integrals and the full lb/life/tel/fleet
    pytrees — must match bit for bit (``NaN`` compares equal to
    itself).  Returns ``(ok, mismatched plane names)``.
    """
    bad: list[str] = []
    for name in SimState._fields:
        if name in _MODE_ONLY_PLANES:
            continue
        la, ta = jax.tree_util.tree_flatten(getattr(a, name))
        lb, tb = jax.tree_util.tree_flatten(getattr(b, name))
        if ta != tb:
            bad.append(f"{name} (tree structure)")
            continue
        for i, (u, v) in enumerate(zip(la, lb)):
            u, v = np.asarray(u), np.asarray(v)
            eq = (u.shape == v.shape and u.dtype == v.dtype)
            if eq:
                eq = np.array_equal(u, v) or (
                    np.issubdtype(u.dtype, np.floating)
                    and np.array_equal(u, v, equal_nan=True))
            if not eq:
                bad.append(name if len(la) == 1 else f"{name}[{i}]")
    return (not bad, bad)
