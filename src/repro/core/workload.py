"""Workload generation for the scheduling study (paper §3.2, §6.1).

All workloads are open-loop (arrivals are independent of completions —
Treadmill/Schroeder guidance followed by the paper) with tunable
inter-arrival and execution-time distributions:

* **Execution times** — heavy-tailed Log-normal with ``μ=-0.38, σ=2.36``
  matching the Azure Functions trace (median 0.6 s ≈ e^-0.38 ≈ 0.68 s,
  p99 > 140 s), or light-tailed exponential for the robustness study.
* **Arrivals** — Poisson with rate ``λ = load × total_cores / E[service]``
  so ``load`` is the offered fraction of cluster compute capacity.
* **Skew** — invocations belong to ``n_functions`` distinct functions; one
  "hot" function contributes ``hot_fraction`` of the load, the rest share
  the remainder equally (0.98 in the §3 simulations, 0.90 in the §6
  "MS Representative" workload, 1/n for the balanced workload).

Generation happens host-side in numpy float64 (event times need the
precision); the simulator consumes the arrays directly.  Per-arrival
uniform randoms ``u_lb`` are pre-drawn so the JAX simulator and the numpy
oracle consume *identical* randomness and can be compared task-by-task.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .cluster import ClusterCfg

# Azure trace Log-normal parameters (paper Fig. 2 caption).
AZURE_MU = -0.38
AZURE_SIGMA = 2.36


def lognormal_mean(mu: float = AZURE_MU, sigma: float = AZURE_SIGMA) -> float:
    return math.exp(mu + sigma * sigma / 2.0)


@dataclasses.dataclass(frozen=True)
class Workload:
    """A concrete trace of function invocations, sorted by arrival time."""

    arrival: np.ndarray     # (N,) float64, seconds, non-decreasing
    func: np.ndarray        # (N,) int32 function id in [0, n_functions)
    service: np.ndarray     # (N,) float64 execution time, seconds
    u_lb: np.ndarray        # (N,) float64 uniform(0,1) — LB randomness
    func_home: np.ndarray   # (F,) int32 sticky-hash home worker (LOC)
    n_functions: int
    load: float             # offered load as fraction of cluster capacity
    name: str = "workload"

    @property
    def n(self) -> int:
        return int(self.arrival.shape[0])

    @property
    def horizon(self) -> float:
        return float(self.arrival[-1]) if self.n else 0.0


@dataclasses.dataclass(frozen=True)
class WorkloadBatch:
    """``R`` stacked workload replications sharing one ``(N, F)`` shape.

    The replication axis is the batch axis of the vmapped simulator
    (:func:`repro.core.simulator.simulate_many`): replications may differ
    in seed and offered load (arrival-rate scale) but must agree on the
    number of arrivals and functions so they map onto one compiled program.
    """

    arrival: np.ndarray     # (R, N) float64
    func: np.ndarray        # (R, N) int32
    service: np.ndarray     # (R, N) float64
    u_lb: np.ndarray        # (R, N) float64
    func_home: np.ndarray   # (R, F) int32
    n_functions: int
    loads: tuple            # (R,) offered load per replication
    names: tuple            # (R,) workload names

    @property
    def n_reps(self) -> int:
        return int(self.arrival.shape[0])

    @property
    def n(self) -> int:
        return int(self.arrival.shape[1])

    def rep(self, r: int) -> Workload:
        """The ``r``-th replication as a plain :class:`Workload`."""
        return Workload(
            arrival=self.arrival[r], func=self.func[r],
            service=self.service[r], u_lb=self.u_lb[r],
            func_home=self.func_home[r], n_functions=self.n_functions,
            load=self.loads[r], name=self.names[r])

    def __getitem__(self, sl: slice) -> "WorkloadBatch":
        """A sub-batch over a slice of the replication axis."""
        return WorkloadBatch(
            arrival=self.arrival[sl], func=self.func[sl],
            service=self.service[sl], u_lb=self.u_lb[sl],
            func_home=self.func_home[sl], n_functions=self.n_functions,
            loads=self.loads[sl], names=self.names[sl])


def validate_workload(wl: Workload) -> None:
    """Check a workload's internal shape consistency; raise ``ValueError``.

    Catches malformed hand-built workloads (the trace-replay and test
    paths construct ``Workload`` directly) *before* they reach
    ``np.stack`` / the simulator, where they would surface as opaque
    broadcast errors.
    """
    n = wl.arrival.shape[0] if wl.arrival.ndim == 1 else -1
    for field in ("arrival", "func", "service", "u_lb"):
        a = getattr(wl, field)
        if a.ndim != 1 or a.shape[0] != n:
            raise ValueError(
                f"workload {wl.name!r}: {field} must be 1-D of length "
                f"{max(n, 0)} (matching arrival); got shape {a.shape}")
    if wl.func_home.ndim != 1 or wl.func_home.shape[0] != wl.n_functions:
        raise ValueError(
            f"workload {wl.name!r}: func_home must be 1-D of length "
            f"n_functions={wl.n_functions}; got shape {wl.func_home.shape}")
    if n and (int(wl.func.min()) < 0
              or int(wl.func.max()) >= wl.n_functions):
        raise ValueError(
            f"workload {wl.name!r}: func ids must lie in "
            f"[0, {wl.n_functions}); got range "
            f"[{int(wl.func.min())}, {int(wl.func.max())}]")
    if n > 1 and not (np.diff(wl.arrival) >= 0).all():
        raise ValueError(
            f"workload {wl.name!r}: arrival times must be "
            f"non-decreasing (the simulators scan arrivals in order)")


def stack_workloads(wls) -> WorkloadBatch:
    """Stack workloads with a shared ``(N, F)`` shape into a batch.

    Every workload is validated (:func:`validate_workload`) and checked
    for ``(N, F)`` agreement up front, so mismatches raise a named
    ``ValueError`` here rather than a numpy broadcast error downstream.
    """
    wls = list(wls)
    if not wls:
        raise ValueError("stack_workloads needs at least one workload")
    for wl in wls:
        validate_workload(wl)
    n, f = wls[0].n, wls[0].n_functions
    for wl in wls[1:]:
        if wl.n != n or wl.n_functions != f:
            raise ValueError(
                f"all replications must share (N, F)=({n}, {f}); got "
                f"({wl.n}, {wl.n_functions}) for {wl.name!r}")
    return WorkloadBatch(
        arrival=np.stack([wl.arrival for wl in wls]),
        func=np.stack([wl.func for wl in wls]),
        service=np.stack([wl.service for wl in wls]),
        u_lb=np.stack([wl.u_lb for wl in wls]),
        func_home=np.stack([wl.func_home for wl in wls]),
        n_functions=f,
        loads=tuple(wl.load for wl in wls),
        names=tuple(wl.name for wl in wls))


def replicate_workload(workload_fn, cluster: ClusterCfg, loads, n_arrivals,
                       *, seeds=(0,)) -> WorkloadBatch:
    """Generate the ``loads × seeds`` grid of replications as one batch.

    ``workload_fn`` is any of the §6.1 generators below (signature
    ``(cluster, load, n, seed) -> Workload``).  Replication order is
    load-major: ``[(l0, s0), (l0, s1), ..., (l1, s0), ...]`` — one
    :func:`~repro.core.simulator.simulate_many` call then sweeps the whole
    grid through a single compiled program.
    """
    return stack_workloads(
        workload_fn(cluster, load, n_arrivals, seed)
        for load in loads for seed in seeds)


def _function_mix(rng: np.random.Generator, n: int, n_functions: int,
                  hot_fraction: float) -> np.ndarray:
    """Draw per-invocation function ids with a single hot function."""
    if n_functions == 1:
        return np.zeros(n, dtype=np.int32)
    p = np.full(n_functions, (1.0 - hot_fraction) / (n_functions - 1))
    p[0] = hot_fraction
    return rng.choice(n_functions, size=n, p=p).astype(np.int32)


def synth_workload(
    cluster: ClusterCfg,
    load: float,
    n_arrivals: int,
    *,
    n_functions: int = 50,
    hot_fraction: float = 0.98,
    exec_dist: str = "lognormal",
    mu: float = AZURE_MU,
    sigma: float = AZURE_SIGMA,
    exp_mean: float | None = None,
    max_service: float = 600.0,
    seed: int = 0,
    name: str | None = None,
) -> Workload:
    """Generate a synthetic workload in the paper's style.

    ``exec_dist`` is ``"lognormal"`` (Azure-shaped, default) or
    ``"exponential"`` (the §6.5 Homogeneous-Execution-Times workload).
    ``max_service`` truncates execution times at the platform timeout —
    Azure Functions kills executions at a configurable bound (10 min by
    default), and the released trace's durations are bounded the same
    way; without the cap a σ=2.36 Log-normal's offered load is dominated
    by a handful of never-finishing giants and finite-horizon load is
    ill-defined.
    """
    rng = np.random.default_rng(seed)
    if exec_dist == "lognormal":
        service = rng.lognormal(mean=mu, sigma=sigma, size=n_arrivals)
        service = np.minimum(service, max_service)
    elif exec_dist == "exponential":
        m = exp_mean if exp_mean is not None else lognormal_mean(mu, sigma)
        service = rng.exponential(scale=m, size=n_arrivals)
    else:
        raise ValueError(f"unknown exec_dist {exec_dist!r}")

    # Calibrate λ against the *empirical* mean of this trace: with
    # σ=2.36 the analytic Log-normal mean is dominated by the extreme
    # tail and finite traces would otherwise realize far less load than
    # requested ("scale the number of invocations to produce different
    # load levels", §6.1).
    mean_service = float(service.mean())
    lam = load * cluster.total_cores / mean_service  # arrivals per second
    inter = rng.exponential(scale=1.0 / lam, size=n_arrivals)
    arrival = np.cumsum(inter)

    func = _function_mix(rng, n_arrivals, n_functions, hot_fraction)
    u_lb = rng.uniform(size=n_arrivals)
    func_home = rng.integers(0, cluster.n_workers,
                             size=n_functions).astype(np.int32)
    return Workload(
        arrival=arrival.astype(np.float64),
        func=func,
        service=service.astype(np.float64),
        u_lb=u_lb,
        func_home=func_home,
        n_functions=n_functions,
        load=load,
        name=name or f"synth-{exec_dist}-load{load:.2f}",
    )


# --- The five evaluation workloads of §6.1, parameterized by load. ---

def ms_trace(cluster: ClusterCfg, load: float, n: int, seed: int = 0
             ) -> Workload:
    """Azure-trace-derived: 50 fns, extreme skew, Log-normal exec."""
    return synth_workload(cluster, load, n, n_functions=50,
                          hot_fraction=0.98, seed=seed, name="ms-trace")


def ms_representative(cluster: ClusterCfg, load: float, n: int, seed: int = 0
                      ) -> Workload:
    """Poisson arrivals, 1 fn = 90 % of load, 49 fns share 10 %."""
    return synth_workload(cluster, load, n, n_functions=50,
                          hot_fraction=0.90, seed=seed,
                          name="ms-representative")


def single_function(cluster: ClusterCfg, load: float, n: int, seed: int = 0
                    ) -> Workload:
    """All invocations belong to one function (analytics-style, max skew)."""
    return synth_workload(cluster, load, n, n_functions=1, hot_fraction=1.0,
                          seed=seed, name="single-function")


def multi_balanced(cluster: ClusterCfg, load: float, n: int, seed: int = 0
                   ) -> Workload:
    """50 functions, each contributing equally (zero skew)."""
    return synth_workload(cluster, load, n, n_functions=50,
                          hot_fraction=1.0 / 50, seed=seed,
                          name="multi-balanced")


def homogeneous_exec(cluster: ClusterCfg, load: float, n: int, seed: int = 0
                     ) -> Workload:
    """MS-trace skew but light-tailed exponential exec times (§6.5)."""
    return synth_workload(cluster, load, n, n_functions=50,
                          hot_fraction=0.98, exec_dist="exponential",
                          exp_mean=8.9, seed=seed, name="homogeneous-exec")


# Bimodal class means (seconds) — far enough apart that a per-function
# duration estimate is worth real scheduling information.
BIMODAL_SHORT_S = 0.3
BIMODAL_LONG_S = 12.0


def bimodal_exec(cluster: ClusterCfg, load: float, n: int, seed: int = 0,
                 *, n_functions: int = 20, sigma: float = 0.25) -> Workload:
    """Bimodal per-function durations: even fns short, odd fns long.

    Every function's durations are tightly clustered (Log-normal jitter
    ``sigma`` around its class mean), so the function id *predicts* the
    execution time — the regime where data-driven policies (Przybylski
    et al. 2021) pay off: ``DD`` learns the two modes from completions
    and balances expected work, while size-blind placement (``R``/``RR``)
    strands short invocations behind long ones.
    """
    rng = np.random.default_rng(seed)
    func = rng.integers(0, n_functions, size=n).astype(np.int32)
    base = np.where(func % 2 == 0, BIMODAL_SHORT_S, BIMODAL_LONG_S)
    service = base * rng.lognormal(mean=0.0, sigma=sigma, size=n)
    # λ calibrated against the realized mean, like synth_workload
    lam = load * cluster.total_cores / float(service.mean())
    arrival = np.cumsum(rng.exponential(scale=1.0 / lam, size=n))
    u_lb = rng.uniform(size=n)
    func_home = rng.integers(0, cluster.n_workers,
                             size=n_functions).astype(np.int32)
    return Workload(
        arrival=arrival.astype(np.float64), func=func,
        service=service.astype(np.float64), u_lb=u_lb,
        func_home=func_home, n_functions=n_functions, load=load,
        name="bimodal-exec")


WORKLOADS = {
    "ms-trace": ms_trace,
    "ms-representative": ms_representative,
    "single-function": single_function,
    "multi-balanced": multi_balanced,
    "homogeneous-exec": homogeneous_exec,
    "bimodal-exec": bimodal_exec,
}
