"""Vectorized JAX discrete-event simulator for serverless scheduling.

The paper (§3.2) uses a discrete-event simulator to sweep the scheduling
policy space.  A classical event-heap simulator is pointer-chasing and
branchy — the opposite of what TPU/vector hardware wants.  This engine
re-expresses the identical semantics (see :mod:`repro.core.sim_ref` for the
contract) as:

* an outer :func:`jax.lax.scan` over arrivals (the only true sequential
  dependency in the system),
* an inner bounded :func:`jax.lax.while_loop` that fast-forwards the
  cluster through completion events between two arrivals — rates are
  piecewise constant between completions, so each iteration advances to
  the next completion in closed form over the whole ``[W, S]`` slot matrix,
* branch-free load-balancing selection and rate assignment resolved from
  the policy registry (:func:`repro.policy.resolve`) — the engine never
  branches on policy names, so registered balancers/schedulers are
  sweepable without touching it.

Selection dispatches through a *backend*: ``"jax"`` (pure jit/vmap
closures) or ``"pallas"`` (the batched controller kernel — for ``H``
this is :mod:`repro.kernels.hermes_select`, in interpret mode off-TPU).
The default ``"auto"`` picks ``pallas`` whenever the policy's balancer
ships a kernel, so ``simulate_many(HERMES, ...)`` shares one kernel
dispatch across all stacked replications per arrival (the replication
axis becomes the kernel batch under ``vmap``).

Three entry points share the engine: :func:`simulate` runs one workload;
:func:`simulate_many` runs ``R`` stacked replications (seeds / arrival-rate
scales with a shared ``(N, F)`` shape) through a single :func:`jax.vmap`-ed
program; :func:`repro.core.streaming.simulate_stream` feeds the same
arrival/placement bodies chunk by chunk for horizons too long to scan
monolithically (bit-equal by construction — see that module).  Compiled
engines are memoized process-wide on ``(policy, cluster, N, F, batched,
backend, telemetry, chunk)`` (see :func:`_cache_key`; streaming keys
carry the chunk size where monolithic ones carry the horizon), so
policy × load sweeps compile each engine exactly once.

All event times are float64 (the simulator enables x64; model code in this
repo always pins explicit dtypes so this is safe process-wide).

State layout (``W`` workers × ``S`` slots):

==============  ========  =====================================
``remaining``   f64       remaining work; ``inf`` in empty slots
``task_arr``    f64       arrival time of the occupying task
``task_idx``    i32       arrival index (doubles as FCFS seq); -1 empty
``warm``        i32       ``[W, F+1]`` idle warm executors (+1 pad col)
``queue``       i32       late-binding FIFO ring of arrival indices
``life``        pytree    container-lifecycle carry (``()`` disabled)
==============  ========  =====================================

With ``cluster.lifecycle`` set (:mod:`repro.lifecycle`), the carry
additionally threads per-pool idle-since clocks ``[W, F+1]``,
per-function last-completion times, the active keep-alive windows and
the policy's histogram state through the scan — the same carried-state
pattern the balancer registry uses.  Warm pools are then masked by the
windows wherever they are read (*alive* pools reserve slots and feed
the LRU eviction + ``max_idle`` budget; *materialized* pools serve warm
hits), cold starts charge the per-function preset cost, and every
transition mirrors :class:`repro.lifecycle.LifecycleRuntime` op for op
so the np ≡ jax parity contract extends to lifecycle state.  With the
default ``lifecycle=None`` the traced program is exactly the
pre-lifecycle one.

With ``cluster.fleet`` set (:mod:`repro.fleet`), workers become
heterogeneous: every rate the scheduler assigns is scaled by the
worker's ``speed`` (service *work* stays nominal; fast workers drain
it faster), cold-start penalties scale the same way, and stateful
balancers observe *effective* (wall-clock-equivalent) execution times
so throughput learners like ``SWARM`` can infer the speed vector
online.  A non-``STATIC`` autoscale policy additionally threads an
active-worker count ``n_on`` through the carry: arrivals only place on
workers ``< n_on`` (the rest are masked slot-full at selection — the
balancer contract is untouched), the registered ``decide`` hook
grows/shrinks ``n_on`` against the telemetry slowdown sketch under a
cooldown, and a provisioned-time integral accumulates the
core-seconds the fleet actually held.  ``fleet=None`` — the default —
python-gates all of it away (bit-for-bit golden contract, like
``lifecycle`` and ``telemetry``).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import partial
from typing import Any, NamedTuple

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax import lax

from repro.fleet import STATIC as _AUTO_STATIC, resolve_fleet
from repro.lifecycle import resolve_lifecycle
from repro.policy import default_backend, resolve
from repro.telemetry import engine as tel_engine
from repro.telemetry import timeline_engine as tln_engine
from repro.telemetry.spans import get_tracer
from repro.telemetry.state import (TelemetryCfg, TelemetryResult,
                                   warmup_cutoff)
from repro.telemetry.timeline import (EV_AUTOSCALE, EV_MODE_FLIP,
                                      TimelineCfg, TimelineResult,
                                      validate_timeline)

from .cluster import ClusterCfg
from .taxonomy import LoadBalance, PolicySpec
from .workload import Workload, WorkloadBatch, stack_workloads

EPS = 1e-9
_BIG_TIME = 1e18


class SimState(NamedTuple):
    remaining: jax.Array   # [W, S] f64
    task_arr: jax.Array    # [W, S] f64
    task_idx: jax.Array    # [W, S] i32, -1 = empty
    warm: jax.Array        # [W, F+1] i32
    q: jax.Array           # [Q] i32 ring buffer (late binding)
    q_head: jax.Array      # i32
    q_tail: jax.Array      # i32
    now: jax.Array         # f64
    resp: jax.Array        # [N+1] f64 (last = scratch)
    cold: jax.Array        # [N+1] bool
    rejected: jax.Array    # [N+1] bool
    worker_of: jax.Array   # [N+1] i32
    server_time: jax.Array  # f64
    core_time: jax.Array    # f64
    lb: Any                 # balancer carried state (pytree; () stateless)
    life: Any               # lifecycle carried state (pytree; () disabled)
    tel: Any                # telemetry carried state (pytree; () disabled)
    fleet: Any              # autoscaler carried state (pytree; () disabled)
    # Streaming-engine planes (repro.core.streaming).  () in the
    # monolithic engine — empty pytree nodes, so the monolithic carry
    # structure (and traced program) is unchanged.  In stream mode the
    # (N,)-sized planes above (resp/cold/rejected/worker_of/q) are ()
    # instead, and completions read the occupant's function/service
    # from these per-slot mirrors so a chunk never needs to gather from
    # arrivals that entered the system in an earlier chunk.
    task_fn: Any = ()       # [W, S] i32: occupant's function id
    task_svc: Any = ()      # [W, S] f64: occupant's nominal service
    stream: Any = ()        # exact online counters dict (see streaming)
    # Windowed time-series flight recorder (repro.telemetry.timeline).
    # () when disabled; otherwise a dict of fixed-[K]-window planes
    # whose shapes never depend on the horizon, so the same carry hands
    # across streaming chunk boundaries unchanged.
    tl: Any = ()


@dataclasses.dataclass(frozen=True)
class SimOutput:
    response: np.ndarray
    cold: np.ndarray
    rejected: np.ndarray
    worker: np.ndarray
    server_time: float
    core_time: float
    end_time: float
    #: streaming in-engine metrics (None unless ``telemetry=`` was passed)
    telemetry: TelemetryResult | None = None
    #: provisioned core-seconds: the autoscaler's ``n_on × cores`` time
    #: integral, or ``end_time × total_cores`` for a fixed fleet
    prov_core_s: float = 0.0
    #: windowed flight-recorder planes (None unless ``timeline=`` passed)
    timeline: TimelineResult | None = None


@dataclasses.dataclass(frozen=True)
class BatchSimOutput:
    """Results of ``R`` stacked workload replications (leading axis R)."""

    response: np.ndarray     # [R, N] f64
    cold: np.ndarray         # [R, N] bool
    rejected: np.ndarray     # [R, N] bool
    worker: np.ndarray       # [R, N] i32
    server_time: np.ndarray  # [R] f64
    core_time: np.ndarray    # [R] f64
    end_time: np.ndarray     # [R] f64
    #: batched streaming metrics, leading axis R (None unless enabled)
    telemetry: TelemetryResult | None = None
    #: provisioned core-seconds per replication ([R] f64)
    prov_core_s: np.ndarray | None = None
    #: batched flight-recorder planes, leading axis R (None unless enabled)
    timeline: TimelineResult | None = None

    @property
    def n_reps(self) -> int:
        return int(self.response.shape[0])

    def rep(self, r: int) -> SimOutput:
        """The ``r``-th replication as a plain :class:`SimOutput`."""
        return SimOutput(
            response=self.response[r], cold=self.cold[r],
            rejected=self.rejected[r], worker=self.worker[r],
            server_time=float(self.server_time[r]),
            core_time=float(self.core_time[r]),
            end_time=float(self.end_time[r]),
            telemetry=None if self.telemetry is None
            else self.telemetry.rep(r),
            prov_core_s=0.0 if self.prov_core_s is None
            else float(self.prov_core_s[r]),
            timeline=None if self.timeline is None
            else self.timeline.rep(r))

    def __getitem__(self, sl: slice) -> "BatchSimOutput":
        """A sub-batch over a slice of the replication axis."""
        return BatchSimOutput(
            response=self.response[sl], cold=self.cold[sl],
            rejected=self.rejected[sl], worker=self.worker[sl],
            server_time=self.server_time[sl], core_time=self.core_time[sl],
            end_time=self.end_time[sl],
            telemetry=None if self.telemetry is None
            else self.telemetry[sl],
            prov_core_s=None if self.prov_core_s is None
            else self.prov_core_s[sl],
            timeline=None if self.timeline is None
            else self.timeline[sl])


def _build_engine(policy: PolicySpec, cluster: ClusterCfg,
                  n_arrivals: int, n_functions: int,
                  backend: str = "jax",
                  telemetry: TelemetryCfg | None = None,
                  timeline: TimelineCfg | None = None,
                  stream: bool = False):
    """Build the raw (un-jitted) scan engine for (policy, cluster, N, F).

    ``backend`` selects how worker selection dispatches (``"jax"`` or
    ``"pallas"``); rate assignment always uses the registry's jax
    closures.  The returned ``run(arrivals, funcs, services, u_lb,
    homes) -> SimState`` is pure and rank-polymorphic under
    :func:`jax.vmap`: mapping every argument over a leading replication
    axis yields the batched engine used by :func:`simulate_many`.

    ``telemetry`` opts the carry into streaming in-engine metrics
    (:mod:`repro.telemetry`): histogram sketches, cold/evict/reject
    counters and occupancy integrals updated inside the scan.
    ``tel_on`` python-gates every update exactly like ``life_on``, so
    the default ``telemetry=None`` traces the bit-identical
    pre-telemetry program (golden contract).

    ``stream=True`` builds the *chunk engine* used by
    :func:`repro.core.streaming.simulate_stream`: ``n_arrivals`` is the
    fixed chunk length (not the horizon), and instead of ``run`` the
    builder returns ``(init, run_chunk, run_drain)``:

    * ``init(n_reps, cutoff)`` — the initial batched carry (every leaf
      gains a leading ``R`` axis; ``cutoff`` is the global warmup
      index, carried so the compiled program is horizon-independent);
    * ``run_chunk(st, gids, valid, arrivals, funcs, services, u_lb,
      homes) -> (st, ys)`` — one compiled scan over a chunk of
      arrivals; ``gids`` are global arrival indices, ``valid`` masks
      tail padding (invalid steps are identity on the carry), ``ys``
      are the per-arrival ``(rejected, cold, worker)`` outputs;
    * ``run_drain(st) -> st`` — the end-of-horizon completion drain
      (the monolithic engine's post-scan tail).

    The stream carry holds no ``(N,)``-sized plane: per-arrival outputs
    leave through ``ys``, responses reach metrics only through the
    telemetry sketches and the exact online counters in
    ``SimState.stream``, and completions read the occupant's
    function/service from the ``task_fn``/``task_svc`` slot mirrors.
    Every op a chunk step executes on the carry is the same op the
    monolithic scan executes at that arrival, so the handoff is
    bit-exact (the REPRO-CHECK contract gated by ``benchmarks``).
    """
    W, C, S = cluster.n_workers, cluster.cores, cluster.slots
    F = n_functions
    N = n_arrivals
    Q = N  # late-binding controller queue can hold every arrival
    res = resolve(policy, backend=backend, cluster=cluster)
    late = res.late
    if stream and late:
        raise ValueError(
            f"streaming engine requires early binding — policy "
            f"{policy!r} uses late binding, whose controller queue "
            f"scales with the horizon; run it through simulate_many")
    penalty = float(cluster.cold_start_penalty)
    select = res.select        # None for late binding
    # carried-state balancers (init_state registered): select threads a
    # state pytree through the scan carry and on_complete updates it per
    # task completion (see repro.policy.registry)
    stateful = res.stateful and not late
    # container lifecycle (repro.lifecycle).  life_on gates every
    # lifecycle op at trace time, so the disabled default traces the
    # exact pre-lifecycle program (bit-for-bit golden contract).
    lres = resolve_lifecycle(cluster, backend="jax", n_functions=F)
    life_on = lres is not None
    if life_on:
        life_windows, life_observe = lres.windows, lres.observe
        life_max_idle = lres.max_idle
        life_costs = None if lres.cold_costs is None \
            else jnp.asarray(lres.cold_costs)
    # streaming telemetry (repro.telemetry).  tel_on gates every update
    # at trace time — telemetry=None traces the pre-telemetry program.
    tel_on = telemetry is not None
    if tel_on:
        tel_edges = tel_engine.edges_for_trace()
        if not stream:
            tel_cutoff = warmup_cutoff(N, telemetry)
        # stream mode: N is the chunk length, not the horizon — the
        # global warmup index rides in the carry (SimState.stream)
    # windowed time-series flight recorder (repro.telemetry.timeline).
    # tl_on python-gates every update exactly like tel_on — the default
    # timeline=None traces the bit-identical pre-timeline program.  The
    # plane is independent of tel_on (a timeline without run-aggregate
    # telemetry is valid; the autoscaler separately mandates telemetry).
    tl_on = timeline is not None
    if tl_on:
        validate_timeline(timeline)
        tl_edges = tel_engine.edges_for_trace()
        TL_K = int(timeline.n_windows)
        # static trace-time constants (conversions hoisted out of the
        # traced bodies — HOT001-clean)
        TL_CORES = float(C)
        TL_WS_CFG = float(timeline.window_s)
        # hybrid-balancer pack<->spread flips only exist for Hermes
        # under early binding (late binding has no balancer select)
        flip_on = (not late) and policy.balance == LoadBalance.HYBRID
    # heterogeneous fleet + autoscaling (repro.fleet).  fleet_on gates
    # the speed scaling, auto_on the active-worker control loop; the
    # disabled default traces the exact pre-fleet program.
    fres = resolve_fleet(cluster, backend="jax")
    fleet_on = fres is not None
    auto_on = fleet_on and fres.auto_on
    if fleet_on:
        speed_arr = jnp.asarray(fres.speeds)          # [W] f64
    if auto_on:
        if late:
            raise ValueError(
                f"autoscaler {fres.policy.name!r} requires early binding"
                f" — late binding has no per-worker placement to mask")
        if fres.policy.needs_telemetry and not tel_on:
            raise ValueError(
                f"autoscaler {fres.policy.name!r} reads the telemetry "
                f"slowdown sketch as its sensor; pass telemetry="
                f"TelemetryCfg() to the simulator")
        auto_decide = fres.decide
        auto_cool = float(fres.cfg.cooldown_s)

    def rates_of(st: SimState) -> jax.Array:
        active = st.task_idx >= 0
        if late:
            r = active.astype(jnp.float64)
        else:
            r = res.rates(st.task_idx, st.remaining)
        if fleet_on:
            # worker speed multiplies every scheduler-assigned rate:
            # service *work* stays nominal, fast workers drain it faster
            r = r * speed_arr[:, None]
        return r

    def place(st: SimState, tid, w, f, svc_nom, t_arr):
        """Place arrival ``tid`` (fn ``f``, nominal service ``svc_nom``,
        arrival time ``t_arr``) on worker ``w`` (must be valid).

        Returns the new state; in stream mode ``(state, is_cold)`` —
        the cold flag leaves through the scan ``ys`` instead of the
        dropped ``(N,)`` cold plane.
        """
        active_w = (st.task_idx[w] >= 0).sum()
        life = st.life
        if life_on:
            # lifecycle masks (mirroring LifecycleRuntime): only
            # *materialized* pools (inside their pre-warm + keep-alive
            # window) serve warm hits, occupy memory (slot pressure /
            # budget) and are eviction candidates.  The victim is the
            # LRU materialized pool — oldest idle-since, first index on
            # ties, the tie-breaking contract shared with the oracle
            lu = life["idle_since"]
            pre, keep = life["pre"], life["keep"]
            ages_w = st.now - lu[w, :F]
            mat_w = (ages_w >= pre) & (ages_w <= pre + keep)
            eff = jnp.where(mat_w, st.warm[w, :F], 0)
            warm_cnt = eff[f]
            is_cold = warm_cnt == 0
            idle = eff.sum()
            need_evict = is_cold & (active_w + idle >= S)
            victim = jnp.argmin(jnp.where(eff > 0, lu[w, :F], jnp.inf))
            pen_f = penalty if life_costs is None else life_costs[f]
            if life_observe is not None:
                # observe the placed pool's idle age AFTER the
                # warm/cold decision (LifecycleRuntime.observe_place);
                # virgin pools (idle_since < 0) are masked out
                seen = lu[w, f] >= 0.0
                gap = jnp.maximum(st.now - lu[w, f], 0.0)
                ka_new = life_observe(life["ka"], f, gap)
                ka = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(seen, a, b), ka_new,
                    life["ka"])
                pre2, keep2 = life_windows(ka)
                life = dict(life, ka=ka, pre=pre2, keep=keep2)
        else:
            warm_cnt = st.warm[w, f]
            is_cold = warm_cnt == 0
            idle = st.warm[w, :F].sum()
            need_evict = is_cold & (active_w + idle >= S)
            victim = jnp.argmax(st.warm[w, :F])
            pen_f = penalty
        warm = st.warm.at[w, f].add(jnp.where(is_cold, 0, -1))
        warm = warm.at[w, victim].add(jnp.where(need_evict, -1, 0))
        slot = jnp.argmax(st.task_idx[w] < 0)
        svc = svc_nom + jnp.where(is_cold, pen_f, 0.0)
        tel = st.tel
        if tel_on:
            # one placement record per accepted arrival (rejections are
            # counted in step; place is never reached for them)
            tel = tel_engine.on_place(tel, w, is_cold, need_evict)
        tl = st.tl
        if tl_on:
            # credited to the window of the dispatch time (= the
            # arrival time under early binding)
            tl = tln_engine.on_place(tl, st.now, is_cold, need_evict)
        st = st._replace(
            remaining=st.remaining.at[w, slot].set(svc),
            task_arr=st.task_arr.at[w, slot].set(t_arr),
            task_idx=st.task_idx.at[w, slot].set(tid.astype(jnp.int32)),
            warm=warm,
            life=life,
            tel=tel,
            tl=tl,
        )
        if stream:
            # per-slot mirrors let the completion drain observe the
            # task's function/service without gathering from the (N,)
            # inputs of an earlier chunk
            st = st._replace(
                task_fn=st.task_fn.at[w, slot].set(f.astype(jnp.int32)),
                task_svc=st.task_svc.at[w, slot].set(svc_nom))
            return st, is_cold
        return st._replace(
            cold=st.cold.at[tid].set(is_cold),
            worker_of=st.worker_of.at[tid].set(w.astype(jnp.int32)))

    def pop_all(st: SimState, funcs, services, arrivals) -> SimState:
        """Dispatch queued invocations while any worker has a free core."""
        def cond(st):
            active = (st.task_idx >= 0).sum(axis=1)
            return (st.q_tail > st.q_head) & (active.min() < C)

        def body(st):
            active = (st.task_idx >= 0).sum(axis=1)
            w = jnp.argmin(active)
            arr_idx = st.q[st.q_head % Q]
            st = place(st, arr_idx, w, funcs[arr_idx],
                       services[arr_idx], arrivals[arr_idx])
            return st._replace(q_head=st.q_head + 1)

        return lax.while_loop(cond, body, st)

    def advance(st: SimState, dt, funcs, services, arrivals) -> SimState:
        """Fast-forward the cluster by ``dt`` seconds of wall time.

        One completion is processed per iteration — the earliest-finishing
        slot (the ``argmin`` of time-to-done).  Simultaneous completions
        drain in successive zero-``tau`` iterations; their bookkeeping
        (response writes to distinct indices, warm-pool increments)
        commutes, so results are identical to batch-completing them while
        the per-iteration update touches O(1) state instead of scattering
        over the whole ``[W, S]`` matrix (the engine's hot path at large
        ``W``).
        """

        def cond(carry):
            st, dt_left = carry
            active = st.task_idx >= 0
            # a tie can be left pending when a completion lands exactly on
            # the window edge — drain it before yielding to the caller
            pending = (active & (st.remaining <= EPS)).any()
            go = active.any() & ((dt_left > 0) | pending)
            if late:
                n_active = active.sum(axis=1)
                can_pop = (st.q_tail > st.q_head) & (n_active.min() < C)
                go = go | can_pop
            return go

        def body(carry):
            st, dt_left = carry
            if late:
                st = pop_all(st, funcs, services, arrivals)
            active = st.task_idx >= 0
            rates = rates_of(st)
            t_done = jnp.where(rates > 0, st.remaining / rates, jnp.inf)
            tmin = t_done.min()
            tau = jnp.minimum(dt_left, tmin)
            tau = jnp.where(jnp.isfinite(tau) & (tau > 0), tau, 0.0)
            # integrate occupancy (constant over tau)
            n_w = active.sum(axis=1)
            server_time = st.server_time + tau * (n_w > 0).sum()
            core_time = st.core_time + tau * jnp.minimum(n_w, C).sum()
            tel = st.tel
            if tel_on:
                # busy/depth/queue-length time integrals, pre-advance
                # occupancy — the same left-Riemann convention as
                # server_time/core_time just above
                tel = tel_engine.on_advance(tel, tau, n_w > 0, n_w,
                                            st.q_tail - st.q_head)
            tl = st.tl
            if tl_on:
                # same integrals, windowed; the whole tau slice credits
                # the window of its start (left-start convention —
                # identical in the oracle and serving platform)
                tl = tln_engine.on_advance(tl, st.now, tau, n_w > 0,
                                           st.q_tail - st.q_head)
            now = st.now + tau
            remaining = st.remaining - rates * tau
            # complete the argmin slot only (idx N / col F are scratch);
            # the remaining<=EPS clause matches cond's pending drain — a
            # task left within EPS of done at the window edge completes
            # here (as both the old batch-done engine and the oracle do)
            # rather than stalling the loop
            j = jnp.argmin(t_done.reshape(-1))
            wj, sj = j // S, j % S
            tid = st.task_idx[wj, sj]
            completed = (tmin <= dt_left) | \
                ((tid >= 0) & (st.remaining[wj, sj] <= EPS))
            resp_val = now - st.task_arr[wj, sj]
            if stream:
                # the (N,)-input gathers of the monolithic path are
                # replaced by the per-slot mirrors written at placement
                # — same bits, so every downstream FP op is identical
                svc_nom = st.task_svc[wj, sj]
                f_j = st.task_fn[wj, sj]
                cutoff_op = st.stream["cutoff"]
                resp = st.resp
            else:
                svc_nom = services[jnp.maximum(tid, 0)]
                f_j = funcs[jnp.maximum(tid, 0)]
                cutoff_op = tel_cutoff if tel_on else None
                resp = st.resp.at[jnp.where(completed, tid, N)].set(
                    jnp.where(completed, resp_val, 0.0))
            if tel_on:
                # histogram scatter for the (masked) completion; warmup
                # tasks are dropped inside on_complete to match
                # summarize's post-warmup population
                tel = tel_engine.on_complete(
                    tel, resp_val, svc_nom, tid, completed,
                    cutoff_op, tel_edges)
            if tl_on:
                # windowed coarse sketches take ALL completions (no
                # warmup cutoff — the recorder shows the ramp), in the
                # window of the completion time
                tl = tln_engine.on_complete(tl, now, resp_val, svc_nom,
                                            completed, tl_edges)
            w_pad = jnp.where(completed, wj, 0)
            f_pad = jnp.where(completed, f_j, F)
            life = st.life
            if life_on:
                # mirror LifecycleRuntime.on_complete: zero a stale
                # pool before the increment (expired executors must not
                # resurrect), refresh the idle clock, then enforce the
                # max_idle budget by LRU eviction over the worker's
                # materialized pools
                lu = life["idle_since"]
                pre, keep = life["pre"], life["keep"]
                age_j = now - lu[wj, f_j]
                stale = age_j > pre[f_j] + keep[f_j]
                base = jnp.where(stale, 0, st.warm[wj, f_j])
                warm = st.warm.at[w_pad, f_pad].set(
                    jnp.where(completed, base + 1,
                              st.warm[w_pad, f_pad]).astype(jnp.int32))
                lu = lu.at[w_pad, f_pad].set(
                    jnp.where(completed, now, lu[w_pad, f_pad]))
                life = dict(life, idle_since=lu)
                if life_max_idle > 0:
                    ages_row = now - lu[wj, :F]
                    mat_row = (ages_row >= pre) & (ages_row <= pre + keep)
                    eff = jnp.where(mat_row, warm[wj, :F], 0)
                    over = completed & (eff.sum() > life_max_idle)
                    evict = jnp.argmin(jnp.where(eff > 0, lu[wj, :F],
                                                 jnp.inf))
                    warm = warm.at[jnp.where(over, wj, 0),
                                   jnp.where(over, evict, F)].add(
                        -over.astype(jnp.int32))
                    if tel_on:
                        tel = tel_engine.on_evict(tel, over)
                    if tl_on:
                        tl = tln_engine.on_evict(tl, now, over)
            else:
                warm = st.warm.at[w_pad, f_pad].add(
                    completed.astype(jnp.int32))
            warm = warm.at[:, F].set(0)
            remaining = remaining.at[wj, sj].set(
                jnp.where(completed, jnp.inf, remaining[wj, sj]))
            task_idx = st.task_idx.at[wj, sj].set(
                jnp.where(completed, jnp.int32(-1), tid))
            lb = st.lb
            if stateful:
                # one hook call per completion, masked branch-free: the
                # updated pytree is selected only where the argmin slot
                # really completed (simultaneous completions drain one
                # zero-tau iteration each, lowest worker index first —
                # the same order the numpy oracle applies its hooks)
                n_after = (task_idx[wj] >= 0).sum()
                svc_obs = svc_nom
                if fleet_on:
                    # the hook observes the *effective* execution time
                    # on the completing worker (f64 division in both
                    # backends — bitwise np ≡ jax), so throughput
                    # learners see the heterogeneity
                    svc_obs = svc_obs / speed_arr[wj]
                upd = res.on_complete(lb, wj, f_j, svc_obs, n_after)
                lb = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(completed, a, b), upd, lb)
            st = st._replace(
                remaining=remaining, task_idx=task_idx,
                warm=warm, now=now, resp=resp,
                server_time=server_time, core_time=core_time, lb=lb,
                life=life, tel=tel, tl=tl)
            if stream:
                # exact online counters: the long path never holds a
                # per-task slowdown array, but the mean response /
                # slowdown over the post-warmup population stays exact
                sc = st.stream
                rec = completed & (tid >= sc["cutoff"])
                slow_v = resp_val / jnp.maximum(svc_nom, 1e-12)
                st = st._replace(stream=dict(
                    sc,
                    n_done=sc["n_done"] + completed.astype(jnp.int64),
                    n_obs=sc["n_obs"] + rec.astype(jnp.int64),
                    resp_sum=sc["resp_sum"]
                    + jnp.where(rec, resp_val, 0.0),
                    slow_sum=sc["slow_sum"]
                    + jnp.where(rec, slow_v, 0.0)))
            return st, dt_left - tau

        st, _ = lax.while_loop(cond, body, (st, dt))
        if late:
            st = pop_all(st, funcs, services, arrivals)
        return st

    def early_arrival(st: SimState, tid, t_i, f_i, u_i, svc_i,
                      funcs, services, arrivals, homes):
        """Advance to ``t_i`` and run the early-binding select/place for
        arrival ``tid`` — the one shared body of the monolithic and
        stream steps, so chunked ≡ monolithic holds by construction.
        Returns ``(st, w, is_cold)``.
        """
        if auto_on:
            # provisioned-time integral over [now, t_i] at the current
            # n_on (decisions only take effect at arrival boundaries,
            # so n_on is constant across the whole advance)
            fl = st.fleet
            st = st._replace(fleet=dict(fl, prov_time=(
                fl["prov_time"]
                + (t_i - st.now) * fl["n_on"].astype(jnp.float64))))
        if tl_on:
            # windowed provisioned core-seconds over the same interval,
            # credited to the interval-start window; without an
            # autoscaler the whole fleet is provisioned throughout
            n_prov = st.fleet["n_on"].astype(jnp.float64) if auto_on \
                else jnp.float64(W)
            st = st._replace(tl=tln_engine.on_prov(
                st.tl, st.now, (t_i - st.now) * n_prov * TL_CORES))
        st = advance(st, t_i - st.now, funcs, services, arrivals)
        st = st._replace(now=t_i)
        active = (st.task_idx >= 0).sum(axis=1).astype(jnp.int32)
        if life_on:
            # selection sees the materialized warm column (pools in
            # their pre-warm phase or past their window are
            # invisible) — mirrors LifecycleRuntime.materialized_col
            lu = st.life["idle_since"]
            pre, keep = st.life["pre"], st.life["keep"]
            ages = st.now - lu[:, f_i]
            m = (ages >= pre[f_i]) & (ages <= pre[f_i] + keep[f_i])
            wcol = jnp.where(m, st.warm[:, f_i], 0)
        else:
            wcol = st.warm[:, f_i]
        sel_active = active
        if auto_on:
            # autoscale decision: read the slowdown-sketch window
            # (counts since the last snapshot), decide only when the
            # cooldown elapsed and the window is non-empty, then
            # snapshot + re-arm — identical gating in the oracle
            fl = st.fleet
            window = st.tel["slow_hist"] - fl["snap"]
            do = (t_i >= fl["cool_until"]) & (window.sum() >= 1)
            n_new = auto_decide(fl["n_on"], window)
            n_on = jnp.where(do, n_new, fl["n_on"]).astype(jnp.int32)
            st = st._replace(fleet=dict(
                fl, n_on=n_on,
                cool_until=jnp.where(do, t_i + auto_cool,
                                     fl["cool_until"]),
                snap=jnp.where(do, st.tel["slow_hist"], fl["snap"])))
            # deprovisioned workers are masked slot-full at
            # selection (the serving platform's health-mask idiom):
            # the balancer contract is untouched, and running tasks
            # on scaled-down workers drain normally
            sel_active = jnp.where(
                jnp.arange(W, dtype=jnp.int32) < n_on, active,
                jnp.int32(S))
            if tl_on:
                # log the decision just taken (only when it changed the
                # level), with the sensor p99 the controller read off
                # the same window — fl still holds the pre-decision
                # n_on here
                changed = do & (n_new != fl["n_on"])
                st = st._replace(tl=tln_engine.on_event(
                    st.tl, changed, t_i, EV_AUTOSCALE, n_new,
                    tln_engine.sensor_p99(window, tl_edges)))
        if tl_on:
            # arrival count + last-write-wins active-worker level, in
            # the arrival's window (post-decision, so the plane shows
            # the trajectory the decision log replays)
            st = st._replace(tl=tln_engine.on_arrival(
                st.tl, t_i, n_on if auto_on else jnp.int32(W)))
            if flip_on:
                # the hybrid balancer packs while any active worker
                # still has a free core (hermes_score's low_load read
                # on the same masked active vector select sees)
                new_mode = (sel_active < C).any().astype(jnp.int32)
                st = st._replace(tl=tln_engine.on_event(
                    st.tl, new_mode != st.tl["mode"], t_i,
                    EV_MODE_FLIP, new_mode, jnp.float64(np.nan)))
                st = st._replace(tl=dict(st.tl, mode=new_mode))
        if stateful:
            w, lb = select(st.lb, sel_active, wcol, f_i, homes,
                           u_i, tid)
            st = st._replace(lb=lb)
        else:
            w = select(sel_active, wcol, f_i, homes, u_i, tid)
        if not stream:
            st = st._replace(rejected=st.rejected.at[tid].set(w < 0))
        if tel_on:
            st = st._replace(tel=tel_engine.on_reject(st.tel, w < 0))
        if tl_on:
            st = st._replace(tl=tln_engine.on_reject(st.tl, t_i, w < 0))
        if stream:
            st, is_cold = lax.cond(
                w >= 0,
                lambda s: place(s, tid, jnp.maximum(w, 0), f_i, svc_i,
                                t_i),
                lambda s: (s, jnp.bool_(False)), st)
        else:
            st = lax.cond(
                w >= 0,
                lambda s: place(s, tid, jnp.maximum(w, 0), f_i, svc_i,
                                t_i),
                lambda s: s, st)
            is_cold = jnp.bool_(False)
        return st, w, is_cold

    if stream:
        def step(st: SimState, xs, funcs, services, arrivals, homes):
            gid, valid, t_i, f_i, u_i, svc_i = xs

            def live(s):
                s, w, is_cold = early_arrival(
                    s, gid, t_i, f_i, u_i, svc_i, funcs, services,
                    arrivals, homes)
                return s, (w < 0, is_cold,
                           jnp.where(w >= 0, w, -1).astype(jnp.int32))

            def skip(s):
                return s, (jnp.bool_(False), jnp.bool_(False),
                           jnp.int32(-1))

            # ``valid`` masks the padded tail of the last chunk.  It is
            # passed unbatched under vmap, so the predicate stays
            # scalar and the cond stays a real branch — padded steps
            # execute nothing and are identity on the carry
            return lax.cond(valid, live, skip, st)
    else:
        def step(st: SimState, xs, funcs, services, arrivals, homes):
            i, t_i, f_i, u_i = xs
            if late:
                if tl_on:
                    # fixed fleet: provisioned core-seconds accrue over
                    # the inter-arrival gap at the full W
                    st = st._replace(tl=tln_engine.on_prov(
                        st.tl, st.now,
                        (t_i - st.now) * jnp.float64(W) * TL_CORES))
                st = advance(st, t_i - st.now, funcs, services, arrivals)
                st = st._replace(now=t_i)
                if tl_on:
                    st = st._replace(tl=tln_engine.on_arrival(
                        st.tl, t_i, jnp.int32(W)))
                active = (st.task_idx >= 0).sum(axis=1).astype(jnp.int32)

                def do_place(st):
                    return place(st, i, jnp.argmin(active), f_i,
                                 services[i], t_i)

                def do_queue(st):
                    return st._replace(q=st.q.at[st.q_tail % Q].set(
                        i.astype(jnp.int32)), q_tail=st.q_tail + 1)
                st = lax.cond(active.min() < C, do_place, do_queue, st)
            else:
                st, _, _ = early_arrival(st, i, t_i, f_i, u_i,
                                         services[i], funcs, services,
                                         arrivals, homes)
            return st, ()

    def init_planes():
        """Initial lb/life/tel/fleet/tl carry pytrees (shared between
        the monolithic ``run`` and the stream ``init`` — identical
        bits)."""
        lb0 = ()
        if stateful:
            lb0 = jax.tree_util.tree_map(jnp.asarray,
                                         res.init_state(W, F))
        life0 = ()
        if life_on:
            ka0 = ()
            if lres.stateful:
                ka0 = jax.tree_util.tree_map(
                    jnp.asarray, lres.init_policy_state(W, F))
            pre0, keep0 = life_windows(ka0)
            life0 = {
                # +1 pad col: completion scatters park on the pad when
                # nothing completed, exactly like ``warm``.  -1 marks a
                # pool with no completion history (masks observations)
                "idle_since": jnp.full((W, F + 1), -1.0,
                                       dtype=jnp.float64),
                # explicit dtype also strips any weak type a keep-alive
                # policy's windows() may have produced
                "pre": jnp.asarray(pre0, dtype=jnp.float64),
                "keep": jnp.asarray(keep0, dtype=jnp.float64),
                "ka": ka0,
            }
        tel0 = tel_engine.init_state(W) if tel_on else ()
        fleet0 = ()
        if auto_on:
            from repro.telemetry.sketch import N_BINS
            fleet0 = {
                # start fully provisioned; the controller scales down
                # through troughs (min_workers floor) and back up
                "n_on": jnp.int32(W),
                "cool_until": jnp.float64(0.0),
                "prov_time": jnp.float64(0.0),
                # slowdown-sketch snapshot at the last decision; the
                # decision window is slow_hist - snap
                "snap": jnp.zeros((N_BINS,), dtype=jnp.int64),
            }
        tl0 = tln_engine.init_state(W, timeline) if tl_on else ()
        return lb0, life0, tel0, fleet0, tl0

    def run(arrivals, funcs, services, u_lb, homes):
        lb0, life0, tel0, fleet0, tl0 = init_planes()
        st = SimState(
            remaining=jnp.full((W, S), jnp.inf, dtype=jnp.float64),
            task_arr=jnp.zeros((W, S), dtype=jnp.float64),
            task_idx=jnp.full((W, S), -1, dtype=jnp.int32),
            warm=jnp.zeros((W, F + 1), dtype=jnp.int32),
            q=jnp.zeros((Q,), dtype=jnp.int32),
            q_head=jnp.int32(0), q_tail=jnp.int32(0),
            now=jnp.float64(0.0),
            resp=jnp.full((N + 1,), jnp.nan, dtype=jnp.float64),
            cold=jnp.zeros((N + 1,), dtype=bool),
            rejected=jnp.zeros((N + 1,), dtype=bool),
            worker_of=jnp.full((N + 1,), -1, dtype=jnp.int32),
            server_time=jnp.float64(0.0), core_time=jnp.float64(0.0),
            lb=lb0, life=life0, tel=tel0, fleet=fleet0, tl=tl0,
        )
        if tl_on:
            # runtime window width: the configured constant, or the
            # horizon (last arrival) over K — one f64 division of the
            # same operands the numpy oracle divides, so window
            # assignment is bitwise identical across engines
            ws = jnp.float64(TL_WS_CFG) if TL_WS_CFG > 0.0 \
                else arrivals[N - 1] / jnp.float64(TL_K)
            st = st._replace(tl=dict(st.tl, window_s=ws))
        xs = (jnp.arange(N, dtype=jnp.int64), arrivals, funcs, u_lb)
        st, _ = lax.scan(
            partial(step, funcs=funcs, services=services, arrivals=arrivals,
                    homes=homes), st, xs)
        t_last = st.now
        st = advance(st, jnp.float64(_BIG_TIME), funcs, services, arrivals)
        if auto_on:
            # drain tail: the fleet stays provisioned until the last
            # completion (advance stops accumulating when idle)
            fl = st.fleet
            st = st._replace(fleet=dict(fl, prov_time=(
                fl["prov_time"]
                + (st.now - t_last) * fl["n_on"].astype(jnp.float64))))
        if tl_on:
            n_prov = st.fleet["n_on"].astype(jnp.float64) if auto_on \
                else jnp.float64(W)
            st = st._replace(tl=tln_engine.on_prov(
                st.tl, t_last, (st.now - t_last) * n_prov * TL_CORES))
        return st

    if not stream:
        return run

    # ---- stream mode: horizon-independent chunk engine ----------------

    def init(n_reps: int, cutoff: int, window_s=None) -> SimState:
        """Initial batched carry (leading ``n_reps`` axis, eager).

        ``cutoff`` is the *global* post-warmup index — it rides in the
        carry so one compiled chunk program serves any horizon.
        ``window_s`` (timeline engines only) is the per-replication
        ``[R]`` runtime window width — computed host-side by
        ``simulate_stream`` from each replication's horizon, exactly as
        the monolithic engine computes it in-trace.
        """
        lb0, life0, tel0, fleet0, tl0 = init_planes()
        st = SimState(
            remaining=jnp.full((W, S), jnp.inf, dtype=jnp.float64),
            task_arr=jnp.zeros((W, S), dtype=jnp.float64),
            task_idx=jnp.full((W, S), -1, dtype=jnp.int32),
            warm=jnp.zeros((W, F + 1), dtype=jnp.int32),
            q=(), q_head=jnp.int32(0), q_tail=jnp.int32(0),
            now=jnp.float64(0.0),
            resp=(), cold=(), rejected=(), worker_of=(),
            server_time=jnp.float64(0.0), core_time=jnp.float64(0.0),
            lb=lb0, life=life0, tel=tel0, fleet=fleet0, tl=tl0,
            task_fn=jnp.zeros((W, S), dtype=jnp.int32),
            task_svc=jnp.zeros((W, S), dtype=jnp.float64),
            stream={
                "cutoff": jnp.int64(cutoff),
                "n_done": jnp.int64(0), "n_obs": jnp.int64(0),
                "resp_sum": jnp.float64(0.0),
                "slow_sum": jnp.float64(0.0),
            })
        st = jax.tree_util.tree_map(
            lambda x: jnp.tile(x[None], (n_reps,) + (1,) * x.ndim), st)
        if tl_on and window_s is not None:
            st = st._replace(tl=dict(st.tl, window_s=jnp.asarray(
                window_s, dtype=jnp.float64)))
        return st

    def run_chunk(st, gids, valid, arrivals, funcs, services, u_lb,
                  homes):
        """One compiled scan over a chunk of arrivals.

        Returns ``(st, ys)`` where ``ys`` are the per-arrival
        ``(rejected, cold, worker)`` outputs of the chunk.
        """
        xs = (gids, valid, arrivals, funcs, u_lb, services)
        return lax.scan(
            partial(step, funcs=funcs, services=services,
                    arrivals=arrivals, homes=homes), st, xs)

    def run_drain(st):
        """End-of-horizon drain — the monolithic engine's scan tail."""
        t_last = st.now
        st = advance(st, jnp.float64(_BIG_TIME), None, None, None)
        if auto_on:
            fl = st.fleet
            st = st._replace(fleet=dict(fl, prov_time=(
                fl["prov_time"]
                + (st.now - t_last) * fl["n_on"].astype(jnp.float64))))
        if tl_on:
            n_prov = st.fleet["n_on"].astype(jnp.float64) if auto_on \
                else jnp.float64(W)
            st = st._replace(tl=tln_engine.on_prov(
                st.tl, t_last, (st.now - t_last) * n_prov * TL_CORES))
        return st

    return init, run_chunk, run_drain


# --------------------------------------------------------------------------
# Process-wide compile cache (bounded LRU).
#
# ``simulate()`` used to rebuild (and therefore re-trace + re-compile) the
# whole scan program on every call — a policy × load sweep paid XLA
# compilation per *cell*.  The engine is fully determined by
# ``(policy, cluster, N, F)`` (``cluster`` folds in W/C/S and the cold-start
# penalty), so compiled programs are memoized on that key; jit's own shape
# cache then handles the batch axis, and a sweep over arrival-rate scale
# reuses one compiled program per policy.
#
# The cache is LRU-bounded at ``ENGINE_CACHE_MAX`` entries: every distinct
# (N, F) shape pins its jitted callable plus XLA executable, so an
# unbounded dict grows without limit under long multi-shape sweeps
# (trace replays with per-trace F, scale studies varying N).  64 covers
# every in-repo sweep (the full benchmark harness compiles < 40 engines)
# while evicting cold programs in recompile-on-miss fashion.
# --------------------------------------------------------------------------

#: Default max resident compiled engines; see note above.  This is only
#: the *initial* bound — rebinding this name later has no effect; use
#: :func:`set_engine_cache_capacity` to change the live limit.
ENGINE_CACHE_MAX = 64

_ENGINE_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
_ENGINE_CACHE_CAPACITY = ENGINE_CACHE_MAX
#: Lifetime lookup counters (reset together with the cache); exported by
#: :func:`engine_cache_stats` and surfaced in BENCH_report.json.
_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def _resolve_backend(policy: PolicySpec, backend: str) -> str:
    """Normalize the user-facing backend choice (``"auto"`` dispatch)."""
    if backend == "auto":
        return default_backend(policy)
    return backend


def _cache_key(policy: PolicySpec, cluster: ClusterCfg,
               n_arrivals: int, n_functions: int, batched: bool,
               backend: str,
               telemetry: TelemetryCfg | None = None,
               chunk: int | None = None,
               timeline: TimelineCfg | None = None) -> tuple:
    # telemetry-on engines trace a different program, so the cfg is part
    # of the key (None = the golden pre-telemetry program).  ``chunk``
    # marks a streaming chunk engine (the chunk size IS the key's shape
    # axis — n_arrivals then holds the chunk length, and one compiled
    # program serves any horizon); None = monolithic.  ``timeline``
    # likewise gates a different traced program (the flight-recorder
    # plane), so its cfg joins the key as a trailing element.
    return (tuple(policy), tuple(cluster), int(n_arrivals),
            int(n_functions), batched, backend,
            None if telemetry is None else tuple(telemetry),
            None if chunk is None else int(chunk),
            None if timeline is None else tuple(timeline))


def _cache_get_or_build(key: tuple, build):
    """Return ``(engine, fresh)``; ``fresh`` marks a cache-miss build.

    The build is wrapped in an ``engine.build`` tracer span, so with
    tracing on every compile-cache miss is visible on the timeline
    (hits cost one dict lookup and no span).
    """
    fn = _ENGINE_CACHE.get(key)
    if fn is not None:
        _CACHE_STATS["hits"] += 1
        _ENGINE_CACHE.move_to_end(key)
        return fn, False
    _CACHE_STATS["misses"] += 1
    with get_tracer().span("engine.build", backend=key[5],
                           batched=key[4], n=key[2]):
        fn = build()
    _ENGINE_CACHE[key] = fn
    while len(_ENGINE_CACHE) > _ENGINE_CACHE_CAPACITY:
        _ENGINE_CACHE.popitem(last=False)
        _CACHE_STATS["evictions"] += 1
    return fn, True


def engine_cache_stats() -> dict:
    """Cache occupancy + lifetime hit/miss/eviction counters."""
    keys = list(_ENGINE_CACHE)
    return {"entries": len(keys),
            "batched": sum(1 for k in keys if k[4]),
            "single": sum(1 for k in keys if not k[4]),
            "capacity": _ENGINE_CACHE_CAPACITY,
            "hits": _CACHE_STATS["hits"],
            "misses": _CACHE_STATS["misses"],
            "evictions": _CACHE_STATS["evictions"]}


def engine_cache_capacity() -> int:
    return _ENGINE_CACHE_CAPACITY


def set_engine_cache_capacity(capacity: int) -> None:
    """Re-bound the LRU (evicting oldest entries if shrinking)."""
    global _ENGINE_CACHE_CAPACITY
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    _ENGINE_CACHE_CAPACITY = int(capacity)
    while len(_ENGINE_CACHE) > _ENGINE_CACHE_CAPACITY:
        _ENGINE_CACHE.popitem(last=False)
        _CACHE_STATS["evictions"] += 1


def clear_engine_cache() -> None:
    """Drop all compiled engines and reset the lookup counters."""
    _ENGINE_CACHE.clear()
    for k in _CACHE_STATS:
        _CACHE_STATS[k] = 0


def _get_engine(policy: PolicySpec, cluster: ClusterCfg,
                n_arrivals: int, n_functions: int, batched: bool,
                backend: str, telemetry: TelemetryCfg | None,
                timeline: TimelineCfg | None = None):
    """Cached engine lookup; returns ``(engine, fresh)``.

    ``fresh`` marks a cache-miss build — the next dispatch through the
    callable pays XLA compilation, which :func:`simulate` /
    :func:`simulate_many` surface as an ``engine.first_run`` span
    (vs ``engine.run`` for steady-state cached dispatches).
    """
    cluster.validate()   # named errors instead of deep broadcast failures
    backend = _resolve_backend(policy, backend)
    key = _cache_key(policy, cluster, n_arrivals, n_functions, batched,
                     backend, telemetry, timeline=timeline)
    raw = lambda: _build_engine(policy, cluster, n_arrivals, n_functions,
                                backend, telemetry=telemetry,
                                timeline=timeline)
    if batched:
        return _cache_get_or_build(key, lambda: jax.jit(jax.vmap(raw())))
    return _cache_get_or_build(key, lambda: jax.jit(raw()))


def _get_stream_engine(policy: PolicySpec, cluster: ClusterCfg,
                       chunk: int, n_functions: int, backend: str,
                       telemetry: TelemetryCfg | None,
                       timeline: TimelineCfg | None = None):
    """Cached streaming chunk-engine lookup.

    Returns ``((init, step_fn, drain_fn), fresh)``.  ``step_fn`` is the
    jitted+vmapped chunk scan with the carry donated
    (``donate_argnums=(0,)``), so handing the carry across segment
    boundaries reuses its device buffers instead of copying them;
    ``drain_fn`` donates the same way.  The key carries the chunk size
    instead of the horizon — growing ``N`` reuses one compiled program.
    """
    if chunk < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk}")
    cluster.validate()
    backend = _resolve_backend(policy, backend)
    key = _cache_key(policy, cluster, int(chunk), n_functions, True,
                     backend, telemetry, chunk=int(chunk),
                     timeline=timeline)

    def build():
        init, run_chunk, run_drain = _build_engine(
            policy, cluster, int(chunk), n_functions, backend,
            telemetry=telemetry, timeline=timeline, stream=True)
        # carry batched over reps; gids/valid unbatched so the padding
        # cond keeps a scalar predicate (a real branch, not a select)
        step_fn = jax.jit(
            jax.vmap(run_chunk, in_axes=(0, None, None, 0, 0, 0, 0, 0)),
            donate_argnums=(0,))
        drain_fn = jax.jit(jax.vmap(run_drain), donate_argnums=(0,))
        return (init, step_fn, drain_fn)

    return _cache_get_or_build(key, build)


def build_simulator(policy: PolicySpec, cluster: ClusterCfg, *,
                    n_arrivals: int, n_functions: int,
                    backend: str = "auto",
                    telemetry: TelemetryCfg | None = None,
                    timeline: TimelineCfg | None = None):
    """Jitted single-workload simulator, memoized process-wide.

    Repeated calls with an equal key return the *same* compiled callable, so
    sweeps over loads/seeds (which only change array values, not shapes)
    compile exactly once per policy.  ``backend`` is ``"jax"``,
    ``"pallas"`` or ``"auto"`` (kernel dispatch whenever the balancer
    ships one — see :func:`repro.policy.default_backend`).  The memo is a
    bounded LRU (``ENGINE_CACHE_MAX`` entries by default; resize with
    :func:`set_engine_cache_capacity`); a key evicted by newer shapes is
    transparently rebuilt on the next call.  ``telemetry`` selects the
    streaming-metrics variant (a distinct cache entry — the carry shape
    differs); ``timeline`` likewise selects the windowed flight-recorder
    variant.
    """
    fn, _ = _get_engine(policy, cluster, n_arrivals, n_functions, False,
                        backend, telemetry, timeline)
    return fn


def build_batch_simulator(policy: PolicySpec, cluster: ClusterCfg, *,
                          n_arrivals: int, n_functions: int,
                          backend: str = "auto",
                          telemetry: TelemetryCfg | None = None,
                          timeline: TimelineCfg | None = None):
    """Jitted ``vmap``-ed simulator over a leading replication axis.

    All five inputs carry a leading ``R`` axis (``arrivals/funcs/services/
    u_lb`` are ``[R, N]``, ``homes`` is ``[R, F]``); one compiled program
    advances all R replications in lockstep.  With the ``pallas``
    backend (the ``auto`` choice for Hermes), the replication axis maps
    onto the controller kernel's batch dimension: one
    :mod:`repro.kernels.hermes_select` dispatch serves every stacked
    replication per arrival.
    """
    fn, _ = _get_engine(policy, cluster, n_arrivals, n_functions, True,
                        backend, telemetry, timeline)
    return fn


def _cluster_auto_on(cluster: ClusterCfg) -> bool:
    """Whether this cluster runs an active autoscale control loop."""
    fl = cluster.fleet
    return fl is not None and \
        str(fl.autoscale).strip().upper() != _AUTO_STATIC


def _prov_core_s(st, cluster: ClusterCfg):
    """Provisioned core-seconds: ∫ n_on(t)·cores dt (fig. 13 x-axis).

    Without an autoscaler the active set is the whole fleet for the
    whole run, so the integral degenerates to ``end_time × W × C``.
    """
    if _cluster_auto_on(cluster):
        return np.asarray(st.fleet["prov_time"]) * cluster.cores
    return np.asarray(st.now) * cluster.n_workers * cluster.cores


def simulate(policy: PolicySpec, cluster: ClusterCfg, wl: Workload,
             *, backend: str = "auto",
             telemetry: TelemetryCfg | None = None,
             timeline: TimelineCfg | None = None) -> SimOutput:
    """Run the JAX simulator on a workload; returns host-side results.

    With ``telemetry`` set, the returned output carries a
    :class:`~repro.telemetry.TelemetryResult` accumulated inside the
    scan (histogram percentile sketches, counters, occupancy
    integrals).  With ``timeline`` set, it additionally carries a
    :class:`~repro.telemetry.TimelineResult` — the windowed
    flight-recorder plane (per-window counters/sketches/integrals and
    the bounded decision-event log).
    """
    run, fresh = _get_engine(policy, cluster, wl.n, wl.n_functions,
                             False, backend, telemetry, timeline)
    tr = get_tracer()
    with tr.span("engine.first_run" if fresh else "engine.run",
                 policy=str(policy), backend=backend, n=wl.n):
        st = run(jnp.asarray(wl.arrival), jnp.asarray(wl.func),
                 jnp.asarray(wl.service), jnp.asarray(wl.u_lb),
                 jnp.asarray(wl.func_home))
        if tr.enabled:
            st = jax.block_until_ready(st)
    return SimOutput(
        response=np.asarray(st.resp[:wl.n]),
        cold=np.asarray(st.cold[:wl.n]),
        rejected=np.asarray(st.rejected[:wl.n]),
        worker=np.asarray(st.worker_of[:wl.n]),
        server_time=float(st.server_time),
        core_time=float(st.core_time),
        end_time=float(st.now),
        telemetry=None if telemetry is None else TelemetryResult.from_state(
            jax.tree_util.tree_map(np.asarray, st.tel), cfg=telemetry),
        prov_core_s=float(_prov_core_s(st, cluster)),
        timeline=None if timeline is None else TimelineResult.from_state(
            jax.tree_util.tree_map(np.asarray, st.tl), cfg=timeline),
    )


def simulate_many(policy: PolicySpec, cluster: ClusterCfg,
                  workloads, *, backend: str = "auto",
                  telemetry: TelemetryCfg | None = None,
                  timeline: TimelineCfg | None = None
                  ) -> BatchSimOutput:
    """Run ``R`` stacked workload replications through one compiled program.

    ``workloads`` is a :class:`~repro.core.workload.WorkloadBatch` or a
    sequence of :class:`Workload` sharing one ``(N, F)`` shape (stacked
    here).  Semantically identical to ``R`` independent :func:`simulate`
    calls — the batched engine is the same scan program under ``vmap`` —
    but compiles once and advances every replication per XLA dispatch.
    With ``telemetry`` set, the output's
    :class:`~repro.telemetry.TelemetryResult` keeps the leading ``R``
    axis; its percentile readers pool across it (matching
    ``summarize_batch``'s pooled statistics).
    """
    wb = workloads if isinstance(workloads, WorkloadBatch) \
        else stack_workloads(workloads)
    run, fresh = _get_engine(policy, cluster, wb.n, wb.n_functions,
                             True, backend, telemetry, timeline)
    tr = get_tracer()
    with tr.span("engine.first_run" if fresh else "engine.run",
                 policy=str(policy), backend=backend, n=wb.n,
                 reps=wb.n_reps):
        st = run(jnp.asarray(wb.arrival), jnp.asarray(wb.func),
                 jnp.asarray(wb.service), jnp.asarray(wb.u_lb),
                 jnp.asarray(wb.func_home))
        if tr.enabled:
            st = jax.block_until_ready(st)
    return BatchSimOutput(
        response=np.asarray(st.resp[:, :wb.n]),
        cold=np.asarray(st.cold[:, :wb.n]),
        rejected=np.asarray(st.rejected[:, :wb.n]),
        worker=np.asarray(st.worker_of[:, :wb.n]),
        server_time=np.asarray(st.server_time),
        core_time=np.asarray(st.core_time),
        end_time=np.asarray(st.now),
        telemetry=None if telemetry is None else TelemetryResult.from_state(
            jax.tree_util.tree_map(np.asarray, st.tel), cfg=telemetry),
        prov_core_s=np.asarray(_prov_core_s(st, cluster), dtype=np.float64),
        timeline=None if timeline is None else TimelineResult.from_state(
            jax.tree_util.tree_map(np.asarray, st.tl), cfg=timeline),
    )
