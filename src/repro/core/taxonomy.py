"""Taxonomy of serverless scheduling policies (paper §3.1).

A policy is a triple ``T/LB/S``:

* ``T``  — binding time: **E**\\ arly (dispatch on arrival, queue at workers)
           or **L**\\ ate (queue at the controller until a core frees).
* ``LB`` — load balancing: ``LOC`` (locality/sticky hashing — OpenWhisk
           default), ``R`` (random), ``LL`` (least-loaded / JSQ) or ``H``
           (Hermes hybrid: packing at low load, least-loaded at high load,
           locality-aware tie-breaking).
* ``S``  — intra-worker scheduling: ``PS`` (processor sharing ≈ CFS),
           ``FCFS`` or ``SRPT`` (oracle execution times; §3.4).

Policies are *data*: the simulator and the serving runtime both take a
:class:`PolicySpec` and stay branch-free internally, so the entire space can
be swept by a single jitted program per spec.
"""
from __future__ import annotations

import enum
from typing import NamedTuple


class Binding(enum.IntEnum):
    EARLY = 0
    LATE = 1


class LoadBalance(enum.IntEnum):
    LOCALITY = 0      # OpenWhisk-style sticky hashing (LOC)
    RANDOM = 1        # uniform over workers with free capacity (R)
    LEAST_LOADED = 2  # join-shortest-queue by active invocations (LL)
    HYBRID = 3        # Hermes (H): pack at low load, LL at high load


class WorkerSched(enum.IntEnum):
    PS = 0    # processor sharing: each active task gets min(1, C/n) cores
    FCFS = 1  # first C tasks in arrival order run at rate 1
    SRPT = 2  # C tasks with smallest remaining work run at rate 1 (oracle)


class PolicySpec(NamedTuple):
    binding: Binding
    balance: LoadBalance
    sched: WorkerSched

    @property
    def name(self) -> str:
        t = "E" if self.binding == Binding.EARLY else "L"
        lb = {
            LoadBalance.LOCALITY: "LOC",
            LoadBalance.RANDOM: "R",
            LoadBalance.LEAST_LOADED: "LL",
            LoadBalance.HYBRID: "H",
        }[self.balance]
        s = {WorkerSched.PS: "PS", WorkerSched.FCFS: "FCFS",
             WorkerSched.SRPT: "SRPT"}[self.sched]
        return f"{t}/{lb}/{s}"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.name


_LB = {"LOC": LoadBalance.LOCALITY, "R": LoadBalance.RANDOM,
       "LL": LoadBalance.LEAST_LOADED, "H": LoadBalance.HYBRID}
_S = {"PS": WorkerSched.PS, "FCFS": WorkerSched.FCFS,
      "SRPT": WorkerSched.SRPT}


def parse_policy(text: str) -> PolicySpec:
    """Parse ``"E/LL/PS"``-style notation (paper §3.1) into a PolicySpec.

    For late binding the LB/S components are irrelevant (the simulator,
    like the paper's, runs dispatched tasks uninterruptedly at rate 1);
    ``"L/*/*"`` is accepted as an alias of ``"L/LL/FCFS"``.
    """
    t, lb, s = text.strip().upper().split("/")
    binding = Binding.EARLY if t == "E" else Binding.LATE
    if binding == Binding.LATE and (lb == "*" or s == "*"):
        return PolicySpec(Binding.LATE, LoadBalance.LEAST_LOADED,
                          WorkerSched.FCFS)
    return PolicySpec(binding, _LB[lb], _S[s])


# The policy combinations explored in the paper's Fig. 2 (§3.3) plus the
# SRPT study (§3.4) and Hermes itself (§4).
LATE_BINDING = parse_policy("L/*/*")
E_LL_PS = parse_policy("E/LL/PS")
E_LL_FCFS = parse_policy("E/LL/FCFS")
E_LOC_PS = parse_policy("E/LOC/PS")        # vanilla OpenWhisk
E_LOC_FCFS = parse_policy("E/LOC/FCFS")
E_R_PS = parse_policy("E/R/PS")
E_R_FCFS = parse_policy("E/R/FCFS")
E_LL_SRPT = parse_policy("E/LL/SRPT")
HERMES = parse_policy("E/H/PS")

FIG2_POLICIES = (
    LATE_BINDING, E_LL_FCFS, E_LL_PS, E_LOC_FCFS, E_LOC_PS, E_R_FCFS, E_R_PS,
)
EVAL_POLICIES = (E_LOC_PS, LATE_BINDING, E_LL_PS, HERMES)  # paper §6 baselines
