"""Taxonomy of serverless scheduling policies (paper §3.1).

A policy is a triple ``T/LB/S``:

* ``T``  — binding time: **E**\\ arly (dispatch on arrival, queue at workers)
           or **L**\\ ate (queue at the controller until a core frees).
* ``LB`` — load balancing: any balancer registered in
           :mod:`repro.policy` — the paper's ``LOC`` (locality/sticky
           hashing — OpenWhisk default), ``R`` (random), ``LL``
           (least-loaded / JSQ) and ``H`` (Hermes hybrid), plus zoo
           extensions such as ``JSQ2`` (power-of-two-choices) and ``RR``
           (round-robin) and anything added via
           :func:`repro.policy.register_balancer`.
* ``S``  — intra-worker scheduling: any registered scheduler — ``PS``
           (processor sharing ≈ CFS), ``FCFS`` or ``SRPT`` (oracle
           execution times; §3.4).

Policies are *data*: a :class:`PolicySpec` is a triple of registry
*names*; the simulators and the serving runtime resolve it against a
backend (``np`` / ``jax`` / ``pallas``) through
:func:`repro.policy.resolve` and stay branch-free internally, so the
entire space can be swept by a single jitted program per spec.

The :class:`Binding` / :class:`LoadBalance` / :class:`WorkerSched` enums
remain as typed aliases of the built-in registry names (their values ARE
the names, and compare/hash equal to plain strings), so pre-registry
code and tests keep working unchanged.
"""
from __future__ import annotations

import enum
from typing import NamedTuple


class Binding(str, enum.Enum):
    EARLY = "E"
    LATE = "L"


class LoadBalance(str, enum.Enum):
    LOCALITY = "LOC"      # OpenWhisk-style sticky hashing (LOC)
    RANDOM = "R"          # uniform over workers with free capacity (R)
    LEAST_LOADED = "LL"   # join-shortest-queue by active invocations (LL)
    HYBRID = "H"          # Hermes (H): pack at low load, LL at high load


class WorkerSched(str, enum.Enum):
    PS = "PS"      # processor sharing: each active task gets min(1, C/n)
    FCFS = "FCFS"  # first C tasks in arrival order run at rate 1
    SRPT = "SRPT"  # C tasks with smallest remaining work run at rate 1


def _value(x) -> str:
    return x.value if isinstance(x, enum.Enum) else str(x)


class PolicySpec(NamedTuple):
    """A policy as a triple of registry names.

    Fields hold either the plain registry name (``"JSQ2"``) or the
    equivalent built-in enum member (``LoadBalance.LEAST_LOADED``); the
    two compare and hash equal, so specs built either way are
    interchangeable (including as engine-cache keys).  Build specs with
    :func:`parse_policy` for normalized fields.
    """

    binding: str
    balance: str
    sched: str

    @property
    def name(self) -> str:
        return f"{_value(self.binding)}/{_value(self.balance)}/" \
               f"{_value(self.sched)}"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.name


# Built-in names → enum members, so parse_policy returns typed fields
# for the paper's policies (and plain strings for registry extensions).
_BINDING_ENUM = {b.value: b for b in Binding}
_LB_ENUM = {lb.value: lb for lb in LoadBalance}
_S_ENUM = {s.value: s for s in WorkerSched}


def parse_policy(text: str) -> PolicySpec:
    """Parse ``"E/LL/PS"``-style notation (paper §3.1) into a PolicySpec.

    Accepts any balancer/scheduler registered in :mod:`repro.policy`
    (``"E/JSQ2/PS"`` works as soon as ``JSQ2`` is registered); unknown
    tokens raise a ``ValueError`` naming the offending token and listing
    the registered alternatives.

    For late binding the LB/S components are irrelevant (the simulator,
    like the paper's, runs dispatched tasks uninterruptedly at rate 1);
    ``"L/*/*"`` is accepted as an alias of ``"L/LL/FCFS"``.
    """
    from repro.policy import get_balancer, get_binding, get_sched

    parts = text.strip().upper().split("/")
    if len(parts) != 3:
        raise ValueError(f"policy {text!r} is not of the form T/LB/S "
                         f"(e.g. 'E/LL/PS')")
    t, lb, s = parts
    binding = get_binding(t)      # named ValueError on unknown token
    if binding.late and (lb == "*" or s == "*"):
        return PolicySpec(Binding.LATE, LoadBalance.LEAST_LOADED,
                          WorkerSched.FCFS)
    bal = get_balancer(lb)
    sched = get_sched(s)
    return PolicySpec(_BINDING_ENUM.get(binding.name, binding.name),
                      _LB_ENUM.get(bal.name, bal.name),
                      _S_ENUM.get(sched.name, sched.name))


# The policy combinations explored in the paper's Fig. 2 (§3.3) plus the
# SRPT study (§3.4) and Hermes itself (§4).  Built directly (not via
# parse_policy) so importing the taxonomy never touches the registry.
LATE_BINDING = PolicySpec(Binding.LATE, LoadBalance.LEAST_LOADED,
                          WorkerSched.FCFS)
E_LL_PS = PolicySpec(Binding.EARLY, LoadBalance.LEAST_LOADED, WorkerSched.PS)
E_LL_FCFS = PolicySpec(Binding.EARLY, LoadBalance.LEAST_LOADED,
                       WorkerSched.FCFS)
E_LOC_PS = PolicySpec(Binding.EARLY, LoadBalance.LOCALITY,
                      WorkerSched.PS)           # vanilla OpenWhisk
E_LOC_FCFS = PolicySpec(Binding.EARLY, LoadBalance.LOCALITY,
                        WorkerSched.FCFS)
E_R_PS = PolicySpec(Binding.EARLY, LoadBalance.RANDOM, WorkerSched.PS)
E_R_FCFS = PolicySpec(Binding.EARLY, LoadBalance.RANDOM, WorkerSched.FCFS)
E_LL_SRPT = PolicySpec(Binding.EARLY, LoadBalance.LEAST_LOADED,
                       WorkerSched.SRPT)
HERMES = PolicySpec(Binding.EARLY, LoadBalance.HYBRID, WorkerSched.PS)

FIG2_POLICIES = (
    LATE_BINDING, E_LL_FCFS, E_LL_PS, E_LOC_FCFS, E_LOC_PS, E_R_FCFS, E_R_PS,
)
EVAL_POLICIES = (E_LOC_PS, LATE_BINDING, E_LL_PS, HERMES)  # paper §6 baselines

# Registry extensions swept by benchmarks/fig11_policy_zoo.py.  HIKU
# (pull-based ready-ring) and DD (data-driven per-function estimates)
# carry balancer state through the engines — see
# :mod:`repro.policy.balancers`.
E_JSQ2_PS = PolicySpec(Binding.EARLY, "JSQ2", WorkerSched.PS)
E_RR_PS = PolicySpec(Binding.EARLY, "RR", WorkerSched.PS)
E_HIKU_PS = PolicySpec(Binding.EARLY, "HIKU", WorkerSched.PS)
E_DD_PS = PolicySpec(Binding.EARLY, "DD", WorkerSched.PS)
# SWARM learns per-worker slowness online (heterogeneous-fleet aware) —
# see repro.policy.balancers and repro.fleet.
E_SWARM_PS = PolicySpec(Binding.EARLY, "SWARM", WorkerSched.PS)
ZOO_POLICIES = (E_R_PS, E_RR_PS, E_JSQ2_PS, E_HIKU_PS, E_DD_PS,
                E_SWARM_PS, E_LL_PS, HERMES)
