"""Core of the paper's contribution: scheduling taxonomy, simulators, Hermes.

Importing :mod:`repro.core.simulator` enables JAX x64 (event-time
precision); all model code in this repo pins explicit dtypes, so this is
safe process-wide.
"""
from .cluster import ClusterCfg, PAPER_LARGE, PAPER_SMALL, PAPER_TESTBED
from ..fleet import FleetCfg
from ..lifecycle import LifecycleCfg
from .taxonomy import (Binding, LoadBalance, PolicySpec, WorkerSched,
                       parse_policy, FIG2_POLICIES, EVAL_POLICIES, HERMES,
                       LATE_BINDING, E_LL_PS, E_LL_FCFS, E_LL_SRPT, E_LOC_PS,
                       E_LOC_FCFS, E_R_PS, E_R_FCFS, E_JSQ2_PS, E_RR_PS,
                       E_HIKU_PS, E_DD_PS, E_SWARM_PS, ZOO_POLICIES)
from .workload import (Workload, WorkloadBatch, WORKLOADS, synth_workload,
                       validate_workload,
                       stack_workloads, replicate_workload, ms_trace,
                       ms_representative, single_function, multi_balanced,
                       homogeneous_exec, bimodal_exec, lognormal_mean,
                       AZURE_MU, AZURE_SIGMA)
from .metrics import (Summary, BatchSummary, Stat, summarize, summarize_sim,
                      summarize_batch, summarize_batch_sim)

# Trace-replay scenarios (repro.trace) join the synthetic §6.1 generators
# so every --workload flag / sweep accepts them.  catalog is import-light
# (no repro.core imports at module level), so this cannot cycle.
from ..trace.catalog import TRACE_SCENARIOS
WORKLOADS.update(TRACE_SCENARIOS)

__all__ = [
    "ClusterCfg", "FleetCfg", "LifecycleCfg", "PAPER_LARGE", "PAPER_SMALL",
    "PAPER_TESTBED",
    "Binding", "LoadBalance", "PolicySpec", "WorkerSched", "parse_policy",
    "FIG2_POLICIES", "EVAL_POLICIES", "HERMES", "LATE_BINDING", "E_LL_PS",
    "E_LL_FCFS", "E_LL_SRPT", "E_LOC_PS", "E_LOC_FCFS", "E_R_PS", "E_R_FCFS",
    "E_JSQ2_PS", "E_RR_PS", "E_HIKU_PS", "E_DD_PS", "E_SWARM_PS",
    "ZOO_POLICIES",
    "Workload", "WorkloadBatch", "WORKLOADS", "synth_workload",
    "validate_workload", "stack_workloads", "replicate_workload", "ms_trace",
    "ms_representative", "single_function", "multi_balanced",
    "homogeneous_exec", "bimodal_exec", "lognormal_mean",
    "AZURE_MU", "AZURE_SIGMA",
    "Summary", "BatchSummary", "Stat", "summarize", "summarize_sim",
    "summarize_batch", "summarize_batch_sim",
]
