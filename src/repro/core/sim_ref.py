"""Pure-numpy reference discrete-event simulator (oracle).

This is the readable, obviously-correct implementation of the simulation
contract; the vectorized JAX engine in :mod:`repro.core.simulator` must
reproduce it task-by-task.  Shared semantics (both engines implement this
exactly):

* Arrivals are processed in order; between consecutive arrivals the cluster
  is advanced through every completion event (piecewise-constant rates).
* Worker scheduling rates (per active task, in cores):
    - PS:   every task gets ``min(1, C/n)``;
    - FCFS: the ``C`` earliest-arrived tasks get 1, the rest 0;
    - SRPT: the ``C`` tasks with least remaining work get 1 (oracle exec
      times; ties by arrival sequence), the rest 0;
    - Late binding: workers hold at most ``C`` tasks, all at rate 1; excess
      invocations queue FIFO at the controller.
* Load-balancing selection is deterministic given the pre-drawn per-arrival
  uniform ``u_lb`` (random policies), the function-home table (locality)
  and the arrival sequence number (round-robin).  Selection and rate
  assignment are resolved from the policy registry
  (:func:`repro.policy.resolve` with ``backend="np"``), so any
  registered balancer/scheduler runs through this oracle unchanged; see
  :mod:`repro.policy.balancers` for the built-in contracts (LOC / R /
  LL / H / JSQ2 / RR, plus the carried-state HIKU / DD — their state
  pytree is threaded through selection and updated by an
  ``on_complete`` hook once per task completion, counting down the
  worker's remaining active tasks in worker-index order exactly as the
  vectorized engine drains its per-completion argmin loop).
* Warm executors: each completion leaves one idle warm executor for its
  function on its worker.  A placement consumes a matching warm executor
  (warm start) if present, else it is a cold start; if the worker's slots
  are exhausted by busy+idle executors, an idle executor is evicted —
  the function with the most idle executors by default, the LRU pool
  (oldest idle-since timestamp, ties toward the lowest function id)
  under a lifecycle config.  Both engines share this tie-breaking
  contract exactly (``tests/test_simulator.py`` locks it with a
  randomized full-warm-pool agreement test).  Late binding checks
  warmth at *dispatch* (queue pop) time, matching the paper's
  observation that queuing increases warm hits (§6.3).
* Container lifecycle (``cluster.lifecycle`` set): warm pools carry
  idle-since clocks; a keep-alive policy from :mod:`repro.lifecycle`
  masks pools alive/materialized, cold starts charge the per-function
  preset cost, and the ``max_idle`` budget LRU-evicts at completions.
  All lifecycle state transitions go through the shared
  :class:`repro.lifecycle.LifecycleRuntime`, which the vectorized
  engine mirrors op for op.
* Heterogeneous fleet (``cluster.fleet`` set): every scheduler-assigned
  rate is scaled by the worker's speed (service *work* stays nominal),
  stateful balancers observe effective execution times, and a
  non-``STATIC`` autoscale policy runs the same arrival-boundary
  control loop as the scan engine: decide against the telemetry
  slowdown-sketch window under a cooldown, mask deprovisioned workers
  slot-full at selection, integrate provisioned time.
* After the last arrival the cluster is drained to empty; only rejected
  invocations have NaN response.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.fleet import resolve_fleet
from repro.lifecycle import LifecycleRuntime, resolve_lifecycle
from repro.policy import resolve
from repro.telemetry.state import (TelemetryCfg, TelemetryResult, init_np,
                                   on_advance_np, on_complete_np,
                                   on_evict_np, on_place_np, on_reject_np,
                                   warmup_cutoff)
from repro.telemetry.timeline import (EV_AUTOSCALE, EV_MODE_FLIP,
                                      TimelineCfg, TimelineResult,
                                      auto_window_s, init_tl_np,
                                      sensor_p99_np, tl_event_np,
                                      tl_on_advance_np, tl_on_arrival_np,
                                      tl_on_complete_np, tl_on_evict_np,
                                      tl_on_place_np, tl_on_prov_np,
                                      tl_on_reject_np, validate_timeline)

from .cluster import ClusterCfg
from .taxonomy import LoadBalance, PolicySpec
from .workload import Workload

EPS = 1e-9


@dataclasses.dataclass
class _Task:
    arr_idx: int
    func: int
    arrival: float
    remaining: float
    seq: int
    rate: float = 0.0


@dataclasses.dataclass(frozen=True)
class SimResult:
    response: np.ndarray    # (N,) float64 seconds; NaN if rejected
    cold: np.ndarray        # (N,) bool — placement caused a cold start
    rejected: np.ndarray    # (N,) bool
    worker: np.ndarray      # (N,) int32; -1 if rejected
    server_time: float      # ∫ #workers-with-≥1-active dt
    core_time: float        # ∫ Σ_w min(n_w, C) dt
    end_time: float
    #: streaming metrics (None unless ``telemetry=`` was passed); the
    #: oracle twin of the scan engine's carry — integer planes bitwise
    #: np ≡ jax, float integrals to float64 accumulation order
    telemetry: TelemetryResult | None = None
    #: provisioned core-seconds: the autoscaler's ``n_on × cores`` time
    #: integral, or ``end_time × total_cores`` for a fixed fleet
    prov_core_s: float = 0.0
    #: windowed flight recorder (None unless ``timeline=`` was passed);
    #: the oracle twin of the scan engine's ``tl`` carry — integer
    #: planes bitwise np ≡ jax, float integrals to accumulation order
    timeline: TimelineResult | None = None


def simulate_ref(policy: PolicySpec, cluster: ClusterCfg, wl: Workload,
                 *, telemetry: TelemetryCfg | None = None,
                 timeline: TimelineCfg | None = None,
                 chunk_size: int | None = None,
                 chunk_hook=None) -> SimResult:
    """Pure-numpy oracle event loop (the semantic contract).

    ``chunk_size``/``chunk_hook`` replay the streaming engine's segment
    boundaries: after every ``chunk_size``-th arrival has been
    processed (advance + placement, before the next arrival), the hook
    is called as ``chunk_hook(chunk_idx, tel_snapshot, now)`` with a
    deep copy of the telemetry plane — the per-segment parity probe
    for :func:`repro.core.streaming.simulate_stream`.
    """
    W, C, S = cluster.n_workers, cluster.cores, cluster.slots
    F = wl.n_functions
    N = wl.n

    tasks: list[list[_Task]] = [[] for _ in range(W)]
    warm = np.zeros((W, F), dtype=np.int64)
    queue: list[int] = []  # arrival indices (late binding only)

    response = np.full(N, np.nan)
    cold = np.zeros(N, dtype=bool)
    rejected = np.zeros(N, dtype=bool)
    worker_of = np.full(N, -1, dtype=np.int32)

    server_time = 0.0
    core_time = 0.0
    now = 0.0
    # numpy-backend resolution: select/rates are the oracle callables of
    # the registered balancer/scheduler (None for late binding)
    res = resolve(policy, backend="np", cluster=cluster)
    late = res.late
    # carried-state balancers thread a state pytree through selection
    # and receive a hook per completion (repro.policy.registry contract)
    lb_state = res.init_state(W, F) if (res.stateful and not late) else None
    # container lifecycle (None = legacy infinite keep-alive, bit-exact)
    lres = resolve_lifecycle(cluster, backend="np", n_functions=F)
    life = LifecycleRuntime(lres, W, F) if lres is not None else None
    # streaming telemetry — updated at the same event boundaries as the
    # scan engine's carry (place / advance / complete / reject)
    tel = init_np(W) if telemetry is not None else None
    tel_cutoff = warmup_cutoff(N, telemetry) if telemetry is not None else 0
    # windowed flight recorder — hooks fire at the same event boundaries
    # (and in the same order) as the scan engine's tl carry
    tl = None
    if timeline is not None:
        validate_timeline(timeline)
        tl = init_tl_np(W, timeline,
                        auto_window_s(float(wl.arrival[-1]), timeline))
    flip_on = tl is not None and not late \
        and policy.balance == LoadBalance.HYBRID
    # heterogeneous fleet + autoscaling (None = homogeneous, bit-exact)
    fres = resolve_fleet(cluster, backend="np")
    fleet_on = fres is not None
    auto_on = fleet_on and fres.auto_on
    speeds = np.asarray(fres.speeds) if fleet_on else None
    if auto_on:
        if late:
            raise ValueError(
                f"autoscaler {fres.policy.name!r} requires early binding"
                f" — late binding has no per-worker placement to mask")
        if fres.policy.needs_telemetry and tel is None:
            raise ValueError(
                f"autoscaler {fres.policy.name!r} reads the telemetry "
                f"slowdown sketch as its sensor; pass telemetry="
                f"TelemetryCfg() to the simulator")
        from repro.telemetry.sketch import N_BINS
        auto_decide = fres.decide
        auto_cool = float(fres.cfg.cooldown_s)
        n_on = W                        # start fully provisioned
        cool_until = 0.0
        prov_time = 0.0
        snap = np.zeros(N_BINS, dtype=np.int64)

    def set_rates(w: int) -> None:
        ts = tasks[w]
        spd = float(speeds[w]) if fleet_on else 1.0
        if not ts:
            return
        if late:
            for t in ts:
                t.rate = spd
            return
        rs = res.rates([t.remaining for t in ts], [t.seq for t in ts])
        for t, r in zip(ts, rs):
            t.rate = r * spd if fleet_on else r

    def start_task(w: int, arr_idx: int, start_service: bool) -> None:
        """Place arrival ``arr_idx`` on worker ``w`` (slot already free)."""
        f = int(wl.func[arr_idx])
        avail = int(warm[w, f]) if life is None \
            else life.materialized_at(w, f, warm[w, f], now)
        evicted = False
        if avail > 0:
            warm[w, f] -= 1
            is_cold = False
        else:
            is_cold = True
            idle = int(warm[w].sum()) if life is None \
                else int(life.eff_row(warm[w], w, now).sum())
            if len(tasks[w]) + idle >= S:      # evict an idle executor
                # victim: most idle executors (legacy) / LRU pool
                # (lifecycle) — first index breaks ties, the contract
                # shared with the vectorized engine
                victim = int(np.argmax(warm[w])) if life is None \
                    else life.evict_victim(warm[w], w, now)
                warm[w, victim] -= 1
                evicted = True
        if tel is not None:
            on_place_np(tel, w, is_cold, evicted)
        if tl is not None:
            tl_on_place_np(tl, now, is_cold, evicted)
        cold[arr_idx] = is_cold
        worker_of[arr_idx] = w
        svc = float(wl.service[arr_idx])
        if is_cold:
            svc += cluster.cold_start_penalty if life is None \
                else life.cold_cost(f, cluster.cold_start_penalty)
        if life is not None:
            # adaptive keep-alive observes the placed pool's idle age
            # AFTER the warm/cold decision (same order as the
            # vectorized engine's in-place observation block)
            life.observe_place(w, f, now)
        tasks[w].append(_Task(arr_idx=arr_idx, func=f,
                              arrival=float(wl.arrival[arr_idx]),
                              remaining=svc, seq=arr_idx))

    def pop_queue() -> None:
        """Dispatch queued invocations to workers with free cores."""
        while queue:
            loads = [len(tasks[w]) for w in range(W)]
            w = int(np.argmin(loads))
            if loads[w] >= C:
                break
            start_task(w, queue.pop(0), True)

    def advance(dt: float) -> None:
        nonlocal now, server_time, core_time, lb_state
        dt_left = dt
        while True:
            any_task = any(tasks[w] for w in range(W))
            if not any_task:
                if late:
                    pop_queue()
                    if any(tasks[w] for w in range(W)):
                        continue
                break
            for w in range(W):
                set_rates(w)
            tau = dt_left
            for w in range(W):
                for t in tasks[w]:
                    if t.rate > 0:
                        tau = min(tau, t.remaining / t.rate)
            if tau <= 0 and dt_left <= 0:
                break
            tau = max(tau, 0.0)
            # integrals with pre-advance occupancy (rates constant over tau)
            server_time += tau * sum(1 for w in range(W) if tasks[w])
            core_time += tau * sum(min(len(tasks[w]), C) for w in range(W))
            if tel is not None:
                on_advance_np(
                    tel, tau,
                    np.array([bool(tasks[w]) for w in range(W)]),
                    np.array([len(tasks[w]) for w in range(W)]),
                    len(queue))
            if tl is not None:
                # windowed twin: the whole tau slice credits the window
                # of its start (left-start convention, same as the scan
                # engine)
                tl_on_advance_np(
                    tl, now, tau,
                    np.array([bool(tasks[w]) for w in range(W)]),
                    len(queue))
            now += tau
            dt_left -= tau
            for w in range(W):
                survivors = []
                n_alive = len(tasks[w])
                for t in tasks[w]:
                    t.remaining -= t.rate * tau
                    if t.remaining <= EPS:
                        response[t.arr_idx] = now - t.arrival
                        if tel is not None:
                            on_complete_np(tel, response[t.arr_idx],
                                           float(wl.service[t.arr_idx]),
                                           t.arr_idx, tel_cutoff)
                        if tl is not None:
                            # all completions (no warmup cutoff), in the
                            # window of the completion time
                            tl_on_complete_np(
                                tl, now, response[t.arr_idx],
                                float(wl.service[t.arr_idx]))
                        if life is None:
                            warm[w, t.func] += 1
                        else:
                            budget_evicted = life.on_complete(
                                warm, w, t.func, now)
                            if budget_evicted:
                                if tel is not None:
                                    on_evict_np(tel)
                                if tl is not None:
                                    tl_on_evict_np(tl, now)
                        n_alive -= 1
                        if lb_state is not None:
                            # effective (wall-clock-equivalent) duration
                            # when the fleet is heterogeneous — one f64
                            # division, bitwise ≡ the scan engine's
                            svc_obs = wl.service[t.arr_idx] / speeds[w] \
                                if fleet_on else wl.service[t.arr_idx]
                            lb_state = res.on_complete(
                                lb_state, w, t.func, float(svc_obs),
                                n_alive)
                    else:
                        survivors.append(t)
                tasks[w] = survivors
            if late:
                pop_queue()
            if dt_left <= 0:
                break

    for i in range(N):
        t_i = float(wl.arrival[i])
        if auto_on:
            # provisioned-time integral over [now, t_i] at the current
            # n_on (decisions only take effect at arrival boundaries)
            prov_time += (t_i - now) * float(n_on)
        if tl is not None:
            # windowed provisioned core-seconds over the same interval,
            # credited to the interval-start window (same operand order
            # as the scan engine: (dt × n_prov) × C)
            n_prov = float(n_on) if auto_on else float(W)
            tl_on_prov_np(tl, now, (t_i - now) * n_prov * float(C))
        advance(t_i - now)
        now = t_i  # guard drift
        active = np.array([len(tasks[w]) for w in range(W)])
        if late:
            if tl is not None:
                tl_on_arrival_np(tl, t_i, W)
            if active.min() < C:
                start_task(int(np.argmin(active)), i, True)
            else:
                queue.append(i)
        else:
            f = int(wl.func[i])
            wcol = warm[:, f] if life is None \
                else life.materialized_col(warm[:, f], f, now)
            sel_active = active
            if auto_on:
                # autoscale decision: slowdown-sketch window since the
                # last snapshot, gated by cooldown + non-empty window —
                # same gating (and decide ops) as the scan engine
                window = tel["slow_hist"] - snap
                if t_i >= cool_until and int(window.sum()) >= 1:
                    n_new = int(auto_decide(n_on, window))
                    if tl is not None and n_new != n_on:
                        # log the level change with the sensor p99 the
                        # controller read off the same window
                        tl_event_np(tl, t_i, EV_AUTOSCALE, n_new,
                                    sensor_p99_np(window))
                    n_on = n_new
                    cool_until = t_i + auto_cool
                    snap = tel["slow_hist"].copy()
                # deprovisioned workers are masked slot-full at
                # selection; their running tasks drain normally
                sel_active = np.where(np.arange(W) < n_on, active, S)
            if tl is not None:
                # post-decision level, last write wins in the window
                tl_on_arrival_np(tl, t_i, n_on if auto_on else W)
                if flip_on:
                    # the hybrid balancer packs while any selectable
                    # worker still has a free core (hermes_score's
                    # low_load read on the masked active vector)
                    new_mode = int(bool((sel_active < C).any()))
                    if new_mode != int(tl["mode"]):
                        tl_event_np(tl, t_i, EV_MODE_FLIP, new_mode,
                                    float("nan"))
                    tl["mode"] = np.int32(new_mode)
            if lb_state is not None:
                w, lb_state = res.select(lb_state, sel_active, wcol, f,
                                         wl.func_home, float(wl.u_lb[i]), i)
            else:
                w = res.select(sel_active, wcol, f, wl.func_home,
                               float(wl.u_lb[i]), i)
            if w < 0:
                rejected[i] = True
                if tel is not None:
                    on_reject_np(tel)
                if tl is not None:
                    tl_on_reject_np(tl, t_i)
            else:
                start_task(w, i, True)
        if chunk_hook is not None and chunk_size and \
                ((i + 1) % chunk_size == 0 or i + 1 == N):
            # the streaming engine's chunk boundary: the last arrival
            # of the segment has been placed, nothing else has run
            chunk_hook(i // chunk_size,
                       None if tel is None
                       else {k: np.copy(v) for k, v in tel.items()},
                       now)

    t_last = now
    advance(math.inf)  # drain
    if auto_on:
        # drain tail: the fleet stays provisioned to the last completion
        prov_time += (now - t_last) * float(n_on)
        prov_core_s = prov_time * C
    else:
        prov_core_s = now * W * C
    if tl is not None:
        n_prov = float(n_on) if auto_on else float(W)
        tl_on_prov_np(tl, t_last, (now - t_last) * n_prov * float(C))
    return SimResult(response=response, cold=cold, rejected=rejected,
                     worker=worker_of, server_time=server_time,
                     core_time=core_time, end_time=now,
                     telemetry=None if tel is None
                     else TelemetryResult.from_state(tel, cfg=telemetry),
                     prov_core_s=prov_core_s,
                     timeline=None if tl is None
                     else TimelineResult.from_state(tl, cfg=timeline))


def simulate_ref_chunks(policy: PolicySpec, cluster: ClusterCfg,
                        wl: Workload, *, chunk_size: int,
                        telemetry: TelemetryCfg | None = None
                        ) -> tuple[SimResult, list[dict | None]]:
    """Oracle replay of the streaming engine's segment boundaries.

    Runs :func:`simulate_ref` once, snapshotting the telemetry plane at
    every chunk boundary (after the segment's last arrival has been
    placed).  Returns ``(result, snapshots)`` — one snapshot per chunk,
    each a deep-copied telemetry dict (or None with telemetry off).
    The integer histogram/counter planes are bitwise-comparable to the
    jax engine's carry at the same boundary, so a chunked jax run and
    this replay agreeing *per segment* is the streaming parity gate.
    """
    snaps: list[dict | None] = []
    res = simulate_ref(
        policy, cluster, wl, telemetry=telemetry,
        chunk_size=int(chunk_size),
        chunk_hook=lambda c, tel_snap, now: snaps.append(tel_snap))
    return res, snaps
