"""Cluster configuration for the scheduling simulator and serving runtime."""
from __future__ import annotations

from typing import NamedTuple, Optional

from repro.fleet.config import FleetCfg
from repro.lifecycle.config import LifecycleCfg


class ClusterCfg(NamedTuple):
    """A homogeneous cluster of ``n_workers`` machines.

    Mirrors the paper's testbed model (§3.2, §6.1): each worker has
    ``cores`` CPU cores and can host up to ``capacity_factor × cores``
    invocations (running + waiting) — the memory-capacity model OpenWhisk
    uses (26,624 MB / 256 MB = 104 ≈ 8×12 cores in the paper's setup).
    """

    n_workers: int = 4
    cores: int = 12
    capacity_factor: int = 8
    # Cold-start penalty added to an invocation's service time when no warm
    # executor exists on the chosen worker.  The paper's *simulator* sets
    # this to 0 ("does not model overheads such as the container start-up
    # time", §3.2); the OpenWhisk runtime experiences a real one, which the
    # serving layer models explicitly.
    cold_start_penalty: float = 0.0
    # Container-lifecycle model (repro.lifecycle): keep-alive policy,
    # warm-pool budget and cold-start preset.  ``None`` — the default —
    # is the pre-lifecycle model, bit-for-bit: an ever-growing warm set
    # with no idle-timeout and the scalar penalty above.
    lifecycle: Optional[LifecycleCfg] = None
    # Heterogeneous-fleet model (repro.fleet): per-worker speed/memory
    # vectors and the autoscale control loop.  ``None`` — the default —
    # is the pre-fleet model, bit-for-bit: every worker at unit speed
    # and a fixed active set of all ``n_workers``.
    fleet: Optional[FleetCfg] = None

    @property
    def slots(self) -> int:
        """Max invocations (running + queued) a worker can host."""
        return self.capacity_factor * self.cores

    @property
    def total_cores(self) -> int:
        return self.n_workers * self.cores

    def validate(self) -> "ClusterCfg":
        """Reject impossible configs with named errors.

        Called by ``build_simulator`` / ``resolve`` so a bad cluster
        fails at the API boundary instead of as an opaque numpy
        broadcast error deep in the scan.  Returns ``self`` so call
        sites can chain.
        """
        if int(self.n_workers) <= 0:
            raise ValueError(
                f"ClusterCfg.n_workers must be positive, got "
                f"{self.n_workers}")
        if int(self.cores) <= 0:
            raise ValueError(
                f"ClusterCfg.cores must be positive, got {self.cores}")
        if int(self.capacity_factor) <= 0:
            raise ValueError(
                f"ClusterCfg.capacity_factor must be positive, got "
                f"{self.capacity_factor}")
        if self.fleet is not None:
            W = int(self.n_workers)
            for field in ("speed", "mem"):
                vec = getattr(self.fleet, field)
                if not vec:
                    continue
                if len(vec) != W:
                    raise ValueError(
                        f"FleetCfg.{field} has {len(vec)} entries for "
                        f"n_workers={W}, got {tuple(vec)}")
                if any(not v > 0 for v in vec):
                    raise ValueError(
                        f"FleetCfg.{field} entries must be positive, "
                        f"got {tuple(vec)}")
            if not 1 <= int(self.fleet.min_workers) <= W:
                raise ValueError(
                    f"FleetCfg.min_workers must be in [1, n_workers="
                    f"{W}], got {self.fleet.min_workers}")
            # registry-validated names fail with their own named errors
            from repro.fleet import parse_autoscale, parse_fleet_preset
            if not self.fleet.speed:
                parse_fleet_preset(self.fleet.preset)
            parse_autoscale(self.fleet.autoscale)
        return self


# Setups used in the paper.
PAPER_SMALL = ClusterCfg(n_workers=4, cores=12)      # §3.3, Fig 2/3
PAPER_LARGE = ClusterCfg(n_workers=100, cores=12)    # §3.5, Fig 4
PAPER_TESTBED = ClusterCfg(n_workers=8, cores=12)    # §6, 8 invokers
