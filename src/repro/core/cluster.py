"""Cluster configuration for the scheduling simulator and serving runtime."""
from __future__ import annotations

from typing import NamedTuple, Optional

from repro.lifecycle.config import LifecycleCfg


class ClusterCfg(NamedTuple):
    """A homogeneous cluster of ``n_workers`` machines.

    Mirrors the paper's testbed model (§3.2, §6.1): each worker has
    ``cores`` CPU cores and can host up to ``capacity_factor × cores``
    invocations (running + waiting) — the memory-capacity model OpenWhisk
    uses (26,624 MB / 256 MB = 104 ≈ 8×12 cores in the paper's setup).
    """

    n_workers: int = 4
    cores: int = 12
    capacity_factor: int = 8
    # Cold-start penalty added to an invocation's service time when no warm
    # executor exists on the chosen worker.  The paper's *simulator* sets
    # this to 0 ("does not model overheads such as the container start-up
    # time", §3.2); the OpenWhisk runtime experiences a real one, which the
    # serving layer models explicitly.
    cold_start_penalty: float = 0.0
    # Container-lifecycle model (repro.lifecycle): keep-alive policy,
    # warm-pool budget and cold-start preset.  ``None`` — the default —
    # is the pre-lifecycle model, bit-for-bit: an ever-growing warm set
    # with no idle-timeout and the scalar penalty above.
    lifecycle: Optional[LifecycleCfg] = None

    @property
    def slots(self) -> int:
        """Max invocations (running + queued) a worker can host."""
        return self.capacity_factor * self.cores

    @property
    def total_cores(self) -> int:
        return self.n_workers * self.cores


# Setups used in the paper.
PAPER_SMALL = ClusterCfg(n_workers=4, cores=12)      # §3.3, Fig 2/3
PAPER_LARGE = ClusterCfg(n_workers=100, cores=12)    # §3.5, Fig 4
PAPER_TESTBED = ClusterCfg(n_workers=8, cores=12)    # §6, 8 invokers
