"""Metrics for the scheduling study — slowdown first (paper §3.3).

``slowdown = response_time / execution_time`` — the paper's headline metric:
tail latency hides head-of-line blocking of short functions behind long
ones; tail slowdown exposes it.

Two altitudes:

* :func:`summarize` — one ``(policy, workload)`` run → :class:`Summary`.
* :func:`summarize_batch` — ``R`` stacked replications (the batched
  engine's output) → :class:`BatchSummary`: per-replication summaries,
  a pooled summary over the combined task population, and
  across-replication mean ± 95 % confidence intervals for every scalar
  metric (Student-t for small R).

Both operate on materialized per-task arrays.  For horizons where those
arrays are the memory bottleneck, the streaming engine
(:mod:`repro.core.streaming`) skips them entirely and accumulates the
same metrics online — exact counters for means/rates plus telemetry
histogram sketches (:class:`repro.telemetry.TelemetryResult`) for
percentiles.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Summary:
    n: int
    n_rejected: int
    cold_frac: float          # fraction of completed invocations cold-started
    lat_p50: float
    lat_p99: float
    slow_p50: float
    slow_p99: float
    slow_mean: float
    mean_servers: float       # time-averaged # of busy servers
    mean_cores: float         # time-averaged # of busy cores
    throughput: float         # completed invocations / horizon

    def row(self) -> dict:
        return dataclasses.asdict(self)


def summarize(response: np.ndarray, service: np.ndarray,
              cold: np.ndarray, rejected: np.ndarray,
              server_time: float, core_time: float, end_time: float,
              *, warmup_frac: float = 0.1,
              arrival: np.ndarray | None = None) -> Summary:
    """Aggregate per-task results.

    ``warmup_frac`` drops the earliest fraction of arrivals (cold system)
    so steady-state percentiles are not polluted by ramp-up, mirroring the
    paper's 1-hour steady-state runs.
    """
    n = len(response)
    lo = int(n * warmup_frac)
    sel = np.ones(n, dtype=bool)
    sel[:lo] = False
    ok = sel & ~rejected & np.isfinite(response)
    resp = response[ok]
    svc = np.maximum(service[ok], 1e-12)
    slow = resp / svc
    horizon = max(end_time, 1e-12)

    def pct(x, q):
        return float(np.percentile(x, q)) if len(x) else float("nan")

    return Summary(
        n=int(ok.sum()),
        n_rejected=int((rejected & sel).sum()),
        cold_frac=float(cold[ok].mean()) if ok.any() else float("nan"),
        lat_p50=pct(resp, 50), lat_p99=pct(resp, 99),
        slow_p50=pct(slow, 50), slow_p99=pct(slow, 99),
        slow_mean=float(slow.mean()) if len(slow) else float("nan"),
        mean_servers=server_time / horizon,
        mean_cores=core_time / horizon,
        throughput=float(np.isfinite(response).sum()) / horizon,
    )


def _check_warmup_contract(out, kw) -> None:
    """Raise :class:`~repro.telemetry.WarmupMismatchError` when the
    engine's telemetry sketches were populated with a different warmup
    cutoff than the one this summarize call is about to apply — the
    two would silently describe different task populations."""
    tel = getattr(out, "telemetry", None)
    if tel is None or getattr(tel, "cfg", None) is None:
        return
    wf = float(kw.get("warmup_frac", 0.1))
    if float(tel.cfg.warmup_frac) != wf:
        from repro.telemetry import WarmupMismatchError
        raise WarmupMismatchError(tel.cfg.warmup_frac, wf)


def summarize_sim(out, wl, **kw) -> Summary:
    """Convenience wrapper over a SimOutput + Workload pair."""
    _check_warmup_contract(out, kw)
    return summarize(out.response, wl.service, out.cold, out.rejected,
                     out.server_time, out.core_time, out.end_time, **kw)


# --------------------------------------------------------------------------
# Batched (replication-axis-aware) summaries
# --------------------------------------------------------------------------

# Two-sided 95 % Student-t critical values by degrees of freedom; the
# normal 1.96 beyond the table.  Inlined to keep metrics scipy-free.
_T95 = {1: 12.706, 2: 4.303, 3: 3.182, 4: 2.776, 5: 2.571, 6: 2.447,
        7: 2.365, 8: 2.306, 9: 2.262, 10: 2.228, 11: 2.201, 12: 2.179,
        13: 2.160, 14: 2.145, 15: 2.131, 16: 2.120, 17: 2.110, 18: 2.101,
        19: 2.093, 20: 2.086, 25: 2.060, 30: 2.042}


def _t95(df: int) -> float:
    if df <= 0:
        return float("nan")
    if df in _T95:
        return _T95[df]
    if df < 25:
        return _T95[20]
    if df < 30:
        return _T95[25]
    return 1.96


@dataclasses.dataclass(frozen=True)
class Stat:
    """Across-replication mean with a 95 % confidence half-width."""

    mean: float
    ci95: float     # half-width; 0 for R=1 (no spread estimate)

    @property
    def lo(self) -> float:
        return self.mean - self.ci95

    @property
    def hi(self) -> float:
        return self.mean + self.ci95


# Summary fields that are meaningful to average across replications.
STAT_FIELDS = ("cold_frac", "lat_p50", "lat_p99", "slow_p50", "slow_p99",
               "slow_mean", "mean_servers", "mean_cores", "throughput")


@dataclasses.dataclass(frozen=True)
class BatchSummary:
    per_rep: tuple            # (R,) Summary — one per replication
    pooled: Summary           # percentiles over the combined task population
    stats: dict               # field name -> Stat (mean ± CI over reps)

    @property
    def n_reps(self) -> int:
        return len(self.per_rep)

    def row(self) -> dict:
        """Flat dict: pooled metrics + per-field mean/ci95 columns."""
        out = self.pooled.row()
        for k, st in self.stats.items():
            out[f"{k}_mean"] = st.mean
            out[f"{k}_ci95"] = st.ci95
        return out


def _stats_over(per_rep) -> dict:
    stats = {}
    for fld in STAT_FIELDS:
        vals = np.array([getattr(s, fld) for s in per_rep], dtype=float)
        vals = vals[np.isfinite(vals)]
        if len(vals) == 0:
            stats[fld] = Stat(float("nan"), float("nan"))
            continue
        mean = float(vals.mean())
        if len(vals) < 2:
            stats[fld] = Stat(mean, 0.0)
        else:
            sem = float(vals.std(ddof=1)) / np.sqrt(len(vals))
            stats[fld] = Stat(mean, _t95(len(vals) - 1) * sem)
    return stats


def summarize_batch(response: np.ndarray, service: np.ndarray,
                    cold: np.ndarray, rejected: np.ndarray,
                    server_time: np.ndarray, core_time: np.ndarray,
                    end_time: np.ndarray, *, warmup_frac: float = 0.1
                    ) -> BatchSummary:
    """Aggregate ``(R, N)`` stacked results along both axes.

    Per-replication :class:`Summary` rows use the same warmup handling as
    :func:`summarize`; the pooled summary treats the R × N tasks (after
    per-replication warmup drop) as one population and time-weights the
    utilization integrals by each replication's horizon.
    """
    R = response.shape[0]
    per_rep = tuple(
        summarize(response[r], service[r], cold[r], rejected[r],
                  float(server_time[r]), float(core_time[r]),
                  float(end_time[r]), warmup_frac=warmup_frac)
        for r in range(R))

    n = response.shape[1]
    lo = int(n * warmup_frac)
    sel = np.ones((R, n), dtype=bool)
    sel[:, :lo] = False
    ok = sel & ~rejected & np.isfinite(response)
    resp = response[ok]
    svc = np.maximum(service[ok], 1e-12)
    slow = resp / svc
    horizon = max(float(np.sum(end_time)), 1e-12)

    def pct(x, q):
        return float(np.percentile(x, q)) if len(x) else float("nan")

    pooled = Summary(
        n=int(ok.sum()),
        n_rejected=int((rejected & sel).sum()),
        cold_frac=float(cold[ok].mean()) if ok.any() else float("nan"),
        lat_p50=pct(resp, 50), lat_p99=pct(resp, 99),
        slow_p50=pct(slow, 50), slow_p99=pct(slow, 99),
        slow_mean=float(slow.mean()) if len(slow) else float("nan"),
        mean_servers=float(np.sum(server_time)) / horizon,
        mean_cores=float(np.sum(core_time)) / horizon,
        throughput=float(np.isfinite(response).sum()) / horizon,
    )
    return BatchSummary(per_rep=per_rep, pooled=pooled,
                        stats=_stats_over(per_rep))


def summarize_batch_sim(out, wb, **kw) -> BatchSummary:
    """Convenience wrapper over a BatchSimOutput + WorkloadBatch pair."""
    _check_warmup_contract(out, kw)
    return summarize_batch(out.response, wb.service, out.cold, out.rejected,
                           out.server_time, out.core_time, out.end_time,
                           **kw)
