"""Metrics for the scheduling study — slowdown first (paper §3.3).

``slowdown = response_time / execution_time`` — the paper's headline metric:
tail latency hides head-of-line blocking of short functions behind long
ones; tail slowdown exposes it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Summary:
    n: int
    n_rejected: int
    cold_frac: float          # fraction of completed invocations cold-started
    lat_p50: float
    lat_p99: float
    slow_p50: float
    slow_p99: float
    slow_mean: float
    mean_servers: float       # time-averaged # of busy servers
    mean_cores: float         # time-averaged # of busy cores
    throughput: float         # completed invocations / horizon

    def row(self) -> dict:
        return dataclasses.asdict(self)


def summarize(response: np.ndarray, service: np.ndarray,
              cold: np.ndarray, rejected: np.ndarray,
              server_time: float, core_time: float, end_time: float,
              *, warmup_frac: float = 0.1,
              arrival: np.ndarray | None = None) -> Summary:
    """Aggregate per-task results.

    ``warmup_frac`` drops the earliest fraction of arrivals (cold system)
    so steady-state percentiles are not polluted by ramp-up, mirroring the
    paper's 1-hour steady-state runs.
    """
    n = len(response)
    lo = int(n * warmup_frac)
    sel = np.ones(n, dtype=bool)
    sel[:lo] = False
    ok = sel & ~rejected & np.isfinite(response)
    resp = response[ok]
    svc = np.maximum(service[ok], 1e-12)
    slow = resp / svc
    horizon = max(end_time, 1e-12)

    def pct(x, q):
        return float(np.percentile(x, q)) if len(x) else float("nan")

    return Summary(
        n=int(ok.sum()),
        n_rejected=int((rejected & sel).sum()),
        cold_frac=float(cold[ok].mean()) if ok.any() else float("nan"),
        lat_p50=pct(resp, 50), lat_p99=pct(resp, 99),
        slow_p50=pct(slow, 50), slow_p99=pct(slow, 99),
        slow_mean=float(slow.mean()) if len(slow) else float("nan"),
        mean_servers=server_time / horizon,
        mean_cores=core_time / horizon,
        throughput=float(np.isfinite(response).sum()) / horizon,
    )


def summarize_sim(out, wl, **kw) -> Summary:
    """Convenience wrapper over a SimOutput + Workload pair."""
    return summarize(out.response, wl.service, out.cold, out.rejected,
                     out.server_time, out.core_time, out.end_time, **kw)
