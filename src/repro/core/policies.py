"""Load-balancing worker selection — numpy and JAX twins.

Both implementations follow the identical deterministic contract documented
in :mod:`repro.core.sim_ref` so the two simulators can be compared
task-by-task.  Selection returns a worker index, or ``-1`` when every
worker's slots (busy + local queue) are exhausted (OpenWhisk returns an
error in that case; the simulators count a rejection).

The Hermes policy (§4.2) is scored lexicographically so it can run
branch-free inside jitted code and inside the Pallas controller kernel:

* low-load mode (some worker has a free core) — among workers with a free
  core, prefer class ``3`` = non-empty with a warm executor for the
  function, ``2`` = non-empty, ``1`` = empty with warm executor, ``0`` =
  empty; within a class prefer the *most* loaded (packing / fill-up).
* high-load mode (no free core anywhere) — least-loaded among workers with
  a free slot, warm executor breaks ties.
"""
from __future__ import annotations

import numpy as np

from .taxonomy import LoadBalance

_INT_INF = np.int64(1 << 40)


# --------------------------------------------------------------------------
# numpy implementations (oracle)
# --------------------------------------------------------------------------

def hermes_score_np(active: np.ndarray, warm_f: np.ndarray, cores: int,
                    slots: int) -> tuple[np.ndarray, bool]:
    """Return (score vector to maximize, low_load_mode)."""
    has_core = active < cores
    low_load = bool(has_core.any())
    warm = warm_f > 0
    if low_load:
        nonempty = active > 0
        cls = np.where(nonempty, 2 + warm.astype(np.int64),
                       warm.astype(np.int64))
        score = cls * (slots + 1) + active
        score = np.where(has_core, score, -_INT_INF)
    else:
        has_slot = active < slots
        key = active.astype(np.int64) * 2 - warm.astype(np.int64)
        score = np.where(has_slot, -key, -_INT_INF)  # maximize = least loaded
    return score, low_load


def select_worker_np(balance: LoadBalance, active: np.ndarray,
                     warm: np.ndarray, func: int, func_home: np.ndarray,
                     u: float, cores: int, slots: int) -> int:
    W = active.shape[0]
    has_slot = active < slots
    if not has_slot.any():
        return -1
    if balance == LoadBalance.LOCALITY:
        home = int(func_home[func])
        ring = (home + np.arange(W)) % W
        free = has_slot[ring]
        return int(ring[int(np.argmax(free))])
    if balance == LoadBalance.RANDOM:
        free_idx = np.nonzero(has_slot)[0]
        return int(free_idx[min(int(u * len(free_idx)), len(free_idx) - 1)])
    if balance == LoadBalance.LEAST_LOADED:
        key = np.where(has_slot, active, _INT_INF)
        return int(np.argmin(key))
    # HYBRID (Hermes)
    score, _ = hermes_score_np(active, warm[:, func], cores, slots)
    return int(np.argmax(score))


# --------------------------------------------------------------------------
# JAX implementations — imported lazily so numpy-only users avoid jax init
# --------------------------------------------------------------------------

def make_select_worker_jax(balance: LoadBalance, cores: int, slots: int):
    """Build a jittable ``(active, warm_col, func, func_home, u) -> w``.

    ``warm_col`` is the ``warm[:, func]`` column; returns int32 worker id,
    -1 when all full.  Deterministic contract identical to numpy above.
    """
    import jax.numpy as jnp

    BIG = jnp.int32(1 << 30)

    def _guard(w, has_slot):
        return jnp.where(has_slot.any(), w, -1).astype(jnp.int32)

    if balance == LoadBalance.LOCALITY:
        def select(active, warm_col, func, func_home, u):
            W = active.shape[0]
            has_slot = active < slots
            home = func_home[func]
            ring = (home + jnp.arange(W, dtype=jnp.int32)) % W
            free = has_slot[ring]
            w = ring[jnp.argmax(free)]
            return _guard(w, has_slot)
    elif balance == LoadBalance.RANDOM:
        def select(active, warm_col, func, func_home, u):
            has_slot = active < slots
            k = has_slot.sum()
            target = jnp.minimum((u * k).astype(jnp.int32), k - 1)
            # index of the (target+1)-th free worker
            csum = jnp.cumsum(has_slot.astype(jnp.int32)) - 1
            hit = has_slot & (csum == target)
            w = jnp.argmax(hit)
            return _guard(w, has_slot)
    elif balance == LoadBalance.LEAST_LOADED:
        def select(active, warm_col, func, func_home, u):
            has_slot = active < slots
            key = jnp.where(has_slot, active, BIG)
            return _guard(jnp.argmin(key), has_slot)
    elif balance == LoadBalance.HYBRID:
        def select(active, warm_col, func, func_home, u):
            active = active.astype(jnp.int32)
            has_slot = active < slots
            has_core = active < cores
            warm = (warm_col > 0).astype(jnp.int32)
            nonempty = (active > 0).astype(jnp.int32)
            cls = jnp.where(nonempty > 0, 2 + warm, warm)
            lo_score = jnp.where(has_core, cls * (slots + 1) + active, -BIG)
            hi_key = active * 2 - warm
            hi_score = jnp.where(has_slot, -hi_key, -BIG)
            score = jnp.where(has_core.any(), lo_score, hi_score)
            return _guard(jnp.argmax(score), has_slot)
    else:  # pragma: no cover
        raise ValueError(balance)
    return select
