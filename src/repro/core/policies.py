"""Load-balancing worker selection — compatibility shims over the registry.

The implementations live in :mod:`repro.policy` (balancers registered by
name, with ``np`` / ``jax`` / ``pallas`` backends sharing one
deterministic contract — see :mod:`repro.policy.registry`).  This module
keeps the historical call signatures used by tests, benchmarks and the
kernels' oracles:

* :func:`select_worker_np` — per-arrival numpy selection taking the full
  ``warm [W, F]`` matrix and a :class:`~repro.core.taxonomy.LoadBalance`
  member (or any registered balancer name).
* :func:`make_select_worker_jax` — jittable selection factory with the
  pre-registry 5-argument closure signature.
* :func:`hermes_score_np` — the Hermes lexicographic score (re-exported;
  the Pallas kernel's oracle).
"""
from __future__ import annotations

import numpy as np

from repro.policy import get_balancer, np_select, jax_select
from repro.policy.balancers import hermes_score_np  # noqa: F401 (re-export)


def _reject_stateful(balance):
    bal = get_balancer(balance)
    if bal.stateful:
        raise ValueError(
            f"balancer {bal.name!r} carries state (init_state registered); "
            f"the stateless compat shims cannot drive it — use "
            f"repro.policy.resolve and thread the state explicitly")


def select_worker_np(balance, active: np.ndarray, warm: np.ndarray,
                     func: int, func_home: np.ndarray, u: float, cores: int,
                     slots: int, idx: int = 0) -> int:
    """Select a worker with ``balance`` (name or enum); -1 when all full."""
    _reject_stateful(balance)
    sel = np_select(balance, cores, slots)
    return sel(active, warm[:, func], func, func_home, u, idx)


def make_select_worker_jax(balance, cores: int, slots: int):
    """Build a jittable ``(active, warm_col, func, func_home, u) -> w``.

    ``warm_col`` is the ``warm[:, func]`` column; returns int32 worker id,
    -1 when all full.  Deterministic contract identical to numpy above.
    (The registry's native closures additionally take the arrival index
    ``idx``; this wrapper defaults it to 0, which is only correct for
    balancers that ignore it — for an idx-dependent balancer like ``RR``
    pass the arrival sequence number explicitly or the rotation
    degenerates to a fixed probe from worker 0.)
    """
    _reject_stateful(balance)
    sel = jax_select(balance, cores, slots)

    def select(active, warm_col, func, func_home, u, idx=0):
        return sel(active, warm_col, func, func_home, u, idx)
    return select
