"""Load-balancing worker selection — compatibility shims over the registry.

The implementations live in :mod:`repro.policy` (balancers registered by
name, with ``np`` / ``jax`` / ``pallas`` backends sharing one
deterministic contract — see :mod:`repro.policy.registry`).  This module
keeps the historical call signatures used by tests, benchmarks and the
kernels' oracles:

* :func:`select_worker_np` — per-arrival numpy selection taking the full
  ``warm [W, F]`` matrix and a :class:`~repro.core.taxonomy.LoadBalance`
  member (or any registered balancer name).
* :func:`make_select_worker_jax` — jittable selection factory with the
  pre-registry 5-argument closure signature.
* :func:`hermes_score_np` — the Hermes lexicographic score (re-exported;
  the Pallas kernel's oracle).
"""
from __future__ import annotations

import numpy as np

from repro.policy import np_select, jax_select
from repro.policy.balancers import hermes_score_np  # noqa: F401 (re-export)


def select_worker_np(balance, active: np.ndarray, warm: np.ndarray,
                     func: int, func_home: np.ndarray, u: float, cores: int,
                     slots: int, idx: int = 0) -> int:
    """Select a worker with ``balance`` (name or enum); -1 when all full."""
    sel = np_select(balance, cores, slots)
    return sel(active, warm[:, func], func, func_home, u, idx)


def make_select_worker_jax(balance, cores: int, slots: int):
    """Build a jittable ``(active, warm_col, func, func_home, u) -> w``.

    ``warm_col`` is the ``warm[:, func]`` column; returns int32 worker id,
    -1 when all full.  Deterministic contract identical to numpy above.
    (The registry's native closures additionally take the arrival index
    ``idx``; this wrapper pins it to 0 for balancers that ignore it.)
    """
    sel = jax_select(balance, cores, slots)

    def select(active, warm_col, func, func_home, u):
        return sel(active, warm_col, func, func_home, u, 0)
    return select
