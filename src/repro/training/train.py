"""Train-step construction: grad accumulation, SPMD sharding, cross-pod
compressed gradient sync, fault-tolerant driver loop.

Two step builders:

* :func:`build_train_step` — pure-SPMD: autodiff's implicit data-parallel
  all-reduce handles gradient sync (XLA overlaps it with the backward
  pass); microbatch grad accumulation via an inner scan.
* :func:`build_train_step_compressed` — partial-manual ``shard_map`` over
  the ``pod`` axis only: each pod computes gradients on its sub-batch
  (data/model axes stay under GSPMD), then the *cross-pod* sync runs the
  int8 error-feedback compressor from :mod:`repro.training.compression` —
  the expensive inter-pod links carry 4× less traffic.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distribution.sharding import current_ctx, pspec, shard_map_compat
from repro.training.compression import ef_compress_sync, init_error_feedback
from repro.training.optimizer import (OptCfg, OptState, adamw_update,
                                      init_opt_state)


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    err: Any | None          # error-feedback buffers (compressed sync only)


def init_train_state(model, rng, *, compressed: bool = False) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=init_opt_state(params),
                      err=init_error_feedback(params) if compressed
                      else None)


def state_specs(model, *, compressed: bool = False):
    """PartitionSpec pytree matching a TrainState (under the active ctx)."""
    ps = model.param_specs()
    return TrainState(
        params=ps,
        opt=OptState(m=ps, v=ps, step=P()),
        err=ps if compressed else None)


def _accum_grads(loss_fn, params, tokens, labels, microbatches: int):
    """Mean loss/grads over ``microbatches`` sequential slices of batch."""
    if microbatches <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
        return loss, grads
    B = tokens.shape[0]
    assert B % microbatches == 0
    mb = B // microbatches
    tk = tokens.reshape(microbatches, mb, *tokens.shape[1:])
    lb = labels.reshape(microbatches, mb, *labels.shape[1:])

    def body(carry, x):
        loss_acc, g_acc = carry
        t, l = x
        loss, g = jax.value_and_grad(loss_fn)(params, t, l)
        return (loss_acc + loss,
                jax.tree.map(jnp.add, g_acc, g)), None

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero_g),
                                    (tk, lb))
    inv = 1.0 / microbatches
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


def build_train_step(model, opt_cfg: OptCfg, *, microbatches: int = 1):
    """Standard SPMD train step: (state, tokens, labels) → (state, metrics)."""

    def train_step(state: TrainState, tokens, labels):
        loss, grads = _accum_grads(model.loss, state.params, tokens, labels,
                                   microbatches)
        new_p, new_opt, metrics = adamw_update(opt_cfg, state.params, grads,
                                               state.opt)
        metrics["loss"] = loss
        return TrainState(new_p, new_opt, state.err), metrics

    return train_step


def build_train_step_compressed(model, opt_cfg: OptCfg, *,
                                microbatches: int = 1):
    """Cross-pod int8 error-feedback gradient sync (multi-pod meshes).

    Requires an active sharding context whose mesh has a ``pod`` axis.
    The loss is averaged per pod; the compressed psum then averages over
    pods, so gradients match the uncompressed step up to quantization.
    """
    from repro.distribution.sharding import ShardCtx, sharding_ctx
    ctx = current_ctx()
    assert ctx is not None and ctx.pod_axis is not None, \
        "compressed sync needs a multi-pod mesh context"
    pod = ctx.pod_axis
    mesh = ctx.mesh
    # Inside the pod-manual region the model must not reference the pod
    # axis (it is manual there); batch data-parallelism continues over
    # the in-pod data axis, model/data sharding stays GSPMD-auto.
    inner_rules = dict(ctx.rules)
    inner_rules["batch"] = "data"
    inner_ctx = ShardCtx(mesh=mesh, rules=inner_rules, dp_axes=("data",),
                         tp_axis=ctx.tp_axis, pod_axis=None)

    # Partial-manual shard_map on jax<0.5 (no jax.shard_map) trips an XLA
    # manual-subgroup check when sharding constraints or a layer-scan
    # appear under grad inside the auto region.  There: suspend the inner
    # constraints (GSPMD places the region; semantics unchanged) and
    # unroll the layer stack (identical params/math, scan-free HLO).
    from repro.distribution.sharding import no_sharding_ctx
    if hasattr(jax, "shard_map"):
        _inner_scope = lambda: sharding_ctx(inner_ctx)     # noqa: E731
    else:
        from repro.models.transformer import build_model
        model = build_model(model.cfg, layer_mode="unroll")
        _inner_scope = no_sharding_ctx

    def local(state: TrainState, tokens, labels):
        with _inner_scope():              # trace-time rebinding
            loss, grads = _accum_grads(model.loss, state.params, tokens,
                                       labels, microbatches)
            grads, new_err = ef_compress_sync(grads, state.err, pod)
            loss = jax.lax.pmean(loss, pod)
            new_p, new_opt, metrics = adamw_update(opt_cfg, state.params,
                                                   grads, state.opt)
        metrics["loss"] = loss
        return TrainState(new_p, new_opt, new_err), metrics

    # shard_map specs name only the manual axis: state replicated across
    # pods, batch split on its leading dim; everything else is auto.
    rep = jax.tree.map(lambda _: P(), model.param_specs(),
                       is_leaf=lambda s: isinstance(s, P))
    state_sp = TrainState(params=rep, opt=OptState(m=rep, v=rep, step=P()),
                          err=rep)
    batch_spec = P(pod)
    metric_sp = {"grad_norm": P(), "lr": P(), "loss": P()}
    return shard_map_compat(
        local, mesh,
        in_specs=(state_sp, batch_spec, batch_spec),
        out_specs=(state_sp, metric_sp),
        axis_names={pod}, check_vma=False)


# ---------------------------------------------------------------------------
# Fault-tolerant driver (checkpoint/restart around a step function)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunReport:
    steps_done: int
    restarts: int
    final_loss: float
    losses: list


def run_with_restarts(step_fn, state, data_iter, *, n_steps: int,
                      ckpt_mgr=None, ckpt_every: int = 50,
                      max_restarts: int = 3,
                      failure_hook=None) -> tuple[Any, RunReport]:
    """Run ``n_steps``, checkpointing every ``ckpt_every``; on an exception
    restore the last checkpoint and continue (node-failure semantics: any
    step may die; progress resumes from the last durable state).

    ``failure_hook(step)`` (tests) may raise to inject failures.
    ``data_iter(step)`` must be resumable by step index so replayed steps
    see identical data.
    """
    restarts = 0
    losses = []
    step = 0
    state0 = state                       # durable initial state (step 0)
    if ckpt_mgr is not None and ckpt_mgr.latest_step() is not None:
        state, step = ckpt_mgr.restore(state)
    while step < n_steps:
        try:
            if failure_hook is not None:
                failure_hook(step)
            tokens, labels = data_iter(step)
            state, metrics = step_fn(state, tokens, labels)
            losses.append(float(metrics["loss"]))
            step += 1
            if ckpt_mgr is not None and step % ckpt_every == 0:
                ckpt_mgr.save(state, step)
        except Exception:                                  # noqa: BLE001
            restarts += 1
            if restarts > max_restarts:
                raise
            if ckpt_mgr is None:
                raise
            if ckpt_mgr.latest_step() is None:
                state, step = state0, 0   # failed before first checkpoint
            else:
                state, step = ckpt_mgr.restore(state)
    if ckpt_mgr is not None:
        ckpt_mgr.save(state, step)
        ckpt_mgr.wait()
    return state, RunReport(steps_done=step, restarts=restarts,
                            final_loss=losses[-1] if losses else float("nan"),
                            losses=losses)
