"""Step-atomic, mesh-elastic checkpointing (no orbax in this env).

Format: one directory per step containing ``arrays.npz`` (flattened leaf
arrays keyed by tree path) + ``manifest.json``; written to ``<step>.tmp``
and committed with an atomic ``os.replace`` so a crash mid-save never
corrupts the latest checkpoint.  Arrays are saved *unsharded* (gathered
to host), so a checkpoint written on one mesh restores onto **any** mesh
— this is the elastic re-mesh path: ``restore(..., sharding_tree=...)``
re-places every leaf under the new mesh's NamedShardings.

Saving runs on a background thread (device_get + npz write off the
training thread); ``wait()`` joins before shutdown.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- write ---------------------------------------------------------

    def save(self, state, step: int, *, blocking: bool = False) -> None:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def _write():
            keys, leaves, _ = _flatten_with_paths(host)
            tmp = os.path.join(self.dir, f"{step}.tmp")
            final = os.path.join(self.dir, str(step))
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"a{i}": a for i, a in enumerate(leaves)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "keys": keys}, f)
            shutil.rmtree(final, ignore_errors=True)
            os.replace(tmp, final)                      # atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self._steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, str(s)),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------

    def _steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.isdigit() and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None, *,
                sharding_tree=None):
        """Restore into the structure of ``like``.

        ``sharding_tree`` (optional pytree of Shardings, same structure)
        re-places leaves on a (possibly different) mesh — elastic re-mesh.
        Returns (state, step).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, str(step))
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves = [data[f"a{i}"] for i in range(len(manifest["keys"]))]
        _, like_leaves, treedef = _flatten_with_paths(like)
        assert len(leaves) == len(like_leaves), "tree structure mismatch"
        if sharding_tree is not None:
            _, sh_leaves, _ = _flatten_with_paths(sharding_tree)
            arrs = [jax.device_put(a.astype(l.dtype), s) for a, l, s
                    in zip(leaves, like_leaves, sh_leaves)]
        else:
            arrs = [jax.device_put(a.astype(l.dtype)) for a, l
                    in zip(leaves, like_leaves)]
        return jax.tree.unflatten(treedef, arrs), manifest["step"]
