"""AdamW with global-norm clipping and a warmup+cosine schedule.

Self-contained (no optax in this environment).  Moments are fp32 and
inherit the parameter PartitionSpecs — for the FSDP archs the params are
already data-sharded, so optimizer state is ZeRO-3-sharded for free; for
the small archs it is TP-sharded and DP-replicated (a few GB at most).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptCfg:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: OptCfg, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2)
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: OptCfg, params, grads, opt: OptState):
    """Returns (new_params, new_opt, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                      # decoupled decay on matrices
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt.m)
    flat_v = tdef.flatten_up_to(opt.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {
        "grad_norm": gnorm, "lr": lr}
