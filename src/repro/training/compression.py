"""Error-feedback int8 gradient compression for cross-pod all-reduce.

The multi-pod mesh's ``pod`` axis crosses the slow inter-pod links (DCI),
so the cross-pod gradient sync is the collective we compress: each pod
quantizes ``g + err`` to int8 with a per-tensor scale, all-reduces the
int8 payload (4× less DCI traffic than fp32, 2× less than bf16), and
keeps the quantization residual locally for the next step (error
feedback — Karimireddy et al., the standard trick that restores
convergence for biased compressors).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array):
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_sync(grads, err, axis: str):
    """Inside shard_map (manual over ``axis``): compress, psum, dequant.

    grads/err: pytrees of per-pod gradient leaves (fp32 math).
    Returns (synced_grads_mean, new_err).
    """
    # jax.lax.axis_size is newer-JAX; psum(1) is the portable spelling
    n = (jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size")
         else jax.lax.psum(1, axis))

    def one(g, e):
        if g.size == 0:            # placeholder leaves (e.g. no-op norms)
            return g, e
        x = g.astype(jnp.float32) + e
        q, scale = quantize(x)
        # max-scale across pods so the int8 payloads share a grid
        scale = jax.lax.pmax(scale, axis)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_e = x - dequantize(q, scale)
        # int8 payload summed over pods (accumulate in int32)
        qs = jax.lax.psum(q.astype(jnp.int32), axis)
        g_sync = qs.astype(jnp.float32) * scale / n
        return g_sync.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
