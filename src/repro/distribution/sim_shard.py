"""Replication-axis device sharding for the streaming simulator.

The training side maps *logical tensor dims* onto mesh axes
(:mod:`repro.distribution.sharding`); the simulator's unit of
parallelism is coarser — whole replications (seeds / policy-sweep
cells) are independent, so the batched carry of
:func:`repro.core.streaming.simulate_stream` simply splits its leading
``R`` axis across a 1-D ``"rep"`` mesh
(:func:`repro.launch.mesh.make_rep_mesh`).  Every leaf of the carry and
every per-chunk input is placed with ``NamedSharding(mesh, P("rep",
None, ...))``; the chunk program is already vmapped over that axis, so
XLA partitions the scan across devices with no cross-device
communication (replications never interact).

Unbatched operands (the global-id / valid-mask vectors, whose cond
predicates must stay scalar) are left alone — jit replicates them.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

#: The mesh axis name the replication dimension maps onto.
REP_AXIS = "rep"


def rep_sharding(mesh, ndim: int) -> NamedSharding:
    """Sharding for one leaf: leading axis over ``"rep"``, rest replicated."""
    return NamedSharding(mesh, P(REP_AXIS, *([None] * (ndim - 1))))


def shard_reps(tree, mesh):
    """``device_put`` every leaf with its leading rep axis sharded.

    Every leaf must carry the replication axis first (the streaming
    carry and batched workload planes do) and its extent must divide
    over the mesh — both violations raise named errors instead of XLA
    layout failures.
    """
    if REP_AXIS not in mesh.axis_names:
        raise ValueError(
            f"mesh has axes {mesh.axis_names}, expected a 1-D "
            f"{REP_AXIS!r} mesh — build one with "
            f"repro.launch.mesh.make_rep_mesh()")
    n = mesh.shape[REP_AXIS]

    def put(x):
        if getattr(x, "ndim", 0) == 0:
            return jax.device_put(x, NamedSharding(mesh, P()))
        if x.shape[0] % n != 0:
            raise ValueError(
                f"replication axis of size {x.shape[0]} does not "
                f"divide across the {n}-device {REP_AXIS!r} mesh; "
                f"pad the rep count or shrink the mesh "
                f"(make_rep_mesh(n_devices=...))")
        return jax.device_put(x, rep_sharding(mesh, x.ndim))

    return jax.tree_util.tree_map(put, tree)
