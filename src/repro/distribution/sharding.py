"""Logical-axis sharding context (MaxText-style rules, contextvar-scoped).

Model code never names physical mesh axes.  It annotates tensors with
*logical* dimension names (``shard(x, "batch", "seq", "embed")``) and the
launcher installs a :class:`ShardCtx` that maps logical names to physical
mesh axes.  Outside any context the annotations are no-ops, so the same
model code runs single-device (smoke tests) and SPMD (dry-run/production)
unchanged.

Logical axis vocabulary
=======================

==============  ==========================================================
``batch``       global batch — data parallel (``("pod","data")`` multi-pod)
``seq``         sequence — unsharded by default; ``seq_kv`` may map to
                ``data`` for long-context flash-decode merging
``embed``       d_model of activations — unsharded (activations replicate)
``heads``       attention query heads — tensor parallel
``kv_heads``    attention kv heads — tensor parallel when divisible
``ff``          MLP hidden — tensor parallel
``vocab``       embedding/logits vocabulary — tensor parallel
``expert``      MoE expert dim — expert parallel (maps to ``model``)
``fsdp``        parameter dim sharded over the data axis (ZeRO-3 style)
``tokens_tp``   token dim inside EP routing — maps to ``model``
``state``       recurrent state channels (RWKV/Mamba) — tensor parallel
==============  ==========================================================
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Any  # str | tuple[str, ...] | None


def shard_map_compat(f, mesh, in_specs, out_specs, *, axis_names=None,
                     check_vma=False):
    """``jax.shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map(..., axis_names=manual_axes,
    check_vma=...)``; 0.4.x has ``jax.experimental.shard_map.shard_map(...,
    auto=non_manual_axes, check_rep=...)``.  ``axis_names=None`` means all
    mesh axes are manual (both APIs' default).
    """
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = dict(check_rep=check_vma)
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh, in_specs, out_specs, **kw)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    rules: Mapping[str, AxisVal]
    # physical axis names for collectives (shard_map paths)
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    pod_axis: str | None = None

    def axis_size(self, logical: str) -> int:
        phys = self.rules.get(logical)
        if phys is None:
            return 1
        if isinstance(phys, str):
            phys = (phys,)
        n = 1
        for a in phys:
            n *= self.mesh.shape[a]
        return n


_ctx: contextvars.ContextVar[ShardCtx | None] = contextvars.ContextVar(
    "repro_shard_ctx", default=None)


def current_ctx() -> ShardCtx | None:
    return _ctx.get()


@contextlib.contextmanager
def sharding_ctx(ctx: ShardCtx):
    tok = _ctx.set(ctx)
    try:
        with ctx.mesh:
            yield ctx
    finally:
        _ctx.reset(tok)


@contextlib.contextmanager
def no_sharding_ctx():
    """Suspend logical-axis constraints (``shard()`` becomes a no-op).

    Used inside partial-manual ``shard_map`` regions on older JAX, where
    inner ``with_sharding_constraint``s over the auto axes trip an XLA
    manual-subgroup check; GSPMD then auto-shards the region instead.
    """
    tok = _ctx.set(None)
    try:
        yield
    finally:
        _ctx.reset(tok)


def pspec(*logical: str | None) -> P:
    """Translate logical dim names into a PartitionSpec under the context."""
    ctx = _ctx.get()
    if ctx is None:
        return P()
    return P(*[ctx.rules.get(l) if l else None for l in logical])


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain ``x``'s sharding by logical dim names (no-op w/o context)."""
    ctx = _ctx.get()
    if ctx is None:
        return x
    assert x.ndim == len(logical), (x.shape, logical)
    spec = pspec(*logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def named_sharding(*logical: str | None) -> NamedSharding | None:
    ctx = _ctx.get()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, pspec(*logical))


def tp_size() -> int:
    ctx = _ctx.get()
    return 1 if ctx is None else ctx.mesh.shape[ctx.tp_axis]


def axis_size(logical: str) -> int:
    """Product of mesh-axis sizes a logical name maps to (1 w/o ctx)."""
    ctx = _ctx.get()
    return 1 if ctx is None else ctx.axis_size(logical)


def phys(*logical: str) -> tuple | None:
    """Concatenate the physical axes of several logical names (one dim).

    Used where a single tensor dim carries several logical shardings
    (e.g. a decode cache sequence dim sharded over data *and* model)."""
    ctx = _ctx.get()
    if ctx is None:
        return None
    axes: list = []
    for l in logical:
        a = ctx.rules.get(l)
        if a is None:
            continue
        axes.extend(a if isinstance(a, tuple) else (a,))
    return tuple(axes) if axes else None


def dp_size() -> int:
    ctx = _ctx.get()
    if ctx is None:
        return 1
    n = 1
    for a in ctx.dp_axes:
        n *= ctx.mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# Rules construction
# ---------------------------------------------------------------------------

def make_rules(*, multi_pod: bool = False, fsdp: bool = False,
               shard_heads: bool = True, shard_kv_heads: bool = True,
               seq_kv_data: bool = False) -> dict[str, AxisVal]:
    """Standard logical→physical rules for the production meshes.

    ``fsdp`` additionally shards a designated parameter dim over the data
    axis (ZeRO-3) for the ≥14 B archs.  ``shard_heads=False`` keeps
    attention replicated over the model axis (archs whose head count does
    not divide the TP degree and whose attention is a small param
    fraction, e.g. gemma-2b with 8 heads).  ``seq_kv_data=True`` maps the
    KV-cache sequence dim onto the data axis (long-context flash-decode).
    """
    dp: AxisVal = ("pod", "data") if multi_pod else ("data",)
    rules: dict[str, AxisVal] = {
        "batch": dp,
        "seq": None,
        "embed": None,
        "heads": "model" if shard_heads else None,
        "kv_heads": "model" if (shard_heads and shard_kv_heads) else None,
        "ff": "model",
        "vocab": "model",
        "expert": "model",
        "tokens_tp": "model",
        "state": "model",
        "fsdp": "data" if fsdp else None,
        # serving layout for MoE decode: expert weights sharded on the
        # per-expert ff dim over 'data' (no per-layer FSDP weight
        # all-gather on the latency path); launcher enables per-shape.
        "expert_ff": None,
        "seq_kv": "data" if seq_kv_data else None,
        "seq_kv_tp": "model",    # decode-cache seq dim when kv_heads ∤ TP
        # Megatron-style sequence parallelism: residual-stream activations
        # (the values remat saves at layer boundaries) are sharded over
        # the model axis; enabled per-shape by the launcher.
        "act_seq": None,
    }
    return rules


def param_sharding_tree(param_specs, mesh: Mesh):
    """Map a pytree of PartitionSpec to NamedSharding."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs, is_leaf=lambda s: isinstance(s, P))
