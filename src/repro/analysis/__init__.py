"""repro.analysis — contract & determinism auditor.

Three passes gate every engine in CI:

1. **AST lint** (:mod:`repro.analysis.linter` /
   :mod:`repro.analysis.rules`) — determinism (``DET*``), traced
   hot-path (``HOT*``) and parity-lane dtype (``PAR*``) rules over
   ``src/`` and ``benchmarks/``, with inline
   ``# repro-lint: disable=<ID>`` escape hatches.
2. **Jaxpr audit** (:mod:`repro.analysis.jaxpr_audit`) — traces every
   registered (balancer × backend) engine plus each keep-alive lane
   and walks the ClosedJaxpr for weak types, carry drift, host
   callbacks and cache-key incompleteness (``JXP*``).
3. **Contracts & budgets** (:mod:`repro.analysis.contracts` /
   :mod:`repro.analysis.budgets`) — registry completeness (``CON*``)
   and per-engine jaxpr equation budgets (``BGT001``) recorded into
   ``experiments/BENCH_report.json``.

Run ``python -m repro.analysis --strict`` for the CI gate;
see the README "Static analysis" section for the rule catalog.
"""
from .budgets import BASELINES, bench_rows, check_budgets
from .contracts import check_contracts
from .findings import Finding
from .jaxpr_audit import (audit_cache_key, audit_engines, audit_fn,
                          audit_jaxpr, count_eqns, iter_engine_specs,
                          run_audit, trace_engine)
from .linter import lint_file, lint_paths
from .registry import register_traced, traced
from .rules import RULES

__all__ = [
    "BASELINES", "Finding", "RULES",
    "audit_cache_key", "audit_engines", "audit_fn", "audit_jaxpr",
    "bench_rows", "check_budgets", "check_contracts", "count_eqns",
    "iter_engine_specs", "lint_file", "lint_paths", "register_traced",
    "run_audit", "run_all", "trace_engine", "traced",
]


def run_all(paths=None, *, jaxpr: bool = True
            ) -> tuple[list[Finding], list[dict]]:
    """Every pass in order; returns (findings, budget rows).

    ``paths`` defaults to the repo's ``src`` and ``benchmarks`` trees
    (resolved relative to this package's parent checkout).
    """
    if paths is None:
        from pathlib import Path
        root = Path(__file__).resolve().parents[3]
        paths = [p for p in (root / "src", root / "benchmarks")
                 if p.is_dir()]
    findings = list(lint_paths(paths))
    rows: list[dict] = []
    if jaxpr:
        stats, jf = run_audit()
        findings.extend(jf)
        findings.extend(check_contracts())
        brows, bf = check_budgets(stats)
        rows = brows
        findings.extend(bf)
    return findings, rows
