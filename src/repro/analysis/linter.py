"""Visitor-based AST lint engine over ``src/`` and ``benchmarks/``.

:func:`lint_file` parses one file, figures out its lane flags (hot
path / parity lane, from :mod:`repro.analysis.registry` plus the
``# repro-lint: hot-path`` / ``# repro-lint: parity-lane`` marker
comments), tracks which functions are traced (``@traced`` / jit
decorators / the name registry) and runs every rule check from
:mod:`repro.analysis.rules`.  Findings silenced by an inline
``# repro-lint: disable=<ID>`` on any physical line of the offending
statement (or a file-level ``disable-file=``) are dropped.

:func:`lint_paths` walks directories recursively (``*.py`` only,
skipping ``__pycache__`` and hidden directories).
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from pathlib import Path
from typing import Iterable, Optional

from .findings import Finding
from .registry import (is_hot_path_file, is_parity_lane_file,
                       nesting_path_matches, traced_patterns_for)
from .rules import (RULES, LintContext, check_branch, check_call,
                    check_import, check_iteration)

_DIRECTIVE = re.compile(
    r"#\s*repro-lint:\s*(disable(?:-file)?)\s*=\s*([A-Za-z0-9_,\s]+)")
_MARKER = re.compile(r"#\s*repro-lint:\s*(hot-path|parity-lane)\b")


def _scan_comments(text: str):
    """(line → disabled-ids, file-disabled-ids, marker set)."""
    per_line: dict[int, set[str]] = {}
    file_wide: set[str] = set()
    markers: set[str] = set()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(text).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _MARKER.search(tok.string)
            if m:
                markers.add(m.group(1))
            m = _DIRECTIVE.search(tok.string)
            if not m:
                continue
            ids = {p.strip().upper() for p in m.group(2).split(",")
                   if p.strip()}
            ids = {i for i in ids if i in RULES}
            if m.group(1) == "disable-file":
                file_wide |= ids
            else:
                per_line.setdefault(tok.start[0], set()).update(ids)
    except tokenize.TokenError:
        pass
    return per_line, file_wide, markers


def _is_traced_decorator(dec: ast.AST) -> bool:
    """``@traced``, ``@jit``, ``@jax.jit``, ``@partial(jax.jit, ...)``."""
    def name_of(node):
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
        return ".".join(reversed(parts))

    n = name_of(dec)
    if n in ("traced", "jit", "jax.jit") or n.endswith(".traced"):
        return True
    if isinstance(dec, ast.Call):
        fn = name_of(dec.func)
        if fn in ("jit", "jax.jit"):
            return True
        if fn.endswith("partial") and dec.args:
            return name_of(dec.args[0]) in ("jit", "jax.jit")
    return False


def _static_argnames(decorator_list) -> set[str]:
    """Names pinned static by ``@partial(jax.jit, static_argnames=...)``.

    Static args are trace-time Python values — the ``HOT*`` rules must
    not treat them as traced.
    """
    names: set[str] = set()
    for dec in decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg != "static_argnames":
                continue
            vals = kw.value.elts if isinstance(
                kw.value, (ast.Tuple, ast.List)) else [kw.value]
            for v in vals:
                if isinstance(v, ast.Constant) and isinstance(v.value,
                                                              str):
                    names.add(v.value)
    return names


class _Visitor(ast.NodeVisitor):
    def __init__(self, ctx: LintContext,
                 traced_patterns: tuple[str, ...]):
        self.ctx = ctx
        self.patterns = traced_patterns
        self.findings: list[tuple[ast.AST, Finding]] = []
        self._stack: list[str] = []

    # -- imports (aliases are collected in lint_file's pre-pass) ------
    def visit_ImportFrom(self, node: ast.ImportFrom):
        self._emit(node, check_import(node, self.ctx))
        self.generic_visit(node)

    # -- function nesting / traced tracking --------------------------
    def _visit_function(self, node):
        self._stack.append(node.name)
        dotted = ".".join(self._stack)
        was_traced = self.ctx.in_traced
        becomes_traced = was_traced \
            or any(_is_traced_decorator(d) for d in node.decorator_list) \
            or nesting_path_matches(dotted, self.patterns)
        saved_params = self.ctx.traced_params
        if becomes_traced:
            params = {a.arg for a in (node.args.args
                                      + node.args.posonlyargs
                                      + node.args.kwonlyargs)}
            if node.args.vararg:
                params.add(node.args.vararg.arg)
            if node.args.kwarg:
                params.add(node.args.kwarg.arg)
            params -= _static_argnames(node.decorator_list)
            base = self.ctx.traced_params if was_traced else set()
            self.ctx.traced_params = (base or set()) | params
            self.ctx.traced_depth += 1
        self.generic_visit(node)
        if becomes_traced:
            self.ctx.traced_depth -= 1
        self.ctx.traced_params = saved_params
        self._stack.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_ClassDef(self, node: ast.ClassDef):
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    # -- rule dispatch ------------------------------------------------
    def visit_Call(self, node: ast.Call):
        self._emit(node, check_call(node, self.ctx))
        self.generic_visit(node)

    def _visit_branch(self, node):
        self._emit(node, check_branch(node, self.ctx))
        self.generic_visit(node)

    visit_If = _visit_branch
    visit_While = _visit_branch
    visit_IfExp = _visit_branch
    visit_Assert = _visit_branch

    def visit_For(self, node: ast.For):
        self._emit(node, check_iteration(node, self.ctx))
        self.generic_visit(node)

    def _visit_comp(self, node):
        for comp in node.generators:
            self._emit(comp.iter, check_iteration(comp, self.ctx))
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    def _emit(self, node: ast.AST, findings: Iterable[Finding]):
        for f in findings:
            self.findings.append((node, f))  # disables applied in lint_file


def lint_file(path, *, text: Optional[str] = None) -> list[Finding]:
    """Lint one file; returns findings with disables already applied."""
    p = Path(path)
    if text is None:
        text = p.read_text()
    posix = p.as_posix()
    per_line, file_wide, markers = _scan_comments(text)
    try:
        tree = ast.parse(text, filename=str(p))
    except SyntaxError as e:
        return [Finding(path=str(p), line=int(e.lineno or 0),
                        rule="LNT000", message=str(e.msg),
                        hint=RULES["LNT000"].hint)]
    ctx = LintContext(
        path=str(p), np_aliases=set(), jnp_aliases=set(),
        random_aliases=set(),
        is_hot_path=is_hot_path_file(posix) or "hot-path" in markers,
        is_parity=is_parity_lane_file(posix) or "parity-lane" in markers)
    # Alias pre-pass: function-local `import jax.numpy as jnp` must be
    # visible to rule checks in functions defined earlier in the file.
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                bound = a.asname or a.name.split(".")[0]
                if a.name == "numpy":
                    ctx.np_aliases.add(bound)
                elif a.name == "jax.numpy" and a.asname:
                    ctx.jnp_aliases.add(a.asname)
                elif a.name == "random":
                    ctx.random_aliases.add(bound)
        elif isinstance(node, ast.ImportFrom):
            if node.level == 0 and node.module == "jax":
                for a in node.names:
                    if a.name == "numpy":
                        ctx.jnp_aliases.add(a.asname or "numpy")
    visitor = _Visitor(ctx, traced_patterns_for(posix))
    visitor.visit(tree)
    out: list[Finding] = []
    for node, f in visitor.findings:
        if f.rule in file_wide:
            continue
        start = getattr(node, "lineno", f.line) or f.line
        end = getattr(node, "end_lineno", start) or start
        if any(f.rule in per_line.get(ln, ())
               for ln in range(start, end + 1)):
            continue
        out.append(f)
    out.sort(key=lambda f: (f.line, f.rule))
    return out


def iter_python_files(paths: Iterable) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(
                f for f in p.rglob("*.py")
                if "__pycache__" not in f.parts
                and not any(part.startswith(".") for part in f.parts)))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_paths(paths: Iterable) -> list[Finding]:
    """Lint every ``*.py`` under ``paths`` (files or directories)."""
    findings: list[Finding] = []
    for f in iter_python_files(paths):
        findings.extend(lint_file(f))
    return findings
