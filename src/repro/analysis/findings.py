"""The :class:`Finding` record shared by every analysis pass.

A finding is one concrete violation: a rule id from the catalog
(:mod:`repro.analysis.rules`), a location (file:line for AST findings,
a symbolic location like ``<registry:balancer:DD>`` for registry/jaxpr
findings), a one-line message and a fix hint.  Findings are plain
frozen dataclasses so passes can be unit-tested by comparing them
directly and the CLI can render/sort them uniformly.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One concrete analysis violation."""

    path: str          # file path, or "<registry:...>" / "<jaxpr:...>"
    line: int          # 1-based; 0 for non-file findings
    rule: str          # catalog id, e.g. "DET001"
    message: str
    hint: str = ""

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        out = f"{loc}: {self.rule} {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out
