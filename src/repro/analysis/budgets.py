"""Per-engine jaxpr complexity budgets (``BGT001``).

Fusion regressions show up as equation-count blowups long before they
show up as wall-clock noise: a carry dtype drift de-fuses the scan
body, a new host sync splits the program, an accidental ``vmap`` of a
scalar path multiplies the eqn count.  This pass traces every
registered engine (see :func:`repro.analysis.jaxpr_audit
.iter_engine_specs`), counts jaxpr equations recursively, and fails if
any engine exceeds its recorded budget.

Budgets are *measured baselines × headroom* — loose enough to allow
normal drift (new policy features change counts by a few eqns), tight
enough that a structural break (≥ ~50%) trips.  An engine with no
recorded baseline gets :data:`DEFAULT_BUDGET`; re-baseline with
``python -m repro.analysis --print-baselines`` after intentional
engine surgery.

:func:`bench_rows` is the ``benchmarks/run.py`` hook: it returns the
rows recorded under ``analysis`` in ``BENCH_report.json`` alongside a
REPRO-CHECK-style ``(ok, detail)`` verdict.
"""
from __future__ import annotations

from .findings import Finding
from .rules import RULES

#: Measured eqn-count baselines per engine label (AUDIT_N=8 arrivals,
#: AUDIT_F=3 functions, AUDIT_W=3 workers — counts are shape-dependent,
#: keep in sync with :mod:`repro.analysis.jaxpr_audit`).
BASELINES: dict[str, int] = {
    "E/LOC/PS|jax": 610,
    "E/LOC/PS|pallas": 610,
    "E/R/PS|jax": 594,
    "E/R/PS|pallas": 594,
    "E/LL/PS|jax": 581,
    "E/LL/PS|pallas": 581,
    "E/H/PS|jax": 603,
    "E/H/PS|pallas": 625,
    "E/JSQ2/PS|jax": 609,
    "E/JSQ2/PS|pallas": 609,
    "E/RR/PS|jax": 616,
    "E/RR/PS|pallas": 616,
    "E/HIKU/PS|jax": 769,
    "E/HIKU/PS|pallas": 769,
    "E/DD/PS|jax": 685,
    "E/DD/PS|pallas": 685,
    "E/SWARM/PS|jax": 729,
    "E/SWARM/PS|pallas": 729,
    "E/LL/PS|jax|ka=NONE": 758,
    "E/LL/PS|jax|ka=FIXED_TTL": 758,
    "E/LL/PS|jax|ka=HYBRID_HIST": 862,
    "L/LL/FCFS|jax": 1308,
    # telemetry-on lanes (streaming histogram/counter carry in the
    # scan); the telemetry-off baselines above are unchanged — the
    # disabled path traces the identical pre-telemetry program
    "E/LL/PS|jax|tel": 791,
    "E/H/PS|jax|tel": 813,
    "E/HIKU/PS|jax|tel": 979,
    "E/H/PS|pallas|tel": 835,
    "E/LL/PS|jax|ka=FIXED_TTL|tel": 968,
    "L/LL/FCFS|jax|tel": 1568,
    # heterogeneous-fleet lanes: the speed-vector divide costs ~4 eqns
    # on a speed-blind engine; SWARM's learned-state carry and the
    # TARGET_P99 autoscaler+telemetry lane are budgeted on top
    "E/LL/PS|jax|fleet": 585,
    "E/SWARM/PS|jax|fleet": 745,
    "E/LL/PS|jax|fleet|auto|tel": 891,
    # streaming chunk-engine lanes: one segment's scan traced on the
    # engine's own init carry (slot mirrors + exact counters, no (N,)
    # output planes — hence smaller than the monolithic twins)
    "E/LL/PS|jax|chunk": 388,
    "E/LL/PS|jax|tel|chunk": 499,
    "E/LL/PS|jax|ka=HYBRID_HIST|tel|chunk": 690,
    "E/LL/PS|jax|fleet|auto|tel|chunk": 592,
    # windowed-timeline lanes: the flight-recorder plane scatters into
    # K-window counters/sketches on every arrival and completion, so
    # it costs more than the telemetry sketch alone; timeline-off
    # baselines above are unchanged (the disabled path traces the
    # identical pre-timeline program — locked by
    # test_timeline_off_is_bit_identical)
    "E/LL/PS|jax|tl": 1068,
    "E/LL/PS|jax|tel|tl": 1278,
    "E/H/PS|jax|tel|tl": 1334,
    "E/LL/PS|jax|fleet|auto|tel|tl": 1453,
    "E/LL/PS|jax|tel|tl|chunk": 779,
}

#: Headroom multiplier over the measured baseline.
HEADROOM: float = 1.5

#: Budget for engines with no recorded baseline (new policies land
#: before re-baselining; this only guards against gross blowups).
DEFAULT_BUDGET: int = 2000


def budget_for(label: str) -> int:
    base = BASELINES.get(label)
    if base is None:
        return DEFAULT_BUDGET
    return int(base * HEADROOM)


def check_budgets(stats=None) -> tuple[list[dict], list[Finding]]:
    """Trace every engine, compare eqn counts against budgets.

    Returns ``(rows, findings)`` where ``rows`` are JSON-ready dicts
    (one per engine: label, eqns, budget, baseline, ok) and
    ``findings`` carry a ``BGT001`` per over-budget engine.
    """
    if stats is None:
        from .jaxpr_audit import audit_engines
        stats, _ = audit_engines()
    rows: list[dict] = []
    findings: list[Finding] = []
    for st in stats:
        budget = budget_for(st.label)
        row = st.row()
        row["baseline"] = BASELINES.get(st.label)
        row["budget"] = budget
        row["ok"] = st.eqns <= budget
        rows.append(row)
        if not row["ok"]:
            findings.append(Finding(
                path=f"<engine:{st.label}>", line=0, rule="BGT001",
                message=(f"jaxpr has {st.eqns} eqns, budget {budget} "
                         f"(baseline {row['baseline']}) — a fusion or "
                         f"carry-structure regression"),
                hint=RULES["BGT001"].hint))
    return rows, findings


def bench_rows() -> tuple[list[dict], bool, str]:
    """Budget gate for ``benchmarks/run.py``: (rows, ok, detail)."""
    rows, findings = check_budgets()
    over = [f.path for f in findings]
    detail = (f"{len(rows)} engines traced, "
              + (f"over budget: {', '.join(over)}" if over
                 else "all within eqn budgets"))
    return rows, not over, detail


def format_baselines(stats) -> str:
    """Render measured stats as a paste-ready ``BASELINES`` literal."""
    lines = ["BASELINES: dict[str, int] = {"]
    for st in stats:
        lines.append(f'    "{st.label}": {st.eqns},')
    lines.append("}")
    return "\n".join(lines)
