"""CLI for the repro.analysis gate.

Usage::

    python -m repro.analysis                  # lint + jaxpr audit, report
    python -m repro.analysis --strict         # exit 1 on any finding (CI)
    python -m repro.analysis src/repro/core   # lint specific paths only
    python -m repro.analysis --no-jaxpr       # fast: skip engine tracing
    python -m repro.analysis --list-rules     # rule catalog
    python -m repro.analysis --print-baselines  # paste-ready eqn budgets
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST lint + jaxpr audit + contract/budget gate.")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint "
                         "(default: src/ and benchmarks/)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero if any finding survives")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the jaxpr/contract/budget passes "
                         "(pure AST lint, no jax import)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--print-baselines", action="store_true",
                    help="trace every engine and print a paste-ready "
                         "BASELINES literal for budgets.py")
    args = ap.parse_args(argv)

    if args.list_rules:
        from .rules import RULES
        for rid, rule in sorted(RULES.items()):
            print(f"{rid:8s} {rule.title}")
            print(f"         fix: {rule.hint}")
        return 0

    if args.print_baselines:
        from .budgets import format_baselines
        from .jaxpr_audit import audit_engines
        stats, _ = audit_engines()
        print(format_baselines(stats))
        return 0

    from . import run_all
    findings, rows = run_all(args.paths or None,
                             jaxpr=not args.no_jaxpr)
    for f in findings:
        print(f.format())
    if rows:
        traced = len(rows)
        over = [r["label"] for r in rows if not r["ok"]]
        print(f"[analysis] {traced} engines traced; "
              + (f"OVER BUDGET: {', '.join(over)}" if over
                 else "all within eqn budgets"))
    n = len(findings)
    print(f"[analysis] {n} finding{'s' if n != 1 else ''}")
    if findings and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
