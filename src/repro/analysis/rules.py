"""Rule catalog + AST check functions for the repro linter.

Each rule has a stable id, a one-line summary and a fix hint; the
check functions are called by :mod:`repro.analysis.linter`'s visitor
with a per-file :class:`LintContext`.  Any finding can be silenced with
an inline escape hatch on its line::

    foo = np.random.rand(4)   # repro-lint: disable=DET001 -- justification

or for a whole file (any line)::

    # repro-lint: disable-file=PAR001 -- generated code

Rule groups:

* ``DET*`` — determinism: hidden global RNG state.
* ``HOT*`` — traced/engine hot-path hazards: host syncs, Python
  branching on traced values, registry-order-dependent iteration.
* ``PAR*`` — np ≡ jax ≡ pallas parity lanes: weak-dtype hazards.
* ``LNT*`` — the linter itself (unparseable file).

The jaxpr audit (``JXP*``), registry contracts (``CON*``) and budget
gate (``BGT*``) ids live in the same catalog so ``--list-rules`` and
the README table cover every finding the subsystem can emit.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterator, Optional

from .findings import Finding


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    hint: str
    doc: str = ""


_RULES = (
    Rule("LNT000", "file does not parse",
         "fix the syntax error; the linter skips unparseable files"),
    Rule("DET001", "unseeded numpy RNG",
         "use np.random.default_rng(seed) / np.random.Generator; the "
         "legacy global RNG (np.random.rand, .seed, ...) is hidden "
         "process state",
         "Legacy np.random.* calls share one mutable global stream: "
         "results then depend on call order across the whole process, "
         "which breaks replayable experiments."),
    Rule("DET002", "unseeded Python random",
         "use a seeded np.random.default_rng(seed) (or random.Random("
         "seed)) instead of the random module's global instance"),
    Rule("HOT001", "host sync inside traced function",
         "keep values on device: drop float()/int()/.item()/np.asarray "
         "from jitted code; use jnp ops (a traced value cannot be "
         "concretized without blocking the trace)"),
    Rule("HOT002", "Python branch on traced value",
         "use jnp.where / lax.cond / lax.select — a Python if on a "
         "traced value either fails to trace or silently bakes in one "
         "branch at trace time"),
    Rule("HOT003", "registry dict iteration in engine hot path",
         "iterate a sorted(...) snapshot (or resolve entries up front); "
         "raw registry iteration order depends on registration order"),
    Rule("PAR001", "weak-dtype array creation in parity lane",
         "pass an explicit dtype= (e.g. jnp.zeros(shape, "
         "dtype=jnp.float64)); weak-typed arrays let XLA re-promote "
         "differently from the numpy oracle"),
    Rule("PAR002", "builtin-type astype in parity lane",
         "astype(float) resolves to the platform default dtype; pin "
         "jnp.float64 / np.float64 explicitly"),
    Rule("JXP001", "weak-typed engine output or scan carry",
         "pin the dtype where the buffer is created; weak carries "
         "re-promote on the next op and can recompile per call site"),
    Rule("JXP002", "scan/while carry structure or dtype drift",
         "make the carry pytree structure and leaf dtypes identical "
         "between iterations (initialize with the final dtypes)"),
    Rule("JXP003", "unexpected 64-bit value in audited program",
         "this lane is declared 32-bit; find the promoting op "
         "(Python float literals and np scalars promote) and pin dtypes"),
    Rule("JXP004", "host callback inside compiled engine",
         "remove debug prints / pure_callback from the hot path, or "
         "gate them out of production engines"),
    Rule("JXP005", "engine cache key misses a config field",
         "add the field to repro.core.simulator._cache_key — two "
         "configs differing in it would silently share a compiled "
         "engine"),
    Rule("CON001", "balancer registry contract violation",
         "declared backends must be callable factories; stateful "
         "balancers (init_state set) must return (select, on_complete) "
         "pairs from every backend factory"),
    Rule("CON002", "sched registry contract violation",
         "a registered sched needs callable make_np and make_jax "
         "factories (both engines resolve it)"),
    Rule("CON003", "keep-alive registry contract violation",
         "factories must return (windows, observe); stateful policies "
         "need init_state and a non-None observe on every backend"),
    Rule("CON004", "kernel package contract violation",
         "a kernel package ships kernel.py + ops.py + ref.py with a "
         "<op>_ref reference matching the op's signature"),
    Rule("BGT001", "jaxpr eqn budget exceeded",
         "the engine's traced program grew past its recorded budget — "
         "a fusion break or accidental unrolling; inspect "
         "jax.make_jaxpr of the engine and re-baseline deliberately if "
         "intended"),
)

RULES: dict[str, Rule] = {r.id: r for r in _RULES}

#: Rules emitted by the AST linter (the rest come from the jaxpr audit,
#: contract checks and budget gate).
LINT_RULE_IDS = ("DET001", "DET002", "HOT001", "HOT002", "HOT003",
                 "PAR001", "PAR002")


@dataclasses.dataclass
class LintContext:
    """Per-file state the check functions read (linter.py maintains it)."""

    path: str                       # as reported in findings
    np_aliases: set[str]            # names bound to the numpy module
    jnp_aliases: set[str]           # names bound to jax.numpy
    random_aliases: set[str]        # names bound to stdlib random
    is_hot_path: bool
    is_parity: bool
    traced_depth: int = 0           # >0 inside a traced function
    traced_params: Optional[set] = None   # union of traced fns' params

    @property
    def in_traced(self) -> bool:
        return self.traced_depth > 0


def _finding(ctx: LintContext, node: ast.AST, rule_id: str,
             message: str) -> Finding:
    return Finding(path=ctx.path, line=getattr(node, "lineno", 0),
                   rule=rule_id, message=message,
                   hint=RULES[rule_id].hint)


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for an Attribute/Name chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# Legacy global-stream numpy RNG entry points (module-level functions of
# np.random that mutate the hidden global RandomState).
LEGACY_NP_RANDOM = frozenset({
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "ranf", "sample", "choice", "shuffle", "permutation", "uniform",
    "normal", "standard_normal", "exponential", "poisson", "binomial",
    "beta", "gamma", "lognormal", "pareto", "weibull", "zipf",
    "get_state", "set_state", "random_integers", "bytes",
})

# Stdlib random module functions backed by its hidden global instance.
GLOBAL_PY_RANDOM = frozenset({
    "seed", "random", "randint", "randrange", "choice", "choices",
    "shuffle", "sample", "uniform", "triangular", "betavariate",
    "expovariate", "gammavariate", "gauss", "lognormvariate",
    "normalvariate", "vonmisesvariate", "paretovariate",
    "weibullvariate", "getrandbits", "randbytes",
})

# Weak-dtype jnp constructors and the argument position after which a
# positional dtype may appear (zeros(shape, dtype), full(shape, v, dtype)).
_WEAK_CTORS = {"zeros": 2, "ones": 2, "empty": 2, "full": 3,
               "arange": 4, "linspace": 3}

_HOST_SYNC_ATTRS = frozenset({"item", "tolist"})
_HOST_SYNC_NP = frozenset({"asarray", "array", "copyto"})


def check_import(node: ast.AST, ctx: LintContext) -> Iterator[Finding]:
    """``from random import shuffle`` pulls in the global instance."""
    if isinstance(node, ast.ImportFrom) and node.module == "random" \
            and node.level == 0:
        pulled = [a.name for a in node.names
                  if a.name in GLOBAL_PY_RANDOM or a.name == "*"]
        if pulled:
            yield _finding(
                ctx, node, "DET002",
                f"from random import {', '.join(pulled)} binds the "
                f"module's hidden global RNG instance")


def check_call(node: ast.Call, ctx: LintContext) -> Iterator[Finding]:
    dotted = _dotted(node.func)

    # --- DET001: legacy numpy global RNG -----------------------------
    if dotted:
        head, _, rest = dotted.partition(".")
        if head in ctx.np_aliases and rest.startswith("random."):
            fn = rest[len("random."):]
            if fn in LEGACY_NP_RANDOM:
                yield _finding(
                    ctx, node, "DET001",
                    f"np.random.{fn} uses the hidden global RandomState")
            elif fn in ("default_rng", "RandomState") and not node.args \
                    and not node.keywords:
                yield _finding(
                    ctx, node, "DET001",
                    f"np.random.{fn}() without a seed is "
                    f"entropy-seeded (non-reproducible)")

        # --- DET002: stdlib random global instance -------------------
        if head in ctx.random_aliases:
            if rest in GLOBAL_PY_RANDOM:
                yield _finding(
                    ctx, node, "DET002",
                    f"random.{rest} uses the module's hidden global "
                    f"RNG instance")
            elif rest == "Random" and not node.args and not node.keywords:
                yield _finding(ctx, node, "DET002",
                               "random.Random() without a seed is "
                               "entropy-seeded (non-reproducible)")

    # --- HOT001: host syncs inside traced code -----------------------
    if ctx.in_traced:
        if isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int", "bool") \
                and node.args \
                and not isinstance(node.args[0], ast.Constant):
            yield _finding(
                ctx, node, "HOT001",
                f"{node.func.id}(...) concretizes a traced value "
                f"(host sync / ConcretizationTypeError)")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in _HOST_SYNC_ATTRS:
            yield _finding(
                ctx, node, "HOT001",
                f".{node.func.attr}() pulls a traced value to host")
        elif dotted:
            head, _, rest = dotted.partition(".")
            if head in ctx.np_aliases and rest in _HOST_SYNC_NP:
                yield _finding(
                    ctx, node, "HOT001",
                    f"np.{rest} materializes a traced value on host "
                    f"(numpy call inside a jax trace)")

    # --- PAR001 / PAR002: weak dtypes in parity lanes ----------------
    if ctx.is_parity and dotted:
        head, _, rest = dotted.partition(".")
        if head in ctx.jnp_aliases and rest in _WEAK_CTORS:
            has_dtype = any(kw.arg == "dtype" for kw in node.keywords) \
                or len(node.args) >= _WEAK_CTORS[rest]
            if not has_dtype:
                yield _finding(
                    ctx, node, "PAR001",
                    f"jnp.{rest} without an explicit dtype creates a "
                    f"weak/default-typed array")
    if ctx.is_parity and isinstance(node.func, ast.Attribute) \
            and node.func.attr == "astype" and len(node.args) == 1 \
            and isinstance(node.args[0], ast.Name) \
            and node.args[0].id in ("float", "int", "bool", "complex"):
        yield _finding(
            ctx, node, "PAR002",
            f"astype({node.args[0].id}) resolves to the platform "
            f"default dtype")


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def check_branch(node: ast.AST, ctx: LintContext) -> Iterator[Finding]:
    """HOT002: Python control flow on a traced value."""
    if not ctx.in_traced or not ctx.traced_params:
        return
    test = getattr(node, "test", None)
    if test is None:
        return
    hits = _names_in(test) & ctx.traced_params
    if hits:
        kind = {ast.If: "if", ast.While: "while",
                ast.IfExp: "conditional expression",
                ast.Assert: "assert"}.get(type(node), "branch")
        yield _finding(
            ctx, node, "HOT002",
            f"Python {kind} on traced value(s) "
            f"{', '.join(sorted(hits))}")


def check_iteration(node: ast.AST, ctx: LintContext) -> Iterator[Finding]:
    """HOT003: raw registry-dict iteration in a hot-path module."""
    from .registry import REGISTRY_NAMES
    if not ctx.is_hot_path:
        return
    iters: list[ast.AST] = []
    if isinstance(node, (ast.For, ast.comprehension)):
        iters.append(node.iter)
    for it in iters:
        target = it
        # unwrap REG.items() / .keys() / .values()
        if isinstance(it, ast.Call) and isinstance(it.func, ast.Attribute) \
                and it.func.attr in ("items", "keys", "values"):
            target = it.func.value
        name = target.id if isinstance(target, ast.Name) else \
            (_dotted(target) or "").rsplit(".", 1)[-1]
        if name in REGISTRY_NAMES:
            yield _finding(
                ctx, node, "HOT003",
                f"iteration over open registry {name} in an engine hot "
                f"path (order = registration order)")
