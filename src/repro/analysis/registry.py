"""Analysis-time registries: traced functions, hot paths, parity lanes.

The hot-path lint rules (``HOT*``) need to know which functions are
*traced* — executed under ``jax.jit`` / inside the scan engine — and
which modules are engine hot paths or np ≡ jax ≡ pallas parity lanes.
Three sources feed that knowledge:

1. **The ``@traced`` decorator** — a zero-cost marker for code that is
   called from inside a jitted program but not itself decorated with
   ``jax.jit`` (helper functions, registered balancer closures in
   downstream projects).  The linter also recognizes ``@jax.jit``,
   ``@jit`` and ``@partial(jax.jit, ...)`` decorators directly.
2. **The name registry** ``TRACED_FUNCTIONS`` — dotted-path patterns
   per file for functions that cannot carry a decorator (closures built
   inside engine factories, e.g. ``_build_engine.step`` in
   :mod:`repro.core.simulator`).  Patterns are ``fnmatch``-style and
   match the lexical nesting path of a ``def``; any function nested
   inside a matched one is traced too.  Extend with
   :func:`register_traced`.
3. **File-level marker comments** — ``# repro-lint: hot-path`` and
   ``# repro-lint: parity-lane`` opt a new module into the
   corresponding rule sets without touching this registry.
"""
from __future__ import annotations

from fnmatch import fnmatch
from typing import Callable, TypeVar

F = TypeVar("F", bound=Callable)


def traced(fn: F) -> F:
    """Mark ``fn`` as executed under a jax trace (lint marker, no-op).

    The hot-path rules (``HOT001``/``HOT002``) apply inside functions
    carrying this decorator, exactly as they do inside ``@jax.jit``-ed
    ones.  Runtime behavior is unchanged.
    """
    fn.__repro_traced__ = True
    return fn


#: Dotted-nesting-path patterns of traced functions, per file suffix.
#: A function whose path (e.g. ``_build_engine.step``) matches a
#: pattern — or that is lexically nested inside a matched function —
#: is treated as traced by the hot-path rules.
TRACED_FUNCTIONS: dict[str, tuple[str, ...]] = {
    "repro/core/simulator.py": (
        "_build_engine.rates_of",
        "_build_engine.place",
        "_build_engine.pop_all",
        "_build_engine.advance",
        "_build_engine.step",
        "_build_engine.run",
    ),
    "repro/policy/balancers.py": (
        "*_jax.select", "*_jax.on_complete", "*_pallas.select",
        "*_batch.batch",
    ),
    "repro/policy/scheds.py": ("*_jax.rates", "_rank_rows"),
    "repro/lifecycle/policies.py": (
        "*_jax.windows", "*_jax.observe", "*_jax.make.windows",
    ),
    "repro/kernels/*/kernel.py": ("_kernel", "*_kernel"),
    # telemetry scan-carry updates: called from inside the engines'
    # jitted scan bodies (repro.core.simulator place/advance/step)
    "repro/telemetry/engine.py": (
        "bin_index", "on_place", "on_advance", "on_complete",
        "on_evict", "on_reject",
    ),
    # autoscaler decide() closures ride the engines' scan carry
    "repro/fleet/policies.py": ("*_jax.decide",),
}

#: Engine hot-path modules: the per-arrival event loops and everything
#: they call per decision.  ``HOT003`` (registry dict iteration) applies
#: here — iteration order over an open registry depends on registration
#: order, which is a determinism hazard inside an engine.
HOT_PATH_MODULES: tuple[str, ...] = (
    "repro/core/simulator.py",
    "repro/core/sim_ref.py",
    "repro/serving/engine.py",
    "repro/policy/balancers.py",
    "repro/policy/scheds.py",
    "repro/lifecycle/runtime.py",
    "repro/lifecycle/policies.py",
    "repro/telemetry/engine.py",
    "repro/telemetry/state.py",
    "repro/fleet/policies.py",
)

#: Files participating in the bitwise np ≡ jax ≡ pallas parity lanes.
#: ``PAR*`` rules apply here: every array must carry an explicit dtype
#: so XLA's weak-type promotion can never diverge from numpy.
PARITY_LANE_FILES: tuple[str, ...] = (
    "repro/core/simulator.py",
    "repro/policy/balancers.py",
    "repro/policy/scheds.py",
    "repro/lifecycle/policies.py",
    "repro/kernels/*/kernel.py",
    "repro/kernels/*/ops.py",
    "repro/kernels/*/ref.py",
    "repro/telemetry/engine.py",
    "repro/fleet/policies.py",
)

#: Open-registry dict names whose raw iteration inside a hot path is a
#: registration-order hazard (``HOT003``).
REGISTRY_NAMES: frozenset[str] = frozenset({
    "BALANCERS", "SCHEDS", "BINDINGS", "KEEPALIVES", "WORKLOADS",
    "AUTOSCALERS",
})


def register_traced(file_pattern: str, *patterns: str) -> None:
    """Register traced-function name patterns for ``file_pattern``.

    ``file_pattern`` is matched against the end of the posix file path
    (``repro/mypkg/engine.py``); ``patterns`` are dotted nesting paths
    (``build.step``; ``fnmatch`` wildcards allowed).  Use this for
    closures that cannot carry the :func:`traced` decorator.
    """
    existing = TRACED_FUNCTIONS.get(file_pattern, ())
    TRACED_FUNCTIONS[file_pattern] = tuple(existing) + tuple(patterns)


def _path_matches(posix_path: str, pattern: str) -> bool:
    return fnmatch(posix_path, pattern) or fnmatch(posix_path,
                                                  "*/" + pattern)


def traced_patterns_for(posix_path: str) -> tuple[str, ...]:
    """All registered traced-name patterns applying to this file."""
    out: list[str] = []
    for file_pat, pats in TRACED_FUNCTIONS.items():
        if _path_matches(posix_path, file_pat):
            out.extend(pats)
    return tuple(out)


def is_hot_path_file(posix_path: str) -> bool:
    return any(_path_matches(posix_path, p) for p in HOT_PATH_MODULES)


def is_parity_lane_file(posix_path: str) -> bool:
    return any(_path_matches(posix_path, p) for p in PARITY_LANE_FILES)


def nesting_path_matches(dotted: str, patterns: tuple[str, ...]) -> bool:
    """True if ``dotted`` or any of its ancestors matches a pattern."""
    parts = dotted.split(".")
    prefixes = [".".join(parts[:i]) for i in range(1, len(parts) + 1)]
    return any(fnmatch(pref, pat) for pref in prefixes for pat in patterns)
