"""Registry contract-completeness checks (``CON*`` findings).

The engines never branch on policy names — they trust the registries.
That trust is a contract this pass makes checkable:

* **Balancers** (``CON001``): every declared backend factory must be
  callable and instantiable at a probe shape; a balancer must ship both
  ``np`` and ``jax`` backends (otherwise it silently drops out of the
  oracle-vs-engine parity lane); stateful balancers
  (``init_state`` set) must return ``(select, on_complete)`` pairs
  from *every* backend factory and a non-empty state pytree, stateless
  ones must return bare callables.
* **Scheds** (``CON002``): ``make_np`` and ``make_jax`` both present
  and instantiable (both engines resolve rate assignment).
* **Keep-alives** (``CON003``): factories return ``(windows,
  observe)``; ``observe`` is non-None iff the policy declares
  ``init_state``; the np backend's ``windows`` must produce per-
  function ``(pre, keep)`` vectors of the probed length.
* **Kernel packages** (``CON004``): every ``repro.kernels`` subpackage
  ships the ``kernel.py`` + ``ops.py`` + ``ref.py`` trio; each public
  op has a ``<op>_ref`` reference whose required signature the op can
  satisfy (the op may add batch arguments and tuning keywords; the
  reference must not require more).
"""
from __future__ import annotations

import importlib
import inspect
from pathlib import Path

from .findings import Finding
from .rules import RULES

_PROBE_CORES, _PROBE_SLOTS = 2, 4
_PROBE_W, _PROBE_F = 2, 3


def _find(findings, loc: str, rule: str, msg: str) -> None:
    findings.append(Finding(path=loc, line=0, rule=rule, message=msg,
                            hint=RULES[rule].hint))


# --------------------------------------------------------------------------
# balancers / scheds / keep-alives
# --------------------------------------------------------------------------

def check_balancers() -> list[Finding]:
    from repro.policy.registry import BALANCERS, _load_builtins
    _load_builtins()
    findings: list[Finding] = []
    for name, bal in sorted(BALANCERS.items()):
        loc = f"<registry:balancer:{name}>"
        if bal.make_np is None or bal.make_jax is None:
            _find(findings, loc, "CON001",
                  f"missing {'np' if bal.make_np is None else 'jax'} "
                  f"backend — not sweepable by every engine "
                  f"(has: {bal.backends()})")
        for bname, factory in (("np", bal.make_np), ("jax", bal.make_jax),
                               ("pallas", bal.make_pallas),
                               ("batch", bal.make_batch)):
            if factory is None:
                continue
            if not callable(factory):
                _find(findings, loc, "CON001",
                      f"make_{bname} is not callable")
                continue
            try:
                made = factory(_PROBE_CORES, _PROBE_SLOTS)
            except Exception as e:  # noqa: BLE001 — report, don't crash
                _find(findings, loc, "CON001",
                      f"make_{bname}({_PROBE_CORES}, {_PROBE_SLOTS}) "
                      f"raised {type(e).__name__}: {e}")
                continue
            if bname == "batch":
                if not callable(made):
                    _find(findings, loc, "CON001",
                          "make_batch must return a callable")
                continue
            if bal.stateful:
                if not (isinstance(made, tuple) and len(made) == 2
                        and all(callable(f) for f in made)):
                    _find(findings, loc, "CON001",
                          f"stateful balancer's make_{bname} must "
                          f"return a (select, on_complete) pair of "
                          f"callables, got {type(made).__name__}")
            elif not callable(made) or isinstance(made, tuple):
                _find(findings, loc, "CON001",
                      f"stateless balancer's make_{bname} must return "
                      f"a bare select callable, got "
                      f"{type(made).__name__}")
        if bal.stateful:
            try:
                st = bal.init_state(_PROBE_W, _PROBE_F)
            except Exception as e:  # noqa: BLE001
                _find(findings, loc, "CON001",
                      f"init_state({_PROBE_W}, {_PROBE_F}) raised "
                      f"{type(e).__name__}: {e}")
            else:
                if not isinstance(st, dict) or not st:
                    _find(findings, loc, "CON001",
                          "init_state must return a non-empty dict "
                          "state pytree")
    return findings


def check_scheds() -> list[Finding]:
    from repro.policy.registry import SCHEDS, _load_builtins
    _load_builtins()
    findings: list[Finding] = []
    for name, sd in sorted(SCHEDS.items()):
        loc = f"<registry:sched:{name}>"
        for bname, factory in (("np", sd.make_np), ("jax", sd.make_jax)):
            if factory is None:
                _find(findings, loc, "CON002",
                      f"missing make_{bname} — both engines resolve "
                      f"rate assignment through the registry")
                continue
            try:
                made = factory(_PROBE_CORES)
            except Exception as e:  # noqa: BLE001
                _find(findings, loc, "CON002",
                      f"make_{bname}({_PROBE_CORES}) raised "
                      f"{type(e).__name__}: {e}")
                continue
            if not callable(made):
                _find(findings, loc, "CON002",
                      f"make_{bname} must return a rates callable")
    return findings


def check_keepalives() -> list[Finding]:
    from repro.lifecycle.config import LifecycleCfg
    from repro.lifecycle.registry import KEEPALIVES, _load_builtins
    _load_builtins()
    findings: list[Finding] = []
    for name, ka in sorted(KEEPALIVES.items()):
        loc = f"<registry:keepalive:{name}>"
        cfg = LifecycleCfg(keepalive=name)
        for bname, factory in (("np", ka.make_np), ("jax", ka.make_jax)):
            if factory is None:
                _find(findings, loc, "CON003",
                      f"missing make_{bname} backend "
                      f"(has: {ka.backends()})")
                continue
            try:
                made = factory(cfg, _PROBE_F)
            except Exception as e:  # noqa: BLE001
                _find(findings, loc, "CON003",
                      f"make_{bname}(cfg, {_PROBE_F}) raised "
                      f"{type(e).__name__}: {e}")
                continue
            if not (isinstance(made, tuple) and len(made) == 2
                    and callable(made[0])):
                _find(findings, loc, "CON003",
                      f"make_{bname} must return a (windows, observe) "
                      f"pair, got {type(made).__name__}")
                continue
            windows, observe = made
            if ka.stateful and observe is None:
                _find(findings, loc, "CON003",
                      f"stateful keep-alive's make_{bname} must return "
                      f"a non-None observe hook")
            if not ka.stateful and observe is not None:
                _find(findings, loc, "CON003",
                      f"stateless keep-alive's make_{bname} returned "
                      f"an observe hook but no init_state is declared")
            if bname == "np":
                state = None
                if ka.stateful:
                    state = ka.init_state(cfg, _PROBE_W, _PROBE_F)
                try:
                    pre, keep = windows(state)
                except Exception as e:  # noqa: BLE001
                    _find(findings, loc, "CON003",
                          f"windows(state) raised "
                          f"{type(e).__name__}: {e}")
                    continue
                if getattr(pre, "shape", None) != (_PROBE_F,) \
                        or getattr(keep, "shape", None) != (_PROBE_F,):
                    _find(findings, loc, "CON003",
                          f"windows must return per-function "
                          f"(pre[F], keep[F]) vectors, got shapes "
                          f"{getattr(pre, 'shape', None)} / "
                          f"{getattr(keep, 'shape', None)}")
        if ka.stateful:
            st = ka.init_state(cfg, _PROBE_W, _PROBE_F)
            if not isinstance(st, dict) or not st:
                _find(findings, loc, "CON003",
                      "init_state must return a non-empty dict state "
                      "pytree")
    return findings


# --------------------------------------------------------------------------
# kernel packages
# --------------------------------------------------------------------------

def _public_functions(mod) -> dict:
    # jitted ops are PjitFunction wrappers, not plain functions — accept
    # any callable defined in the module (functools.wraps preserves
    # __module__ through jax.jit).
    return {n: f for n, f in vars(mod).items()
            if callable(f) and not n.startswith("_")
            and not inspect.isclass(f) and not inspect.ismodule(f)
            and getattr(f, "__module__", None) == mod.__name__}


def check_kernels() -> list[Finding]:
    import repro.kernels as kpkg
    findings: list[Finding] = []
    root = Path(kpkg.__file__).parent
    for pkg_dir in sorted(p for p in root.iterdir() if p.is_dir()
                          and p.name != "__pycache__"):
        name = pkg_dir.name
        loc = f"<kernels:{name}>"
        missing = [m for m in ("kernel.py", "ops.py", "ref.py")
                   if not (pkg_dir / m).exists()]
        if missing:
            _find(findings, loc, "CON004",
                  f"kernel package missing {', '.join(missing)}")
            continue
        try:
            ops = importlib.import_module(f"repro.kernels.{name}.ops")
            ref = importlib.import_module(f"repro.kernels.{name}.ref")
        except Exception as e:  # noqa: BLE001
            _find(findings, loc, "CON004",
                  f"import failed: {type(e).__name__}: {e}")
            continue
        ops_fns = _public_functions(ops)
        ref_fns = _public_functions(ref)
        if not ops_fns:
            _find(findings, loc, "CON004", "ops.py exposes no public op")
        if not any(n.endswith("_ref") for n in ref_fns):
            _find(findings, loc, "CON004",
                  "ref.py exposes no *_ref reference implementation")
        for op_name, op in ops_fns.items():
            ref_fn = ref_fns.get(f"{op_name}_ref")
            if ref_fn is None:
                _find(findings, loc, "CON004",
                      f"no {op_name}_ref in ref.py for op '{op_name}'")
                continue
            op_sig = inspect.signature(op)
            ref_sig = inspect.signature(ref_fn)

            def required(sig, kinds):
                return [p.name for p in sig.parameters.values()
                        if p.kind in kinds
                        and p.default is inspect.Parameter.empty]

            pos = (inspect.Parameter.POSITIONAL_ONLY,
                   inspect.Parameter.POSITIONAL_OR_KEYWORD)
            kw = (inspect.Parameter.KEYWORD_ONLY,)
            if len(required(op_sig, pos)) < len(required(ref_sig, pos)):
                _find(findings, loc, "CON004",
                      f"'{op_name}' takes fewer required array args "
                      f"than {op_name}_ref "
                      f"({required(op_sig, pos)} vs "
                      f"{required(ref_sig, pos)})")
            missing_kw = [p for p in required(ref_sig, kw)
                          if p not in op_sig.parameters]
            if missing_kw:
                _find(findings, loc, "CON004",
                      f"'{op_name}' is missing required keyword(s) of "
                      f"{op_name}_ref: {missing_kw}")
    return findings


def check_contracts() -> list[Finding]:
    """Every registry + kernel-package contract check."""
    return (check_balancers() + check_scheds() + check_keepalives()
            + check_kernels())
