"""Jaxpr-level determinism & contract audit of the compiled engines.

Where the AST lint sees source, this pass sees what XLA will actually
compile: each registered (balancer × backend) engine — plus one lane
per registered keep-alive policy — is traced via :func:`jax.make_jaxpr`
at a tiny shape (tracing only, no compilation) and the ClosedJaxpr is
walked for hazards that historically showed up as flaky parity
failures:

* ``JXP001`` — weak-typed engine outputs or scan/while carries (weak
  types re-promote at the next op and can diverge from the numpy
  oracle or recompile per call site),
* ``JXP002`` — carry pytree structure / dtype drift between scan
  iterations (jax itself errors on hard mismatches; the audit reports
  the aval diff readably and also covers while_loop carries),
* ``JXP003`` — 64-bit values in lanes declared 32-bit (the simulator
  engines are float64 *by design* and audit with ``allow_64=True``;
  kernel/toy lanes can pin 32-bit),
* ``JXP004`` — host callbacks (``debug_callback`` / ``pure_callback``
  / ``io_callback`` / infeed/outfeed) inside the compiled hot path,
* ``JXP005`` — engine-cache-key incompleteness: every
  ``ClusterCfg`` / ``LifecycleCfg`` / ``FleetCfg`` field is perturbed
  and the
  :func:`repro.core.simulator._cache_key` is probed — a field that
  changes the traced program but not the key would silently share a
  compiled engine between different configs.

:func:`audit_engines` also returns one stats row per engine (jaxpr eqn
count, scan count, carry leaves/bytes) — the raw material for the
budget gate in :mod:`repro.analysis.budgets`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional

import numpy as np

from .findings import Finding
from .rules import RULES

#: Primitive names that run code on host mid-program.
HOST_CALLBACK_PRIMS = frozenset({
    "debug_callback", "pure_callback", "io_callback", "callback",
    "infeed", "outfeed", "host_callback_call",
})

#: Tiny audit shape — tracing cost only, results never executed.
AUDIT_N, AUDIT_F, AUDIT_W = 8, 3, 3


def _jax():
    import jax  # deferred so `--no-jaxpr` lint runs never import jax
    return jax


# --------------------------------------------------------------------------
# jaxpr walking helpers
# --------------------------------------------------------------------------

def _sub_jaxprs(params: dict):
    from jax.core import Jaxpr
    from jax.extend.core import ClosedJaxpr  # type: ignore
    for v in params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for item in vals:
            if isinstance(item, ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, Jaxpr):
                yield item
            elif hasattr(item, "jaxpr") and hasattr(item, "consts"):
                yield item.jaxpr


def iter_eqns(jaxpr) -> Iterable:
    """All eqns of ``jaxpr`` and (recursively) its sub-jaxprs."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)  # unwrap ClosedJaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def count_eqns(jaxpr) -> int:
    return sum(1 for _ in iter_eqns(jaxpr))


def _aval_str(aval) -> str:
    weak = ", weak" if getattr(aval, "weak_type", False) else ""
    return f"{getattr(aval, 'dtype', '?')}{getattr(aval, 'shape', '?')}" \
           f"{weak}"


def _avals_mismatch(a, b) -> bool:
    return (getattr(a, "shape", None) != getattr(b, "shape", None)
            or getattr(a, "dtype", None) != getattr(b, "dtype", None)
            or getattr(a, "weak_type", False)
            != getattr(b, "weak_type", False))


# --------------------------------------------------------------------------
# single-program audit (also the unit-testable entry point)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JaxprStats:
    label: str
    eqns: int
    scans: int
    whiles: int
    carry_leaves: int
    carry_bytes: int
    outputs: int

    def row(self) -> dict:
        return dataclasses.asdict(self)


def audit_jaxpr(closed, *, label: str = "<fn>",
                allow_64: bool = True,
                allow_weak_outputs: bool = False
                ) -> tuple[JaxprStats, list[Finding]]:
    """Walk one ClosedJaxpr; returns (stats, findings)."""
    findings: list[Finding] = []
    loc = f"<jaxpr:{label}>"

    def find(rule: str, msg: str):
        findings.append(Finding(path=loc, line=0, rule=rule,
                                message=msg, hint=RULES[rule].hint))

    scans = whiles = 0
    carry_leaves = 0
    carry_bytes = 0
    for eqn in iter_eqns(closed):
        prim = eqn.primitive.name
        if prim in HOST_CALLBACK_PRIMS or prim.endswith("_callback"):
            find("JXP004", f"host callback primitive '{prim}' in "
                           f"compiled program")
        if prim == "scan":
            scans += 1
            body = eqn.params["jaxpr"]
            nc = eqn.params["num_carry"]
            nconsts = eqn.params["num_consts"]
            cin = list(body.in_avals)[nconsts:nconsts + nc]
            cout = list(body.out_avals)[:nc]
            for i, (a, b) in enumerate(zip(cin, cout)):
                if _avals_mismatch(a, b):
                    find("JXP002",
                         f"scan carry leaf {i} drifts across "
                         f"iterations: {_aval_str(a)} -> {_aval_str(b)}")
            carry_leaves += nc
            for a in cin:
                carry_bytes += int(np.prod(a.shape, dtype=np.int64)
                                   * a.dtype.itemsize)
                if getattr(a, "weak_type", False):
                    find("JXP001", f"weak-typed scan carry leaf "
                                   f"{_aval_str(a)}")
        elif prim == "while":
            whiles += 1
            body = eqn.params["body_jaxpr"]
            nconsts = eqn.params["body_nconsts"]
            cin = list(body.in_avals)[nconsts:]
            cout = list(body.out_avals)
            for i, (a, b) in enumerate(zip(cin, cout)):
                if _avals_mismatch(a, b):
                    find("JXP002",
                         f"while carry leaf {i} drifts across "
                         f"iterations: {_aval_str(a)} -> {_aval_str(b)}")
                if getattr(a, "weak_type", False):
                    find("JXP001", f"weak-typed while carry leaf "
                                   f"{_aval_str(a)}")

    out_avals = closed.out_avals
    for i, a in enumerate(out_avals):
        if getattr(a, "weak_type", False) and not allow_weak_outputs:
            find("JXP001", f"weak-typed program output {i}: "
                           f"{_aval_str(a)}")
        if not allow_64 and getattr(a, "dtype", None) is not None \
                and a.dtype.itemsize == 8 \
                and a.dtype.kind in ("f", "i", "u", "c"):
            find("JXP003", f"64-bit output {i} ({_aval_str(a)}) in a "
                           f"lane declared 32-bit")
    if not allow_64:
        for eqn in iter_eqns(closed):
            for v in eqn.outvars:
                a = getattr(v, "aval", None)
                dt = getattr(a, "dtype", None)
                if dt is not None and dt.itemsize == 8 \
                        and dt.kind in ("f", "i", "u", "c"):
                    find("JXP003",
                         f"64-bit intermediate from primitive "
                         f"'{eqn.primitive.name}' ({_aval_str(a)})")
                    break
            else:
                continue
            break

    stats = JaxprStats(label=label, eqns=count_eqns(closed), scans=scans,
                       whiles=whiles, carry_leaves=carry_leaves,
                       carry_bytes=carry_bytes, outputs=len(out_avals))
    return stats, findings


def audit_fn(fn: Callable, *example_args, label: str = "<fn>",
             allow_64: bool = True, allow_weak_outputs: bool = False
             ) -> tuple[JaxprStats, list[Finding]]:
    """Trace ``fn`` on example args/ShapeDtypeStructs and audit it."""
    jax = _jax()
    closed = jax.make_jaxpr(fn)(*example_args)
    return audit_jaxpr(closed, label=label, allow_64=allow_64,
                       allow_weak_outputs=allow_weak_outputs)


# --------------------------------------------------------------------------
# engine enumeration + tracing
# --------------------------------------------------------------------------

def _audit_cluster(lifecycle=None, fleet=None):
    from repro.core.cluster import ClusterCfg
    return ClusterCfg(n_workers=AUDIT_W, cores=2, capacity_factor=2,
                      lifecycle=lifecycle, fleet=fleet)


def iter_engine_specs(*, balancers: Optional[Iterable[str]] = None,
                      sched: str = "PS") -> list[tuple]:
    """(label, policy, cluster, backend, telemetry, chunk, timeline)
    per engine.

    Covers every (balancer × traceable backend) pair in the registry —
    backends are ``jax`` plus ``pallas`` (balancers without a kernel
    run their jax implementation under the pallas backend, exactly as
    :func:`repro.policy.registry._pallas_select` dispatches them) —
    plus one ``jax`` lane per registered keep-alive policy (balancer
    ``LL``) so lifecycle carries are audited too, plus ``|tel`` lanes
    (telemetry-on variants of representative engines: stateless,
    kernel, carried-state, lifecycle and late binding) so the streaming
    telemetry carry is covered by the jaxpr rules and eqn budgets,
    plus ``|fleet`` lanes (heterogeneous two-gen speeds under the
    speed-blind LL and the speed-learning SWARM balancers, and one
    ``|fleet|auto|tel`` lane with the ``TARGET_P99`` autoscaler carry
    riding the telemetry sketch), plus ``|chunk`` lanes (the streaming
    chunk engine's per-segment scan — same arrival/completion bodies
    with the slot mirrors and exact-counter carry; ``chunk`` is
    ``None`` for monolithic lanes), plus ``|tl`` lanes (the windowed
    flight-recorder plane of :mod:`repro.telemetry.timeline` riding
    the carry — alone, stacked on telemetry, on the hybrid balancer
    whose mode flips it logs, under the autoscaler whose decisions it
    logs, and through the chunk engine; ``timeline`` is the trailing
    tuple element, ``None`` when the plane is off).
    """
    from repro.core.taxonomy import Binding, PolicySpec
    from repro.fleet import FleetCfg
    from repro.lifecycle import LifecycleCfg
    from repro.lifecycle.registry import keepalive_names
    from repro.policy import balancer_names
    from repro.telemetry import TelemetryCfg
    names = tuple(balancers) if balancers is not None \
        else balancer_names()
    specs: list[tuple] = []
    plain = _audit_cluster()
    for bname in names:
        pol = PolicySpec(Binding.EARLY, bname, sched)
        for backend in ("jax", "pallas"):
            specs.append((f"{pol.name}|{backend}", pol, plain, backend,
                          None))
    if balancers is None:
        pol = PolicySpec(Binding.EARLY, "LL", sched)
        for ka in keepalive_names():
            cl = _audit_cluster(LifecycleCfg(keepalive=ka))
            specs.append((f"{pol.name}|jax|ka={ka}", pol, cl, "jax",
                          None))
        # the late-binding engine (no balancer axis, controller queue)
        late = PolicySpec(Binding.LATE, "LL", "FCFS")
        specs.append((f"{late.name}|jax", late, plain, "jax", None))
        # telemetry-on lanes — one per engine family, not the full
        # product (the telemetry carry is policy-independent)
        tel = TelemetryCfg()
        for bname in ("LL", "H", "HIKU"):
            p = PolicySpec(Binding.EARLY, bname, sched)
            specs.append((f"{p.name}|jax|tel", p, plain, "jax", tel))
        ph = PolicySpec(Binding.EARLY, "H", sched)
        specs.append((f"{ph.name}|pallas|tel", ph, plain, "pallas", tel))
        cl = _audit_cluster(LifecycleCfg(keepalive="FIXED_TTL"))
        specs.append((f"{pol.name}|jax|ka=FIXED_TTL|tel", pol, cl,
                      "jax", tel))
        specs.append((f"{late.name}|jax|tel", late, plain, "jax", tel))
        # heterogeneous-fleet lanes: speed vectors thread the scan for
        # a speed-blind and a speed-learning balancer, and one
        # autoscaler lane carries the MIAD controller state (needs the
        # telemetry sketch it reads)
        het = _audit_cluster(fleet=FleetCfg(preset="two-gen"))
        for bname in ("LL", "SWARM"):
            p = PolicySpec(Binding.EARLY, bname, sched)
            specs.append((f"{p.name}|jax|fleet", p, het, "jax", None))
        auto = _audit_cluster(fleet=FleetCfg(
            preset="two-gen", autoscale="TARGET_P99", target_p99=4.0,
            min_workers=1, cooldown_s=1.0))
        specs.append((f"{pol.name}|jax|fleet|auto|tel", pol, auto,
                      "jax", tel))
        # streaming chunk-engine lanes: plain, telemetry-on, the
        # heaviest lifecycle carry, and the full autoscaler stack —
        # budgeted under their own ``|chunk`` labels (the chunk scan
        # adds slot mirrors + exact counters to the carry)
        kacl = _audit_cluster(LifecycleCfg(keepalive="HYBRID_HIST"))
        for lane, cl2, t2 in ((f"{pol.name}|jax|chunk", plain, None),
                              (f"{pol.name}|jax|tel|chunk", plain, tel),
                              (f"{pol.name}|jax|ka=HYBRID_HIST|tel"
                               f"|chunk", kacl, tel),
                              (f"{pol.name}|jax|fleet|auto|tel|chunk",
                               auto, tel)):
            specs.append((lane, pol, cl2, "jax", t2, AUDIT_N))
        # windowed-timeline lanes: the flight-recorder plane alone,
        # stacked on the telemetry sketch, on the hybrid balancer
        # (whose mode flips it logs), under the autoscaler (whose
        # grow/shrink decisions it logs), and riding the chunk
        # engine's carry across segment boundaries
        from repro.telemetry import TimelineCfg
        tl = TimelineCfg()
        for lane, p3, cl3, t3, ch3 in (
                (f"{pol.name}|jax|tl", pol, plain, None, None),
                (f"{pol.name}|jax|tel|tl", pol, plain, tel, None),
                (f"{ph.name}|jax|tel|tl", ph, plain, tel, None),
                (f"{pol.name}|jax|fleet|auto|tel|tl", pol, auto, tel,
                 None),
                (f"{pol.name}|jax|tel|tl|chunk", pol, plain, tel,
                 AUDIT_N)):
            specs.append((lane, p3, cl3, "jax", t3, ch3, tl))
    return [s + (None,) * (7 - len(s)) for s in specs]


def trace_engine(policy, cluster, backend: str = "jax",
                 n_arrivals: int = AUDIT_N, n_functions: int = AUDIT_F,
                 telemetry=None, timeline=None):
    """``jax.make_jaxpr`` of the raw scan engine (tracing only)."""
    jax = _jax()
    import jax.numpy as jnp
    from repro.core.simulator import _build_engine
    run = _build_engine(policy, cluster, n_arrivals, n_functions,
                        backend, telemetry=telemetry,
                        timeline=timeline)
    N, F = n_arrivals, n_functions
    f64 = jax.ShapeDtypeStruct((N,), jnp.float64)
    i64 = jax.ShapeDtypeStruct((N,), jnp.int64)
    homes = jax.ShapeDtypeStruct((F,), jnp.int64)
    return jax.make_jaxpr(run)(f64, i64, f64, f64, homes)


def trace_stream_engine(policy, cluster, backend: str = "jax",
                        chunk: int = AUDIT_N,
                        n_functions: int = AUDIT_F, telemetry=None,
                        timeline=None):
    """``jax.make_jaxpr`` of the streaming chunk scan (one segment).

    The carry avals come from the engine's own ``init`` (leading rep
    axis stripped), so the traced program is exactly what one
    ``step_fn`` dispatch of :func:`repro.core.streaming
    .simulate_stream` compiles per replication.
    """
    jax = _jax()
    import jax.numpy as jnp
    from repro.core.simulator import _build_engine
    init, run_chunk, _ = _build_engine(
        policy, cluster, int(chunk), n_functions, backend,
        telemetry=telemetry, timeline=timeline, stream=True)
    st = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), init(1, 0))
    k, F = int(chunk), n_functions
    f64 = jax.ShapeDtypeStruct((k,), jnp.float64)
    i64 = jax.ShapeDtypeStruct((k,), jnp.int64)
    valid = jax.ShapeDtypeStruct((k,), jnp.bool_)
    homes = jax.ShapeDtypeStruct((F,), jnp.int64)
    return jax.make_jaxpr(run_chunk)(st, i64, valid, f64, i64, f64,
                                     f64, homes)


def audit_engines(*, balancers: Optional[Iterable[str]] = None
                  ) -> tuple[list[JaxprStats], list[Finding]]:
    """Trace + audit every engine spec; returns (stats, findings)."""
    all_stats: list[JaxprStats] = []
    findings: list[Finding] = []
    for label, policy, cluster, backend, telemetry, chunk, timeline \
            in iter_engine_specs(balancers=balancers):
        if chunk is not None:
            closed = trace_stream_engine(policy, cluster, backend,
                                         chunk=chunk,
                                         telemetry=telemetry,
                                         timeline=timeline)
        else:
            closed = trace_engine(policy, cluster, backend,
                                  telemetry=telemetry,
                                  timeline=timeline)
        stats, fs = audit_jaxpr(closed, label=label, allow_64=True)
        all_stats.append(stats)
        findings.extend(fs)
    return all_stats, findings


# --------------------------------------------------------------------------
# engine-cache-key completeness probe (JXP005)
# --------------------------------------------------------------------------

def _perturb(value: Any, field: str):
    """A different-but-valid value for a config field, or None to skip."""
    if field == "keepalive":
        from repro.lifecycle.registry import keepalive_names
        others = [k for k in keepalive_names() if k != value]
        return others[0] if others else None
    if field == "coldstart":
        return "paper-sim" if value != "paper-sim" else "scalar"
    if field == "preset":
        return "two-gen" if value != "two-gen" else "long-tail"
    if field == "autoscale":
        return "TARGET_P99" if value != "TARGET_P99" else "STATIC"
    if field in ("speed", "mem"):
        return (1.0,) * AUDIT_W if value == () else ()
    if isinstance(value, bool):
        return not value
    if isinstance(value, int):
        return value + 1
    if isinstance(value, float):
        return value + 1.0
    if isinstance(value, str):
        return value + "_x"
    return None


def audit_cache_key() -> list[Finding]:
    """Probe ``build_simulator``'s memo key against every config field.

    For each ``ClusterCfg`` field (and each ``LifecycleCfg`` /
    ``FleetCfg`` sub-field) a perturbed config is built; if the
    engine-cache key does not
    change, two different configs would share one compiled engine —
    the bug class the PR-6 satellite regression test locks in.
    """
    from repro.core.simulator import _cache_key
    from repro.core.taxonomy import parse_policy
    from repro.fleet import FleetCfg
    from repro.lifecycle import LifecycleCfg
    findings: list[Finding] = []
    policy = parse_policy("E/LL/PS")

    def probe(base, changed, field: str):
        k0 = _cache_key(policy, base, AUDIT_N, AUDIT_F, False, "jax")
        k1 = _cache_key(policy, changed, AUDIT_N, AUDIT_F, False, "jax")
        if k0 == k1:
            findings.append(Finding(
                path=f"<cache-key:{field}>", line=0, rule="JXP005",
                message=f"configs differing in '{field}' share an "
                        f"engine cache key", hint=RULES["JXP005"].hint))

    base = _audit_cluster()
    for field in type(base)._fields:
        value = getattr(base, field)
        if field == "lifecycle":
            probe(base, base._replace(lifecycle=LifecycleCfg()),
                  "lifecycle")
            continue
        if field == "fleet":
            probe(base, base._replace(fleet=FleetCfg()), "fleet")
            continue
        new = _perturb(value, field)
        if new is None:
            continue
        probe(base, base._replace(**{field: new}), field)

    fbase = _audit_cluster(fleet=FleetCfg())
    for field in FleetCfg._fields:
        value = getattr(fbase.fleet, field)
        new = _perturb(value, field)
        if new is None:
            continue
        probe(fbase, fbase._replace(
            fleet=fbase.fleet._replace(**{field: new})),
            f"fleet.{field}")

    lbase = _audit_cluster(LifecycleCfg())
    for field in LifecycleCfg._fields:
        value = getattr(lbase.lifecycle, field)
        new = _perturb(value, field)
        if new is None:
            continue
        probe(lbase, lbase._replace(
            lifecycle=lbase.lifecycle._replace(**{field: new})),
            f"lifecycle.{field}")

    # telemetry is part of the traced program (python-gated carry), so
    # it must be part of the key: off vs on, and every TelemetryCfg
    # field perturbed
    from repro.telemetry import TelemetryCfg

    def probe_tel(t0, t1, field: str):
        k0 = _cache_key(policy, base, AUDIT_N, AUDIT_F, False, "jax", t0)
        k1 = _cache_key(policy, base, AUDIT_N, AUDIT_F, False, "jax", t1)
        if k0 == k1:
            findings.append(Finding(
                path=f"<cache-key:{field}>", line=0, rule="JXP005",
                message=f"configs differing in '{field}' share an "
                        f"engine cache key", hint=RULES["JXP005"].hint))

    tbase = TelemetryCfg()
    probe_tel(None, tbase, "telemetry")
    for field in TelemetryCfg._fields:
        new = _perturb(getattr(tbase, field), field)
        if new is None:
            continue
        probe_tel(tbase, tbase._replace(**{field: new}),
                  f"telemetry.{field}")

    # the chunk size is its own key component: a monolithic engine and
    # a chunked one (and two different chunk sizes) must never share a
    # compiled program
    def probe_chunk(c0, c1, field: str):
        k0 = _cache_key(policy, base, AUDIT_N, AUDIT_F, True, "jax",
                        None, c0)
        k1 = _cache_key(policy, base, AUDIT_N, AUDIT_F, True, "jax",
                        None, c1)
        if k0 == k1:
            findings.append(Finding(
                path=f"<cache-key:{field}>", line=0, rule="JXP005",
                message=f"configs differing in '{field}' share an "
                        f"engine cache key", hint=RULES["JXP005"].hint))

    probe_chunk(None, AUDIT_N, "chunk")
    probe_chunk(AUDIT_N, 2 * AUDIT_N, "chunk.size")

    # the timeline plane is python-gated into the carry exactly like
    # telemetry, so it is the key's trailing component: off vs on, and
    # every TimelineCfg field perturbed (n_windows/coarse_bins resize
    # carry planes; max_events resizes the event log; window_s is
    # baked into the traced window-index arithmetic)
    from repro.telemetry import TimelineCfg

    def probe_timeline(t0, t1, field: str):
        k0 = _cache_key(policy, base, AUDIT_N, AUDIT_F, False, "jax",
                        None, None, t0)
        k1 = _cache_key(policy, base, AUDIT_N, AUDIT_F, False, "jax",
                        None, None, t1)
        if k0 == k1:
            findings.append(Finding(
                path=f"<cache-key:{field}>", line=0, rule="JXP005",
                message=f"configs differing in '{field}' share an "
                        f"engine cache key", hint=RULES["JXP005"].hint))

    wbase = TimelineCfg()
    probe_timeline(None, wbase, "timeline")
    for field in TimelineCfg._fields:
        new = _perturb(getattr(wbase, field), field)
        if new is None:
            continue
        probe_timeline(wbase, wbase._replace(**{field: new}),
                       f"timeline.{field}")
    return findings


def run_audit(*, balancers: Optional[Iterable[str]] = None
              ) -> tuple[list[JaxprStats], list[Finding]]:
    """Full jaxpr pass: engine audits + cache-key probe."""
    stats, findings = audit_engines(balancers=balancers)
    findings.extend(audit_cache_key())
    return stats, findings
