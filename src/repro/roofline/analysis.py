"""Three-term roofline analysis from compiled dry-run artifacts.

Per (arch × shape × mesh) cell::

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

(cost_analysis on a GSPMD-partitioned executable is already per device,
so no further division by chip count.)

**While-loop correction.**  XLA's cost analysis counts a while-loop body
once, and this framework scans over layers (and the flash attention
scans over blocks).  The roofline therefore never reads the full scanned
module; it compiles two *unrolled* lowerings with ``L = unit`` and
``L = 2·unit`` layers (``unit`` = the arch's repeat period) and solves
the affine model ``cost(L) = fixed + per_layer·L`` exactly — layers are
homogeneous, so the extrapolation to the real depth is exact, and the
unrolled attention (`attn_impl="xla_unrolled"`) makes the true causal
FLOPs visible.  The full-depth scanned compile (launch/dryrun.py) is
still what proves memory fits; this module owns the FLOPs/bytes/
collective terms.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI, ~25 GB/s/link inter-pod (DCI assumption, stated in EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

from repro import configs
from repro.configs.shapes import SHAPES, applicable

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (in-pod)
DCI_BW = 25e9                # bytes/s per link (cross-pod, assumption)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    # extrapolated per-device totals for the real depth
    flops: float
    bytes_hbm: float
    coll_bytes: float
    coll_cross_pod: float
    # the three terms, in seconds
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float            # 6·N_active·tokens (train) / 2· (serve)
    useful_ratio: float           # MODEL_FLOPS / (HLO_FLOPs × chips)
    roofline_frac: float          # t_ideal_compute / max(terms)
    note: str = ""

    def row(self):
        return dataclasses.asdict(self)


def model_flops(cfg, shape_cfg) -> float:
    """Analytic matmul FLOPs: 6·N·D (train) or 2·N·D (forward-only)."""
    n_active = cfg.active_params()
    tokens = shape_cfg.global_batch * (
        shape_cfg.seq_len if shape_cfg.kind in ("train", "prefill") else 1)
    mult = 6.0 if shape_cfg.kind == "train" else 2.0
    return mult * n_active * tokens


def _unit(cfg) -> int:
    return cfg.hybrid_attn_every if cfg.family == "hybrid" and \
        cfg.hybrid_attn_every else 1


def roofline_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                  rule_overrides: dict | None = None,
                  cfg_overrides: dict | None = None,
                  attn_impl: str = "xla_unrolled") -> Roofline | None:
    """Two-point unrolled lowering → affine per-layer cost → roofline."""
    from repro.launch.dryrun import run_cell  # env flag set by caller/main
    cfg = configs.get(arch)
    shape_cfg = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape_cfg)
    if not ok:
        return None
    u = _unit(cfg)
    r1 = run_cell(arch, shape_name, multi_pod=multi_pod,
                  layer_mode="unroll", n_layers=u, attn_impl=attn_impl,
                  rule_overrides=rule_overrides,
                  cfg_overrides=cfg_overrides)
    r2 = run_cell(arch, shape_name, multi_pod=multi_pod,
                  layer_mode="unroll", n_layers=2 * u, attn_impl=attn_impl,
                  rule_overrides=rule_overrides,
                  cfg_overrides=cfg_overrides)
    if r1.status != "ok" or r2.status != "ok":
        raise RuntimeError(
            f"roofline lowering failed: {r1.reason} / {r2.reason}")
    L = cfg.n_layers

    def extrap(a, b):
        per_layer = (b - a) / u
        return a + per_layer * (L - u)

    flops = extrap(r1.flops, r2.flops)
    bytes_hbm = extrap(r1.bytes_accessed, r2.bytes_accessed)
    coll = extrap(r1.collectives.get("total", 0.0),
                  r2.collectives.get("total", 0.0))
    cp = extrap(r1.collectives.get("cross_pod", 0.0),
                r2.collectives.get("cross_pod", 0.0))
    chips = 512 if multi_pod else 256
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_hbm / HBM_BW
    t_coll = (coll - cp) / ICI_BW + cp / DCI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape_cfg)
    useful = mf / max(flops * chips, 1e-9)
    t_ideal = mf / chips / PEAK_FLOPS
    frac = t_ideal / max(max(terms.values()), 1e-12)
    return Roofline(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        flops=flops, bytes_hbm=bytes_hbm, coll_bytes=coll,
        coll_cross_pod=cp, t_compute=t_comp, t_memory=t_mem,
        t_collective=t_coll, bottleneck=bottleneck, model_flops=mf,
        useful_ratio=useful, roofline_frac=frac)


def fmt_row(r: Roofline) -> str:
    return (f"{r.arch:18s} {r.shape:12s} {r.mesh:8s} "
            f"comp={r.t_compute*1e3:9.3f}ms mem={r.t_memory*1e3:9.3f}ms "
            f"coll={r.t_collective*1e3:9.3f}ms -> {r.bottleneck:10s} "
            f"useful={r.useful_ratio:6.3f} roofline={r.roofline_frac:6.3f}")


def main() -> None:
    # device-count override must precede jax init — dryrun sets it on
    # import, so import it before anything touches jax.
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/roofline.json")
    args = ap.parse_args()
    cells = ([(a, s) for a in configs.ARCH_NAMES for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    rows = []
    for arch, shape in cells:
        try:
            r = roofline_cell(arch, shape, multi_pod=args.multi_pod)
        except RuntimeError as e:
            print(f"{arch:18s} {shape:12s} FAIL {e}", flush=True)
            continue
        if r is None:
            print(f"{arch:18s} {shape:12s} SKIP (inapplicable)", flush=True)
            continue
        print(fmt_row(r), flush=True)
        rows.append(r.row())
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    import repro.launch.dryrun  # noqa: F401 — sets the device-count flag
    main()
