"""Roofline report: derived metrics + markdown table from roofline.json.

Adds the *bandwidth* roofline fraction: decode steps are intrinsically
memory-bound (arithmetic intensity ≈ 1 flop/byte), so grading them
against peak FLOP/s alone is meaningless.  We compute an analytic
lower bound on HBM traffic per device:

* train   — parameter-system traffic: params(read+write) + grads +
            fp32 moments (read+write): ≈ (4·p_bytes + 16)·N/chips,
            plus token activations through the stack once.
* prefill — active params read (bf16) + KV/state cache write.
* decode  — active params read + full cache read per token.

``bw_frac   = t_min_bytes / max(term)`` — how close the dominant term is
to the analytic traffic floor;
``comp_frac = t_ideal_flops / max(term)`` — the classic MFU-style bound;
``roofline_frac = max(comp, bw)`` is the reported score per cell.
"""
from __future__ import annotations

import argparse
import json

from repro import configs
from repro.configs.shapes import SHAPES
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS


def _cache_bytes(cfg, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "rwkv6":
        H = cfg.d_model // cfg.rwkv.head_size
        K = cfg.rwkv.head_size
        return cfg.n_layers * B * (H * K * K * 4 + 2 * cfg.d_model * 2)
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        Hs = d_in // s.head_dim
        ssm = cfg.n_layers * B * (Hs * s.head_dim * s.d_state * 4
                                  + (s.conv_width - 1)
                                  * (d_in + 2 * s.d_state) * 2)
        n_attn = cfg.n_layers // cfg.hybrid_attn_every \
            if cfg.hybrid_attn_every else 0
        kv = n_attn * B * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2
        return ssm + kv
    if cfg.mla is not None:
        return cfg.n_layers * B * S * (cfg.mla.kv_lora
                                       + cfg.mla.qk_rope) * 2
    return cfg.n_layers * B * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2


def min_bytes(cfg, shape, chips: int) -> float:
    n_act = cfg.active_params()
    p_bytes = 2 if cfg.param_dtype == "bfloat16" else 4
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    act_stream = tokens * cfg.d_model * cfg.n_layers * 2 * 2  # r+w, bf16
    if shape.kind == "train":
        total = n_act * (4 * p_bytes + 16) + 3 * act_stream
    elif shape.kind == "prefill":
        total = n_act * 2 + _cache_bytes(cfg, shape) + act_stream
    else:
        total = n_act * 2 + _cache_bytes(cfg, shape) + act_stream
    return total / chips


def enrich(row: dict) -> dict:
    cfg = configs.get(row["arch"])
    shape = SHAPES[row["shape"]]
    chips = 512 if row["mesh"] == "2x16x16" else 256
    t_max = max(row["t_compute"], row["t_memory"], row["t_collective"])
    mb = min_bytes(cfg, shape, chips)
    t_bw = mb / HBM_BW
    comp_frac = (row["model_flops"] / chips / PEAK_FLOPS) / t_max
    bw_frac = t_bw / t_max
    out = dict(row)
    out.update(min_bytes_dev=mb, t_bw_ideal=t_bw,
               comp_frac=comp_frac, bw_frac=bw_frac,
               roofline_frac=max(comp_frac, bw_frac))
    return out


def to_markdown(rows) -> str:
    head = ("| arch | shape | mesh | compute | memory | collective | "
            "bottleneck | useful | comp-frac | bw-frac | roofline |\n"
            "|---|---|---|---|---|---|---|---|---|---|---|")
    out = [head]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']*1e3:.2f} ms | {r['t_memory']*1e3:.2f} ms "
            f"| {r['t_collective']*1e3:.2f} ms | **{r['bottleneck']}** "
            f"| {r['useful_ratio']:.2f} | {r['comp_frac']:.3f} "
            f"| {r['bw_frac']:.3f} | **{r['roofline_frac']:.3f}** |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="experiments/roofline.json")
    ap.add_argument("--out", default="experiments/roofline_table.md")
    args = ap.parse_args()
    rows = [enrich(r) for r in json.load(open(args.json))]
    md = to_markdown(rows)
    with open(args.out, "w") as f:
        f.write(md + "\n")
    print(md)
    # the three hillclimb candidates
    trainish = [r for r in rows if r["shape"] in ("train_4k",
                                                  "prefill_32k")]
    worst = min(rows, key=lambda r: r["roofline_frac"])
    collbound = max(rows, key=lambda r: r["t_collective"]
                    / max(r["t_compute"], r["t_memory"], 1e-12))
    print(f"\nworst roofline: {worst['arch']}/{worst['shape']} "
          f"({worst['roofline_frac']:.3f})")
    print(f"most collective-bound: {collbound['arch']}/"
          f"{collbound['shape']}")


if __name__ == "__main__":
    main()
