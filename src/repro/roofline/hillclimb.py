"""§Perf hillclimb: hypothesis → change → re-lower → re-analyse.

Runs the three selected cells through their iteration ladders (each
rung toggles one optimization via cfg/rule overrides so the delta is
attributable), printing before/after roofline terms and writing
``experiments/perf_iterations.json``.

    PYTHONPATH=src python -m repro.roofline.hillclimb
"""
from __future__ import annotations

import json
import os

# --- each entry: (arch, shape, label, hypothesis, kwargs) ---------------
RUNS = [
    # ---- Cell 1: granite-20b decode_32k (collective-bound serving) ----
    ("granite-20b", "decode_32k", "baseline",
     "MQA kv=1 → cache seq-sharded over model; XLA materializes a "
     "gathered f32 [B,H,S] score array per layer → 5 GB/step collectives "
     "+ 30 GB/step HBM.",
     dict(cfg_overrides={"flash_decode": False})),
    ("granite-20b", "decode_32k", "+flash-decode",
     "shard_map partial-softmax merge: scores stay local [B,H,S/16] "
     "f32; combine = pmax(m)+psum(l,o) ≈ 200 KB/layer → collective "
     "term ~30x down, memory term ~8x down.",
     dict()),
    # ---- Cell 2: deepseek-v2-236b decode_32k (worst cell) -------------
    ("deepseek-v2-236b", "decode_32k", "baseline",
     "Two pathologies: (a) FSDP weight layout forces a per-layer expert "
     "weight all-gather (~28 GB/step — 26x what the 128 tokens need); "
     "(b) MLA scores materialize gathered f32 [B,H,S] arrays.",
     dict(cfg_overrides={"flash_decode": False},
          rule_overrides={"fsdp": "data", "expert_ff": None})),
    ("deepseek-v2-236b", "decode_32k", "+mla-flash-decode",
     "latent-space partial softmax over the seq-sharded c_kv cache "
     "(scores local, psum of [B,H,kv_lora]) → memory term down.",
     dict(rule_overrides={"fsdp": "data", "expert_ff": None})),
    ("deepseek-v2-236b", "decode_32k", "+serving-weight-layout",
     "decode latency path must not FSDP-gather: shard expert ff dim "
     "over 'data' instead (reads local, combine psum is [T,D]-sized) "
     "→ collective term ~20x down.",
     dict()),
    # ---- Cell 3: qwen3-14b train_4k (collective-bound training) -------
    ("qwen3-14b", "train_4k", "baseline",
     "40 heads on 16-way TP: GSPMD 'involuntary full rematerialization' "
     "replicates head-sharded tensors at every attention block "
     "transition → 5.6 TB/step collectives.",
     dict(cfg_overrides={"gqa_pad": False})),
    ("qwen3-14b", "train_4k", "+gqa-pad",
     "pad q heads 40→48 inside each KV group + replicate kv 8→16: all "
     "head dims divide TP → pathological copies vanish; cost ≤1.2x "
     "attention FLOPs.",
     dict()),
    ("qwen3-14b", "train_4k", "+remat-dots",
     "full remat recomputes every matmul in backward (useful≈0.75); "
     "checkpoint_dots keeps matmul outputs → HLO FLOPs ≈ model FLOPs.",
     dict(cfg_overrides={"remat": "dots"})),
]


def main() -> None:
    from repro.roofline.analysis import fmt_row, roofline_cell
    from repro.roofline.report import enrich
    rows = []
    prev_key = None
    prev = None
    for arch, shape, label, hyp, kw in RUNS:
        r = roofline_cell(arch, shape, **kw)
        e = enrich(r.row())
        key = (arch, shape)
        print(f"\n=== {arch} / {shape} — {label} ===", flush=True)
        print(f"hypothesis: {hyp}")
        print(fmt_row(r))
        print(f"  comp-frac={e['comp_frac']:.4f} bw-frac={e['bw_frac']:.4f}"
              f" roofline={e['roofline_frac']:.4f}")
        if prev is not None and prev_key == key:
            for t in ("t_compute", "t_memory", "t_collective"):
                b, a = prev[t], e[t]
                print(f"  {t}: {b*1e3:10.2f} → {a*1e3:10.2f} ms  "
                      f"({b/max(a,1e-12):5.1f}x)")
        e.update(label=label, hypothesis=hyp)
        rows.append(e)
        prev, prev_key = e, key
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/perf_iterations.json", "w") as f:
        json.dump(rows, f, indent=1)
    print("\nwrote experiments/perf_iterations.json")


if __name__ == "__main__":
    import repro.launch.dryrun  # noqa: F401 — device-count flag
    main()
