"""Deterministic synthetic LM data pipeline.

Two generators:

* ``random_batch`` — uniform tokens (throughput benchmarks, dry-runs).
* ``lcg_batch`` — a learnable affine-recurrence language (``t_{i+1} =
  (a·t_i + b) mod V`` with per-sequence (a, b) drawn from a small set),
  so end-to-end training demos show a decreasing loss.

Batches are keyed by step index — replaying a step after a restart
yields bit-identical data (required by the fault-tolerant driver).
``place`` puts a batch on the mesh with the ``batch`` logical sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distribution.sharding import named_sharding


def random_batch(step: int, batch: int, seq: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng((seed, step))
    tokens = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int32)
    return tokens[:, :-1], tokens[:, 1:]


_COEFFS = [(5, 3), (7, 11), (13, 5), (3, 17)]


def lcg_batch(step: int, batch: int, seq: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng((seed, step))
    ab = rng.integers(0, len(_COEFFS), batch)
    t0 = rng.integers(0, vocab, batch)
    toks = np.empty((batch, seq + 1), dtype=np.int64)
    toks[:, 0] = t0
    for i, (a, b) in enumerate(_COEFFS):
        sel = ab == i
        for t in range(seq):
            toks[sel, t + 1] = (a * toks[sel, t] + b) % vocab
    toks = toks.astype(np.int32)
    return toks[:, :-1], toks[:, 1:]


def place(tokens, labels):
    """Device-put a host batch under the active ``batch`` sharding."""
    sh = named_sharding("batch", "seq")
    if sh is None:
        return jnp.asarray(tokens), jnp.asarray(labels)
    return (jax.device_put(jnp.asarray(tokens), sh),
            jax.device_put(jnp.asarray(labels), sh))


def make_data_iter(kind: str, batch: int, seq: int, vocab: int,
                   seed: int = 0, *, device: bool = True):
    gen = {"random": random_batch, "lcg": lcg_batch}[kind]

    def data_iter(step: int):
        t, l = gen(step, batch, seq, vocab, seed)
        return place(t, l) if device else (jnp.asarray(t), jnp.asarray(l))

    return data_iter
