"""Trace → ``Workload`` reconstruction for the batched simulator.

Two reconstruction halves (both deterministic in ``seed``):

* **Arrivals** are *per-minute-count-exact*: every function's invocation
  count in every minute of the trace is honored exactly — each of the
  ``c`` invocations of function ``f`` in minute ``m`` lands uniformly at
  random inside ``[60m, 60(m+1))``.  Non-stationarity (diurnal cycles,
  bursts, flash crowds) is therefore preserved by construction, unlike
  the stationary Poisson generators in :mod:`repro.core.workload`.
* **Durations** are sampled from a per-function Log-normal fitted by
  least squares in log space to the trace's ``percentile_Average_*``
  columns (the 1/25/50/75/99 points; 0/100 are sample min/max and are
  excluded), truncated at the platform timeout like
  :func:`repro.core.workload.synth_workload`.

Offered-load targeting uses *time compression*: scaling every arrival
time by ``α`` leaves the count-per-(scaled)-minute structure and the
shape of the non-stationarity intact while sweeping the offered-load
fraction — the trace analogue of the paper's "scale the number of
invocations to produce different load levels" (§6.1).  Traces shorter
than the requested ``n_arrivals`` are tiled whole-trace-at-a-time with
fresh per-repeat randomness.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.cluster import ClusterCfg
from repro.core.workload import Workload, WorkloadBatch, stack_workloads

from .schema import AZURE_MU, AZURE_SIGMA, AzureTrace, norm_ppf

MINUTE_S = 60.0

# Fit on the interior percentiles only: 0/100 are the min/max of a
# finite sample, not distribution quantiles.
_FIT_PERCENTILES = (1, 25, 50, 75, 99)
_FIT_Z = np.array([norm_ppf(p / 100.0) for p in _FIT_PERCENTILES])


def fit_lognormal_from_percentiles(duration_ms: dict) -> tuple[float, float]:
    """Least-squares Log-normal fit ``(mu, sigma)`` (log-space, seconds).

    Solves ``ln(p_q) = mu + sigma * z_q`` over the interior percentile
    points.  Degenerate inputs (constant or non-positive percentiles)
    collapse to ``sigma = 0`` around the median.
    """
    pts = [(z, duration_ms.get(p)) for z, p in zip(_FIT_Z, _FIT_PERCENTILES)]
    pts = [(z, v) for z, v in pts if v is not None and v > 0]
    if not pts:
        raise ValueError(
            f"no positive interior percentiles to fit: {duration_ms}")
    z = np.array([p[0] for p in pts])
    y = np.log(np.array([p[1] for p in pts]) / 1000.0)
    if len(pts) == 1 or np.allclose(y, y[0]):
        return float(y.mean()), 0.0
    zc = z - z.mean()
    sigma = float((zc * (y - y.mean())).sum() / (zc * zc).sum())
    sigma = max(sigma, 0.0)
    mu = float(y.mean() - sigma * z.mean())
    return mu, sigma


def _minute_exact_arrivals(counts: np.ndarray, rng: np.random.Generator,
                           t_offset_minutes: int) -> tuple:
    """Sorted arrival times + function ids honoring ``(F, T)`` counts."""
    f_ids, m_ids = np.nonzero(counts)
    c = counts[f_ids, m_ids]
    f_rep = np.repeat(f_ids, c).astype(np.int32)
    m_rep = np.repeat(m_ids, c)
    t = (m_rep + t_offset_minutes) * MINUTE_S \
        + rng.uniform(0.0, MINUTE_S, size=int(c.sum()))
    order = np.argsort(t, kind="stable")
    return t[order], f_rep[order]


def replay_trace(trace: AzureTrace, cluster: ClusterCfg, *,
                 load: float | None = None, n_arrivals: int | None = None,
                 seed: int = 0, max_service: float = 600.0,
                 name: str | None = None) -> Workload:
    """Reconstruct a :class:`~repro.core.workload.Workload` from a trace.

    ``load`` — target offered-load fraction of cluster capacity, reached
    by uniformly compressing/stretching arrival times (``None`` keeps
    real time: one trace minute = 60 s, and ``Workload.load`` records the
    realized fraction).  ``n_arrivals`` — exact invocation count to emit;
    the trace is tiled whole-trace-at-a-time when shorter and truncated
    when longer (``None`` replays the trace once, verbatim).
    """
    counts = trace.counts_matrix()
    total = int(counts.sum())
    if total == 0:
        raise ValueError("trace has zero invocations; nothing to replay")
    F = trace.n_functions
    rng = np.random.default_rng(seed)

    need = total if n_arrivals is None else int(n_arrivals)
    if need < 1:
        raise ValueError(f"n_arrivals must be >= 1, got {n_arrivals}")
    t_chunks, f_chunks, produced, rep = [], [], 0, 0
    while produced < need:
        t, f = _minute_exact_arrivals(counts, rng, rep * trace.minutes)
        t_chunks.append(t)
        f_chunks.append(f)
        produced += len(t)
        rep += 1
    arrival = np.concatenate(t_chunks)[:need]
    func = np.concatenate(f_chunks)[:need]

    mus = np.empty(F)
    sigmas = np.empty(F)
    for i, fn in enumerate(trace.functions):
        try:
            mus[i], sigmas[i] = \
                fit_lognormal_from_percentiles(fn.duration_ms)
        except ValueError:
            # real Azure rows can be all-zero (Count=0 / sub-ms
            # functions); fall back to the trace-wide default, as
            # load_trace does for missing duration rows
            mus[i], sigmas[i] = AZURE_MU, AZURE_SIGMA
    service = np.exp(mus[func] + sigmas[func] * rng.standard_normal(need))
    service = np.minimum(service, max_service)

    horizon = float(arrival[-1])
    if horizon <= 0.0:
        raise ValueError("degenerate trace: all arrivals at t=0")
    realized = float(service.sum()) / (horizon * cluster.total_cores)
    if load is not None:
        if load <= 0:
            raise ValueError(f"load must be positive, got {load}")
        arrival = arrival * (realized / load)
    return Workload(
        arrival=arrival.astype(np.float64),
        func=func,
        service=service.astype(np.float64),
        u_lb=rng.uniform(size=need),
        func_home=rng.integers(0, cluster.n_workers,
                               size=F).astype(np.int32),
        n_functions=F,
        load=float(load) if load is not None else realized,
        name=name or "trace-replay",
    )


def per_minute_counts(wl: Workload, n_functions: int, minutes: int, *,
                      minute_s: float = MINUTE_S) -> np.ndarray:
    """Histogram a workload back into an ``(F, T)`` count matrix.

    The inverse of the arrival half of :func:`replay_trace` (with
    ``load=None`` and no tiling/truncation it reproduces
    ``trace.counts_matrix()`` exactly).  Arrivals past ``minutes`` fold
    back modulo the trace length, undoing whole-trace tiling.
    """
    m = np.floor(wl.arrival / minute_s).astype(np.int64) % minutes
    out = np.zeros((n_functions, minutes), dtype=np.int64)
    np.add.at(out, (wl.func, m), 1)
    return out


def resample_workloads(wls, *, n: int | None = None) -> WorkloadBatch:
    """Resample heterogeneous workloads onto one ``(N, F)`` batch shape.

    Trace replays of different scenarios/files rarely agree on arrival
    count or function count, but :func:`repro.core.simulator
    .simulate_many` needs one shape per compiled program.  This truncates
    every workload to ``n`` arrivals (default: the smallest ``N`` in the
    set — truncation only, never padding: padded phantom arrivals would
    perturb the schedule) and widens ``n_functions`` to the largest ``F``
    (absent function ids never occur in ``func``, so their padded sticky
    homes — worker 0 — are inert).
    """
    wls = list(wls)
    if not wls:
        raise ValueError("resample_workloads needs at least one workload")
    n_min = min(wl.n for wl in wls)
    n = n_min if n is None else int(n)
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    if n > n_min:
        raise ValueError(
            f"cannot resample up: requested n={n} but the shortest "
            f"workload ({min(wls, key=lambda w: w.n).name!r}) has only "
            f"{n_min} arrivals")
    F = max(wl.n_functions for wl in wls)
    out = []
    for wl in wls:
        home = wl.func_home
        if wl.n_functions < F:
            home = np.concatenate([
                home, np.zeros(F - wl.n_functions, dtype=np.int32)])
        out.append(dataclasses.replace(
            wl, arrival=wl.arrival[:n], func=wl.func[:n],
            service=wl.service[:n], u_lb=wl.u_lb[:n],
            func_home=home, n_functions=F))
    return stack_workloads(out)
