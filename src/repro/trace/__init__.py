"""Azure-schema trace ingestion & non-stationary replay.

The paper's policy exploration (§3) and Hermes evaluation (§6) are driven
by the 14-day Azure Functions 2019 trace.  This package makes trace-shaped
load a first-class workload source for the batched simulator:

* :mod:`repro.trace.schema` — parsing + validation of the released Azure
  Functions 2019 dataset layout (per-function per-minute invocation
  counts; per-function execution-duration percentiles).
* :mod:`repro.trace.synth_trace` — a deterministic generator that *emits*
  trace files in the Azure schema (diurnal / bursty / cold-start-heavy /
  flash-crowd presets), so the repo is self-contained without shipping
  the 1 GB+ dataset.  A small fixture slice lives under
  ``repro/trace/data/``.
* :mod:`repro.trace.replay` — per-minute-count-exact non-stationary
  arrival reconstruction + Log-normal duration sampling fitted to the
  trace percentiles, emitting :class:`~repro.core.workload.Workload`
  arrays that slot directly into ``simulate`` / ``simulate_many``.
* :mod:`repro.trace.catalog` — named scenario registry; merged into
  ``repro.core.WORKLOADS`` so every ``--workload`` flag accepts trace
  scenarios (``azure-diurnal``, ``azure-bursty``, ...).
* :mod:`repro.trace.cache` — parsed-trace cache keyed by file digest.

Import-order note: :mod:`repro.core` imports :mod:`repro.trace.catalog`
to merge the scenario registry into ``WORKLOADS``, and
:mod:`repro.trace.replay` imports workload dataclasses from
:mod:`repro.core.workload` — so ``catalog`` (and this ``__init__``) stay
import-light and everything heavier is loaded lazily via PEP 562.
"""
from __future__ import annotations

from .catalog import TRACE_SCENARIOS, DATA_DIR  # noqa: F401  (core-free)

_LAZY = {
    "schema": ".schema",
    "synth_trace": ".synth_trace",
    "replay": ".replay",
    "cache": ".cache",
}

_LAZY_SYMBOLS = {
    "AzureTrace": "schema", "TraceFunction": "schema", "load_trace": "schema",
    "synthesize_trace": "synth_trace", "write_trace_csvs": "synth_trace",
    "SCENARIOS": "synth_trace",
    "replay_trace": "replay", "resample_workloads": "replay",
    "per_minute_counts": "replay", "fit_lognormal_from_percentiles": "replay",
    "load_trace_cached": "cache", "file_digest": "cache",
}

__all__ = ["TRACE_SCENARIOS", "DATA_DIR", "catalog", *_LAZY,
           *_LAZY_SYMBOLS]


def __getattr__(name: str):
    import importlib
    if name in _LAZY:
        return importlib.import_module(_LAZY[name], __name__)
    if name in _LAZY_SYMBOLS:
        mod = importlib.import_module("." + _LAZY_SYMBOLS[name], __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
