"""Azure Functions 2019 trace schema: CSV parsing + validation.

The released dataset (Shahrad et al., ATC'20 — the trace behind the
paper's §3/§6 evaluation) ships two per-day CSV families this package
consumes:

* ``invocations_per_function_md.anon.dXX.csv`` — one row per function,
  key columns ``HashOwner,HashApp,HashFunction,Trigger`` followed by
  ``1..1440`` integer invocation counts, one per minute of the day.
* ``function_durations_percentiles.anon.dXX.csv`` — one row per
  function: ``Average,Count,Minimum,Maximum`` plus
  ``percentile_Average_{0,1,25,50,75,99,100}`` execution durations in
  **milliseconds**.

This module is numpy-only — no JAX, and its single ``repro.core``
dependency is the paper's Log-normal constants (``repro.core`` never
imports the simulator at package level, so nothing heavy is dragged
in).  Everything is
validated up front — header layout, contiguous minute columns,
non-negative integer counts, percentile monotonicity, key joins — so a
malformed file fails with a named ``ValueError`` instead of a downstream
shape error.
"""
from __future__ import annotations

import csv
import dataclasses
import math

import numpy as np

HASH_COLUMNS = ("HashOwner", "HashApp", "HashFunction")
INVOCATION_FIXED_COLUMNS = HASH_COLUMNS + ("Trigger",)
DURATION_PERCENTILES = (0, 1, 25, 50, 75, 99, 100)
DURATION_COLUMNS = HASH_COLUMNS + ("Average", "Count", "Minimum", "Maximum") \
    + tuple(f"percentile_Average_{p}" for p in DURATION_PERCENTILES)

# Azure-trace Log-normal parameters (paper Fig. 2 caption) — the default
# duration distribution for functions missing a durations row.  Single
# source of truth is repro.core.workload; re-exported here for trace-side
# consumers.
from repro.core.workload import AZURE_MU, AZURE_SIGMA  # noqa: E402,F401


def norm_ppf(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation).

    |relative error| < 1.15e-9 over (0, 1); keeps the package scipy-free.
    """
    if not 0.0 < p < 1.0:
        raise ValueError(f"norm_ppf needs p in (0, 1), got {p}")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p > phigh:
        q = math.sqrt(-2 * math.log(1 - p))
        return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                 * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
            * r + a[5]) * q / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4])
            * r + 1)


# z-scores used to materialize the Azure percentile columns from a
# Log-normal.  p0/p100 are the *observed* min/max of a finite sample —
# modeled at the ±(1 - 1e-3) quantile rather than ±inf.
_PCTL_Z = {0: norm_ppf(1e-3), 1: norm_ppf(0.01), 25: norm_ppf(0.25),
           50: 0.0, 75: norm_ppf(0.75), 99: norm_ppf(0.99),
           100: norm_ppf(1 - 1e-3)}


def lognormal_percentiles_ms(mu: float, sigma: float) -> dict[int, float]:
    """Azure ``percentile_Average_*`` columns (ms) of a Log-normal whose
    log-space parameters ``mu, sigma`` are in *seconds*."""
    return {p: 1000.0 * math.exp(mu + sigma * z)
            for p, z in _PCTL_Z.items()}


@dataclasses.dataclass(frozen=True)
class TraceFunction:
    """One function of an Azure-schema trace (joined across both files)."""

    owner: str
    app: str
    func: str
    trigger: str
    counts: np.ndarray          # (T,) int64 invocations per minute
    duration_ms: dict           # percentile (int) -> duration in ms
    average_ms: float
    count: int                  # dataset-reported execution count
    minimum_ms: float
    maximum_ms: float

    @property
    def total_invocations(self) -> int:
        return int(self.counts.sum())

    @property
    def key(self) -> tuple:
        return (self.owner, self.app, self.func)


@dataclasses.dataclass(frozen=True)
class AzureTrace:
    """A parsed trace slice: ``F`` functions over ``T`` minutes."""

    functions: tuple            # (F,) TraceFunction, invocation-file order
    minutes: int                # T

    @property
    def n_functions(self) -> int:
        return len(self.functions)

    @property
    def total_invocations(self) -> int:
        return sum(f.total_invocations for f in self.functions)

    def counts_matrix(self) -> np.ndarray:
        """The ``(F, T)`` per-minute invocation-count matrix."""
        if not self.functions:
            return np.zeros((0, self.minutes), dtype=np.int64)
        return np.stack([f.counts for f in self.functions])


def _read_rows(path: str) -> tuple[list, list]:
    with open(path, newline="") as fh:
        reader = csv.reader(fh)
        rows = [row for row in reader if row]
    if not rows:
        raise ValueError(f"{path}: empty trace file")
    return rows[0], rows[1:]


def read_invocations(path: str) -> tuple[list, int]:
    """Parse an Azure invocations-per-minute CSV.

    Returns ``(entries, minutes)`` with one
    ``(key, trigger, counts[int64 T])`` tuple per row, in file order.
    """
    header, rows = _read_rows(path)
    k = len(INVOCATION_FIXED_COLUMNS)
    if tuple(header[:k]) != INVOCATION_FIXED_COLUMNS:
        raise ValueError(
            f"{path}: invocation header must start with "
            f"{','.join(INVOCATION_FIXED_COLUMNS)}; got {header[:k]}")
    minute_cols = header[k:]
    if not minute_cols:
        raise ValueError(f"{path}: no per-minute count columns")
    expected = [str(i + 1) for i in range(len(minute_cols))]
    if minute_cols != expected:
        raise ValueError(
            f"{path}: minute columns must be contiguous 1..{len(expected)}; "
            f"got {minute_cols[:5]}...")
    minutes = len(minute_cols)
    entries, seen = [], set()
    for i, row in enumerate(rows):
        if len(row) != k + minutes:
            raise ValueError(
                f"{path} row {i + 2}: expected {k + minutes} cells, "
                f"got {len(row)}")
        key = tuple(row[:3])
        if key in seen:
            raise ValueError(f"{path} row {i + 2}: duplicate function {key}")
        seen.add(key)
        try:
            counts = np.array([int(c) for c in row[k:]], dtype=np.int64)
        except ValueError as e:
            raise ValueError(
                f"{path} row {i + 2}: non-integer invocation count "
                f"({e})") from None
        if (counts < 0).any():
            raise ValueError(
                f"{path} row {i + 2}: negative invocation count")
        entries.append((key, row[3], counts))
    return entries, minutes


def read_durations(path: str) -> dict:
    """Parse an Azure duration-percentiles CSV into ``{key: stats}``."""
    header, rows = _read_rows(path)
    if tuple(header) != DURATION_COLUMNS:
        raise ValueError(
            f"{path}: duration header must be exactly "
            f"{','.join(DURATION_COLUMNS)}; got {header}")
    out = {}
    for i, row in enumerate(rows):
        if len(row) != len(DURATION_COLUMNS):
            raise ValueError(
                f"{path} row {i + 2}: expected {len(DURATION_COLUMNS)} "
                f"cells, got {len(row)}")
        key = tuple(row[:3])
        if key in out:
            raise ValueError(f"{path} row {i + 2}: duplicate function {key}")
        try:
            avg, cnt = float(row[3]), int(float(row[4]))
            mn, mx = float(row[5]), float(row[6])
            pct = {p: float(v)
                   for p, v in zip(DURATION_PERCENTILES, row[7:])}
        except ValueError as e:
            raise ValueError(
                f"{path} row {i + 2}: malformed numeric cell ({e})"
            ) from None
        if cnt < 0:
            raise ValueError(f"{path} row {i + 2}: negative Count")
        if mn > mx:
            raise ValueError(
                f"{path} row {i + 2}: Minimum {mn} > Maximum {mx}")
        vals = [pct[p] for p in DURATION_PERCENTILES]
        if any(v < 0 for v in vals):
            raise ValueError(f"{path} row {i + 2}: negative percentile")
        if any(a > b for a, b in zip(vals, vals[1:])):
            raise ValueError(
                f"{path} row {i + 2}: percentiles not non-decreasing: "
                f"{vals}")
        out[key] = dict(average_ms=avg, count=cnt, minimum_ms=mn,
                        maximum_ms=mx, duration_ms=pct)
    return out


def load_trace(invocations_csv: str, durations_csv: str, *,
               allow_missing_durations: bool = False) -> AzureTrace:
    """Join the two Azure files into an :class:`AzureTrace`.

    Functions present in the invocations file but missing a durations row
    raise by default (the bundled/synthetic traces are always complete);
    ``allow_missing_durations=True`` substitutes the trace-wide Azure
    Log-normal default instead — the pragmatic choice on real dataset
    slices, where the join is imperfect.  Duration rows with no matching
    invocation row are ignored (the real dataset has those too).
    """
    entries, minutes = read_invocations(invocations_csv)
    durations = read_durations(durations_csv)
    default = None
    funcs, missing = [], []
    for key, trigger, counts in entries:
        stats = durations.get(key)
        if stats is None:
            if not allow_missing_durations:
                missing.append(key)
                continue
            if default is None:
                pct = lognormal_percentiles_ms(AZURE_MU, AZURE_SIGMA)
                default = dict(
                    average_ms=1000.0 * math.exp(
                        AZURE_MU + AZURE_SIGMA ** 2 / 2),
                    count=0, minimum_ms=pct[0], maximum_ms=pct[100],
                    duration_ms=pct)
            # fresh duration_ms per function — no aliasing across the
            # frozen TraceFunction instances
            stats = {**default, "duration_ms": dict(default["duration_ms"])}
        funcs.append(TraceFunction(
            owner=key[0], app=key[1], func=key[2], trigger=trigger,
            counts=counts, **stats))
    if missing:
        raise ValueError(
            f"{durations_csv}: no duration row for {len(missing)} "
            f"function(s) present in {invocations_csv} "
            f"(first: {missing[0]}); pass allow_missing_durations=True "
            f"to substitute the Azure default Log-normal")
    return AzureTrace(functions=tuple(funcs), minutes=minutes)
