"""Deterministic generator of trace files in the Azure schema.

The released Azure Functions 2019 dataset is >1 GB and cannot ship with
the repo, so scenario traces are *synthesized in the dataset's own
schema* — per-function per-minute invocation counts plus per-function
duration percentiles — and round-trip through exactly the same
:mod:`repro.trace.schema` / :mod:`repro.trace.replay` path a real
dataset slice would.  Four presets cover the non-stationary regimes the
stationary Poisson generators in :mod:`repro.core.workload` cannot
express:

``diurnal``
    Zipf-weighted functions riding a sinusoidal daily cycle with
    per-function phase offsets — the dominant shape of the real trace
    (Shahrad et al. §3.3).
``bursty``
    Low Poisson baseline with per-function on/off burst windows at
    ~12× the base rate (MMPP-style), stressing reactive balancing.
``cold-heavy``
    80 % of functions invoked rarely (well below keep-alive periods) so
    most arrivals cold-start; 20 % carry the bulk of the load.
``flash-crowd``
    Flat background plus one function spiking ~40× for a short window
    mid-trace — the worst case for locality-first placement.

Counts are Poisson draws around the scenario intensity profile,
normalized so the expected total invocation count hits
``total_invocations``; everything is a pure function of ``seed``.
Durations are per-function Log-normals whose percentile columns are
materialized analytically (:func:`repro.trace.schema
.lognormal_percentiles_ms`), so :func:`repro.trace.replay
.fit_lognormal_from_percentiles` recovers the parameters exactly.
"""
from __future__ import annotations

import csv
import dataclasses
import math
import os

import numpy as np

from .schema import (AzureTrace, DURATION_COLUMNS, DURATION_PERCENTILES,
                     INVOCATION_FIXED_COLUMNS, TraceFunction,
                     lognormal_percentiles_ms)

_TRIGGERS = ("http", "timer", "queue", "event", "storage")


@dataclasses.dataclass(frozen=True)
class ScenarioCfg:
    """Preset defaults for one synthetic-trace scenario."""

    name: str
    description: str
    n_functions: int = 40
    minutes: int = 180


SCENARIOS = {
    "diurnal": ScenarioCfg(
        "diurnal", "Zipf skew on a sinusoidal daily cycle"),
    "bursty": ScenarioCfg(
        "bursty", "low baseline with ~12x on/off burst windows"),
    "cold-heavy": ScenarioCfg(
        "cold-heavy", "80% of functions too rare to stay warm",
        n_functions=60),
    "flash-crowd": ScenarioCfg(
        "flash-crowd", "flat background + one ~40x mid-trace spike"),
}


def _zipf_weights(n: int, s: float = 1.1) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** s
    return w / w.sum()


def _intensity(scenario: str, n_functions: int, minutes: int,
               rng: np.random.Generator) -> np.ndarray:
    """Unnormalized ``(F, T)`` mean-invocation-rate profile."""
    F, T = n_functions, minutes
    m = np.arange(T)
    if scenario == "diurnal":
        w = _zipf_weights(F)
        period = min(T, 1440)
        phase = rng.uniform(0, 2 * math.pi, size=F)
        cycle = 1.0 + 0.8 * np.sin(
            2 * math.pi * m[None, :] / period + phase[:, None])
        return w[:, None] * cycle
    if scenario == "bursty":
        base = _zipf_weights(F, s=0.7)[:, None] * np.ones(T)[None, :]
        burst = np.zeros((F, T))
        for f in range(F):
            n_bursts = rng.integers(1, 4)
            for _ in range(n_bursts):
                start = int(rng.integers(0, T))
                width = int(rng.integers(max(2, T // 60), max(3, T // 12)))
                burst[f, start:start + width] = 1.0
        return base * (1.0 + 11.0 * burst)
    if scenario == "cold-heavy":
        n_hot = max(1, F // 5)
        w = np.full(F, 0.2 / max(F - n_hot, 1))
        w[:n_hot] = 0.8 / n_hot
        jitter = rng.uniform(0.5, 1.5, size=(F, T))
        return w[:, None] * jitter
    if scenario == "flash-crowd":
        w = _zipf_weights(F, s=0.5)
        prof = w[:, None] * np.ones(T)[None, :]
        start = int(0.45 * T)
        width = max(2, T // 20)
        spike_f = min(2, F - 1)  # a mid-rank function goes viral
        prof[spike_f, start:start + width] *= 40.0
        return prof
    raise ValueError(
        f"unknown scenario {scenario!r}; expected one of "
        f"{sorted(SCENARIOS)}")


def synthesize_trace(scenario: str, *, n_functions: int | None = None,
                     minutes: int | None = None,
                     total_invocations: int = 20000,
                     seed: int = 0) -> AzureTrace:
    """Generate an :class:`AzureTrace` for a named scenario preset.

    Deterministic in ``(scenario, n_functions, minutes,
    total_invocations, seed)``.  ``total_invocations`` is the *expected*
    total count (realized counts are Poisson).
    """
    cfg = SCENARIOS.get(scenario)
    if cfg is None:
        raise ValueError(
            f"unknown scenario {scenario!r}; expected one of "
            f"{sorted(SCENARIOS)}")
    F = n_functions if n_functions is not None else cfg.n_functions
    T = minutes if minutes is not None else cfg.minutes
    if F < 1 or T < 1:
        raise ValueError(f"need n_functions, minutes >= 1; got ({F}, {T})")
    rng = np.random.default_rng(seed)
    intensity = _intensity(scenario, F, T, rng)
    intensity = intensity * (total_invocations / max(intensity.sum(), 1e-12))
    counts = rng.poisson(intensity).astype(np.int64)

    # Per-function Log-normal duration parameters (log-space, seconds).
    # sigma capped well below the trace-wide 2.36 so per-function p99
    # stays under the 10-min platform timeout and replayed percentiles
    # are statistically recoverable from a few thousand samples.
    mu = rng.normal(-0.4, 0.8, size=F)
    sigma = rng.uniform(0.4, 1.5, size=F)

    funcs = []
    for f in range(F):
        pct = lognormal_percentiles_ms(float(mu[f]), float(sigma[f]))
        funcs.append(TraceFunction(
            owner=f"owner{seed:04d}", app=f"app{f // 8:03d}",
            func=f"fn{f:04d}-{scenario}",
            trigger=_TRIGGERS[f % len(_TRIGGERS)],
            counts=counts[f],
            duration_ms=pct,
            average_ms=1000.0 * math.exp(
                float(mu[f]) + float(sigma[f]) ** 2 / 2),
            count=int(counts[f].sum()),
            minimum_ms=pct[0], maximum_ms=pct[100]))
    return AzureTrace(functions=tuple(funcs), minutes=T)


def write_trace_csvs(trace: AzureTrace, invocations_csv: str,
                     durations_csv: str) -> None:
    """Emit a trace as the two Azure-schema CSV files.

    Floats are written with ``repr`` so parse → write → parse is exact.
    """
    for path in (invocations_csv, durations_csv):
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
    with open(invocations_csv, "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(list(INVOCATION_FIXED_COLUMNS)
                   + [str(i + 1) for i in range(trace.minutes)])
        for fn in trace.functions:
            w.writerow([fn.owner, fn.app, fn.func, fn.trigger]
                       + [int(c) for c in fn.counts])
    with open(durations_csv, "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(list(DURATION_COLUMNS))
        for fn in trace.functions:
            w.writerow([fn.owner, fn.app, fn.func,
                        repr(fn.average_ms), fn.count,
                        repr(fn.minimum_ms), repr(fn.maximum_ms)]
                       + [repr(fn.duration_ms[p])
                          for p in DURATION_PERCENTILES])


def write_fixture(out_dir: str, *, scenario: str = "diurnal",
                  n_functions: int = 12, minutes: int = 60,
                  total_invocations: int = 2500, seed: int = 2019) -> tuple:
    """(Re)generate the bundled fixture slice under ``repro/trace/data``."""
    inv = os.path.join(out_dir, "azure_fixture_invocations.csv")
    dur = os.path.join(out_dir, "azure_fixture_durations.csv")
    trace = synthesize_trace(scenario, n_functions=n_functions,
                             minutes=minutes,
                             total_invocations=total_invocations, seed=seed)
    write_trace_csvs(trace, inv, dur)
    return inv, dur


if __name__ == "__main__":
    import sys
    out = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.join(os.path.dirname(__file__), "data")
    paths = write_fixture(out)
    print("\n".join(paths))
