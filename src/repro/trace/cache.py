"""Digest-keyed cache of parsed traces.

Real Azure dataset slices are tens of MB of CSV; benchmark sweeps and
CLI runs re-load the same files for every (policy × load × seed) cell.
Parsed :class:`~repro.trace.schema.AzureTrace` objects are memoized
process-wide on the SHA-256 digest of the *file contents* (not paths or
mtimes — a rewritten file re-parses, a renamed copy hits), bounded LRU
so long multi-trace sweeps cannot grow it without limit.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

from .schema import AzureTrace, load_trace

#: Max parsed traces kept resident.  A full 14-day Azure sweep touches
#: 14 day-slices; 16 leaves headroom without letting a directory scan
#: pin hundreds of parsed traces.
TRACE_CACHE_MAX = 16

_TRACE_CACHE: "OrderedDict[tuple, AzureTrace]" = OrderedDict()
_HITS = 0
_MISSES = 0


def file_digest(path: str) -> str:
    """SHA-256 hex digest of a file's bytes (streamed, 1 MiB chunks)."""
    h = hashlib.sha256()
    with open(path, "rb") as fh:
        for chunk in iter(lambda: fh.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def load_trace_cached(invocations_csv: str, durations_csv: str, *,
                      allow_missing_durations: bool = False) -> AzureTrace:
    """:func:`repro.trace.schema.load_trace` through the digest cache."""
    global _HITS, _MISSES
    key = (file_digest(invocations_csv), file_digest(durations_csv),
           allow_missing_durations)
    trace = _TRACE_CACHE.get(key)
    if trace is not None:
        _HITS += 1
        _TRACE_CACHE.move_to_end(key)
        return trace
    _MISSES += 1
    trace = load_trace(invocations_csv, durations_csv,
                       allow_missing_durations=allow_missing_durations)
    _TRACE_CACHE[key] = trace
    while len(_TRACE_CACHE) > TRACE_CACHE_MAX:
        _TRACE_CACHE.popitem(last=False)
    return trace


def trace_cache_stats() -> dict:
    return {"entries": len(_TRACE_CACHE), "hits": _HITS,
            "misses": _MISSES, "capacity": TRACE_CACHE_MAX}


def clear_trace_cache() -> None:
    global _HITS, _MISSES
    _TRACE_CACHE.clear()
    _HITS = _MISSES = 0
