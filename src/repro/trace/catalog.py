"""Named trace-replay scenarios, merged into ``repro.core.WORKLOADS``.

Every entry has the standard workload-generator signature
``(cluster, load, n_arrivals, seed) -> Workload`` used throughout the
repo (benchmark sweeps, ``replicate_workload`` grids, ``--workload``
CLI flags), so trace scenarios are drop-in replacements for the
synthetic §6.1 generators — stackable into one
:class:`~repro.core.workload.WorkloadBatch` across loads and seeds.

Two scenario families:

* ``azure-diurnal`` / ``azure-bursty`` / ``azure-cold-heavy`` /
  ``azure-flash-crowd`` — synthesize an Azure-schema trace on the fly
  (deterministic in ``seed``; sized ~25 % above ``n_arrivals`` so tiling
  is the exception) and replay it at the requested offered load.  The
  same seed yields the same underlying trace at every load, so load
  sweeps use common random numbers and differ only in time compression.
* ``azure-fixture`` — replays the bundled dataset slice under
  ``repro/trace/data/`` through the full CSV → schema → cache → replay
  path (the exact pipeline a real dataset slice takes).

Import-order contract: this module is imported from
``repro/core/__init__.py`` *while that package is still initializing*,
and ``repro.trace.replay`` imports ``repro.core.workload`` — so all
``repro.trace``/``repro.core`` imports here live inside the scenario
functions, never at module level.
"""
from __future__ import annotations

import os

DATA_DIR = os.path.join(os.path.dirname(__file__), "data")
FIXTURE_INVOCATIONS = os.path.join(DATA_DIR, "azure_fixture_invocations.csv")
FIXTURE_DURATIONS = os.path.join(DATA_DIR, "azure_fixture_durations.csv")

# Replay RNG is decoupled from trace-synthesis RNG so trace shape and
# within-minute jitter vary independently across seeds.
_REPLAY_SEED_OFFSET = 7919


def _synth_scenario(scenario: str):
    def workload_fn(cluster, load, n_arrivals, seed=0):
        from .replay import replay_trace
        from .synth_trace import synthesize_trace
        trace = synthesize_trace(
            scenario, total_invocations=max(int(n_arrivals * 1.25), 64),
            seed=seed)
        return replay_trace(trace, cluster, load=load,
                            n_arrivals=n_arrivals,
                            seed=seed + _REPLAY_SEED_OFFSET,
                            name=f"azure-{scenario}")
    workload_fn.__name__ = f"azure_{scenario.replace('-', '_')}"
    workload_fn.__doc__ = (
        f"Trace replay of the synthetic Azure-schema {scenario!r} "
        f"scenario (see repro.trace.synth_trace).")
    return workload_fn


def azure_fixture(cluster, load, n_arrivals, seed=0):
    """Replay the bundled Azure-schema fixture slice (CSV → cache path)."""
    from .cache import load_trace_cached
    from .replay import replay_trace
    trace = load_trace_cached(FIXTURE_INVOCATIONS, FIXTURE_DURATIONS)
    return replay_trace(trace, cluster, load=load, n_arrivals=n_arrivals,
                        seed=seed + _REPLAY_SEED_OFFSET,
                        name="azure-fixture")


TRACE_SCENARIOS = {
    "azure-diurnal": _synth_scenario("diurnal"),
    "azure-bursty": _synth_scenario("bursty"),
    "azure-cold-heavy": _synth_scenario("cold-heavy"),
    "azure-flash-crowd": _synth_scenario("flash-crowd"),
    "azure-fixture": azure_fixture,
}
