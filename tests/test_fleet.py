"""Heterogeneous-fleet subsystem: golden default regression, speed
scaling, SWARM learning, autoscaler semantics and the registry contract.

The acceptance contract of the fleet axis:

* ``ClusterCfg()`` (no fleet) reproduces the pre-fleet results
  bit-for-bit on ALL THREE engines — locked against golden values
  captured from the seed engines;
* a ``uniform`` fleet (every speed 1.0) is bitwise identical to the
  homogeneous model (multiplying by 1.0 and dividing by 1.0 are exact);
* with unequal speeds, ``simulate ≡ simulate_ref ≡ simulate_many``
  task-by-task, including carried-state balancers and the autoscale
  control loop;
* the SWARM balancer actually learns the speed vector online (more
  placements on fast workers, lower tail than speed-blind LL);
* the autoscaler registry is open and its np/jax ``decide`` hooks take
  identical integer decisions.
"""
import numpy as np
import pytest

from repro.core import (ClusterCfg, E_DD_PS, E_LL_PS, E_SWARM_PS, FleetCfg,
                        HERMES, LATE_BINDING, parse_policy, synth_workload)
from repro.core.sim_ref import simulate_ref
from repro.core.simulator import simulate, simulate_many
from repro.fleet import (fleet_from_flags, get_autoscaler, parse_autoscale,
                         parse_fleet_preset, register_autoscaler,
                         resolve_fleet, speeds_for, unregister_autoscaler)
from repro.telemetry import TelemetryCfg

CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2,
                     cold_start_penalty=0.25)


def _wl(load=0.9, n=300, seed=7):
    return synth_workload(CLUSTER, load, n, n_functions=5,
                          hot_fraction=0.8, seed=seed)


def _fleet(preset="two-gen", **kw):
    return CLUSTER._replace(fleet=FleetCfg(preset=preset, **kw))


def _agree(policy, cluster, wl, telemetry=None):
    """simulate ≡ simulate_ref ≡ simulate_many, task-by-task."""
    out = simulate(policy, cluster, wl, telemetry=telemetry)
    ref = simulate_ref(policy, cluster, wl, telemetry=telemetry)
    np.testing.assert_array_equal(out.worker, ref.worker)
    np.testing.assert_array_equal(out.cold, ref.cold)
    np.testing.assert_array_equal(out.rejected, ref.rejected)
    np.testing.assert_allclose(
        np.nan_to_num(out.response, nan=-1.0),
        np.nan_to_num(ref.response, nan=-1.0), atol=1e-9)
    np.testing.assert_allclose(out.prov_core_s, ref.prov_core_s,
                               rtol=1e-9)
    batch = simulate_many(policy, cluster, [wl, wl], telemetry=telemetry)
    np.testing.assert_array_equal(
        np.nan_to_num(batch.response[0], nan=-1.0),
        np.nan_to_num(out.response, nan=-1.0))
    np.testing.assert_array_equal(batch.response[0], batch.response[1])
    return out, ref


# --------------------------------------------------------------- golden


# Captured from the seed engines (pre-fleet code) on _wl() above:
# policy -> ((scan sum/cold/rej), (oracle ...), (serving ...)).
_GOLDEN = {
    "E/H/PS": ((1216.6925067819345, 48, 0),
               (1216.6925067819345, 48, 0),
               (1213.7727968717463, 46, 0)),
    "E/LL/PS": ((1213.6759411691799, 53, 0),
                (1213.6759411691796, 53, 0),
                (1243.1626103184565, 53, 0)),
    "E/DD/PS": ((1414.2908184863632, 70, 0),
                (1414.290818486363, 70, 0),
                (1451.5937560680638, 73, 1)),
    "L/LL/FCFS": ((1217.1144495097842, 38, 0),
                  (1217.1144495097842, 38, 0),
                  (1227.9385679023862, 36, 0)),
}


@pytest.mark.parametrize("pname", sorted(_GOLDEN))
def test_default_reproduces_seed_results_bit_for_bit(pname):
    """fleet=None must not perturb any of the three engines."""
    from repro.serving.engine import ServeCfg, ServingCluster
    wl = _wl()
    pol = parse_policy(pname)
    (g_scan, g_ref, g_serve) = _GOLDEN[pname]
    out = simulate(pol, CLUSTER, wl)
    assert float(np.nansum(out.response)) == pytest.approx(g_scan[0],
                                                           rel=1e-12)
    assert (int(out.cold.sum()), int(out.rejected.sum())) == g_scan[1:]
    ref = simulate_ref(pol, CLUSTER, wl)
    assert float(np.nansum(ref.response)) == pytest.approx(g_ref[0],
                                                           rel=1e-12)
    assert (int(ref.cold.sum()), int(ref.rejected.sum())) == g_ref[1:]
    sv = ServingCluster(ServeCfg(cluster=CLUSTER), pol).run(wl)
    assert float(np.nansum(sv.response)) == pytest.approx(g_serve[0],
                                                          rel=1e-12)
    assert (int(sv.cold.sum()), int(sv.rejected.sum())) == g_serve[1:]
    # a fixed fleet's provisioned time degenerates to end_time × W × C
    assert out.prov_core_s == pytest.approx(
        out.end_time * CLUSTER.n_workers * CLUSTER.cores)


@pytest.mark.parametrize("policy", [HERMES, E_SWARM_PS],
                         ids=lambda p: p.name)
def test_uniform_fleet_bitwise_homogeneous(policy):
    """speed ≡ 1.0 multiplies/divides are IEEE-exact: the uniform
    preset must match the homogeneous model bit-for-bit everywhere."""
    from repro.serving.engine import ServeCfg, ServingCluster
    wl = _wl()
    uni = _fleet("uniform")
    base = simulate(policy, CLUSTER, wl)
    out = simulate(policy, uni, wl)
    np.testing.assert_array_equal(base.response, out.response)
    np.testing.assert_array_equal(base.worker, out.worker)
    rbase = simulate_ref(policy, CLUSTER, wl)
    rout = simulate_ref(policy, uni, wl)
    np.testing.assert_array_equal(rbase.response, rout.response)
    np.testing.assert_array_equal(rbase.worker, rout.worker)
    sbase = ServingCluster(ServeCfg(cluster=CLUSTER), policy).run(wl)
    sout = ServingCluster(ServeCfg(cluster=uni), policy).run(wl)
    np.testing.assert_array_equal(sbase.response, sout.response)
    np.testing.assert_array_equal(sbase.worker, sout.worker)


def test_heterogeneity_changes_results():
    wl = _wl()
    base = simulate(HERMES, CLUSTER, wl)
    slow = simulate(HERMES, _fleet("two-gen"), wl)
    # half the fleet at half speed strictly lengthens total response
    assert float(np.nansum(slow.response)) > float(np.nansum(base.response))


# ------------------------------------------------- golden engine parity


@pytest.mark.parametrize("policy",
                         [HERMES, E_LL_PS, E_SWARM_PS, E_DD_PS,
                          LATE_BINDING],
                         ids=lambda p: p.name)
@pytest.mark.parametrize("preset", ["two-gen", "long-tail"])
def test_golden_engine_agreement_heterogeneous(policy, preset):
    """Vectorized scan ≡ numpy oracle ≡ batched vmap with unequal
    speeds, for stateless and carried-state balancers and both fleet
    presets."""
    cl = _fleet(preset)
    for load, seed in ((0.5, 0), (0.9, 1)):
        _agree(policy, cl, _wl(load, 300, seed))


def test_explicit_speed_vector():
    """An explicit FleetCfg.speed overrides the preset and reaches the
    engines (one crippled worker visibly changes the simulation)."""
    wl = _wl()
    cl = CLUSTER._replace(fleet=FleetCfg(speed=(1.0, 1.0, 1.0, 0.125)))
    out, _ = _agree(HERMES, cl, wl)
    base = simulate(HERMES, CLUSTER, wl)
    assert float(np.nansum(out.response)) > float(np.nansum(base.response))
    np.testing.assert_array_equal(
        speeds_for(cl.fleet, 4), [1.0, 1.0, 1.0, 0.125])


# -------------------------------------------------------- SWARM learning


def test_swarm_learns_speed_skew():
    """On a two-gen fleet SWARM's learned 1/speed priorities shift
    placements toward the fast generation (workers [0, W//2) at speed
    1.0, the rest at 0.5) without reading FleetCfg."""
    wl = _wl(0.9, 600, 11)
    out = simulate(E_SWARM_PS, _fleet("two-gen"), wl)
    placed = out.worker[out.worker >= 0]
    fast = int((placed < 2).sum())
    slow = int((placed >= 2).sum())
    assert fast > slow, (fast, slow)
    # and the learned skew beats speed-blind least-loaded on the tail
    ll = simulate(E_LL_PS, _fleet("two-gen"), wl)
    p99 = np.nanpercentile(out.response, 99)
    p99_ll = np.nanpercentile(ll.response, 99)
    assert p99 <= p99_ll * 1.05, (p99, p99_ll)


# ------------------------------------------------- autoscaler decisions


def _window_at(value, count=100):
    """A sketch window with all mass in the bin containing ``value``."""
    from repro.telemetry.sketch import N_BINS, hist_edges
    edges = hist_edges()
    w = np.zeros(N_BINS, dtype=np.int64)
    w[int(np.searchsorted(edges, value, side="right")) - 1] = count
    return w


def test_target_p99_miad_semantics():
    """Grow multiplicatively on overshoot, shrink by one when below the
    hysteresis band, hold inside it; clip to [min_workers, n_workers];
    empty windows never move."""
    cfg = FleetCfg(autoscale="TARGET_P99", target_p99=4.0,
                   min_workers=2, hysteresis=0.1)
    decide = get_autoscaler("TARGET_P99").make_np(cfg, 8)
    hot = _window_at(50.0)       # p99 ~50 >> hi = 2.2
    cold = _window_at(1.0)       # p99 ~1 << lo = 1.8
    mid = _window_at(2.0)        # inside the band around 4.0/2
    assert decide(4, hot) == 6           # += max(1, 4//2)
    assert decide(1, hot) == 2           # += 1, floored at min_workers
    assert decide(7, hot) == 8           # clipped at n_workers
    assert decide(8, hot) == 8
    assert decide(6, cold) == 5          # -= 1
    assert decide(2, cold) == 2          # min_workers floor
    assert decide(5, mid) == 5           # dead-band hold
    assert decide(5, np.zeros_like(hot)) == 5


def test_target_p99_np_jax_decide_parity():
    """The np and jax controllers take identical integer decisions on
    identical windows (the sensor mirrors sketch_percentile op-for-op)."""
    import jax.numpy as jnp
    from repro.telemetry.sketch import N_BINS
    cfg = FleetCfg(autoscale="TARGET_P99", target_p99=3.0,
                   min_workers=1, hysteresis=0.15)
    pol = get_autoscaler("TARGET_P99")
    d_np = pol.make_np(cfg, 6)
    d_jax = pol.make_jax(cfg, 6)
    rng = np.random.default_rng(0)
    for _ in range(50):
        w = np.zeros(N_BINS, dtype=np.int64)
        idx = rng.integers(0, N_BINS, size=rng.integers(1, 6))
        w[idx] = rng.integers(1, 40, size=idx.size)
        n_on = int(rng.integers(1, 7))
        got_np = d_np(n_on, w)
        got_jax = int(d_jax(jnp.asarray(n_on, dtype=jnp.int32),
                            jnp.asarray(w)))
        assert got_np == got_jax, (n_on, got_np, got_jax)
    # empty-window no-op in both backends
    z = np.zeros(N_BINS, dtype=np.int64)
    assert d_np(3, z) == 3 == int(d_jax(jnp.asarray(3, dtype=jnp.int32),
                                        jnp.asarray(z)))


# ------------------------------------------------- autoscaling engines


def _auto_cluster(**kw):
    # target 4.0 puts the shrink band (lo = 1.8) above slowdown 1.0, so
    # the controller can actually scale down through quiet windows
    base = dict(preset="uniform", autoscale="TARGET_P99", target_p99=4.0,
                min_workers=1, cooldown_s=1.0)
    base.update(kw)
    return CLUSTER._replace(fleet=FleetCfg(**base))


def test_autoscale_engine_agreement_and_prov_accounting():
    wl = _wl(0.7, 300, 3)
    cl = _auto_cluster()
    out, ref = _agree(HERMES, cl, wl, telemetry=TelemetryCfg())
    static_prov = out.end_time * CLUSTER.n_workers * CLUSTER.cores
    # the controller actually scaled down somewhere: the provisioned
    # integral is strictly inside (0, static] and matches the oracle
    assert 0.0 < out.prov_core_s < static_prov
    # batched runs carry the per-rep integral too
    batch = simulate_many(HERMES, cl, [wl, wl], telemetry=TelemetryCfg())
    assert batch.prov_core_s.shape == (2,)
    np.testing.assert_allclose(batch.prov_core_s[0], out.prov_core_s,
                               rtol=1e-9)


def test_autoscale_requires_early_binding_and_telemetry():
    wl = _wl(0.5, 100, 0)
    cl = _auto_cluster()
    with pytest.raises(ValueError, match="requires early binding"):
        simulate(LATE_BINDING, cl, wl, telemetry=TelemetryCfg())
    with pytest.raises(ValueError, match="telemetry"):
        simulate(HERMES, cl, wl)
    with pytest.raises(ValueError, match="requires early binding"):
        simulate_ref(LATE_BINDING, cl, wl, telemetry=TelemetryCfg())
    with pytest.raises(ValueError, match="telemetry"):
        simulate_ref(HERMES, cl, wl)


def test_register_custom_autoscaler_end_to_end():
    """The autoscale contract is open: a fixed-step controller
    registered in ~15 lines drives both engines in agreement."""
    def make_np(cfg, n_workers):
        def decide(n_on, window):
            # shed one worker whenever anything completed in the window
            return max(int(cfg.min_workers), int(n_on) - 1)
        return decide

    def make_jax(cfg, n_workers):
        import jax.numpy as jnp

        def decide(n_on, window):
            n = jnp.maximum(int(cfg.min_workers),
                            n_on.astype(jnp.int32) - 1)
            return n.astype(jnp.int32)
        return decide

    register_autoscaler("SHED", make_np=make_np, make_jax=make_jax,
                        doc="shed one worker per decision window")
    try:
        assert parse_autoscale("shed") == "SHED"
        cl = _auto_cluster(autoscale="SHED", min_workers=2)
        wl = _wl(0.5, 300, 2)
        out, _ = _agree(HERMES, cl, wl, telemetry=TelemetryCfg())
        # the fleet ended scaled down: strictly fewer provisioned
        # core-seconds than the static envelope
        assert out.prov_core_s < \
            out.end_time * CLUSTER.n_workers * CLUSTER.cores
        placed = out.worker[out.worker >= 0]
        assert placed.max() <= 3          # never placed off-fleet
    finally:
        unregister_autoscaler("SHED")


# --------------------------------------------------- registry / config


def test_cluster_validate_named_errors():
    wl = _wl(0.5, 50, 0)
    with pytest.raises(ValueError, match="n_workers must be positive"):
        ClusterCfg(n_workers=0).validate()
    with pytest.raises(ValueError, match="cores must be positive"):
        ClusterCfg(cores=0).validate()
    with pytest.raises(ValueError, match="capacity_factor must be"):
        ClusterCfg(capacity_factor=-1).validate()
    with pytest.raises(ValueError, match="speed has 2 entries for n_workers=4"):
        CLUSTER._replace(fleet=FleetCfg(speed=(1.0, 0.5))).validate()
    with pytest.raises(ValueError, match="entries must be positive"):
        CLUSTER._replace(
            fleet=FleetCfg(speed=(1.0, 0.0, 1.0, 1.0))).validate()
    with pytest.raises(ValueError, match="min_workers must be in"):
        CLUSTER._replace(fleet=FleetCfg(min_workers=9)).validate()
    with pytest.raises(ValueError, match="unknown fleet preset"):
        CLUSTER._replace(fleet=FleetCfg(preset="turbo")).validate()
    with pytest.raises(ValueError, match="unknown autoscale policy"):
        CLUSTER._replace(fleet=FleetCfg(autoscale="MAGIC")).validate()
    # the engines call validate() at their API boundary
    bad = CLUSTER._replace(fleet=FleetCfg(speed=(1.0, 0.5)))
    with pytest.raises(ValueError, match="speed has 2 entries"):
        simulate(HERMES, bad, wl)
    with pytest.raises(ValueError, match="speed has 2 entries"):
        simulate_ref(HERMES, bad, wl)


def test_fleet_presets_and_resolve():
    assert parse_fleet_preset("TWO-GEN") == "two-gen"
    np.testing.assert_array_equal(
        speeds_for(FleetCfg(preset="uniform"), 4), np.ones(4))
    two = speeds_for(FleetCfg(preset="two-gen"), 5)
    np.testing.assert_array_equal(two, [1.0, 1.0, 1.0, 0.5, 0.5])
    tail = speeds_for(FleetCfg(preset="long-tail"), 4)
    assert tail[0] == 1.0 and np.all(np.diff(tail) < 0) and tail[-1] > 0
    assert resolve_fleet(CLUSTER) is None
    res = resolve_fleet(_fleet("two-gen"), backend="np")
    assert not res.auto_on and res.speeds.shape == (4,)
    res = resolve_fleet(_auto_cluster(), backend="jax")
    assert res.auto_on and callable(res.decide)
    assert get_autoscaler("STATIC").needs_telemetry is False
    assert get_autoscaler("TARGET_P99").needs_telemetry is True


def test_fleet_from_flags_cli_semantics():
    """All-defaults -> None (legacy, bit-for-bit); an autoscale flag
    without a preset runs the uniform fleet; names validated."""
    assert fleet_from_flags() is None
    fl = fleet_from_flags(preset="two-gen")
    assert fl == FleetCfg(preset="two-gen")
    fl = fleet_from_flags(speed=[1.0, 0.5])
    assert fl.speed == (1.0, 0.5)
    fl = fleet_from_flags(autoscale="target_p99", target_p99=3.0,
                          min_workers=2, cooldown_s=2.0)
    assert fl.preset == "uniform" and fl.autoscale == "TARGET_P99"
    assert fl.target_p99 == 3.0 and fl.min_workers == 2
    with pytest.raises(ValueError, match="unknown fleet preset"):
        fleet_from_flags(preset="NOPE")
    with pytest.raises(ValueError, match="unknown autoscale policy"):
        fleet_from_flags(autoscale="NOPE")


# --------------------------------------------------- serving platform


def test_serving_platform_matches_oracle_under_fleet():
    from repro.serving.engine import ServeCfg, ServingCluster
    wl = _wl(0.7, 300, 3)
    for cl in (_fleet("two-gen"), _fleet("long-tail")):
        # serving's cold cost is ServeCfg.cold_start_s — align it with
        # the oracle's cluster.cold_start_penalty for exact parity
        cfg0 = ServeCfg(cluster=cl, cold_start_s=0.25, ctrl_latency_s=0.0)
        sv = ServingCluster(cfg0, HERMES).run(wl)
        rf = simulate_ref(HERMES, cl, wl)
        np.testing.assert_array_equal(sv.worker, rf.worker)
        np.testing.assert_array_equal(sv.cold, rf.cold)


def test_serving_platform_autoscales():
    from repro.serving.engine import ServeCfg, ServingCluster
    wl = _wl(0.6, 300, 5)
    cl = _auto_cluster()
    cfg0 = ServeCfg(cluster=cl, cold_start_s=0.25, ctrl_latency_s=0.0)
    sv = ServingCluster(cfg0, HERMES, telemetry=TelemetryCfg()).run(wl)
    rf = simulate_ref(HERMES, cl, wl, telemetry=TelemetryCfg())
    np.testing.assert_array_equal(sv.worker, rf.worker)
    np.testing.assert_allclose(sv.prov_core_s, rf.prov_core_s, rtol=1e-9)
    assert sv.prov_core_s < sv.end_time * CLUSTER.n_workers * CLUSTER.cores
    # explicit ServeCfg.speeds still wins over the fleet preset
    cfgS = ServeCfg(cluster=_fleet("two-gen"),
                    speeds=(1.0, 1.0, 1.0, 0.25))
    out = ServingCluster(cfgS, E_LL_PS).run(wl)
    assert np.isfinite(out.end_time)
