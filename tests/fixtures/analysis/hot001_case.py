"""Fixture: host sync inside a @traced function -> exactly one HOT001."""
from repro.analysis import traced


@traced
def f(x):
    return float(x)
