"""Fixture: Python branch on a traced param -> exactly one HOT002."""
from repro.analysis import traced


@traced
def f(x):
    if x > 0:
        return x
    return -x
