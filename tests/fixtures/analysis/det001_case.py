"""Fixture: legacy numpy global RNG -> exactly one DET001."""
import numpy as np


def draw():
    return np.random.rand(4)
