"""Fixture: builtin-type astype -> exactly one PAR002."""
# repro-lint: parity-lane


def widen(x):
    return x.astype(float)
