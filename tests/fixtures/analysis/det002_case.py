"""Fixture: stdlib random global instance -> exactly one DET002."""
import random


def draw():
    return random.random()
