"""Fixture: weak-dtype jnp constructor -> exactly one PAR001."""
# repro-lint: parity-lane
import jax.numpy as jnp


def zeros():
    return jnp.zeros((3,))
