"""Fixture: every violation justified inline -> zero findings."""
# repro-lint: parity-lane
import numpy as np
import jax.numpy as jnp


def draw():
    return np.random.rand(4)  # repro-lint: disable=DET001 -- fixture

def zeros():
    # multi-line statement: a disable on any physical line applies
    return jnp.zeros(
        (3,))  # repro-lint: disable=PAR001 -- fixture
