"""Fixture: registry iteration in a hot path -> exactly one HOT003."""
# repro-lint: hot-path

BALANCERS = {}


def sweep():
    return [name for name in BALANCERS]
