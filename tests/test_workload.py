"""Workload generators: load calibration, truncation, degenerate traces,
and the up-front shape validation of ``stack_workloads``."""
import numpy as np
import pytest

from repro.core import (ClusterCfg, Workload, WORKLOADS, stack_workloads,
                        synth_workload, validate_workload)

CLUSTER = ClusterCfg(n_workers=4, cores=12)


def _realized_load(wl: Workload, cluster: ClusterCfg) -> float:
    return float(wl.service.sum()) / (wl.horizon * cluster.total_cores)


@pytest.mark.parametrize("exec_dist", ["lognormal", "exponential"])
@pytest.mark.parametrize("load", [0.3, 0.9])
def test_realized_offered_load_matches_request(exec_dist, load):
    # λ is calibrated against the empirical mean service time, so the
    # realized fraction of cluster capacity concentrates on `load` at
    # ~1/sqrt(n); 5% tolerance is ~7 sigma at n=20000.
    wl = synth_workload(CLUSTER, load, 20000, exec_dist=exec_dist, seed=3)
    assert _realized_load(wl, CLUSTER) == pytest.approx(load, rel=0.05)


@pytest.mark.parametrize("name", ["ms-trace", "ms-representative",
                                  "single-function", "multi-balanced",
                                  "homogeneous-exec"])
def test_section61_generators_calibrated(name):
    wl = WORKLOADS[name](CLUSTER, 0.6, 20000, 1)
    assert wl.n == 20000
    assert _realized_load(wl, CLUSTER) == pytest.approx(0.6, rel=0.05)
    assert (np.diff(wl.arrival) >= 0).all()


def test_max_service_truncation_honored():
    wl = synth_workload(CLUSTER, 0.5, 5000, max_service=2.0, seed=0)
    assert wl.service.max() <= 2.0
    # σ=2.36 puts a large mass above 2s — truncation must have fired
    assert (wl.service == 2.0).sum() > 100
    # and the default 600s cap binds the Azure-shaped tail too
    wl600 = synth_workload(CLUSTER, 0.5, 200000, seed=0)
    assert wl600.service.max() <= 600.0


def test_empty_trace_properties():
    wl = Workload(
        arrival=np.empty(0), func=np.empty(0, dtype=np.int32),
        service=np.empty(0), u_lb=np.empty(0),
        func_home=np.zeros(3, dtype=np.int32), n_functions=3,
        load=0.0, name="empty")
    assert wl.n == 0
    assert wl.horizon == 0.0
    validate_workload(wl)            # empty is structurally valid
    wb = stack_workloads([wl, wl])
    assert wb.n_reps == 2 and wb.n == 0


def _valid(n=50, f=4):
    rng = np.random.default_rng(0)
    return Workload(
        arrival=np.sort(rng.uniform(0, 100, n)),
        func=rng.integers(0, f, n).astype(np.int32),
        service=rng.uniform(0.1, 2.0, n),
        u_lb=rng.uniform(size=n),
        func_home=rng.integers(0, 4, f).astype(np.int32),
        n_functions=f, load=0.5, name="hand-built")


def test_stack_workloads_rejects_internal_mismatch():
    import dataclasses
    wl = _valid()
    bad_len = dataclasses.replace(wl, service=wl.service[:-1])
    with pytest.raises(ValueError, match="service"):
        stack_workloads([bad_len])
    bad_home = dataclasses.replace(
        wl, func_home=np.zeros(2, dtype=np.int32))
    with pytest.raises(ValueError, match="func_home"):
        stack_workloads([bad_home])
    bad_func = dataclasses.replace(
        wl, func=np.full(wl.n, 99, dtype=np.int32))
    with pytest.raises(ValueError, match="func ids"):
        stack_workloads([bad_func])
    bad_2d = dataclasses.replace(
        wl, u_lb=np.stack([wl.u_lb, wl.u_lb]))
    with pytest.raises(ValueError, match="u_lb"):
        stack_workloads([bad_2d])
    unsorted = dataclasses.replace(wl, arrival=wl.arrival[::-1].copy())
    with pytest.raises(ValueError, match="non-decreasing"):
        stack_workloads([unsorted])
    # the valid one still stacks
    assert stack_workloads([wl, _valid()]).n_reps == 2
