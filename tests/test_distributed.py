"""Sharded-execution tests (subprocesses with fake host devices, so the
main pytest process keeps its single CPU device)."""
import pytest

pytestmark = pytest.mark.slow


COMMON = """
import os, sys
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import configs
from repro.distribution.sharding import ShardCtx, make_rules, sharding_ctx
from repro.launch.mesh import make_test_mesh
from repro.models.transformer import build_model

def get_f32(name):
    # f32 so sharded-vs-single comparisons test *math*, not bf16
    # reduction-order noise
    return dataclasses.replace(configs.get_smoke(name), dtype="float32")
"""


def test_sharded_train_matches_single_device(devices_script):
    out = devices_script(COMMON + """
from repro.training.optimizer import OptCfg
from repro.training.train import init_train_state, build_train_step
from repro.data.pipeline import random_batch

cfg = get_f32("olmo-1b")
model = build_model(cfg)
ocfg = OptCfg(lr=1e-2, warmup_steps=2, total_steps=10)
tokens, labels = random_batch(0, 4, 32, cfg.vocab)
tokens, labels = jnp.asarray(tokens), jnp.asarray(labels)

# single-device reference
state0 = init_train_state(model, jax.random.key(0))
step0 = jax.jit(build_train_step(model, ocfg))
s_ref = state0
for i in range(3):
    s_ref, m_ref = step0(s_ref, tokens, labels)

# sharded
mesh = make_test_mesh((2, 2), ("data", "model"))
rules = make_rules()
ctx = ShardCtx(mesh=mesh, rules=rules)
with sharding_ctx(ctx):
    model_s = build_model(cfg)
    state = init_train_state(model_s, jax.random.key(0))
    specs = model_s.param_specs()
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda s: isinstance(s, P))
    params = jax.tree.map(jax.device_put, state.params, sh)
    state = state._replace(params=params)
    step = jax.jit(build_train_step(model_s, ocfg))
    for i in range(3):
        state, m = step(state, tokens, labels)

print("loss_ref", float(m_ref["loss"]), "loss_sharded", float(m["loss"]))
assert abs(float(m_ref["loss"]) - float(m["loss"])) < 2e-3
for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(state.params)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-3, atol=1e-3)
print("OK")
""", n_devices=4)
    assert "OK" in out


def test_moe_ep_matches_dense_on_mesh(devices_script):
    out = devices_script(COMMON + """
import dataclasses
from repro.models import moe as moe_mod
from repro.models.common import MoECfg

cfg = dataclasses.replace(
    configs.get_smoke("dbrx-132b"),
    moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=64, capacity_factor=16.0))
p = moe_mod.init_moe(jax.random.key(0), cfg)
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model), jnp.float32)
y_ref, aux_ref = moe_mod.moe_dense(cfg, p, x)

mesh = make_test_mesh((2, 2), ("data", "model"))
ctx = ShardCtx(mesh=mesh, rules=make_rules())
with sharding_ctx(ctx):
    y, aux = jax.jit(lambda p, x: moe_mod.moe_ep(cfg, p, x))(p, x)
np.testing.assert_allclose(np.asarray(y, np.float32),
                           np.asarray(y_ref, np.float32),
                           rtol=2e-2, atol=2e-2)
print("aux", float(aux), float(aux_ref))
assert abs(float(aux) - float(aux_ref)) < 1e-3
print("OK")
""", n_devices=4)
    assert "OK" in out


def test_compressed_grad_sync_close_to_exact(devices_script):
    out = devices_script(COMMON + """
from repro.training.optimizer import OptCfg
from repro.training.train import (init_train_state, build_train_step,
                                  build_train_step_compressed)
from repro.data.pipeline import random_batch

cfg = get_f32("olmo-1b")
ocfg = OptCfg(lr=5e-3, warmup_steps=2, total_steps=20)
tokens, labels = random_batch(0, 4, 32, cfg.vocab)
tokens, labels = jnp.asarray(tokens), jnp.asarray(labels)

mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
rules = make_rules(multi_pod=True)
ctx = ShardCtx(mesh=mesh, rules=rules, dp_axes=("pod", "data"),
               pod_axis="pod")
with sharding_ctx(ctx):
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0), compressed=True)
    step_c = jax.jit(build_train_step_compressed(model, ocfg))
    step_e = jax.jit(build_train_step(model, ocfg))
    se = state._replace(err=None)
    losses_c, losses_e = [], []
    sc = state
    for i in range(5):
        sc, mc = step_c(sc, tokens, labels)
        se, me = step_e(se, tokens, labels)
        losses_c.append(float(mc["loss"]))
        losses_e.append(float(me["loss"]))
print("compressed", losses_c)
print("exact     ", losses_e)
assert losses_c[-1] < losses_c[0]       # converging
assert abs(losses_c[-1] - losses_e[-1]) < 0.25
print("OK")
""", n_devices=8)
    assert "OK" in out


def test_elastic_remesh_checkpoint(devices_script):
    out = devices_script(COMMON + """
import tempfile
from repro.training.checkpoint import CheckpointManager
from repro.training.train import init_train_state

cfg = configs.get_smoke("gemma-2b")
mesh_a = make_test_mesh((2, 4), ("data", "model"))
ctx_a = ShardCtx(mesh=mesh_a, rules=make_rules())
with sharding_ctx(ctx_a):
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    specs = model.param_specs()
    sh = jax.tree.map(lambda s: NamedSharding(mesh_a, s), specs,
                      is_leaf=lambda s: isinstance(s, P))
    params = jax.tree.map(jax.device_put, state.params, sh)

with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(params, 1, blocking=True)
    # restore onto a DIFFERENT mesh shape (elastic re-mesh)
    mesh_b = make_test_mesh((4, 2), ("data", "model"))
    ctx_b = ShardCtx(mesh=mesh_b, rules=make_rules())
    with sharding_ctx(ctx_b):
        model_b = build_model(cfg)
        sh_b = jax.tree.map(lambda s: NamedSharding(mesh_b, s),
                            model_b.param_specs(),
                            is_leaf=lambda s: isinstance(s, P))
        restored, step = mgr.restore(params, sharding_tree=sh_b)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert b.sharding.mesh.shape["data"] == 4
print("OK")
""", n_devices=8)
    assert "OK" in out


def test_seq_sharded_decode_cache_matches(devices_script):
    """Flash-decode: cache sequence dim sharded over model axis."""
    out = devices_script(COMMON + """
cfg = get_f32("qwen3-14b")      # kv=2 heads < tp → seq-sharded
model = build_model(cfg)
params = model.init(jax.random.key(0))
B, S = 2, 32
toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
cache = model.init_cache(B, S + 4)
lg_ref, cache_ref = jax.jit(model.prefill)(params, toks, cache)
pos = jnp.full((B,), S, jnp.int32)
dec_ref, _ = jax.jit(model.decode_step)(params, toks[:, :1], cache_ref, pos)

mesh = make_test_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh=mesh, rules=make_rules())
with sharding_ctx(ctx):
    model_s = build_model(cfg)
    cspec = model_s.cache_specs(B, S + 4)
    # qwen smoke: kv_heads=2 does not divide model=4 → seq-sharded cache
    assert cspec["k"][2] is not None, cspec
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspec,
                       is_leaf=lambda s: isinstance(s, P))
    cache_s = jax.tree.map(jax.device_put, cache_ref, csh)
    dec_s, _ = jax.jit(model_s.decode_step)(params, toks[:, :1], cache_s,
                                            pos)
np.testing.assert_allclose(np.asarray(dec_ref, np.float32),
                           np.asarray(dec_s, np.float32),
                           rtol=1e-3, atol=1e-3)
print("OK")
""", n_devices=8)
    assert "OK" in out


def test_dryrun_single_cell_production_mesh(devices_script):
    """One real dry-run cell on the 16x16 production mesh (512 fake
    devices would be the multi-pod pass; single-pod = 256 suffices to
    prove the pipeline inside pytest — the full sweep is a deliverable
    run separately)."""
    out = devices_script("""
import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "")
from repro.launch.dryrun import run_cell
r = run_cell("olmo-1b", "decode_32k", multi_pod=False)
assert r.status == "ok", r.reason
assert r.peak_memory_bytes < 16 * 2**30
assert r.flops > 0
print("OK", r.flops, r.peak_memory_bytes)
""", n_devices=512, timeout=560)
    assert "OK" in out
