"""Unit + property tests for the policy registry, taxonomy and balancers.

``hypothesis`` is optional: when installed, the property tests fuzz the
policy contracts; without it, seeded random examples exercise the same
deterministic assertions (the checkers below are shared by both lanes).
The contract lanes run over EVERY registered balancer (built-ins plus
zoo extensions), and the cross-backend parity test pins ``np`` ≡ ``jax``
≡ ``pallas`` (interpret mode) selection task-by-task.
"""
import numpy as np
import pytest

# x64 keeps the jax-side uniform draws bit-identical to the numpy oracle
# (JSQ2 derives its two candidates from float64 truncation); the engines
# enable it process-wide anyway on first simulator import.
from repro.core import simulator as _simulator  # noqa: F401

from repro.core.policies import (hermes_score_np, make_select_worker_jax,
                                 select_worker_np)
from repro.core.taxonomy import (Binding, LoadBalance, PolicySpec,
                                 WorkerSched, parse_policy, HERMES,
                                 FIG2_POLICIES, ZOO_POLICIES)
from repro.policy import (balancer_names, default_backend, get_balancer,
                          jax_select, np_select, register_balancer,
                          resolve, sched_names, unregister_balancer)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def test_parse_roundtrip():
    for text in ("E/LL/PS", "E/LOC/FCFS", "E/R/PS", "E/H/PS",
                 "E/LL/SRPT", "E/JSQ2/PS", "E/RR/FCFS"):
        assert parse_policy(text).name == text
    assert parse_policy("L/*/*").binding == Binding.LATE
    assert HERMES.name == "E/H/PS"
    assert len(FIG2_POLICIES) == 7
    assert {p.name for p in ZOO_POLICIES} >= {"E/JSQ2/PS", "E/RR/PS"}
    # enum members and plain registry names are interchangeable
    assert PolicySpec("E", "LL", "PS") == PolicySpec(
        Binding.EARLY, LoadBalance.LEAST_LOADED, WorkerSched.PS)
    assert hash(PolicySpec("E", "LL", "PS")) == hash(parse_policy("E/LL/PS"))


def test_parse_policy_named_errors():
    with pytest.raises(ValueError, match="unknown load balancer 'XX'"):
        parse_policy("E/XX/PS")
    with pytest.raises(ValueError, match="registered balancers.*LL"):
        parse_policy("E/XX/PS")
    with pytest.raises(ValueError, match="unknown worker scheduler 'YY'"):
        parse_policy("E/LL/YY")
    with pytest.raises(ValueError, match="registered schedulers"):
        parse_policy("E/LL/YY")
    with pytest.raises(ValueError, match="unknown binding 'X'"):
        parse_policy("X/LL/PS")
    with pytest.raises(ValueError, match="T/LB/S"):
        parse_policy("E/LL")


def test_parse_policy_errors_list_their_own_registry():
    """Each axis's ValueError suggests alternatives from ITS registry
    only — an unknown sched must list schedulers, never balancers, and
    vice versa (regression guard against cross-wired suggestion text)."""
    import re

    def words(msg):
        return set(re.findall(r"[A-Z0-9]+", msg.split(";", 1)[1]))

    with pytest.raises(ValueError) as bad_sched:
        parse_policy("E/LL/NOPE")
    msg = str(bad_sched.value)
    assert "registered schedulers" in msg
    assert words(msg) == set(sched_names())
    with pytest.raises(ValueError) as bad_bal:
        parse_policy("E/NOPE/PS")
    msg = str(bad_bal.value)
    assert "registered balancers" in msg
    assert words(msg) == set(balancer_names())
    assert {"HIKU", "DD"} <= words(msg)
    with pytest.raises(ValueError) as bad_bind:
        parse_policy("Z/LL/PS")
    msg = str(bad_bind.value)
    assert "registered bindings" in msg
    assert words(msg) == {"E", "L"}
    # the fleet axes follow the same contract: each error lists exactly
    # its own registry's names
    from repro.fleet import (autoscaler_names, fleet_preset_names,
                             parse_autoscale, parse_fleet_preset)

    def tokens(msg, pat):
        return set(re.findall(pat, msg.split(":", 1)[1]))

    with pytest.raises(ValueError) as bad_preset:
        parse_fleet_preset("NOPE")
    msg = str(bad_preset.value)
    assert "unknown fleet preset" in msg
    assert tokens(msg, r"[a-z0-9-]+") == set(fleet_preset_names())
    assert {"uniform", "two-gen", "long-tail"} <= \
        tokens(msg, r"[a-z0-9-]+")
    with pytest.raises(ValueError) as bad_auto:
        parse_autoscale("NOPE")
    msg = str(bad_auto.value)
    assert "unknown autoscale policy" in msg
    assert "registered autoscale policies" in msg
    assert tokens(msg, r"[A-Z0-9_]+") == set(autoscaler_names())
    assert {"STATIC", "TARGET_P99"} <= tokens(msg, r"[A-Z0-9_]+")
    assert "registered fleet presets" in str(bad_preset.value)
    # the lifecycle axes too: keep-alive policies and cold-start presets
    from repro.lifecycle import keepalive_names, parse_keepalive
    from repro.lifecycle.coldstart import cold_preset_names, \
        parse_cold_preset
    with pytest.raises(ValueError) as bad_ka:
        parse_keepalive("NOPE")
    msg = str(bad_ka.value)
    assert "unknown keep-alive policy" in msg
    assert "registered keep-alive policies" in msg
    assert tokens(msg, r"[A-Z0-9_]+") == set(keepalive_names())
    assert {"NONE", "FIXED_TTL", "HYBRID_HIST"} <= \
        tokens(msg, r"[A-Z0-9_]+")
    with pytest.raises(ValueError) as bad_cold:
        parse_cold_preset("NOPE")
    msg = str(bad_cold.value)
    assert "unknown cold-start preset" in msg
    assert "registered cold-start presets" in msg
    assert tokens(msg, r"[a-z0-9-]+") == set(cold_preset_names())
    assert {"scalar", "paper-sim", "openwhisk"} <= \
        tokens(msg, r"[a-z0-9-]+")
    # vector-length errors name the offending value and the expected W
    from repro.core.cluster import ClusterCfg
    from repro.fleet.config import FleetCfg
    with pytest.raises(ValueError) as bad_len:
        ClusterCfg(n_workers=4)._replace(
            fleet=FleetCfg(speed=(1.0, 0.5))).validate()
    msg = str(bad_len.value)
    assert "n_workers=4" in msg and "(1.0, 0.5)" in msg


def test_registry_names():
    assert set(balancer_names()) >= {"LOC", "R", "LL", "H", "JSQ2", "RR",
                                     "HIKU", "DD"}
    assert set(sched_names()) == {"PS", "FCFS", "SRPT"}
    assert get_balancer("H").backends() == ("np", "jax", "pallas")
    assert get_balancer("JSQ2").backends() == ("np", "jax")
    # auto-backend: kernel-carrying balancers dispatch through pallas
    assert default_backend(HERMES) == "pallas"
    assert default_backend(parse_policy("E/LL/PS")) == "jax"
    # carried-state balancers declare init_state; stateless ones don't
    assert get_balancer("HIKU").stateful and get_balancer("DD").stateful
    assert not get_balancer("LL").stateful
    assert default_backend(parse_policy("E/HIKU/PS")) == "jax"


def test_stateless_shims_reject_stateful_balancers():
    from repro.core.policies import (make_select_worker_jax,
                                     select_worker_np)
    active = np.zeros(3, dtype=np.int64)
    warm = np.zeros((3, 2), dtype=np.int64)
    homes = np.zeros(2, dtype=np.int32)
    for name in ("HIKU", "DD"):
        with pytest.raises(ValueError, match="carries state"):
            select_worker_np(name, active, warm, 0, homes, 0.5, 2, 4)
        with pytest.raises(ValueError, match="carries state"):
            make_select_worker_jax(name, 2, 4)


# --------------------------------------------------------------------------
# Shared contract checkers (used by the hypothesis lane and the seeded lane)
# --------------------------------------------------------------------------

def _check_hermes_score(active, warm, cores, slots):
    score, low_load = hermes_score_np(active, warm, cores, slots)
    w = int(np.argmax(score))
    has_slot = active < slots
    if not has_slot.any():
        return                      # caller rejects in this case
    assert low_load == bool((active < cores).any())
    if low_load:
        # chosen worker must have a free core (paper: pack up to N cores)
        assert active[w] < cores
        # lexicographic: no worker with a free core has a higher class,
        # nor same class with more load
        warm_b = warm > 0
        cls = np.where(active > 0, 2 + warm_b, warm_b.astype(int))
        eligible = active < cores
        best = max((cls[i], active[i])
                   for i in range(len(active)) if eligible[i])
        assert (cls[w], active[w]) == best
    else:
        # least-loaded among free slots, warm tie-break
        key = np.where(has_slot, 2 * active - (warm > 0), 1 << 40)
        assert key[w] == key.min()
        assert has_slot[w]


def _check_select_np_valid(active, cores, slots, seed):
    rng = np.random.default_rng(seed)
    W = len(active)
    F = 4
    warm = rng.integers(0, 2, (W, F))
    func = int(rng.integers(0, F))
    homes = rng.integers(0, W, F).astype(np.int32)
    u = float(rng.uniform())
    idx = int(rng.integers(0, 1000))
    for bal in balancer_names():
        record = get_balancer(bal)
        if record.stateful:
            # stateful contract: a fresh state, and a rejected arrival
            # must hand the state back unchanged.  (Validity of the
            # chosen worker under an *arbitrary* active vector is not a
            # stateful invariant — e.g. HIKU's ring assumes engine-
            # consistent state — so only the range/rejection contract
            # is checked here; engine-consistency is covered by the
            # simulate ≡ simulate_ref golden tests.)
            sel, _ = record.make_np(cores, slots)
            state = record.init_state(W, F)
            w, state2 = sel(state, active, warm[:, func], func, homes, u,
                            idx)
            if (active < slots).any():
                assert 0 <= w < W, (bal, w, active)
            else:
                assert w == -1
                for k in state:
                    assert np.array_equal(state[k], state2[k]), (bal, k)
            continue
        w = select_worker_np(bal, active, warm, func, homes, u, cores,
                             slots, idx=idx)
        if (active < slots).any():
            assert 0 <= w < W and active[w] < slots, (bal, w, active)
        else:
            assert w == -1


def _check_jax_matches_np(active, cores, slots, seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    active = active.astype(np.int32)
    W = len(active)
    F = 4
    warm = rng.integers(0, 2, (W, F)).astype(np.int32)
    func = int(rng.integers(0, F))
    homes = rng.integers(0, W, F).astype(np.int32)
    u = float(rng.uniform())
    idx = int(rng.integers(0, 1000))
    for bal in balancer_names():
        record = get_balancer(bal)
        args_j = (jnp.asarray(active), jnp.asarray(warm[:, func]),
                  jnp.int32(func), jnp.asarray(homes), jnp.float64(u),
                  jnp.int32(idx))
        if record.stateful:
            sel_np, _ = record.make_np(cores, slots)
            sel_jx, _ = record.make_jax(cores, slots)
            s_np = record.init_state(W, F)
            s_jx = {k: jnp.asarray(v)
                    for k, v in record.init_state(W, F).items()}
            w_np, s_np = sel_np(s_np, active, warm[:, func], func, homes,
                                u, idx)
            w_j, s_jx = sel_jx(s_jx, *args_j)
            assert w_np == int(w_j), (bal, active.tolist())
            for k in s_np:
                np.testing.assert_array_equal(
                    np.asarray(s_np[k]), np.asarray(s_jx[k]),
                    err_msg=f"{bal} state[{k}]")
            continue
        w_np = select_worker_np(bal, active, warm, func, homes, u, cores,
                                slots, idx=idx)
        sel = jax_select(bal, cores, slots)
        w_j = int(sel(*args_j))
        assert w_np == w_j, (bal, active.tolist(), warm[:, func])


def _random_state(seed):
    """Seeded analogue of the hypothesis ``state`` strategy below."""
    rng = np.random.default_rng(seed)
    W = int(rng.integers(2, 17))
    cores = int(rng.integers(1, 17))
    capf = int(rng.integers(1, 13))
    slots = cores * capf
    active = np.minimum(rng.integers(0, 101, W).astype(np.int64), slots)
    warm = rng.integers(0, 4, W).astype(np.int64)
    return active, warm, cores, slots


# --------------------------------------------------------------------------
# Seeded lane — always runs, hypothesis not required
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(50))
def test_hermes_score_properties_seeded(seed):
    active, warm, cores, slots = _random_state(seed)
    _check_hermes_score(active, warm, cores, slots)
    # also cover the all-full high-load corner deterministically
    _check_hermes_score(np.full_like(active, slots), warm, cores, slots)


@pytest.mark.parametrize("seed", range(30))
def test_select_worker_np_always_valid_seeded(seed):
    active, _, cores, slots = _random_state(seed)
    _check_select_np_valid(active, cores, slots, seed + 1000)
    # cluster-full corner: every balance policy must reject (-1)
    _check_select_np_valid(np.full_like(active, slots), cores, slots,
                           seed + 1000)


@pytest.mark.parametrize("seed", range(15))
def test_select_worker_jax_matches_np_seeded(seed):
    active, _, cores, slots = _random_state(seed)
    _check_jax_matches_np(active, cores, slots, seed + 2000)


# --------------------------------------------------------------------------
# Cross-backend parity: np ≡ jax ≡ pallas(interpret), task by task, for
# every registered balancer over randomized (active, warm) states
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", balancer_names())
@pytest.mark.parametrize("seed", range(6))
def test_backend_parity_task_by_task(name, seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(10_000 + seed)
    W = int(rng.integers(2, 17))
    cores = int(rng.integers(1, 9))
    slots = cores * int(rng.integers(1, 9))
    F = 5
    homes = rng.integers(0, W, F).astype(np.int32)
    bal = get_balancer(name)
    if bal.stateful:
        return _check_stateful_backend_parity(bal, rng, W, cores, slots,
                                              F, homes)
    sel_np = np_select(name, cores, slots)
    sel_jax = jax_select(name, cores, slots)
    sel_pl = bal.make_pallas(cores, slots) if bal.make_pallas else None
    for t in range(12):
        # include slot-full workers (and, via the last round, a full
        # cluster) so the -1 contract is exercised on every backend
        hi = slots if t < 11 else 0
        active = (np.full(W, slots) if t == 11
                  else rng.integers(0, hi + 1, W)).astype(np.int64)
        warm_col = rng.integers(0, 3, W).astype(np.int64)
        func = int(rng.integers(0, F))
        u = float(rng.uniform())
        idx = int(rng.integers(0, 1000))
        w_np = sel_np(active, warm_col, func, homes, u, idx)
        args_j = (jnp.asarray(active.astype(np.int32)),
                  jnp.asarray(warm_col.astype(np.int32)),
                  jnp.int32(func), jnp.asarray(homes), jnp.float64(u),
                  jnp.int32(idx))
        w_j = int(sel_jax(*args_j))
        assert w_np == w_j, (name, "jax", active.tolist(), warm_col)
        if sel_pl is not None:
            w_p = int(sel_pl(*args_j))
            assert w_np == w_p, (name, "pallas", active.tolist(), warm_col)


def _check_stateful_backend_parity(bal, rng, W, cores, slots, F, homes):
    """Thread np and jax state through an interleaved select /
    on_complete stream and demand bitwise-equal states every step —
    the carried-state analogue of the task-by-task parity contract
    (EMA float updates included, so FMA-style backend drift is caught).
    """
    import jax.numpy as jnp
    sel_np, oc_np = bal.make_np(cores, slots)
    sel_jx, oc_jx = bal.make_jax(cores, slots)
    s_np = bal.init_state(W, F)
    s_jx = {k: jnp.asarray(v) for k, v in bal.init_state(W, F).items()}
    for t in range(24):
        if rng.uniform() < 0.55:
            full = t % 11 == 10
            active = (np.full(W, slots) if full
                      else rng.integers(0, slots + 1, W)).astype(np.int64)
            warm_col = rng.integers(0, 3, W).astype(np.int64)
            func = int(rng.integers(0, F))
            u = float(rng.uniform())
            w_np, s_np = sel_np(s_np, active, warm_col, func, homes, u, t)
            w_jx, s_jx = sel_jx(
                s_jx, jnp.asarray(active.astype(np.int32)),
                jnp.asarray(warm_col.astype(np.int32)), jnp.int32(func),
                jnp.asarray(homes), jnp.float64(u), jnp.int32(t))
            assert w_np == int(w_jx), (bal.name, t, active.tolist())
            if full:
                assert w_np == -1
        else:
            w = int(rng.integers(0, W))
            func = int(rng.integers(0, F))
            svc = float(rng.lognormal(0.0, 1.0))
            n_after = int(rng.integers(0, 3))
            s_np = oc_np(s_np, w, func, svc, n_after)
            s_jx = oc_jx(s_jx, jnp.int32(w), jnp.int32(func),
                         jnp.float64(svc), jnp.int32(n_after))
        assert set(s_np) == set(s_jx)
        for k in s_np:
            np.testing.assert_array_equal(
                np.asarray(s_np[k]), np.asarray(s_jx[k]),
                err_msg=f"{bal.name} step {t} state[{k}]")


# --------------------------------------------------------------------------
# Registry regression: the registry-resolved engines reproduce the
# pre-registry engines bit-for-bit (golden values recorded from the
# enum-dispatch implementation at the commit introducing repro.policy)
# --------------------------------------------------------------------------

_GOLDEN_CLUSTER = dict(n_workers=4, cores=3, capacity_factor=2)
# policy name -> (nansum(response), n_cold, n_rejected, server_time,
#                 core_time) for synth_workload(load=0.9, n=250,
#                 n_functions=5, hot_fraction=0.8, seed=0)
GOLDEN_SIM = {
    "L/LL/FCFS": (2234.855441484522, 31, 0, 1724.5313381516,
                  2228.6312810489976),
    "E/LL/FCFS": (2257.1284711882117, 35, 0, 1724.5313381515998,
                  2228.631281048997),
    "E/LL/PS": (2236.4790573536984, 33, 0, 1726.3205356448295,
                2228.6312810489976),
    "E/LOC/FCFS": (2881.1012849325516, 42, 0, 1211.390510887456,
                   2228.6312810489976),
    "E/LOC/PS": (2864.6831589262856, 36, 0, 1364.284602142182,
                 2228.6312810489985),
    "E/R/FCFS": (2513.290535167693, 63, 0, 1341.53576011165,
                 2228.6312810489985),
    "E/R/PS": (2317.8045230972084, 60, 0, 1351.7626209397845,
               2228.6312810489985),
    "E/H/PS": (2233.1967927570226, 37, 0, 1447.405502466479,
               2228.631281048997),
    "E/LL/SRPT": (2230.670600599903, 32, 0, 1725.1277972202631,
                  2228.631281048997),
}
GOLDEN_REF = {
    "L/LL/FCFS": (2234.855441484522, 31, 0, 1724.5313381516,
                  2228.631281048997),
    "E/LL/FCFS": (2257.1284711882117, 35, 0, 1724.5313381515998,
                  2228.6312810489967),
    "E/LL/PS": (2236.4790573536984, 33, 0, 1726.3205356448295,
                2228.631281048997),
    "E/LOC/FCFS": (2881.1012849325516, 42, 0, 1211.390510887456,
                   2228.631281048998),
    "E/LOC/PS": (2864.6831589262856, 36, 0, 1364.284602142182,
                 2228.6312810489985),
    "E/R/FCFS": (2513.290535167693, 63, 0, 1341.53576011165,
                 2228.6312810489985),
    "E/R/PS": (2317.8045230972084, 60, 0, 1351.7626209397845,
               2228.6312810489985),
    "E/H/PS": (2233.1967927570226, 37, 0, 1447.405502466479,
               2228.6312810489967),
    "E/LL/SRPT": (2230.670600599903, 32, 0, 1725.1277972202631,
                  2228.6312810489967),
}


def _golden_workload():
    from repro.core import ClusterCfg, synth_workload
    cl = ClusterCfg(**_GOLDEN_CLUSTER)
    return cl, synth_workload(cl, 0.9, 250, n_functions=5,
                              hot_fraction=0.8, seed=0)


@pytest.mark.parametrize("pname", sorted(GOLDEN_SIM))
def test_golden_metrics_simulate(pname):
    from repro.core.simulator import simulate, simulate_many
    cl, wl = _golden_workload()
    pol = parse_policy(pname)
    out = simulate(pol, cl, wl)
    exp = GOLDEN_SIM[pname]
    np.testing.assert_allclose(np.nansum(out.response), exp[0], rtol=1e-12)
    assert int(out.cold.sum()) == exp[1]
    assert int(out.rejected.sum()) == exp[2]
    np.testing.assert_allclose(out.server_time, exp[3], rtol=1e-12)
    np.testing.assert_allclose(out.core_time, exp[4], rtol=1e-12)
    # batched engine: same numbers through simulate_many (for HERMES this
    # exercises the Pallas-kernel selection backend)
    batch = simulate_many(pol, cl, [wl])
    np.testing.assert_array_equal(
        np.nan_to_num(batch.response[0], nan=-1.0),
        np.nan_to_num(out.response, nan=-1.0))


@pytest.mark.parametrize("pname", sorted(GOLDEN_REF))
def test_golden_metrics_simulate_ref(pname):
    from repro.core.sim_ref import simulate_ref
    cl, wl = _golden_workload()
    out = simulate_ref(parse_policy(pname), cl, wl)
    exp = GOLDEN_REF[pname]
    np.testing.assert_allclose(np.nansum(out.response), exp[0], rtol=1e-12)
    assert int(out.cold.sum()) == exp[1]
    assert int(out.rejected.sum()) == exp[2]
    np.testing.assert_allclose(out.server_time, exp[3], rtol=1e-12)
    np.testing.assert_allclose(out.core_time, exp[4], rtol=1e-12)


# --------------------------------------------------------------------------
# Registry extensibility + kernel dispatch
# --------------------------------------------------------------------------

def test_register_custom_balancer_end_to_end():
    """A balancer registered in <20 lines sweeps through both engines."""
    from repro.core import ClusterCfg, synth_workload
    from repro.core.sim_ref import simulate_ref
    from repro.core.simulator import simulate

    def make_np(cores, slots):
        def select(active, warm_col, func, func_home, u, idx):
            free = np.nonzero(active < slots)[0]
            return int(free[0]) if len(free) else -1
        return select

    def make_jax(cores, slots):
        import jax.numpy as jnp

        def select(active, warm_col, func, func_home, u, idx):
            has_slot = active < slots
            w = jnp.argmax(has_slot).astype(jnp.int32)
            return jnp.where(has_slot.any(), w, -1).astype(jnp.int32)
        return select

    register_balancer("FF", make_np=make_np, make_jax=make_jax,
                      doc="first free worker")
    try:
        pol = parse_policy("E/FF/PS")
        assert pol.name == "E/FF/PS"
        cl = ClusterCfg(n_workers=3, cores=2, capacity_factor=2)
        wl = synth_workload(cl, 0.7, 150, n_functions=4, seed=1)
        out = simulate(pol, cl, wl)
        ref = simulate_ref(pol, cl, wl)
        np.testing.assert_allclose(
            np.nan_to_num(out.response, nan=-1.0),
            np.nan_to_num(ref.response, nan=-1.0), atol=1e-6)
        np.testing.assert_array_equal(out.worker, ref.worker)
        with pytest.raises(ValueError, match="already registered"):
            register_balancer("FF", make_np=make_np)

        # overwriting a registration must invalidate compiled engines
        # (they capture the resolved select closure by name)
        def make_np2(cores, slots):
            def select(active, warm_col, func, func_home, u, idx):
                free = np.nonzero(active < slots)[0]
                return int(free[-1]) if len(free) else -1
            return select

        def make_jax2(cores, slots):
            import jax.numpy as jnp

            def select(active, warm_col, func, func_home, u, idx):
                has_slot = active < slots
                W = active.shape[0]
                w = (W - 1 - jnp.argmax(has_slot[::-1])).astype(jnp.int32)
                return jnp.where(has_slot.any(), w, -1).astype(jnp.int32)
            return select

        register_balancer("FF", make_np=make_np2, make_jax=make_jax2,
                          overwrite=True, doc="last free worker")
        out2 = simulate(pol, cl, wl)
        ref2 = simulate_ref(pol, cl, wl)
        np.testing.assert_array_equal(out2.worker, ref2.worker)
        assert not np.array_equal(out2.worker, out.worker)
    finally:
        unregister_balancer("FF")
    with pytest.raises(ValueError, match="unknown load balancer 'FF'"):
        parse_policy("E/FF/PS")


def test_simulate_many_hermes_routes_through_pallas_kernel(monkeypatch):
    """The batched engine's Hermes selection dispatches through
    ``repro.kernels.hermes_select`` (the ROADMAP kernel-batch-path item)."""
    import repro.kernels.hermes_select.kernel as hk
    from repro.core import ClusterCfg, synth_workload
    from repro.core import simulator as sim
    from repro.policy.registry import _factory_cache_clear

    calls = []
    orig = hk.hermes_select_batch

    def spy(*args, **kwargs):
        calls.append(kwargs.get("interpret"))
        return orig(*args, **kwargs)

    monkeypatch.setattr(hk, "hermes_select_batch", spy)
    _factory_cache_clear()
    sim.clear_engine_cache()
    try:
        cl = ClusterCfg(n_workers=3, cores=2, capacity_factor=2)
        wl = synth_workload(cl, 0.6, 40, n_functions=3, seed=0)
        out = sim.simulate_many(HERMES, cl, [wl, wl])
        assert calls, "Hermes selection did not reach the Pallas kernel"
        np.testing.assert_array_equal(out.response[0], out.response[1])
        # the jax backend stays available and agrees
        out_jax = sim.simulate_many(HERMES, cl, [wl, wl], backend="jax")
        np.testing.assert_array_equal(out.response, out_jax.response)
    finally:
        # drop closures that captured the spy
        _factory_cache_clear()
        sim.clear_engine_cache()


def test_make_select_worker_jax_compat_signature():
    """Pre-registry 5-argument closure API keeps working (enum or name)."""
    import jax.numpy as jnp
    sel = make_select_worker_jax(LoadBalance.HYBRID, 2, 4)
    active = jnp.asarray(np.array([1, 3, 0], np.int32))
    warm = jnp.asarray(np.array([0, 1, 0], np.int32))
    homes = jnp.asarray(np.zeros(2, np.int32))
    w = int(sel(active, warm, jnp.int32(0), homes, jnp.float64(0.3)))
    assert w == np.argmax(hermes_score_np(
        np.array([1, 3, 0]), np.array([0, 1, 0]), 2, 4)[0])


# --------------------------------------------------------------------------
# Property lane — fuzzing on top of the seeded lane when hypothesis exists
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    state = st.integers(min_value=2, max_value=16).flatmap(
        lambda w: st.tuples(
            st.lists(st.integers(0, 100), min_size=w, max_size=w),
            st.lists(st.integers(0, 3), min_size=w, max_size=w),
            st.integers(1, 16),                 # cores
            st.integers(1, 12),                 # capacity factor
        ))

    @settings(max_examples=200, deadline=None)
    @given(state)
    def test_hermes_score_properties(sw):
        active_l, warm_l, cores, capf = sw
        slots = cores * capf
        active = np.minimum(np.array(active_l, np.int64), slots)
        warm = np.array(warm_l, np.int64)
        _check_hermes_score(active, warm, cores, slots)

    @settings(max_examples=100, deadline=None)
    @given(state, st.integers(0, 1 << 30))
    def test_select_worker_np_always_valid(sw, seed):
        active_l, _, cores, capf = sw
        slots = cores * capf
        active = np.minimum(np.array(active_l, np.int64), slots)
        _check_select_np_valid(active, cores, slots, seed)

    @settings(max_examples=50, deadline=None)
    @given(state, st.integers(0, 1 << 30))
    def test_select_worker_jax_matches_np(sw, seed):
        active_l, _, cores, capf = sw
        slots = cores * capf
        active = np.minimum(np.array(active_l, np.int64), slots)
        _check_jax_matches_np(active, cores, slots, seed)
