"""Unit + property tests for the scheduling taxonomy and policies.

``hypothesis`` is optional: when installed, the property tests fuzz the
policy contracts; without it, seeded random examples exercise the same
deterministic assertions (the checkers below are shared by both lanes).
"""
import numpy as np
import pytest

from repro.core.policies import (hermes_score_np, make_select_worker_jax,
                                 select_worker_np)
from repro.core.taxonomy import (Binding, LoadBalance, PolicySpec,
                                 WorkerSched, parse_policy, HERMES,
                                 FIG2_POLICIES)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def test_parse_roundtrip():
    for text in ("E/LL/PS", "E/LOC/FCFS", "E/R/PS", "E/H/PS",
                 "E/LL/SRPT"):
        assert parse_policy(text).name == text
    assert parse_policy("L/*/*").binding == Binding.LATE
    assert HERMES.name == "E/H/PS"
    assert len(FIG2_POLICIES) == 7


# --------------------------------------------------------------------------
# Shared contract checkers (used by the hypothesis lane and the seeded lane)
# --------------------------------------------------------------------------

def _check_hermes_score(active, warm, cores, slots):
    score, low_load = hermes_score_np(active, warm, cores, slots)
    w = int(np.argmax(score))
    has_slot = active < slots
    if not has_slot.any():
        return                      # caller rejects in this case
    assert low_load == bool((active < cores).any())
    if low_load:
        # chosen worker must have a free core (paper: pack up to N cores)
        assert active[w] < cores
        # lexicographic: no worker with a free core has a higher class,
        # nor same class with more load
        warm_b = warm > 0
        cls = np.where(active > 0, 2 + warm_b, warm_b.astype(int))
        eligible = active < cores
        best = max((cls[i], active[i])
                   for i in range(len(active)) if eligible[i])
        assert (cls[w], active[w]) == best
    else:
        # least-loaded among free slots, warm tie-break
        key = np.where(has_slot, 2 * active - (warm > 0), 1 << 40)
        assert key[w] == key.min()
        assert has_slot[w]


def _check_select_np_valid(active, cores, slots, seed):
    rng = np.random.default_rng(seed)
    W = len(active)
    F = 4
    warm = rng.integers(0, 2, (W, F))
    func = int(rng.integers(0, F))
    homes = rng.integers(0, W, F).astype(np.int32)
    u = float(rng.uniform())
    for bal in LoadBalance:
        w = select_worker_np(bal, active, warm, func, homes, u, cores,
                             slots)
        if (active < slots).any():
            assert 0 <= w < W and active[w] < slots, (bal, w, active)
        else:
            assert w == -1


def _check_jax_matches_np(active, cores, slots, seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    active = active.astype(np.int32)
    W = len(active)
    F = 4
    warm = rng.integers(0, 2, (W, F)).astype(np.int32)
    func = int(rng.integers(0, F))
    homes = rng.integers(0, W, F).astype(np.int32)
    u = float(rng.uniform())
    for bal in LoadBalance:
        w_np = select_worker_np(bal, active, warm, func, homes, u, cores,
                                slots)
        sel = make_select_worker_jax(bal, cores, slots)
        w_j = int(sel(jnp.asarray(active), jnp.asarray(warm[:, func]),
                      jnp.int32(func), jnp.asarray(homes), jnp.float64(u)))
        assert w_np == w_j, (bal.name, active.tolist(), warm[:, func])


def _random_state(seed):
    """Seeded analogue of the hypothesis ``state`` strategy below."""
    rng = np.random.default_rng(seed)
    W = int(rng.integers(2, 17))
    cores = int(rng.integers(1, 17))
    capf = int(rng.integers(1, 13))
    slots = cores * capf
    active = np.minimum(rng.integers(0, 101, W).astype(np.int64), slots)
    warm = rng.integers(0, 4, W).astype(np.int64)
    return active, warm, cores, slots


# --------------------------------------------------------------------------
# Seeded lane — always runs, hypothesis not required
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(50))
def test_hermes_score_properties_seeded(seed):
    active, warm, cores, slots = _random_state(seed)
    _check_hermes_score(active, warm, cores, slots)
    # also cover the all-full high-load corner deterministically
    _check_hermes_score(np.full_like(active, slots), warm, cores, slots)


@pytest.mark.parametrize("seed", range(30))
def test_select_worker_np_always_valid_seeded(seed):
    active, _, cores, slots = _random_state(seed)
    _check_select_np_valid(active, cores, slots, seed + 1000)
    # cluster-full corner: every balance policy must reject (-1)
    _check_select_np_valid(np.full_like(active, slots), cores, slots,
                           seed + 1000)


@pytest.mark.parametrize("seed", range(15))
def test_select_worker_jax_matches_np_seeded(seed):
    active, _, cores, slots = _random_state(seed)
    _check_jax_matches_np(active, cores, slots, seed + 2000)


# --------------------------------------------------------------------------
# Property lane — fuzzing on top of the seeded lane when hypothesis exists
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    state = st.integers(min_value=2, max_value=16).flatmap(
        lambda w: st.tuples(
            st.lists(st.integers(0, 100), min_size=w, max_size=w),
            st.lists(st.integers(0, 3), min_size=w, max_size=w),
            st.integers(1, 16),                 # cores
            st.integers(1, 12),                 # capacity factor
        ))

    @settings(max_examples=200, deadline=None)
    @given(state)
    def test_hermes_score_properties(sw):
        active_l, warm_l, cores, capf = sw
        slots = cores * capf
        active = np.minimum(np.array(active_l, np.int64), slots)
        warm = np.array(warm_l, np.int64)
        _check_hermes_score(active, warm, cores, slots)

    @settings(max_examples=100, deadline=None)
    @given(state, st.integers(0, 1 << 30))
    def test_select_worker_np_always_valid(sw, seed):
        active_l, _, cores, capf = sw
        slots = cores * capf
        active = np.minimum(np.array(active_l, np.int64), slots)
        _check_select_np_valid(active, cores, slots, seed)

    @settings(max_examples=50, deadline=None)
    @given(state, st.integers(0, 1 << 30))
    def test_select_worker_jax_matches_np(sw, seed):
        active_l, _, cores, capf = sw
        slots = cores * capf
        active = np.minimum(np.array(active_l, np.int64), slots)
        _check_jax_matches_np(active, cores, slots, seed)
