"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

K0 = jax.random.key(42)


def _tols(dtype):
    return {"rtol": 2e-2, "atol": 2e-2} if dtype == jnp.bfloat16 else \
        {"rtol": 2e-3, "atol": 2e-3}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("shape", [
    (2, 256, 4, 2, 64),    # GQA
    (1, 128, 8, 8, 128),   # MHA
    (2, 256, 4, 1, 128),   # MQA
    (1, 192, 6, 2, 32),    # uneven blocks (192 % 128 != 0)
])
def test_flash_attention(shape, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    B, S, H, KV, Dh = shape
    ks = jax.random.split(jax.random.fold_in(K0, hash(shape) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, Dh), dtype)
    out = flash_attention(q, k, v, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tols(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("shape", [(2, 512, 4, 2, 64), (3, 256, 8, 1, 128)])
def test_decode_attention(shape, dtype):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    B, S, H, KV, Dh = shape
    ks = jax.random.split(jax.random.fold_in(K0, S + H), 3)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, Dh), dtype)
    pos = jnp.asarray(
        np.random.default_rng(0).integers(1, S, B), jnp.int32)
    out = decode_attention(q, k, v, pos, bk=128)
    ref = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tols(dtype))


@pytest.mark.parametrize("chunk", [16, 32])
@pytest.mark.parametrize("shape", [(2, 128, 3, 64), (1, 64, 2, 64)])
def test_wkv6(shape, chunk):
    from repro.kernels.rwkv6_wkv.ops import wkv6
    from repro.kernels.rwkv6_wkv.ref import wkv6_ref
    B, T, H, K = shape
    ks = jax.random.split(jax.random.fold_in(K0, T * H), 5)
    r = jax.random.normal(ks[0], (B, T, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, K)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)))
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    y, s = wkv6(r, k, v, lw, u, chunk=chunk)
    yr, sr = wkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(y, yr, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(s, sr, rtol=2e-3, atol=2e-3)


def test_wkv6_matches_model_path():
    """Kernel vs the model's chunked-scan implementation."""
    from repro.kernels.rwkv6_wkv.ops import wkv6
    from repro.models.rwkv6 import wkv_chunked
    B, T, H, K = 1, 96, 2, 64
    ks = jax.random.split(K0, 5)
    r = jax.random.normal(ks[0], (B, T, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, K)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)))
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    y1, s1 = wkv6(r, k, v, lw, u, chunk=32)
    y2, s2 = wkv_chunked(r, k, v, lw, u,
                         jnp.zeros((B, H, K, K), jnp.float32), chunk=32)
    np.testing.assert_allclose(y1, y2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(s1, s2, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("chunk", [32, 64])
@pytest.mark.parametrize("shape", [(2, 128, 4, 32, 16), (1, 64, 2, 16, 8)])
def test_ssd(shape, chunk):
    from repro.kernels.mamba2_ssd.ops import ssd
    from repro.kernels.mamba2_ssd.ref import ssd_ref
    B, T, H, P, N = shape
    ks = jax.random.split(jax.random.fold_in(K0, T * P), 4)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    bm = jax.random.normal(ks[2], (B, T, N)) * 0.5
    cm = jax.random.normal(ks[3], (B, T, N)) * 0.5
    a = -jnp.exp(jnp.linspace(-1, 1, H))
    y, h = ssd(x, dt, bm, cm, a, chunk=chunk)
    yr, hr = ssd_ref(x, dt, bm, cm, a)
    np.testing.assert_allclose(y, yr, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(h, hr, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("seed", range(3))
def test_hermes_select(seed):
    from repro.kernels.hermes_select.ops import hermes_select
    from repro.kernels.hermes_select.ref import hermes_select_ref
    rng = np.random.default_rng(seed)
    W, F, N, cores = int(rng.integers(2, 16)), 6, 96, int(rng.integers(2, 8))
    slots = cores * 8
    active = jnp.asarray(rng.integers(0, slots, W), jnp.int32)
    warm = jnp.asarray(rng.integers(0, 3, (W, F)), jnp.int32)
    funcs = jnp.asarray(rng.integers(0, F, N), jnp.int32)
    out, act = hermes_select(active, warm, funcs, cores=cores, slots=slots)
    ro, ra = hermes_select_ref(np.asarray(active),
                               np.asarray(warm.T[funcs]),
                               cores=cores, slots=slots)
    np.testing.assert_array_equal(np.asarray(out), ro)
    np.testing.assert_array_equal(np.asarray(act), ra)
