"""Simulator contract tests: JAX engine ≡ numpy oracle + invariants."""
import numpy as np
import pytest

from repro.core import (ClusterCfg, FIG2_POLICIES, HERMES, E_LL_SRPT,
                        synth_workload, summarize_sim)
from repro.core.sim_ref import simulate_ref
from repro.core.simulator import simulate

POLICIES = list(FIG2_POLICIES) + [HERMES, E_LL_SRPT]
CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)


def _wl(load, n=250, seed=0, **kw):
    return synth_workload(CLUSTER, load, n, n_functions=5,
                          hot_fraction=0.8, seed=seed, **kw)


@pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.name)
@pytest.mark.parametrize("load", [0.4, 0.9, 1.3])
def test_jax_matches_oracle(policy, load):
    wl = _wl(load)
    ref = simulate_ref(policy, CLUSTER, wl)
    out = simulate(policy, CLUSTER, wl)
    np.testing.assert_allclose(
        np.nan_to_num(out.response, nan=-1.0),
        np.nan_to_num(ref.response, nan=-1.0), rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(out.cold, ref.cold)
    np.testing.assert_array_equal(out.rejected, ref.rejected)
    assert abs(out.server_time - ref.server_time) < 1e-3 * max(
        1.0, ref.server_time)
    assert abs(out.core_time - ref.core_time) < 1e-3 * max(
        1.0, ref.core_time)


@pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.name)
def test_invariants(policy):
    wl = _wl(0.8, n=400, seed=3)
    out = simulate(policy, CLUSTER, wl)
    done = ~out.rejected
    # every accepted invocation completes after the drain
    assert np.isfinite(out.response[done]).all()
    # response ≥ service (can't finish faster than its work)
    assert (out.response[done] >= wl.service[done] - 1e-6).all()
    # work conservation: total core-time == total service of accepted
    assert abs(out.core_time - wl.service[done].sum()) < 1e-3 * \
        wl.service[done].sum()
    # rejected only when genuinely full is possible
    if out.rejected.any():
        assert CLUSTER.slots * CLUSTER.n_workers <= 400
    s = summarize_sim(out, wl)
    assert s.slow_p50 >= 1.0 - 1e-9
    assert s.slow_p99 >= s.slow_p50


def test_seeds_differ():
    a = _wl(0.5, seed=0)
    b = _wl(0.5, seed=1)
    assert not np.allclose(a.service, b.service)


def test_service_cap():
    wl = _wl(0.5, n=4000, max_service=100.0)
    assert wl.service.max() <= 100.0


def test_cold_start_penalty_increases_response():
    wl = _wl(0.5, n=300)
    cold_cluster = CLUSTER._replace(cold_start_penalty=0.7)
    base = simulate_ref(HERMES, CLUSTER, wl)
    pen = simulate_ref(HERMES, cold_cluster, wl)
    assert np.nansum(pen.response) > np.nansum(base.response)


def test_warm_reuse_reduces_cold_starts():
    """A single-function workload should cold-start ~once per worker."""
    wl = synth_workload(CLUSTER, 0.5, 300, n_functions=1,
                        hot_fraction=1.0, seed=2)
    out = simulate_ref(HERMES, CLUSTER, wl)
    # far fewer cold starts than invocations
    assert out.cold.sum() < 0.2 * wl.n


@pytest.mark.parametrize("policy", [HERMES, POLICIES[0], POLICIES[2],
                                    POLICIES[4], POLICIES[6]],
                         ids=lambda p: p.name)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_eviction_agreement_under_full_warm_pools(policy, seed):
    """Randomized lock on the slot-pressure eviction tie-breaking
    contract: with tiny slot counts (capacity_factor=1), overload and
    many functions, warm pools sit at capacity and the ``need_evict``
    drain fires constantly — the JAX engine's victim choice (legacy:
    argmax warm count; lifecycle: LRU idle-since, ties to the lowest
    function id) must match the numpy oracle invocation-by-invocation,
    with and without a lifecycle configured."""
    from repro.core import LifecycleCfg
    base = ClusterCfg(n_workers=3, cores=2, capacity_factor=1,
                      cold_start_penalty=0.3)
    wl = synth_workload(base, 1.1, 250, n_functions=8,
                        hot_fraction=0.4, seed=seed)
    for lc in (None, LifecycleCfg(ttl_s=4.0, max_idle=1)):
        cl = base._replace(lifecycle=lc)
        out = simulate(policy, cl, wl)
        ref = simulate_ref(policy, cl, wl)
        np.testing.assert_array_equal(out.cold, ref.cold)
        np.testing.assert_array_equal(out.worker, ref.worker)
        np.testing.assert_array_equal(out.rejected, ref.rejected)
        np.testing.assert_allclose(
            np.nan_to_num(out.response, nan=-1.0),
            np.nan_to_num(ref.response, nan=-1.0), atol=1e-6)
