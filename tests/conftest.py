"""Shared fixtures.  NOTE: no global device-count override here — smoke
tests and benches must see 1 device; sharded tests spawn subprocesses
with their own ``--xla_force_host_platform_device_count`` (see
test_distributed.py)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_devices_script(body: str, n_devices: int = 8,
                       timeout: int = 560) -> str:
    """Run a Python snippet in a fresh process with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def devices_script():
    return run_devices_script
