"""repro.analysis: lint rules, jaxpr audit, contracts, budgets, CLI."""
import os

import pytest

from repro.analysis import (BASELINES, RULES, audit_cache_key, audit_engines,
                            audit_fn, check_contracts, lint_file, lint_paths)
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.budgets import (DEFAULT_BUDGET, budget_for,
                                    check_budgets)
from repro.analysis.jaxpr_audit import JaxprStats, iter_engine_specs

from conftest import REPO

FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")


def fixture(name: str) -> str:
    return os.path.join(FIXTURES, name)


# --------------------------------------------------------------------------
# AST lint: one fixture per rule, each fires exactly once
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fname,rule", [
    ("det001_case.py", "DET001"),
    ("det002_case.py", "DET002"),
    ("hot001_case.py", "HOT001"),
    ("hot002_case.py", "HOT002"),
    ("hot003_case.py", "HOT003"),
    ("par001_case.py", "PAR001"),
    ("par002_case.py", "PAR002"),
])
def test_fixture_fires_exactly_once(fname, rule):
    findings = lint_file(fixture(fname))
    assert [f.rule for f in findings] == [rule], findings


def test_unparseable_file_reports_lnt000():
    text = open(fixture("lnt000_case.py.txt")).read()
    findings = lint_file("lnt000_case.py", text=text)
    assert [f.rule for f in findings] == ["LNT000"]


def test_disable_comments_silence_findings():
    assert lint_file(fixture("disabled_case.py")) == []


def test_file_wide_disable():
    text = ("# repro-lint: disable-file=DET001\n"
            "import numpy as np\n"
            "x = np.random.rand(3)\n"
            "y = np.random.randn(3)\n")
    assert lint_file("mod.py", text=text) == []


def test_unknown_rule_id_in_disable_is_ignored():
    text = ("import numpy as np\n"
            "x = np.random.rand(3)  # repro-lint: disable=NOPE123\n")
    findings = lint_file("mod.py", text=text)
    assert [f.rule for f in findings] == ["DET001"]


def test_static_argnames_not_traced():
    text = ("from functools import partial\n"
            "import jax\n"
            "@partial(jax.jit, static_argnames=('flag',))\n"
            "def f(x, *, flag):\n"
            "    assert flag\n"
            "    return x\n")
    assert lint_file("mod.py", text=text) == []


def test_findings_carry_hints_and_format():
    (f,) = lint_file(fixture("det001_case.py"))
    assert f.hint == RULES["DET001"].hint
    assert "det001_case.py" in f.format() and "DET001" in f.format()


def test_clean_pass_golden_over_tree():
    findings = lint_paths([os.path.join(REPO, "src"),
                           os.path.join(REPO, "benchmarks")])
    assert findings == [], "\n".join(f.format() for f in findings)


# --------------------------------------------------------------------------
# jaxpr audit: toy programs
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    import jax
    return jax.ShapeDtypeStruct(shape, dtype)


def test_jaxpr_audit_clean_toy():
    import jax.numpy as jnp

    def toy(x):
        return x + x

    stats, findings = audit_fn(toy, _sds((4,), jnp.float32),
                               label="toy", allow_64=False)
    assert findings == []
    assert stats.eqns >= 1 and stats.label == "toy"


def test_jaxpr_audit_flags_x64_promotion():
    import repro.core.simulator  # noqa: F401 — enables x64 on import
    import jax.numpy as jnp

    def toy(x):
        return (x.astype(jnp.float64) * jnp.float64(2.0)).sum()

    _, findings = audit_fn(toy, _sds((4,), jnp.float32), allow_64=False)
    assert any(f.rule == "JXP003" for f in findings)


def test_jaxpr_audit_flags_host_callback():
    import jax

    def toy(x):
        jax.debug.print("x={x}", x=x)
        return x + x

    _, findings = audit_fn(toy, _sds((4,), jax.numpy.float32))
    assert any(f.rule == "JXP004" for f in findings)


def test_jaxpr_audit_flags_weak_scan_carry():
    import jax

    def toy(x):
        def body(c, xi):
            return c * 2.0, c
        c, _ = jax.lax.scan(body, 1.0, x)
        return c

    stats, findings = audit_fn(toy, _sds((4,), jax.numpy.float64),
                               allow_weak_outputs=True)
    assert stats.scans == 1
    assert any(f.rule == "JXP001" for f in findings)


# --------------------------------------------------------------------------
# jaxpr audit: the real engines
# --------------------------------------------------------------------------

def test_audit_engines_smoke():
    stats, findings = audit_engines(balancers=["LL"])
    assert [s.label for s in stats] == ["E/LL/PS|jax", "E/LL/PS|pallas"]
    assert findings == [], "\n".join(f.format() for f in findings)
    assert all(s.eqns > 0 and s.scans >= 1 for s in stats)


def test_engine_specs_cover_every_balancer_and_backend():
    from repro.policy import balancer_names
    labels = {label for label, *_ in iter_engine_specs()}
    for bname in balancer_names():
        for backend in ("jax", "pallas"):
            assert f"E/{bname}/PS|{backend}" in labels


def test_cache_key_covers_every_config_field():
    findings = audit_cache_key()
    assert findings == [], "\n".join(f.format() for f in findings)


def test_lifecycle_subfield_does_not_share_cached_engine():
    from repro.core.cluster import ClusterCfg
    from repro.core.simulator import build_simulator
    from repro.core.taxonomy import parse_policy
    from repro.lifecycle import LifecycleCfg

    pol = parse_policy("E/LL/PS")
    c1 = ClusterCfg(n_workers=2, cores=2, capacity_factor=2,
                    lifecycle=LifecycleCfg(ttl_s=60.0))
    c2 = c1._replace(lifecycle=c1.lifecycle._replace(ttl_s=61.0))
    e1 = build_simulator(pol, c1, n_arrivals=4, n_functions=2)
    e1b = build_simulator(pol, c1, n_arrivals=4, n_functions=2)
    e2 = build_simulator(pol, c2, n_arrivals=4, n_functions=2)
    assert e1 is e1b
    assert e1 is not e2


# --------------------------------------------------------------------------
# contracts
# --------------------------------------------------------------------------

def test_contracts_clean_on_current_registries():
    findings = check_contracts()
    assert findings == [], "\n".join(f.format() for f in findings)


def test_contract_flags_stateless_pair_mismatch():
    from repro.analysis.contracts import check_balancers
    from repro.policy.registry import BALANCERS, register_balancer

    # a stateful-shaped return from a stateless balancer is a violation
    register_balancer(
        "XCONTRACT",
        make_np=lambda cores, slots: (lambda *a: 0, lambda *a: None),
        make_jax=lambda cores, slots: (lambda *a: 0, lambda *a: None))
    try:
        findings = check_balancers()
        mine = [f for f in findings if "XCONTRACT" in f.path]
        assert mine and all(f.rule == "CON001" for f in mine)
    finally:
        del BALANCERS["XCONTRACT"]


# --------------------------------------------------------------------------
# budgets
# --------------------------------------------------------------------------

def test_baselines_cover_all_current_engines():
    labels = {label for label, *_ in iter_engine_specs()}
    assert labels == set(BASELINES)


def test_budget_for_unknown_engine_uses_default():
    assert budget_for("E/NOPE/PS|jax") == DEFAULT_BUDGET


def test_over_budget_engine_yields_bgt001():
    st = JaxprStats(label="E/LL/PS|jax", eqns=10 ** 6, scans=1, whiles=2,
                    carry_leaves=14, carry_bytes=0, outputs=1)
    rows, findings = check_budgets([st])
    assert rows[0]["ok"] is False
    assert [f.rule for f in findings] == ["BGT001"]


def test_within_budget_engine_is_clean():
    st = JaxprStats(label="E/LL/PS|jax", eqns=BASELINES["E/LL/PS|jax"],
                    scans=1, whiles=2, carry_leaves=14, carry_bytes=0,
                    outputs=1)
    rows, findings = check_budgets([st])
    assert rows[0]["ok"] is True and findings == []


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def test_cli_strict_passes_on_clean_file(tmp_path, capsys):
    clean = tmp_path / "ok.py"
    clean.write_text("x = 1\n")
    assert analysis_main([str(clean), "--strict", "--no-jaxpr"]) == 0
    assert "0 findings" in capsys.readouterr().out


def test_cli_strict_fails_on_violation(capsys):
    rc = analysis_main([fixture("det001_case.py"), "--strict",
                        "--no-jaxpr"])
    assert rc == 1
    assert "DET001" in capsys.readouterr().out


def test_cli_non_strict_reports_but_passes(capsys):
    rc = analysis_main([fixture("det001_case.py"), "--no-jaxpr"])
    assert rc == 0
    assert "DET001" in capsys.readouterr().out


def test_cli_list_rules(capsys):
    assert analysis_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("DET001", "HOT002", "PAR001", "JXP005", "CON004",
                "BGT001"):
        assert rid in out
