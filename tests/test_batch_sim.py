"""Batched engine contract: ``simulate_many`` ≡ R independent ``simulate``
calls bit-for-bit, plus compile-cache identity and batch metrics."""
import numpy as np
import pytest

from repro.core import (ClusterCfg, E_LL_SRPT, E_LOC_FCFS, E_R_PS, HERMES,
                        LATE_BINDING, replicate_workload, stack_workloads,
                        summarize_batch_sim, summarize_sim, synth_workload)
from repro.core.simulator import (build_batch_simulator, build_simulator,
                                  simulate, simulate_many)

# One policy per binding/balance/sched family:
#   L/LL/FCFS (late binding), E/LOC/FCFS (locality + FCFS),
#   E/R/PS (random + PS), E/LL/SRPT (least-loaded + SRPT),
#   E/H/PS (Hermes hybrid + PS).
FAMILY_POLICIES = (LATE_BINDING, E_LOC_FCFS, E_R_PS, E_LL_SRPT, HERMES)
CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)


def _wls(n=200):
    """Replications differing in both load and seed (shared (N, F))."""
    return [synth_workload(CLUSTER, load, n, n_functions=5,
                           hot_fraction=0.8, seed=seed)
            for load, seed in ((0.4, 0), (0.9, 1), (1.3, 2))]


@pytest.mark.parametrize("policy", FAMILY_POLICIES, ids=lambda p: p.name)
def test_simulate_many_matches_independent_runs(policy):
    wls = _wls()
    batch = simulate_many(policy, CLUSTER, wls)
    assert batch.n_reps == len(wls)
    for r, wl in enumerate(wls):
        single = simulate(policy, CLUSTER, wl)
        # bit-for-bit: the batched engine is the same program under vmap
        np.testing.assert_array_equal(
            np.nan_to_num(batch.response[r], nan=-1.0),
            np.nan_to_num(single.response, nan=-1.0))
        np.testing.assert_array_equal(batch.cold[r], single.cold)
        np.testing.assert_array_equal(batch.rejected[r], single.rejected)
        np.testing.assert_array_equal(batch.worker[r], single.worker)
        assert float(batch.server_time[r]) == single.server_time
        assert float(batch.core_time[r]) == single.core_time
        assert float(batch.end_time[r]) == single.end_time
        # rep() view round-trips
        rep = batch.rep(r)
        np.testing.assert_array_equal(
            np.nan_to_num(rep.response, nan=-1.0),
            np.nan_to_num(single.response, nan=-1.0))


def test_completion_within_eps_of_arrival_edge_terminates():
    """Regression: a task finishing EPS-close past an arrival boundary
    must complete in the pending-drain iteration, not livelock the
    while_loop (remaining in (0, EPS] with the window exhausted)."""
    import numpy as np
    from repro.core import Workload, E_LL_FCFS
    from repro.core.sim_ref import simulate_ref
    cl = ClusterCfg(n_workers=1, cores=2, capacity_factor=2)
    wl = Workload(
        arrival=np.array([0.0, 1.0]),
        func=np.zeros(2, dtype=np.int32),
        service=np.array([1.0 + 5e-10, 1.0]),   # done 5e-10 past arrival 2
        u_lb=np.zeros(2),
        func_home=np.zeros(1, dtype=np.int32),
        n_functions=1, load=0.5, name="eps-edge")
    out = simulate(E_LL_FCFS, cl, wl)
    ref = simulate_ref(E_LL_FCFS, cl, wl)
    np.testing.assert_allclose(out.response, ref.response, atol=1e-6)
    batch = simulate_many(E_LL_FCFS, cl, [wl, wl])
    np.testing.assert_array_equal(batch.response[0], out.response)


def test_compile_cache_returns_same_fn():
    kw = dict(n_arrivals=200, n_functions=5)
    a = build_simulator(HERMES, CLUSTER, **kw)
    b = build_simulator(HERMES, CLUSTER, **kw)
    assert a is b
    ab = build_batch_simulator(HERMES, CLUSTER, **kw)
    bb = build_batch_simulator(HERMES, CLUSTER, **kw)
    assert ab is bb
    assert ab is not a
    # any key component change misses the cache
    assert build_simulator(E_R_PS, CLUSTER, **kw) is not a
    assert build_simulator(
        HERMES, CLUSTER._replace(cold_start_penalty=0.5), **kw) is not a
    assert build_simulator(HERMES, CLUSTER, n_arrivals=201,
                           n_functions=5) is not a


def test_engine_cache_lru_bounded_and_evicts():
    """The compile cache is a bounded LRU: recently-used engines survive,
    the oldest are evicted once capacity is exceeded."""
    from repro.core import simulator as sim
    sim.clear_engine_cache()
    old_cap = sim.engine_cache_capacity()
    try:
        sim.set_engine_cache_capacity(2)
        kw = dict(n_functions=2)
        a = sim.build_simulator(HERMES, CLUSTER, n_arrivals=10, **kw)
        b = sim.build_simulator(HERMES, CLUSTER, n_arrivals=11, **kw)
        # touching a makes b the LRU entry
        assert sim.build_simulator(HERMES, CLUSTER, n_arrivals=10,
                                   **kw) is a
        sim.build_simulator(HERMES, CLUSTER, n_arrivals=12, **kw)
        stats = sim.engine_cache_stats()
        assert stats["entries"] == 2 and stats["capacity"] == 2
        # a survived (was MRU), b was evicted and rebuilds fresh
        assert sim.build_simulator(HERMES, CLUSTER, n_arrivals=10,
                                   **kw) is a
        assert sim.build_simulator(HERMES, CLUSTER, n_arrivals=11,
                                   **kw) is not b
        # shrinking the bound evicts immediately
        sim.set_engine_cache_capacity(1)
        assert sim.engine_cache_stats()["entries"] == 1
        with pytest.raises(ValueError):
            sim.set_engine_cache_capacity(0)
    finally:
        sim.set_engine_cache_capacity(old_cap)
        sim.clear_engine_cache()


def test_stack_workloads_validates_shape():
    a = synth_workload(CLUSTER, 0.5, 100, n_functions=5, seed=0)
    b = synth_workload(CLUSTER, 0.5, 101, n_functions=5, seed=0)
    c = synth_workload(CLUSTER, 0.5, 100, n_functions=6, seed=0)
    with pytest.raises(ValueError):
        stack_workloads([a, b])
    with pytest.raises(ValueError):
        stack_workloads([a, c])
    with pytest.raises(ValueError):
        stack_workloads([])
    wb = stack_workloads([a])
    assert wb.n_reps == 1 and wb.n == 100


def test_replicate_workload_grid_order():
    def wfn(cluster, load, n, seed):
        return synth_workload(cluster, load, n, n_functions=3, seed=seed,
                              name=f"l{load}-s{seed}")
    wb = replicate_workload(wfn, CLUSTER, [0.3, 0.8], 50, seeds=(0, 1, 2))
    assert wb.n_reps == 6
    # load-major: loads change slowest, seeds fastest
    assert wb.names == ("l0.3-s0", "l0.3-s1", "l0.3-s2",
                        "l0.8-s0", "l0.8-s1", "l0.8-s2")
    assert wb.loads == (0.3, 0.3, 0.3, 0.8, 0.8, 0.8)
    # distinct seeds produce distinct traces
    assert not np.allclose(wb.service[0], wb.service[1])


def test_summarize_batch_single_rep_matches_summarize():
    wl = synth_workload(CLUSTER, 0.8, 300, n_functions=5, seed=3)
    out = simulate(HERMES, CLUSTER, wl)
    bout = simulate_many(HERMES, CLUSTER, [wl])
    wb = stack_workloads([wl])
    bs = summarize_batch_sim(bout, wb)
    s = summarize_sim(out, wl)
    assert bs.n_reps == 1
    assert bs.per_rep[0] == s
    assert bs.pooled == s
    # no spread estimate from a single replication
    assert all(st.ci95 == 0.0 for st in bs.stats.values()
               if np.isfinite(st.ci95))


def test_mixed_synthetic_and_replay_batch():
    """Synthetic generators + azure-* trace replays stack into ONE
    ``simulate_many`` batch (the ROADMAP mixed-batches item): the shape
    is harmonized by resampling, per-rep results are bit-identical to
    independent ``simulate`` runs, and metrics summarize per workload.
    """
    from benchmarks.common import mixed_workload_batch, sweep_policies_mixed
    names = ("ms-trace", "azure-diurnal", "azure-bursty")
    wb = mixed_workload_batch(CLUSTER, names, 0.6, 180, seed=0)
    assert wb.n_reps == len(names)
    # harmonized shape: truncated to shortest N, widened to the largest
    # component F (ms-trace's 50; replay traces carry fewer functions)
    assert wb.n == 180
    assert wb.n_functions == 50
    assert int(wb.func.max()) < wb.n_functions
    assert wb.names[1].startswith("azure-diurnal")
    # mixed batch ≡ R independent runs, including a carried-state policy
    from repro.core import E_DD_PS
    for policy in (HERMES, E_DD_PS):
        batch = simulate_many(policy, CLUSTER, wb)
        for r in range(wb.n_reps):
            single = simulate(policy, CLUSTER, wb.rep(r))
            np.testing.assert_array_equal(
                np.nan_to_num(batch.response[r], nan=-1.0),
                np.nan_to_num(single.response, nan=-1.0))
    rows = sweep_policies_mixed([HERMES, E_DD_PS], CLUSTER, names, 0.6,
                                180, seed=0)
    assert len(rows) == 2 * len(names)
    assert {r["workload"] for r in rows} == set(names)
    assert all(np.isfinite(r["slow_p50"]) for r in rows)


def test_summarize_batch_confidence_intervals():
    wls = [synth_workload(CLUSTER, 0.8, 300, n_functions=5, seed=s)
           for s in range(4)]
    bout = simulate_many(HERMES, CLUSTER, wls)
    bs = summarize_batch_sim(bout, stack_workloads(wls))
    assert bs.n_reps == 4
    st = bs.stats["slow_p50"]
    per = [s.slow_p50 for s in bs.per_rep]
    assert min(per) <= st.mean <= max(per)
    assert st.ci95 >= 0.0 and st.lo <= st.mean <= st.hi
    row = bs.row()
    assert row["slow_p50_mean"] == st.mean
    assert row["slow_p50_ci95"] == st.ci95
