"""Batched engine contract: ``simulate_many`` ≡ R independent ``simulate``
calls bit-for-bit, plus compile-cache identity and batch metrics."""
import numpy as np
import pytest

from repro.core import (ClusterCfg, E_LL_SRPT, E_LOC_FCFS, E_R_PS, HERMES,
                        LATE_BINDING, replicate_workload, stack_workloads,
                        summarize_batch_sim, summarize_sim, synth_workload)
from repro.core.simulator import (build_batch_simulator, build_simulator,
                                  simulate, simulate_many)

# One policy per binding/balance/sched family:
#   L/LL/FCFS (late binding), E/LOC/FCFS (locality + FCFS),
#   E/R/PS (random + PS), E/LL/SRPT (least-loaded + SRPT),
#   E/H/PS (Hermes hybrid + PS).
FAMILY_POLICIES = (LATE_BINDING, E_LOC_FCFS, E_R_PS, E_LL_SRPT, HERMES)
CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)


def _wls(n=200):
    """Replications differing in both load and seed (shared (N, F))."""
    return [synth_workload(CLUSTER, load, n, n_functions=5,
                           hot_fraction=0.8, seed=seed)
            for load, seed in ((0.4, 0), (0.9, 1), (1.3, 2))]


@pytest.mark.parametrize("policy", FAMILY_POLICIES, ids=lambda p: p.name)
def test_simulate_many_matches_independent_runs(policy):
    wls = _wls()
    batch = simulate_many(policy, CLUSTER, wls)
    assert batch.n_reps == len(wls)
    for r, wl in enumerate(wls):
        single = simulate(policy, CLUSTER, wl)
        # bit-for-bit: the batched engine is the same program under vmap
        np.testing.assert_array_equal(
            np.nan_to_num(batch.response[r], nan=-1.0),
            np.nan_to_num(single.response, nan=-1.0))
        np.testing.assert_array_equal(batch.cold[r], single.cold)
        np.testing.assert_array_equal(batch.rejected[r], single.rejected)
        np.testing.assert_array_equal(batch.worker[r], single.worker)
        assert float(batch.server_time[r]) == single.server_time
        assert float(batch.core_time[r]) == single.core_time
        assert float(batch.end_time[r]) == single.end_time
        # rep() view round-trips
        rep = batch.rep(r)
        np.testing.assert_array_equal(
            np.nan_to_num(rep.response, nan=-1.0),
            np.nan_to_num(single.response, nan=-1.0))


def test_completion_within_eps_of_arrival_edge_terminates():
    """Regression: a task finishing EPS-close past an arrival boundary
    must complete in the pending-drain iteration, not livelock the
    while_loop (remaining in (0, EPS] with the window exhausted)."""
    import numpy as np
    from repro.core import Workload, E_LL_FCFS
    from repro.core.sim_ref import simulate_ref
    cl = ClusterCfg(n_workers=1, cores=2, capacity_factor=2)
    wl = Workload(
        arrival=np.array([0.0, 1.0]),
        func=np.zeros(2, dtype=np.int32),
        service=np.array([1.0 + 5e-10, 1.0]),   # done 5e-10 past arrival 2
        u_lb=np.zeros(2),
        func_home=np.zeros(1, dtype=np.int32),
        n_functions=1, load=0.5, name="eps-edge")
    out = simulate(E_LL_FCFS, cl, wl)
    ref = simulate_ref(E_LL_FCFS, cl, wl)
    np.testing.assert_allclose(out.response, ref.response, atol=1e-6)
    batch = simulate_many(E_LL_FCFS, cl, [wl, wl])
    np.testing.assert_array_equal(batch.response[0], out.response)


def test_compile_cache_returns_same_fn():
    kw = dict(n_arrivals=200, n_functions=5)
    a = build_simulator(HERMES, CLUSTER, **kw)
    b = build_simulator(HERMES, CLUSTER, **kw)
    assert a is b
    ab = build_batch_simulator(HERMES, CLUSTER, **kw)
    bb = build_batch_simulator(HERMES, CLUSTER, **kw)
    assert ab is bb
    assert ab is not a
    # any key component change misses the cache
    assert build_simulator(E_R_PS, CLUSTER, **kw) is not a
    assert build_simulator(
        HERMES, CLUSTER._replace(cold_start_penalty=0.5), **kw) is not a
    assert build_simulator(HERMES, CLUSTER, n_arrivals=201,
                           n_functions=5) is not a


def test_engine_cache_lru_bounded_and_evicts():
    """The compile cache is a bounded LRU: recently-used engines survive,
    the oldest are evicted once capacity is exceeded."""
    from repro.core import simulator as sim
    sim.clear_engine_cache()
    old_cap = sim.engine_cache_capacity()
    try:
        sim.set_engine_cache_capacity(2)
        kw = dict(n_functions=2)
        a = sim.build_simulator(HERMES, CLUSTER, n_arrivals=10, **kw)
        b = sim.build_simulator(HERMES, CLUSTER, n_arrivals=11, **kw)
        # touching a makes b the LRU entry
        assert sim.build_simulator(HERMES, CLUSTER, n_arrivals=10,
                                   **kw) is a
        sim.build_simulator(HERMES, CLUSTER, n_arrivals=12, **kw)
        stats = sim.engine_cache_stats()
        assert stats["entries"] == 2 and stats["capacity"] == 2
        # a survived (was MRU), b was evicted and rebuilds fresh
        assert sim.build_simulator(HERMES, CLUSTER, n_arrivals=10,
                                   **kw) is a
        assert sim.build_simulator(HERMES, CLUSTER, n_arrivals=11,
                                   **kw) is not b
        # shrinking the bound evicts immediately
        sim.set_engine_cache_capacity(1)
        assert sim.engine_cache_stats()["entries"] == 1
        with pytest.raises(ValueError):
            sim.set_engine_cache_capacity(0)
    finally:
        sim.set_engine_cache_capacity(old_cap)
        sim.clear_engine_cache()


def test_stack_workloads_validates_shape():
    a = synth_workload(CLUSTER, 0.5, 100, n_functions=5, seed=0)
    b = synth_workload(CLUSTER, 0.5, 101, n_functions=5, seed=0)
    c = synth_workload(CLUSTER, 0.5, 100, n_functions=6, seed=0)
    with pytest.raises(ValueError):
        stack_workloads([a, b])
    with pytest.raises(ValueError):
        stack_workloads([a, c])
    with pytest.raises(ValueError):
        stack_workloads([])
    wb = stack_workloads([a])
    assert wb.n_reps == 1 and wb.n == 100


def test_replicate_workload_grid_order():
    def wfn(cluster, load, n, seed):
        return synth_workload(cluster, load, n, n_functions=3, seed=seed,
                              name=f"l{load}-s{seed}")
    wb = replicate_workload(wfn, CLUSTER, [0.3, 0.8], 50, seeds=(0, 1, 2))
    assert wb.n_reps == 6
    # load-major: loads change slowest, seeds fastest
    assert wb.names == ("l0.3-s0", "l0.3-s1", "l0.3-s2",
                        "l0.8-s0", "l0.8-s1", "l0.8-s2")
    assert wb.loads == (0.3, 0.3, 0.3, 0.8, 0.8, 0.8)
    # distinct seeds produce distinct traces
    assert not np.allclose(wb.service[0], wb.service[1])


def test_summarize_batch_single_rep_matches_summarize():
    wl = synth_workload(CLUSTER, 0.8, 300, n_functions=5, seed=3)
    out = simulate(HERMES, CLUSTER, wl)
    bout = simulate_many(HERMES, CLUSTER, [wl])
    wb = stack_workloads([wl])
    bs = summarize_batch_sim(bout, wb)
    s = summarize_sim(out, wl)
    assert bs.n_reps == 1
    assert bs.per_rep[0] == s
    assert bs.pooled == s
    # no spread estimate from a single replication
    assert all(st.ci95 == 0.0 for st in bs.stats.values()
               if np.isfinite(st.ci95))


def test_mixed_synthetic_and_replay_batch():
    """Synthetic generators + azure-* trace replays stack into ONE
    ``simulate_many`` batch (the ROADMAP mixed-batches item): the shape
    is harmonized by resampling, per-rep results are bit-identical to
    independent ``simulate`` runs, and metrics summarize per workload.
    """
    from benchmarks.common import mixed_workload_batch, sweep_policies_mixed
    names = ("ms-trace", "azure-diurnal", "azure-bursty")
    wb = mixed_workload_batch(CLUSTER, names, 0.6, 180, seed=0)
    assert wb.n_reps == len(names)
    # harmonized shape: truncated to shortest N, widened to the largest
    # component F (ms-trace's 50; replay traces carry fewer functions)
    assert wb.n == 180
    assert wb.n_functions == 50
    assert int(wb.func.max()) < wb.n_functions
    assert wb.names[1].startswith("azure-diurnal")
    # mixed batch ≡ R independent runs, including a carried-state policy
    from repro.core import E_DD_PS
    for policy in (HERMES, E_DD_PS):
        batch = simulate_many(policy, CLUSTER, wb)
        for r in range(wb.n_reps):
            single = simulate(policy, CLUSTER, wb.rep(r))
            np.testing.assert_array_equal(
                np.nan_to_num(batch.response[r], nan=-1.0),
                np.nan_to_num(single.response, nan=-1.0))
    rows = sweep_policies_mixed([HERMES, E_DD_PS], CLUSTER, names, 0.6,
                                180, seed=0)
    assert len(rows) == 2 * len(names)
    assert {r["workload"] for r in rows} == set(names)
    assert all(np.isfinite(r["slow_p50"]) for r in rows)


def test_summarize_batch_confidence_intervals():
    wls = [synth_workload(CLUSTER, 0.8, 300, n_functions=5, seed=s)
           for s in range(4)]
    bout = simulate_many(HERMES, CLUSTER, wls)
    bs = summarize_batch_sim(bout, stack_workloads(wls))
    assert bs.n_reps == 4
    st = bs.stats["slow_p50"]
    per = [s.slow_p50 for s in bs.per_rep]
    assert min(per) <= st.mean <= max(per)
    assert st.ci95 >= 0.0 and st.lo <= st.mean <= st.hi
    row = bs.row()
    assert row["slow_p50_mean"] == st.mean
    assert row["slow_p50_ci95"] == st.ci95


# ------------------------------------------------- streaming engine

# The heaviest carry the engines support: carried-state balancer (DD) +
# hybrid-histogram keep-alive + telemetry sketches + two-generation
# fleet + TARGET_P99 autoscaler.  If chunking is bit-equal here, every
# lighter combination is covered by construction (the chunk step shares
# its arrival/completion bodies with the monolithic scan).
def _stream_cluster():
    from repro.core import LifecycleCfg
    from repro.fleet import FleetCfg
    return CLUSTER._replace(
        lifecycle=LifecycleCfg(keepalive="HYBRID_HIST", ttl_s=2.0,
                               max_idle=3, coldstart="paper-sim"),
        fleet=FleetCfg(preset="two-gen", autoscale="TARGET_P99",
                       min_workers=2, target_p99=4.0, cooldown_s=2.0))


@pytest.mark.parametrize("chunk", [16, 50, 97, 300],
                         ids=lambda k: f"k{k}")
def test_stream_matches_monolithic_bitwise(chunk):
    """Chunked(N, k) ≡ monolithic(N) bit-for-bit — final carry,
    per-arrival outputs, telemetry sketches and pooled metrics — for
    dividing, non-dividing (97) and larger-than-horizon (300) chunks."""
    from repro.core import E_DD_PS
    from repro.telemetry import TelemetryCfg
    from repro.core.simulator import build_batch_simulator
    from repro.core.streaming import final_states_equal, simulate_stream
    import jax.numpy as jnp

    cl = _stream_cluster()
    tel = TelemetryCfg()
    wls = [synth_workload(cl, load, 200, n_functions=5, seed=seed)
           for load, seed in ((0.5, 0), (1.1, 1))]
    wb = stack_workloads(wls)
    run = build_batch_simulator(E_DD_PS, cl, n_arrivals=wb.n,
                                n_functions=wb.n_functions,
                                telemetry=tel)
    mono = run(jnp.asarray(wb.arrival), jnp.asarray(wb.func),
               jnp.asarray(wb.service), jnp.asarray(wb.u_lb),
               jnp.asarray(wb.func_home))
    out = simulate_stream(E_DD_PS, cl, wb, chunk_size=chunk,
                          telemetry=tel, collect_outputs=True,
                          keep_final_state=True)
    ok, bad = final_states_equal(out.final_state, mono)
    assert ok, f"carry mismatch in planes: {bad}"
    # per-arrival outputs stream out through the scan ys
    np.testing.assert_array_equal(out.rejected,
                                  np.asarray(mono.rejected[:, :wb.n]))
    np.testing.assert_array_equal(out.cold,
                                  np.asarray(mono.cold[:, :wb.n]))
    np.testing.assert_array_equal(out.worker,
                                  np.asarray(mono.worker_of[:, :wb.n]))
    # exact online counters reproduce the monolithic per-task planes
    from repro.telemetry.state import warmup_cutoff
    cut = warmup_cutoff(wb.n, tel)
    resp = np.asarray(mono.resp[:, :wb.n])
    done = ~np.isnan(resp)
    obs = done & (np.arange(wb.n) >= cut)
    np.testing.assert_array_equal(out.n_done, done.sum(axis=1))
    np.testing.assert_array_equal(out.n_observed, obs.sum(axis=1))
    np.testing.assert_allclose(
        out.resp_mean,
        np.where(obs, resp, 0.0).sum(axis=1) / np.maximum(
            obs.sum(axis=1), 1), rtol=1e-12)
    assert out.n_chunks == -(-wb.n // chunk)
    assert out.chunk_size == chunk


def test_stream_matches_numpy_oracle_per_segment():
    """The chunked jax engine and the numpy oracle's chunked replay
    agree at every segment boundary, not just at the end."""
    from repro.core import E_LL_PS
    from repro.telemetry import TelemetryCfg
    from repro.core.sim_ref import simulate_ref_chunks
    from repro.core.streaming import simulate_stream

    cl = CLUSTER
    tel = TelemetryCfg()
    wl = synth_workload(cl, 0.9, 140, n_functions=5, seed=4)
    ref, snaps = simulate_ref_chunks(E_LL_PS, cl, wl, chunk_size=40,
                                     telemetry=tel)
    seen = []
    simulate_stream(
        E_LL_PS, cl, wl, chunk_size=40, telemetry=tel,
        chunk_callback=lambda c, st: seen.append(
            {k: np.copy(np.asarray(v)[0]) for k, v in st.tel.items()}))
    assert len(seen) == len(snaps) == 4
    for got, want in zip(seen, snaps):
        for key in ("slow_hist", "lat_hist", "n_cold", "n_warm",
                    "n_evict", "n_reject", "decisions"):
            np.testing.assert_array_equal(got[key], want[key],
                                          err_msg=key)
        for key in ("busy_time", "depth_time", "qlen_time"):
            np.testing.assert_allclose(got[key], want[key], atol=1e-9,
                                       err_msg=key)


def test_stream_engine_cache_horizon_independent():
    """One compiled chunk program serves any horizon; the cache key is
    (policy, cluster, chunk), never N."""
    from repro.core import E_LL_PS
    from repro.telemetry import TelemetryCfg
    from repro.core.simulator import _get_stream_engine

    tel = TelemetryCfg()
    a, fresh_a = _get_stream_engine(E_LL_PS, CLUSTER, 32, 5, "auto", tel)
    b, fresh_b = _get_stream_engine(E_LL_PS, CLUSTER, 32, 5, "auto", tel)
    assert a is b and not fresh_b
    c, _ = _get_stream_engine(E_LL_PS, CLUSTER, 64, 5, "auto", tel)
    assert c is not a
    # different horizons reuse the same engine end to end
    from repro.core.streaming import simulate_stream
    wl_s = synth_workload(CLUSTER, 0.7, 64, n_functions=5, seed=0)
    wl_l = synth_workload(CLUSTER, 0.7, 200, n_functions=5, seed=0)
    o1 = simulate_stream(E_LL_PS, CLUSTER, wl_s, chunk_size=32,
                         telemetry=tel)
    o2 = simulate_stream(E_LL_PS, CLUSTER, wl_l, chunk_size=32,
                         telemetry=tel)
    assert o1.n_chunks == 2 and o2.n_chunks == 7
    d, fresh_d = _get_stream_engine(E_LL_PS, CLUSTER, 32, 5, "auto", tel)
    assert d is a and not fresh_d


def test_stream_requires_early_binding():
    from repro.telemetry import TelemetryCfg
    from repro.core.streaming import simulate_stream

    wl = synth_workload(CLUSTER, 0.5, 50, n_functions=5, seed=0)
    with pytest.raises(ValueError, match="early binding"):
        simulate_stream(LATE_BINDING, CLUSTER, wl, chunk_size=16,
                        telemetry=TelemetryCfg())


@pytest.mark.slow
def test_stream_sharded_reps_match_unsharded(devices_script):
    """Rep-axis device sharding changes placement, not results: the
    sharded run is bit-equal to the single-device run, and a rep count
    that does not divide the mesh raises the named error."""
    devices_script("""
import numpy as np
from repro.core import ClusterCfg, E_DD_PS, synth_workload
from repro.telemetry import TelemetryCfg
from repro.core.streaming import final_states_equal, simulate_stream
from repro.launch.mesh import make_rep_mesh

cl = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)
tel = TelemetryCfg()
wls = [synth_workload(cl, 0.8, 120, n_functions=5, seed=s)
       for s in range(4)]
mesh = make_rep_mesh(4)
a = simulate_stream(E_DD_PS, cl, wls, chunk_size=40, telemetry=tel,
                    keep_final_state=True)
b = simulate_stream(E_DD_PS, cl, wls, chunk_size=40, telemetry=tel,
                    keep_final_state=True, mesh=mesh)
ok, bad = final_states_equal(a.final_state, b.final_state)
assert ok, bad
np.testing.assert_array_equal(a.n_done, b.n_done)
np.testing.assert_array_equal(a.resp_mean, b.resp_mean)
try:
    simulate_stream(E_DD_PS, cl, wls[:3], chunk_size=40, telemetry=tel,
                    mesh=mesh)
except ValueError as e:
    assert "does not divide" in str(e), e
else:
    raise AssertionError("expected named divisibility error")
print("sharded-ok")
""", n_devices=4)


@pytest.mark.slow
def test_stream_full_day_large_fleet_under_memory_budget():
    """The horizon gate end-to-end: one full synthetic azure-diurnal
    day at W=1000 in a single streaming run, peak RSS under budget."""
    from benchmarks.fig14_stream import (PEAK_MB_BUDGET, _horizon_lane)

    row = _horizon_lane(quick=False)[0]
    assert row["ok"], row
    assert row["n_workers"] >= 1000
    assert row["full_day"] and row["n_done"] > 0
    assert row["peak_rss_mb"] <= PEAK_MB_BUDGET
