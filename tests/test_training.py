"""Training substrate: optimizer, checkpointing, restart, compression.

``hypothesis`` is optional — the quantize round-trip bound is always
checked on seeded random vectors; hypothesis adds fuzzing when present.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro import configs
from repro.data.pipeline import lcg_batch, make_data_iter, random_batch
from repro.models.transformer import build_model
from repro.training.checkpoint import CheckpointManager
from repro.training.compression import dequantize, quantize
from repro.training.optimizer import OptCfg, adamw_update, init_opt_state, \
    schedule
from repro.training.train import (build_train_step, init_train_state,
                                  run_with_restarts)


def test_schedule_shape():
    cfg = OptCfg(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] < lrs[1]                      # warmup
    assert max(lrs) <= 1e-3 * (1 + 1e-5)
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)   # min_lr_frac


def test_adamw_decreases_quadratic():
    cfg = OptCfg(lr=0.1, warmup_steps=0, total_steps=100,
                 weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def _check_quantize_roundtrip(xs):
    x = jnp.asarray(xs, jnp.float32)
    q, scale = quantize(x)
    err = np.abs(np.asarray(dequantize(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


@pytest.mark.parametrize("seed", range(20))
def test_quantize_roundtrip_bound_seeded(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 65))
    _check_quantize_roundtrip(rng.uniform(-100, 100, n).tolist())


def test_quantize_roundtrip_bound_corners():
    _check_quantize_roundtrip([0.0])
    _check_quantize_roundtrip([100.0, -100.0])
    _check_quantize_roundtrip([1e-30] * 8)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                    max_size=64))
    def test_quantize_roundtrip_bound(xs):
        _check_quantize_roundtrip(xs)


def test_data_pipeline_deterministic():
    t1, l1 = random_batch(7, 4, 16, 100)
    t2, l2 = random_batch(7, 4, 16, 100)
    np.testing.assert_array_equal(t1, t2)
    t3, _ = random_batch(8, 4, 16, 100)
    assert not np.array_equal(t1, t3)
    t, l = lcg_batch(0, 4, 16, 97)
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])


def test_train_loss_decreases():
    cfg = configs.get_smoke("olmo-1b")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(build_train_step(
        model, OptCfg(lr=1e-2, warmup_steps=5, total_steps=100)))
    data = make_data_iter("lcg", 4, 32, cfg.vocab, device=False)
    losses = []
    for i in range(60):
        state, m = step(state, *data(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_checkpoint_atomic_and_restores():
    cfg = configs.get_smoke("musicgen-large")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        mgr.save(state, 10, blocking=True)
        mgr.save(state, 20, blocking=True)
        mgr.save(state, 30, blocking=True)
        assert mgr.latest_step() == 30
        # keep=2 garbage-collects the oldest
        assert not os.path.exists(os.path.join(d, "10"))
        restored, step = mgr.restore(state)
        assert step == 30
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(restored.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_with_restarts_recovers_and_replays():
    cfg = configs.get_smoke("olmo-1b")
    model = build_model(cfg)
    ocfg = OptCfg(lr=1e-2, warmup_steps=2, total_steps=50)
    data = make_data_iter("lcg", 4, 32, cfg.vocab, device=False)
    step = jax.jit(build_train_step(model, ocfg))

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        fails = {7, 18}

        def hook(s):
            if s in fails:
                fails.discard(s)
                raise RuntimeError("injected node failure")

        state = init_train_state(model, jax.random.key(0))
        state, rep = run_with_restarts(step, state, data, n_steps=25,
                                       ckpt_mgr=mgr, ckpt_every=5,
                                       failure_hook=hook)
        assert rep.steps_done == 25
        assert rep.restarts == 2
        # identical run without failures reaches the same final loss
        state2 = init_train_state(model, jax.random.key(0))
        with tempfile.TemporaryDirectory() as d2:
            state2, rep2 = run_with_restarts(
                step, state2, data, n_steps=25,
                ckpt_mgr=CheckpointManager(d2), ckpt_every=5)
        assert rep.final_loss == pytest.approx(rep2.final_loss, rel=1e-5)


def test_restart_budget_exhaustion_raises():
    cfg = configs.get_smoke("olmo-1b")
    model = build_model(cfg)
    step = jax.jit(build_train_step(
        model, OptCfg(lr=1e-3, warmup_steps=2, total_steps=50)))
    data = make_data_iter("lcg", 2, 16, cfg.vocab, device=False)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        state = init_train_state(model, jax.random.key(0))

        def hook(s):
            raise RuntimeError("always failing")

        with pytest.raises(RuntimeError):
            run_with_restarts(step, state, data, n_steps=10, ckpt_mgr=mgr,
                              max_restarts=2, failure_hook=hook)
