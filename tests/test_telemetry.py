"""repro.telemetry contract tests.

Three planes, three obligations:

* the histogram sketch approximates exact percentiles within its
  documented tolerance over heavy-tailed / multi-modal / trace-replay
  service distributions;
* the in-scan jax telemetry carry is *bitwise* equal to the numpy
  oracle's (integer planes) for every registered engine, and enabling
  it never perturbs the simulation results;
* spans export valid Chrome trace JSON, the run manifest collects, and
  the engine cache keys/stats see telemetry correctly.
"""
import json

import numpy as np
import pytest

from repro.core import ClusterCfg, parse_policy, synth_workload
from repro.core.sim_ref import simulate_ref
from repro.core.simulator import (engine_cache_stats, simulate,
                                  simulate_many)
from repro.core.workload import ms_trace, stack_workloads
from repro.policy import balancer_names
from repro.telemetry import (N_BINS, TelemetryCfg, Tracer, bin_index_np,
                             hist_edges, sketch_count, sketch_percentile,
                             wall_split_from_aggregate)
from repro.telemetry.manifest import collect as collect_manifest

CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)
TEL = TelemetryCfg(warmup_frac=0.1)

ALL_POLICIES = [parse_policy(f"E/{b}/PS") for b in balancer_names()] \
    + [parse_policy("L/*/*")]


def _wl(load, n=250, seed=0):
    return synth_workload(CLUSTER, load, n, n_functions=5,
                          hot_fraction=0.8, seed=seed)


# --------------------------------------------------------------------------
# plane 1a: sketch accuracy (documented ≤2% tolerance; half-bin ≈0.76%)
# --------------------------------------------------------------------------

def _draws(kind, n=20000, seed=0):
    rng = np.random.default_rng(seed)
    if kind == "lognormal":
        return rng.lognormal(mean=0.5, sigma=1.5, size=n)
    if kind == "bimodal":
        # unbalanced modes so p50/p90/p99 each fall *inside* a mode —
        # a quantile exactly in the inter-mode density gap is
        # ill-conditioned for any estimator (interpolation across the
        # gap), not a sketch property
        n_short = int(n * 0.6)
        short = rng.lognormal(mean=-2.0, sigma=0.4, size=n_short)
        long = rng.lognormal(mean=2.5, sigma=0.6, size=n - n_short)
        return np.concatenate([short, long])
    if kind == "azure-replay":
        # trace-replay-shaped service draws: the azure-* generators'
        # per-function duration percentiles span ms..minutes
        from repro.core import WORKLOADS
        wl = WORKLOADS["azure-bursty"](CLUSTER, 0.6, n, seed=seed)
        return np.asarray(wl.service, dtype=np.float64)
    raise AssertionError(kind)


@pytest.mark.parametrize("kind", ["lognormal", "bimodal", "azure-replay"])
@pytest.mark.parametrize("q", [50, 90, 99])
def test_sketch_percentile_accuracy(kind, q):
    x = _draws(kind)
    counts = np.bincount(bin_index_np(x), minlength=N_BINS)
    got = sketch_percentile(counts, q)
    want = float(np.percentile(x, q))
    # half-bin geometric error is ≈0.76%; rank interpolation adds a
    # little slack on top for discrete ranks
    assert abs(got - want) / want < 0.02
    assert sketch_count(counts) == x.size


def test_sketch_edges_and_bins():
    e = hist_edges()
    assert e.shape == (N_BINS + 1,) and e.dtype == np.float64
    assert np.all(np.diff(e) > 0)
    # clipping at both ends, exact-edge goes to the right-closed bin
    assert bin_index_np(np.array([0.0, 1e-9])).tolist() == [0, 0]
    assert bin_index_np(np.array([1e9])).tolist() == [N_BINS - 1]
    b = bin_index_np(np.array([1.0]))[0]
    assert e[b] <= 1.0 < e[b + 1]


def test_sketch_percentile_empty_is_nan():
    assert np.isnan(sketch_percentile(np.zeros(N_BINS, dtype=np.int64),
                                      50))


# --------------------------------------------------------------------------
# plane 1b: np ≡ jax parity + telemetry-off goldenness, every engine
# --------------------------------------------------------------------------

def _assert_tel_equal(a, b):
    np.testing.assert_array_equal(a.slow_hist, b.slow_hist)
    np.testing.assert_array_equal(a.lat_hist, b.lat_hist)
    for f in ("n_cold", "n_warm", "n_evict", "n_reject"):
        assert int(np.sum(getattr(a, f))) == int(np.sum(getattr(b, f))), f
    np.testing.assert_array_equal(a.decisions, b.decisions)
    np.testing.assert_allclose(a.busy_time, b.busy_time, rtol=1e-9)
    np.testing.assert_allclose(a.depth_time, b.depth_time, rtol=1e-9)
    np.testing.assert_allclose(a.qlen_time, b.qlen_time, rtol=1e-9)


@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.name)
def test_jax_telemetry_matches_oracle(policy):
    wl = _wl(0.9)
    ref = simulate_ref(policy, CLUSTER, wl, telemetry=TEL)
    out = simulate(policy, CLUSTER, wl, telemetry=TEL)
    _assert_tel_equal(out.telemetry, ref.telemetry)


@pytest.mark.parametrize("policy", ALL_POLICIES[:3],
                         ids=lambda p: p.name)
def test_telemetry_does_not_perturb_results(policy):
    wl = _wl(0.9, seed=2)
    base = simulate(policy, CLUSTER, wl)
    tel = simulate(policy, CLUSTER, wl, telemetry=TEL)
    np.testing.assert_array_equal(
        np.nan_to_num(base.response, nan=-1.0),
        np.nan_to_num(tel.response, nan=-1.0))
    np.testing.assert_array_equal(base.cold, tel.cold)
    np.testing.assert_array_equal(base.rejected, tel.rejected)
    assert base.telemetry is None and tel.telemetry is not None


def test_telemetry_counts_match_population():
    # the sketch observes exactly the post-warmup accepted completions
    wl = _wl(0.8, n=400, seed=3)
    pol = parse_policy("E/H/PS")
    out = simulate(pol, CLUSTER, wl, telemetry=TEL)
    cut = int(wl.n * TEL.warmup_frac)
    accepted = (~out.rejected)[cut:].sum()
    assert sketch_count(out.telemetry.slow_hist) == accepted
    assert sketch_count(out.telemetry.lat_hist) == accepted
    t = out.telemetry
    # every arrival lands in exactly one of placed-cold/warm/rejected
    assert int(t.n_cold + t.n_warm + t.n_reject) == wl.n


def test_lifecycle_eviction_telemetry_parity():
    from repro.lifecycle import LifecycleCfg
    cl = CLUSTER._replace(lifecycle=LifecycleCfg(
        keepalive="FIXED_TTL", ttl_s=5.0, max_idle=2))
    wl = synth_workload(cl, 0.9, 250, n_functions=5, hot_fraction=0.8,
                        seed=1)
    pol = parse_policy("E/LL/PS")
    ref = simulate_ref(pol, cl, wl, telemetry=TEL)
    out = simulate(pol, cl, wl, telemetry=TEL)
    _assert_tel_equal(out.telemetry, ref.telemetry)


def test_batch_telemetry_pools_and_slices():
    wls = [ms_trace(CLUSTER, 0.6, 300, seed=s) for s in (0, 1, 2)]
    wb = stack_workloads(wls)
    pol = parse_policy("E/LL/PS")
    out = simulate_many(pol, CLUSTER, wb, telemetry=TEL)
    refs = [simulate_ref(pol, CLUSTER, w, telemetry=TEL) for w in wls]
    # pooled hist == sum of per-rep oracle hists; rep(r) == oracle r
    np.testing.assert_array_equal(
        out.telemetry.slow_hist.sum(axis=0),
        np.sum([r.telemetry.slow_hist for r in refs], axis=0))
    for r, ref in enumerate(refs):
        _assert_tel_equal(out.telemetry.rep(r), ref.telemetry)
    sl = out[1:3]
    np.testing.assert_array_equal(sl.telemetry.slow_hist,
                                  out.telemetry.slow_hist[1:3])
    assert np.isfinite(out.telemetry.slow_percentile(99))


def test_serving_matches_oracle_telemetry():
    from repro.serving.engine import ServeCfg, ServingCluster
    wl = _wl(0.8, n=300, seed=5)
    pol = parse_policy("E/H/PS")
    sc = ServingCluster(
        ServeCfg(cluster=CLUSTER,
                 cold_start_s=CLUSTER.cold_start_penalty,
                 ctrl_latency_s=0.0),
        pol, telemetry=TEL)
    out = sc.run(wl)
    ref = simulate_ref(pol, CLUSTER, wl, telemetry=TEL)
    _assert_tel_equal(out.telemetry, ref.telemetry)


def test_summary_fields():
    wl = _wl(0.8)
    out = simulate(parse_policy("E/LL/PS"), CLUSTER, wl, telemetry=TEL)
    s = out.telemetry.summary()
    for k in ("n_observed", "slow_p50", "slow_p99", "lat_p50_s",
              "lat_p99_s", "n_cold", "n_warm", "cold_frac", "n_evict",
              "n_reject", "busy_time_s", "qlen_time_s",
              "decision_max_frac"):
        assert k in s, k
    assert s["slow_p50"] >= 1.0 - 0.02  # sketch slack around exact ≥1


# --------------------------------------------------------------------------
# plane 2: span tracing
# --------------------------------------------------------------------------

def test_tracer_spans_export_chrome_trace(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("outer", mode="test"):
        with tr.span("inner"):
            pass
    tr.instant("mark")
    tr.event_at("task", 1.5, 0.25, tid=2, cold=True)
    path = tmp_path / "trace.json"
    tr.export(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    names = [e["name"] for e in evs]
    assert "outer" in names and "inner" in names and "task" in names
    complete = [e for e in evs if e["ph"] == "X"]
    assert all(e["dur"] >= 0 for e in complete)
    task = next(e for e in evs if e["name"] == "task")
    assert task["ts"] == pytest.approx(1.5e6) \
        and task["dur"] == pytest.approx(0.25e6)
    agg = tr.aggregate()
    assert agg["outer"]["count"] == 1
    assert agg["outer"]["total_s"] >= agg["inner"]["total_s"]


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("x"):
        pass
    assert tr.events == []


def test_wall_split_from_aggregate():
    agg = {"engine.build": {"count": 2, "total_s": 1.0},
           "engine.first_run": {"count": 2, "total_s": 3.0},
           "engine.run": {"count": 10, "total_s": 5.0}}
    ws = wall_split_from_aggregate(agg)
    assert ws["builds"] == 2 and ws["runs"] == 10
    assert ws["compile_heavy_s"] == pytest.approx(4.0)
    assert ws["steady_state_s"] == pytest.approx(5.0)


# --------------------------------------------------------------------------
# plane 3: provenance + engine-cache integration
# --------------------------------------------------------------------------

def test_manifest_collects():
    m = collect_manifest(seeds={"base": 0}, args={"mode": "test"})
    d = m.as_dict()
    for k in ("git_sha", "python", "jax_version", "numpy_version",
              "devices", "started_at", "seeds", "args"):
        assert k in d, k
    assert d["seeds"] == {"base": 0}


def test_engine_cache_sees_telemetry():
    from repro.core.simulator import build_simulator
    pol = parse_policy("E/LL/PS")
    kw = dict(n_arrivals=16, n_functions=3)
    e_off = build_simulator(pol, CLUSTER, **kw)
    e_on = build_simulator(pol, CLUSTER, telemetry=TEL, **kw)
    e_on2 = build_simulator(pol, CLUSTER, telemetry=TEL, **kw)
    e_on3 = build_simulator(pol, CLUSTER,
                            telemetry=TEL._replace(warmup_frac=0.2),
                            **kw)
    assert e_off is not e_on
    assert e_on is e_on2
    assert e_on is not e_on3


def test_engine_cache_stats_counters():
    from repro.core.simulator import build_simulator
    stats0 = engine_cache_stats()
    pol = parse_policy("E/R/PS")
    kw = dict(n_arrivals=24, n_functions=3)
    build_simulator(pol, CLUSTER, **kw)   # miss (fresh key)
    build_simulator(pol, CLUSTER, **kw)   # hit
    stats1 = engine_cache_stats()
    assert stats1["misses"] >= stats0["misses"] + 1
    assert stats1["hits"] >= stats0["hits"] + 1
    for k in ("entries", "capacity", "hits", "misses", "evictions"):
        assert k in stats1, k
