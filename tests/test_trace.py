"""repro.trace — Azure-schema ingestion & non-stationary replay.

The acceptance contract: a workload synthesized via ``synth_trace`` →
Azure-schema CSV → ``schema.py`` → ``replay.py`` reproduces the
per-minute invocation counts *exactly* and the duration percentiles
within statistical tolerance.
"""
import math

import numpy as np
import pytest

from repro.core import ClusterCfg, HERMES, WORKLOADS, stack_workloads
from repro.trace import catalog
from repro.trace.cache import (clear_trace_cache, file_digest,
                               load_trace_cached, trace_cache_stats)
from repro.trace.replay import (fit_lognormal_from_percentiles,
                                per_minute_counts, replay_trace,
                                resample_workloads)
from repro.trace.schema import (AZURE_MU, AZURE_SIGMA, DURATION_COLUMNS,
                                load_trace, lognormal_percentiles_ms,
                                norm_ppf)
from repro.trace.synth_trace import (SCENARIOS, synthesize_trace,
                                     write_trace_csvs)

CLUSTER = ClusterCfg(n_workers=4, cores=12)


def _csv_pair(tmp_path, trace):
    inv = str(tmp_path / "inv.csv")
    dur = str(tmp_path / "dur.csv")
    write_trace_csvs(trace, inv, dur)
    return inv, dur


# ---------------------------------------------------------------- schema


def test_norm_ppf_matches_known_quantiles():
    # classic z-scores to 4 decimals
    assert abs(norm_ppf(0.5)) < 1e-12
    assert norm_ppf(0.975) == pytest.approx(1.959964, abs=1e-5)
    assert norm_ppf(0.99) == pytest.approx(2.326348, abs=1e-5)
    assert norm_ppf(0.01) == pytest.approx(-2.326348, abs=1e-5)


def test_schema_round_trips_exactly(tmp_path):
    trace = synthesize_trace("diurnal", n_functions=5, minutes=30,
                             total_invocations=500, seed=11)
    inv, dur = _csv_pair(tmp_path, trace)
    loaded = load_trace(inv, dur)
    assert loaded.minutes == trace.minutes
    assert loaded.n_functions == trace.n_functions
    np.testing.assert_array_equal(loaded.counts_matrix(),
                                  trace.counts_matrix())
    for a, b in zip(loaded.functions, trace.functions):
        assert a.key == b.key and a.trigger == b.trigger
        assert a.count == b.count
        # repr round trip keeps floats bit-exact
        assert a.duration_ms == b.duration_ms
        assert a.average_ms == b.average_ms


def _break_count_cell(line: str, value: str) -> str:
    cells = line.split(",")
    cells[-1] = value
    return ",".join(cells)


@pytest.mark.parametrize("breaker, match", [
    (lambda l: [l[0].replace("Trigger", "Trigr")] + l[1:], "header"),
    (lambda l: [l[0].replace(",3,", ",9,", 1)] + l[1:], "contiguous"),
    (lambda l: [l[0], _break_count_cell(l[1], "-3")] + l[2:], "negative"),
    (lambda l: [l[0], _break_count_cell(l[1], "x")] + l[2:],
     "non-integer"),
    (lambda l: l + [l[1]], "duplicate"),
])
def test_schema_rejects_malformed_invocations(tmp_path, breaker, match):
    trace = synthesize_trace("diurnal", n_functions=3, minutes=10,
                             total_invocations=200, seed=0)
    inv, dur = _csv_pair(tmp_path, trace)
    lines = open(inv).read().splitlines()
    broken = tmp_path / "broken.csv"
    broken.write_text("\n".join(breaker(lines)) + "\n")
    with pytest.raises(ValueError, match=match):
        load_trace(str(broken), dur)


def test_schema_rejects_nonmonotone_percentiles(tmp_path):
    trace = synthesize_trace("diurnal", n_functions=3, minutes=10,
                             total_invocations=200, seed=0)
    inv, dur = _csv_pair(tmp_path, trace)
    lines = open(dur).read().splitlines()
    cells = lines[1].split(",")
    p50_col = DURATION_COLUMNS.index("percentile_Average_50")
    p75_col = DURATION_COLUMNS.index("percentile_Average_75")
    cells[p50_col], cells[p75_col] = cells[p75_col], cells[p50_col]
    broken = tmp_path / "broken_dur.csv"
    broken.write_text("\n".join([lines[0], ",".join(cells)] + lines[2:])
                      + "\n")
    with pytest.raises(ValueError, match="non-decreasing"):
        load_trace(inv, str(broken))


def test_schema_missing_durations_strict_vs_default(tmp_path):
    trace = synthesize_trace("diurnal", n_functions=3, minutes=10,
                             total_invocations=200, seed=0)
    inv, dur = _csv_pair(tmp_path, trace)
    lines = open(dur).read().splitlines()
    short = tmp_path / "short_dur.csv"
    short.write_text("\n".join(lines[:-1]) + "\n")   # drop last function
    with pytest.raises(ValueError, match="no duration row"):
        load_trace(inv, str(short))
    loaded = load_trace(inv, str(short), allow_missing_durations=True)
    assert loaded.n_functions == 3
    filled = loaded.functions[-1]
    expect = lognormal_percentiles_ms(AZURE_MU, AZURE_SIGMA)
    assert filled.duration_ms == expect


# ---------------------------------------------------------------- replay


def test_replay_round_trip_counts_exact_and_percentiles_close(tmp_path):
    trace = synthesize_trace("diurnal", n_functions=6, minutes=90,
                             total_invocations=12000, seed=7)
    inv, dur = _csv_pair(tmp_path, trace)
    loaded = load_trace(inv, dur)
    wl = replay_trace(loaded, CLUSTER, seed=3)   # load=None: real time

    # per-minute invocation counts reproduce the trace EXACTLY
    counts = per_minute_counts(wl, loaded.n_functions, loaded.minutes)
    np.testing.assert_array_equal(counts, loaded.counts_matrix())
    # arrivals are sorted and non-negative
    assert (np.diff(wl.arrival) >= 0).all() and wl.arrival[0] >= 0

    # fitted Log-normal recovers the generating parameters exactly
    # (percentile columns were materialized analytically)
    for fn in loaded.functions:
        mu, sigma = fit_lognormal_from_percentiles(fn.duration_ms)
        assert 1000 * math.exp(mu) == pytest.approx(fn.duration_ms[50],
                                                    rel=1e-9)
    # empirical duration percentiles within tolerance of the trace's
    checked = 0
    for i, fn in enumerate(loaded.functions):
        svc_ms = wl.service[wl.func == i] * 1000.0
        if len(svc_ms) < 1500:
            continue
        for q, rel in ((50, 0.10), (75, 0.12)):
            assert np.percentile(svc_ms, q) == pytest.approx(
                fn.duration_ms[q], rel=rel), f"fn{i} p{q}"
        checked += 1
    assert checked >= 2  # the Zipf-hot functions qualify


def test_replay_load_targeting_and_tiling():
    trace = synthesize_trace("bursty", n_functions=8, minutes=40,
                             total_invocations=1000, seed=5)
    # n_arrivals > trace total forces whole-trace tiling
    wl = replay_trace(trace, CLUSTER, load=0.6, n_arrivals=3000, seed=1)
    assert wl.n == 3000
    realized = wl.service.sum() / (wl.horizon * CLUSTER.total_cores)
    assert realized == pytest.approx(0.6, rel=1e-9)
    assert (np.diff(wl.arrival) >= 0).all()
    # same seed -> identical replay; different seed -> different jitter
    wl2 = replay_trace(trace, CLUSTER, load=0.6, n_arrivals=3000, seed=1)
    np.testing.assert_array_equal(wl.arrival, wl2.arrival)
    wl3 = replay_trace(trace, CLUSTER, load=0.6, n_arrivals=3000, seed=2)
    assert not np.array_equal(wl.arrival, wl3.arrival)


def test_replay_rejects_empty_trace():
    import dataclasses
    trace = synthesize_trace("diurnal", n_functions=2, minutes=5,
                             total_invocations=400, seed=0)
    empty = dataclasses.replace(trace, functions=tuple(
        dataclasses.replace(f, counts=np.zeros_like(f.counts))
        for f in trace.functions))
    with pytest.raises(ValueError, match="zero invocations"):
        replay_trace(empty, CLUSTER)


def test_replay_falls_back_on_zero_percentile_rows():
    """Real Azure duration rows can be all-zero (Count=0 / sub-ms
    functions); replay substitutes the trace-wide Azure default instead
    of crashing."""
    import dataclasses
    trace = synthesize_trace("diurnal", n_functions=3, minutes=20,
                             total_invocations=600, seed=6)
    zeroed = dataclasses.replace(trace, functions=(
        dataclasses.replace(
            trace.functions[0], average_ms=0.0, minimum_ms=0.0,
            maximum_ms=0.0,
            duration_ms={p: 0.0 for p in trace.functions[0].duration_ms}),
        *trace.functions[1:]))
    wl = replay_trace(zeroed, CLUSTER, seed=1)
    assert np.isfinite(wl.service).all() and (wl.service > 0).all()
    # the zeroed function samples from the AZURE_MU/AZURE_SIGMA default
    svc0 = wl.service[wl.func == 0]
    assert len(svc0) > 0


def test_resample_workloads_mixed_shapes():
    t1 = synthesize_trace("diurnal", n_functions=4, minutes=30,
                          total_invocations=900, seed=1)
    t2 = synthesize_trace("bursty", n_functions=7, minutes=30,
                          total_invocations=1400, seed=2)
    w1 = replay_trace(t1, CLUSTER, seed=1)
    w2 = replay_trace(t2, CLUSTER, seed=2)
    assert w1.n != w2.n and w1.n_functions != w2.n_functions
    wb = resample_workloads([w1, w2])
    assert wb.n == min(w1.n, w2.n)
    assert wb.n_functions == 7
    # truncation preserves the prefix
    np.testing.assert_array_equal(wb.arrival[0], w1.arrival[:wb.n])
    with pytest.raises(ValueError, match="resample up"):
        resample_workloads([w1, w2], n=max(w1.n, w2.n) + 1)


def test_fixture_replay_recovers_reported_percentiles():
    """Real-dataset validation harness (ROADMAP): the bundled Azure
    fixture slice, loaded through the ``allow_missing_durations=True``
    join a real dataset slice needs, replays into duration percentiles
    within tolerance of the slice's *reported* ``percentile_Average_*``
    columns — the regression gate a full Azure day-slice run reuses."""
    from repro.trace.catalog import (FIXTURE_DURATIONS,
                                     FIXTURE_INVOCATIONS)
    trace = load_trace(FIXTURE_INVOCATIONS, FIXTURE_DURATIONS,
                       allow_missing_durations=True)
    wl = replay_trace(trace, CLUSTER, n_arrivals=25000, seed=11)
    checked = 0
    for i, fn in enumerate(trace.functions):
        svc_ms = wl.service[wl.func == i] * 1000.0
        if len(svc_ms) < 1000:
            continue
        assert np.percentile(svc_ms, 50) == pytest.approx(
            fn.duration_ms[50], rel=0.12), f"fn{i} p50"
        assert np.percentile(svc_ms, 75) == pytest.approx(
            fn.duration_ms[75], rel=0.18), f"fn{i} p75"
        checked += 1
    assert checked >= 4       # the fixture's hot functions qualify


def test_fixture_missing_duration_rows_fall_back_to_default(tmp_path):
    """The same join with duration rows genuinely missing (the real
    dataset's imperfect join): dropped functions sample the Azure
    default Log-normal, the rest keep their reported percentiles."""
    from repro.trace.catalog import (FIXTURE_DURATIONS,
                                     FIXTURE_INVOCATIONS)
    lines = open(FIXTURE_DURATIONS).read().splitlines()
    short = tmp_path / "short_dur.csv"
    short.write_text("\n".join(lines[:-2]) + "\n")   # drop 2 functions
    trace = load_trace(FIXTURE_INVOCATIONS, str(short),
                       allow_missing_durations=True)
    assert trace.n_functions == 12                   # join kept them all
    expect = lognormal_percentiles_ms(AZURE_MU, AZURE_SIGMA)
    for fn in trace.functions[-2:]:
        assert fn.duration_ms == expect
    wl = replay_trace(trace, CLUSTER, n_arrivals=8000, seed=3)
    assert np.isfinite(wl.service).all() and (wl.service > 0).all()
    # a kept function still matches its reported median
    svc0 = wl.service[wl.func == 0] * 1000.0
    assert np.percentile(svc0, 50) == pytest.approx(
        trace.functions[0].duration_ms[50], rel=0.15)


# ---------------------------------------------------------------- cache


def test_trace_cache_hits_on_digest(tmp_path):
    clear_trace_cache()
    trace = synthesize_trace("diurnal", n_functions=3, minutes=10,
                             total_invocations=300, seed=4)
    inv, dur = _csv_pair(tmp_path, trace)
    a = load_trace_cached(inv, dur)
    b = load_trace_cached(inv, dur)
    assert a is b
    assert trace_cache_stats()["hits"] == 1
    # identical bytes under a different path still hit
    inv2 = tmp_path / "copy.csv"
    inv2.write_bytes(open(inv, "rb").read())
    assert file_digest(str(inv2)) == file_digest(inv)
    assert load_trace_cached(str(inv2), dur) is a
    # rewritten content re-parses
    other = synthesize_trace("diurnal", n_functions=3, minutes=10,
                             total_invocations=300, seed=9)
    write_trace_csvs(other, inv, dur)
    c = load_trace_cached(inv, dur)
    assert c is not a
    clear_trace_cache()


# --------------------------------------------------------------- catalog


def test_trace_scenarios_merged_into_workloads():
    for name in catalog.TRACE_SCENARIOS:
        assert name in WORKLOADS
    assert set(SCENARIOS) == {"diurnal", "bursty", "cold-heavy",
                              "flash-crowd"}


@pytest.mark.parametrize("name", sorted(catalog.TRACE_SCENARIOS))
def test_catalog_scenarios_meet_workload_contract(name):
    wl = WORKLOADS[name](CLUSTER, 0.7, 600, 1)
    assert wl.n == 600
    realized = wl.service.sum() / (wl.horizon * CLUSTER.total_cores)
    assert realized == pytest.approx(0.7, rel=1e-9)
    assert (np.diff(wl.arrival) >= 0).all()
    # stackable across loads and seeds (shared (N, F))
    wb = stack_workloads([wl, WORKLOADS[name](CLUSTER, 0.4, 600, 2)])
    assert wb.n_reps == 2


def test_trace_scenario_through_batched_engine():
    from repro.core.simulator import simulate_many
    cl = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)
    wls = [WORKLOADS["azure-diurnal"](cl, load, 250, seed)
           for load, seed in ((0.5, 0), (0.8, 1))]
    out = simulate_many(HERMES, cl, wls)
    assert out.n_reps == 2
    assert np.isfinite(out.response).all()
    assert (out.response >= np.stack([wl.service for wl in wls])
            - 1e-9).all()
