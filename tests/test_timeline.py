"""repro.telemetry.timeline contract tests.

The windowed flight-recorder plane's obligations:

* enabling it never perturbs the simulation (python-gated carry — the
  timeline-off program is bit-identical);
* per-window planes are exact: numpy oracle ≡ jax scan (integer planes
  bitwise, f64 integrals to 1e-9) and chunked stream ≡ monolithic
  bitwise, including a padded final chunk whose window accumulators
  merge across the boundary;
* sketch pooling edge cases (empty windows, single-completion windows)
  read sanely;
* the bounded decision log replays the autoscaler's exact ``n_on``
  trajectory, and truncation is *visible*, never silent;
* config and warmup contracts fail with named errors;
* the exporters (CSV, OpenMetrics, Perfetto counter tracks) emit
  well-formed output.
"""
import json

import numpy as np
import pytest

from repro.core import ClusterCfg, FleetCfg, parse_policy, synth_workload
from repro.core.metrics import summarize_batch_sim, summarize_sim
from repro.core.sim_ref import simulate_ref
from repro.core.simulator import simulate, simulate_many
from repro.core.streaming import simulate_stream
from repro.core.workload import stack_workloads
from repro.telemetry import (TelemetryCfg, TimelineCfg, TimelineResult,
                             Tracer, WarmupMismatchError, auto_window_s,
                             coarse_edges, validate_timeline,
                             window_index_np)
from repro.telemetry.timeline import init_tl_np, tl_on_complete_np

CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)
TL = TimelineCfg(n_windows=16, coarse_bins=96, max_events=64)
AUTO_CLUSTER = CLUSTER._replace(
    fleet=FleetCfg(preset="two-gen", autoscale="TARGET_P99",
                   min_workers=2, target_p99=4.0, cooldown_s=2.0))

_INT = ("mode", "arrivals", "n_cold", "n_warm", "n_evict", "n_reject",
        "slow_hist", "lat_hist", "n_on", "ev_kind", "ev_val", "ev_count")
_FLT = ("window_s", "busy_time", "qlen_time", "prov_core", "ev_t",
        "ev_p99")


def _wl(load, n=200, seed=0):
    return synth_workload(CLUSTER, load, n, n_functions=5,
                          hot_fraction=0.8, seed=seed)


def _assert_tl_equal(a: TimelineResult, b: TimelineResult,
                     bitwise_float: bool):
    for name in _INT:
        assert np.array_equal(getattr(a, name), getattr(b, name)), name
    for name in _FLT:
        u = np.asarray(getattr(a, name), dtype=np.float64)
        v = np.asarray(getattr(b, name), dtype=np.float64)
        if bitwise_float:
            assert np.array_equal(u, v, equal_nan=True), name
        else:
            np.testing.assert_allclose(u, v, rtol=1e-9, atol=1e-9,
                                       err_msg=name)


# --------------------------------------------------------------------------
# config + warmup contracts: named errors
# --------------------------------------------------------------------------

def test_validate_timeline_named_errors():
    with pytest.raises(ValueError, match="n_windows"):
        validate_timeline(TimelineCfg(n_windows=0))
    with pytest.raises(ValueError, match="max_events"):
        validate_timeline(TimelineCfg(max_events=0))
    with pytest.raises(ValueError, match="coarse_bins"):
        validate_timeline(TimelineCfg(coarse_bins=100))  # 1536 % 100 != 0
    cfg = TimelineCfg()
    assert validate_timeline(cfg) is cfg


def test_warmup_mismatch_is_a_named_error():
    wl = _wl(0.6)
    out = simulate(parse_policy("E/LL/PS"), CLUSTER, wl, backend="jax",
                   telemetry=TelemetryCfg(warmup_frac=0.2))
    with pytest.raises(WarmupMismatchError) as ei:
        summarize_sim(out, wl, warmup_frac=0.1)
    assert ei.value.engine_frac == 0.2
    assert ei.value.summarize_frac == 0.1
    # the matching cutoff summarizes fine
    summarize_sim(out, wl, warmup_frac=0.2)
    # batch twin
    wb = stack_workloads([_wl(0.6), _wl(0.8, seed=1)])
    outb = simulate_many(parse_policy("E/LL/PS"), CLUSTER, wb,
                         telemetry=TelemetryCfg(warmup_frac=0.2))
    with pytest.raises(WarmupMismatchError):
        summarize_batch_sim(outb, wb)        # default 0.1 != 0.2
    summarize_batch_sim(outb, wb, warmup_frac=0.2)


# --------------------------------------------------------------------------
# the timeline never perturbs the simulation
# --------------------------------------------------------------------------

def test_timeline_off_is_bit_identical():
    wl = _wl(0.8)
    pol = parse_policy("E/H/PS")
    base = simulate(pol, CLUSTER, wl, backend="jax")
    on = simulate(pol, CLUSTER, wl, backend="jax", timeline=TL)
    assert np.array_equal(base.response, on.response, equal_nan=True)
    assert np.array_equal(base.cold, on.cold)
    assert np.array_equal(base.worker, on.worker)
    assert base.timeline is None and on.timeline is not None


# --------------------------------------------------------------------------
# exactness: np oracle ≡ jax scan ≡ chunked stream
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["E/LL/PS", "E/H/PS", "L/LL/FCFS"])
def test_np_jax_parity(spec):
    pol = parse_policy(spec)
    wl = _wl(0.9)
    ref = simulate_ref(pol, CLUSTER, wl, telemetry=TelemetryCfg(),
                       timeline=TL)
    jx = simulate(pol, CLUSTER, wl, backend="jax",
                  telemetry=TelemetryCfg(), timeline=TL)
    _assert_tl_equal(ref.timeline, jx.timeline, bitwise_float=False)
    # the auto window width is the same IEEE division on all engines
    assert float(jx.timeline.window_s) == \
        auto_window_s(float(wl.arrival[-1]), TL)


def test_stream_padded_final_chunk_merges_windows():
    # 100 % 48 != 0 — the final chunk is padded; window accumulators
    # must hand across the boundary and ignore the dead tail steps
    pol = parse_policy("E/LL/PS")
    wls = [_wl(0.7, n=100), _wl(1.0, n=100, seed=1)]
    wb = stack_workloads(wls)
    mono = simulate_many(pol, CLUSTER, wb, backend="jax",
                         telemetry=TelemetryCfg(), timeline=TL)
    out = simulate_stream(pol, CLUSTER, wb, chunk_size=48,
                          backend="jax", telemetry=TelemetryCfg(),
                          timeline=TL)
    assert out.n_chunks == 3
    _assert_tl_equal(out.timeline, mono.timeline, bitwise_float=True)


# --------------------------------------------------------------------------
# sketch pooling edge cases
# --------------------------------------------------------------------------

def test_empty_and_single_completion_windows():
    cfg = TimelineCfg(n_windows=4, coarse_bins=96)
    tl = init_tl_np(2, cfg, window_s=10.0)
    tl_on_complete_np(tl, 5.0, response_s=2.0, service_s=1.0)  # window 0
    res = TimelineResult.from_state(tl, cfg=cfg)
    # single completion: both percentiles read the same (only) bin,
    # whose geometric midpoint brackets the true value
    p50, p99 = res.slow_percentile(0, 50), res.slow_percentile(0, 99)
    assert p50 == p99
    edges = coarse_edges(cfg)
    assert edges[0] <= p50 <= edges[-1]
    assert abs(p50 - 2.0) / 2.0 < 0.2     # coarse-bin quantization only
    # empty windows: NaN percentile, zero counters — never a crash
    assert np.isnan(res.slow_percentile(1, 99))
    assert np.isnan(res.lat_percentile(3, 50))
    assert int(res.arrivals.sum()) == 0
    rows = res.to_rows()
    assert len(rows) == 4


def test_window_index_clips_and_degenerate_width():
    assert window_index_np(0.0, 10.0, 4) == 0
    assert window_index_np(39.9, 10.0, 4) == 3
    assert window_index_np(1e9, 10.0, 4) == 3      # clipped, never OOB
    assert window_index_np(5.0, 0.0, 4) == 0       # degenerate width


# --------------------------------------------------------------------------
# decision log: exact replay + visible truncation
# --------------------------------------------------------------------------

def _auto_out(max_events=64, n=400):
    wl = synth_workload(AUTO_CLUSTER, 0.9, n, n_functions=5, seed=2)
    cfg = TimelineCfg(n_windows=16, coarse_bins=96,
                      max_events=max_events)
    return simulate(parse_policy("E/LL/PS"), AUTO_CLUSTER, wl,
                    backend="jax", telemetry=TelemetryCfg(),
                    timeline=cfg)


def test_decision_log_replays_n_on_exactly():
    out = _auto_out()
    tl = out.timeline
    evs = tl.events()
    auto_evs = [e for e in evs if e["kind"] == "autoscale"]
    assert auto_evs, "autoscaler never acted — scenario too tame"
    assert all(np.isfinite(e["sensor_p99"]) for e in auto_evs)
    rep = tl.replay_n_on(AUTO_CLUSTER.n_workers)
    mask = np.asarray(tl.arrivals) > 0
    assert np.array_equal(rep[mask], np.asarray(tl.n_on)[mask])


def test_decision_log_truncation_is_visible():
    out = _auto_out(max_events=1)
    tl = out.timeline
    # the counter keeps counting past the buffer — truncation shows
    assert int(tl.ev_count) > 1
    with pytest.raises(ValueError, match="truncated"):
        tl.replay_n_on(AUTO_CLUSTER.n_workers)


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------

def test_exporters_roundtrip(tmp_path):
    out = _auto_out()
    tl = out.timeline
    csv_p = tl.write_csv(str(tmp_path / "tl.csv"))
    with open(csv_p) as f:
        header = f.readline()
        n_lines = sum(1 for _ in f)
    assert "window" in header and "arrivals" in header
    assert n_lines == tl.n_windows
    om_p = tl.write_openmetrics(str(tmp_path / "tl.om"))
    om = open(om_p).read()
    assert om.rstrip().endswith("# EOF")
    assert "repro_timeline_arrivals_total" in om
    s = tl.summary()
    assert s["arrivals_total"] == 400
    assert s["n_events"] == int(tl.ev_count)
    json.dumps(s)                       # JSON-friendly digest
    tr = Tracer(enabled=True)
    tl.emit_counters(tr)
    trace_p = str(tmp_path / "trace.json")
    tr.export(trace_p)
    evs = json.load(open(trace_p))["traceEvents"]
    counters = [e for e in evs if e.get("ph") == "C"]
    assert any(e["name"] == "timeline.arrivals" for e in counters)
