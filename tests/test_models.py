"""Per-architecture smoke tests (reduced configs, CPU) + model math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.common import MoECfg
from repro.models.transformer import build_model

RNG = jax.random.key(0)


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_arch_smoke(arch):
    """One forward + one train-grad step: shapes right, finite values."""
    cfg = configs.get_smoke(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits, aux = jax.jit(model.forward)(params, toks)
    assert logits.shape == (B, S, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(
        params, toks, jnp.roll(toks, -1, 1))
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g, np.float32)).all()


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_prefill_decode_consistency(arch):
    """prefill(t[:n]) then decode one-by-one ≡ forward(t) logits.

    Run in f32 so this checks *mathematical* equivalence of the serving
    path (incl. MLA weight absorption, WKV/SSD chunked-vs-step scans)
    rather than bf16 noise between the two orderings.
    """
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(RNG)
    B, S, n_dec = 1, 24, 4
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab)
    full_logits, _ = jax.jit(model.forward)(params, toks)

    pre = S - n_dec
    cache = model.init_cache(B, S + 1)
    lg, cache = jax.jit(model.prefill)(params, toks[:, :pre], cache)
    np.testing.assert_allclose(
        np.asarray(lg[:, -1], np.float32),
        np.asarray(full_logits[:, pre - 1], np.float32),
        rtol=2e-4, atol=2e-4)
    decode = jax.jit(model.decode_step)
    for i in range(n_dec):
        pos = jnp.full((B,), pre + i, jnp.int32)
        lg, cache = decode(params, toks[:, pre + i:pre + i + 1], cache, pos)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full_logits[:, pre + i], np.float32),
            rtol=2e-4, atol=2e-4, err_msg=f"{arch} decode step {i}")


def test_full_configs_match_assignment():
    """The exact numbers from the assignment table."""
    t = {n: configs.get(n) for n in configs.ARCH_NAMES}
    a = t["rwkv6-3b"]
    assert (a.n_layers, a.d_model, a.d_ff, a.vocab) == \
        (32, 2560, 8960, 65536)
    a = t["qwen3-14b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab) == (40, 5120, 40, 8, 17408, 151936)
    assert a.qk_norm
    a = t["olmo-1b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.d_ff, a.vocab) == \
        (16, 2048, 16, 8192, 50304)
    assert a.norm == "layernorm_np"
    a = t["granite-20b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab) == (52, 6144, 48, 1, 24576, 49152)
    a = t["gemma-2b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.head_dim,
            a.d_ff, a.vocab) == (18, 2048, 8, 1, 256, 16384, 256000)
    assert a.mlp == "geglu"
    a = t["zamba2-2.7b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.d_ff, a.vocab,
            a.ssm.d_state) == (54, 2560, 32, 10240, 32000, 64)
    a = t["musicgen-large"]
    assert (a.n_layers, a.d_model, a.n_heads, a.d_ff, a.vocab) == \
        (48, 2048, 32, 8192, 2048)
    a = t["deepseek-v2-236b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.vocab) == \
        (60, 5120, 128, 102400)
    assert (a.moe.n_experts, a.moe.top_k, a.moe.d_ff_expert,
            a.moe.n_shared) == (160, 6, 1536, 2)
    assert (a.mla.kv_lora, a.mla.qk_rope) == (512, 64)
    a = t["dbrx-132b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.vocab) == \
        (40, 6144, 48, 8, 100352)
    assert (a.moe.n_experts, a.moe.top_k) == (16, 4)
    a = t["chameleon-34b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab) == (48, 8192, 64, 8, 22016, 65536)


def test_param_counts_plausible():
    """n_params() bookkeeping lands near the advertised sizes."""
    expect = {"rwkv6-3b": (2.5e9, 4.5e9), "qwen3-14b": (12e9, 16e9),
              "olmo-1b": (0.9e9, 1.6e9), "granite-20b": (17e9, 23e9),
              "gemma-2b": (2.0e9, 3.3e9), "zamba2-2.7b": (2.2e9, 3.4e9),
              "musicgen-large": (2.2e9, 4e9),
              "deepseek-v2-236b": (200e9, 260e9),
              "dbrx-132b": (110e9, 150e9), "chameleon-34b": (28e9, 40e9)}
    for name, (lo, hi) in expect.items():
        n = configs.get(name).n_params()
        assert lo < n < hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_active_params_moe():
    c = configs.get("deepseek-v2-236b")
    assert c.active_params() < 0.15 * c.n_params()


def test_moe_ep_capacity_dense_parity():
    """EP (sorted dispatch) ≡ dense oracle when capacity never binds —
    single-device path (no mesh ctx → falls back to dense); the sharded
    parity is covered in test_distributed.py."""
    from repro.models import moe as moe_mod
    cfg = dataclasses.replace(
        configs.get_smoke("dbrx-132b"),
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=64,
                   capacity_factor=8.0))
    p = moe_mod.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y1, a1 = moe_mod.moe_dense(cfg, p, x)
    y2, a2 = moe_mod.moe_ep(cfg, p, x)       # no ctx → dense fallback
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_smoke_unroll_matches_scan():
    cfg = configs.get_smoke("olmo-1b")
    m_scan = build_model(cfg, layer_mode="scan")
    m_unroll = build_model(cfg, layer_mode="unroll")
    params = m_scan.init(RNG)
    toks = jax.random.randint(jax.random.key(3), (1, 16), 0, cfg.vocab)
    l1, _ = jax.jit(m_scan.forward)(params, toks)
    l2, _ = jax.jit(m_unroll.forward)(params, toks)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-2, atol=1e-2)


def test_attn_impls_agree():
    import dataclasses as dc
    cfg = configs.get_smoke("qwen3-14b")
    variants = {}
    toks = jax.random.randint(jax.random.key(4), (1, 64), 0, cfg.vocab)
    for impl in ("naive", "xla_chunked", "xla_unrolled", "pallas"):
        c = dc.replace(cfg, attn_impl=impl, attn_chunk=16, head_dim=32)
        m = build_model(c)
        if impl == "naive":
            params = m.init(RNG)
            variants["params"] = params
        logits, _ = jax.jit(m.forward)(variants["params"], toks)
        variants[impl] = np.asarray(logits, np.float32)
    for impl in ("xla_chunked", "xla_unrolled", "pallas"):
        np.testing.assert_allclose(variants[impl], variants["naive"],
                                   rtol=6e-2, atol=6e-2, err_msg=impl)
