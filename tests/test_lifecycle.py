"""Container-lifecycle subsystem: registry contract, np ≡ jax parity,
golden engine agreement, and the bit-for-bit default regression.

The acceptance contract of the lifecycle axis:

* ``ClusterCfg()`` (no lifecycle) reproduces the pre-lifecycle results
  bit-for-bit — locked against golden values captured from the seed
  engines;
* with a lifecycle configured, ``simulate ≡ simulate_ref ≡
  simulate_many`` task-by-task (the same golden contract the policy
  registry satisfies), for stateless (``FIXED_TTL``) and carried-state
  (``HYBRID_HIST``) keep-alive policies alike;
* the registry is open: a custom keep-alive registered in ~20 lines
  runs through both engines in agreement.
"""
import numpy as np
import pytest

from repro.core import (ClusterCfg, E_LL_PS, HERMES, LATE_BINDING,
                        LifecycleCfg, synth_workload)
from repro.core.sim_ref import simulate_ref
from repro.core.simulator import simulate, simulate_many
from repro.lifecycle import (LifecycleRuntime, cold_costs_for,
                             get_keepalive, parse_cold_preset,
                             parse_keepalive, register_keepalive,
                             resolve_lifecycle, unregister_keepalive)

CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2,
                     cold_start_penalty=0.25)


def _wl(load=0.9, n=300, seed=7):
    return synth_workload(CLUSTER, load, n, n_functions=5,
                          hot_fraction=0.8, seed=seed)


def _life(keepalive="FIXED_TTL", **kw):
    return CLUSTER._replace(lifecycle=LifecycleCfg(keepalive=keepalive,
                                                   **kw))


def _agree(policy, cluster, wl):
    """simulate ≡ simulate_ref ≡ simulate_many, task-by-task."""
    out = simulate(policy, cluster, wl)
    ref = simulate_ref(policy, cluster, wl)
    np.testing.assert_array_equal(out.worker, ref.worker)
    np.testing.assert_array_equal(out.cold, ref.cold)
    np.testing.assert_array_equal(out.rejected, ref.rejected)
    np.testing.assert_allclose(
        np.nan_to_num(out.response, nan=-1.0),
        np.nan_to_num(ref.response, nan=-1.0), atol=1e-9)
    batch = simulate_many(policy, cluster, [wl, wl])
    np.testing.assert_array_equal(
        np.nan_to_num(batch.response[0], nan=-1.0),
        np.nan_to_num(out.response, nan=-1.0))
    np.testing.assert_array_equal(batch.response[0], batch.response[1])
    return out


# --------------------------------------------------------------- golden


# Captured from the seed engines (pre-lifecycle code) on _wl() above:
# (policy, sum of responses, n cold starts).
_GOLDEN = [
    (HERMES, 1216.6925067819345, 48),
    (E_LL_PS, 1213.6759411691799, 53),
    (LATE_BINDING, 1217.1144495097842, 38),
]


@pytest.mark.parametrize("policy,resp_sum,n_cold", _GOLDEN,
                         ids=lambda v: str(v))
def test_default_reproduces_seed_results_bit_for_bit(policy, resp_sum,
                                                     n_cold):
    """lifecycle=None must not perturb the pre-lifecycle engines."""
    wl = _wl()
    out = simulate(policy, CLUSTER, wl)
    assert float(np.nansum(out.response)) == pytest.approx(resp_sum,
                                                           rel=1e-12)
    assert int(out.cold.sum()) == n_cold
    ref = simulate_ref(policy, CLUSTER, wl)
    assert float(np.nansum(ref.response)) == pytest.approx(resp_sum,
                                                           rel=1e-9)
    assert int(ref.cold.sum()) == n_cold


def test_lifecycle_configs_change_results():
    wl = _wl()
    base = simulate(HERMES, CLUSTER, wl)
    ttl = simulate(HERMES, _life(ttl_s=3.0), wl)
    none = simulate(HERMES, _life("NONE"), wl)
    hyb = simulate(HERMES, _life("HYBRID_HIST", ttl_s=3.0), wl)
    # finite keep-alive can only add cold starts vs keep-forever
    assert int(ttl.cold.sum()) > int(base.cold.sum())
    assert int(none.cold.sum()) == wl.n          # everything cold
    assert int(hyb.cold.sum()) > int(base.cold.sum())
    assert not np.array_equal(ttl.cold, hyb.cold)


# ------------------------------------------------- golden engine parity


@pytest.mark.parametrize("policy", [HERMES, E_LL_PS, LATE_BINDING],
                         ids=lambda p: p.name)
@pytest.mark.parametrize("keepalive", ["NONE", "FIXED_TTL", "HYBRID_HIST"])
def test_golden_engine_agreement(policy, keepalive):
    """Vectorized scan ≡ numpy oracle ≡ batched vmap under lifecycle,
    including warm-pool budget pressure and a cold-start preset."""
    cl = _life(keepalive, ttl_s=3.0, max_idle=2, coldstart="openwhisk")
    for load, seed in ((0.5, 0), (0.9, 1), (1.3, 2)):
        _agree(policy, cl, _wl(load, 300, seed))


def test_golden_agreement_with_stateful_balancer():
    """Lifecycle carry composes with balancer carry (DD's EMA state and
    HYBRID_HIST's histograms thread the same scan together)."""
    from repro.core import E_DD_PS
    cl = _life("HYBRID_HIST", ttl_s=3.0, max_idle=2)
    _agree(E_DD_PS, cl, _wl(0.9, 300, 3))


# --------------------------------------------------- registry contract


def test_register_custom_keepalive_end_to_end():
    """The keep-alive contract is open: a per-function tiered TTL
    registered in ~20 lines runs through both engines in agreement (the
    README 'custom keep-alive in 20 lines' shape)."""
    def make_np(cfg, n_functions):
        keep = np.where(np.arange(n_functions) % 2 == 0,
                        2.0 * cfg.ttl_s, 0.25 * cfg.ttl_s)
        pre = np.zeros(n_functions)

        def windows(state):
            return pre, keep
        return windows, None

    def make_jax(cfg, n_functions):
        import jax.numpy as jnp
        keep = jnp.where(jnp.arange(n_functions) % 2 == 0,
                         2.0 * cfg.ttl_s, 0.25 * cfg.ttl_s)
        pre = jnp.zeros(n_functions)

        def windows(state):
            return pre, keep
        return windows, None

    register_keepalive("TIERED", make_np=make_np, make_jax=make_jax,
                       doc="even fns 2x TTL, odd fns 0.25x")
    try:
        assert parse_keepalive("tiered") == "TIERED"
        cl = _life("TIERED", ttl_s=2.0)
        out = _agree(HERMES, cl, _wl(0.8, 300, 5))
        # the tiering is visible: the generous-TTL class cold-starts
        # less often per invocation than the stingy one
        wl = _wl(0.8, 300, 5)
        even = wl.func % 2 == 0
        assert out.cold[even].mean() < out.cold[~even].mean()
    finally:
        unregister_keepalive("TIERED")


def test_early_builtin_name_collision_fails_fast_without_wedging():
    """Registering a built-in name as the process's FIRST registry
    touch must fail at the call site (built-ins are loaded first), not
    succeed silently and wedge the deferred built-in import.  Needs a
    fresh interpreter: once built-ins have loaded in-process, the
    pre-load state cannot be reconstructed (the package keeps the
    ``policies`` submodule attribute)."""
    import subprocess
    import sys
    code = (
        "from repro.lifecycle import register_keepalive, keepalive_names\n"
        "try:\n"
        "    register_keepalive('FIXED_TTL',\n"
        "                       make_np=lambda cfg, F: (None, None))\n"
        "except ValueError as e:\n"
        "    assert 'already registered' in str(e), e\n"
        "else:\n"
        "    raise SystemExit('collision not detected')\n"
        "names = set(keepalive_names())\n"
        "assert {'NONE', 'FIXED_TTL', 'HYBRID_HIST'} <= names, names\n"
        "print('OK')\n")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
                              "PATH": "/usr/bin:/bin:/usr/local/bin"},
                         cwd=str(__import__("pathlib").Path(
                             __file__).resolve().parent.parent))
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def test_registry_named_errors_and_backends():
    with pytest.raises(ValueError, match="unknown keep-alive.*FIXED_TTL"):
        parse_keepalive("NOPE")
    with pytest.raises(ValueError, match="unknown cold-start preset"):
        parse_cold_preset("NOPE")
    assert parse_cold_preset("scalar") == "scalar"
    ka = get_keepalive("HYBRID_HIST")
    assert ka.stateful and ka.backends() == ("np", "jax")
    assert not get_keepalive("FIXED_TTL").stateful
    with pytest.raises(ValueError, match="already registered"):
        register_keepalive("FIXED_TTL", make_np=lambda cfg, F: (None, None))
    # unknown names inside a cluster config surface the same error
    with pytest.raises(ValueError, match="unknown keep-alive"):
        resolve_lifecycle(_life("GHOST"), backend="np", n_functions=4)


def test_resolved_lifecycle_shape():
    res = resolve_lifecycle(_life(ttl_s=9.0, max_idle=3,
                                  coldstart="aws-lambda"),
                            backend="np", n_functions=6)
    assert res.max_idle == 3 and res.cold_costs.shape == (6,)
    assert res.observe is None           # FIXED_TTL is stateless
    pre, keep = res.windows(None)
    assert np.all(pre == 0.0) and np.all(keep == 9.0)
    assert resolve_lifecycle(CLUSTER, backend="np", n_functions=6) is None


# ------------------------------------------------- np ≡ jax state parity


def test_hybrid_hist_windows_bitwise_parity():
    """Per-step state parity: the same observation sequence drives the
    np and jax HYBRID_HIST backends to bitwise-identical windows."""
    import jax.numpy as jnp
    cfg = LifecycleCfg(keepalive="HYBRID_HIST", ttl_s=4.0)
    ka = get_keepalive("HYBRID_HIST")
    wn, on = ka.make_np(cfg, 3)
    wj, oj = ka.make_jax(cfg, 3)
    s_np = ka.init_state(cfg, 2, 3)
    s_jax = {k: jnp.asarray(v) for k, v in ka.init_state(cfg, 2, 3).items()}
    rng = np.random.default_rng(0)
    for i in range(200):
        f = int(rng.integers(0, 3))
        gap = float(rng.exponential(3.0))
        s_np = on(s_np, f, gap)
        s_jax = oj(s_jax, f, gap)
        pre_n, keep_n = wn(s_np)
        pre_j, keep_j = wj(s_jax)
        np.testing.assert_array_equal(pre_n, np.asarray(pre_j))
        np.testing.assert_array_equal(keep_n, np.asarray(keep_j))
    np.testing.assert_array_equal(s_np["hist"], np.asarray(s_jax["hist"]))


# ------------------------------------------------ cold-start presets


def test_cold_presets_deterministic_per_function():
    a = cold_costs_for("aws-lambda", 16)
    b = cold_costs_for("aws-lambda", 16)
    np.testing.assert_array_equal(a, b)           # process-stable
    assert len(np.unique(a)) > 1                  # per-function spread
    assert (a > 0).all()
    assert cold_costs_for("scalar", 16) is None
    np.testing.assert_array_equal(cold_costs_for("paper-sim", 4),
                                  np.zeros(4))


def test_cold_preset_latencies_golden_locked():
    """Value-lock the CRC32-seeded preset draws: the seed path is
    ``default_rng(crc32(name))`` with no ``hash()`` salting, so every
    process, platform and backend must see exactly these latencies —
    a silent reseed would quietly shift every lifecycle benchmark."""
    golden = {
        "aws-lambda": [0.26241618965687286, 0.4676961322876191,
                       0.9339690225462384, 0.1162548297360505,
                       0.14245870186250864, 0.2965521275249738],
        "azure-functions": [0.09681418277487916, 0.7245567309094818,
                            1.5524833470747126, 0.11443851318457184,
                            0.4532842308332041, 0.13544235566618817],
    }
    for preset, want in golden.items():
        np.testing.assert_allclose(cold_costs_for(preset, 6), want,
                                   rtol=1e-12)
        # a longer vector keeps the same per-function prefix draws?  No:
        # the generator is re-seeded per call, so the prefix IS stable
        np.testing.assert_allclose(cold_costs_for(preset, 12)[:6], want,
                                   rtol=1e-12)
    np.testing.assert_array_equal(cold_costs_for("openwhisk", 6),
                                  np.full(6, 0.5))


def test_preset_costs_charged_by_engines():
    wl = _wl(0.6, 250, 2)
    cheap = simulate(HERMES, _life(ttl_s=2.0, coldstart="paper-sim"), wl)
    dear = simulate(HERMES, _life(ttl_s=2.0, coldstart="openwhisk"), wl)
    assert np.nansum(dear.response) > np.nansum(cheap.response)


# ----------------------------------------- budget / eviction semantics


def test_max_idle_budget_enforced_lru():
    cl = ClusterCfg(n_workers=2, cores=2, capacity_factor=4,
                    lifecycle=LifecycleCfg(ttl_s=100.0, max_idle=2))
    res = resolve_lifecycle(cl, backend="np", n_functions=5)
    rt = LifecycleRuntime(res, 2, 5)
    warm = np.zeros((2, 5), dtype=np.int64)
    for f, t in ((0, 1.0), (1, 2.0), (2, 3.0)):
        rt.on_complete(warm, 0, f, t)
    # budget 2: the third completion LRU-evicted fn 0 (oldest)
    assert warm[0].tolist() == [0, 1, 1, 0, 0]
    # tie-break on equal idle_since goes to the lowest function id
    rt2 = LifecycleRuntime(res, 2, 5)
    warm2 = np.zeros((2, 5), dtype=np.int64)
    rt2.idle_since[1, 3] = 5.0
    rt2.idle_since[1, 4] = 5.0
    warm2[1, 3] = warm2[1, 4] = 1
    assert rt2.evict_victim(warm2[1], 1, 6.0) == 3


def test_budget_changes_simulation():
    wl = _wl(0.9, 300, 4)
    loose = simulate(HERMES, _life(ttl_s=50.0), wl)
    tight = simulate(HERMES, _life(ttl_s=50.0, max_idle=1), wl)
    assert int(tight.cold.sum()) > int(loose.cold.sum())


# --------------------------------------------------- serving platform


def test_serving_platform_matches_oracle_under_lifecycle():
    from repro.serving.engine import ServeCfg, ServingCluster
    wl = _wl(0.7, 300, 3)
    for ka in ("FIXED_TTL", "HYBRID_HIST"):
        cl = _life(ka, ttl_s=3.0, max_idle=2, coldstart="aws-lambda")
        cfg0 = ServeCfg(cluster=cl, cold_start_s=0.0, ctrl_latency_s=0.0)
        sv = ServingCluster(cfg0, HERMES).run(wl)
        rf = simulate_ref(HERMES, cl, wl)
        np.testing.assert_array_equal(sv.worker, rf.worker)
        np.testing.assert_array_equal(sv.cold, rf.cold)


def test_lifecycle_from_flags_cli_semantics():
    """The CLI helper: all-defaults -> None (legacy, bit-for-bit);
    preset or budget alone -> infinite window (no surprise expiry);
    explicit keep-alive -> the requested window; names validated."""
    import math
    from repro.lifecycle import lifecycle_from_flags
    assert lifecycle_from_flags() is None
    lc = lifecycle_from_flags(coldstart="openwhisk")
    assert lc.keepalive == "FIXED_TTL" and lc.ttl_s == math.inf
    lc = lifecycle_from_flags(max_idle=4)
    assert lc.ttl_s == math.inf and lc.max_idle == 4
    lc = lifecycle_from_flags("hybrid_hist", 30.0, 2, "aws-lambda")
    assert lc == LifecycleCfg("HYBRID_HIST", 30.0, 2, "aws-lambda")
    with pytest.raises(ValueError, match="unknown keep-alive"):
        lifecycle_from_flags("NOPE")
    with pytest.raises(ValueError, match="unknown cold-start preset"):
        lifecycle_from_flags(coldstart="NOPE")
    # the infinite window runs through the engines in parity
    wl = _wl(0.8, 250, 1)
    cl = CLUSTER._replace(lifecycle=lifecycle_from_flags(
        coldstart="openwhisk"))
    _agree(HERMES, cl, wl)


def test_inprocess_worker_keepalive_expiry():
    from repro.serving.backends import InProcessWorker
    w = InProcessWorker(registry=None, keepalive_s=5.0)
    w.warm = {"a": object(), "b": object()}
    w.lru = ["a", "b"]
    w.idle_since = {"a": 10.0, "b": 14.0}
    assert w.expire_idle(now=16.0) == 1          # 'a' idle 6s > 5s
    assert list(w.warm) == ["b"] and w.lru == ["b"]
    assert w.expire_idle(now=16.0) == 0
