"""Serving platform: Hermes dispatch, cold starts, straggler mitigation."""
import numpy as np
import pytest

from repro.core import (E_LL_PS, E_LOC_PS, HERMES, PAPER_TESTBED,
                        ms_trace, summarize)
from repro.core.cluster import ClusterCfg
from repro.serving.engine import ServeCfg, ServingCluster


def _summ(out, wl):
    return summarize(out.response, wl.service, out.cold, out.rejected,
                     out.server_time, out.core_time, out.end_time)


def test_hermes_beats_vanilla_on_skewed():
    cfg = ServeCfg(cluster=PAPER_TESTBED, cold_start_s=0.5)
    wl = ms_trace(PAPER_TESTBED, 0.5, 1500, seed=0)
    h = _summ(ServingCluster(cfg, HERMES).run(wl), wl)
    v = _summ(ServingCluster(cfg, E_LOC_PS).run(wl), wl)
    assert h.slow_p99 < v.slow_p99


def test_hermes_fewer_cold_starts_than_ll():
    cfg = ServeCfg(cluster=PAPER_TESTBED, cold_start_s=0.5)
    wl = ms_trace(PAPER_TESTBED, 0.3, 1500, seed=1)
    h = ServingCluster(cfg, HERMES).run(wl)
    ll = ServingCluster(cfg, E_LL_PS).run(wl)
    assert h.n_cold < ll.n_cold


def test_hermes_consolidates_servers_at_low_load():
    cfg = ServeCfg(cluster=PAPER_TESTBED, cold_start_s=0.0)
    wl = ms_trace(PAPER_TESTBED, 0.25, 1500, seed=2)
    h = _summ(ServingCluster(cfg, HERMES).run(wl), wl)
    ll = _summ(ServingCluster(cfg, E_LL_PS).run(wl), wl)
    assert h.mean_servers < ll.mean_servers


def test_kernel_controller_matches_python():
    cfg = ServeCfg(cluster=ClusterCfg(n_workers=4, cores=4),
                   cold_start_s=0.2)
    wl = ms_trace(cfg.cluster, 0.5, 400, seed=3)
    a = ServingCluster(cfg, HERMES, use_kernel=False).run(wl)
    b = ServingCluster(cfg, HERMES, use_kernel=True).run(wl)
    np.testing.assert_allclose(np.nan_to_num(a.response, nan=-1),
                               np.nan_to_num(b.response, nan=-1),
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_array_equal(a.worker, b.worker)


def test_straggler_redispatch_helps():
    """One worker at 5% speed: deadline re-dispatch must cut tail."""
    cl = ClusterCfg(n_workers=4, cores=4)
    wl = ms_trace(cl, 0.5, 1200, seed=4)
    base = ServeCfg(cluster=cl, cold_start_s=0.1, speeds=(0.05,))
    # detector notices the degraded worker after 30s; invocations placed
    # before that are rescued by deadline re-dispatch
    mit = ServeCfg(cluster=cl, cold_start_s=0.1, speeds=(0.05,),
                   redispatch_deadline_s=1.0, redispatch_frac=0.5,
                   health_aware=True, detect_after_s=30.0)
    r0 = ServingCluster(base, HERMES).run(wl)
    r1 = ServingCluster(mit, HERMES).run(wl)
    s0, s1 = _summ(r0, wl), _summ(r1, wl)
    assert r1.n_redispatch > 0
    assert s1.slow_p99 < s0.slow_p99 * 0.5, (s0.slow_p99, s1.slow_p99)


@pytest.mark.slow
def test_real_model_backend_end_to_end():
    """Registered smoke models served through the Hermes frontend with
    *measured* (compile-time) cold starts."""
    from repro import configs
    from repro.serving.backends import (HermesFrontend, Invocation,
                                        ModelRegistry)
    reg = ModelRegistry()
    reg.register("olmo", configs.get_smoke("olmo-1b"))
    reg.register("musicgen", configs.get_smoke("musicgen-large"))
    fe = HermesFrontend(reg, n_workers=2, cores=2, max_len=64)
    rng = np.random.default_rng(0)
    lat = {"olmo": [], "musicgen": []}
    for i in range(6):
        fname = ("olmo", "musicgen")[i % 2]
        inv = Invocation(func=fname,
                         prompt=rng.integers(0, 100, 8), n_new=4)
        out = fe.dispatch(inv)
        assert out.tokens is not None and len(out.tokens) == 4
        lat[fname].append((out.response_s, out.cold))
    for fname, rs in lat.items():
        colds = [r for r, c in rs if c]
        warms = [r for r, c in rs if not c]
        assert colds and warms
        # a cold start pays real compile cost ≫ warm invocation
        assert min(colds) > 3 * max(warms), (fname, rs)
