"""End-to-end behaviour tests: the paper's claims at test scale, plus
examples and benchmark plumbing."""
import numpy as np
import pytest

from repro.core import (E_LL_PS, E_LL_FCFS, E_LOC_PS, HERMES, LATE_BINDING,
                        ClusterCfg, ms_trace, multi_balanced, summarize_sim)
from repro.core.simulator import simulate

CL = ClusterCfg(n_workers=4, cores=12)


def _slow99(policy, wl):
    return summarize_sim(simulate(policy, CL, wl), wl).slow_p99


def test_lesson1_head_of_line_blocking():
    """PS-based early binding beats FCFS/late binding on tail slowdown
    under the Azure-shaped heavy-tailed workload (paper Lesson 1)."""
    wl = ms_trace(CL, 0.9, 6000, seed=0)
    ps = _slow99(E_LL_PS, wl)
    fcfs = _slow99(E_LL_FCFS, wl)
    late = _slow99(LATE_BINDING, wl)
    assert ps * 5 < fcfs, (ps, fcfs)
    assert ps * 5 < late, (ps, late)


def test_lesson2_locality_balancing_saturates():
    """Sticky locality hashing overloads the hot worker (Lesson 2)."""
    wl = ms_trace(CL, 0.6, 6000, seed=0)
    assert _slow99(E_LOC_PS, wl) > 3 * _slow99(E_LL_PS, wl)


def test_vanilla_wins_only_on_balanced_mix():
    """§6.2: with zero skew, locality hashing is fine — the OpenWhisk
    scheduler is 'optimized for the wrong workload'."""
    wl = multi_balanced(CL, 0.5, 6000, seed=0)
    loc = _slow99(E_LOC_PS, wl)
    ll = _slow99(E_LL_PS, wl)
    assert loc < ll * 2 + 2          # comparable on balanced mix


def test_hermes_equals_ll_performance_with_fewer_servers():
    wl = ms_trace(CL, 0.3, 6000, seed=1)
    h = summarize_sim(simulate(HERMES, CL, wl), wl)
    ll = summarize_sim(simulate(E_LL_PS, CL, wl), wl)
    assert h.slow_p99 <= ll.slow_p99 * 1.2 + 1.0
    assert h.mean_servers < ll.mean_servers


def test_benchmark_modules_run_tiny():
    """Benchmark plumbing: every figure module produces rows."""
    import benchmarks.fig2_policy_space as f2
    rows = f2.sweep_policies if False else None
    from benchmarks.common import sweep_policies
    from repro.core import FIG2_POLICIES
    rows = sweep_policies(FIG2_POLICIES[:2], CL, [0.5], 300, ms_trace)
    assert len(rows) == 2 and all(r["slow_p99"] >= 1 for r in rows)


def test_quickstart_example_runs():
    import subprocess
    import sys
    import os
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    p = subprocess.run(
        [sys.executable, os.path.join(repo, "examples", "quickstart.py"),
         "--quick"], capture_output=True, text=True, timeout=560, env=env)
    assert p.returncode == 0, p.stdout + p.stderr
