"""Registry balancers end-to-end through the sweep harness and platform.

Covers the benchmark layer of the policy registry: ``sweep_policies``
accepting newly registered balancers (JSQ2 / RR), the duplicate-load
row-ordering fix in :mod:`benchmarks.common`, and the serving platform
running a zoo policy.
"""
import numpy as np
import pytest

from repro.core import (ClusterCfg, E_JSQ2_PS, E_LL_PS, E_RR_PS,
                        synth_workload)

CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)


def _wfn(cluster, load, n, seed):
    return synth_workload(cluster, load, n, n_functions=4,
                          hot_fraction=0.8, seed=seed)


def test_sweep_policies_accepts_zoo_balancers():
    from benchmarks.common import sweep_policies
    rows = sweep_policies([E_JSQ2_PS, E_RR_PS], CLUSTER, [0.4, 0.8], 150,
                          _wfn)
    assert {r["policy"] for r in rows} == {"E/JSQ2/PS", "E/RR/PS"}
    # load-major interleaving with policies cycling inside each load
    assert [r["load"] for r in rows] == [0.4, 0.4, 0.8, 0.8]
    assert all(np.isfinite(r["slow_p99"]) for r in rows)


def test_sweep_policies_duplicate_loads_keep_generation_order():
    from benchmarks.common import sweep_policies
    rows = sweep_policies([E_LL_PS], CLUSTER, [0.4, 0.8, 0.4], 120, _wfn)
    assert [r["load"] for r in rows] == [0.4, 0.4, 0.8]
    # both 0.4 replications survive as distinct rows (same seed → same
    # workload → identical metrics), and the 0.8 row differs
    assert rows[0]["slow_p99"] == rows[1]["slow_p99"]


def test_sweep_policies_ref_engine_zoo():
    from benchmarks.common import sweep_policies
    jax_rows = sweep_policies([E_JSQ2_PS], CLUSTER, [0.6], 120, _wfn)
    ref_rows = sweep_policies([E_JSQ2_PS], CLUSTER, [0.6], 120, _wfn,
                              engine="ref")
    assert jax_rows[0]["slow_p99"] == pytest.approx(
        ref_rows[0]["slow_p99"], rel=1e-9)


def test_serving_platform_runs_zoo_policy():
    from repro.serving.engine import ServeCfg, ServingCluster
    wl = _wfn(CLUSTER, 0.6, 300, 0)
    cfg = ServeCfg(cluster=CLUSTER, cold_start_s=0.2)
    out = ServingCluster(cfg, E_JSQ2_PS).run(wl)
    done = ~out.rejected
    assert np.isfinite(out.response[done]).all()
    rr = ServingCluster(cfg, E_RR_PS).run(wl)
    assert np.isfinite(rr.response[~rr.rejected]).all()


def test_serving_kernel_flag_requires_batch_backend():
    from repro.serving.engine import ServeCfg, ServingCluster
    cfg = ServeCfg(cluster=CLUSTER)
    with pytest.raises(ValueError, match="no batched kernel"):
        ServingCluster(cfg, E_JSQ2_PS, use_kernel=True)
