"""Registry balancers end-to-end through the sweep harness and platform.

Covers the benchmark layer of the policy registry: ``sweep_policies``
accepting newly registered balancers (JSQ2 / RR), the duplicate-load
row-ordering fix in :mod:`benchmarks.common`, the serving platform
running zoo policies, and the carried-state contract (HIKU / DD):
engine agreement (vectorized scan ≡ numpy oracle ≡ batched vmap),
``init_state`` registry round-trips, the ready-ring / EMA semantics,
and a custom stateful balancer registered end-to-end.
"""
import numpy as np
import pytest

from repro.core import (ClusterCfg, E_DD_PS, E_HIKU_PS, E_JSQ2_PS,
                        E_LL_PS, E_RR_PS, ZOO_POLICIES, bimodal_exec,
                        synth_workload)
from repro.policy import get_balancer, register_balancer, resolve, \
    unregister_balancer

CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)
STATEFUL_POLICIES = (E_HIKU_PS, E_DD_PS)


def _wfn(cluster, load, n, seed):
    return synth_workload(cluster, load, n, n_functions=4,
                          hot_fraction=0.8, seed=seed)


def test_sweep_policies_accepts_zoo_balancers():
    from benchmarks.common import sweep_policies
    rows = sweep_policies([E_JSQ2_PS, E_RR_PS], CLUSTER, [0.4, 0.8], 150,
                          _wfn)
    assert {r["policy"] for r in rows} == {"E/JSQ2/PS", "E/RR/PS"}
    # load-major interleaving with policies cycling inside each load
    assert [r["load"] for r in rows] == [0.4, 0.4, 0.8, 0.8]
    assert all(np.isfinite(r["slow_p99"]) for r in rows)


def test_sweep_policies_duplicate_loads_keep_generation_order():
    from benchmarks.common import sweep_policies
    rows = sweep_policies([E_LL_PS], CLUSTER, [0.4, 0.8, 0.4], 120, _wfn)
    assert [r["load"] for r in rows] == [0.4, 0.4, 0.8]
    # both 0.4 replications survive as distinct rows (same seed → same
    # workload → identical metrics), and the 0.8 row differs
    assert rows[0]["slow_p99"] == rows[1]["slow_p99"]


def test_sweep_policies_ref_engine_zoo():
    from benchmarks.common import sweep_policies
    jax_rows = sweep_policies([E_JSQ2_PS], CLUSTER, [0.6], 120, _wfn)
    ref_rows = sweep_policies([E_JSQ2_PS], CLUSTER, [0.6], 120, _wfn,
                              engine="ref")
    assert jax_rows[0]["slow_p99"] == pytest.approx(
        ref_rows[0]["slow_p99"], rel=1e-9)


def test_serving_platform_runs_zoo_policy():
    from repro.serving.engine import ServeCfg, ServingCluster
    wl = _wfn(CLUSTER, 0.6, 300, 0)
    cfg = ServeCfg(cluster=CLUSTER, cold_start_s=0.2)
    out = ServingCluster(cfg, E_JSQ2_PS).run(wl)
    done = ~out.rejected
    assert np.isfinite(out.response[done]).all()
    rr = ServingCluster(cfg, E_RR_PS).run(wl)
    assert np.isfinite(rr.response[~rr.rejected]).all()


def test_serving_kernel_flag_requires_batch_backend():
    from repro.serving.engine import ServeCfg, ServingCluster
    cfg = ServeCfg(cluster=CLUSTER)
    with pytest.raises(ValueError, match="no batched kernel"):
        ServingCluster(cfg, E_JSQ2_PS, use_kernel=True)


# --------------------------------------------------------------------------
# Carried-state balancers (HIKU / DD): engine agreement + registry
# round-trip + decision semantics
# --------------------------------------------------------------------------

@pytest.mark.parametrize("policy", STATEFUL_POLICIES, ids=lambda p: p.name)
@pytest.mark.parametrize("wname", ["synth", "bimodal"])
def test_stateful_golden_engine_agreement(policy, wname):
    """The vectorized scan engine ≡ the numpy oracle ≡ the batched vmap
    engine, task-by-task, for the carried-state balancers — the golden
    contract every stateless balancer already satisfies, extended to
    state threaded through selection AND per-completion hooks."""
    from repro.core.sim_ref import simulate_ref
    from repro.core.simulator import simulate, simulate_many
    mk = (lambda l, s: _wfn(CLUSTER, l, 300, s)) if wname == "synth" else \
        (lambda l, s: bimodal_exec(CLUSTER, l, 300, seed=s))
    for load, seed in ((0.5, 0), (0.9, 1), (1.3, 2)):
        wl = mk(load, seed)
        out = simulate(policy, CLUSTER, wl)
        ref = simulate_ref(policy, CLUSTER, wl)
        np.testing.assert_array_equal(out.worker, ref.worker)
        np.testing.assert_allclose(
            np.nan_to_num(out.response, nan=-1.0),
            np.nan_to_num(ref.response, nan=-1.0), atol=1e-9)
        np.testing.assert_array_equal(out.cold, ref.cold)
        np.testing.assert_array_equal(out.rejected, ref.rejected)
        batch = simulate_many(policy, CLUSTER, [wl, wl])
        np.testing.assert_array_equal(
            np.nan_to_num(batch.response[0], nan=-1.0),
            np.nan_to_num(out.response, nan=-1.0))
        np.testing.assert_array_equal(batch.response[0], batch.response[1])


def test_init_state_registry_round_trip():
    """init_state survives the registry: fresh, isolated copies per call,
    exposed through resolve() on every backend with the hook attached."""
    for name, keys in (("HIKU", {"ring", "in_ring", "head", "tail"}),
                       ("DD", {"est", "ew"})):
        bal = get_balancer(name)
        assert bal.stateful
        s1 = bal.init_state(5, 7)
        s2 = bal.init_state(5, 7)
        assert set(s1) == keys
        for k in keys:      # independent copies — mutation can't leak
            arr = np.asarray(s1[k])
            if arr.ndim:
                arr[...] = -123
                assert not np.array_equal(np.asarray(s1[k]),
                                          np.asarray(s2[k]))
        for backend in ("np", "jax", "pallas"):
            res = resolve(f"E/{name}/PS", backend=backend, cluster=CLUSTER)
            assert res.stateful
            assert res.init_state is bal.init_state
            assert callable(res.select) and callable(res.on_complete)
    # stateless balancers resolve without state machinery
    res = resolve("E/LL/PS", backend="np", cluster=CLUSTER)
    assert not res.stateful and res.on_complete is None


def test_hiku_ready_ring_semantics():
    """Pull-based decisions step by step: pops drain the advertised ring
    FIFO, an empty ring falls back to least-loaded, and a completion
    that idles a worker re-advertises it exactly once."""
    bal = get_balancer("HIKU")
    sel, oc = bal.make_np(2, 4)
    state = bal.init_state(3, 2)
    active = np.array([1, 2, 1])
    warm = np.zeros(3, dtype=np.int64)
    homes = np.zeros(2, dtype=np.int32)
    # ring starts [0, 1, 2]: three pops in FIFO order
    for expect in (0, 1, 2):
        w, state = sel(state, active, warm, 0, homes, 0.5, 0)
        assert w == expect
    # ring empty -> least-loaded fallback (first argmin index)
    w, state = sel(state, active, warm, 0, homes, 0.5, 0)
    assert w == 0 and int(state["tail"]) == int(state["head"])
    # completion leaving tasks behind does NOT advertise…
    state = oc(state, 1, 0, 1.0, 1)
    assert int(state["tail"]) == int(state["head"])
    # …the one that idles worker 1 does, exactly once (flag-gated)
    state = oc(state, 1, 0, 1.0, 0)
    state = oc(state, 1, 0, 1.0, 0)
    assert int(state["tail"]) - int(state["head"]) == 1
    w, state = sel(state, active, warm, 0, homes, 0.5, 0)
    assert w == 1
    # full cluster rejects and must hand back the state unchanged
    full = np.full(3, 4)
    w, state2 = sel(state, full, warm, 0, homes, 0.5, 0)
    assert w == -1
    for k in state:
        assert np.array_equal(np.asarray(state[k]), np.asarray(state2[k]))


def test_hiku_busy_pop_falls_back_to_least_loaded():
    """A ring member busied WITHOUT a select pop (serving re-dispatch
    migrations do this) must not be handed out: the pop validates the
    candidate's slot and falls back to least-loaded, un-advertising the
    stale entry — identically on both backends (parity lanes cover the
    jax side)."""
    bal = get_balancer("HIKU")
    sel, _ = bal.make_np(2, 4)
    state = bal.init_state(3, 2)
    warm = np.zeros(3, dtype=np.int64)
    homes = np.zeros(2, dtype=np.int32)
    # worker 0 (ring head) externally saturated: fall back to LL (w=2)
    active = np.array([4, 3, 0])
    w, state = sel(state, active, warm, 0, homes, 0.5, 0)
    assert w == 2
    # the stale head was consumed: next pop yields worker 1
    w, state = sel(state, np.zeros(3, dtype=np.int64), warm, 0, homes,
                   0.5, 1)
    assert w == 1


def test_frontend_dispatches_stateful_balancers(monkeypatch):
    """HermesFrontend threads carried state through live dispatch: HIKU
    rotates through the advertised ring (each synchronous completion
    re-advertises its worker), DD stays within worker bounds."""
    from repro.serving import backends as sb

    def fake_execute(self, inv):
        inv.tokens = np.zeros(inv.n_new, np.int32)
        inv.cold = inv.func not in self.warm
        self.warm.setdefault(inv.func, None)
        inv.response_s = 1e-3
        return inv

    monkeypatch.setattr(sb.InProcessWorker, "execute", fake_execute)
    reg = sb.ModelRegistry()
    reg.register("a", None)
    reg.register("b", None)
    for name, expect in (("HIKU", [0, 1, 2, 0, 1, 2]), ("DD", None)):
        fe = sb.HermesFrontend(reg, n_workers=3, cores=2, balancer=name)
        assert fe._lb_state is not None
        got = []
        for i in range(6):
            inv = sb.Invocation(func="ab"[i % 2],
                                prompt=np.zeros(4, np.int32), n_new=2)
            got.append(fe.dispatch(inv).worker)
        if expect is not None:
            assert got == expect, got
        assert all(0 <= w < 3 for w in got)


def test_dd_estimates_drive_dispatch():
    """DD learns per-function durations and packs by expected work."""
    bal = get_balancer("DD")
    sel, oc = bal.make_np(2, 4)
    state = bal.init_state(2, 2)
    homes = np.zeros(2, dtype=np.int32)
    warm = np.zeros(2, dtype=np.int64)
    active = np.zeros(2, dtype=np.int64)
    # teach it: func 0 is long (10 s), func 1 short (0.1 s)
    for _ in range(20):
        state = oc(state, 0, 0, 10.0, 0)
        state = oc(state, 1, 1, 0.1, 0)
    assert state["est"][0] > 5.0 > 1.0 > state["est"][1]
    state = dict(state, ew=np.zeros(2))
    # a long invocation lands on worker 0 and charges ~10 s of work…
    w, state = sel(state, active, warm, 0, homes, 0.5, 0)
    assert w == 0 and state["ew"][0] > 5.0
    # …so the next two (short) invocations prefer worker 1
    w, state = sel(state, np.array([1, 0]), warm, 1, homes, 0.5, 1)
    assert w == 1
    w, state = sel(state, np.array([1, 1]), warm, 1, homes, 0.5, 2)
    assert w == 1
    # completion discharges the worker (clamped at zero)
    state = oc(state, 0, 0, 10.0, 0)
    assert state["ew"][0] < 5.0 and (state["ew"] >= 0.0).all()


def test_register_custom_stateful_balancer_end_to_end():
    """The carried-state contract is open: a sticky last-worker balancer
    registered in ~20 lines runs through both engines in agreement (the
    README 'HIKU in 20 lines' shape)."""
    from repro.core import parse_policy
    from repro.core.sim_ref import simulate_ref
    from repro.core.simulator import simulate

    def init_state(n_workers, n_functions):
        return {"last": np.int32(-1)}

    def make_np(cores, slots):
        def select(state, active, warm_col, func, func_home, u, idx):
            has_slot = active < slots
            if not has_slot.any():
                return -1, state
            last = int(state["last"])
            if 0 <= last and active[last] < slots:
                return last, state
            w = int(np.argmin(np.where(has_slot, active, 1 << 40)))
            return w, dict(state, last=np.int32(w))

        def on_complete(state, w, func, service, n_active_after):
            return state
        return select, on_complete

    def make_jax(cores, slots):
        import jax.numpy as jnp

        def select(state, active, warm_col, func, func_home, u, idx):
            has_slot = active < slots
            last = state["last"]
            sticky = (last >= 0) & (active[jnp.maximum(last, 0)] < slots)
            ll = jnp.argmin(jnp.where(has_slot, active.astype(jnp.int32),
                                      jnp.int32(1 << 30))).astype(jnp.int32)
            w = jnp.where(sticky, last, ll)
            new = dict(state, last=jnp.where(
                sticky, last, ll).astype(state["last"].dtype))
            return jnp.where(has_slot.any(), w, -1).astype(jnp.int32), new

        def on_complete(state, w, func, service, n_active_after):
            return state
        return select, on_complete

    register_balancer("STICKY", make_np=make_np, make_jax=make_jax,
                      init_state=init_state, doc="sticky last choice")
    try:
        pol = parse_policy("E/STICKY/PS")
        wl = _wfn(CLUSTER, 0.8, 250, 3)
        out = simulate(pol, CLUSTER, wl)
        ref = simulate_ref(pol, CLUSTER, wl)
        np.testing.assert_array_equal(out.worker, ref.worker)
        # sticky behavior is visible: long same-worker runs
        assert (np.diff(ref.worker[~ref.rejected]) == 0).mean() > 0.5
    finally:
        unregister_balancer("STICKY")


def test_sweep_policies_accepts_stateful_balancers():
    from benchmarks.common import registry_policies, sweep_policies
    rows = sweep_policies(STATEFUL_POLICIES, CLUSTER, [0.5, 0.9], 150,
                          _wfn)
    assert {r["policy"] for r in rows} == {"E/HIKU/PS", "E/DD/PS"}
    assert all(np.isfinite(r["slow_p99"]) for r in rows)
    # registry_policies folds every registered balancer into a sweep list
    names = {p.name for p in registry_policies(ZOO_POLICIES)}
    assert {"E/HIKU/PS", "E/DD/PS", "E/LOC/PS"} <= names


def test_serving_platform_runs_stateful_policies():
    from repro.core.sim_ref import simulate_ref
    from repro.serving.engine import ServeCfg, ServingCluster
    wl = _wfn(CLUSTER, 0.6, 300, 0)
    cfg = ServeCfg(cluster=CLUSTER, cold_start_s=0.2)
    for pol in STATEFUL_POLICIES:
        out = ServingCluster(cfg, pol).run(wl)
        assert np.isfinite(out.response[~out.rejected]).all()
    # with zero platform overheads the serving loop IS the oracle
    cfg0 = ServeCfg(cluster=CLUSTER, cold_start_s=0.0, ctrl_latency_s=0.0)
    for pol in STATEFUL_POLICIES:
        sv = ServingCluster(cfg0, pol).run(wl)
        rf = simulate_ref(pol, CLUSTER, wl)
        np.testing.assert_array_equal(sv.worker, rf.worker)
