"""End-to-end training driver: train a reduced-config architecture for a
few hundred steps with checkpoint/restart fault tolerance.

Any of the ten assigned archs is selectable; reduced configs keep this
CPU-runnable.  (The full-size configs are exercised by the dry-run:
``python -m repro.launch.dryrun --all``.)

Usage::

    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b \
        --steps 300 --batch 8 --seq 64
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    import jax
    from repro import configs
    from repro.data.pipeline import make_data_iter
    from repro.models.transformer import build_model
    from repro.training.checkpoint import CheckpointManager
    from repro.training.optimizer import OptCfg
    from repro.training.train import (build_train_step, init_train_state,
                                      run_with_restarts)

    cfg = configs.get_smoke(args.arch)
    model = build_model(cfg)
    print(f"arch={args.arch} (reduced): L={cfg.n_layers} d={cfg.d_model} "
          f"family={cfg.family}")
    ocfg = OptCfg(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    state = init_train_state(model, jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"params: {n_params:,}")
    step_fn = jax.jit(build_train_step(model, ocfg,
                                       microbatches=args.microbatches))
    data = make_data_iter("lcg", args.batch, args.seq, cfg.vocab,
                          device=False)
    mgr = CheckpointManager(args.ckpt_dir)
    t0 = time.time()
    state, rep = run_with_restarts(step_fn, state, data,
                                   n_steps=args.steps, ckpt_mgr=mgr,
                                   ckpt_every=max(args.steps // 5, 10))
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"{rep.steps_done} steps in {dt:.0f}s ({tok_s:,.0f} tok/s) — "
          f"loss {rep.losses[0]:.3f} → {rep.final_loss:.3f} "
          f"(restarts={rep.restarts})")
    assert rep.final_loss < rep.losses[0]


if __name__ == "__main__":
    main()
