"""Interactive policy-space exploration (paper §3 methodology).

Sweep any ``T/LB/S`` policy over load and workload knobs; prints a
slowdown/latency/efficiency table.  Examples::

    PYTHONPATH=src python examples/policy_explorer.py \
        --policies E/H/PS E/LL/PS L/*/* --loads 0.3 0.6 0.9 \
        --workload ms-trace --workers 8 --cores 12
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policies", nargs="+",
                    default=["E/H/PS", "E/LL/PS", "E/LOC/PS", "L/*/*"])
    ap.add_argument("--loads", nargs="+", type=float,
                    default=[0.3, 0.6, 0.9])
    ap.add_argument("--workload", default="ms-trace",
                    choices=["ms-trace", "ms-representative",
                             "single-function", "multi-balanced",
                             "homogeneous-exec"])
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--cores", type=int, default=12)
    ap.add_argument("-n", type=int, default=4000)
    ap.add_argument("--engine", choices=["sim", "serve"], default="sim",
                    help="pure simulator vs serving platform (cold starts)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core import (ClusterCfg, WORKLOADS, parse_policy, summarize,
                            summarize_sim)
    from repro.core.simulator import simulate
    from repro.serving.engine import ServeCfg, ServingCluster

    cl = ClusterCfg(n_workers=args.workers, cores=args.cores)
    wfn = WORKLOADS[args.workload]
    print(f"{'policy':10s} {'load':>5s} {'slow50':>8s} {'slow99':>10s} "
          f"{'lat99':>9s} {'cold%':>6s} {'servers':>8s}")
    for load in args.loads:
        wl = wfn(cl, load, args.n, seed=args.seed)
        for ptext in args.policies:
            pol = parse_policy(ptext)
            if args.engine == "sim":
                s = summarize_sim(simulate(pol, cl, wl), wl)
            else:
                out = ServingCluster(ServeCfg(cluster=cl), pol).run(wl)
                s = summarize(out.response, wl.service, out.cold,
                              out.rejected, out.server_time, out.core_time,
                              out.end_time)
            print(f"{pol.name:10s} {load:5.2f} {s.slow_p50:8.2f} "
                  f"{s.slow_p99:10.1f} {s.lat_p99:9.2f} "
                  f"{100*s.cold_frac:6.1f} {s.mean_servers:8.2f}")


if __name__ == "__main__":
    main()
