"""Interactive policy-space exploration (paper §3 methodology).

Sweep any ``T/LB/S`` policy over load and workload knobs; prints a
slowdown/latency/efficiency table.  ``LB`` and ``S`` accept every
balancer/scheduler registered in :mod:`repro.policy` — the paper's
``LOC``/``R``/``LL``/``H`` plus zoo extensions like ``JSQ2``
(power-of-two-choices) and ``RR`` (round-robin), and anything you add
via :func:`repro.policy.register_balancer` (``--list-policies`` shows
what is registered).  Examples::

    PYTHONPATH=src python examples/policy_explorer.py \
        --policies E/H/PS E/JSQ2/PS E/LL/PS L/*/* --loads 0.3 0.6 0.9 \
        --workload ms-trace --workers 8 --cores 12

Batched sweeps
--------------
With ``--engine sim`` (the default) the whole ``loads × reps`` grid is
stacked into one :class:`~repro.core.workload.WorkloadBatch` per policy
and run through a single ``jax.vmap``-ed compiled program
(:func:`repro.core.simulator.simulate_many`) — one XLA compile per
policy regardless of how many load points or seed replications you
sweep.  ``--reps R`` replicates every load point over ``R`` consecutive
seeds inside the same batch and reports the across-replication mean
± 95 % confidence half-width of each metric::

    PYTHONPATH=src python examples/policy_explorer.py \
        --policies E/H/PS E/LL/PS --loads 0.3 0.5 0.7 0.9 --reps 5

The ``--engine serve`` path (cold-start platform with straggler
mitigation hooks) remains per-cell and ignores ``--reps``.

``--workload`` accepts every ``repro.core.WORKLOADS`` entry, including
the non-stationary ``azure-*`` trace-replay scenarios
(:mod:`repro.trace`)::

    PYTHONPATH=src python examples/policy_explorer.py \
        --workload azure-bursty --loads 0.5 0.7 --reps 3

Container lifecycle
-------------------
``--keepalive`` threads a keep-alive policy from the
:mod:`repro.lifecycle` registry through whichever engine you pick —
``NONE`` (tear down at completion), ``FIXED_TTL`` (``--ttl`` seconds),
``HYBRID_HIST`` (learned per-function pre-warm + keep-alive windows),
or anything you add via :func:`repro.lifecycle.register_keepalive`.
``--max-idle`` caps the per-worker warm pool and ``--cold-start-preset``
swaps the scalar penalty for per-function provider costs.  Without
``--keepalive``, executors never expire: a preset or budget alone runs
with an *infinite* keep-alive window, and with every lifecycle flag at
its default the explorer keeps the exact legacy warm-pool model.
``--list-policies`` also prints the registered keep-alive policies and
cold-start presets::

    PYTHONPATH=src python examples/policy_explorer.py \
        --policies E/H/PS E/LL/PS --keepalive HYBRID_HIST --ttl 30 \
        --max-idle 8 --cold-start-preset openwhisk --loads 0.3 0.7

Heterogeneous fleets & autoscaling
----------------------------------
``--fleet-preset`` / ``--speed`` give workers unequal speeds
(:mod:`repro.fleet`; try the ``SWARM`` balancer, which learns the
speeds online), and ``--autoscale TARGET_P99`` turns on the
latency-target control loop (telemetry is enabled automatically when
the autoscaler reads the sketch)::

    PYTHONPATH=src python examples/policy_explorer.py \
        --policies E/LL/PS E/SWARM/PS --fleet-preset two-gen \
        --workload azure-diurnal --loads 0.5 0.8

With every fleet flag at its default the explorer keeps the exact
homogeneous fixed-W model; ``--list-policies`` prints the registered
fleet presets and autoscale policies alongside the other axes.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policies", nargs="+",
                    default=["E/H/PS", "E/LL/PS", "E/LOC/PS", "L/*/*"])
    ap.add_argument("--loads", nargs="+", type=float,
                    default=[0.3, 0.6, 0.9])
    ap.add_argument("--workload", default="ms-trace",
                    help="any repro.core.WORKLOADS name, incl. azure-* "
                         "trace-replay scenarios")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--cores", type=int, default=12)
    ap.add_argument("-n", type=int, default=4000)
    ap.add_argument("--engine", choices=["sim", "serve"], default="sim",
                    help="pure simulator vs serving platform (cold starts)")
    ap.add_argument("--keepalive", metavar="NAME",
                    help="container keep-alive policy (repro.lifecycle "
                         "registry); omit for the legacy keep-forever "
                         "warm pool")
    ap.add_argument("--ttl", type=float, default=60.0,
                    help="keep-alive window seconds")
    ap.add_argument("--max-idle", type=int, default=0,
                    help="per-worker warm-pool budget (0 = unbounded)")
    ap.add_argument("--cold-start-preset", metavar="NAME",
                    default="scalar",
                    help="per-function cold-start preset ('scalar' = "
                         "legacy single penalty)")
    ap.add_argument("--fleet-preset", metavar="NAME",
                    help="per-worker speed preset (repro.fleet registry); "
                         "omit (with no other fleet flag) for the "
                         "homogeneous pool")
    ap.add_argument("--speed", nargs="+", type=float, metavar="S",
                    help="explicit per-worker speeds (overrides "
                         "--fleet-preset; length must equal --workers)")
    ap.add_argument("--autoscale", metavar="NAME",
                    help="active-worker autoscale policy (repro.fleet "
                         "registry: STATIC, TARGET_P99, ...)")
    ap.add_argument("--target-p99", type=float, default=5.0,
                    help="autoscaler p99 slowdown ceiling")
    ap.add_argument("--min-workers", type=int, default=1,
                    help="autoscaler floor on active workers")
    ap.add_argument("--cooldown", type=float, default=60.0,
                    help="seconds between autoscale decisions")
    ap.add_argument("--hysteresis", type=float, default=0.1,
                    help="autoscaler dead-band half-width")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=1,
                    help="seed replications per load point (sim engine); "
                         ">1 adds ±95%% CI columns")
    ap.add_argument("--list-policies", action="store_true",
                    help="print registered balancers/schedulers and exit")
    ap.add_argument("--telemetry", action="store_true",
                    help="carry streaming telemetry (repro.telemetry) "
                         "through the sweep and print per-policy sketch "
                         "summaries")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="export a Perfetto-loadable Chrome trace JSON "
                         "of the sweep (implies --telemetry)")
    ap.add_argument("--timeline-out", metavar="PATH", default=None,
                    help="carry the windowed flight-recorder timeline "
                         "(repro.telemetry.timeline) and export it per "
                         "policy: per-window CSV at PATH with the "
                         "policy name suffixed, plus an OpenMetrics "
                         "sibling (.om); with --trace-out the windows "
                         "also land in the trace as Perfetto counter "
                         "tracks")
    args = ap.parse_args()

    if args.list_policies:
        from repro.fleet import (autoscaler_names, fleet_preset_names,
                                 get_autoscaler)
        from repro.lifecycle import (cold_preset_names, get_keepalive,
                                     keepalive_names)
        from repro.policy import (balancer_names, get_balancer, get_sched,
                                  sched_names)
        print("balancers (LB):")
        for name in balancer_names():
            bal = get_balancer(name)
            print(f"  {name:6s} [{','.join(bal.backends())}]  {bal.doc}")
        print("worker schedulers (S):")
        for name in sched_names():
            print(f"  {name:6s} {get_sched(name).doc}")
        print("bindings (T): E (early), L (late; 'L/*/*' alias works)")
        print("keep-alive policies (--keepalive):")
        for name in keepalive_names():
            ka = get_keepalive(name)
            print(f"  {name:12s} [{','.join(ka.backends())}]  {ka.doc}")
        print(f"cold-start presets (--cold-start-preset): "
              f"{', '.join(cold_preset_names())}")
        print(f"fleet presets (--fleet-preset): "
              f"{', '.join(fleet_preset_names())}")
        print("autoscale policies (--autoscale):")
        for name in autoscaler_names():
            pol = get_autoscaler(name)
            tel = "telemetry" if pol.needs_telemetry else "no-telemetry"
            print(f"  {name:12s} [{tel}]  {pol.doc}")
        return

    from repro.core import (ClusterCfg, WORKLOADS, parse_policy,
                            replicate_workload, summarize,
                            summarize_batch_sim)
    from repro.core.simulator import simulate_many
    from repro.fleet import fleet_from_flags, get_autoscaler
    from repro.lifecycle import lifecycle_from_flags
    from repro.serving.engine import ServeCfg, ServingCluster

    if args.workload not in WORKLOADS:
        ap.error(f"unknown --workload {args.workload!r}; choose from "
                 f"{', '.join(sorted(WORKLOADS))}")
    # named ValueError on unknown names; a preset/budget without an
    # explicit --keepalive gets an infinite window (no surprise expiry)
    lifecycle = lifecycle_from_flags(args.keepalive, args.ttl,
                                     args.max_idle, args.cold_start_preset)
    # same contract for the fleet axes: all defaults -> fleet=None
    fleet = fleet_from_flags(args.fleet_preset, args.speed, args.autoscale,
                             args.target_p99, args.min_workers,
                             args.cooldown, args.hysteresis)
    cl = ClusterCfg(n_workers=args.workers, cores=args.cores,
                    lifecycle=lifecycle, fleet=fleet).validate()
    # sketch-reading autoscalers need the telemetry carry even when no
    # summary was requested
    auto_needs_tel = (fleet is not None and
                      get_autoscaler(fleet.autoscale).needs_telemetry)
    telemetry_on = bool(args.telemetry or args.trace_out or auto_needs_tel)
    tel_cfg = None
    tracer = None
    if telemetry_on:
        from repro.telemetry import TelemetryCfg, configure_tracing
        tel_cfg = TelemetryCfg()
        if args.telemetry or args.trace_out:   # span tracing stays opt-in
            tracer = configure_tracing(True)
    tl_cfg = None
    if args.timeline_out:
        from repro.telemetry import TimelineCfg
        tl_cfg = TimelineCfg()

    def export_timeline(tag, tl):
        import os
        base, ext = os.path.splitext(args.timeline_out)
        ext = ext or ".csv"
        p_csv = tl.write_csv(f"{base}.{tag}{ext}")
        p_om = tl.write_openmetrics(f"{base}.{tag}{ext}.om")
        if tracer is not None:
            tl.emit_counters(tracer, prefix=f"timeline/{tag}")
        print(f"timeline[{tag}]: {p_csv} + {p_om}")

    wfn = WORKLOADS[args.workload]
    ci = " ±ci95" if args.reps > 1 and args.engine == "sim" else ""
    print(f"{'policy':10s} {'load':>5s} {'slow50':>8s} "
          f"{'slow99':>10s}{ci} {'lat99':>9s} {'cold%':>6s} "
          f"{'servers':>8s}")

    if args.engine == "sim":
        seeds = tuple(range(args.seed, args.seed + args.reps))
        wb = replicate_workload(wfn, cl, args.loads, args.n, seeds=seeds)
        results = {}
        for ptext in args.policies:
            pol = parse_policy(ptext)
            results[pol.name] = (pol, simulate_many(pol, cl, wb,
                                                    telemetry=tel_cfg,
                                                    timeline=tl_cfg))
        for li, load in enumerate(args.loads):
            sl = slice(li * args.reps, (li + 1) * args.reps)
            for pname, (pol, out) in results.items():
                bs = summarize_batch_sim(out[sl], wb[sl])
                s = bs.pooled
                ci_txt = (f" ±{bs.stats['slow_p99'].ci95:6.1f}"
                          if args.reps > 1 else "")
                print(f"{pname:10s} {load:5.2f} {s.slow_p50:8.2f} "
                      f"{s.slow_p99:10.1f}{ci_txt} {s.lat_p99:9.2f} "
                      f"{100*s.cold_frac:6.1f} {s.mean_servers:8.2f}")
        if telemetry_on:
            print("telemetry (pooled sketch over the whole batch):")
            for pname, (pol, out) in results.items():
                t = out.telemetry.summary()
                print(f"  {pname:10s} sketch slow p50/p99 = "
                      f"{t['slow_p50']:.2f} / {t['slow_p99']:.1f}  "
                      f"cold={t['n_cold']} warm={t['n_warm']} "
                      f"evict={t['n_evict']} reject={t['n_reject']}")
        if args.timeline_out:
            # the batched timeline pools over loads × reps (same
            # horizon, shared virtual-time windows)
            for pname, (pol, out) in results.items():
                export_timeline(pname.replace("/", "-"), out.timeline)
        if args.trace_out:
            tracer.export(args.trace_out)
            print(f"trace: {args.trace_out} "
                  f"(load at https://ui.perfetto.dev)")
        return

    for load in args.loads:
        wl = wfn(cl, load, args.n, seed=args.seed)
        for ptext in args.policies:
            pol = parse_policy(ptext)
            sc = ServingCluster(ServeCfg(cluster=cl), pol,
                                telemetry=tel_cfg, timeline=tl_cfg)
            if tracer is not None:
                with tracer.span("explore.serve", policy=pol.name,
                                 load=load, n=args.n):
                    out = sc.run(wl)
            else:
                out = sc.run(wl)
            s = summarize(out.response, wl.service, out.cold,
                          out.rejected, out.server_time, out.core_time,
                          out.end_time)
            print(f"{pol.name:10s} {load:5.2f} {s.slow_p50:8.2f} "
                  f"{s.slow_p99:10.1f} {s.lat_p99:9.2f} "
                  f"{100*s.cold_frac:6.1f} {s.mean_servers:8.2f}")
            if out.timeline is not None:
                export_timeline(f"{pol.name.replace('/', '-')}-{load}",
                                out.timeline)
    if args.trace_out:
        tracer.export(args.trace_out)
        print(f"trace: {args.trace_out} (load at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
