"""End-to-end driver: serve real JAX models behind the Hermes frontend.

Registers two reduced-config architectures as serverless "functions",
dispatches a batch of requests through the Hermes controller onto
in-process workers, and reports per-invocation latency with *measured*
cold starts (the XLA compile + weight-residency cost — not a model).

Usage:  PYTHONPATH=src python examples/serve_cluster.py [--requests N]
"""
import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()

    from repro import configs
    from repro.serving.backends import (HermesFrontend, Invocation,
                                        ModelRegistry)

    reg = ModelRegistry()
    reg.register("olmo-tiny", configs.get_smoke("olmo-1b"))
    reg.register("musicgen-tiny", configs.get_smoke("musicgen-large"))
    fe = HermesFrontend(reg, n_workers=args.workers, cores=2, max_len=64)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    done = []
    for i in range(args.requests):
        func = ("olmo-tiny", "musicgen-tiny")[i % 2]
        vocab = 100
        inv = Invocation(func=func, prompt=rng.integers(0, vocab, 8),
                         n_new=6)
        out = fe.dispatch(inv)
        done.append(out)
        print(f"req {i:2d} fn={func:14s} worker={out.worker} "
              f"{'COLD' if out.cold else 'warm'} "
              f"latency={out.response_s*1e3:8.1f}ms "
              f"tokens={out.tokens.tolist()}")
    wall = time.perf_counter() - t0
    colds = [d for d in done if d.cold]
    warms = [d for d in done if not d.cold]
    print(f"\n{len(done)} requests in {wall:.1f}s — "
          f"{len(colds)} cold (mean {np.mean([d.response_s for d in colds]):.2f}s), "
          f"{len(warms)} warm (mean {np.mean([d.response_s for d in warms])*1e3:.0f}ms)")


if __name__ == "__main__":
    main()
