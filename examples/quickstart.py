"""Quickstart: the paper in five minutes on one CPU.

1. Sweep the scheduling-policy taxonomy on an Azure-shaped workload
   (paper §3) with the JAX discrete-event simulator.
2. Serve the same workload through the platform layer with Hermes vs
   vanilla OpenWhisk scheduling (paper §6) — cold starts included.
3. Run one batched controller dispatch through the Pallas kernel.

Usage:  PYTHONPATH=src python examples/quickstart.py [--quick]
"""
import argparse
import sys

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    n = 1500 if args.quick else 8000

    from repro.core import (EVAL_POLICIES, HERMES, E_LOC_PS, PAPER_TESTBED,
                            ms_trace, summarize, summarize_sim)
    from repro.core.simulator import simulate

    print("== 1. policy-space simulation (paper §3) ==")
    wl = ms_trace(PAPER_TESTBED, load=0.7, n=n, seed=0)
    for pol in EVAL_POLICIES:
        s = summarize_sim(simulate(pol, PAPER_TESTBED, wl), wl)
        print(f"  {pol.name:10s} slow_p50={s.slow_p50:6.2f} "
              f"slow_p99={s.slow_p99:8.1f} servers={s.mean_servers:5.2f}")

    print("== 2. serving platform with cold starts (paper §6) ==")
    from repro.serving.engine import ServeCfg, ServingCluster
    cfg = ServeCfg(cluster=PAPER_TESTBED, cold_start_s=0.5)
    for name, pol in (("hermes", HERMES), ("vanilla-ow", E_LOC_PS)):
        out = ServingCluster(cfg, pol).run(wl)
        s = summarize(out.response, wl.service, out.cold, out.rejected,
                      out.server_time, out.core_time, out.end_time)
        print(f"  {name:10s} slow_p99={s.slow_p99:8.1f} "
              f"cold%={100*s.cold_frac:5.1f} servers={s.mean_servers:5.2f}")

    print("== 3. batched Hermes dispatch (Pallas controller kernel) ==")
    import jax.numpy as jnp
    from repro.kernels.hermes_select.ops import hermes_select
    rng = np.random.default_rng(0)
    W, F, N = 8, 50, 256
    choices, active = hermes_select(
        jnp.zeros((W,), jnp.int32),
        jnp.asarray(rng.integers(0, 2, (W, F)), jnp.int32),
        jnp.asarray(rng.integers(0, F, N), jnp.int32),
        cores=12, slots=96)
    print(f"  dispatched {N} invocations; per-worker load: "
          f"{np.asarray(active).tolist()}")
    assert int(active.sum()) == N
    print("quickstart OK")


if __name__ == "__main__":
    sys.exit(main())
