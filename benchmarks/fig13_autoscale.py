"""Fig. 13 (extension) — heterogeneous fleets & latency-target autoscaling.

The fleet subsystem (:mod:`repro.fleet`) adds two axes the paper's
testbed holds fixed: per-worker speed and the number of provisioned
workers.  Two lanes, both on ``azure-diurnal`` trace replay:

* **balancer lane** — a two-generation fleet (half the workers at half
  speed) under speed-blind least-loaded, Hermes, and the SWARM
  balancer that learns per-worker slowness online from completion
  times.  Expected shape: LL counts tasks without weighing where a
  task runs slowly, so its tail pays for every task parked on a slow
  worker; SWARM's learned inverse-speed priorities recover most of
  that gap without being told the speeds.
* **frontier lane** — provisioned core-seconds × p99 slowdown.  Static
  fleets of ``W`` ∈ ``STATIC_WORKERS`` workers versus the
  ``TARGET_P99`` autoscaler (telemetry-sketch sensor, half-target
  setpoint, MIAD grow/shrink) allowed to scale within the same 8-worker
  envelope.  Expected shape: under a diurnal arrival pattern the
  autoscaler meets the p99 target while provisioning fewer
  core-seconds than the smallest static fleet that also meets it —
  static fleets pay for the peak all day.

Every row carries ``lane`` / ``provision`` / ``prov_core_s`` columns so
``BENCH_report.json`` can reconstruct the frontier.
"""
from __future__ import annotations

import time

from repro.core import (ClusterCfg, E_LL_PS, E_SWARM_PS, FleetCfg, HERMES,
                        PAPER_TESTBED, WORKLOADS, summarize)
from repro.core.simulator import simulate
from repro.telemetry import TelemetryCfg

from .common import write_csv

WORKLOAD = "azure-diurnal"

# Balancer lane: two-generation fleet, gate anchored at load 0.8.
BALANCER_FLEET = "two-gen"
BALANCER_LOAD = 0.8
BALANCER_SCHEDULERS = {"hermes": HERMES, "least-loaded": E_LL_PS,
                       "swarm": E_SWARM_PS}

# Frontier lane: static W sweep vs the TARGET_P99 closed loop.
FRONTIER_LOAD = 0.85
STATIC_WORKERS = (5, 6, 7, 8)
STATIC_CORES = PAPER_TESTBED.cores
TARGET_P99 = 3.0
AUTO_FLEET = FleetCfg(preset="uniform", autoscale="TARGET_P99",
                      target_p99=TARGET_P99, min_workers=2,
                      cooldown_s=2.0)

N_ARRIVALS = 6000


def _row(lane, scheduler, fleet, provision, load, seed, wl, out, wall):
    s = summarize(out.response, wl.service, out.cold, out.rejected,
                  out.server_time, out.core_time, out.end_time)
    return {"lane": lane, "workload": WORKLOAD, "scheduler": scheduler,
            "fleet": fleet, "provision": provision, "load": load,
            "seed": seed, "target_p99": TARGET_P99,
            "wall_s": round(wall, 3), **s.row(),
            "prov_core_s": float(out.prov_core_s)}


def _balancer_lane(loads, seed):
    wfn = WORKLOADS[WORKLOAD]
    cl = PAPER_TESTBED._replace(fleet=FleetCfg(preset=BALANCER_FLEET))
    rows = []
    for load in loads:
        wl = wfn(PAPER_TESTBED, load, N_ARRIVALS, seed=seed)
        for name, pol in BALANCER_SCHEDULERS.items():
            t0 = time.time()
            out = simulate(pol, cl, wl, backend="jax")
            rows.append(_row("balancer", name, BALANCER_FLEET, "static-8",
                             load, seed, wl, out, time.time() - t0))
    return rows


def _frontier_lane(seeds):
    wfn = WORKLOADS[WORKLOAD]
    rows = []
    for seed in seeds:
        # same trace for every provisioning point of a seed
        wl = wfn(PAPER_TESTBED, FRONTIER_LOAD, N_ARRIVALS, seed=seed)
        for wn in STATIC_WORKERS:
            t0 = time.time()
            out = simulate(HERMES, ClusterCfg(n_workers=wn,
                                              cores=STATIC_CORES),
                           wl, backend="jax")
            rows.append(_row("frontier", "hermes", "none", f"static-{wn}",
                             FRONTIER_LOAD, seed, wl, out,
                             time.time() - t0))
        cl = PAPER_TESTBED._replace(fleet=AUTO_FLEET)
        t0 = time.time()
        out = simulate(HERMES, cl, wl, backend="jax",
                       telemetry=TelemetryCfg())
        rows.append(_row("frontier", "hermes", "uniform", "auto",
                         FRONTIER_LOAD, seed, wl, out, time.time() - t0))
    return rows


def run(quick: bool = True):
    # both tiers stay at the gate-verified N; full mode widens the
    # figure (more loads on the balancer lane, more trace seeds on the
    # frontier) rather than re-scaling it
    bal_loads = [BALANCER_LOAD] if quick else [0.5, 0.65, BALANCER_LOAD]
    seeds = (1,) if quick else (1, 2, 3)
    rows = _balancer_lane(bal_loads, seed=1)
    rows += _frontier_lane(seeds)
    write_csv("fig13_autoscale.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['lane']:9s} {r['provision']:9s} {r['scheduler']:13s} "
              f"load={r['load']:.2f} seed={r['seed']} "
              f"slow99={r['slow_p99']:8.2f} "
              f"prov={r['prov_core_s']:9.0f}")
