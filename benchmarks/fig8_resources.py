"""Paper Fig. 8 — average servers/cores utilized (MS Trace workload).

Expected reproduction: cores used ≈ equal across policies; Hermes uses
markedly fewer *servers* than Least-Loaded at low load (consolidation)
while matching its slowdown.
"""
from __future__ import annotations

from .common import write_csv
from .fig6_slowdown import run as run_fig6


def run(quick: bool = True):
    rows = run_fig6(quick, workloads=("ms-trace",), zoo=False)
    res = [{"scheduler": r["scheduler"], "load": r["load"],
            "mean_servers": r["mean_servers"], "mean_cores": r["mean_cores"],
            "slow_p99": r["slow_p99"]} for r in rows]
    write_csv("fig8_resources.csv", res)
    return res


if __name__ == "__main__":
    for r in run():
        print(f"{r['scheduler']:13s} load={r['load']:.2f} "
              f"servers={r['mean_servers']:5.2f} cores={r['mean_cores']:6.2f}")
