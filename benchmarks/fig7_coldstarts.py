"""Paper Fig. 7 — % of invocations that cold-start, per scheduler,
now crossed with the container-lifecycle keep-alive axis.

Two row families (every row carries a ``keepalive`` column):

* ``legacy-inf`` — the paper's original model (warm set never expires),
  derived from fig6's batched sweep as before.  Expected reproduction:
  Hermes lowest on skewed workloads (locality-aware packing);
  Least-Loaded highest at low load (spreads 50 functions over all 8
  invokers); Vanilla lowest only on the balanced workload.
* ``NONE`` / ``FIXED_TTL`` / ``HYBRID_HIST`` — the same balancers under
  real container lifecycles (:mod:`repro.lifecycle`): executors expire,
  so the locality gap *widens* — spreading policies now pay the
  idle-timeout on every worker they touch.

Derives the legacy family from fig6's sweep (the engine compile cache
makes the re-run nearly free); the lifecycle families run their own
batched sweeps, one compiled engine per (keep-alive, scheduler).
"""
from __future__ import annotations

from repro.core import (E_LL_PS, E_LOC_PS, HERMES, LifecycleCfg,
                        PAPER_TESTBED, WORKLOADS, stack_workloads,
                        summarize)
from repro.core.simulator import simulate_many

from .common import write_csv
from .fig6_slowdown import run as run_fig6

#: keep-alive configs swept against every scheduler below.
KEEPALIVES = ("NONE", "FIXED_TTL", "HYBRID_HIST")
TTL_S = 10.0
SCHEDULERS = {"hermes": HERMES, "least-loaded": E_LL_PS,
              "vanilla-ow": E_LOC_PS}
LIFECYCLE_WORKLOADS = ("ms-trace", "azure-diurnal")


def run(quick: bool = True):
    rows = [{"workload": r["workload"], "scheduler": r["scheduler"],
             "keepalive": "legacy-inf", "load": r["load"],
             "rps": r["rps"], "cold_pct": 100.0 * r["cold_frac"]}
            for r in run_fig6(quick, zoo=False)]
    loads = [0.3, 0.7] if quick else [0.1, 0.3, 0.5, 0.7, 0.9]
    n = 4000 if quick else 15000
    for wname in LIFECYCLE_WORKLOADS:
        # one batch per workload, shared by every keep-alive config
        # (generation — incl. the trace replay — is load-independent of
        # the lifecycle axis)
        wfn = WORKLOADS[wname]
        wb = stack_workloads([wfn(PAPER_TESTBED, load, n, seed=1)
                              for load in loads])
        for ka in KEEPALIVES:
            cl = PAPER_TESTBED._replace(lifecycle=LifecycleCfg(
                keepalive=ka, ttl_s=TTL_S, coldstart="openwhisk"))
            for sname, pol in SCHEDULERS.items():
                out = simulate_many(pol, cl, wb)
                for r, load in enumerate(loads):
                    rps = wb.n / max(float(wb.arrival[r, -1]), 1e-9)
                    s = summarize(out.response[r], wb.service[r],
                                  out.cold[r], out.rejected[r],
                                  float(out.server_time[r]),
                                  float(out.core_time[r]),
                                  float(out.end_time[r]))
                    rows.append({"workload": wname, "scheduler": sname,
                                 "keepalive": ka, "load": load,
                                 "rps": round(rps, 2),
                                 "cold_pct": 100.0 * s.cold_frac})
    write_csv("fig7_coldstarts.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['workload']:18s} {r['scheduler']:13s} "
              f"ka={r['keepalive']:12s} load={r['load']:.2f} "
              f"cold%={r['cold_pct']:5.1f}")
