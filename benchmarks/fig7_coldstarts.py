"""Paper Fig. 7 — % of invocations that cold-start, per scheduler.

Expected reproduction: Hermes lowest on skewed workloads (locality-aware
packing); Least-Loaded highest at low load (spreads 50 functions over
all 8 invokers); Vanilla lowest only on the balanced workload.

Derives from fig6's batched sweep; the engine compile cache makes the
re-run nearly free.
"""
from __future__ import annotations

from .common import write_csv
from .fig6_slowdown import run as run_fig6


def run(quick: bool = True):
    rows = run_fig6(quick, zoo=False)
    cold = [{"workload": r["workload"], "scheduler": r["scheduler"],
             "load": r["load"], "rps": r["rps"],
             "cold_pct": 100.0 * r["cold_frac"]} for r in rows]
    write_csv("fig7_coldstarts.csv", cold)
    return cold


if __name__ == "__main__":
    for r in run():
        print(f"{r['workload']:18s} {r['scheduler']:13s} "
              f"load={r['load']:.2f} cold%={r['cold_pct']:5.1f}")
