"""Fig. 11 (extension) — the policy zoo: registry balancers swept together.

The registry (:mod:`repro.policy`) makes the paper's policy space open:
this sweep runs ``E/<B>/PS`` for *every* registered balancer — the §3
taxonomy entries plus the zoo: ``JSQ2`` (power-of-two-choices), ``RR``
(round-robin), and the carried-state pair ``HIKU`` (pull-based
ready-ring, Akbari & Hauswirth 2025) and ``DD`` (data-driven
per-function estimates, Przybylski et al. 2021) — all on the batched
``simulate_many`` engine.  Three lanes:

* ``ms-trace`` — the Azure-shaped workload on the paper's small
  cluster, the original fig11 lane.  Expected shape (classic
  balls-into-bins / Lesson 2): two samples (``JSQ2``) close most of the
  random-vs-least-loaded gap; blind rotation (``RR``) does not;
  ``HIKU`` tracks ``LL`` (popping an advertised idle worker ≈ joining a
  zero-length queue) at a fraction of the state reads.
* ``bimodal-exec`` — per-function bimodal durations, the regime where
  ``DD``'s learned estimates carry real information: expected-load
  dispatch beats size-blind random placement.
* ``mixed`` — synthetic + ``azure-*`` trace replays stacked into ONE
  ``simulate_many`` batch (:func:`benchmarks.common.mixed_workload_batch`
  — the ROADMAP mixed-batches item): every zoo balancer is exercised
  under stationary and non-stationary arrivals in a single compiled
  program per policy.

Every row carries a ``workload`` column naming its lane.
"""
from __future__ import annotations

from repro.core import PAPER_SMALL, ZOO_POLICIES, bimodal_exec, ms_trace

from .common import (registry_policies, sweep_policies,
                     sweep_policies_mixed, write_csv)

# The mixed lane: stationary synthetic + non-stationary trace replays
# in one batch (resampled onto a shared (N, F) shape).
MIXED_WORKLOADS = ("ms-trace", "azure-diurnal", "azure-bursty")
MIXED_LOAD = 0.7


def run(quick: bool = True):
    loads = [0.5, 0.7, 0.8, 0.9] if quick else \
        [0.3, 0.4, 0.5, 0.6, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95]
    n = 6000 if quick else 20000
    pols = registry_policies(ZOO_POLICIES)
    rows = [dict(r, workload="ms-trace")
            for r in sweep_policies(pols, PAPER_SMALL, loads, n, ms_trace)]
    rows += [dict(r, workload="bimodal-exec")
             for r in sweep_policies(pols, PAPER_SMALL, loads, n,
                                     bimodal_exec)]
    rows += sweep_policies_mixed(pols, PAPER_SMALL, MIXED_WORKLOADS,
                                 MIXED_LOAD, n // 2)
    write_csv("fig11_policy_zoo.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['workload']:14s} {r['policy']:10s} "
              f"load={r['load']:.2f} slow50={r['slow_p50']:8.2f} "
              f"slow99={r['slow_p99']:10.1f} "
              f"cold%={100 * r['cold_frac']:5.1f}")
