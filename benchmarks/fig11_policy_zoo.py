"""Fig. 11 (extension) — the policy zoo: registry balancers swept together.

The registry (:mod:`repro.policy`) makes the paper's policy space open:
this sweep runs the §3 taxonomy balancers alongside the two registered
extensions — ``JSQ2`` (power-of-two-choices sampling) and ``RR``
(round-robin) — under the Azure-shaped workload on the paper's small
cluster, all on the batched ``simulate_many`` engine.

Expected shape of the result (classic balls-into-bins / the paper's
Lesson 2): sampling *two* queues closes most of the gap between blind
random/round-robin placement and full least-loaded information —
``E/JSQ2/PS`` tracks ``E/LL/PS`` closely on p99 slowdown while ``E/R/PS``
and ``E/RR/PS`` degrade at high load; Hermes adds its warm-executor /
packing advantages on top.
"""
from __future__ import annotations

from repro.core import PAPER_SMALL, ZOO_POLICIES, ms_trace

from .common import sweep_policies, write_csv


def run(quick: bool = True):
    loads = [0.5, 0.7, 0.8, 0.9] if quick else \
        [0.3, 0.4, 0.5, 0.6, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95]
    n = 6000 if quick else 20000
    rows = sweep_policies(ZOO_POLICIES, PAPER_SMALL, loads, n, ms_trace)
    write_csv("fig11_policy_zoo.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['policy']:10s} load={r['load']:.2f} "
              f"slow50={r['slow_p50']:8.2f} slow99={r['slow_p99']:10.1f} "
              f"cold%={100 * r['cold_frac']:5.1f}")
