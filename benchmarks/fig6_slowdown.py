"""Paper Fig. 6 — 99% slowdown of the §6 baselines on four workloads,
with cold starts modeled (8 invokers × 12 cores — the paper's testbed).

Expected reproduction: Vanilla OpenWhisk (E/LOC/PS) explodes early on
skewed workloads; Late Binding saturates ~40% below Least-Loaded/Hermes;
Hermes ≤ Least-Loaded everywhere (locality) and only on the zero-skew
Multiple-Functions-Balanced workload does Vanilla look good.

Engine note: this figure used to drive the event-driven
``ServingCluster`` python loop per (workload × load × scheduler) cell.
With no stragglers/re-dispatch configured that platform is semantically
the simulator with ``cold_start_penalty=cold_start_s`` plus a constant
controller decision latency added per response — so the sweep now runs
on the batched JAX engine: one ``simulate_many`` call per (workload ×
scheduler) covering every load point, with the compile cache shared
across fig6/7/8/9.
"""
from __future__ import annotations

import time

from repro.core import (E_LL_PS, E_LOC_PS, HERMES, LATE_BINDING,
                        PAPER_TESTBED, WORKLOADS, stack_workloads,
                        summarize)
from repro.core.simulator import simulate_many

from .common import write_csv

SCHEDULERS = {"vanilla-ow": E_LOC_PS, "late-binding": LATE_BINDING,
              "least-loaded": E_LL_PS, "hermes": HERMES}


def schedulers() -> dict:
    """The §6 baselines plus one ``zoo-<b>`` entry per registry balancer.

    Balancers already covered by a named baseline (LOC/LL/H under PS)
    are not duplicated; anything registered later joins the sweep
    automatically (expansion delegated to
    :func:`benchmarks.common.registry_policies` so every figure shares
    one expansion rule).
    """
    from repro.policy import canonical_name

    from .common import registry_policies
    out = dict(SCHEDULERS)
    covered = {p.name for p in out.values()}
    for pol in registry_policies(tuple(out.values())):
        if pol.name not in covered:
            out[f"zoo-{canonical_name(pol.balance).lower()}"] = pol
    return out
FIG6_WORKLOADS = ("ms-trace", "ms-representative", "single-function",
                  "multi-balanced")
# Controller decision latency added to every completed response (§6.6,
# matches ServeCfg.ctrl_latency_s).
CTRL_LATENCY_S = 0.0005


def run(quick: bool = True, *, workloads=FIG6_WORKLOADS,
        cold_start_s: float = 0.5, zoo: bool = True):
    """``zoo=False`` restricts to the §6 baselines — fig7/8/9 derive
    from this sweep and only gate baselines, so they skip re-running
    the registry zoo."""
    loads = [0.3, 0.5, 0.7, 0.85] if quick else \
        [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
    n = 4000 if quick else 15000
    cl = PAPER_TESTBED._replace(cold_start_penalty=cold_start_s)
    scheds = schedulers() if zoo else dict(SCHEDULERS)
    rows = []
    for wname in workloads:
        wfn = WORKLOADS[wname]
        wb = stack_workloads(
            [wfn(PAPER_TESTBED, load, n, seed=1) for load in loads])
        for sname, pol in scheds.items():
            t0 = time.time()
            out = simulate_many(pol, cl, wb)
            cell_s = (time.time() - t0) / len(loads)
            for r, load in enumerate(loads):
                rps = wb.n / max(float(wb.arrival[r, -1]), 1e-9)
                s = summarize(out.response[r] + CTRL_LATENCY_S,
                              wb.service[r], out.cold[r], out.rejected[r],
                              float(out.server_time[r]),
                              float(out.core_time[r]),
                              float(out.end_time[r]))
                rows.append({"workload": wname, "scheduler": sname,
                             "load": load, "rps": round(rps, 2),
                             "wall_s": round(cell_s, 3), **s.row()})
    write_csv("fig6_slowdown.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['workload']:18s} {r['scheduler']:13s} "
              f"load={r['load']:.2f} slow99={r['slow_p99']:10.1f} "
              f"cold%={100*r['cold_frac']:5.1f}")
