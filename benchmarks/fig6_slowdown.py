"""Paper Fig. 6 — 99% slowdown of the §6 baselines on four workloads,
run on the *serving platform* (cold starts modeled, 8 invokers × 12
cores — the paper's testbed).

Expected reproduction: Vanilla OpenWhisk (E/LOC/PS) explodes early on
skewed workloads; Late Binding saturates ~40% below Least-Loaded/Hermes;
Hermes ≤ Least-Loaded everywhere (locality) and only on the zero-skew
Multiple-Functions-Balanced workload does Vanilla look good.
"""
from __future__ import annotations

import time

from repro.core import (E_LL_PS, E_LOC_PS, HERMES, LATE_BINDING,
                        PAPER_TESTBED, WORKLOADS, summarize)
from repro.serving.engine import ServeCfg, ServingCluster

from .common import write_csv

SCHEDULERS = {"vanilla-ow": E_LOC_PS, "late-binding": LATE_BINDING,
              "least-loaded": E_LL_PS, "hermes": HERMES}
FIG6_WORKLOADS = ("ms-trace", "ms-representative", "single-function",
                  "multi-balanced")


def run(quick: bool = True, *, workloads=FIG6_WORKLOADS,
        cold_start_s: float = 0.5):
    loads = [0.3, 0.5, 0.7, 0.85] if quick else \
        [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
    n = 4000 if quick else 15000
    cfg = ServeCfg(cluster=PAPER_TESTBED, cold_start_s=cold_start_s)
    rows = []
    for wname in workloads:
        wfn = WORKLOADS[wname]
        for load in loads:
            wl = wfn(PAPER_TESTBED, load, n, seed=1)
            rps = wl.n / max(wl.horizon, 1e-9)
            for sname, pol in SCHEDULERS.items():
                t0 = time.time()
                out = ServingCluster(cfg, pol).run(wl)
                s = summarize(out.response, wl.service, out.cold,
                              out.rejected, out.server_time, out.core_time,
                              out.end_time)
                rows.append({"workload": wname, "scheduler": sname,
                             "load": load, "rps": round(rps, 2),
                             "wall_s": round(time.time() - t0, 2),
                             **s.row()})
    write_csv("fig6_slowdown.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['workload']:18s} {r['scheduler']:13s} "
              f"load={r['load']:.2f} slow99={r['slow_p99']:10.1f} "
              f"cold%={100*r['cold_frac']:5.1f}")
