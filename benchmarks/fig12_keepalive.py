"""Fig. 12 (extension) — the keep-alive axis: lifecycle policies swept.

The container-lifecycle subsystem (:mod:`repro.lifecycle`) makes
keep-alive a sweepable scheduling axis like the balancer space.  Two
lanes, both on the batched ``simulate_many`` engine:

* **budget lane** (``azure-cold-heavy``) — Hermes under ``NONE`` /
  ``FIXED_TTL`` / ``HYBRID_HIST`` at one *equal* per-worker warm-pool
  budget.  Expected shape: the learned per-function windows of
  ``HYBRID_HIST`` (Shahrad et al. ATC'20) cover each pool's actual
  reuse intervals and release the rest of the budget early, so it
  cold-starts less than one-size-fits-all ``FIXED_TTL``; ``NONE`` is
  the cold-start upper bound.
* **balancer lane** (``azure-diurnal``) — every lifecycle-relevant
  baseline (Hermes, least-loaded, vanilla LOC) under ``FIXED_TTL``:
  locality-aware packing must keep its cold-start edge over
  least-loaded once executors actually expire (the Fig 7 story with a
  finite keep-alive, where it is harder — LL's spreading now pays the
  idle-timeout on every worker).

Every row carries a ``keepalive`` column so ``BENCH_report.json``
distinguishes lifecycle configs in the perf trajectory.
"""
from __future__ import annotations

import time

from repro.core import (E_LL_PS, E_LOC_PS, HERMES, LifecycleCfg,
                        PAPER_TESTBED, WORKLOADS, stack_workloads,
                        summarize)
from repro.core.simulator import simulate_many

from .common import write_csv

# Budget lane: equal warm-pool budget, TTL short enough that rare
# functions' reuse intervals straddle it (HYBRID_HIST's histogram spans
# 4x the TTL, so it can learn windows FIXED_TTL cannot express).
BUDGET_WORKLOAD = "azure-cold-heavy"
BUDGET_TTL_S = 10.0
BUDGET_MAX_IDLE = 4
BUDGET_KEEPALIVES = ("NONE", "FIXED_TTL", "HYBRID_HIST")

# Balancer lane: the Fig 7 locality story under a finite keep-alive.
BALANCER_WORKLOAD = "azure-diurnal"
BALANCER_TTL_S = 10.0
BALANCER_SCHEDULERS = {"hermes": HERMES, "least-loaded": E_LL_PS,
                       "vanilla-ow": E_LOC_PS}

COLD_PRESET = "openwhisk"


def _batch(wname, loads, n, seed=1):
    wfn = WORKLOADS[wname]
    return stack_workloads([wfn(PAPER_TESTBED, load, n, seed=seed)
                            for load in loads])


def _sweep(wname, wb, cluster, schedulers, keepalive, loads):
    rows = []
    for sname, pol in schedulers.items():
        t0 = time.time()
        out = simulate_many(pol, cluster, wb)
        cell_s = (time.time() - t0) / len(loads)
        for r, load in enumerate(loads):
            s = summarize(out.response[r], wb.service[r], out.cold[r],
                          out.rejected[r], float(out.server_time[r]),
                          float(out.core_time[r]),
                          float(out.end_time[r]))
            rows.append({"workload": wname, "scheduler": sname,
                         "keepalive": keepalive, "load": load,
                         "wall_s": round(cell_s, 3), **s.row()})
    return rows


def run(quick: bool = True):
    loads = [0.3, 0.7] if quick else [0.2, 0.3, 0.5, 0.7, 0.85]
    n = 4000 if quick else 15000
    rows = []
    wb = _batch(BUDGET_WORKLOAD, loads, n)   # shared across keep-alives
    for ka in BUDGET_KEEPALIVES:
        cl = PAPER_TESTBED._replace(lifecycle=LifecycleCfg(
            keepalive=ka, ttl_s=BUDGET_TTL_S, max_idle=BUDGET_MAX_IDLE,
            coldstart=COLD_PRESET))
        rows += _sweep(BUDGET_WORKLOAD, wb, cl, {"hermes": HERMES}, ka,
                       loads)
    cl = PAPER_TESTBED._replace(lifecycle=LifecycleCfg(
        keepalive="FIXED_TTL", ttl_s=BALANCER_TTL_S,
        coldstart=COLD_PRESET))
    rows += _sweep(BALANCER_WORKLOAD, _batch(BALANCER_WORKLOAD, loads, n),
                   cl, BALANCER_SCHEDULERS, "FIXED_TTL", loads)
    write_csv("fig12_keepalive.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['workload']:18s} {r['scheduler']:13s} "
              f"ka={r['keepalive']:12s} load={r['load']:.2f} "
              f"cold%={100 * r['cold_frac']:5.1f} "
              f"slow99={r['slow_p99']:10.1f}")
