"""Benchmark harness entry point: one module per paper table/figure.

``python -m benchmarks.run [--quick|--full]`` executes every benchmark
(``--quick``, the default, is sized for a single-core CPU container and
is the tier the ``bench-smoke`` CI job gates on), writes one CSV per
figure under ``experiments/``, prints a compact summary, checks the
paper's headline claims (printed as REPRO-CHECK lines) and emits a
machine-readable ``experiments/BENCH_report.json`` (uploaded as a CI
artifact) with every check verdict and all figure rows.

Every figure sweep runs on the batched engine: per policy, all load
points are stacked into one ``simulate_many`` call, and the process-wide
compile cache (keyed on ``(policy, cluster, N, F)``) means each distinct
engine is traced + compiled exactly once across the whole harness.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_CHECKS: list = []


def _claim(name: str, ok: bool, detail: str) -> bool:
    print(f"REPRO-CHECK {'PASS' if ok else 'FAIL'}  {name}: {detail}")
    _CHECKS.append({"name": name, "ok": bool(ok), "detail": detail})
    return ok


def _by(rows, **kv):
    out = [r for r in rows
           if all(r[k] == v for k, v in kv.items())]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="tiny-N/R smoke sweeps (the default; this is "
                           "what CI's bench-smoke job runs)")
    mode.add_argument("--full", action="store_true",
                      help="paper-scale sweeps (hours)")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable span tracing (repro.telemetry): exports "
                         "a Perfetto-loadable Chrome trace JSON with "
                         "engine build/compile/run and figure-phase "
                         "spans")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="trace JSON path (default "
                         "experiments/trace_bench.json; implies "
                         "--telemetry)")
    ap.add_argument("--timeline-out", default=None, metavar="PATH",
                    help="export the fig15 decision-lane flight "
                         "recorder: per-window CSV at PATH plus an "
                         "OpenMetrics text sibling at PATH.om; with "
                         "tracing on, the windows also land in the "
                         "trace JSON as Perfetto counter tracks")
    args = ap.parse_args()
    quick = not args.full
    # monotonic clock for elapsed time (immune to wall-clock steps);
    # wall-clock start is recorded separately in the manifest
    t_start = time.perf_counter()
    ok = True

    from repro.telemetry import (collect_manifest, configure_tracing,
                                 get_tracer, wall_split_from_aggregate)
    trace_on = bool(args.telemetry or args.trace_out)
    if trace_on:
        configure_tracing(True)
    tracer = get_tracer()
    manifest = collect_manifest(
        seeds={"workload_base": 0},
        args={"mode": "quick" if quick else "full",
              "telemetry": trace_on})

    from . import (bench_telemetry, fig2_policy_space, fig3_srpt,
                   fig4_scale, fig6_slowdown, fig7_coldstarts,
                   fig8_resources, fig9_robustness, fig10_trace_replay,
                   fig11_policy_zoo, fig12_keepalive, fig13_autoscale,
                   fig14_stream, fig15_timeline, tab_overhead)

    print("== fig2: policy space (4x12 cores, Azure workload) ==",
          flush=True)
    with tracer.span("fig2"):
        f2 = fig2_policy_space.run(quick)
    hi = [r for r in f2 if r["load"] == 0.8]
    ps = next(r for r in hi if r["policy"] == "E/LL/PS")
    late = next(r for r in hi if r["policy"] == "L/LL/FCFS")
    loc = next(r for r in hi if r["policy"] == "E/LOC/PS")
    ok &= _claim("L1: PS beats Late Binding on p99 slowdown @0.8",
                 ps["slow_p99"] < late["slow_p99"],
                 f"E/LL/PS={ps['slow_p99']:.1f} vs Late={late['slow_p99']:.1f}")
    ok &= _claim("L2: LL beats LOC on p99 slowdown @0.8",
                 ps["slow_p99"] < loc["slow_p99"],
                 f"E/LL/PS={ps['slow_p99']:.1f} vs E/LOC/PS={loc['slow_p99']:.1f}")
    lat_ratio = ps["lat_p99"] / max(late["lat_p99"], 1e-9)
    ok &= _claim("Fig2a: p99 *latency* hides the gap (ratio ~1)",
                 0.2 < lat_ratio < 5.0, f"lat99 ratio={lat_ratio:.2f}")
    lo2 = [r for r in f2 if r["load"] == 0.3]
    hiku2 = next(r for r in lo2 if r["policy"] == "E/HIKU/PS")
    ll2 = next(r for r in lo2 if r["policy"] == "E/LL/PS")
    ok &= _claim("Zoo: pull-based HIKU ≤ LL mean slowdown at low load "
                 "(popping an advertised idle worker ≈ joining an empty "
                 "queue)",
                 hiku2["slow_mean"] <= ll2["slow_mean"] * 1.05,
                 f"HIKU={hiku2['slow_mean']:.3f} vs "
                 f"LL={ll2['slow_mean']:.3f} @0.3")

    print("== fig3: SRPT vs PS ==", flush=True)
    with tracer.span("fig3"):
        f3 = fig3_srpt.run(quick)
    hi3 = [r for r in f3 if r["load"] == max(r["load"] for r in f3)]
    srpt = next(r for r in hi3 if r["policy"] == "E/LL/SRPT")
    psr = next(r for r in hi3 if r["policy"] == "E/LL/PS")
    ok &= _claim("L3 (median half): oracle SRPT's median ≤ PS's at high "
                 "load", srpt["slow_p50"] <= psr["slow_p50"] * 1.05,
                 f"p50 {srpt['slow_p50']:.2f} <= {psr['slow_p50']:.2f}")
    # Tail half of L3 (SRPT p99 ≫ PS p99) does NOT reproduce at stable
    # loads under drain-complete measurement — documented deviation, see
    # EXPERIMENTS.md §Fig3.  We report the observation instead of gating.
    print(f"  [L3 tail observation] SRPT p99={srpt['slow_p99']:.1f} vs "
          f"PS p99={psr['slow_p99']:.1f} at load={srpt['load']} "
          f"(paper expects SRPT ≫ PS; see EXPERIMENTS.md)")

    print("== fig4: 100-server scale ==", flush=True)
    with tracer.span("fig4"):
        f4 = fig4_scale.run(quick)
    hi4 = [r for r in f4 if r["load"] == 0.9]
    ll = next(r for r in hi4 if r["policy"] == "E/LL/PS")
    lb100 = next(r for r in hi4 if r["policy"] == "L/LL/FCFS")
    loc100 = next(r for r in hi4 if r["policy"] == "E/LOC/PS")
    r100 = next(r for r in hi4 if r["policy"] == "E/R/PS")
    lb4 = next(r for r in f2 if r["policy"] == "L/LL/FCFS"
               and r["load"] == 0.9)
    ok &= _claim("§3.5: Late Binding improves dramatically with scale "
                 "(100 vs 4 servers @0.9)",
                 lb100["slow_p99"] < 0.1 * lb4["slow_p99"],
                 f"W=100: {lb100['slow_p99']:.1f} vs W=4: "
                 f"{lb4['slow_p99']:.1f}")
    ok &= _claim("§3.5: LOC/R still degrade at scale, LL does not @0.9",
                 ll["slow_p99"] < loc100["slow_p99"]
                 and ll["slow_p99"] < r100["slow_p99"],
                 f"LL={ll['slow_p99']:.1f} LOC={loc100['slow_p99']:.1f} "
                 f"R={r100['slow_p99']:.1f}")
    # The >0.96 LL-vs-Late crossover needs multi-hour traces at W=100 to
    # materialize under calibrated load (2% overload accumulates too
    # slowly in a 5-minute window) — observation reported, not gated.
    hi97 = [r for r in f4 if r["load"] == max(r["load"] for r in f4)]
    ll97 = next(r for r in hi97 if r["policy"] == "E/LL/PS")
    lb97 = next(r for r in hi97 if r["policy"] == "L/LL/FCFS")
    print(f"  [§3.5 observation @load={ll97['load']}] "
          f"E/LL/PS p99={ll97['slow_p99']:.1f} vs "
          f"Late p99={lb97['slow_p99']:.1f} (paper: LL wins >0.96)")

    print("== fig6/7/8: serving platform (cold starts) ==", flush=True)
    with tracer.span("fig6"):
        f6 = fig6_slowdown.run(quick)
    lo = _by(f6, workload="ms-trace", load=0.3)
    hermes = next(r for r in lo if r["scheduler"] == "hermes")
    vanilla = next(r for r in lo if r["scheduler"] == "vanilla-ow")
    ok &= _claim("§6.2: Hermes ≥50% lower p99 slowdown than vanilla "
                 "OpenWhisk at low load",
                 hermes["slow_p99"] < 0.5 * vanilla["slow_p99"],
                 f"hermes={hermes['slow_p99']:.1f} vs "
                 f"vanilla={vanilla['slow_p99']:.1f}")
    lor = _by(f6, workload="ms-representative", load=0.3)
    hermes = next(r for r in lor if r["scheduler"] == "hermes")
    ll6 = next(r for r in lor if r["scheduler"] == "least-loaded")
    ok &= _claim("§6.2: Hermes ≤ least-loaded slowdown (locality win)",
                 hermes["slow_p99"] <= ll6["slow_p99"] * 1.1,
                 f"hermes={hermes['slow_p99']:.1f} vs "
                 f"LL={ll6['slow_p99']:.1f}")
    ok &= _claim("§6.3: Hermes fewer cold starts than least-loaded",
                 hermes["cold_frac"] < ll6["cold_frac"],
                 f"{100*hermes['cold_frac']:.1f}% < "
                 f"{100*ll6['cold_frac']:.1f}%")
    with tracer.span("fig8"):
        f8 = fig8_resources.run(quick)
    lo8 = [r for r in f8 if r["load"] == 0.3]
    h8 = next(r for r in lo8 if r["scheduler"] == "hermes")
    l8 = next(r for r in lo8 if r["scheduler"] == "least-loaded")
    ok &= _claim("§6.4: Hermes uses fewer servers than least-loaded "
                 "at low load", h8["mean_servers"] < l8["mean_servers"],
                 f"{h8['mean_servers']:.2f} < {l8['mean_servers']:.2f}")
    with tracer.span("fig7"):
        fig7_coldstarts.run(quick)

    print("== fig9: homogeneous exec times ==", flush=True)
    with tracer.span("fig9"):
        f9 = fig9_robustness.run(quick)
    hi9 = _by(f9, load=0.7)
    h9 = next(r for r in hi9 if r["scheduler"] == "hermes")
    l9 = next(r for r in hi9 if r["scheduler"] == "least-loaded")
    ok &= _claim("§6.5: Hermes ≈ least-loaded on light-tailed workload",
                 h9["slow_p99"] <= l9["slow_p99"] * 1.5 + 5,
                 f"hermes={h9['slow_p99']:.1f} vs LL={l9['slow_p99']:.1f}")

    print("== fig10: non-stationary Azure-schema trace replay ==",
          flush=True)
    with tracer.span("fig10"):
        f10 = fig10_trace_replay.run(quick)
    d10 = _by(f10, workload="azure-diurnal", load=0.5)
    h10 = next(r for r in d10 if r["scheduler"] == "hermes")
    v10 = next(r for r in d10 if r["scheduler"] == "vanilla-ow")
    l10 = next(r for r in d10 if r["scheduler"] == "least-loaded")
    ok &= _claim("Trace replay: Hermes ≥50% below vanilla OW p99 slowdown "
                 "under diurnal load",
                 h10["slow_p99_mean"] < 0.5 * v10["slow_p99_mean"],
                 f"hermes={h10['slow_p99_mean']:.1f}"
                 f"±{h10['slow_p99_ci95']:.1f} vs "
                 f"vanilla={v10['slow_p99_mean']:.1f}"
                 f"±{v10['slow_p99_ci95']:.1f}")
    ok &= _claim("Trace replay: Hermes fewer cold starts than "
                 "least-loaded under diurnal load",
                 h10["cold_frac_mean"] < l10["cold_frac_mean"],
                 f"{100 * h10['cold_frac_mean']:.1f}% < "
                 f"{100 * l10['cold_frac_mean']:.1f}%")
    b10 = _by(f10, workload="azure-bursty", load=0.7)
    hb = next(r for r in b10 if r["scheduler"] == "hermes")
    lb = next(r for r in b10 if r["scheduler"] == "least-loaded")
    print(f"  [bursty @0.7 observation] hermes "
          f"p99={hb['slow_p99_mean']:.1f}±{hb['slow_p99_ci95']:.1f} vs "
          f"least-loaded p99={lb['slow_p99_mean']:.1f}"
          f"±{lb['slow_p99_ci95']:.1f}")

    print("== fig11: policy zoo (full registry: JSQ2, RR, HIKU, DD) ==",
          flush=True)
    with tracer.span("fig11"):
        f11 = fig11_policy_zoo.run(quick)
    hi11 = _by(f11, workload="ms-trace", load=0.9)
    jsq2 = next(r for r in hi11 if r["policy"] == "E/JSQ2/PS")
    r11 = next(r for r in hi11 if r["policy"] == "E/R/PS")
    ll11 = next(r for r in hi11 if r["policy"] == "E/LL/PS")
    rr11 = next(r for r in hi11 if r["policy"] == "E/RR/PS")
    hiku11 = next(r for r in hi11 if r["policy"] == "E/HIKU/PS")
    ok &= _claim("Zoo: two choices beat one — E/JSQ2/PS p99 < E/R/PS @0.9",
                 jsq2["slow_p99"] < r11["slow_p99"],
                 f"JSQ2={jsq2['slow_p99']:.1f} vs R={r11['slow_p99']:.1f}")
    ok &= _claim("Zoo: JSQ2 tracks full-information LL (≤1.5x p99) @0.9",
                 jsq2["slow_p99"] <= 1.5 * ll11["slow_p99"],
                 f"JSQ2={jsq2['slow_p99']:.1f} vs LL={ll11['slow_p99']:.1f}")
    print(f"  [zoo observation @0.9] RR p99={rr11['slow_p99']:.1f} "
          f"(blind rotation, between R and JSQ2); "
          f"HIKU p99={hiku11['slow_p99']:.1f} vs "
          f"LL p99={ll11['slow_p99']:.1f}")
    bi11 = _by(f11, workload="bimodal-exec", load=0.8)
    dd11 = next(r for r in bi11 if r["policy"] == "E/DD/PS")
    rb11 = next(r for r in bi11 if r["policy"] == "E/R/PS")
    ok &= _claim("Zoo: data-driven DD beats size-blind R on bimodal "
                 "durations @0.8 (learned per-function estimates)",
                 dd11["slow_p99"] < rb11["slow_p99"],
                 f"DD={dd11['slow_p99']:.1f} vs R={rb11['slow_p99']:.1f}")
    mx = _by(f11, workload="azure-bursty",
             load=fig11_policy_zoo.MIXED_LOAD)
    if mx:
        mh = next(r for r in mx if r["policy"] == "E/HIKU/PS")
        md = next(r for r in mx if r["policy"] == "E/DD/PS")
        ml = next(r for r in mx if r["policy"] == "E/LL/PS")
        print(f"  [mixed-batch observation: bursty replay @"
              f"{fig11_policy_zoo.MIXED_LOAD}] "
              f"HIKU p99={mh['slow_p99']:.1f} DD p99={md['slow_p99']:.1f} "
              f"LL p99={ml['slow_p99']:.1f}")

    print("== fig12: container lifecycle / keep-alive axis ==", flush=True)
    with tracer.span("fig12"):
        f12 = fig12_keepalive.run(quick)
    bud = _by(f12, workload=fig12_keepalive.BUDGET_WORKLOAD,
              scheduler="hermes")
    cold_of = {ka: sum(r["cold_frac"] for r in bud if r["keepalive"] == ka)
               for ka in fig12_keepalive.BUDGET_KEEPALIVES}
    ok &= _claim("Lifecycle: HYBRID_HIST fewer cold starts than "
                 "FIXED_TTL at equal warm-pool budget (learned "
                 "per-function windows)",
                 cold_of["HYBRID_HIST"] < cold_of["FIXED_TTL"],
                 f"HYBRID={cold_of['HYBRID_HIST']:.3f} vs "
                 f"FIXED={cold_of['FIXED_TTL']:.3f} "
                 f"(summed cold_frac across loads)")
    ok &= _claim("Lifecycle: NONE is the cold-start upper bound",
                 cold_of["NONE"] >= cold_of["FIXED_TTL"]
                 and cold_of["NONE"] >= cold_of["HYBRID_HIST"],
                 f"NONE={cold_of['NONE']:.3f}")
    bal12 = _by(f12, workload=fig12_keepalive.BALANCER_WORKLOAD)
    h12 = sum(r["cold_frac"] for r in bal12 if r["scheduler"] == "hermes")
    l12 = sum(r["cold_frac"] for r in bal12
              if r["scheduler"] == "least-loaded")
    ok &= _claim("Lifecycle: Hermes keeps its cold-start edge over LL "
                 "under FIXED_TTL on azure-diurnal",
                 h12 < l12,
                 f"hermes={h12:.3f} vs LL={l12:.3f} "
                 f"(summed cold_frac across loads)")

    print("== fig13: heterogeneous fleet / latency-target autoscaling ==",
          flush=True)
    with tracer.span("fig13"):
        f13 = fig13_autoscale.run(quick)
    bal13 = _by(f13, lane="balancer", load=fig13_autoscale.BALANCER_LOAD)
    sw13 = next(r for r in bal13 if r["scheduler"] == "swarm")
    ll13 = next(r for r in bal13 if r["scheduler"] == "least-loaded")
    ok &= _claim("Fleet: SWARM ≤ speed-blind LL p99 slowdown on a "
                 "two-gen fleet @0.8 (learned per-worker slowness)",
                 sw13["slow_p99"] <= ll13["slow_p99"],
                 f"SWARM={sw13['slow_p99']:.2f} vs LL={ll13['slow_p99']:.2f}")
    fr13 = _by(f13, lane="frontier")
    tgt13 = fig13_autoscale.TARGET_P99
    auto_ok, auto_bits = True, []
    for seed in sorted({r["seed"] for r in fr13}):
        sr = _by(fr13, seed=seed)
        auto = next(r for r in sr if r["provision"] == "auto")
        meet = [r for r in sr if r["provision"] != "auto"
                and r["slow_p99"] <= tgt13]
        # smallest static fleet that meets the target (upper bound inf
        # if none does: the autoscaler then only has to meet the target)
        best = min(meet, key=lambda r: r["prov_core_s"]) if meet else None
        cap = best["prov_core_s"] if best else float("inf")
        auto_ok &= (auto["slow_p99"] <= tgt13
                    and auto["prov_core_s"] < cap)
        auto_bits.append(
            f"seed{seed}: p99={auto['slow_p99']:.2f} "
            f"prov={auto['prov_core_s']:.0f} vs "
            f"{best['provision'] if best else 'none'}="
            f"{cap:.0f}")
    ok &= _claim("Fleet: TARGET_P99 autoscaler meets the p99 target with "
                 f"fewer provisioned core-seconds than the smallest "
                 f"static fleet meeting it (target={tgt13})",
                 auto_ok, "; ".join(auto_bits))

    print("== fig14: horizon-scale streaming engine ==", flush=True)
    with tracer.span("fig14"):
        f14 = fig14_stream.run(quick)
    eq14 = _by(f14, lane="equivalence")
    bad14 = [f"{r['stack']}@k{r['chunk']}: {r['mismatches']}"
             for r in eq14 if not r["ok"]]
    ok &= _claim("Streaming: chunked scan ≡ monolithic bit-for-bit "
                 "(final carry, per-arrival outputs, telemetry "
                 "sketches; per-segment vs the numpy oracle) across "
                 f"{len(eq14)} registry stacks incl. non-dividing "
                 "chunk sizes",
                 not bad14,
                 f"{len(eq14)} cells bitwise" if not bad14
                 else "; ".join(bad14))
    hz14 = _by(f14, lane="horizon")[0]
    ok &= _claim("Streaming: "
                 f"{'full' if hz14['full_day'] else 'shortened'} "
                 f"synthetic {hz14['workload']} day "
                 f"(N={hz14['n_arrivals']}) at W={hz14['n_workers']} "
                 "completes in ONE run under the peak-memory budget",
                 hz14["ok"],
                 f"peak={hz14['peak_rss_mb']:.0f}MiB ≤ "
                 f"{hz14['peak_mb_budget']:.0f}MiB, "
                 f"{hz14['n_chunks']} chunks of {hz14['chunk']}, "
                 f"{hz14['n_done']} completions, "
                 f"wall={hz14['wall_s']:.1f}s")

    print("== fig15: windowed flight-recorder timeline ==", flush=True)
    with tracer.span("fig15"):
        f15 = fig15_timeline.run(quick)
    par15 = _by(f15, lane="parity")
    bad15 = [f"{r['stack']}: {r['mismatches']}"
             for r in par15 if not r["ok"]]
    ok &= _claim("Timeline: per-window planes are exact — np oracle ≡ "
                 "jax scan (ints bitwise, integrals 1e-9) and "
                 "streamed ≡ monolithic bitwise across a non-dividing "
                 "chunking",
                 not bad15,
                 f"{len(par15)} stacks exact" if not bad15
                 else "; ".join(bad15))
    di15 = _by(f15, lane="diurnal")
    ok &= _claim("Timeline: diurnal load shape reproduced and window "
                 "counters reconcile with the exact per-arrival planes "
                 "(scan + serving platform)",
                 all(r["ok"] for r in di15),
                 "; ".join(
                     f"{r['stack']}: peak={r['arrivals_peak']}"
                     f"/med={r['arrivals_median']:.0f}"
                     + (f" {r['mismatches']}" if r["mismatches"] else "")
                     for r in di15))
    dec15 = _by(f15, lane="decision")[0]
    ok &= _claim("Timeline: decision log replays the exact n_on "
                 "trajectory (two-gen fleet + TARGET_P99 on "
                 "azure-diurnal)",
                 dec15["ok"],
                 f"{dec15['n_events']} events "
                 f"({dec15['n_autoscale']} autoscale), "
                 f"n_on∈[{dec15['n_on_min']},{dec15['n_on_max']}]"
                 + (f"; {dec15['mismatches']}"
                    if dec15["mismatches"] else ""))

    print("== §6.6: scheduler overhead ==", flush=True)
    with tracer.span("tab_overhead"):
        tov = tab_overhead.run(quick)
    py = {r["scheduler"]: r for r in tov if r["impl"] == "python"}
    ok &= _claim("§6.6: Hermes decision cost ≈ least-loaded (<2x)",
                 py["hermes(H)"]["us_per_decision"]
                 < 2.0 * py["least-loaded"]["us_per_decision"] + 20,
                 f"hermes={py['hermes(H)']['us_per_decision']:.1f}us vs "
                 f"LL={py['least-loaded']['us_per_decision']:.1f}us")
    for r in tov:
        print(f"  {r['scheduler']:16s} {r['impl']:14s} "
              f"{r['decisions_per_s']:12.0f} dec/s")

    print("== telemetry: streaming sketch vs exact percentiles ==",
          flush=True)
    with tracer.span("bench_telemetry"):
        ftel = bench_telemetry.run(quick)
    fsk = _by(ftel, lane="sketch")
    worst50 = max(r["rel_err_p50"] for r in fsk)
    worst99 = max(r["rel_err_p99"] for r in fsk)
    ok &= _claim("Telemetry: sketch p50/p99 slowdown within "
                 f"{bench_telemetry.TOL_REL:.0%} of exact "
                 "summarize_batch for every registered balancer at "
                 f"loads {bench_telemetry.LOADS}",
                 all(r["ok"] for r in fsk),
                 f"{len(fsk)} cells; worst rel err "
                 f"p50={worst50:.4f} p99={worst99:.4f}")
    fov = _by(ftel, lane="overhead")[0]
    ok &= _claim("Timeline: flight-recorder plane adds ≤"
                 f"{bench_telemetry.TOL_TL_OVERHEAD:.0%} steady-state "
                 "wall over telemetry-only",
                 fov["ok"],
                 f"tel={fov['tel_wall_s']:.3f}s vs "
                 f"+timeline={fov['tl_wall_s']:.3f}s "
                 f"({100 * fov['overhead_frac']:+.1f}%)")

    print("== analysis: jaxpr eqn budgets ==", flush=True)
    from repro.analysis import bench_rows
    analysis_rows, analysis_ok, analysis_detail = bench_rows()
    ok &= _claim("Analysis: every engine within its jaxpr eqn budget",
                 analysis_ok, analysis_detail)

    from repro.core.simulator import engine_cache_stats
    from .common import OUT_DIR
    elapsed = time.perf_counter() - t_start
    cache = engine_cache_stats()
    manifest.duration_s = round(elapsed, 3)
    manifest.engine_cache = cache
    manifest.wall_split = wall_split_from_aggregate(tracer.aggregate())
    os.makedirs(OUT_DIR, exist_ok=True)
    tl15 = fig15_timeline.LAST_TIMELINE
    timeline_paths = None
    if tl15 is not None:
        manifest.timeline = tl15.summary()
        if trace_on:
            # merge the windows into the span trace as Perfetto
            # counter tracks (one track per tracked series)
            tl15.emit_counters(tracer)
        if args.timeline_out:
            timeline_paths = (tl15.write_csv(args.timeline_out),
                              tl15.write_openmetrics(
                                  args.timeline_out + ".om"))
    trace_path = None
    if trace_on:
        trace_path = args.trace_out or \
            os.path.join(OUT_DIR, "trace_bench.json")
        tracer.export(trace_path)
    report = {
        "mode": "quick" if quick else "full",
        "started_at": manifest.started_at,
        "elapsed_s": round(elapsed, 1),
        "ok": bool(ok),
        "checks": _CHECKS,
        "engine_cache": cache,
        "manifest": manifest.as_dict(),
        "trace": trace_path,
        "analysis": analysis_rows,
        "figures": {"fig2": f2, "fig3": f3, "fig4": f4, "fig6": f6,
                    "fig8": f8, "fig9": f9, "fig10": f10, "fig11": f11,
                    "fig12": f12, "fig13": f13, "fig14": f14,
                    "fig15": f15, "tab_overhead": tov,
                    "bench_telemetry": ftel},
    }
    report_path = os.path.join(OUT_DIR, "BENCH_report.json")
    with open(report_path, "w") as f:
        json.dump(report, f, indent=1, default=float)
    hit_total = max(cache["hits"] + cache["misses"], 1)
    print(f"engine cache: {cache['entries']}/{cache['capacity']} "
          f"resident, {cache['hits']} hits / {cache['misses']} misses "
          f"({100 * cache['hits'] / hit_total:.0f}% hit rate), "
          f"{cache['evictions']} evictions")
    if timeline_paths:
        print(f"timeline: {timeline_paths[0]} (per-window CSV) + "
              f"{timeline_paths[1]} (OpenMetrics)")
    if trace_path:
        print(f"trace: {trace_path} (load at https://ui.perfetto.dev)")
    print(f"\nbenchmarks done in {elapsed:.0f}s; CSVs in "
          f"experiments/; report: {report_path}; "
          f"overall: {'PASS' if ok else 'FAIL'}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
