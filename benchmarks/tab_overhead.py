"""Paper §6.6 table — scheduler overhead / max decision throughput.

The paper measures end-to-end zero-work invocations (≈3.8 k RPS, equal
across schedulers, <0.5 ms per decision).  Here the controller *is* the
measurable unit: we time scheduling decisions per second for each
policy's decision function, plus the batched Pallas ``hermes_select``
kernel (interpret mode on CPU — on TPU the batch amortizes one HBM read
of cluster state).  The reproduction claim is relative: Hermes costs no
more than least-loaded/random — scheduling is not the bottleneck.

Keep-alive decisions are timed too (``impl="lifecycle-np"`` rows): one
"decision" is the per-placement lifecycle work a controller adds — the
materialized warm-column mask plus, for adaptive policies, the idle-gap
observation and window refit.  Rows carry a ``keepalive`` column so the
``BENCH_report.json`` trajectory separates lifecycle configs.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import PAPER_TESTBED
from repro.core.policies import select_worker_np
from repro.core.taxonomy import LoadBalance

from .common import write_csv

POLICIES = {"vanilla-ow(LOC)": LoadBalance.LOCALITY,
            "random": LoadBalance.RANDOM,
            "least-loaded": LoadBalance.LEAST_LOADED,
            "hermes(H)": LoadBalance.HYBRID,
            # registry zoo balancers (any registered name works here)
            "two-choices": "JSQ2",
            "round-robin": "RR"}


def run(quick: bool = True):
    cl = PAPER_TESTBED
    W, F, N = cl.n_workers, 50, 3000 if quick else 30000
    rng = np.random.default_rng(0)
    active = rng.integers(0, cl.slots, W)
    warm = rng.integers(0, 2, (W, F))
    funcs = rng.integers(0, F, N)
    homes = rng.integers(0, W, F).astype(np.int32)
    us = rng.uniform(size=N)
    rows = []
    for name, bal in POLICIES.items():
        t0 = time.perf_counter()
        for i in range(N):
            select_worker_np(bal, active, warm, int(funcs[i]), homes,
                             float(us[i]), cl.cores, cl.slots)
        dt = time.perf_counter() - t0
        rows.append({"scheduler": name, "impl": "python",
                     "keepalive": "-",
                     "decisions_per_s": N / dt,
                     "us_per_decision": dt / N * 1e6,
                     "compile_s": 0.0, "run_s": round(dt, 6)})
    # carried-state balancers go through the stateful contract (the
    # stateless shim rejects them): decision cost includes the
    # functional state update, the honest per-arrival price
    from repro.policy import get_balancer
    for name, label in (("HIKU", "pull-based(HIKU)"),
                        ("DD", "data-driven(DD)")):
        b = get_balancer(name)
        sel, _ = b.make_np(cl.cores, cl.slots)
        state = b.init_state(W, F)
        t0 = time.perf_counter()
        for i in range(N):
            f = int(funcs[i])
            _, state = sel(state, active, warm[:, f], f, homes,
                           float(us[i]), i)
        dt = time.perf_counter() - t0
        rows.append({"scheduler": label, "impl": "python",
                     "keepalive": "-",
                     "decisions_per_s": N / dt,
                     "us_per_decision": dt / N * 1e6,
                     "compile_s": 0.0, "run_s": round(dt, 6)})
    # keep-alive decision cost (repro.lifecycle): per placement, the
    # materialized warm-column mask + (adaptive policies) the idle-gap
    # observation and window refit — the honest lifecycle overhead a
    # controller pays on top of worker selection
    from repro.core import ClusterCfg
    from repro.lifecycle import (LifecycleCfg, LifecycleRuntime,
                                 resolve_lifecycle)
    times = np.cumsum(rng.exponential(0.1, size=N))
    for ka in ("FIXED_TTL", "HYBRID_HIST"):
        lcl = ClusterCfg(n_workers=W, cores=cl.cores,
                         lifecycle=LifecycleCfg(keepalive=ka, ttl_s=10.0))
        rt = LifecycleRuntime(
            resolve_lifecycle(lcl, backend="np", n_functions=F), W, F)
        ws = rng.integers(0, W, N)
        wpool = warm.astype(np.int64).copy()
        for j in range(4 * W * F):     # history so observations fire
            rt.on_complete(wpool, j % W, (j // W) % F, 0.0)
        t0 = time.perf_counter()
        for i in range(N):
            f = int(funcs[i])
            now = float(times[i])
            rt.materialized_col(warm[:, f], f, now)
            rt.observe_place(int(ws[i]), f, now)
        dt = time.perf_counter() - t0
        rows.append({"scheduler": f"keepalive({ka})",
                     "impl": "lifecycle-np", "keepalive": ka,
                     "decisions_per_s": N / dt,
                     "us_per_decision": dt / N * 1e6,
                     "compile_s": 0.0, "run_s": round(dt, 6)})
    # engine-plane overhead: the per-arrival price of the telemetry and
    # timeline carries through the full scan engine.  One steady-state
    # dispatch (min of 3, compile excluded) per variant; an "arrival"
    # is the decision unit, so us_per_decision is directly comparable
    # with the controller rows above.
    from repro.core import E_LL_PS, synth_workload
    from repro.core.simulator import simulate
    from repro.telemetry import TelemetryCfg, TimelineCfg
    wl = synth_workload(cl, 0.6, N, n_functions=F, seed=5)
    for label, tel, tline in (
            ("E/LL/PS(plain)", None, None),
            ("E/LL/PS(telemetry)", TelemetryCfg(), None),
            ("E/LL/PS(tel+timeline)", TelemetryCfg(), TimelineCfg())):
        t0 = time.perf_counter()
        simulate(E_LL_PS, cl, wl, backend="jax", telemetry=tel,
                 timeline=tline)
        compile_s = time.perf_counter() - t0
        dt = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            simulate(E_LL_PS, cl, wl, backend="jax", telemetry=tel,
                     timeline=tline)
            dt = min(dt, time.perf_counter() - t0)
        rows.append({"scheduler": label, "impl": "engine-jax",
                     "keepalive": "-",
                     "decisions_per_s": N / dt,
                     "us_per_decision": dt / N * 1e6,
                     "compile_s": round(compile_s, 6),
                     "run_s": round(dt, 6)})
    # batched Pallas kernel (Hermes) — sequential semantics preserved
    from repro.kernels.hermes_select.ops import hermes_select
    import jax.numpy as jnp
    a_j = jnp.asarray(active, jnp.int32)
    w_j = jnp.asarray(warm, jnp.int32)
    f_j = jnp.asarray(funcs, jnp.int32)
    t0 = time.perf_counter()
    out = hermes_select(a_j, w_j, f_j, cores=cl.cores, slots=cl.slots)
    out[0].block_until_ready()                 # compile-inclusive first call
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        out = hermes_select(a_j, w_j, f_j, cores=cl.cores, slots=cl.slots)
        out[0].block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    # compile-vs-run split: first-call wall (trace + XLA compile + run)
    # against a steady-state dispatch — the §6.6 "overhead" decomposition
    rows.append({"scheduler": "hermes(H)", "impl": "pallas-batched",
                 "keepalive": "-",
                 "decisions_per_s": N / dt,
                 "us_per_decision": dt / N * 1e6,
                 "compile_s": round(compile_s, 6),
                 "run_s": round(dt, 6)})
    write_csv("tab_overhead.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['scheduler']:16s} {r['impl']:14s} "
              f"{r['decisions_per_s']:12.0f} dec/s "
              f"{r['us_per_decision']:8.2f} us/dec")
