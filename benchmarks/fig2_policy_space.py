"""Paper Fig. 2 — tail latency (2a) and tail slowdown (2b) across the
policy taxonomy, 4 workers × 12 cores, Azure-shaped workload.

Expected reproduction: all policies look similar on p99 *latency*; on
p99 *slowdown* Late Binding and E/*/FCFS blow up early (head-of-line
blocking), PS-based policies survive, E/LL/PS is best (Lessons 1-2).

Beyond the paper's seven combinations, the sweep covers ``E/<B>/PS``
for *every* balancer in the policy registry (H, JSQ2, RR, the
carried-state HIKU and DD, and anything registered later), so zoo
entries ride through the original figure without code changes.

All load points run as one stacked batch per policy through the
``simulate_many`` engine (see :mod:`benchmarks.common`).
"""
from __future__ import annotations

from repro.core import FIG2_POLICIES, PAPER_SMALL, ms_trace

from .common import registry_policies, sweep_policies, write_csv


def run(quick: bool = True):
    loads = [0.3, 0.5, 0.6, 0.7, 0.8, 0.9, 0.95] if quick else \
        [0.1, 0.2, 0.3, 0.4, 0.5, 0.55, 0.6, 0.65, 0.7, 0.75, 0.8,
         0.85, 0.9, 0.95]
    n = 8000 if quick else 20000
    rows = sweep_policies(registry_policies(FIG2_POLICIES), PAPER_SMALL,
                          loads, n, ms_trace)
    write_csv("fig2_policy_space.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['policy']:10s} load={r['load']:.2f} "
              f"lat99={r['lat_p99']:10.2f}s slow99={r['slow_p99']:10.1f}")
