"""Fig. 15 (extension) — the windowed flight-recorder timeline plane.

Three lanes gating :mod:`repro.telemetry.timeline` (the fixed-``K``
virtual-time window plane carried through the scan, the oracle, the
serving platform and the streaming engine):

* **parity lane** — per-window values are an *exact recomputation*,
  three ways.  For a registry-spanning set of stacks (plain early
  binding, the Hermes hybrid balancer with its mode-flip log, late
  binding, and the full two-gen + ``TARGET_P99`` autoscale stack) the
  numpy oracle's timeline must match the jax scan's (integer planes
  bitwise, f64 integrals to 1e-9), and the chunked streaming engine's
  must match the monolithic scan's **bitwise** — including a chunk
  size that does not divide the horizon, so window accumulators hand
  across a padded final chunk.
* **diurnal lane** — on an ``azure-diurnal`` replay the per-window
  arrival counts must equal a host-side recomputation bitwise, the
  window counters must sum to the run's exact per-arrival planes
  (cold/warm/reject, completions into the slowdown sketch), and the
  timeline must actually *show* the trace's load shape (peak window ≫
  median window).  A serving-platform row runs the same checks through
  :class:`repro.serving.engine.ServingCluster`.
* **decision lane** — the bounded decision-event log is replayed
  (:meth:`TimelineResult.replay_n_on`) on the fig13 autoscale scenario
  (two-generation fleet + ``TARGET_P99`` on ``azure-diurnal``) and
  must reconstruct the engine's recorded per-window ``n_on`` plane
  *exactly* on every arrival-bearing window.

Every row carries ``lane`` / ``stack`` / ``ok`` / ``mismatches``
columns so ``BENCH_report.json`` can reconstruct all three gates.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ClusterCfg, E_LL_PS, FleetCfg, HERMES,
                        PAPER_TESTBED, WORKLOADS, stack_workloads,
                        synth_workload)
from repro.core.simulator import build_batch_simulator, simulate
from repro.core.sim_ref import simulate_ref
from repro.core.streaming import final_states_equal, simulate_stream
from repro.core.taxonomy import Binding, PolicySpec
from repro.telemetry import TelemetryCfg, TimelineCfg, auto_window_s
from repro.telemetry.timeline import window_index_np

from .common import write_csv

# Parity lane: the fig14 equivalence shape — small horizon, two
# replications, a chunk size that does not divide N (240 % 96 != 0) so
# the padded-tail window handoff is always exercised.
PAR_N = 240
PAR_CHUNK = 96
PAR_CLUSTER = ClusterCfg(n_workers=4, cores=3, capacity_factor=2)
PAR_LOADS = ((0.6, 0), (1.0, 1))    # (load, seed) per replication
PAR_TL = TimelineCfg(n_windows=32, coarse_bins=96, max_events=128)

# Diurnal lane: one Azure-schema diurnal replay through the scan and
# the serving platform.
DI_WORKLOAD = "azure-diurnal"
DI_LOAD = 0.5
DI_N = 4000
DI_TL = TimelineCfg()            # default 64 windows

# Decision lane: the fig13 closed-loop scenario — two-generation fleet
# under the TARGET_P99 autoscaler on a diurnal trace.  max_events is
# sized so the log is never truncated (replay_n_on refuses otherwise).
DEC_LOAD = 0.85
DEC_N = 6000
DEC_FLEET = FleetCfg(preset="two-gen", autoscale="TARGET_P99",
                     target_p99=3.0, min_workers=2, cooldown_s=2.0)
DEC_TL = TimelineCfg(max_events=512)

#: integer timeline planes — bitwise everywhere
_INT_PLANES = ("mode", "arrivals", "n_cold", "n_warm", "n_evict",
               "n_reject", "slow_hist", "lat_hist", "n_on",
               "ev_kind", "ev_val", "ev_count")
#: f64 planes — bitwise stream≡mono, 1e-9 np≡jax (accumulation order)
_FLOAT_PLANES = ("window_s", "busy_time", "qlen_time", "prov_core",
                 "ev_t", "ev_p99")


def _timelines_equal(a, b, *, bitwise_float: bool) -> list[str]:
    """Mismatched plane names between two TimelineResults."""
    bad = []
    for name in _INT_PLANES:
        if not np.array_equal(getattr(a, name), getattr(b, name)):
            bad.append(name)
    for name in _FLOAT_PLANES:
        u = np.asarray(getattr(a, name), dtype=np.float64)
        v = np.asarray(getattr(b, name), dtype=np.float64)
        if bitwise_float:
            ok = u.shape == v.shape and np.array_equal(u, v,
                                                       equal_nan=True)
        else:
            ok = u.shape == v.shape and np.allclose(
                u, v, rtol=1e-9, atol=1e-9, equal_nan=True)
        if not ok:
            bad.append(name)
    return bad


def parity_stacks():
    """(label, policy, cluster) per audited timeline stack."""
    hermes = PolicySpec(Binding.EARLY, "H", "PS")
    late = PolicySpec(Binding.LATE, "LL", "FCFS")
    auto = PAR_CLUSTER._replace(
        fleet=FleetCfg(preset="two-gen", autoscale="TARGET_P99",
                       min_workers=2, target_p99=4.0, cooldown_s=2.0))
    return [
        ("E/LL/PS", E_LL_PS, PAR_CLUSTER),
        ("E/H/PS|mode-flips", hermes, PAR_CLUSTER),
        ("L/LL/FCFS", late, PAR_CLUSTER),
        ("E/LL/PS|fleet|auto", E_LL_PS, auto),
    ]


def _check_parity(policy, cluster, tel):
    """One stack: np ≡ jax per replication, then stream ≡ mono bitwise
    across a padded-tail chunking.  Returns (ok, mismatches)."""
    import jax.numpy as jnp

    bad = []
    wls = [synth_workload(cluster, load, PAR_N, n_functions=5, seed=seed)
           for load, seed in PAR_LOADS]
    # numpy oracle vs jax scan, one replication at a time (the oracle
    # is single-rep); integer planes bitwise, integrals to 1e-9
    for r, wl in enumerate(wls):
        ref = simulate_ref(policy, cluster, wl, telemetry=tel,
                           timeline=PAR_TL)
        jx = simulate(policy, cluster, wl, backend="jax", telemetry=tel,
                      timeline=PAR_TL)
        bad += [f"np/jax.r{r}.{m}" for m in _timelines_equal(
            ref.timeline, jx.timeline, bitwise_float=False)]
    # chunked stream vs monolithic batch, bitwise — the timeline rides
    # the carry, so final_states_equal covers it too.  (The streaming
    # engine is early-binding only; late stacks stop at np ≡ jax.)
    if policy.binding is Binding.LATE:
        return (not bad, bad)
    wb = stack_workloads(wls)
    run = build_batch_simulator(policy, cluster, n_arrivals=wb.n,
                                n_functions=wb.n_functions,
                                backend="jax", telemetry=tel,
                                timeline=PAR_TL)
    mono = run(jnp.asarray(wb.arrival), jnp.asarray(wb.func),
               jnp.asarray(wb.service), jnp.asarray(wb.u_lb),
               jnp.asarray(wb.func_home))
    out = simulate_stream(policy, cluster, wb, chunk_size=PAR_CHUNK,
                          backend="jax", telemetry=tel, timeline=PAR_TL,
                          keep_final_state=True)
    ok_st, bad_st = final_states_equal(out.final_state, mono)
    bad += [f"stream/mono.{m}" for m in bad_st]
    from repro.telemetry import TimelineResult
    import jax
    mono_tl = TimelineResult.from_state(
        jax.tree_util.tree_map(np.asarray, mono.tl), cfg=PAR_TL)
    bad += [f"stream/mono.tl.{m}" for m in _timelines_equal(
        out.timeline, mono_tl, bitwise_float=True)]
    return (not bad, bad)


def _parity_lane():
    tel = TelemetryCfg()
    rows = []
    for label, policy, cluster in parity_stacks():
        t0 = time.time()
        ok, bad = _check_parity(policy, cluster, tel)
        rows.append({
            "lane": "parity", "stack": label, "chunk": PAR_CHUNK,
            "n_arrivals": PAR_N, "n_reps": len(PAR_LOADS),
            "ok": bool(ok), "mismatches": ";".join(bad),
            "wall_s": round(time.time() - t0, 3)})
    return rows


def _check_shape(tl, wl, out_cold, out_rejected):
    """Timeline vs exact host recomputation on one run's outputs."""
    bad = []
    K = tl.n_windows
    ws = auto_window_s(float(wl.arrival[-1]), tl.cfg)
    if float(tl.window_s) != ws:
        bad.append("window_s")
    expect = np.bincount(
        np.asarray([window_index_np(float(t), ws, K)
                    for t in wl.arrival], dtype=np.int64), minlength=K)
    if not np.array_equal(tl.arrivals, expect):
        bad.append("arrivals!=host-recount")
    n_rej = int(np.asarray(out_rejected).sum())
    n_cold = int(np.asarray(out_cold).sum())
    placed = wl.n - n_rej
    if int(tl.n_reject.sum()) != n_rej:
        bad.append("n_reject-total")
    if int(tl.n_cold.sum()) != n_cold:
        bad.append("n_cold-total")
    if int(tl.n_cold.sum() + tl.n_warm.sum()) != placed:
        bad.append("placements-total")
    # the sketch takes every completion (no warmup cutoff — a flight
    # recorder must show the ramp)
    if int(tl.slow_hist.sum()) != placed:
        bad.append("slow-sketch-total")
    # the diurnal load shape must be visible in the window plane
    arr = np.asarray(tl.arrivals, dtype=np.float64)
    if not arr.max() > 1.25 * max(float(np.median(arr)), 1.0):
        bad.append(f"shape peak={arr.max():.0f} med={np.median(arr):.0f}")
    return bad


def _diurnal_lane():
    wl = WORKLOADS[DI_WORKLOAD](PAPER_TESTBED, DI_LOAD, DI_N, seed=3)
    rows = []
    t0 = time.time()
    out = simulate(E_LL_PS, PAPER_TESTBED, wl, backend="jax",
                   timeline=DI_TL)
    bad = _check_shape(out.timeline, wl, out.cold, out.rejected)
    rows.append({
        "lane": "diurnal", "stack": "E/LL/PS|scan",
        "workload": DI_WORKLOAD, "load": DI_LOAD, "n_arrivals": DI_N,
        "arrivals_peak": int(out.timeline.arrivals.max()),
        "arrivals_median": float(np.median(out.timeline.arrivals)),
        "ok": not bad, "mismatches": ";".join(bad),
        "wall_s": round(time.time() - t0, 3)})
    # same contract through the serving platform (controller latency,
    # health masks and migrations live here — the counters must still
    # reconcile with the platform's own per-arrival planes)
    from repro.serving.engine import ServeCfg, ServingCluster
    t0 = time.time()
    sv = ServingCluster(ServeCfg(cluster=PAPER_TESTBED), HERMES,
                        timeline=DI_TL).run(wl)
    bad = _check_shape(sv.timeline, wl, sv.cold, sv.rejected)
    rows.append({
        "lane": "diurnal", "stack": "hermes|serving",
        "workload": DI_WORKLOAD, "load": DI_LOAD, "n_arrivals": DI_N,
        "arrivals_peak": int(sv.timeline.arrivals.max()),
        "arrivals_median": float(np.median(sv.timeline.arrivals)),
        "ok": not bad, "mismatches": ";".join(bad),
        "wall_s": round(time.time() - t0, 3)})
    return rows


#: the decision-lane flight recorder from the last :func:`run` — the
#: export source for ``benchmarks.run --timeline-out`` (CSV +
#: OpenMetrics + Perfetto counter tracks) and ``RunManifest.timeline``
LAST_TIMELINE = None


def _decision_lane():
    global LAST_TIMELINE
    cl = PAPER_TESTBED._replace(fleet=DEC_FLEET)
    wl = WORKLOADS[DI_WORKLOAD](PAPER_TESTBED, DEC_LOAD, DEC_N, seed=1)
    t0 = time.time()
    out = simulate(HERMES, cl, wl, backend="jax",
                   telemetry=TelemetryCfg(), timeline=DEC_TL)
    tl = out.timeline
    LAST_TIMELINE = tl
    bad = []
    n_seen = int(tl.ev_count)
    if n_seen > int(DEC_TL.max_events):
        bad.append(f"log-truncated({n_seen}>{DEC_TL.max_events})")
        replay_ok = False
    else:
        # the log alone must reconstruct the engine's n_on plane on
        # every window that has an arrival (empty windows never get a
        # last-write-wins sample, so they stay at init)
        rep = tl.replay_n_on(cl.n_workers)
        mask = np.asarray(tl.arrivals) > 0
        replay_ok = bool(np.array_equal(rep[mask],
                                        np.asarray(tl.n_on)[mask]))
        if not replay_ok:
            bad.append("replay!=n_on")
    evs = tl.events() if n_seen <= int(DEC_TL.max_events) else []
    n_auto = sum(1 for e in evs if e["kind"] == "autoscale")
    if not evs:
        bad.append("no-decisions-logged")
    if n_auto and not all(np.isfinite(e["sensor_p99"]) for e in evs
                          if e["kind"] == "autoscale"):
        bad.append("sensor-p99-nonfinite")
    return [{
        "lane": "decision", "stack": "hermes|fleet|auto",
        "workload": DI_WORKLOAD, "load": DEC_LOAD, "n_arrivals": DEC_N,
        "n_events": n_seen, "n_autoscale": n_auto,
        "n_on_min": int(np.asarray(tl.n_on).min()),
        "n_on_max": int(np.asarray(tl.n_on).max()),
        "ok": not bad, "mismatches": ";".join(bad),
        "wall_s": round(time.time() - t0, 3)}]


def run(quick: bool = True):
    # the lanes are gate-sized (exactness checks don't get stronger
    # with N); full mode just repeats the decision lane across seeds
    rows = _parity_lane()
    rows += _diurnal_lane()
    rows += _decision_lane()
    cols = {k: None for r in rows for k in r}
    write_csv("fig15_timeline.csv",
              [{k: r.get(k, "") for k in cols} for r in rows])
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['lane']:9s} {r['stack']:24s} "
              f"{'OK ' if r['ok'] else 'BAD'} {r['mismatches'] or ''}")
